"""§Roofline — three-term roofline per (arch × shape), from the dry-run.

Sources and method (see EXPERIMENTS.md §Roofline):
  * per-device FLOPs / bytes from ``compiled.cost_analysis()`` of the
    UNROLLED depth-1/2 variants, extrapolated exactly for the uniform
    stacks:  total = f(1) + (units-1)·(f(2)-f(1));
  * per-device collective wire bytes parsed from the compiled HLO of the
    same variants (launch/hlo_analysis.py), same extrapolation;
  * hardware: TPU v5e — 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link
    ICI (terms below charge one link; a 2D-torus ring uses 2+ links, so
    the collective term is conservative by ~2×).

Terms (seconds per step, per chip — the slowest chip sets the pace):
  compute    = HLO_FLOPs_dev / 197e12
  memory     = HLO_bytes_dev / 819e9
  collective = wire_bytes_dev / 50e9

MODEL_FLOPS = 6·N_active·D (train) or 2·N_active·D (inference); the
ratio MODEL_FLOPS / (HLO_FLOPs_dev × chips) exposes remat/dispatch/
padding waste.

Usage: PYTHONPATH=src python -m benchmarks.roofline [--dir results/dryrun]
"""
from __future__ import annotations

import argparse
import json
import pathlib

PEAK_FLOPS = 197e12
HBM_BW = 819e9
LINK_BW = 50e9

SHAPE_TOKENS = {
    "train_4k": 4096 * 256,
    "prefill_32k": 32768 * 32,
    "decode_32k": 128,       # one token per sequence
    "long_500k": 1,
}
TRAIN_FACTOR = {"train_4k": 6.0}  # fwd+bwd; inference shapes use 2.0


def load_cell(dir_: pathlib.Path, arch: str, shape: str, mesh: str, depth: str):
    f = dir_ / f"{arch}__{shape}__{mesh}__d{depth}.json"
    if not f.exists():
        return None
    return json.loads(f.read_text())


def extrapolate(d1: dict, d2: dict) -> dict:
    """total = f(1) + (units-1)·(f(2)-f(1)), per metric."""
    units = d1["units_total"]

    def ext(a, b):
        return a + (units - 1) * (b - a)

    def adj(d):
        return d["collectives"].get("wire_bytes_bf16_adjusted",
                                    d["collectives"]["wire_bytes"])

    return {
        "flops": ext(d1["flops"], d2["flops"]),
        "bytes": ext(d1["bytes_accessed"], d2["bytes_accessed"]),
        "wire": ext(d1["collectives"]["wire_bytes"], d2["collectives"]["wire_bytes"]),
        "wire_adj": ext(adj(d1), adj(d2)),
    }


def analyze(dir_: pathlib.Path, arch: str, shape: str, mesh: str = "pod") -> dict | None:
    d1 = load_cell(dir_, arch, shape, mesh, "1")
    d2 = load_cell(dir_, arch, shape, mesh, "2")
    dfull = load_cell(dir_, arch, shape, mesh, "full")
    if not d1 or not d2:
        return None
    if "skipped" in d1:
        return {"arch": arch, "shape": shape, "skipped": d1["skipped"]}
    tot = extrapolate(d1, d2)
    chips = d1["n_devices"]
    t_compute = tot["flops"] / PEAK_FLOPS
    t_memory = tot["bytes"] / HBM_BW
    t_coll = tot["wire_adj"] / LINK_BW  # bf16-adjusted (see hlo_analysis)
    terms = {"compute": t_compute, "memory": t_memory, "collective": t_coll}
    dominant = max(terms, key=terms.get)
    step_s = max(terms.values())  # perfectly-overlapped lower bound

    factor = TRAIN_FACTOR.get(shape, 2.0)
    n_act = d1["model_params_active"]
    model_flops = factor * n_act * SHAPE_TOKENS[shape]
    hlo_global = tot["flops"] * chips
    useful = model_flops / hlo_global if hlo_global else 0.0
    # roofline fraction: useful model FLOPs per second at the step's pace
    # vs the chips' peak
    mfu = model_flops / (step_s * chips * PEAK_FLOPS) if step_s else 0.0

    from repro.configs import get_config as _gc

    floor = memory_floor_bytes(_gc(arch), shape, chips) / HBM_BW
    step_floor = max(t_compute, floor, t_coll)
    mfu_floor = model_flops / (step_floor * chips * PEAK_FLOPS) if step_floor else 0.0

    out = {
        "arch": arch, "shape": shape, "mesh": mesh, "chips": chips,
        "compute_s": t_compute, "memory_s": t_memory, "collective_s": t_coll,
        "collective_raw_s": tot["wire"] / LINK_BW,
        "memory_floor_s": floor,
        "roofline_fraction_at_floor": mfu_floor,
        "dominant": dominant, "step_s_lb": step_s,
        "model_flops": model_flops, "hlo_flops_global": hlo_global,
        "useful_ratio": useful, "roofline_fraction": mfu,
    }
    if dfull and "memory" in dfull:
        out["full_temp_gib"] = dfull["memory"]["temp_bytes"] / 2**30
        out["full_args_gib"] = dfull["memory"]["argument_bytes"] / 2**30
        out["full_compile_s"] = dfull.get("compile_s")
    return out


def memory_floor_bytes(cfg, shape: str, chips: int) -> float:
    """Analytic per-device HBM-traffic floor (order of magnitude): the
    weight/state/activation bytes an ideal fused TPU implementation must
    stream.  Brackets the truth against the pre-fusion upper bound that
    cost_analysis reports (§Roofline methodology note 2)."""
    n_bytes = 2.0 * cfg.param_count()
    d, L = cfg.d_model, cfg.num_layers
    toks = SHAPE_TOKENS[shape]
    if shape == "train_4k":
        micro = 8
        # weight streams per microbatch (fwd + remat-fwd + bwd ≈ 3 reads of
        # the FSDP-gathered weights) + fp32 optimizer read/write + per-layer
        # activation write/read (coarse ×4 for remat)
        act = toks / chips * d * L * 2 * 4
        opt = 24.0 * cfg.param_count() / chips
        return micro * 3 * n_bytes + opt + act
    if shape == "prefill_32k":
        act = toks / chips * d * L * 2 * 4
        kv = toks * cfg.num_layers * cfg.kv_bytes_per_token_per_layer() / chips
        return n_bytes / 16 + act + kv
    # decode: weights (TP-sharded) + the whole resident KV once per token
    ctx = 32768 if shape == "decode_32k" else 524288
    b = 128 if shape == "decode_32k" else 1
    kv = b * ctx * cfg.num_layers * cfg.kv_bytes_per_token_per_layer() / chips
    return n_bytes / 16 + kv


MOVE_HINTS = {
    "compute": "raise arithmetic efficiency: bigger fused matmul tiles / drop "
               "remat recompute on cheap layers / bf16-native softmax",
    "memory": "cut HBM traffic: fuse elementwise chains, keep fp32 accumulators "
              "in VMEM, quantize KV reads (int8)",
    "collective": "re-shard to shrink wire bytes: overlap collectives with "
                  "compute, move all-gathers to the smaller operand, or batch "
                  "per-layer collectives",
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="results/dryrun")
    ap.add_argument("--mesh", default="pod")
    ap.add_argument("--out", default="results/roofline.md")
    ap.add_argument("--bench-out", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="also merge per-cell roofline terms into the "
                         "BENCH_<pr>.json trajectory point (repro.obs.bench)")
    ap.add_argument("--pr", type=int, default=None,
                    help="PR number for --bench-out (default: run.py's)")
    args = ap.parse_args()
    dir_ = pathlib.Path(args.dir)

    from repro.configs import ASSIGNED
    from repro.launch.steps import SHAPES

    rows, skips = [], []
    for arch in ASSIGNED:
        for shape in SHAPES:
            r = analyze(dir_, arch, shape, args.mesh)
            if r is None:
                continue
            if "skipped" in r:
                skips.append(r)
            else:
                rows.append(r)

    lines = [
        "| arch | shape | compute_s | memory_s [floor, upper] | collective_s | "
        "dominant | MODEL/HLO | roofline_frac [upper, floor] | temp GiB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for r in rows:
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['compute_s']:.3e} | "
            f"[{r['memory_floor_s']:.2e}, {r['memory_s']:.2e}] | "
            f"{r['collective_s']:.3e} | {r['dominant']} | "
            f"{r['useful_ratio']:.2f} | "
            f"[{r['roofline_fraction']:.3f}, {r['roofline_fraction_at_floor']:.3f}] | "
            f"{r.get('full_temp_gib', float('nan')):.1f} |"
        )
    for s in skips:
        lines.append(f"| {s['arch']} | {s['shape']} | — | — | — | SKIPPED | — | — | — |")
    table = "\n".join(lines)
    print(table)
    pathlib.Path(args.out).parent.mkdir(parents=True, exist_ok=True)
    pathlib.Path(args.out).write_text(table + "\n")
    # per-cell JSON for downstream tooling
    (pathlib.Path(args.out).parent / "roofline.json").write_text(
        json.dumps({"cells": rows, "skipped": skips}, indent=2))
    if args.bench_out is not None and rows:
        import sys
        sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))
        from benchmarks.run import BENCH_PR
        from repro.obs.bench import BenchTrajectory, bench_path
        pr = args.pr if args.pr is not None else BENCH_PR
        traj = BenchTrajectory(pr, source="benchmarks.roofline")
        for r in rows:
            cell = f"roofline/{r['arch']}/{r['shape']}"
            traj.add(f"{cell}/step_s_lb", r["step_s_lb"] * 1e6, unit="us",
                     dominant=r["dominant"])
            traj.add(f"{cell}/roofline_fraction", r["roofline_fraction"],
                     unit="frac")
        out = traj.write(args.bench_out or bench_path(pr))
        print(f"# merged {2 * len(rows)} roofline entries into {out}")


if __name__ == "__main__":
    main()
