"""Fig. 14 — latency breakdown across the request lifecycle.

Paper: transfer is 1.1 % (arXiv) / 0.5 % (ShareGPT) of end-to-end
latency — the optimizations make transfer negligible; decode-side
activities dominate, with decode queuing reaching 52 % / 30 % at
QPS 0.5.

Two sources, one figure:

* the event simulator at paper scale (mistral-large-123b, arXiv +
  ShareGPT workloads) — the modeled breakdown;
* a LIVE cell (``fig14/live/...``): a real-substrate ``DisaggService``
  run with the span tracer on, its breakdown computed from the recorded
  per-request lifecycle spans (``repro.obs.breakdown``).  Same component
  names, so the live fractions cross-check the sim's directly — the
  live transfer fraction is the measured analogue of the paper's 1.1 %.
"""
from __future__ import annotations

from benchmarks.common import Row
from repro.configs import get_config
from repro.sim.costs import CostModel, H100_NODE
from repro.sim.events import ClusterSim, SimConfig
from repro.sim.workloads import ARXIV, SHAREGPT, sample_requests


def _live_rows() -> list[Row]:
    """Real-substrate breakdown from lifecycle spans (smoke scale)."""
    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models.registry import build_model
    from repro.obs import Tracer, all_request_breakdowns, mean_fractions
    from repro.serving.disagg import DisaggService

    cfg = get_smoke_config("deepseek-67b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    tracer = Tracer()
    svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                        num_blocks=64, tracer=tracer)
    rng = np.random.default_rng(7)
    handles = [svc.submit(rng.integers(0, cfg.vocab_size, size=16), max_new=2)
               for _ in range(2)]
    svc.loop.run_until_idle()
    breakdowns = all_request_breakdowns(tracer)
    fr = mean_fractions(breakdowns.values())
    ttlt = sum(b.ttlt_s for b in breakdowns.values()) / max(len(breakdowns), 1)
    assert all(h.done for h in handles)
    return [Row(
        "fig14/live/smoke", ttlt * 1e6,
        f"transfer_frac={fr['transfer_s']:.4f};"
        f"decode_frac={fr['decode_s']:.2f};"
        f"queue_frac={fr['queue_s']:.2f};"
        f"prefill_frac={fr['prefill_s']:.2f};"
        f"n={len(breakdowns)}",
    )]


def run() -> list[Row]:
    cfg = get_config("mistral-large-123b")
    rows = []
    for spec in (ARXIV, SHAREGPT):
        for qps in (0.25, 0.5):
            sim = ClusterSim(CostModel(cfg, H100_NODE),
                             SimConfig(n_prefill=1, n_decode=1, mode="pull"))
            reqs = sample_requests(spec, qps=qps, duration_s=240, seed=17)
            res = sim.run(reqs)
            b = res.mean_breakdown()
            total = max(sum(b.values()), 1e-9)
            fr = {k: v / total for k, v in b.items()}
            note = ";paper_transfer=0.011" if spec is ARXIV else ";paper_transfer=0.005"
            rows.append(Row(
                f"fig14/{spec.name}/qps{qps}", total * 1e6,
                f"transfer_frac={fr['transfer_s']:.4f};"
                f"decode_frac={fr['decode_s']:.2f};"
                f"queue_frac={fr['prefill_queue_s'] + fr['decode_queue_s']:.2f}"
                + (note if qps == 0.5 else ""),
            ))
    rows.extend(_live_rows())
    return rows
