"""Fig. 14 — latency breakdown across the request lifecycle.

Paper: transfer is 1.1 % (arXiv) / 0.5 % (ShareGPT) of end-to-end
latency — the optimizations make transfer negligible; decode-side
activities dominate, with decode queuing reaching 52 % / 30 % at
QPS 0.5.
"""
from __future__ import annotations

from benchmarks.common import Row
from repro.configs import get_config
from repro.sim.costs import CostModel, H100_NODE
from repro.sim.events import ClusterSim, SimConfig
from repro.sim.workloads import ARXIV, SHAREGPT, sample_requests


def run() -> list[Row]:
    cfg = get_config("mistral-large-123b")
    rows = []
    for spec in (ARXIV, SHAREGPT):
        for qps in (0.25, 0.5):
            sim = ClusterSim(CostModel(cfg, H100_NODE),
                             SimConfig(n_prefill=1, n_decode=1, mode="pull"))
            reqs = sample_requests(spec, qps=qps, duration_s=240, seed=17)
            res = sim.run(reqs)
            b = res.mean_breakdown()
            total = max(sum(b.values()), 1e-9)
            fr = {k: v / total for k, v in b.items()}
            note = ";paper_transfer=0.011" if spec is ARXIV else ";paper_transfer=0.005"
            rows.append(Row(
                f"fig14/{spec.name}/qps{qps}", total * 1e6,
                f"transfer_frac={fr['transfer_s']:.4f};"
                f"decode_frac={fr['decode_s']:.2f};"
                f"queue_frac={fr['prefill_queue_s'] + fr['decode_queue_s']:.2f}"
                + (note if qps == 0.5 else ""),
            ))
    return rows
