"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (see benchmarks/common.py).
Run as ``PYTHONPATH=src python -m benchmarks.run [--only fig13,fig15]``.

``--json [PATH]`` additionally writes the rows as one schema-versioned
``BENCH_<pr>.json`` point of the cross-PR regression trajectory
(``repro.obs.bench``; default path ``BENCH_<pr>.json`` at the repo
root, merging with entries other writers — e.g. ``roofline.py
--bench-out`` — already put there).
"""
from __future__ import annotations

import argparse
import importlib
import pathlib
import sys
import traceback

MODULES = [
    "fig03_message_timeline",
    "fig04_message_bandwidth",
    "fig06_motivation_qps",
    "fig12_cluster_config",
    "fig13_latency_qps",
    "fig14_breakdown",
    "fig15_bandwidth",
    "fig16_pull_vs_push",
    "fig17_coalescing",
    "fig_continuous",
    "fig_elastic",
    "fig_overlap",
    "fig_prefix_reuse",
    "fig_sched_policies",
    "fig_topology",
    "kernel_bench",
]

# The PR number stamped into BENCH_<pr>.json artifacts.  Bump when a new
# PR wants its own trajectory point (see repro.obs.bench.load_trajectory).
BENCH_PR = 10


def select_modules(prefixes: list[str]) -> list[str]:
    """Modules matching the ``--only`` prefixes (all when none given).
    A prefix that matches NO module is an error — a typo'd ``--only``
    must not silently benchmark nothing."""
    if not prefixes:
        return list(MODULES)
    dead = [p for p in prefixes
            if not any(m.startswith(p) for m in MODULES)]
    if dead:
        raise SystemExit(
            f"--only prefix(es) {dead} match no benchmark module; "
            f"available: {', '.join(MODULES)}")
    return [m for m in MODULES if any(m.startswith(p) for p in prefixes)]


def main(argv: list[str] | None = None) -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated module prefixes")
    ap.add_argument("--json", nargs="?", const="", default=None, metavar="PATH",
                    help="also write rows to a BENCH_<pr>.json trajectory "
                         "point (default path: BENCH_%d.json)" % BENCH_PR)
    ap.add_argument("--pr", type=int, default=BENCH_PR,
                    help="PR number stamped into the --json artifact")
    args = ap.parse_args(argv)
    prefixes = [p for p in args.only.split(",") if p]
    modules = select_modules(prefixes)

    print("name,us_per_call,derived")
    rows = []
    failed = []
    for mod_name in modules:
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
            for row in mod.run():
                print(row.csv(), flush=True)
                rows.append(row)
        except Exception:  # noqa: BLE001
            failed.append(mod_name)
            traceback.print_exc()
    if args.json is not None and rows:
        sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))
        from repro.obs.bench import BenchTrajectory, bench_path, validate_bench
        traj = BenchTrajectory(args.pr, source="benchmarks.run")
        traj.extend_rows(rows)
        out = traj.write(args.json or bench_path(args.pr))
        import json as _json
        doc = validate_bench(_json.loads(out.read_text()))  # self-check
        print(f"# wrote {out} ({len(rows)} rows this run, "
              f"{len(doc['entries'])} entries total)", file=sys.stderr)
    if failed:
        print(f"# FAILED modules: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
