"""Benchmark harness — one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (see benchmarks/common.py).
Run as ``PYTHONPATH=src python -m benchmarks.run [--only fig13,fig15]``.
"""
from __future__ import annotations

import argparse
import importlib
import sys
import traceback

MODULES = [
    "fig03_message_timeline",
    "fig04_message_bandwidth",
    "fig06_motivation_qps",
    "fig12_cluster_config",
    "fig13_latency_qps",
    "fig14_breakdown",
    "fig15_bandwidth",
    "fig16_pull_vs_push",
    "fig17_coalescing",
    "fig_continuous",
    "fig_overlap",
    "fig_sched_policies",
    "kernel_bench",
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default="", help="comma-separated module prefixes")
    args = ap.parse_args()
    prefixes = [p for p in args.only.split(",") if p]

    print("name,us_per_call,derived")
    failed = []
    for mod_name in MODULES:
        if prefixes and not any(mod_name.startswith(p) for p in prefixes):
            continue
        try:
            mod = importlib.import_module(f"benchmarks.{mod_name}")
            for row in mod.run():
                print(row.csv(), flush=True)
        except Exception:  # noqa: BLE001
            failed.append(mod_name)
            traceback.print_exc()
    if failed:
        print(f"# FAILED modules: {failed}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
