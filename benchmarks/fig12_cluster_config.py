"""Fig. 12 — cluster-configuration study (xP yD × prompt × response).

Paper effects reproduced:
  (a) more decode workers cut decode-stage time and, for long responses,
      also prefill-stage time (less KV-allocation blocking);
  (b) more prefill workers cut prefill time (2.34×-4.04× from 1P→2P);
      3P can REGRESS total latency: extra prefill throughput floods the
      decode worker and intensifies decode contention.

By default every cell runs on homogeneous reference nodes.  Pass
``--cluster PRESET[:SEED]`` to replay the grid on a generated
heterogeneous ``ClusterSpec`` instead — the SAME seeded cluster source
``benchmarks.fig_topology`` sweeps (``repro.topo.generate_cluster``), so
the two studies cannot drift apart on what "the cluster" is.  Each xP yD
cell then asks the placement planner for the best machines at exactly
those pinned role counts (the rest idle as spares).
"""
from __future__ import annotations

import argparse

from benchmarks.common import Row
from repro.configs import get_config
from repro.sim.costs import CostModel, H100_NODE
from repro.sim.events import ClusterSim, SimConfig
from repro.sim.workloads import fixed_requests

# (prompt_len, qps) pairs per the paper's loading scheme
GRID = [(8192, 2.0), (16384, 1.0), (32768, 0.5), (65536, 0.3)]


def _run(prompt, resp, qps, n_p, n_d, spec=None) -> dict:
    cfg = get_config("mistral-large-123b")
    reqs = fixed_requests(prompt, resp, qps=qps, duration_s=200, seed=5)
    cost = CostModel(cfg, H100_NODE)
    sim_cfg = SimConfig(n_prefill=n_p, n_decode=n_d, mode="pull")
    if spec is None:
        sim = ClusterSim(cost, sim_cfg)
    else:
        from repro.topo import PlacementPlanner, TopologyBinding, WorkloadShape
        planner = PlacementPlanner(shape=WorkloadShape.from_cost(
            cost, prompt_len=prompt, response_len=resp))
        placement = planner.plan(spec, n_prefill=n_p, n_decode=n_d)
        sim = ClusterSim(cost, sim_cfg,
                         topology=TopologyBinding(spec, placement,
                                                  planner=planner))
    res = sim.run(reqs)
    s = res.summary()
    b = res.mean_breakdown()
    return {
        "total": s["mean_total_s"],
        "prefill_stage": b["prefill_queue_s"] + b["prefill_s"] + b["transfer_s"]
        + b["decode_queue_s"],
        "decode_stage": b["decode_s"],
        "tbt": s["p50_tbt_s"],
    }


def run(spec=None) -> list[Row]:
    tag = "" if spec is None else f";cluster={spec.name}"
    rows = []
    # (a) decode scaling at response 1024
    for prompt, qps in GRID[:3]:
        r1 = _run(prompt, 1024, qps, 1, 1, spec)
        r3 = _run(prompt, 1024, qps, 1, 3, spec)
        rows.append(Row(
            f"fig12a/{prompt}-1024/1P3D", r3["total"] * 1e6,
            f"decode_stage_cut={1 - r3['decode_stage']/max(r1['decode_stage'],1e-9):.2f};"
            f"prefill_stage_cut={1 - r3['prefill_stage']/max(r1['prefill_stage'],1e-9):.2f}"
            f"{tag}",
        ))
    # (b) prefill scaling at response 128
    for prompt, qps in GRID:
        r1 = _run(prompt, 128, qps, 1, 1, spec)
        r2 = _run(prompt, 128, qps, 2, 1, spec)
        rows.append(Row(
            f"fig12b/{prompt}-128/2P1D", r2["total"] * 1e6,
            f"prefill_speedup={r1['prefill_stage']/max(r2['prefill_stage'],1e-9):.2f}x;"
            f"paper_range=2.34-4.04x{tag}",
        ))
    # (b) the 3P regression
    r2 = _run(16384, 1024, 1.5, 2, 1, spec)
    r3 = _run(16384, 1024, 1.5, 3, 1, spec)
    rows.append(Row(
        "fig12b/16384-1024/3P1D-regression", r3["total"] * 1e6,
        f"total_vs_2P={r3['total']/max(r2['total'],1e-9):.3f}x;"
        f"paper=>1 (regression){tag}",
    ))
    return rows


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--cluster", default=None, metavar="PRESET[:SEED]",
                    help="replay the grid on a generated heterogeneous "
                         "ClusterSpec (e.g. hetero_rack:0) — the same "
                         "seeded source fig_topology sweeps")
    args = ap.parse_args()
    spec = None
    if args.cluster is not None:
        from repro.topo import generate_cluster
        preset, _, seed = args.cluster.partition(":")
        spec = generate_cluster(preset, int(seed) if seed else 0)
    print("name,us_per_call,derived")
    for row in run(spec=spec):
        print(row.csv())


if __name__ == "__main__":
    main()
