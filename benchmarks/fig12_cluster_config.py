"""Fig. 12 — cluster-configuration study (xP yD × prompt × response).

Paper effects reproduced:
  (a) more decode workers cut decode-stage time and, for long responses,
      also prefill-stage time (less KV-allocation blocking);
  (b) more prefill workers cut prefill time (2.34×-4.04× from 1P→2P);
      3P can REGRESS total latency: extra prefill throughput floods the
      decode worker and intensifies decode contention.
"""
from __future__ import annotations

from benchmarks.common import Row
from repro.configs import get_config
from repro.sim.costs import CostModel, H100_NODE
from repro.sim.events import ClusterSim, SimConfig
from repro.sim.workloads import fixed_requests

# (prompt_len, qps) pairs per the paper's loading scheme
GRID = [(8192, 2.0), (16384, 1.0), (32768, 0.5), (65536, 0.3)]


def _run(prompt, resp, qps, n_p, n_d) -> dict:
    cfg = get_config("mistral-large-123b")
    reqs = fixed_requests(prompt, resp, qps=qps, duration_s=200, seed=5)
    sim = ClusterSim(CostModel(cfg, H100_NODE),
                     SimConfig(n_prefill=n_p, n_decode=n_d, mode="pull"))
    res = sim.run(reqs)
    s = res.summary()
    b = res.mean_breakdown()
    return {
        "total": s["mean_total_s"],
        "prefill_stage": b["prefill_queue_s"] + b["prefill_s"] + b["transfer_s"]
        + b["decode_queue_s"],
        "decode_stage": b["decode_s"],
        "tbt": s["p50_tbt_s"],
    }


def run() -> list[Row]:
    rows = []
    # (a) decode scaling at response 1024
    for prompt, qps in GRID[:3]:
        r1 = _run(prompt, 1024, qps, 1, 1)
        r3 = _run(prompt, 1024, qps, 1, 3)
        rows.append(Row(
            f"fig12a/{prompt}-1024/1P3D", r3["total"] * 1e6,
            f"decode_stage_cut={1 - r3['decode_stage']/max(r1['decode_stage'],1e-9):.2f};"
            f"prefill_stage_cut={1 - r3['prefill_stage']/max(r1['prefill_stage'],1e-9):.2f}",
        ))
    # (b) prefill scaling at response 128
    for prompt, qps in GRID:
        r1 = _run(prompt, 128, qps, 1, 1)
        r2 = _run(prompt, 128, qps, 2, 1)
        rows.append(Row(
            f"fig12b/{prompt}-128/2P1D", r2["total"] * 1e6,
            f"prefill_speedup={r1['prefill_stage']/max(r2['prefill_stage'],1e-9):.2f}x;"
            f"paper_range=2.34-4.04x",
        ))
    # (b) the 3P regression
    r2 = _run(16384, 1024, 1.5, 2, 1)
    r3 = _run(16384, 1024, 1.5, 3, 1)
    rows.append(Row(
        "fig12b/16384-1024/3P1D-regression", r3["total"] * 1e6,
        f"total_vs_2P={r3['total']/max(r2['total'],1e-9):.3f}x;paper=>1 (regression)",
    ))
    return rows
