"""Delta KV transfer sweep: prefix reuse rate × QPS, sim + real.

Workload: fixed-shape requests where every arrival shares the first
``PREFIX_FRAC`` of its prompt with the other requests carrying the same
prefix id (a handful of shared system prompts — the RAG / multi-turn
shape that motivates delta transfer).  Three transfer variants on the
discrete-event simulator (2 prefill × 2 decode, pull mode):

  * ``full``        — every admission pulls the whole prompt's KV
    (the PR 5/6 baseline);
  * ``delta``       — decode workers retain finished prefixes and graft
    them into later admissions, pulling only the suffix
    (``SimConfig(delta_transfer=True)``);
  * ``delta_quant`` — delta plus int8 wire quantization: the suffix
    that still moves costs half the bytes
    (``quantize_transfer=True``).

The reported metric is the KV-INCLUSIVE TTFT (arrival → decodable on
the decode worker), the quantity the skipped prefix bytes shorten.
Acceptance shape (asserted): at EVERY swept QPS the delta variant's p90
KV-inclusive TTFT is strictly below full-pull, and the steady-state
reuse fraction is within block granularity of the workload's prefix
fraction.

``real_cells()`` measures the same contrast END-TO-END on the real
substrate (JAX compute + real bytes through the transfer engine): one
cold request per prefix, then warm requests whose admissions graft the
retained prefix.  Asserts (a) token streams identical between delta and
full-pull, (b) warm-request pulled bytes reduced by at least the
resident-prefix fraction (exact accounting: pulled + reused always sums
to the full KV footprint), and records the wire-byte halving of the
quantized cell.

As a benchmark module it emits CSV rows through run.py (and lands in
``BENCH_<pr>.json`` via ``--json``); run directly it writes the full
sweep as JSON:

    PYTHONPATH=src python -m benchmarks.fig_prefix_reuse [--fast] \
        [--out fig_prefix_reuse.json] [--bench-out [PATH]]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from benchmarks.common import Row
from repro.configs import get_config
from repro.sim.costs import CostModel, H100_NODE
from repro.sim.events import ClusterSim, SimConfig
from repro.sim.workloads import shared_prefix_requests

DURATION = 120.0
QPS_GRID = (0.25, 0.5, 1.0, 2.0)
FAST_QPS_GRID = (0.5, 2.0)
PROMPT_LEN = 8192
RESPONSE_LEN = 256
PREFIX_FRAC = 0.6   # acceptance floor: ≥ 50 % of the prompt is shared
N_PREFIXES = 2
SEED = 13

VARIANTS = ("full", "delta", "delta_quant")
_VARIANT_CFG = {
    "full": dict(delta_transfer=False),
    "delta": dict(delta_transfer=True),
    "delta_quant": dict(delta_transfer=True, quantize_transfer=True),
}


def _run(cfg: SimConfig, reqs) -> dict[str, float]:
    return ClusterSim(
        CostModel(get_config("mistral-large-123b"), H100_NODE), cfg
    ).run(list(reqs)).summary()


def sweep(fast: bool = False) -> list[dict]:
    cells = []
    duration = 30.0 if fast else DURATION
    for qps in (FAST_QPS_GRID if fast else QPS_GRID):
        reqs = shared_prefix_requests(
            PROMPT_LEN, RESPONSE_LEN, qps=qps, duration_s=duration,
            prefix_frac=PREFIX_FRAC, n_prefixes=N_PREFIXES, seed=SEED)
        for variant in VARIANTS:
            s = _run(SimConfig(n_prefill=2, n_decode=2, mode="pull",
                               **_VARIANT_CFG[variant]), reqs)
            cells.append({
                "variant": variant, "qps": qps, "n": int(s["n"]),
                "p50_ttft_kv_s": s["p50_ttft_kv_s"],
                "p90_ttft_kv_s": s["p90_ttft_kv_s"],
                "p90_total_s": s["p90_total_s"],
                "kv_reuse_frac": s["kv_reuse_frac"],
                "mean_pulled_tokens": s["mean_pulled_tokens"],
                "mean_reused_tokens": s["mean_reused_tokens"],
            })
    # acceptance: the delta variants beat full-pull at EVERY swept QPS,
    # and the skipped bytes track the workload's shared fraction
    for qps in {c["qps"] for c in cells}:
        base = next(c for c in cells
                    if c["qps"] == qps and c["variant"] == "full")
        for variant in ("delta", "delta_quant"):
            c = next(x for x in cells
                     if x["qps"] == qps and x["variant"] == variant)
            assert c["p90_ttft_kv_s"] < base["p90_ttft_kv_s"], (
                f"{variant} p90 ttft_kv {c['p90_ttft_kv_s']:.4f}s not below "
                f"full-pull {base['p90_ttft_kv_s']:.4f}s at qps={qps}")
            assert c["kv_reuse_frac"] > 0.5 * PREFIX_FRAC, (
                f"{variant} reuse_frac {c['kv_reuse_frac']:.3f} too far "
                f"below the workload's shared fraction {PREFIX_FRAC}")
    return cells


# ------------------------------------------------------------- real path
def real_cells(n_requests: int = 6, prompt_len: int = 64,
               prefix_frac: float = 0.5, max_new: int = 4) -> list[dict]:
    """End-to-end delta-vs-full comparison on the real serving substrate
    (CPU-scale: smoke model, memcpy engine, real KV bytes).

    One shared prefix; requests submitted SEQUENTIALLY so request 0's
    retained prefix is resident when requests 1.. admit.  Per variant we
    record the exact pulled/reused byte split the engine accounted and
    the engine-level wire bytes (quantized pulls move half)."""
    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models.transformer import DecoderLM
    from repro.serving.disagg import DisaggService

    cfg = get_smoke_config("deepseek-67b")
    model = DecoderLM(cfg, unroll=True)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(SEED)
    prefix_len = (int(prompt_len * prefix_frac)
                  // model.BLOCK_SIZE) * model.BLOCK_SIZE
    shared = rng.integers(0, cfg.vocab_size, prefix_len).astype(np.int32)
    toks = [np.concatenate([
        shared,
        rng.integers(0, cfg.vocab_size, prompt_len - prefix_len)
        .astype(np.int32),
    ]) for _ in range(n_requests)]

    cells = []
    token_streams: dict[str, list[list[int]]] = {}
    metrics: dict[str, list[dict]] = {}
    for variant in VARIANTS:
        svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                            num_blocks=256, **_VARIANT_CFG[variant])
        outs, per_req = [], []
        for t in toks:  # sequential: request i's prefix is warm for i+1
            h = svc.submit(t, prefix_id="sys", prefix_len=prefix_len)
            outs.append(svc.generate(h, max_new=max_new))
            per_req.append({
                "pulled_bytes": h.metrics.kv_bytes_pulled,
                "reused_bytes": h.metrics.kv_bytes_reused,
                "reuse_frac": h.metrics.kv_reuse_frac,
            })
        token_streams[variant] = outs
        metrics[variant] = per_req
        warm = per_req[1:]
        cells.append({
            "variant": variant, "n": n_requests, "prompt_len": prompt_len,
            "prefix_len": prefix_len, "max_new": max_new,
            "cold_pulled_bytes": per_req[0]["pulled_bytes"],
            "warm_mean_pulled_bytes":
                sum(r["pulled_bytes"] for r in warm) / len(warm),
            "warm_mean_reuse_frac":
                sum(r["reuse_frac"] for r in warm) / len(warm),
            "wire_bytes_moved": svc.engine.stats.bytes_moved,
        })

    # (a) the delta plan changes which bytes MOVE, never which bytes the
    # model sees: token streams are bit-identical to full pull
    assert token_streams["full"] == token_streams["delta"], \
        "delta transfer diverged from full pull on the real path"
    # (b) warm pulls shrink by at least the resident-prefix fraction —
    # exact accounting: pulled + reused covers the full KV footprint
    resident_frac = prefix_len / prompt_len
    full = metrics["full"]
    for i, r in enumerate(metrics["delta"][1:], start=1):
        assert r["pulled_bytes"] + r["reused_bytes"] \
            == full[i]["pulled_bytes"], "pulled+reused != full KV footprint"
        assert r["reuse_frac"] >= resident_frac - 1e-9, (
            f"warm request {i}: reuse_frac {r['reuse_frac']:.3f} below "
            f"resident prefix fraction {resident_frac:.3f}")
    # (c) quantized suffix pulls halve the wire bytes the suffix costs
    dq = next(c for c in cells if c["variant"] == "delta_quant")
    d = next(c for c in cells if c["variant"] == "delta")
    assert dq["wire_bytes_moved"] < d["wire_bytes_moved"], \
        "int8 wire pages did not reduce bytes moved"
    return cells


def _rows(cells: list[dict], real: list[dict] | None = None) -> list[Row]:
    rows = []
    for c in cells:
        rows.append(Row(
            f"prefix_reuse/qps{c['qps']}/{c['variant']}",
            c["p90_ttft_kv_s"] * 1e6,
            f"p50_ttft_kv={c['p50_ttft_kv_s']:.3f}s;"
            f"reuse_frac={c['kv_reuse_frac']:.3f};"
            f"pulled_tok={c['mean_pulled_tokens']:.0f};"
            f"reused_tok={c['mean_reused_tokens']:.0f}",
        ))
    for qps in sorted({c["qps"] for c in cells}):
        base = next(c for c in cells
                    if c["qps"] == qps and c["variant"] == "full")
        delta = next(c for c in cells
                     if c["qps"] == qps and c["variant"] == "delta")
        quant = next(c for c in cells
                     if c["qps"] == qps and c["variant"] == "delta_quant")
        rows.append(Row(
            f"prefix_reuse/qps{qps}/summary", 0.0,
            f"full_vs_delta_p90_ttft_kv="
            f"{base['p90_ttft_kv_s'] / max(delta['p90_ttft_kv_s'], 1e-9):.2f}x;"
            f"full_vs_delta_quant="
            f"{base['p90_ttft_kv_s'] / max(quant['p90_ttft_kv_s'], 1e-9):.2f}x"))
    for c in real or []:
        rows.append(Row(
            f"prefix_reuse/real/{c['variant']}",
            c["warm_mean_pulled_bytes"],
            f"cold_pulled={c['cold_pulled_bytes']};"
            f"warm_reuse_frac={c['warm_mean_reuse_frac']:.3f};"
            f"wire_bytes={c['wire_bytes_moved']}"))
    return rows


def run() -> list[Row]:
    return _rows(sweep(), real_cells())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="fig_prefix_reuse.json")
    ap.add_argument("--fast", action="store_true",
                    help="short sim sweep (30 s, 2 QPS points)")
    ap.add_argument("--skip-real", action="store_true",
                    help="sim sweep only (no JAX model build)")
    ap.add_argument("--bench-out", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="also merge rows into a BENCH_<pr>.json "
                         "trajectory point (default path from run.py)")
    args = ap.parse_args()
    cells = sweep(fast=args.fast)
    real = [] if args.skip_real else real_cells()
    rows = _rows(cells, real)
    with open(args.out, "w") as f:
        json.dump({"config": {"duration_s": 30.0 if args.fast else DURATION,
                              "workload": "shared_prefix",
                              "prompt_len": PROMPT_LEN,
                              "response_len": RESPONSE_LEN,
                              "prefix_frac": PREFIX_FRAC,
                              "n_prefixes": N_PREFIXES,
                              "topology": "2P x 2D",
                              "qps_grid": FAST_QPS_GRID if args.fast
                              else QPS_GRID,
                              "variants": VARIANTS},
                   "cells": cells, "real": real}, f, indent=2)
    print(f"wrote {len(cells)} sim cells + {len(real)} real cells to {args.out}")
    print("name,us_per_call,derived")
    for row in rows:
        print(row.csv())
    if args.bench_out is not None and rows:
        import sys
        sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))
        from benchmarks.run import BENCH_PR
        from repro.obs.bench import BenchTrajectory, bench_path
        traj = BenchTrajectory(BENCH_PR, source="benchmarks.fig_prefix_reuse")
        traj.extend_rows(rows)
        out = traj.write(args.bench_out or bench_path(BENCH_PR))
        print(f"# merged {len(rows)} prefix-reuse entries into {out}")


if __name__ == "__main__":
    main()
