"""Fig. 16 — pull-mode vs push-mode under load.

Paper: pull-mode averages 25.5 % lower per-request latency; at high QPS
push-mode's pre-allocation inflates decode-side KV lifetime, queuing
grows 1.6×, though push's smaller resident batch gives it 5.6-14.4 %
better TBT.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row
from repro.configs import get_config
from repro.sim.costs import CostModel, H100_NODE
from repro.sim.events import ClusterSim, SimConfig
from repro.sim.workloads import ARXIV, SHAREGPT, sample_requests


def run() -> list[Row]:
    cfg = get_config("mistral-large-123b")
    rows, speedups = [], []
    for spec in (ARXIV, SHAREGPT):
        # the pull-mode win is a memory-pressure effect (§4.3): it appears
        # where the decode worker's KV pool binds (ShareGPT ≥0.86 QPS on
        # this hardware); below that, push's transfer-hiding wins slightly
        for qps in ((0.3, 0.45) if spec is ARXIV else (0.86, 0.95)):
            out = {}
            for mode in ("pull", "push"):
                sim = ClusterSim(CostModel(cfg, H100_NODE),
                                 SimConfig(n_prefill=1, n_decode=1, mode=mode))
                reqs = sample_requests(spec, qps=qps, duration_s=300, seed=11)
                out[mode] = sim.run(reqs).summary()
            sp = out["push"]["mean_total_s"] / out["pull"]["mean_total_s"]
            speedups.append(sp)
            tbt = out["push"]["p90_tbt_s"] / out["pull"]["p90_tbt_s"]
            rows.append(Row(f"fig16/{spec.name}/qps{qps}",
                            out["pull"]["mean_total_s"] * 1e6,
                            f"pull_speedup={sp:.3f}x;push_tbt_ratio={tbt:.3f}"))
    rows.append(Row("fig16/summary", 0.0,
                    f"mean_pull_speedup={np.mean(speedups):.3f}x;paper=1.255x"))
    return rows
