"""Scheduling-policy sweep: policy × workload × QPS → latency percentiles.

Runs the discrete-event simulator over a 2 prefill × 2 decode cluster
with a skewed network (cross-rail KV pulls 5× slower — the NetKV
scenario) and sweeps all four ``repro.sched`` policies over both paper
workloads at several arrival rates.  Reports TTFT / end-to-end
percentiles plus the SLO policy's admission behavior.

As a benchmark module it emits the usual CSV rows through run.py; run
directly it also writes the full sweep as JSON:

    PYTHONPATH=src python -m benchmarks.fig_sched_policies \
        [--out fig_sched_policies.json]

Expected shape: network_aware ≤ round_robin on e2e latency under skew
(it keeps pulls off the slow links); slo keeps served TTFT bounded at
overload by rejecting what it cannot serve in time.
"""
from __future__ import annotations

import argparse
import json

from benchmarks.common import Row
from repro.configs import get_config
from repro.sim.costs import CostModel, H100_NODE
from repro.sim.events import ClusterSim, SimConfig
from repro.sim.workloads import ARXIV, SHAREGPT, sample_requests

POLICIES = ("round_robin", "least_loaded", "network_aware", "slo")
DURATION = 120.0
SLO_TTFT_S = 15.0
# cross-rail links 5x slower than the aligned pairs (p_i ↔ d_i)
LINK_SCALES = {("p0", "d1"): 5.0, ("p1", "d0"): 5.0}
# last point of each grid overloads 2 prefill workers (util > 1) so the
# SLO admission controller has something to reject
QPS_GRID = {"arxiv": (0.25, 0.5, 1.0), "sharegpt": (0.5, 1.0, 2.0)}


def sweep() -> list[dict]:
    cost = CostModel(get_config("mistral-large-123b"), H100_NODE)
    cells = []
    for spec in (ARXIV, SHAREGPT):
        for qps in QPS_GRID[spec.name]:
            reqs = sample_requests(spec, qps=qps, duration_s=DURATION, seed=11)
            for policy in POLICIES:
                sim = ClusterSim(
                    cost,
                    SimConfig(n_prefill=2, n_decode=2, mode="pull", policy=policy,
                              slo_s=SLO_TTFT_S if policy == "slo" else None),
                    link_scales=LINK_SCALES,
                )
                s = sim.run(list(reqs)).summary()
                cells.append({
                    "policy": policy,
                    "workload": spec.name,
                    "qps": qps,
                    "n_offered": len(reqs),
                    "n_served": int(s["n"]),
                    "n_rejected": int(s["n_rejected"]),
                    "p50_ttft_s": s["p50_ttft_s"],
                    "p90_ttft_s": s["p90_ttft_s"],
                    "p50_total_s": s["p50_total_s"],
                    "p90_total_s": s["p90_total_s"],
                    "p90_tbt_s": s["p90_tbt_s"],
                })
    return cells


def _rows(cells: list[dict]) -> list[Row]:
    rows = []
    for c in cells:
        rows.append(Row(
            f"sched/{c['workload']}/qps{c['qps']}/{c['policy']}",
            c["p90_total_s"] * 1e6,
            f"p90_ttft={c['p90_ttft_s']:.2f}s;p90_e2e={c['p90_total_s']:.2f}s;"
            f"served={c['n_served']};rejected={c['n_rejected']}",
        ))
    # headline: network-aware vs round-robin e2e under skew, per workload
    for name in ("arxiv", "sharegpt"):
        na = [c for c in cells if c["workload"] == name and c["policy"] == "network_aware"]
        rr = [c for c in cells if c["workload"] == name and c["policy"] == "round_robin"]
        gain = sum(r["p90_total_s"] for r in rr) / max(sum(n["p90_total_s"] for n in na), 1e-9)
        rows.append(Row(f"sched/{name}/summary", 0.0,
                        f"network_aware_vs_round_robin_p90_e2e={gain:.2f}x"))
    return rows


def run() -> list[Row]:
    return _rows(sweep())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="fig_sched_policies.json")
    args = ap.parse_args()
    cells = sweep()
    with open(args.out, "w") as f:
        json.dump({"config": {"duration_s": DURATION, "slo_ttft_s": SLO_TTFT_S,
                              "link_scales": {f"{k[0]}->{k[1]}": v
                                              for k, v in LINK_SCALES.items()},
                              "topology": "2P x 2D"},
                   "cells": cells}, f, indent=2)
    print(f"wrote {len(cells)} cells to {args.out}")
    print("name,us_per_call,derived")
    for row in _rows(cells):
        print(row.csv())


if __name__ == "__main__":
    main()
