"""Elastic fleet sweep: autoscaling + memory-pressure preemption, sim + real.

Two sim contrasts on the discrete-event simulator, mirroring the
``repro.fleet`` control plane (which the sim runs VERBATIM — the
autoscaler is the real ``fleet.Autoscaler`` fed LoadReports built from
sim worker state):

  * **static vs autoscaled at equal peak hardware** — bursty (MMPP)
    arrivals of a prefill-heavy workload against (a) a static 2P×2D
    fleet and (b) an autoscaled fleet capped at the SAME peak worker
    count (``total_cap=4``) that shifts the P/D ratio toward 3P×1D
    during bursts (P/D-Serve-style).  Asserted: the autoscaled fleet's
    p90 end-to-end latency beats static.

  * **park-only vs preemption under memory pressure** — two batch-class
    hogs fill a single decode worker's pool while short interactive
    requests queue behind them.  Without preemption the shorts wait for
    a hog to finish; with ``preemption="swap"`` (host-memory swap-out,
    resume later) or ``"sacrifice"`` (drop + truncate-and-replay) the
    governor evicts a hog and the shorts complete inside the horizon.
    Asserted: both preemption modes complete STRICTLY more requests by
    the horizon than park-only, and no work is lost (everything still
    finishes eventually).

``real_cells()`` proves the same mechanisms END-TO-END on the real
substrate (JAX compute, real KV bytes through the transfer engine):

  * swap-out freezes the stream (no tokens while swapped), swap-in
    resumes it, and the final stream is BIT-IDENTICAL to an unpreempted
    run — the page cache writeback preserved the appended KV;
  * sacrifice replays through prefill and regenerates the identical
    stream (decode is deterministic), with the retry counted;
  * under real memory pressure (4-block decode pool), a swap-enabled
    fleet completes strictly more requests in a fixed tick budget than
    park-only at equal hardware.

    PYTHONPATH=src python -m benchmarks.fig_elastic [--fast] \
        [--out fig_elastic.json] [--skip-real] [--bench-out [PATH]]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from benchmarks.common import Row
from repro.configs import get_config
from repro.sim.costs import CostModel, H100_NODE
from repro.sim.events import ClusterSim, SimConfig
from repro.sim.workloads import SimRequest, WorkloadSpec, bursty_requests

SEED = 17
# prefill-heavy bursty workload: long prompts, short responses — the
# shape whose optimal P/D ratio shifts toward prefill during bursts
BURST_SPEC = WorkloadSpec("burst", mean_prompt=40_000, mean_response=128)
BURST = dict(qps_on=1.2, qps_off=0.05, mean_on_s=60.0, mean_off_s=60.0)
DURATION = 480.0
FAST_DURATION = 240.0
PRESSURE_HORIZON = 100.0


def _cost() -> CostModel:
    return CostModel(get_config("mistral-large-123b"), H100_NODE)


# ------------------------------------------------------------- autoscale
def autoscale_cells(fast: bool = False) -> list[dict]:
    """Static 2P×2D vs autoscaled at the same peak hardware (cap 4)."""
    cost = _cost()
    duration = FAST_DURATION if fast else DURATION
    reqs = bursty_requests(BURST_SPEC, duration_s=duration, seed=SEED, **BURST)
    variants = {
        "static": SimConfig(mode="pull", n_prefill=2, n_decode=2),
        # equal peak hardware: the autoscaler may only SHIFT the ratio
        # (min_prefill pins the static prefill size; growing prefill
        # first drains a decode worker — total never exceeds 4)
        "autoscaled": SimConfig(mode="pull", n_prefill=2, n_decode=2,
                                autoscale=True, total_cap=4,
                                min_prefill=2, max_prefill=3,
                                min_decode=1, max_decode=2,
                                autoscale_interval_s=2.0),
    }
    cells = []
    for name, cfg in variants.items():
        s = ClusterSim(cost, cfg).run(list(reqs)).summary()
        cells.append({
            "variant": name, "n": int(s["n"]), "duration_s": duration,
            "p50_total_s": s["p50_total_s"], "p90_total_s": s["p90_total_s"],
            "p90_ttft_s": s["p90_ttft_s"], "completed": int(s["completed"]),
        })
    static = next(c for c in cells if c["variant"] == "static")
    auto = next(c for c in cells if c["variant"] == "autoscaled")
    assert auto["p90_total_s"] < static["p90_total_s"], (
        f"autoscaled p90 {auto['p90_total_s']:.2f}s not below static "
        f"{static['p90_total_s']:.2f}s at equal peak hardware")
    assert auto["completed"] >= static["completed"], \
        "autoscaling lost completed requests"
    return cells


# ------------------------------------------------------------ preemption
def _pressure_requests(cap: int) -> list[SimRequest]:
    """Two batch-class hogs fill 90 % of one decode pool; six short
    interactive requests arrive behind them and cannot fit until a hog
    leaves (by completion — minutes away — or by preemption)."""
    hog_p, short_p = int(cap * 0.45), int(cap * 0.18)
    return [SimRequest("hog-0", 0.0, hog_p, 4000, slo_class="batch"),
            SimRequest("hog-1", 0.5, hog_p, 4000, slo_class="batch")] + [
            SimRequest(f"short-{i}", 2.0 + i, short_p, 64,
                       slo_class="interactive") for i in range(6)]


def preemption_cells() -> list[dict]:
    cost = _cost()
    reqs = _pressure_requests(cost.kv_capacity_tokens())
    base = dict(mode="pull", n_prefill=2, n_decode=1,
                horizon_s=PRESSURE_HORIZON)
    variants = {
        "park_only": SimConfig(**base),
        "swap": SimConfig(**base, preemption="swap", preempt_high=0.7,
                          victim_policy="priority"),
        "sacrifice": SimConfig(**base, preemption="sacrifice",
                               preempt_high=0.7, victim_policy="priority"),
    }
    cells = []
    for name, cfg in variants.items():
        r = ClusterSim(cost, cfg).run(list(reqs))
        s = r.summary()
        cells.append({
            "variant": name, "n": int(s["n"]),
            "completed_by_horizon": r.completed_by(),
            "horizon_s": PRESSURE_HORIZON,
            "n_swapped": int(s["n_swapped"]),
            "n_sacrificed": int(s["n_sacrificed"]),
            "p90_total_s": s["p90_total_s"],
        })
        # no lost work: preemption defers, it never drops
        assert int(s["n"]) == len(reqs), f"{name} lost requests"
    park = next(c for c in cells if c["variant"] == "park_only")
    for name in ("swap", "sacrifice"):
        c = next(x for x in cells if x["variant"] == name)
        assert c["completed_by_horizon"] > park["completed_by_horizon"], (
            f"{name} completed {c['completed_by_horizon']} by "
            f"{PRESSURE_HORIZON:.0f}s — not strictly more than park-only's "
            f"{park['completed_by_horizon']}")
    return cells


# ------------------------------------------------------------- real path
def real_cells() -> list[dict]:
    """The same mechanisms end-to-end on the real serving substrate."""
    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.fleet import FleetConfig
    from repro.models.transformer import DecoderLM
    from repro.serving.disagg import DisaggService

    cfg = get_smoke_config("deepseek-67b")
    model = DecoderLM(cfg, unroll=True)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(SEED)
    prompt = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
    max_new = 8

    def baseline() -> list[int]:
        svc = DisaggService(model, params, n_prefill=1, n_decode=1)
        return svc.generate(svc.submit(prompt), max_new=max_new)

    def drive(svc, h, cap=200):
        for _ in range(cap):
            if h.finished:
                return
            svc.loop.tick()
        raise AssertionError(f"{h.request_id} did not finish in {cap} ticks")

    want = baseline()
    cells = []

    # ---- swap-out / swap-in: stream pauses, resumes token-identical.
    # preempt="none" keeps the governor off so the bench controls the
    # swap points; the controller still owns the host swap pool.
    svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                        fleet=FleetConfig(preempt="none"))
    h = svc.submit(prompt, max_new=max_new)
    while h.decoded < 2:
        svc.loop.tick()
    wid = h.request.decode_worker
    assert svc.swap_out_request(h.request_id), "swap_out refused"
    frozen = len(h.tokens)
    for _ in range(3):
        svc.loop.tick()
    assert len(h.tokens) == frozen, "tokens advanced while swapped out"
    assert svc.swap_in_request(h.request_id, wid), "swap_in refused"
    drive(svc, h)
    assert h.tokens == want, "swap cycle changed the token stream"
    assert h.metrics.swapped_out == 1
    cells.append({"cell": "swap_identity", "tokens": len(h.tokens),
                  "swapped_out": h.metrics.swapped_out,
                  "ticks_frozen": 3})

    # ---- sacrifice: drop KV, truncate-and-replay, identical stream
    svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                        fleet=FleetConfig(preempt="none"))
    h = svc.submit(prompt, max_new=max_new)
    while h.decoded < 2:
        svc.loop.tick()
    assert svc.sacrifice_request(h.request_id), "sacrifice refused"
    drive(svc, h)
    assert h.tokens == want, "sacrifice replay changed the token stream"
    assert h.metrics.sacrificed == 1
    assert h.request.retries >= 1
    cells.append({"cell": "sacrifice_identity", "tokens": len(h.tokens),
                  "sacrificed": h.metrics.sacrificed,
                  "retries": h.request.retries})

    # ---- memory pressure: park-only vs swap, equal hardware, fixed tick
    # budget.  A 4-block decode pool: request A (3 prompt blocks, grows
    # to 4) fills it; B (2 blocks) cannot admit until A leaves.
    def pressure(fleet) -> int:
        svc = DisaggService(model, params, n_prefill=1, n_decode=0,
                            fleet=fleet)
        svc.add_decode_worker(num_blocks=4)
        a = svc.submit(rng.integers(0, cfg.vocab_size, 96).astype(np.int32),
                       max_new=24, slo_class="batch")
        b = svc.submit(rng.integers(0, cfg.vocab_size, 64).astype(np.int32),
                       max_new=4)
        for _ in range(16):
            svc.loop.tick()
        return sum(1 for x in (a, b) if x.done)

    done_park = pressure(None)
    done_swap = pressure(FleetConfig(preempt="swap", preempt_high=0.5,
                                     victim_policy="fifo"))
    assert done_swap > done_park, (
        f"swap completed {done_swap} in 16 ticks, park-only {done_park} — "
        "preemption must complete strictly more under pressure")
    cells.append({"cell": "pressure_16_ticks", "park_only_done": done_park,
                  "swap_done": done_swap})
    return cells


def _rows(auto: list[dict], preempt: list[dict],
          real: list[dict] | None = None) -> list[Row]:
    rows = []
    for c in auto:
        rows.append(Row(
            f"elastic/burst/{c['variant']}", c["p90_total_s"] * 1e6,
            f"p50={c['p50_total_s']:.2f}s;p90_ttft={c['p90_ttft_s']:.2f}s;"
            f"completed={c['completed']}"))
    static = next(c for c in auto if c["variant"] == "static")
    scaled = next(c for c in auto if c["variant"] == "autoscaled")
    rows.append(Row(
        "elastic/burst/summary", 0.0,
        f"static_vs_autoscaled_p90="
        f"{static['p90_total_s'] / max(scaled['p90_total_s'], 1e-9):.2f}x"))
    for c in preempt:
        rows.append(Row(
            f"elastic/pressure/{c['variant']}",
            c["p90_total_s"] * 1e6,
            f"completed_by_{c['horizon_s']:.0f}s={c['completed_by_horizon']};"
            f"swapped={c['n_swapped']};sacrificed={c['n_sacrificed']}"))
    for c in real or []:
        detail = ";".join(f"{k}={v}" for k, v in c.items() if k != "cell")
        rows.append(Row(f"elastic/real/{c['cell']}", 0.0, detail))
    return rows


def run() -> list[Row]:
    return _rows(autoscale_cells(), preemption_cells(), real_cells())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="fig_elastic.json")
    ap.add_argument("--fast", action="store_true",
                    help="shorter bursty sweep (240 s instead of 480 s)")
    ap.add_argument("--skip-real", action="store_true",
                    help="sim cells only (no JAX model build)")
    ap.add_argument("--bench-out", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="also merge rows into a BENCH_<pr>.json "
                         "trajectory point (default path from run.py)")
    args = ap.parse_args()
    auto = autoscale_cells(fast=args.fast)
    preempt = preemption_cells()
    real = [] if args.skip_real else real_cells()
    rows = _rows(auto, preempt, real)
    with open(args.out, "w") as f:
        json.dump({"config": {"burst": {**BURST, "spec": BURST_SPEC.name},
                              "duration_s": FAST_DURATION if args.fast
                              else DURATION,
                              "pressure_horizon_s": PRESSURE_HORIZON,
                              "topology": "2P x 2D (cap 4)"},
                   "autoscale": auto, "preemption": preempt,
                   "real": real}, f, indent=2)
    print(f"wrote {len(auto)} autoscale + {len(preempt)} preemption sim "
          f"cells + {len(real)} real cells to {args.out}")
    print("name,us_per_call,derived")
    for row in rows:
        print(row.csv())
    if args.bench_out is not None and rows:
        import sys
        sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))
        from benchmarks.run import BENCH_PR
        from repro.obs.bench import BenchTrajectory, bench_path
        traj = BenchTrajectory(BENCH_PR, source="benchmarks.fig_elastic")
        traj.extend_rows(rows)
        out = traj.write(args.bench_out or bench_path(BENCH_PR))
        print(f"# merged {len(rows)} elastic entries into {out}")


if __name__ == "__main__":
    main()
