"""Continuous batching vs round-synchronous decode: the serving-API sweep.

The PR 5 redesign replaced the round-synchronous ``generate_many`` front
door (per-worker cohorts: every request in a round starts and stops
together, late admissions wait for the next round) with an event-driven
``ServeLoop`` whose decode workers run per-step continuous batching —
requests join the running batch as their KV lands and leave at
EOS/``max_new`` without stalling cohabitants.

This benchmark measures what that buys at the tail, on the discrete-event
simulator (2 prefill × 2 decode, pull mode, async "overlapped" engine,
``SimConfig.batching`` = round | continuous — the sim knob that mirrors
the real admission semantics):

  * the reported headline is **p90 time-to-last-token** (arrival → final
    token, ``p90_total_s``) at each swept QPS: a late arrival under round
    batching waits for the whole resident cohort to drain before its
    first decode step, and that wait compounds into the TTLT tail;
  * p90 KV-inclusive TTFT (arrival → decodable) is reported alongside —
    it moves for the same reason.

Beyond the simulator, ``real_cells()`` demonstrates the same contrast
END-TO-END on the real substrate: request B is submitted while request A
is mid-decode; under the ServeLoop B's first decode token lands BEFORE A
finishes (observable via ``RequestHandle`` metrics), while the
round-synchronous path makes B wait for A's entire round.

As a benchmark module it emits CSV rows through run.py; run directly it
writes the full sweep as JSON:

    PYTHONPATH=src python -m benchmarks.fig_continuous [--out fig_continuous.json]
"""
from __future__ import annotations

import argparse
import json
import time

from benchmarks.common import Row
from repro.configs import get_config
from repro.sim.costs import CostModel, H100_NODE
from repro.sim.events import ClusterSim, SimConfig
from repro.sim.workloads import SHAREGPT, sample_requests

DURATION = 120.0
QPS_GRID = (0.25, 0.5, 1.0, 2.0)  # >= 3 QPS points (acceptance)
BATCHINGS = ("round", "continuous")
SEED = 23


def sweep() -> list[dict]:
    cost = CostModel(get_config("mistral-large-123b"), H100_NODE)
    cells = []
    for qps in QPS_GRID:
        reqs = sample_requests(SHAREGPT, qps=qps, duration_s=DURATION, seed=SEED)
        for batching in BATCHINGS:
            s = ClusterSim(cost, SimConfig(
                n_prefill=2, n_decode=2, mode="pull",
                transfer_overlap="overlapped", batching=batching,
            )).run(list(reqs)).summary()
            cells.append({
                "batching": batching, "qps": qps, "n": int(s["n"]),
                "p50_ttlt_s": s["p50_total_s"],
                "p90_ttlt_s": s["p90_total_s"],
                "p90_ttft_kv_s": s["p90_ttft_kv_s"],
                "p90_tbt_s": s["p90_tbt_s"],
            })
    return cells


# ------------------------------------------------------------- real path
def real_cells(prompt_len: int = 64, max_new_a: int = 8,
               max_new_b: int = 2) -> list[dict]:
    """Mid-decode join on the real substrate (JAX compute, real KV bytes).

    Continuous: submit A, tick until A is mid-decode, submit B, keep
    ticking — B's first decode token must land before A's last
    (``joined_before_a_done``), straight off the handles' metrics.
    Round-synchronous baseline: the same arrival pattern driven with
    ``decode_round`` cohorts — B's first decode token can only land
    after A's cohort drains.  Token streams are asserted identical."""
    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models.registry import build_model
    from repro.serving.disagg import DisaggService

    cfg = get_smoke_config("deepseek-67b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(SEED)
    tok_a = rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
    tok_b = rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)

    cells = []
    streams = {}

    # --- continuous: the ServeLoop path -------------------------------
    svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=256)
    t0 = time.perf_counter()
    ha = svc.submit(tok_a, max_new=max_new_a)
    while ha.decoded < max_new_a // 2:  # A mid-decode
        svc.loop.tick()
    hb = svc.submit(tok_b, max_new=max_new_b)
    svc.loop.run_until_idle()
    a_last = ha.metrics.last_token_at
    b_first_decode = time.perf_counter()  # fallback if B never decoded
    if len(hb.metrics.token_times) > 1:
        b_first_decode = hb.metrics.token_times[1]
    streams["continuous"] = (list(ha.tokens), list(hb.tokens))
    cells.append({
        "batching": "continuous",
        "wall_s": time.perf_counter() - t0,
        "b_ttlt_s": hb.metrics.ttlt_s,
        "joined_before_a_done": bool(b_first_decode < a_last),
    })

    # --- round-synchronous baseline: decode_round cohorts -------------
    svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=256)
    t0 = time.perf_counter()
    ra = svc.submit(tok_a)
    svc.admit_queued(only={ra.request_id})
    svc.pump(None)
    dw = svc.decode
    # cohort 1 = {A}: B arrives mid-round but must wait for the cohort
    out_a = dw.decode_round(max_new_a // 2)
    rb = svc.submit(tok_b)
    b_submitted = time.perf_counter()
    out_a2 = dw.decode_round(max_new_a - max_new_a // 2)
    a_done = time.perf_counter()
    dw.finish(ra.request_id)
    # cohort 2 = {B}
    svc.admit_queued(only={rb.request_id})
    svc.pump(None)
    out_b = dw.decode_round(max_new_b)
    b_done = time.perf_counter()
    dw.finish(rb.request_id)
    streams["round"] = (
        [svc.first_tokens[ra.request_id]] + out_a[ra.request_id] + out_a2[ra.request_id],
        [svc.first_tokens[rb.request_id]] + out_b[rb.request_id])
    cells.append({
        "batching": "round",
        "wall_s": time.perf_counter() - t0,
        "b_ttlt_s": b_done - b_submitted,
        "joined_before_a_done": bool(b_done < a_done),
    })
    assert streams["continuous"] == streams["round"], \
        "continuous batching changed the token streams"
    return cells


def _rows(cells: list[dict], real: list[dict] | None = None) -> list[Row]:
    rows = []
    for c in cells:
        rows.append(Row(
            f"continuous/qps{c['qps']}/{c['batching']}",
            c["p90_ttlt_s"] * 1e6,
            f"p50_ttlt={c['p50_ttlt_s']:.2f}s;p90_ttlt={c['p90_ttlt_s']:.2f}s;"
            f"p90_ttft_kv={c['p90_ttft_kv_s']:.3f}s",
        ))
    for qps in sorted({c["qps"] for c in cells}):
        rd = next(c for c in cells if c["qps"] == qps and c["batching"] == "round")
        ct = next(c for c in cells if c["qps"] == qps and c["batching"] == "continuous")
        rows.append(Row(
            f"continuous/qps{qps}/summary", 0.0,
            f"round_vs_continuous_p90_ttlt="
            f"{rd['p90_ttlt_s'] / max(ct['p90_ttlt_s'], 1e-9):.2f}x"))
    for c in real or []:
        rows.append(Row(
            f"continuous/real/{c['batching']}", c["wall_s"] * 1e6,
            f"b_ttlt={c['b_ttlt_s']:.3f}s;"
            f"joined_before_a_done={c['joined_before_a_done']}"))
    return rows


def run() -> list[Row]:
    return _rows(sweep(), real_cells())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="fig_continuous.json")
    ap.add_argument("--skip-real", action="store_true",
                    help="sim sweep only (no JAX model build)")
    args = ap.parse_args()
    cells = sweep()
    real = [] if args.skip_real else real_cells()
    with open(args.out, "w") as f:
        json.dump({"config": {"duration_s": DURATION, "workload": "sharegpt",
                              "topology": "2P x 2D", "qps_grid": QPS_GRID,
                              "batchings": BATCHINGS},
                   "cells": cells, "real": real}, f, indent=2)
    print(f"wrote {len(cells)} sim cells + {len(real)} real cells to {args.out}")
    print("name,us_per_call,derived")
    for row in _rows(cells, real):
        print(row.csv())


if __name__ == "__main__":
    main()
