"""Topology sweep: planned placement vs random role assignment.

For each of three generated cluster shapes (one heterogeneous rack, one
2-region geo split, one 3-region split — ``repro.topo.PRESETS``, seeded,
reproducible), the ``PlacementPlanner`` assigns prefill/decode roles by
its greedy + local-search max-flow heuristic and competes against
uniformly random role assignments on the SAME machines at the SAME
arrival rate — equal hardware, equal load, only the role mapping
differs.  Every variant replays the identical ``ClusterSpec`` through
``ClusterSim(topology=...)``: per-machine prefill/decode slowdowns and
KV-capacity scales, per-pair link bandwidth + propagation latency, and
``network_aware`` routing over those pair costs.

The arrival rate is set to ``LOAD_FRAC`` x the planner's max-flow score
(requests/s), i.e. just under the PLANNED capacity.  A random placement
whose own capacity falls below that rate saturates and its KV-inclusive
TTFT diverges with queue depth; a lucky draw can stay fast.  The honest
claim — and the asserted one — is therefore about the STRATEGY, not any
single draw: the planner's p90 KV-inclusive TTFT must beat the MEAN of
the random placements' p90s on every shape, and its planned capacity
must be at least every draw's capacity (a guarantee by construction:
the planner's restarts include the random start).

``real_cells()`` closes the sim/real loop: the SAME spec (byte-for-byte
through the JSON round-trip — asserted) builds a ``DisaggService`` via
``from_cluster_spec``, whose router prices each (prefill, decode) pair
from the spec's directed links, and requests generate end-to-end.

    PYTHONPATH=src python -m benchmarks.fig_topology [--fast] \
        [--out fig_topology.json] [--skip-real] [--bench-out [PATH]]
"""
from __future__ import annotations

import argparse
import json
import pathlib

from benchmarks.common import Row
from repro.configs import get_config
from repro.sim.costs import CostModel, H100_NODE
from repro.sim.events import ClusterSim, SimConfig
from repro.sim.workloads import fixed_requests
from repro.topo import (
    ClusterSpec,
    PlacementPlanner,
    TopologyBinding,
    WorkloadShape,
    generate_cluster,
    random_placement,
)

# (preset, cluster seed) — three distinct shapes, all from the shared
# generator that fig12_cluster_config --cluster also draws from.
SHAPES = [("hetero_rack", 0), ("geo_pair", 1), ("geo_triad", 0)]
RANDOM_SEEDS = (0, 1, 2)
PROMPT, RESPONSE = 16_384, 512
LOAD_FRAC = 0.7          # arrival rate as a fraction of planned capacity
DURATION = 300.0
FAST_DURATION = 150.0
ARRIVAL_SEED = 7


def _cost() -> CostModel:
    return CostModel(get_config("mistral-large-123b"), H100_NODE)


def _planner(cost: CostModel) -> PlacementPlanner:
    # calibrated from the SAME CostModel the sim runs, so the planner's
    # req/s score and the sim's service times price one workload
    shape = WorkloadShape.from_cost(cost, prompt_len=PROMPT,
                                    response_len=RESPONSE)
    return PlacementPlanner(shape=shape)


def _simulate(cost, spec, placement, planner, reqs) -> dict:
    binding = TopologyBinding(spec, placement, planner=planner)
    cfg = SimConfig(mode="pull", policy="network_aware",
                    n_prefill=binding.n_prefill, n_decode=binding.n_decode)
    s = ClusterSim(cost, cfg, topology=binding).run(list(reqs)).summary()
    return {
        "prefill": list(placement.prefill), "decode": list(placement.decode),
        "score_req_s": placement.score,
        "p90_ttft_kv_s": s["p90_ttft_kv_s"],
        "p50_ttft_kv_s": s["p50_ttft_kv_s"],
        "p90_total_s": s["p90_total_s"], "n": int(s["n"]),
    }


# -------------------------------------------------------------- sim sweep
def sim_cells(fast: bool = False) -> list[dict]:
    cost = _cost()
    planner = _planner(cost)
    duration = FAST_DURATION if fast else DURATION
    cells = []
    for preset, cluster_seed in SHAPES:
        spec = generate_cluster(preset, cluster_seed)
        planned = planner.plan(spec)
        qps = LOAD_FRAC * planned.score
        reqs = fixed_requests(PROMPT, RESPONSE, qps=qps, duration_s=duration,
                              seed=ARRIVAL_SEED)
        cell = {"shape": spec.name, "preset": preset, "seed": cluster_seed,
                "n_machines": len(spec.machines), "qps": qps,
                "duration_s": duration,
                "planned": _simulate(cost, spec, planned, planner, reqs),
                "random": []}
        for rs in RANDOM_SEEDS:
            rand = random_placement(spec, seed=rs, planner=planner)
            # by construction: the planner's restarts include random
            # starts, so its capacity is never below any draw's
            assert planned.score >= rand.score - 1e-9, \
                f"{spec.name}: planned score below random seed {rs}"
            cell["random"].append(
                {"seed": rs, **_simulate(cost, spec, rand, planner, reqs)})
        rand_p90s = [r["p90_ttft_kv_s"] for r in cell["random"]]
        cell["random_mean_p90_ttft_kv_s"] = sum(rand_p90s) / len(rand_p90s)
        assert cell["planned"]["p90_ttft_kv_s"] < \
            cell["random_mean_p90_ttft_kv_s"], (
            f"{spec.name}: planned p90 KV-inclusive TTFT "
            f"{cell['planned']['p90_ttft_kv_s']:.2f}s not below the random-"
            f"assignment mean {cell['random_mean_p90_ttft_kv_s']:.2f}s "
            f"(draws: {[f'{v:.2f}' for v in rand_p90s]}) at equal hardware")
        cells.append(cell)
    return cells


# -------------------------------------------------------------- real path
def real_cells() -> list[dict]:
    """The same ClusterSpec, byte-for-byte, on the real substrate."""
    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models.transformer import DecoderLM

    from repro.serving.disagg import DisaggService

    preset, cluster_seed = SHAPES[0]
    spec = generate_cluster(preset, cluster_seed)
    # the byte-for-byte contract: the sim consumed `spec`; the service
    # consumes the JSON round-trip of it, and both serialize identically
    wire = spec.to_json()
    spec_real = ClusterSpec.from_json(wire)
    assert spec_real.to_json() == wire, "ClusterSpec JSON round-trip drifted"

    cfg = get_smoke_config("deepseek-67b")
    model = DecoderLM(cfg, unroll=True)
    params = model.init_params(jax.random.PRNGKey(0))
    svc = DisaggService.from_cluster_spec(model, params, spec_real,
                                          num_blocks=64)
    b = svc.topology
    planned = _planner(_cost()).plan(spec)
    assert (b.placement.prefill, b.placement.decode) == \
        (planned.prefill, planned.decode), \
        "real service placement diverged from the sim's planner placement"
    # the router prices every (prefill, decode) pair from the spec's
    # directed links — bandwidth AND latency, per direction
    assert len(svc.router.links) == b.n_prefill * b.n_decode
    for (p, d), lm in svc.router.links.items():
        lk = b.pair_link(p, d)
        assert lm.bandwidth_Bps == lk.bandwidth_Bps
        assert lm.latency_s == lk.latency_s

    rng = np.random.default_rng(0)
    toks = []
    for _ in range(2):
        prompt = rng.integers(0, cfg.vocab_size, 48).astype(np.int32)
        req = svc.submit(prompt)
        toks.append(svc.generate(req, max_new=4))
    assert all(len(t) >= 4 for t in toks), "generation under topology failed"
    return [{
        "cell": "spec_identity", "shape": spec.name,
        "n_machines": len(spec.machines),
        "n_prefill": b.n_prefill, "n_decode": b.n_decode,
        "router_links": len(svc.router.links),
        "requests_served": len(toks),
        "spec_bytes": len(wire),
    }]


def _rows(cells: list[dict], real: list[dict] | None = None) -> list[Row]:
    rows = []
    for c in cells:
        p = c["planned"]
        rows.append(Row(
            f"topology/{c['preset']}/planned", p["p90_ttft_kv_s"] * 1e6,
            f"score={p['score_req_s']:.2f}req_s;qps={c['qps']:.2f};"
            f"n_p={len(p['prefill'])};n_d={len(p['decode'])};n={p['n']}"))
        for r in c["random"]:
            rows.append(Row(
                f"topology/{c['preset']}/random{r['seed']}",
                r["p90_ttft_kv_s"] * 1e6,
                f"score={r['score_req_s']:.2f}req_s;"
                f"n_p={len(r['prefill'])};n_d={len(r['decode'])}"))
        rows.append(Row(
            f"topology/{c['preset']}/summary", 0.0,
            f"planned_vs_random_mean_p90_ttft_kv="
            f"{c['random_mean_p90_ttft_kv_s'] / max(p['p90_ttft_kv_s'], 1e-9):.2f}x"))
    for c in real or []:
        detail = ";".join(f"{k}={v}" for k, v in c.items()
                          if k not in ("cell",))
        rows.append(Row(f"topology/real/{c['cell']}", 0.0, detail))
    return rows


def run() -> list[Row]:
    return _rows(sim_cells(), real_cells())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="fig_topology.json")
    ap.add_argument("--fast", action="store_true",
                    help="shorter sweep (150 s of arrivals instead of 300 s)")
    ap.add_argument("--skip-real", action="store_true",
                    help="sim cells only (no JAX model build)")
    ap.add_argument("--bench-out", nargs="?", const="", default=None,
                    metavar="PATH",
                    help="also merge rows into a BENCH_<pr>.json "
                         "trajectory point (default path from run.py)")
    args = ap.parse_args()
    cells = sim_cells(fast=args.fast)
    real = [] if args.skip_real else real_cells()
    rows = _rows(cells, real)
    with open(args.out, "w") as f:
        json.dump({"config": {"shapes": SHAPES, "prompt": PROMPT,
                              "response": RESPONSE, "load_frac": LOAD_FRAC,
                              "duration_s": FAST_DURATION if args.fast
                              else DURATION,
                              "random_seeds": list(RANDOM_SEEDS)},
                   "shapes": cells, "real": real}, f, indent=2)
    print(f"wrote {len(cells)} shape sweeps + {len(real)} real cells "
          f"to {args.out}")
    print("name,us_per_call,derived")
    for row in rows:
        print(row.csv())
    if args.bench_out is not None and rows:
        import sys
        sys.path.insert(0, str(pathlib.Path(__file__).resolve().parent.parent / "src"))
        from benchmarks.run import BENCH_PR
        from repro.obs.bench import BenchTrajectory, bench_path
        traj = BenchTrajectory(BENCH_PR, source="benchmarks.fig_topology")
        traj.extend_rows(rows)
        out = traj.write(args.bench_out or bench_path(BENCH_PR))
        print(f"# merged {len(rows)} topology entries into {out}")


if __name__ == "__main__":
    main()
