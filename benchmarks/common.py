"""Shared helpers for the paper-figure benchmarks.

Every benchmark module exposes ``run() -> list[Row]``; run.py prints the
aggregate CSV ``name,us_per_call,derived`` (one row per measured cell,
``derived`` carrying the figure-level quantity such as a speedup or a
utilization fraction).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

__all__ = ["Row", "timeit", "fmt_rows"]


@dataclasses.dataclass
class Row:
    name: str
    us_per_call: float
    derived: str

    def csv(self) -> str:
        return f"{self.name},{self.us_per_call:.3f},{self.derived}"


def timeit(fn: Callable[[], object], *, repeats: int = 3, warmup: int = 1) -> float:
    """Median wall time in microseconds."""
    for _ in range(warmup):
        fn()
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def fmt_rows(rows: list[Row]) -> str:
    return "\n".join(r.csv() for r in rows)
