"""Fig. 15 — bandwidth utilization: KVDirect vs message-passing (UCX).

Paper: transferring 1024 blocks between 2 GPUs over 400 Gbps, KVDirect
achieves 22.23 GB/s on average across block sizes while UCX (4
connections) reaches 4.05 GB/s — ~5.5×.

Here both modes run through the REAL transfer engine moving real bytes
between two worker address spaces (same coalescer, same ordering rules),
so the *mechanism ratio* is measured, and the modeled clock (paper's
LinkModel constants) gives the absolute GB/s to compare with Fig. 15.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timeit
from repro.core.descriptors import ByteRange, ReadTxn
from repro.core.transfer_engine import LinkModel, MemoryRegion, TransferEngine

N_BLOCKS = 1024
DST_BASE = 1 << 31  # disjoint from the src MR (engine rejects overlap)


def _run_mode(mode: str, block_bytes: int) -> tuple[float, float, float]:
    """returns (wall_us, modeled_GBps, coalesce_factor)"""
    total = N_BLOCKS * block_bytes
    src = np.random.default_rng(0).integers(0, 255, total * 2, dtype=np.uint8)
    dst = np.zeros(total * 2, dtype=np.uint8)

    def go():
        eng = TransferEngine(mode=mode, coalescing="fifo", link=LinkModel.nic_400g(),
                             staging_blocks=2, staging_block_bytes=block_bytes)
        eng.register_memory(MemoryRegion("p0", 0, src))
        eng.register_memory(MemoryRegion("d0", DST_BASE, dst))
        # 8-block contiguous runs (the coalescing opportunity of long
        # prompts), scattered run-to-run — the §4.2 pattern
        txns = []
        perm = np.random.default_rng(1).permutation(N_BLOCKS // 8)
        for r, pr in enumerate(perm):
            for j in range(8):
                off = (pr * 8 + j) * block_bytes
                txns.append(ReadTxn("r", "p0", "d0",
                                    ByteRange(off, block_bytes),
                                    ByteRange(DST_BASE + off, block_bytes)))
        eng.submit(txns)
        eng.drain()
        return eng

    eng = go()
    wall_us = timeit(lambda: go(), repeats=3)
    modeled_gbps = eng.stats.modeled_bandwidth_Bps() / 1e9
    return wall_us, modeled_gbps, eng.stats.coalesce_factor


def run() -> list[Row]:
    rows = []
    ratios = []
    for kb in (4, 8, 16, 32, 64):
        bs = kb * 1024
        w_kv, g_kv, cf = _run_mode("tensor_centric", bs)
        w_msg, g_msg, _ = _run_mode("message", bs)
        ratios.append(g_kv / g_msg)
        rows.append(Row(f"fig15/kvdirect/{kb}KB", w_kv,
                        f"modeled_GBps={g_kv:.2f};coalesce={cf:.1f}"))
        rows.append(Row(f"fig15/message/{kb}KB", w_msg,
                        f"modeled_GBps={g_msg:.2f};ratio={g_kv/g_msg:.2f}x"))
    rows.append(Row("fig15/summary", 0.0,
                    f"mean_bw_ratio={np.mean(ratios):.2f}x;paper=5.5x(22.23/4.05)"))
    return rows
