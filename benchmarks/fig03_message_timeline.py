"""Fig. 3 / Motivation #1 — the per-block message-passing timeline.

Paper: RPC 1 ms → GPU ops 3.25 ms → sync+NIC 1.3 ms → scatter 3.31 ms →
notify 1 ms; the actual wire time is 13.2 % of the total for a 4 KB
block.  We reproduce the effective fraction from the LinkModel and
measure the engine's per-round behavior.
"""
from __future__ import annotations

from benchmarks.common import Row
from repro.core.transfer_engine import LinkModel


def run() -> list[Row]:
    lm = LinkModel.nic_400g()
    rows = []
    for kb in (4, 64, 1024):
        nbytes = kb * 1024
        total = lm.message_round_time(nbytes)
        wire = nbytes / lm.bandwidth_Bps
        # paper's wire fraction counts step 3 (sync + NIC op) as transfer
        effective = (wire + lm.cpu_sync_s) / total
        rows.append(Row(f"fig03/round/{kb}KB", total * 1e6,
                        f"effective_fraction={effective:.3f}" +
                        (";paper=0.132@4KB" if kb == 4 else "")))
    one_sided = lm.read_time(4096)
    rows.append(Row("fig03/kvdirect_read/4KB", one_sided * 1e6,
                    f"speedup_vs_message={lm.message_round_time(4096)/one_sided:.0f}x"))
    return rows
