"""Fig. 6 / Motivation #3 — latency blow-up vs QPS for 16K-token
requests when the decode pool saturates and KV allocation blocks.

Paper (70B, 16K prompts): latency rises from ~23 s to ~68 s as QPS
approaches 1.5-2; at QPS 1.5 the KV-allocation wait is 65 % of total.
"""
from __future__ import annotations

from benchmarks.common import Row
from repro.configs import get_config
from repro.sim.costs import CostModel, H100_NODE
from repro.sim.events import ClusterSim, SimConfig
from repro.sim.workloads import fixed_requests


def run() -> list[Row]:
    cfg = get_config("mistral-large-123b")
    rows = []
    base = None
    for qps in (0.25, 0.5, 1.0, 1.5):
        reqs = fixed_requests(16384, 512, qps=qps, duration_s=240, seed=3)
        sim = ClusterSim(CostModel(cfg, H100_NODE),
                         SimConfig(n_prefill=1, n_decode=1, mode="push"))
        res = sim.run(reqs)
        s = res.summary()
        b = res.mean_breakdown()
        wait_frac = (b["prefill_queue_s"] + b["decode_queue_s"] + b["transfer_s"]) / \
            max(s["mean_total_s"], 1e-9)
        base = base or s["mean_total_s"]
        rows.append(Row(f"fig06/qps{qps}", s["mean_total_s"] * 1e6,
                        f"blowup={s['mean_total_s']/base:.2f}x;wait_frac={wait_frac:.2f}"))
    rows.append(Row("fig06/summary", 0.0, "paper=23s->68s@qps1.5;wait=0.65"))
    return rows
