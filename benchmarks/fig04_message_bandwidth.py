"""Fig. 4 — achieved bandwidth of message-passing (UCX-style) sends.

Paper: 4 KB blocks reach 1.8 % of the 400 Gbps link; ≤13.6 % even at
32 KB; 1024 blocks do ~40 % worse than 2048 (fixed overheads amortize
over more blocks).  We reproduce the utilization curve from the engine's
staging-round model and check the block-count effect.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row
from repro.core.descriptors import ByteRange, ReadTxn
from repro.core.transfer_engine import LinkModel, MemoryRegion, TransferEngine


def _measure(n_blocks: int, block_bytes: int) -> float:
    eng = TransferEngine(mode="message", link=LinkModel.nic_400g(),
                         staging_blocks=2, staging_block_bytes=block_bytes,
                         execute_copies=False)
    eng.register_memory(MemoryRegion("p0", 0, np.zeros(1, np.uint8)))
    eng.register_memory(MemoryRegion("d0", 1 << 40, np.zeros(1, np.uint8)))
    eng.submit([
        ReadTxn("r", "p0", "d0", ByteRange(i * block_bytes, block_bytes),
                ByteRange((1 << 40) + i * block_bytes, block_bytes))
        for i in range(n_blocks)
    ])
    eng.drain()
    return eng.stats.modeled_bandwidth_Bps()


def run() -> list[Row]:
    link_bw = LinkModel.nic_400g().bandwidth_Bps
    rows = []
    for kb in (4, 8, 16, 32):
        for n in (1024, 2048):
            bw = _measure(n, kb * 1024)
            util = bw / link_bw
            note = ""
            if kb == 4 and n == 1024:
                note = ";paper=0.018@4KB"
            if kb == 32 and n == 2048:
                note = ";paper_cap=0.136"
            rows.append(Row(f"fig04/{n}blk/{kb}KB", 0.0, f"util={util:.4f}{note}"))
    # block-count effect: the paper attributes 1024-block transfers doing
    # ~40 % worse than 2048 to fixed per-transfer costs amortizing; model
    # it with the naive first-round latency included
    lm = LinkModel.nic_400g()

    def with_setup(n):
        bw = _measure(n, 4096)
        t = n * 4096 / bw + lm.message_round_time(4096)  # + setup round
        return n * 4096 / t

    rows.append(Row("fig04/block_count_effect", 0.0,
                    f"bw_1024_vs_2048={with_setup(1024)/with_setup(2048):.2f};paper=~0.6"))
    return rows
