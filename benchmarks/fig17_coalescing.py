"""Fig. 17 — block-coalescing effectiveness.

Paper: coalescing gives 1.13× (arXiv) and 1.03× (ShareGPT) average
speedup, growing to 1.32×/1.07× at QPS 0.5 (more requests batched per
prefill ⇒ more adjacency).  arXiv benefits more: longer prompts ⇒ less
fragmentation ⇒ longer contiguous runs.

Both the MEASURED engine coalesce factor (real transactions through the
real coalescer at two fragmentation levels) and the end-to-end simulated
speedup are reported.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row
from repro.configs import get_config
from repro.core.descriptors import ByteRange, ReadTxn
from repro.core.transfer_engine import MemoryRegion, TransferEngine
from repro.sim.costs import CostModel, H100_NODE
from repro.sim.events import ClusterSim, SimConfig
from repro.sim.workloads import ARXIV, SHAREGPT, sample_requests

BLOCK = 65536


def _engine_coalesce_factor(run_len: int) -> float:
    """Average pages per posted read at a given contiguity level."""
    eng = TransferEngine(mode="tensor_centric", coalescing="fifo",
                         execute_copies=False)
    eng.register_memory(MemoryRegion("p0", 0, np.zeros(1, np.uint8)))
    eng.register_memory(MemoryRegion("d0", 1 << 40, np.zeros(1, np.uint8)))
    rng = np.random.default_rng(0)
    n_runs = 512 // run_len
    perm = rng.permutation(n_runs)
    txns = []
    for pr in perm:
        for j in range(run_len):
            off = (int(pr) * run_len + j) * BLOCK
            txns.append(ReadTxn("r", "p0", "d0", ByteRange(off, BLOCK),
                                ByteRange((1 << 40) + off, BLOCK)))
    eng.submit(txns)
    eng.drain()
    return eng.stats.coalesce_factor


def run() -> list[Row]:
    rows = []
    # mechanism: measured coalesce factor vs fragmentation
    for run_len, label in ((1, "fragmented"), (8, "short-prompt"), (64, "long-prompt")):
        cf = _engine_coalesce_factor(run_len)
        rows.append(Row(f"fig17/engine/{label}", 0.0, f"coalesce_factor={cf:.1f}"))

    # end-to-end: coalescing on (factor ~ run length) vs off (factor 1)
    cfg = get_config("mistral-large-123b")
    for spec, cf_on in ((ARXIV, 64.0), (SHAREGPT, 8.0)):
        sp_by_qps = []
        for qps in (0.25, 0.5):
            out = {}
            for label, cf in (("on", cf_on), ("off", 1.0)):
                sim = ClusterSim(CostModel(cfg, H100_NODE),
                                 SimConfig(n_prefill=1, n_decode=1, mode="pull",
                                           coalesce_factor=cf))
                reqs = sample_requests(spec, qps=qps, duration_s=240, seed=13)
                out[label] = sim.run(reqs).summary()["mean_total_s"]
            sp = out["off"] / out["on"]
            sp_by_qps.append(sp)
            rows.append(Row(f"fig17/{spec.name}/qps{qps}", out["on"] * 1e6,
                            f"coalescing_speedup={sp:.3f}x"))
        paper = "1.13x,1.32x@qps0.5" if spec is ARXIV else "1.03x,1.07x@qps0.5"
        rows.append(Row(f"fig17/{spec.name}/summary", 0.0,
                        f"speedups={[round(s,3) for s in sp_by_qps]};paper={paper}"))
    return rows
