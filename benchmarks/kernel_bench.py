"""Kernel + engine microbenchmarks.

Pallas kernels execute in interpret mode on this CPU container (TPU is
the target), so their wall times are NOT hardware-meaningful; they are
included to exercise the harness end-to-end.  The transfer-engine rows
are real measurements (bytes actually move).
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from benchmarks.common import Row, timeit
from repro.kernels.kv_pull.kernel import kv_pull_runs
from repro.kernels.paged_attention.kernel import paged_attention


def run() -> list[Row]:
    rng = np.random.default_rng(0)
    rows = []

    b, h, g, d, per, bs = 4, 8, 2, 128, 8, 32
    q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
    kp = jnp.asarray(rng.standard_normal((b, per, bs, g, d)), jnp.float32)
    vp = jnp.asarray(rng.standard_normal((b, per, bs, g, d)), jnp.float32)
    tbl = jnp.broadcast_to(jnp.arange(per, dtype=jnp.int32)[None], (b, per))
    ctx = jnp.full((b,), per * bs, jnp.int32)
    us = timeit(lambda: paged_attention(q, kp, vp, tbl, ctx, interpret=True)
                .block_until_ready())
    rows.append(Row("kernel/paged_attention/interpret", us, f"ctx={per*bs};b={b}"))

    src = jnp.asarray(rng.standard_normal((64, 32, 8, 128)), jnp.bfloat16)
    dst = jnp.zeros((64, 32, 8, 128), jnp.bfloat16)
    ss = jnp.arange(8, dtype=jnp.int32)
    us = timeit(lambda: kv_pull_runs(src, jnp.array(dst), ss, ss, run_len=8,
                                     interpret=True).block_until_ready())
    mb = 64 * 32 * 8 * 128 * 2 / 2**20
    rows.append(Row("kernel/kv_pull_runs/interpret", us, f"pages=64;MB={mb:.1f}"))
    return rows
