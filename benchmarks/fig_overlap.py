"""Transfer/decode overlap sweep: consumer mode × admission batch × QPS.

Compares the engine generations on the discrete-event simulator
(2 prefill × 2 decode, pull mode):

  * ``blocking``   — the old synchronous engine: one-shot admission
    (batch = 1) and the decode worker sits in ``drain()`` for the whole
    multi-layer pull, so decode iterations and transfers mutually
    exclude on the worker;
  * ``overlapped`` — the async engine with FULL-PULL consumption
    (``DisaggService(consume="full")``, the PR 2 baseline): router-batched
    admissions pipeline on the NIC while decode keeps iterating, but the
    first decode step still waits for COMPLETE — the join point is the
    last byte;
  * ``layerwise``  — the pipelined attention consumer
    (``DisaggService(consume="layerwise")``): the first decode step runs
    layer *l*'s attention as soon as layer *l*'s reads land, so the
    request is decodable once its layer-0 KV arrives and the rest of the
    pull hides behind per-layer compute.

The reported metric is the KV-INCLUSIVE TTFT (paper §5.1: TTFT
"includes the waiting time for the KV cache"): arrival → the request is
decodable on its decode worker.  Expected shape: layerwise ≤ overlapped
at EVERY swept QPS (the layer-0 tail can only shrink the wait), and both
below the one-shot blocking pull.

Beyond the simulator, ``real_cells()`` measures the same contrast
END-TO-END on the real substrate (JAX compute + real bytes through the
transfer engine): wall-clock time from admission to the first completed
decode step under ``consume="full"`` vs ``consume="layerwise"``, plus the
engine backlog observed when the first step began — >0 only when
attention genuinely ran while the pull was still in flight.

As a benchmark module it emits CSV rows through run.py; run directly it
writes the full sweep as JSON:

    PYTHONPATH=src python -m benchmarks.fig_overlap [--out fig_overlap.json]
"""
from __future__ import annotations

import argparse
import json
import time

from benchmarks.common import Row
from repro.configs import get_config
from repro.sim.costs import CostModel, H100_NODE
from repro.sim.events import ClusterSim, SimConfig
from repro.sim.workloads import SHAREGPT, sample_requests

DURATION = 120.0
QPS_GRID = (0.25, 0.5, 1.0, 2.0)
# Swept for EVERY engine.  blocking × batch>1 shows the synchronous
# trade-off (longer drain() stalls vs better NIC utilization); for the
# async engines the cap stops mattering — admissions are re-kicked at
# every transfer/iteration completion, so the NIC stays busy even at
# batch=1 and the cells come out flat.  blocking/b1 is the one-shot
# baseline; overlapped is the PR 2 full-pull baseline the layerwise
# acceptance comparison uses.
BATCH_GRID = (1, 4, 16)
ENGINES = ("blocking", "overlapped", "layerwise")
SEED = 11


def _run(cfg: SimConfig, reqs) -> dict[str, float]:
    return ClusterSim(
        CostModel(get_config("mistral-large-123b"), H100_NODE), cfg
    ).run(list(reqs)).summary()


def sweep() -> list[dict]:
    cells = []
    for qps in QPS_GRID:
        reqs = sample_requests(SHAREGPT, qps=qps, duration_s=DURATION, seed=SEED)
        for engine in ENGINES:
            for batch in BATCH_GRID:
                s = _run(SimConfig(n_prefill=2, n_decode=2, mode="pull",
                                   transfer_overlap=engine,
                                   admission_batch=batch), reqs)
                cells.append({
                    "engine": engine, "batch": batch, "qps": qps, "n": int(s["n"]),
                    "p50_ttft_kv_s": s["p50_ttft_kv_s"],
                    "p90_ttft_kv_s": s["p90_ttft_kv_s"],
                    "p90_total_s": s["p90_total_s"],
                })
    return cells


# ------------------------------------------------------------- real path
def real_cells(n_requests: int = 4, prompt_len: int = 64,
               max_new: int = 4) -> list[dict]:
    """End-to-end consumer-mode comparison on the real serving substrate
    (CPU-scale: smoke model, memcpy engine, real KV bytes).

    For each mode: submit → admit (pulls queued, nothing drained) → drive
    ``decode_round`` until the first round completes.  Records the
    wall-clock admission→first-round time and the engine backlog at the
    moment the first decode step started (layerwise must show >0 backlog:
    attention over early layers while the pull is in flight).  Token
    streams are asserted identical across modes."""
    import jax
    import numpy as np

    from repro.configs import get_smoke_config
    from repro.models.transformer import DecoderLM
    from repro.serving.disagg import DisaggService

    cfg = get_smoke_config("deepseek-67b")
    model = DecoderLM(cfg, unroll=True)  # python-loop layers: both consumer
    # modes run identical per-op math, so tokens are bit-comparable
    params = model.init_params(jax.random.PRNGKey(0))
    rng = np.random.default_rng(SEED)
    toks = [rng.integers(0, cfg.vocab_size, prompt_len).astype(np.int32)
            for _ in range(n_requests)]

    cells = []
    token_streams = {}
    for mode in ("full", "layerwise"):
        svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                            num_blocks=256, consume=mode)
        reqs = [svc.submit(t) for t in toks]
        svc.admit_queued()
        dw = svc.decode

        t0 = time.perf_counter()
        out: dict[str, list[int]] = {}
        empty_rounds = rounds = 0
        first = None  # (pending txns before the round, outputs, seconds)
        while len(out) < n_requests:
            backlog = svc.engine.pending
            got = dw.decode_round(max_new, pump_budget=8)
            rounds += 1
            if got and first is None:
                first = (backlog, len(got), time.perf_counter() - t0)
            if not got:
                empty_rounds += 1
                if not (dw.resident or dw.inflight):
                    break
            for rid, toks_out in got.items():  # finished: leave the batch
                dw.finish(rid)
                svc.pending.pop(rid, None)
                svc.router.forget(rid)
                out[rid] = toks_out
        total_s = time.perf_counter() - t0
        token_streams[mode] = {r.request_id: out.get(r.request_id) for r in reqs}
        cells.append({
            "mode": mode, "n": n_requests, "prompt_len": prompt_len,
            "max_new": max_new, "rounds": rounds, "empty_rounds": empty_rounds,
            "admit_to_first_tokens_s": first[2] if first else float("nan"),
            "admit_to_done_s": total_s,
            "first_round_outputs": first[1] if first else 0,
            "pending_before_first_output_round": first[0] if first else 0,
        })
    assert token_streams["full"] == token_streams["layerwise"], \
        "consumer modes diverged on the real path"
    return cells


def _rows(cells: list[dict], real: list[dict] | None = None) -> list[Row]:
    rows = []
    for c in cells:
        rows.append(Row(
            f"overlap/qps{c['qps']}/{c['engine']}/b{c['batch']}",
            c["p90_ttft_kv_s"] * 1e6,
            f"p50_ttft_kv={c['p50_ttft_kv_s']:.3f}s;"
            f"p90_ttft_kv={c['p90_ttft_kv_s']:.3f}s;"
            f"p90_e2e={c['p90_total_s']:.2f}s",
        ))
    # headlines per QPS: layerwise vs the PR 2 overlapped full-pull
    # baseline (same batch), and best-batch layerwise vs one-shot blocking
    for qps in QPS_GRID:
        base = next(c for c in cells if c["qps"] == qps
                    and c["engine"] == "blocking" and c["batch"] == 1)
        best_lw = min((c for c in cells if c["qps"] == qps
                       and c["engine"] == "layerwise"),
                      key=lambda c: c["p90_ttft_kv_s"])
        worst_ratio = max(
            next(lw for lw in cells if lw["qps"] == qps
                 and lw["engine"] == "layerwise" and lw["batch"] == ov["batch"]
                 )["p90_ttft_kv_s"] / max(ov["p90_ttft_kv_s"], 1e-9)
            for ov in cells if ov["qps"] == qps and ov["engine"] == "overlapped")
        gain = base["p90_ttft_kv_s"] / max(best_lw["p90_ttft_kv_s"], 1e-9)
        rows.append(Row(
            f"overlap/qps{qps}/summary", 0.0,
            f"layerwise_vs_fullpull_worst_p90_ratio={worst_ratio:.3f};"
            f"blocking_vs_layerwise_p90_ttft_kv={gain:.2f}x"
            f"(batch={best_lw['batch']})"))
    for c in real or []:
        rows.append(Row(
            f"overlap/real/{c['mode']}",
            c["admit_to_first_tokens_s"] * 1e6,
            f"admit_to_done={c['admit_to_done_s']:.3f}s;"
            f"first_round_outputs={c['first_round_outputs']}/{c['n']};"
            f"empty_rounds={c['empty_rounds']};"
            f"pending_before_first_output_round="
            f"{c['pending_before_first_output_round']}"))
    return rows


def run() -> list[Row]:
    return _rows(sweep(), real_cells())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="fig_overlap.json")
    ap.add_argument("--skip-real", action="store_true",
                    help="sim sweep only (no JAX model build)")
    args = ap.parse_args()
    cells = sweep()
    real = [] if args.skip_real else real_cells()
    with open(args.out, "w") as f:
        json.dump({"config": {"duration_s": DURATION, "workload": "sharegpt",
                              "topology": "2P x 2D", "qps_grid": QPS_GRID,
                              "batch_grid": BATCH_GRID, "engines": ENGINES},
                   "cells": cells, "real": real}, f, indent=2)
    print(f"wrote {len(cells)} sim cells + {len(real)} real cells to {args.out}")
    print("name,us_per_call,derived")
    for row in _rows(cells, real):
        print(row.csv())


if __name__ == "__main__":
    main()
