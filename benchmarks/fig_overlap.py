"""Transfer/decode overlap sweep: admission batch size × QPS → TTFT.

Compares the two ends of the async-engine refactor on the discrete-event
simulator (2 prefill × 2 decode, pull mode):

  * ``blocking``   — the old synchronous engine: one-shot admission
    (batch = 1) and the decode worker sits in ``drain()`` for the whole
    multi-layer pull, so decode iterations and transfers mutually
    exclude on the worker;
  * ``overlapped`` — the async engine: router-batched admissions pipeline
    on the NIC while decode keeps iterating, and the layer-streamed pull
    makes a request decodable as soon as its layer-0 KV lands.  (The
    engine exposes per-layer completion; today's decode step still waits
    for COMPLETE, so the layer-0 join term models the exposed capability
    a pipelined decode consumer would realize — see ROADMAP.)

The reported metric is the KV-INCLUSIVE TTFT (paper §5.1: TTFT
"includes the waiting time for the KV cache"): arrival → the request is
decodable on its decode worker.  Expected shape: overlapped strictly
below blocking at EVERY swept QPS — at low load the layer-0 tail beats
the full-pull wait; at high load the un-stalled decode loop and batched
admissions also drain the KV queue faster.

As a benchmark module it emits CSV rows through run.py; run directly it
writes the full sweep as JSON:

    PYTHONPATH=src python -m benchmarks.fig_overlap [--out fig_overlap.json]
"""
from __future__ import annotations

import argparse
import json

from benchmarks.common import Row
from repro.configs import get_config
from repro.sim.costs import CostModel, H100_NODE
from repro.sim.events import ClusterSim, SimConfig
from repro.sim.workloads import SHAREGPT, sample_requests

DURATION = 120.0
QPS_GRID = (0.25, 0.5, 1.0, 2.0)
# Swept for BOTH engines.  blocking × batch>1 shows the synchronous
# trade-off (longer drain() stalls vs better NIC utilization); for the
# overlapped engine the cap stops mattering — admissions are re-kicked at
# every transfer/iteration completion, so the NIC stays busy even at
# batch=1 and the cells come out flat.  blocking/b1 is the one-shot
# baseline the acceptance comparison uses.
BATCH_GRID = (1, 4, 16)
SEED = 11


def _run(cfg: SimConfig, reqs) -> dict[str, float]:
    return ClusterSim(
        CostModel(get_config("mistral-large-123b"), H100_NODE), cfg
    ).run(list(reqs)).summary()


def sweep() -> list[dict]:
    cells = []
    for qps in QPS_GRID:
        reqs = sample_requests(SHAREGPT, qps=qps, duration_s=DURATION, seed=SEED)
        for engine in ("blocking", "overlapped"):
            for batch in BATCH_GRID:
                s = _run(SimConfig(n_prefill=2, n_decode=2, mode="pull",
                                   transfer_overlap=engine,
                                   admission_batch=batch), reqs)
                cells.append({
                    "engine": engine, "batch": batch, "qps": qps, "n": int(s["n"]),
                    "p50_ttft_kv_s": s["p50_ttft_kv_s"],
                    "p90_ttft_kv_s": s["p90_ttft_kv_s"],
                    "p90_total_s": s["p90_total_s"],
                })
    return cells


def _rows(cells: list[dict]) -> list[Row]:
    rows = []
    for c in cells:
        rows.append(Row(
            f"overlap/qps{c['qps']}/{c['engine']}/b{c['batch']}",
            c["p90_ttft_kv_s"] * 1e6,
            f"p50_ttft_kv={c['p50_ttft_kv_s']:.3f}s;"
            f"p90_ttft_kv={c['p90_ttft_kv_s']:.3f}s;"
            f"p90_e2e={c['p90_total_s']:.2f}s",
        ))
    # headline: best overlapped batch vs the one-shot blocking pull per QPS
    for qps in QPS_GRID:
        base = next(c for c in cells if c["qps"] == qps
                    and c["engine"] == "blocking" and c["batch"] == 1)
        best = min((c for c in cells if c["qps"] == qps and c["engine"] == "overlapped"),
                   key=lambda c: c["p90_ttft_kv_s"])
        gain = base["p90_ttft_kv_s"] / max(best["p90_ttft_kv_s"], 1e-9)
        rows.append(Row(
            f"overlap/qps{qps}/summary", 0.0,
            f"blocking_vs_overlapped_p90_ttft_kv={gain:.2f}x(batch={best['batch']})"))
    return rows


def run() -> list[Row]:
    return _rows(sweep())


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="fig_overlap.json")
    args = ap.parse_args()
    cells = sweep()
    with open(args.out, "w") as f:
        json.dump({"config": {"duration_s": DURATION, "workload": "sharegpt",
                              "topology": "2P x 2D", "qps_grid": QPS_GRID,
                              "batch_grid": BATCH_GRID},
                   "cells": cells}, f, indent=2)
    print(f"wrote {len(cells)} cells to {args.out}")
    print("name,us_per_call,derived")
    for row in _rows(cells):
        print(row.csv())


if __name__ == "__main__":
    main()
