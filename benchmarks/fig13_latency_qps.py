"""Fig. 13 — P90 per-request latency / TTFT / TBT vs QPS:
KVDirect (1 prefill + 1 decode worker) vs colocated vLLM-style baseline.

Paper headline: 55 % (arXiv) and 24 % (ShareGPT) per-request latency
reduction at matched per-node QPS (the colocated baseline's QPS is
halved for fairness — it uses half the nodes).  TBT stays flat for
KVDirect while the baseline's TBT grows up to 2.2× as prefills interrupt
decoding.
"""
from __future__ import annotations

import numpy as np

from benchmarks.common import Row, timeit
from repro.configs import get_config
from repro.sim.costs import CostModel, H100_NODE
from repro.sim.events import ClusterSim, SimConfig
from repro.sim.workloads import ARXIV, SHAREGPT, sample_requests

DURATION = 300.0


def _stretch(reqs, factor: float):
    """Same requests (identical lengths — no sampling confound), arrivals
    dilated: the paper's 'vLLM QPS divided by 2' fairness rule."""
    import dataclasses

    return [dataclasses.replace(r, arrival_s=r.arrival_s * factor) for r in reqs]


def _sim(reqs, mode, n_workers=(1, 1)) -> dict:
    cfg = get_config("mistral-large-123b")
    cost = CostModel(cfg, H100_NODE)
    sim = ClusterSim(cost, SimConfig(n_prefill=n_workers[0], n_decode=n_workers[1],
                                     mode=mode))
    return sim.run(list(reqs)).summary()


def run() -> list[Row]:
    rows = []
    reductions = {}
    for spec in (ARXIV, SHAREGPT):
        # spans into baseline saturation, like the paper's x-axes: the
        # headline reductions are load-dependent, and the paper's 55 %/24 %
        # live where the colocated scheduler degrades
        qps_grid = (0.125, 0.25, 0.375, 0.5) if spec is ARXIV else (0.25, 0.5, 0.75, 1.0)
        reds, tbt_ratio = [], []
        for qps in qps_grid:
            reqs = sample_requests(spec, qps=qps, duration_s=DURATION, seed=7)
            kv = _sim(reqs, "pull")
            # fair comparison: colocated uses HALF the nodes → half the QPS
            co = _sim(_stretch(reqs, 2.0), "colocated", n_workers=(1, 1))
            red = 1 - kv["p90_total_s"] / co["p90_total_s"]
            reds.append(red)
            tbt_ratio.append(co["p90_tbt_s"] / kv["p90_tbt_s"])
            rows.append(Row(
                f"fig13/{spec.name}/qps{qps}", kv["p90_total_s"] * 1e6,
                f"p90_ttft={kv['p90_ttft_s']:.2f}s;p90_tbt={kv['p90_tbt_s']*1e3:.1f}ms;"
                f"vs_vllm_reduction={red:.2f}",
            ))
        reductions[spec.name] = float(np.mean(reds))
        rows.append(Row(
            f"fig13/{spec.name}/summary", 0.0,
            f"mean_latency_reduction={np.mean(reds):.2f};"
            f"max_tbt_ratio={max(tbt_ratio):.2f}x;"
            + ("paper=0.55" if spec is ARXIV else "paper=0.24"),
        ))
    return rows
