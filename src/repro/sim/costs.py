"""Calibrated cost models for the cluster simulator.

Two hardware profiles:

  * ``H100_NODE`` — the paper's environment (8×H100-80G per worker,
    400 Gbps NIC): used by the paper-figure reproductions so our numbers
    are commensurable with the paper's.
  * ``V5E_POD_SLICE`` — a 16-chip v5e slice per worker (197 TFLOP/s bf16,
    819 GB/s HBM, 50 GB/s ICI per link): the TPU deployment this repo
    targets; used by the TPU-flavored benchmarks.

Model-compute terms use the standard roofline forms:
  prefill(L)  = max(2·N·L / (peak·MFU_prefill), attn quadratic term)
  decode step = max((param_bytes + kv_bytes(batch)) / HBM_bw,
                    2·N·batch / peak)        — memory-bound at small batch
with MFU factors calibrated against the dry-run cost_analysis
(EXPERIMENTS.md §Roofline).  KV transfer costs come from
core.transfer_engine.LinkModel — the SAME timing model the engine itself
accrues, so the simulator and the mechanism layer cannot drift apart.
"""
from __future__ import annotations

import dataclasses

from repro.core.transfer_engine import KVDIRECT_UTIL, LinkModel
from repro.models.config import ModelConfig

__all__ = ["HardwareProfile", "H100_NODE", "V5E_POD_SLICE", "CostModel"]


@dataclasses.dataclass(frozen=True)
class HardwareProfile:
    name: str
    peak_flops: float          # per worker, bf16
    hbm_bw: float              # per worker aggregate, B/s
    hbm_bytes: int             # per worker TOTAL HBM (weights come out of this)
    link: LinkModel
    mfu_prefill: float = 0.55
    mfu_decode: float = 0.90   # fraction of HBM bw achieved in decode
    activation_headroom: float = 0.10


H100_NODE = HardwareProfile(
    name="8xH100",
    peak_flops=8 * 989e12,
    hbm_bw=8 * 3.35e12,
    hbm_bytes=8 * 80 * 2**30,
    link=LinkModel.nic_400g(),
)

V5E_POD_SLICE = HardwareProfile(
    name="16xv5e",
    peak_flops=16 * 197e12,
    hbm_bw=16 * 819e9,
    hbm_bytes=16 * 16 * 2**30,
    link=LinkModel.ici(),
)


@dataclasses.dataclass
class CostModel:
    cfg: ModelConfig
    hw: HardwareProfile

    # ----------------------------------------------------------- compute
    def prefill_s(self, prompt_len: int) -> float:
        n = self.cfg.active_param_count()
        flops = 2.0 * n * prompt_len
        if self.cfg.has_attention:
            flops += 2.0 * prompt_len * prompt_len * self.cfg.attn_dim
        return flops / (self.hw.peak_flops * self.hw.mfu_prefill)

    def decode_step_s(self, active_tokens: int, batch: int) -> float:
        """One generation iteration for a continuous batch.

        memory term: every active request streams the params once
        (amortized over the batch) plus its own KV; compute term: 2·N per
        token."""
        n = self.cfg.active_param_count()
        param_bytes = 2.0 * self.cfg.param_count()
        kv_bytes = float(
            active_tokens * self.cfg.num_layers
            * self.cfg.kv_bytes_per_token_per_layer()
        )
        t_mem = (param_bytes + kv_bytes) / (self.hw.hbm_bw * self.hw.mfu_decode)
        t_flops = 2.0 * n * max(batch, 1) / (self.hw.peak_flops * self.hw.mfu_prefill)
        return max(t_mem, t_flops)

    # ------------------------------------------------------------ memory
    def kv_bytes_per_token(self) -> int:
        if self.cfg.has_attention:
            return self.cfg.num_layers * self.cfg.kv_bytes_per_token_per_layer()
        # SSM state: fixed per request; approximate per-token cost 0
        return 0

    def kv_capacity_tokens(self) -> int:
        """Tokens of KV a worker can hold: total HBM minus the bf16
        weights minus activation headroom.  For the paper's 123B model on
        8×80G this is ~0.8M tokens — the capacity wall behind
        Motivation #3 and the pull-vs-push gap."""
        per_tok = self.kv_bytes_per_token()
        if per_tok == 0:
            return 1 << 62
        weights = 2.0 * self.cfg.param_count()
        usable = self.hw.hbm_bytes * (1 - self.hw.activation_headroom) - weights
        if usable <= 0:
            raise ValueError(f"{self.cfg.name} does not fit {self.hw.name}")
        return int(usable / per_tok)

    # ---------------------------------------------------------- transfer
    # Bandwidth-utilization anchors measured by the paper:
    #   Fig. 4/15 — UCX (message-passing): 1.8 % of link at 4 KB blocks,
    #   capped at 13.6 % for ≥32 KB blocks; KVDirect: 22.23 GB/s of a
    #   400 Gbps link ≈ 44.5 %.  The engine microbenches reproduce the
    #   RATIO mechanistically; the simulator uses the paper's absolute
    #   utilizations so its latencies are commensurable with Figs. 13-17.
    KVDIRECT_UTIL = KVDIRECT_UTIL  # shared anchor (core.transfer_engine)
    MESSAGE_UTIL_4KB = 0.018
    MESSAGE_UTIL_CAP = 0.136

    def _message_util(self, span_bytes: float) -> float:
        return float(min(self.MESSAGE_UTIL_CAP,
                         self.MESSAGE_UTIL_4KB * (span_bytes / 4096.0)))

    def transfer_s(self, prompt_len: int, *, mode: str = "tensor_centric",
                   block_tokens: int = 32, coalesce_factor: float = 8.0) -> float:
        """KV-cache transfer time for one request.  ``coalesce_factor`` =
        average pages per RDMA op after §4.2 coalescing (measured by the
        engine); it scales the per-op posting overhead AND the effective
        message span."""
        bw = self.hw.link.bandwidth_Bps
        if not self.cfg.has_attention:
            # SSM: one contiguous state per layer — degenerate best case
            state_bytes = self.cfg.num_layers * 2 * self.cfg.ssm_inner * self.cfg.ssm_state
            return self.cfg.num_layers * self.hw.link.post_overhead_s + \
                state_bytes / (self.KVDIRECT_UTIL * bw)
        span = block_tokens * self.cfg.kv_bytes_per_token_per_layer() // 2  # one K or V span
        n_spans = -(-prompt_len // block_tokens) * self.cfg.num_layers * 2
        total_bytes = float(prompt_len * self.kv_bytes_per_token())
        if mode == "tensor_centric":
            n_ops = max(1, int(n_spans / coalesce_factor))
            return n_ops * self.hw.link.post_overhead_s + \
                total_bytes / (self.KVDIRECT_UTIL * bw)
        if mode == "message":
            return total_bytes / (self._message_util(span) * bw)
        raise ValueError(mode)

    def transfer_layer_tail_s(self, prompt_len: int, **kw) -> float:
        """Visible tail of a LAYER-STREAMED transfer: the consumer may
        start on layer 0 while layers 1..L-1 are still in flight, so the
        un-overlappable part is one layer's share.  Applies to paged KV
        and to per-layer SSM state alike (``pull_state``/``push_layer``
        both move one layer at a time) — the same tail the sim's push
        path has always modeled, now realized by the pull path's
        ``transfer_overlap="layerwise"`` consumer
        (``DecodeWorker(consume="layerwise")``)."""
        return self.transfer_s(prompt_len, **kw) / max(self.cfg.num_layers, 1)
