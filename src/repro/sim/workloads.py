"""Synthetic workloads matching the paper's two datasets (§5.1).

  arXiv     — long prompts, short responses: mean prompt 40,642 tokens,
              mean response 241 tokens (summarization).
  ShareGPT  — shorter prompts, long responses: mean prompt 20,471,
              mean response 2,328 (chat continuation).

Lengths are lognormal around the paper's means (real length
distributions are heavy-tailed); arrivals are a Poisson process, as in
the paper.  Everything is seeded for reproducibility.
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["WorkloadSpec", "ARXIV", "SHAREGPT", "sample_requests", "fixed_requests",
           "shared_prefix_requests", "bursty_requests", "diurnal_requests"]


@dataclasses.dataclass(frozen=True)
class WorkloadSpec:
    name: str
    mean_prompt: float
    mean_response: float
    sigma: float = 0.6  # lognormal shape
    max_prompt: int = 131_072
    max_response: int = 8_192


ARXIV = WorkloadSpec("arxiv", mean_prompt=40_642, mean_response=241)
SHAREGPT = WorkloadSpec("sharegpt", mean_prompt=20_471, mean_response=2_328)


def _lognormal_with_mean(rng, mean: float, sigma: float, n: int) -> np.ndarray:
    mu = np.log(mean) - 0.5 * sigma * sigma
    return rng.lognormal(mu, sigma, n)


@dataclasses.dataclass(frozen=True)
class SimRequest:
    request_id: str
    arrival_s: float
    prompt_len: int
    response_len: int
    # Shared-prefix identity for delta transfer / prefix-affinity sims:
    # requests with the same prefix_id share their first prefix_len
    # prompt tokens (0 with a prefix_id = the whole prompt).
    prefix_id: str | None = None
    prefix_len: int = 0
    # SLO class, for priority-ordered preemption victims and the SLO
    # admission policy (interactive | standard | batch).
    slo_class: str = "standard"


def sample_requests(spec: WorkloadSpec, *, qps: float, duration_s: float,
                    seed: int = 0) -> list[SimRequest]:
    rng = np.random.default_rng(seed)
    n = max(1, int(qps * duration_s * 1.2))
    gaps = rng.exponential(1.0 / qps, n)
    arrivals = np.cumsum(gaps)
    arrivals = arrivals[arrivals < duration_s]
    n = len(arrivals)
    prompts = np.clip(_lognormal_with_mean(rng, spec.mean_prompt, spec.sigma, n),
                      16, spec.max_prompt).astype(int)
    responses = np.clip(_lognormal_with_mean(rng, spec.mean_response, spec.sigma, n),
                        1, spec.max_response).astype(int)
    return [
        SimRequest(f"{spec.name}-{i}", float(arrivals[i]), int(prompts[i]), int(responses[i]))
        for i in range(n)
    ]


def fixed_requests(prompt_len: int, response_len: int, *, qps: float,
                   duration_s: float, seed: int = 0) -> list[SimRequest]:
    """Fig. 12-style fixed workloads, e.g. 8192-512."""
    rng = np.random.default_rng(seed)
    n = max(1, int(qps * duration_s * 1.2))
    arrivals = np.cumsum(rng.exponential(1.0 / qps, n))
    arrivals = arrivals[arrivals < duration_s]
    return [
        SimRequest(f"fixed-{i}", float(a), prompt_len, response_len)
        for i, a in enumerate(arrivals)
    ]


def _lengths(rng, spec: WorkloadSpec, n: int) -> tuple[np.ndarray, np.ndarray]:
    prompts = np.clip(_lognormal_with_mean(rng, spec.mean_prompt, spec.sigma, n),
                      16, spec.max_prompt).astype(int)
    responses = np.clip(_lognormal_with_mean(rng, spec.mean_response, spec.sigma, n),
                        1, spec.max_response).astype(int)
    return prompts, responses


def bursty_requests(spec: WorkloadSpec, *, qps_on: float, qps_off: float,
                    mean_on_s: float, mean_off_s: float, duration_s: float,
                    seed: int = 0) -> list[SimRequest]:
    """On/off Markov-modulated Poisson arrivals — the elastic-scaling
    stressor (benchmarks/fig_elastic.py).

    The process alternates between an ON phase (rate ``qps_on``) and an
    OFF phase (rate ``qps_off``), with exponentially distributed phase
    lengths (means ``mean_on_s`` / ``mean_off_s``), starting ON.  A
    static fleet sized for the mean under-provisions the bursts and
    over-provisions the lulls; an autoscaler can track the phases.

    Seeded and deterministic: the SAME request list (ids, arrival times,
    lengths) drives both ``sim.ClusterSim`` and the real serving
    substrate, so sim-vs-real comparisons share the workload
    byte-for-byte.
    """
    rng = np.random.default_rng(seed)
    arrivals: list[float] = []
    t, on = 0.0, True
    while t < duration_s:
        end = min(t + rng.exponential(mean_on_s if on else mean_off_s),
                  duration_s)
        qps = qps_on if on else qps_off
        if qps > 0:
            a = t + rng.exponential(1.0 / qps)
            while a < end:
                arrivals.append(a)
                a += rng.exponential(1.0 / qps)
        t, on = end, not on
    prompts, responses = _lengths(rng, spec, len(arrivals))
    return [
        SimRequest(f"burst-{i}", float(a), int(prompts[i]), int(responses[i]))
        for i, a in enumerate(arrivals)
    ]


def diurnal_requests(spec: WorkloadSpec, *, qps_peak: float, qps_trough: float,
                     period_s: float, duration_s: float,
                     seed: int = 0) -> list[SimRequest]:
    """Sinusoidal daily-cycle arrivals via Lewis thinning: a homogeneous
    Poisson process at ``qps_peak`` is thinned to the instantaneous rate

        λ(t) = trough + (peak − trough) · (1 + sin(2πt/period)) / 2

    — the smooth counterpart of ``bursty_requests`` (hours-scale drift
    instead of seconds-scale bursts), for autoscaler experiments where
    the fleet should track a slow swell without thrashing.  Seeded and
    deterministic like every generator here.
    """
    if qps_trough > qps_peak:
        raise ValueError(f"qps_trough {qps_trough} exceeds qps_peak {qps_peak}")
    rng = np.random.default_rng(seed)
    arrivals: list[float] = []
    t = 0.0
    while True:
        t += rng.exponential(1.0 / qps_peak)
        if t >= duration_s:
            break
        lam = qps_trough + (qps_peak - qps_trough) * \
            0.5 * (1.0 + np.sin(2.0 * np.pi * t / period_s))
        if rng.uniform() < lam / qps_peak:
            arrivals.append(t)
    prompts, responses = _lengths(rng, spec, len(arrivals))
    return [
        SimRequest(f"diurnal-{i}", float(a), int(prompts[i]), int(responses[i]))
        for i, a in enumerate(arrivals)
    ]


def shared_prefix_requests(prompt_len: int, response_len: int, *, qps: float,
                           duration_s: float, prefix_frac: float = 0.5,
                           n_prefixes: int = 4, seed: int = 0) -> list[SimRequest]:
    """Delta-transfer workload: fixed-shape requests where each arrival
    shares the first ``prefix_frac`` of its prompt with every other
    request carrying the same prefix id (``n_prefixes`` distinct shared
    system prompts, assigned uniformly at random).  With delta transfer
    on, every request after a prefix's first pull moves only the
    remaining ``1 - prefix_frac`` suffix."""
    if not 0.0 <= prefix_frac <= 1.0:
        raise ValueError(f"prefix_frac must be in [0, 1], got {prefix_frac}")
    rng = np.random.default_rng(seed)
    n = max(1, int(qps * duration_s * 1.2))
    arrivals = np.cumsum(rng.exponential(1.0 / qps, n))
    arrivals = arrivals[arrivals < duration_s]
    prefix_len = int(prompt_len * prefix_frac)
    picks = rng.integers(0, max(n_prefixes, 1), len(arrivals))
    return [
        SimRequest(f"pfx-{i}", float(a), prompt_len, response_len,
                   prefix_id=f"prefix{picks[i]}", prefix_len=prefix_len)
        for i, a in enumerate(arrivals)
    ]
