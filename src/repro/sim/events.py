"""Discrete-event cluster simulator for disaggregated LLM serving.

Reproduces the paper's latency experiments (Figs. 6, 12, 13, 14, 16, 17)
at cluster scale on a laptop: Poisson arrivals, xP yD worker topologies,
continuous-batching decode, KV-capacity admission, pull- vs push-mode
transfer semantics, and a colocated prefill-prioritizing baseline
(the paper's vLLM comparison).

Mechanism fidelity:
  * pull-mode — decode-side KV is allocated only when prefill FINISHES;
    prefill-side KV is held until COMPLETE (end of transfer); a full
    decode pool queues requests while their prefill-side KV stays alive
    and the prefill worker keeps computing other requests (§4.3).
  * push-mode — decode-side KV is RESERVED at admission (before prefill
    starts); transfer overlaps prefill layer-by-layer, so its visible
    tail is one layer's worth; a full decode pool blocks prefill from
    even starting (Motivation #3).
  * colocated — one worker pool does both stages, prefill prioritized at
    iteration boundaries (vLLM-like): a long prefill stalls every
    resident decode for its duration (the TBT blow-up of Fig. 13).

Timing comes from sim.costs.CostModel; transfer timing shares the SAME
LinkModel as the real transfer engine.
"""
from __future__ import annotations

import dataclasses
import heapq
import itertools
from typing import Callable

import numpy as np

from repro.fleet.autoscale import Autoscaler as FleetAutoscaler
from repro.fleet.config import DEFAULT_CLASS_RANK, FleetConfig
from repro.sched.load import LoadReport
from repro.sched.policies import Candidate, Policy, RouteRequest, make_policy
from repro.serving.request import Request, RequestState
from repro.sim.costs import CostModel
from repro.sim.workloads import SimRequest

__all__ = ["ClusterSim", "SimConfig", "SimResults", "percentile"]


@dataclasses.dataclass(frozen=True)
class SimConfig:
    n_prefill: int = 1
    n_decode: int = 1
    mode: str = "pull"            # pull | push | colocated
    transfer_mode: str = "tensor_centric"  # tensor_centric | message
    coalesce_factor: float = 8.0
    max_decode_batch: int = 64
    reserve_response: bool = True  # reserve prompt+response tokens at admission
    # straggler mitigation: if a prefill exceeds hedge_factor × its nominal
    # time, duplicate it on an idle worker; first finisher wins
    hedge_prefill: bool = False
    hedge_factor: float = 2.0
    # scheduling: sched.policies name driving prefill/decode placement
    # (round_robin | least_loaded | network_aware | slo)
    policy: str = "least_loaded"
    # TTFT deadline (s) for policy="slo": arrivals whose projected TTFT
    # exceeds it are rejected at admission instead of degrading everyone
    slo_s: float | None = None
    # Transfer-engine model (pull mode): how a decode worker's KV pulls
    # interact with its decode iterations, and WHEN a request becomes
    # decodable (its consumer mode).
    #   "blocking"   — the synchronous engine: the worker sits in drain()
    #                  for the whole pull, so decode iterations and
    #                  transfers mutually exclude on the worker.
    #   "pipelined"  — pulls serialize on the NIC but never block decode;
    #                  a request joins decode when its whole pull lands.
    #   "overlapped" — the async engine with FULL-PULL consumption (the
    #                  serving path's consume="full"): decode never
    #                  blocks, admissions batch, but the first decode
    #                  step still waits for COMPLETE — so the join point
    #                  is the last byte, same as "pipelined".  Kept as a
    #                  distinct name so sweeps can label the engine
    #                  generation they model.
    #   "layerwise"  — the pipelined attention consumer (the serving
    #                  path's consume="layerwise"): the first decode step
    #                  runs layer l's attention as soon as layer l's
    #                  reads land, so the request joins decode once its
    #                  layer-0 KV arrives (visible tail = one layer's
    #                  share, costs.transfer_layer_tail_s); COMPLETE —
    #                  and the prefill-side free — still waits for the
    #                  last byte.
    transfer_overlap: str = "pipelined"
    # max KV_QUEUED admissions started per scheduling opportunity
    # (0 = admit everything that fits; 1 = one-shot admission)
    admission_batch: int = 0
    # Decode batching discipline — the admission semantics the REAL
    # serving layer exposes, so the simulator and service stay honest
    # with each other:
    #   "continuous" — requests join the running batch at the next
    #                  iteration boundary and leave as they finish (the
    #                  ServeLoop / DecodeWorker.step path);
    #   "round"      — the legacy round-synchronous generate_many: a
    #                  worker freezes its cohort when a round starts;
    #                  requests arriving mid-round wait for the WHOLE
    #                  cohort to drain before decoding begins (their
    #                  decode_start_s — and so KV-inclusive TTFT — eats
    #                  the cohort tail).
    batching: str = "continuous"
    # Delta transfer (pull mode): decode workers retain finished
    # requests' shared-prefix KV (LRU over prefix ids, bounded by
    # prefix_cache_cap) and pull only the suffix for a later request
    # with the same prefix — the sim twin of DecodeWorker's delta
    # admission (docs/transfer.md).
    delta_transfer: bool = False
    prefix_cache_cap: int = 4
    # Quantized transfer: int8 wire format halves the bytes actually
    # moved (per-span scales are noise at this scale); compute is
    # unchanged — the slab dequantizes on landing.
    quantize_transfer: bool = False
    # ---- fleet mirror (docs/fleet.md): the SAME policy space as
    # repro.fleet, so swap-vs-sacrifice and autoscaling choices rank in
    # simulation before they run on the real substrate. ----
    # Memory-pressure preemption (pull mode): what a decode worker does
    # when its pool is >= preempt_high full and the head waiter doesn't
    # fit even after prefix eviction.  "swap" parks the victim's KV in
    # host memory (resume priced at swap_cost_scale x the wire transfer
    # of its context); "sacrifice" drops it and replays from prefill.
    preemption: str = "none"        # none | swap | sacrifice
    victim_policy: str = "lifo"     # lifo | fifo | priority
    preempt_high: float = 0.92
    swap_cost_scale: float = 0.25
    max_preemptions: int = 2
    # Autoscaling (pull mode): the sim drives the REAL repro.fleet
    # Autoscaler (same decision code) on LoadReports built from sim
    # worker state, evaluated every autoscale_interval_s.  Shrink is
    # drain-then-retire, exactly like the serving layer.
    autoscale: bool = False
    autoscale_interval_s: float = 5.0
    autoscale_up: float = 0.85
    autoscale_down: float = 0.25
    autoscale_patience: int = 2
    min_prefill: int = 1
    max_prefill: int = 4
    min_decode: int = 1
    max_decode: int = 4
    total_cap: int | None = None    # equal-peak-hardware P/D-ratio mode
    # Completed-by-horizon accounting: requests DONE by horizon_s count
    # as completed in SimResults.summary() (None = end of sim).
    horizon_s: float | None = None


@dataclasses.dataclass
class SimResults:
    requests: list[Request]
    rejected: list[Request] = dataclasses.field(default_factory=list)
    # Delta-transfer accounting (tokens, per request): what moved on the
    # wire vs what a delta plan served from resident prefix KV.
    pulled_tokens: dict[str, int] = dataclasses.field(default_factory=dict)
    reused_tokens: dict[str, int] = dataclasses.field(default_factory=dict)
    # Fleet-mirror accounting: preemption action counts and the horizon
    # for completed-by-horizon throughput (None = end of sim).
    n_swapped: int = 0
    n_sacrificed: int = 0
    horizon_s: float | None = None

    def completed_by(self, t: float | None = None) -> int:
        """Requests DONE by ``t`` (default: the configured horizon; no
        horizon = all finished requests) — the throughput metric that
        makes park-only vs preemption comparable: parked work that never
        ran counts as zero, not as 'still pending'."""
        t = self.horizon_s if t is None else t
        if t is None:
            return len(self.requests)
        return sum(1 for r in self.requests
                   if r.done_s is not None and r.done_s <= t)

    def _metric(self, fn) -> list[float]:
        return [v for v in (fn(r) for r in self.requests) if v is not None]

    @staticmethod
    def _ttft_kv(r: Request) -> float | None:
        if r.decode_start_s is None:
            return None
        return r.decode_start_s - r.arrival_s

    def p(self, q: float, fn) -> float:
        vals = self._metric(fn)
        return float(np.percentile(vals, q)) if vals else float("nan")

    def summary(self) -> dict[str, float]:
        return {
            "n": len(self.requests),
            "n_rejected": len(self.rejected),
            "p50_total_s": self.p(50, lambda r: r.total_latency_s),
            "p90_total_s": self.p(90, lambda r: r.total_latency_s),
            "p50_ttft_s": self.p(50, lambda r: r.ttft_s),
            "p90_ttft_s": self.p(90, lambda r: r.ttft_s),
            "p50_tbt_s": self.p(50, lambda r: r.tbt_s),
            "p90_tbt_s": self.p(90, lambda r: r.tbt_s),
            # KV-inclusive TTFT (paper §5.1: TTFT "includes the waiting
            # time for the KV cache"): arrival → request decodable on the
            # decode worker.  The metric the transfer-overlap engine moves.
            "p50_ttft_kv_s": self.p(50, self._ttft_kv),
            "p90_ttft_kv_s": self.p(90, self._ttft_kv),
            "mean_total_s": float(np.mean(self._metric(lambda r: r.total_latency_s) or [np.nan])),
            "mean_pulled_tokens": float(np.mean(list(self.pulled_tokens.values()))
                                        if self.pulled_tokens else 0.0),
            "mean_reused_tokens": float(np.mean(list(self.reused_tokens.values()))
                                        if self.reused_tokens else 0.0),
            "kv_reuse_frac": self._reuse_frac(),
            "completed": self.completed_by(),
            "n_swapped": self.n_swapped,
            "n_sacrificed": self.n_sacrificed,
        }

    def _reuse_frac(self) -> float:
        pulled = sum(self.pulled_tokens.values())
        reused = sum(self.reused_tokens.values())
        total = pulled + reused
        return reused / total if total else 0.0

    def mean_breakdown(self) -> dict[str, float]:
        keys = ["prefill_queue_s", "prefill_s", "transfer_s", "decode_queue_s", "decode_s"]
        acc = {k: 0.0 for k in keys}
        n = 0
        for r in self.requests:
            if r.done_s is None:
                continue
            b = r.breakdown()
            for k in keys:
                acc[k] += b[k]
            n += 1
        return {k: v / max(n, 1) for k, v in acc.items()}


def percentile(vals, q):
    return float(np.percentile(vals, q)) if len(vals) else float("nan")


# ----------------------------------------------------------------------
class _PrefillWorker:
    def __init__(self, wid: str, cap_tokens: int, slowdown: float = 1.0):
        self.wid = wid
        self.busy_until = 0.0
        self.held_tokens = 0      # KV held until COMPLETE (pull) / pushed (push)
        self.cap_tokens = cap_tokens
        self.slowdown = slowdown  # >1 = straggling node
        self.draining = False     # no new work; retires when idle + empty


class _DecodeWorker:
    def __init__(self, wid: str, cap_tokens: int, cfg: SimConfig,
                 slowdown: float = 1.0):
        self.wid = wid
        self.cap_tokens = cap_tokens
        self.slowdown = slowdown  # >1 = slower HBM than the reference node
        self.used_tokens = 0
        self.active: list[Request] = []
        self.kv_queue: list[Request] = []      # pull: waiting for decode KV
        self.round_wait: list[Request] = []    # round batching: next cohort
        self.nic_free_at = 0.0
        self.pull_busy_until = 0.0  # blocking engine: worker stuck in drain()
        self.iter_end = 0.0         # end of the in-flight decode iteration
        self.iterating = False
        self.cfg = cfg
        # Delta transfer: retained prefix KV (prefix_id -> tokens held),
        # LRU over insertion order; the held tokens stay in used_tokens
        # until eviction — the sim twin of DecodeWorker.prefix_cache.
        self.prefix_cache: dict[str, int] = {}
        # Fleet mirror: swapped-out victims (FIFO resume order; base
        # alloc tokens recharged at swap-in), drain flag, and in-flight
        # pull count (a draining worker retires only when all are zero).
        self.swapped: list[tuple[Request, int]] = []
        self.draining = False
        self.inflight_pulls = 0

    def free_tokens(self) -> int:
        return self.cap_tokens - self.used_tokens


class ClusterSim:
    """Heap-driven event loop.  Synchronous callbacks, deterministic."""

    def __init__(self, cost: CostModel, sim_cfg: SimConfig,
                 *, prefill_slowdowns: dict[str, float] | None = None,
                 link_scales: dict[tuple[str, str], float] | None = None,
                 symmetric_links: bool = False,
                 topology=None):
        self.cost = cost
        self.cfg = sim_cfg
        self._heap: list = []
        self._seq = itertools.count()
        self.now = 0.0
        cap = cost.kv_capacity_tokens()
        self._cap = cap
        self._slowdowns = dict(prefill_slowdowns or {})
        # Heterogeneous topology (topo.TopologyBinding): per-machine
        # capability scales and per-pair bandwidth/latency replayed from
        # the SAME ClusterSpec the real service binds — mutually
        # exclusive with the flat link_scales/prefill_slowdowns knobs.
        if topology is not None:
            if link_scales:
                raise ValueError("topology and link_scales are mutually "
                                 "exclusive — the binding derives pair costs")
            if prefill_slowdowns:
                raise ValueError("topology and prefill_slowdowns are mutually "
                                 "exclusive — the binding derives slowdowns")
            if sim_cfg.mode == "colocated":
                raise ValueError("topology models a disaggregated cluster "
                                 f"(mode={sim_cfg.mode!r})")
            if (topology.n_prefill, topology.n_decode) != \
                    (sim_cfg.n_prefill, sim_cfg.n_decode):
                raise ValueError(
                    f"topology binds {topology.n_prefill}P+{topology.n_decode}D "
                    f"but SimConfig says {sim_cfg.n_prefill}P+{sim_cfg.n_decode}D")
        self.topology = topology
        self.prefills = [self._new_prefill(f"p{i}")
                         for i in range(sim_cfg.n_prefill)]
        self.decodes = [self._new_decode(f"d{i}")
                        for i in range(sim_cfg.n_decode)]
        # hot-added worker ids continue the seed numbering (never reused)
        self._wid_p = itertools.count(sim_cfg.n_prefill)
        self._wid_d = itertools.count(sim_cfg.n_decode)
        self.prefill_queue: list[Request] = []
        self.push_admission: list[Request] = []
        self._meta: dict[str, SimRequest] = {}
        self.finished: list[Request] = []
        self.rejected: list[Request] = []
        # delta-transfer accounting (tokens): wire vs resident-graft,
        # plus what each admission actually drew from its worker's pool
        self.pulled_tokens: dict[str, int] = {}
        self.reused_tokens: dict[str, int] = {}
        self._alloc_tokens: dict[str, int] = {}
        # per-(prefill, decode) link multiplier on transfer time — the
        # skewed topology the network-aware policy exploits (NetKV).
        # Keys are validated against the worker-id space up front: a typo
        # or a reversed (decode, prefill) pair used to silently fall back
        # to 1.0 and quietly un-skew the experiment.
        self.link_scales = self._validate_link_scales(
            link_scales or {}, symmetric_links)
        if sim_cfg.transfer_overlap not in (
                "pipelined", "blocking", "overlapped", "layerwise"):
            raise ValueError(
                f"transfer_overlap must be pipelined|blocking|overlapped|"
                f"layerwise, got {sim_cfg.transfer_overlap!r}")
        if sim_cfg.batching not in ("continuous", "round"):
            raise ValueError(
                f"batching must be continuous|round, got {sim_cfg.batching!r}")
        if sim_cfg.batching == "round" and sim_cfg.mode == "colocated":
            raise ValueError(
                "batching='round' models the disaggregated generate_many "
                "cohorts; the colocated baseline has its own iteration rule")
        if sim_cfg.policy == "slo":
            if sim_cfg.slo_s is None:
                raise ValueError(
                    "SimConfig(policy='slo') requires slo_s — admission "
                    "against an unconfigured default deadline would "
                    "silently drop requests")
            self.policy = make_policy("slo", classes={"standard": sim_cfg.slo_s})
        else:
            self.policy = make_policy(sim_cfg.policy)
        # ---- fleet mirror ----
        if sim_cfg.preemption not in ("none", "swap", "sacrifice"):
            raise ValueError(
                f"preemption must be none|swap|sacrifice, got {sim_cfg.preemption!r}")
        if sim_cfg.victim_policy not in ("lifo", "fifo", "priority"):
            raise ValueError(
                f"victim_policy must be lifo|fifo|priority, got {sim_cfg.victim_policy!r}")
        if sim_cfg.preemption != "none" and sim_cfg.mode != "pull":
            raise ValueError("preemption models the pull-mode decode pool "
                             f"(mode={sim_cfg.mode!r})")
        if sim_cfg.autoscale and sim_cfg.mode != "pull":
            raise ValueError(f"autoscale requires mode='pull' (got {sim_cfg.mode!r})")
        self.n_swapped = 0
        self.n_sacrificed = 0
        self._preempt_count: dict[str, int] = {}
        self._tok_at_preempt: dict[str, int] = {}
        self._n_expected = 0
        if sim_cfg.autoscale:
            # the REAL autoscaler decision code (repro.fleet), fed
            # LoadReports built from sim worker state — the decision
            # path cannot drift between sim and serving layer
            self.autoscaler = FleetAutoscaler(FleetConfig(
                autoscale=True,
                min_prefill=sim_cfg.min_prefill, max_prefill=sim_cfg.max_prefill,
                min_decode=sim_cfg.min_decode, max_decode=sim_cfg.max_decode,
                total_cap=sim_cfg.total_cap,
                scale_up=sim_cfg.autoscale_up, scale_down=sim_cfg.autoscale_down,
                patience=sim_cfg.autoscale_patience))
        else:
            self.autoscaler = None

    # ----------------------------------------------------------- topology
    def _new_prefill(self, wid: str) -> _PrefillWorker:
        topo = self.topology
        if topo is None:
            return _PrefillWorker(wid, self._cap, self._slowdowns.get(wid, 1.0))
        if topo.machine(wid) is None:  # hot-add: claim the best spare
            topo.add_worker("prefill", wid)
        cap = max(1, int(self._cap * topo.cap_scale(wid, self.cost.hw.hbm_bytes)))
        return _PrefillWorker(
            wid, cap, topo.prefill_slowdown(wid, self.cost.hw.peak_flops))

    def _new_decode(self, wid: str) -> _DecodeWorker:
        topo = self.topology
        if topo is None:
            return _DecodeWorker(wid, self._cap, self.cfg)
        if topo.machine(wid) is None:
            topo.add_worker("decode", wid)
        cap = max(1, int(self._cap * topo.cap_scale(wid, self.cost.hw.hbm_bytes)))
        return _DecodeWorker(
            wid, cap, self.cfg,
            slowdown=topo.decode_slowdown(wid, self.cost.hw.hbm_bw))

    def _validate_link_scales(self, scales, symmetric: bool):
        n_p = max(self.cfg.n_prefill,
                  self.cfg.max_prefill if self.cfg.autoscale else 0)
        n_d = max(self.cfg.n_decode,
                  self.cfg.max_decode if self.cfg.autoscale else 0)
        pids = {f"p{i}" for i in range(n_p)}
        dids = {f"d{i}" for i in range(n_d)}
        out: dict[tuple[str, str], float] = {}
        for (a, b), v in scales.items():
            if a in pids and b in dids:
                key = (a, b)
            elif a in dids and b in pids:
                if not symmetric:
                    raise ValueError(
                        f"link_scales key {(a, b)} is (decode, prefill) — "
                        "keys are directed (prefill, decode); pass "
                        "symmetric_links=True for undirected scales")
                key = (b, a)
            else:
                raise ValueError(
                    f"link_scales key {(a, b)} references unknown worker "
                    f"ids (prefill: {sorted(pids)}, decode: {sorted(dids)})")
            if key in out and out[key] != v:
                raise ValueError(f"conflicting link_scales for pair {key}: "
                                 f"{out[key]} vs {v}")
            out[key] = v
        return out

    # ------------------------------------------------------------ events
    def _at(self, t: float, fn: Callable[[], None]) -> None:
        heapq.heappush(self._heap, (t, next(self._seq), fn))

    def run(self, sim_reqs: list[SimRequest]) -> SimResults:
        self._n_expected = len(sim_reqs)
        for sr in sim_reqs:
            self._at(sr.arrival_s, lambda sr=sr: self._arrive(sr))
        if self.autoscaler is not None:
            self._at(self.cfg.autoscale_interval_s, self._autoscale_tick)
        while self._heap:
            self.now, _, fn = heapq.heappop(self._heap)
            fn()
        return SimResults(self.finished, self.rejected,
                          pulled_tokens=dict(self.pulled_tokens),
                          reused_tokens=dict(self.reused_tokens),
                          n_swapped=self.n_swapped,
                          n_sacrificed=self.n_sacrificed,
                          horizon_s=self.cfg.horizon_s)

    # -------------------------------------------------------- scheduling
    def _ctx(self, req: Request) -> RouteRequest:
        return RouteRequest(
            req.request_id, req.prompt_len,
            kv_bytes=req.prompt_len * self.cost.kv_bytes_per_token(),
            slo_class=req.slo_class, arrival_s=req.arrival_s,
            prefix_id=req.prefix_id,
        )

    def _link_scale(self, req: Request, decode_wid: str) -> float:
        if req.prefill_worker is None:
            return 1.0
        return self.link_scales.get((req.prefill_worker, decode_wid), 1.0)

    def _pair_cost(self, req: Request, decode_wid: str) -> tuple[float, float]:
        """(bandwidth scale, propagation latency) for the request's
        (prefill, decode) pair: from the bound topology when present,
        else the flat link_scales multiplier (zero latency)."""
        if self.topology is None or req.prefill_worker is None:
            return self._link_scale(req, decode_wid), 0.0
        ref_bw = self.cost.hw.link.bandwidth_Bps
        return (self.topology.pair_scale(req.prefill_worker, decode_wid, ref_bw),
                self.topology.pair_latency_s(req.prefill_worker, decode_wid))

    def _resident_tokens(self, req: Request, d: "_DecodeWorker") -> int:
        """Prefix tokens of ``req`` already resident on ``d`` — what a
        delta plan grafts instead of pulling."""
        if not self.cfg.delta_transfer or not req.prefix_id:
            return 0
        cached = d.prefix_cache.get(req.prefix_id, 0)
        plen = req.prefix_len or req.prompt_len
        return min(cached, plen, req.prompt_len)

    def _pair_transfer_s(self, req: Request, decode_wid: str) -> float:
        d = next(x for x in self.decodes if x.wid == decode_wid)
        suffix = req.prompt_len - self._resident_tokens(req, d)
        wire_scale = 0.5 if self.cfg.quantize_transfer else 1.0
        scale, latency_s = self._pair_cost(req, decode_wid)
        return latency_s + wire_scale * scale * self.cost.transfer_s(
            suffix, mode=self.cfg.transfer_mode,
            coalesce_factor=self.cfg.coalesce_factor)

    def _pair_layer_tail_s(self, req: Request, decode_wid: str) -> float:
        """Layer-streamed pull: delay from transfer start to the request
        becoming decodable (layer 0 landed; later layers hide behind the
        per-layer decode pipeline).  Delta/quantized transfer shrink the
        per-layer share the same way they shrink the whole pull."""
        d = next(x for x in self.decodes if x.wid == decode_wid)
        suffix = req.prompt_len - self._resident_tokens(req, d)
        wire_scale = 0.5 if self.cfg.quantize_transfer else 1.0
        scale, latency_s = self._pair_cost(req, decode_wid)
        # layer 0 cannot land before the first byte crosses the path, so
        # the propagation latency is part of the visible tail too
        return latency_s + wire_scale * scale * \
            self.cost.transfer_layer_tail_s(
                suffix, mode=self.cfg.transfer_mode,
                coalesce_factor=self.cfg.coalesce_factor)

    def _projected_ttft_s(self, req: Request) -> float:
        """Admission-time TTFT projection: mean backlog wait + own
        prefill.  Deliberately NO transfer term — measured TTFT is the
        first token, which this simulator emits at prefill completion
        (before the KV pull), and the projection must target the same
        definition or admission over-rejects."""
        own = self.cost.prefill_s(req.prompt_len)
        if self.cfg.mode == "colocated":
            backlog = sum(self.cost.prefill_s(r.prompt_len)
                          for d in self.decodes for r in d.kv_queue)
            return backlog / max(len(self.decodes), 1) + own
        backlog = sum(self.cost.prefill_s(r.prompt_len) for r in self.prefill_queue)
        busy = sum(max(0.0, w.busy_until - self.now) for w in self.prefills)
        return (busy + backlog) / max(len(self.prefills), 1) + own

    # ------------------------------------------------------- disagg flow
    def _arrive(self, sr: SimRequest) -> None:
        req = Request(sr.request_id, sr.prompt_len, sr.response_len, arrival_s=self.now,
                      prefix_id=sr.prefix_id, prefix_len=sr.prefix_len,
                      slo_class=sr.slo_class)
        self._meta[sr.request_id] = sr
        # Admission first, in EVERY mode (colocated must not silently
        # bypass the SLO controller).  Projection is O(queue); only pay
        # for it if the policy actually implements admission control.
        if type(self.policy).admit is not Policy.admit and \
                not self.policy.admit(self._ctx(req), self._projected_ttft_s(req)):
            req.to(RequestState.FAILED)  # SLO admission: reject up front
            self.rejected.append(req)
            return
        if self.cfg.mode == "colocated":
            self._co_arrive(req)
            return
        if self.cfg.mode == "push":
            # Fig. 10 step 1: the DECODE worker allocates blocks AT ARRIVAL,
            # before the prompt is even sent to the prefill worker.  This is
            # the held-but-idle memory of Motivation #3: while the request
            # waits for (and runs) prefill, its decode blocks serve nobody.
            self.push_admission.append(req)
            self._try_push_admissions()
            return
        self.prefill_queue.append(req)
        self._try_start_prefills()

    def _try_push_admissions(self) -> None:
        while self.push_admission:
            req = self.push_admission[0]
            # only offer workers that can actually hold the reservation —
            # a policy pick among non-fitting workers must not stall the
            # queue while another worker has room
            fitting = [d for d in self.decodes
                       if d.free_tokens() >= self._reserved_tokens(req)]
            if not fitting:
                break  # decode pools exhausted by reservations: admissions stall
            d = self._pick_decode(req, fitting)
            self.push_admission.pop(0)
            d.used_tokens += self._reserved_tokens(req)
            req.decode_worker = d.wid
            self.prefill_queue.append(req)
        # ALWAYS re-kick prefill: already-admitted requests may be waiting
        # for the worker even when the head admission stalls
        self._try_start_prefills()

    def _reserved_tokens(self, req: Request) -> int:
        extra = req.max_new_tokens if self.cfg.reserve_response else 0
        return req.prompt_len + extra

    def _pick_prefill(self, req: Request, cands: list[_PrefillWorker]) -> _PrefillWorker:
        chosen = self.policy.pick_prefill(self._ctx(req), [
            Candidate(w.wid,
                      free_units=w.cap_tokens - w.held_tokens,
                      total_units=w.cap_tokens,
                      ready_s=max(0.0, w.busy_until - self.now))
            for w in cands
        ])
        return next(w for w in cands if w.wid == chosen.worker_id)

    def _try_start_prefills(self) -> None:
        while self.prefill_queue:
            req = self.prefill_queue[0]
            cands = [w for w in self.prefills
                     if not w.draining and w.busy_until <= self.now
                     and w.held_tokens + req.prompt_len <= w.cap_tokens]
            if not cands:
                break  # every worker busy or HBM-full: wait
            w = self._pick_prefill(req, cands)
            self.prefill_queue.pop(0)
            req.prefill_worker = w.wid
            w.held_tokens += req.prompt_len
            req.to(RequestState.PREFILLING)
            req.prefill_start_s = self.now
            nominal = self.cost.prefill_s(req.prompt_len)
            dt = nominal * w.slowdown
            w.busy_until = self.now + dt
            self._at(w.busy_until, lambda req=req, w=w: self._prefill_done(req, w))
            if self.cfg.hedge_prefill:
                self._at(self.now + self.cfg.hedge_factor * nominal,
                         lambda req=req: self._maybe_hedge(req))

    def _maybe_hedge(self, req: Request) -> None:
        """Straggler mitigation: the prefill blew past hedge_factor × its
        nominal time — duplicate it on an idle, faster worker (first
        finisher wins; the loser's completion is ignored)."""
        if req.state is not RequestState.PREFILLING or req.prefill_end_s is not None:
            return
        cand = [w for w in self.prefills
                if not w.draining and w.busy_until <= self.now
                and w.wid != req.prefill_worker
                and w.held_tokens + req.prompt_len <= w.cap_tokens]
        if not cand:
            return
        w = min(cand, key=lambda w: w.slowdown)
        req.retries += 1
        w.held_tokens += req.prompt_len
        dt = self.cost.prefill_s(req.prompt_len) * w.slowdown
        w.busy_until = self.now + dt
        self._at(w.busy_until, lambda req=req, w=w: self._prefill_done(req, w))

    def _prefill_done(self, req: Request, w: _PrefillWorker) -> None:
        if req.prefill_end_s is not None:
            # a hedge twin already won; just release this copy's KV
            w.held_tokens -= req.prompt_len
            self._try_start_prefills()
            return
        req.prefill_worker = w.wid  # the winner owns the KV to pull from
        req.prefill_end_s = self.now
        if not req.token_times_s:
            # first token from prefill — a sacrificed request's replay
            # keeps its ORIGINAL first-token time (the stream paused,
            # it didn't restart from the caller's point of view)
            req.token_times_s.append(self.now)
        if self.cfg.mode == "push":
            # transfer overlapped layer-by-layer; visible tail ≈ 1 layer
            tail = self._pair_layer_tail_s(req, req.decode_worker)
            req.to(RequestState.KV_TRANSFER)
            req.transfer_start_s, req.transfer_end_s = self.now, self.now + tail
            w.held_tokens -= req.prompt_len
            self._at(req.transfer_end_s, lambda req=req: self._join_decode(req))
        else:
            req.to(RequestState.KV_QUEUED)
            # like the push path: don't offer exhausted workers to a
            # cost-first policy while another has room (fall back to all
            # when everyone is full — the request queues per §4.3)
            need = self._reserved_tokens(req)
            fitting = [x for x in self.decodes
                       if not x.draining and x.free_tokens() >= need]
            d = self._pick_decode(req, fitting or None)
            req.decode_worker = d.wid
            d.kv_queue.append(req)
            self._try_transfers(d, holder=w)
        self._try_start_prefills()

    def _pick_decode(self, req: Request,
                     cands: list[_DecodeWorker] | None = None) -> _DecodeWorker:
        if cands is None:
            # route around draining workers — unless that's everyone
            cands = [d for d in self.decodes if not d.draining] or self.decodes
        chosen = self.policy.pick_decode(self._ctx(req), [
            Candidate(d.wid,
                      free_units=d.free_tokens(),
                      total_units=d.cap_tokens,
                      queued_units=sum(r.prompt_len for r in d.kv_queue),
                      resident=len(d.active),
                      transfer_cost_s=self._pair_transfer_s(req, d.wid),
                      prefix_hit=1.0 if (req.prefix_id and
                                         req.prefix_id in d.prefix_cache)
                      else 0.0)
            for d in cands
        ])
        return next(d for d in cands if d.wid == chosen.worker_id)

    def _evict_sim_prefix(self, d: _DecodeWorker, keep: str | None) -> bool:
        """Drop the LRU retained prefix (except ``keep`` — a prefix being
        grafted right now stays resident, like the real worker's
        share-before-evict ordering); True if something was freed."""
        for pid in d.prefix_cache:
            if pid != keep:
                d.used_tokens -= d.prefix_cache.pop(pid)
                return True
        return False

    def _retain_sim_prefix(self, d: _DecodeWorker, req: Request, alloc: int) -> int:
        """On finish, keep the request's shared prefix resident for later
        delta admissions (the real worker's prefix retention).  Returns
        the token count carved out of the release; retained tokens stay
        in ``used_tokens`` until the LRU cap evicts them."""
        if (self.cfg.mode != "pull" or not self.cfg.delta_transfer
                or not req.prefix_id or self.cfg.prefix_cache_cap <= 0):
            return 0
        pid = req.prefix_id
        if pid in d.prefix_cache:
            d.prefix_cache[pid] = d.prefix_cache.pop(pid)  # LRU touch
            return 0  # already resident: the cache's copy owns those tokens
        ptoks = min(req.prefix_len or req.prompt_len, req.prompt_len, alloc)
        if ptoks <= 0:
            return 0
        d.prefix_cache[pid] = ptoks
        while len(d.prefix_cache) > self.cfg.prefix_cache_cap:
            evict = next(iter(d.prefix_cache))
            d.used_tokens -= d.prefix_cache.pop(evict)
        return ptoks

    def _try_transfers(self, d: _DecodeWorker, holder: _PrefillWorker | None = None) -> None:
        started = 0
        while d.kv_queue:
            if self.cfg.admission_batch and started >= self.cfg.admission_batch:
                return  # batch cap: the rest waits for the next opportunity
            req = d.kv_queue[0]
            while True:
                # delta plan: the resident prefix grafts for free, only
                # the suffix draws on the pool
                resident = self._resident_tokens(req, d)
                need = self._reserved_tokens(req) - resident
                if d.free_tokens() >= need:
                    break
                if self._evict_sim_prefix(d, keep=req.prefix_id):
                    continue
                # pool full even after prefix eviction: preempt a
                # resident (fleet mirror) or leave the request queued
                if not self._preempt_victim(d):
                    return
            if resident and req.prefix_id in d.prefix_cache:
                d.prefix_cache[req.prefix_id] = \
                    d.prefix_cache.pop(req.prefix_id)  # LRU touch
            d.kv_queue.pop(0)
            d.inflight_pulls += 1
            d.used_tokens += need
            self._alloc_tokens[req.request_id] = need
            self.reused_tokens[req.request_id] = \
                self.reused_tokens.get(req.request_id, 0) + resident
            self.pulled_tokens[req.request_id] = \
                self.pulled_tokens.get(req.request_id, 0) \
                + (req.prompt_len - resident)
            started += 1
            req.to(RequestState.KV_TRANSFER)
            dt = self._pair_transfer_s(req, d.wid)
            start = max(self.now, d.nic_free_at)
            if self.cfg.transfer_overlap == "blocking" and d.iterating:
                # the synchronous engine can't post reads mid-iteration:
                # the worker thread is in the decode step
                start = max(start, d.iter_end)
            d.nic_free_at = start + dt
            if self.cfg.transfer_overlap == "blocking":
                # ...and once it enters drain() it is stuck there
                d.pull_busy_until = max(d.pull_busy_until, start + dt)
            req.transfer_start_s, req.transfer_end_s = start, start + dt
            w = next(p for p in self.prefills if p.wid == req.prefill_worker)
            self._at(start + dt, lambda req=req, w=w: self._transfer_done(req, w))
            if self.cfg.transfer_overlap == "layerwise":
                # layer-streamed consumption: decodable once layer 0 lands
                join_at = start + min(dt, self._pair_layer_tail_s(req, d.wid))
                self._at(join_at, lambda req=req: self._join_decode(req))

    def _transfer_done(self, req: Request, w: _PrefillWorker) -> None:
        # COMPLETE(): prefill frees its copy
        w.held_tokens -= req.prompt_len
        self._try_start_prefills()
        d = next(x for x in self.decodes if x.wid == req.decode_worker)
        d.inflight_pulls -= 1
        if self.cfg.transfer_overlap != "layerwise":
            self._join_decode(req)  # layerwise mode joined at layer 0
        self._try_transfers(d)  # NIC freed: admit the next batch
        self._try_swap_in(d)

    def _join_decode(self, req: Request) -> None:
        d = next(x for x in self.decodes if x.wid == req.decode_worker)
        req.to(RequestState.QUEUED_DECODE)
        if self.cfg.batching == "round":
            # round-synchronous cohorts: a round in progress is frozen —
            # the request waits for the whole cohort to drain
            d.round_wait.append(req)
            if not d.iterating:
                self._start_round(d)
            return
        d.active.append(req)
        req.to(RequestState.DECODING)
        req.decode_start_s = self.now
        if not d.iterating:
            self._schedule_iteration(d)

    def _start_round(self, d: _DecodeWorker) -> None:
        """Round batching: freeze the next cohort (capped at the batch
        limit; the rest waits for the round after)."""
        cohort = d.round_wait[: self.cfg.max_decode_batch]
        del d.round_wait[: len(cohort)]
        for r in cohort:
            r.to(RequestState.DECODING)
            r.decode_start_s = self.now
            d.active.append(r)
        self._schedule_iteration(d)

    def _schedule_iteration(self, d: _DecodeWorker) -> None:
        batch = [r for r in d.active if r.tokens_generated < r.max_new_tokens - 1]
        if not batch:
            if self.cfg.batching == "round" and d.round_wait:
                self._start_round(d)  # cohort drained: admit the next one
                return
            d.iterating = False
            return
        d.iterating = True
        start = self.now
        if self.cfg.transfer_overlap == "blocking":
            # synchronous engine: the worker is in drain() until the pull
            # finishes — decode iterations can't start underneath it
            start = max(start, d.pull_busy_until)
        batch = batch[: self.cfg.max_decode_batch]
        active_tokens = sum(r.prompt_len + r.tokens_generated for r in batch)
        dt = self.cost.decode_step_s(active_tokens, len(batch)) * d.slowdown
        d.iter_end = start + dt
        self._at(start + dt, lambda d=d, batch=batch: self._iteration_done(d, batch))

    def _iteration_done(self, d: _DecodeWorker, batch: list[Request]) -> None:
        for r in batch:
            if r not in d.active:
                continue  # preempted (swapped/sacrificed) mid-iteration
            r.tokens_generated += 1
            r.token_times_s.append(self.now)
            if not self.cfg.reserve_response:
                d.used_tokens += 1
            if r.tokens_generated >= r.max_new_tokens - 1:
                r.done_s = self.now
                r.to(RequestState.DONE)
                d.active.remove(r)
                alloc = self._alloc_tokens.pop(r.request_id, None)
                if alloc is None:  # push path: full reservation was charged
                    alloc = self._reserved_tokens(r) if self.cfg.reserve_response \
                        else (r.prompt_len + r.tokens_generated)
                elif not self.cfg.reserve_response:
                    alloc += r.tokens_generated  # per-token growth charged above
                d.used_tokens -= alloc - self._retain_sim_prefix(d, r, alloc)
                self.finished.append(r)
        if self.cfg.mode == "pull":
            self._try_transfers(d)
            self._try_swap_in(d)
        elif self.cfg.mode == "push":
            self._try_push_admissions()  # freed KV unblocks stalled arrivals
        self._schedule_iteration(d)

    # ------------------------------------------- fleet mirror (preemption)
    def _preempt_victim(self, d: _DecodeWorker) -> bool:
        """Memory-pressure preemption, mirroring ``fleet.MemoryGovernor``:
        free a resident decode by swap-out (host memory, resumed later)
        or sacrifice (drop KV, truncate-and-replay through prefill).
        Returns True if tokens were freed."""
        cfg = self.cfg
        if cfg.preemption == "none" or not d.active:
            return False
        if d.used_tokens / max(d.cap_tokens, 1) < cfg.preempt_high:
            return False  # pressure below the trigger: let the pull queue
        # anti-thrash eligibility: a bounded number of preemptions per
        # request, and never re-preempt before the victim made progress
        eligible = [
            r for r in d.active
            if self._preempt_count.get(r.request_id, 0) < cfg.max_preemptions
            and r.tokens_generated > self._tok_at_preempt.get(r.request_id, -1)
        ]
        if not eligible:
            return False
        if cfg.victim_policy == "fifo":
            r = eligible[0]           # oldest resident: earliest to rejoin
        elif cfg.victim_policy == "priority":
            # lowest SLO class first; ties broken LIFO (newest resident)
            r = max(enumerate(eligible),
                    key=lambda p: (DEFAULT_CLASS_RANK.get(p[1].slo_class, 1),
                                   p[0]))[1]
        else:  # lifo — newest resident has the least sunk decode work
            r = eligible[-1]
        rid = r.request_id
        self._preempt_count[rid] = self._preempt_count.get(rid, 0) + 1
        self._tok_at_preempt[rid] = r.tokens_generated
        d.active.remove(r)
        base = self._alloc_tokens.pop(rid, 0)
        freed = base + (0 if cfg.reserve_response else r.tokens_generated)
        d.used_tokens -= freed
        if cfg.preemption == "swap":
            d.swapped.append((r, base))  # KV parked host-side, state kept
            self.n_swapped += 1
            return True
        # sacrifice: drop the KV and replay through prefill.  The caller's
        # stream pauses and resumes (decode is deterministic), so the
        # ORIGINAL first-token time survives — only later tokens re-emit.
        r.retries += 1
        r.tokens_generated = 0
        r.prefill_end_s = None
        r.transfer_start_s = r.transfer_end_s = None
        r.decode_start_s = None
        r.decode_worker = None
        del r.token_times_s[1:]
        r.to(RequestState.FAILED)
        r.to(RequestState.QUEUED_PREFILL)
        self.prefill_queue.append(r)
        self.n_sacrificed += 1
        self._at(self.now, lambda: self._try_start_prefills())
        return True

    def _try_swap_in(self, d: _DecodeWorker) -> None:
        """Resume swapped-out requests (oldest first) once the pressure
        that evicted them has cleared — never while pulls are still
        queued (resuming under a waiting pull re-triggers the squeeze)."""
        while d.swapped and not d.kv_queue:
            r, base = d.swapped[0]
            need = base + (0 if self.cfg.reserve_response else r.tokens_generated)
            if d.free_tokens() < need:
                return
            d.swapped.pop(0)
            d.used_tokens += need
            self._alloc_tokens[r.request_id] = base
            # swap-in cost: the full KV footprint re-crosses host<->device,
            # cheaper than a network pull by swap_cost_scale
            dt = self.cfg.swap_cost_scale * self.cost.transfer_s(
                r.prompt_len + r.tokens_generated,
                mode=self.cfg.transfer_mode,
                coalesce_factor=self.cfg.coalesce_factor)
            self._at(self.now + dt, lambda r=r, d=d: self._swap_rejoin(d, r))

    def _swap_rejoin(self, d: _DecodeWorker, r: Request) -> None:
        d.active.append(r)
        if not d.iterating:
            self._schedule_iteration(d)

    # ------------------------------------------- fleet mirror (autoscale)
    def _autoscale_tick(self) -> None:
        """Periodic fleet evaluation: feed the REAL ``fleet.Autoscaler``
        LoadReports built from sim worker state (tokens-as-blocks,
        block_size=1) and apply its add/drain plan."""
        p_reports = {
            w.wid: LoadReport(w.wid, "prefill",
                              free_blocks=max(0, w.cap_tokens - w.held_tokens),
                              total_blocks=w.cap_tokens, block_size=1,
                              t=self.now)
            for w in self.prefills}
        d_reports = {
            d.wid: LoadReport(d.wid, "decode",
                              free_blocks=d.free_tokens(),
                              total_blocks=d.cap_tokens,
                              queued_tokens=sum(r.prompt_len
                                                for r in d.kv_queue),
                              block_size=1, t=self.now)
            for d in self.decodes}
        draining = {w.wid: "prefill" for w in self.prefills if w.draining}
        draining.update({d.wid: "decode" for d in self.decodes if d.draining})
        for act in self.autoscaler.plan(p_reports, d_reports,
                                        dispatch_backlog=len(self.prefill_queue),
                                        draining=draining):
            if act[0] == "add" and act[1] == "prefill":
                if self.topology is None or self.topology.has_spare("prefill"):
                    self.prefills.append(self._new_prefill(f"p{next(self._wid_p)}"))
            elif act[0] == "add":
                if self.topology is None or self.topology.has_spare("decode"):
                    self.decodes.append(self._new_decode(f"d{next(self._wid_d)}"))
            elif act[1] == "prefill":
                next(x for x in self.prefills if x.wid == act[2]).draining = True
            else:
                dw = next(x for x in self.decodes if x.wid == act[2])
                dw.draining = True
                # reassign queued pulls onto live workers that fit them;
                # what doesn't fit stays and drains out normally
                for r in list(dw.kv_queue):
                    need = self._reserved_tokens(r)
                    fitting = [x for x in self.decodes
                               if not x.draining and x.free_tokens() >= need]
                    if not fitting:
                        break
                    dw.kv_queue.remove(r)
                    tgt = self._pick_decode(r, fitting)
                    r.decode_worker = tgt.wid
                    tgt.kv_queue.append(r)
                    self._try_transfers(tgt)
        # advance drains: retire workers that have gone quiet (their
        # machines return to the topology's spare pool)
        retire_p = [w for w in self.prefills
                    if w.draining and w.held_tokens <= 0
                    and w.busy_until <= self.now]
        retire_d = [d for d in self.decodes
                    if d.draining and not d.active and not d.kv_queue
                    and not d.round_wait and not d.swapped
                    and not d.inflight_pulls]
        if self.topology is not None:
            for w in retire_p + retire_d:
                self.topology.release_worker(w.wid)
        self.prefills = [w for w in self.prefills if w not in retire_p]
        self.decodes = [d for d in self.decodes if d not in retire_d]
        self._try_start_prefills()  # hot-added capacity admits immediately
        if len(self.finished) + len(self.rejected) < self._n_expected:
            self._at(self.now + self.cfg.autoscale_interval_s,
                     self._autoscale_tick)

    # --------------------------------------------------- colocated (vLLM)
    def _co_arrive(self, req: Request) -> None:
        d = self._pick_decode(req)
        req.decode_worker = d.wid
        d.kv_queue.append(req)
        if not d.iterating:
            self._co_step(d)

    def _co_step(self, d: _DecodeWorker) -> None:
        """One scheduler iteration: prefill-prioritized (vLLM default)."""
        # admit a prefill if one fits
        if d.kv_queue:
            req = d.kv_queue[0]
            if d.free_tokens() >= self._reserved_tokens(req):
                d.kv_queue.pop(0)
                d.used_tokens += self._reserved_tokens(req)
                req.to(RequestState.PREFILLING)
                req.prefill_start_s = self.now
                d.iterating = True
                dt = self.cost.prefill_s(req.prompt_len)
                # the prefill stalls every resident decode for `dt`

                def done(req=req, d=d):
                    req.prefill_end_s = self.now
                    req.token_times_s.append(self.now)
                    req.to(RequestState.KV_TRANSFER)  # zero-cost local handoff
                    req.transfer_start_s = req.transfer_end_s = self.now
                    req.to(RequestState.QUEUED_DECODE)
                    d.active.append(req)
                    req.to(RequestState.DECODING)
                    req.decode_start_s = self.now
                    self._co_step(d)

                self._at(self.now + dt, done)
                return
        # otherwise run one decode iteration
        batch = [r for r in d.active if r.tokens_generated < r.max_new_tokens - 1]
        if not batch:
            d.iterating = False
            return
        d.iterating = True
        batch = batch[: self.cfg.max_decode_batch]
        active_tokens = sum(r.prompt_len + r.tokens_generated for r in batch)
        dt = self.cost.decode_step_s(active_tokens, len(batch))

        def iter_done(d=d, batch=batch):
            for r in batch:
                r.tokens_generated += 1
                r.token_times_s.append(self.now)
                if r.tokens_generated >= r.max_new_tokens - 1:
                    r.done_s = self.now
                    r.to(RequestState.DONE)
                    d.active.remove(r)
                    d.used_tokens -= self._reserved_tokens(r)
                    self.finished.append(r)
            self._co_step(d)

        self._at(self.now + dt, iter_done)
