"""CONNECT() — connection establishment and descriptor exchange (§4.2).

The handshake: a decode worker connects to a prefill worker, and the
prefill worker replies with the ``TensorDesc`` of every registered KV
tensor (Fig. 5).  From then on the decode worker computes remote offsets
locally; the prefill worker is never on the data-plane critical path.

Link alignment: chip *i* of a decode worker only connects to chip *i* of
a prefill worker (§4.2: "GPU i of a decode worker can only connect with
GPU i of a prefill worker" — datacenter rail topology).  On TPU the same
constraint keeps pulls on disjoint ICI paths: decode chip at position
(x, y) of its slice pulls from prefill chip at position (x, y).

Connections carry an *epoch*: when a prefill worker dies and rejoins, its
addresses are invalid; stale descriptors must never be dereferenced.  Any
transfer built against epoch E is rejected if the connection has moved on.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

from repro.core.descriptors import TensorDesc

__all__ = ["ChipInfo", "WorkerInfo", "DescriptorRegistry", "Connection", "ConnectionManager"]


@dataclasses.dataclass(frozen=True)
class ChipInfo:
    chip_id: int
    link_addr: str  # e.g. "192.168.0.132" (paper) or "ici://pod0/x3y7"


@dataclasses.dataclass(frozen=True)
class WorkerInfo:
    worker_id: str
    role: str  # "prefill" | "decode"
    host_addr: str
    chips: tuple[ChipInfo, ...]

    def __post_init__(self) -> None:
        if self.role not in ("prefill", "decode"):
            raise ValueError(f"bad role {self.role!r}")


class DescriptorRegistry:
    """Prefill-side: the tensors this worker is willing to serve reads
    from.  Registered once when the KV cache is allocated; sent verbatim
    during CONNECT."""

    def __init__(self, worker_id: str) -> None:
        self.worker_id = worker_id
        self._descs: dict[str, TensorDesc] = {}

    def register(self, desc: TensorDesc) -> None:
        if desc.worker_id != self.worker_id:
            raise ValueError(f"descriptor worker {desc.worker_id!r} != registry {self.worker_id!r}")
        self._descs[desc.tensor_id] = desc

    def snapshot(self) -> dict[str, TensorDesc]:
        return dict(self._descs)


@dataclasses.dataclass
class Connection:
    decode_worker: str
    prefill_worker: str
    epoch: int
    chip_pairs: tuple[tuple[int, int], ...]  # (decode chip, prefill chip) — link aligned
    descriptors: dict[str, TensorDesc]

    def desc(self, tensor_id: str) -> TensorDesc:
        try:
            return self.descriptors[tensor_id]
        except KeyError:
            raise KeyError(
                f"connection {self.decode_worker}->{self.prefill_worker} (epoch {self.epoch}) "
                f"has no tensor {tensor_id!r}"
            )


class ConnectionManager:
    """Decode-side connection table.  One entry per live prefill worker.

    The decode worker — not the cluster scheduler — owns this table, so a
    scheduler outage never stalls the data plane (§4.2: "To avoid the
    single-point failure of the scheduler, the decode worker maintains
    the connection of all active prefill workers").
    """

    def __init__(self, worker_info: WorkerInfo) -> None:
        if worker_info.role != "decode":
            raise ValueError("ConnectionManager lives on decode workers")
        self.info = worker_info
        self._conns: dict[str, Connection] = {}
        self._epoch = 0
        self._on_invalidate: list[Callable[[str, int], None]] = []

    # ----------------------------------------------------------- events
    def on_invalidate(self, cb: Callable[[str, int], None]) -> None:
        """cb(prefill_worker_id, dead_epoch) — serving layer re-queues
        requests whose KV descriptors just died."""
        self._on_invalidate.append(cb)

    # ---------------------------------------------------------- connect
    def connect(self, peer: WorkerInfo, registry: DescriptorRegistry) -> Connection:
        """The CONNECT() handshake."""
        if peer.role != "prefill":
            raise ValueError("decode workers only connect to prefill workers")
        if registry.worker_id != peer.worker_id:
            raise ValueError("registry does not belong to peer")
        n = min(len(self.info.chips), len(peer.chips))
        pairs = tuple(
            (self.info.chips[i].chip_id, peer.chips[i].chip_id) for i in range(n)
        )  # link-aligned: i <-> i only
        self._epoch += 1
        conn = Connection(
            decode_worker=self.info.worker_id,
            prefill_worker=peer.worker_id,
            epoch=self._epoch,
            chip_pairs=pairs,
            descriptors=registry.snapshot(),
        )
        self._conns[peer.worker_id] = conn
        return conn

    def disconnect(self, prefill_worker: str, *, failed: bool = False) -> None:
        conn = self._conns.pop(prefill_worker, None)
        if conn is not None and failed:
            for cb in self._on_invalidate:
                cb(prefill_worker, conn.epoch)

    # ------------------------------------------------------------ query
    def connection(self, prefill_worker: str) -> Connection:
        try:
            return self._conns[prefill_worker]
        except KeyError:
            raise KeyError(f"no live connection to {prefill_worker!r}")

    def validate_epoch(self, prefill_worker: str, epoch: int) -> None:
        conn = self.connection(prefill_worker)
        if conn.epoch != epoch:
            raise StaleConnectionError(
                f"transfer built at epoch {epoch} but connection to "
                f"{prefill_worker!r} is at epoch {conn.epoch}"
            )

    @property
    def peers(self) -> tuple[str, ...]:
        return tuple(self._conns)


class StaleConnectionError(RuntimeError):
    pass
