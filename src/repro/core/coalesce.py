"""Read-transaction coalescing (KVDirect §4.2).

"KVDirect pops all the read transactions in order until the first
completion transaction for the coalescing opportunity. [...] A group of
transactions can be merged only when the results of both remote and local
locations are contiguous."

Small paged-KV blocks (KBs) cannot saturate a 400 Gbps NIC / an ICI link;
merging adjacent blocks into one DMA descriptor is where the paper's
Fig. 17 speedup (1.13×/1.03×, up to 1.32× at high QPS) comes from.

Two strategies are provided:

* ``coalesce_fifo`` — the paper's strategy: scan the window in FIFO order
  and merge runs that happen to be adjacent.  Faithful baseline.
* ``coalesce_sorted`` — a beyond-paper improvement (§Perf in
  EXPERIMENTS.md): sort the window by (src, dst, remote offset) first so
  non-FIFO-adjacent but memory-adjacent transactions also merge, then
  restore no ordering (reads within a request are order-free — only
  COMPLETE is ordered, which the window boundary already guarantees).
"""
from __future__ import annotations

import dataclasses
from typing import Iterable, Sequence

from repro.core.descriptors import ByteRange, ReadTxn

__all__ = ["CoalescedRead", "coalesce_fifo", "coalesce_sorted", "coalesce"]


@dataclasses.dataclass(frozen=True)
class CoalescedRead:
    """One RDMA-level read covering >=1 original transactions.

    ``qscale`` is the int8 dequantization scale carried over from a
    quantized ``ReadTxn``.  A scale is per-span, so quantized reads never
    merge with neighbours (each keeps its own scale) — see ``_mergeable``.
    """

    src_worker: str
    dst_worker: str
    remote: ByteRange
    local: ByteRange
    request_ids: tuple[str, ...]
    qscale: float | None = None

    @property
    def nbytes(self) -> int:
        return self.remote.nbytes

    @property
    def n_merged(self) -> int:
        return len(self.request_ids)


def _mergeable(acc: CoalescedRead, txn: ReadTxn) -> bool:
    # quantized spans carry one scale each: merging two would lose a
    # scale, so a qscale on either side blocks the merge
    return (
        acc.qscale is None
        and txn.qscale is None
        and acc.src_worker == txn.src_worker
        and acc.dst_worker == txn.dst_worker
        and acc.remote.abuts(txn.remote)
        and acc.local.abuts(txn.local)
    )


def _fold(txns: Iterable[ReadTxn]) -> list[CoalescedRead]:
    out: list[CoalescedRead] = []
    for t in txns:
        if out and _mergeable(out[-1], t):
            prev = out[-1]
            out[-1] = CoalescedRead(
                src_worker=prev.src_worker,
                dst_worker=prev.dst_worker,
                remote=prev.remote.merged(t.remote),
                local=prev.local.merged(t.local),
                request_ids=prev.request_ids + (t.request_id,),
            )
        else:
            out.append(
                CoalescedRead(
                    src_worker=t.src_worker,
                    dst_worker=t.dst_worker,
                    remote=t.remote,
                    local=t.local,
                    request_ids=(t.request_id,),
                    qscale=t.qscale,
                )
            )
    return out


def coalesce_fifo(window: Sequence[ReadTxn]) -> list[CoalescedRead]:
    """Paper-faithful: merge only FIFO-adjacent, memory-adjacent reads."""
    return _fold(window)


def coalesce_sorted(window: Sequence[ReadTxn]) -> list[CoalescedRead]:
    """Beyond-paper: sort by (pair, remote offset, local offset) before
    folding, exposing every adjacency in the window, not just FIFO runs."""
    key = lambda t: (t.src_worker, t.dst_worker, t.remote.offset, t.local.offset)
    return _fold(sorted(window, key=key))


def coalesce(window: Sequence[ReadTxn], *, strategy: str = "fifo") -> list[CoalescedRead]:
    if strategy == "fifo":
        return coalesce_fifo(window)
    if strategy == "sorted":
        return coalesce_sorted(window)
    if strategy == "none":
        return [
            CoalescedRead(t.src_worker, t.dst_worker, t.remote, t.local,
                          (t.request_id,), qscale=t.qscale)
            for t in window
        ]
    raise ValueError(f"unknown coalescing strategy {strategy!r}")
