"""The KVDirect communication engine (§4.2).

A transaction queue drained into one-sided reads plus ACK-serialized
COMPLETE messages.  Two *modes* reproduce the paper's comparison:

* ``tensor_centric`` (KVDirect): the decode worker computes every remote
  offset from the connection-time ``TensorDesc`` and posts one-sided reads
  directly — zero remote-side work per block, coalescing across requests.
* ``message`` (the NCCL/UCX/MSCCL++ strawman of Fig. 3/7a): per round,
  a metadata RPC, a gather "kernel" into a bounded staging buffer, a
  buffer send, a scatter "kernel" on the receiver, and a notify — with
  real double-copies when the memcpy backend is active.

Two *backends* separate mechanism from timing:

* ``memcpy``  — actually moves bytes between worker address spaces
  (numpy views standing in for HBM); wall time is measured.  This is what
  the correctness tests and Fig. 15 measurements use.
* ``timed``   — additionally accrues a modeled clock from ``LinkModel``
  (per-verb post overhead, RPC latency, kernel-launch/sync costs from the
  paper's Fig. 3 breakdown, link bandwidth).  The event simulator and the
  Fig. 3/4 reproductions read this clock.

Both run together: memcpy gives ground-truth bytes, timed gives the
latency the same schedule would cost on the paper's hardware.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Iterable, Sequence

import numpy as np

from repro.core.coalesce import CoalescedRead, coalesce
from repro.core.descriptors import ByteRange, CompleteTxn, ReadTxn, Txn

__all__ = ["KVDIRECT_UTIL", "LinkModel", "TransferStats", "MemoryRegion", "TransferEngine"]

# Paper Fig. 15: KVDirect sustains 22.23 GB/s of a 400 Gbps link ≈ 44.5 %
# effective utilization.  Single source of truth — the simulator's cost
# model and the router's transfer scores both reference it.
KVDIRECT_UTIL = 0.445


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Timing constants.  Defaults reproduce the paper's environment:
    400 Gbps RDMA NIC (50 GB/s), Fig. 3's measured per-step costs for the
    message-passing baseline, and a ~2 µs verb-post overhead for RDMA.

    For the TPU adaptation, construct with ``ici()`` — one-sided remote
    DMA over a 50 GB/s ICI link with a ~1 µs descriptor-post overhead —
    or ``dcn()`` for the cross-pod path.
    """

    bandwidth_Bps: float = 50e9          # 400 Gbps NIC
    post_overhead_s: float = 2e-6        # posting one RDMA verb
    rpc_latency_s: float = 1.0e-3        # Fig. 3 step 1: metadata RPC
    gather_launch_s: float = 3.25e-3     # Fig. 3 step 2: gather kernel + copy to buffer
    cpu_sync_s: float = 1.3e-3           # Fig. 3 step 3: GPU sync + NIC op (fixed part)
    scatter_launch_s: float = 3.31e-3    # Fig. 3 step 4: scatter kernel
    notify_s: float = 1.0e-3             # Fig. 3 step 6: completion notify
    ack_rtt_s: float = 8e-6              # COMPLETE/ACK round trip (one-sided write + ack)
    # Streaming message-passing (UCX) per-block CPU overhead.  4.4 µs
    # reproduces the paper's whole Fig. 4 utilization curve on a 400 Gbps
    # link: util(4 KB) = wire/(wire+4.4 µs) = 1.8 %, util(32 KB) = 13 %.
    message_block_overhead_s: float = 4.4e-6

    @staticmethod
    def nic_400g() -> "LinkModel":
        return LinkModel()

    @staticmethod
    def ici() -> "LinkModel":
        """TPU v5e ICI link: ~50 GB/s, on-chip DMA descriptor post ~1 µs."""
        return LinkModel(bandwidth_Bps=50e9, post_overhead_s=1e-6, ack_rtt_s=4e-6)

    @staticmethod
    def dcn() -> "LinkModel":
        """Cross-pod data-center network: ~25 GB/s effective per host link."""
        return LinkModel(bandwidth_Bps=25e9, post_overhead_s=3e-6, ack_rtt_s=2e-5)

    def read_time(self, nbytes: int) -> float:
        return self.post_overhead_s + nbytes / self.bandwidth_Bps

    def message_round_time(self, nbytes: int) -> float:
        """One NAIVE per-block round (Fig. 3's RPC flow, nothing
        overlapped) — the strawman timeline of Motivation #1."""
        return (
            self.rpc_latency_s
            + self.gather_launch_s
            + self.cpu_sync_s
            + nbytes / self.bandwidth_Bps
            + self.scatter_launch_s
            + self.notify_s
        )

    def message_stream_time(self, nbytes: int, n_blocks: int) -> float:
        """A PIPELINED stream of message sends (UCX-style, Fig. 4): the
        per-block CPU overhead is what bounds throughput."""
        return n_blocks * self.message_block_overhead_s + nbytes / self.bandwidth_Bps


@dataclasses.dataclass
class TransferStats:
    bytes_moved: int = 0
    reads_posted: int = 0           # RDMA-level ops after coalescing
    txns_submitted: int = 0         # original read transactions
    completes: int = 0
    modeled_time_s: float = 0.0     # LinkModel clock
    wall_time_s: float = 0.0        # measured memcpy time
    rounds: int = 0                 # message-mode staging rounds

    @property
    def coalesce_factor(self) -> float:
        return self.txns_submitted / self.reads_posted if self.reads_posted else 1.0

    def modeled_bandwidth_Bps(self) -> float:
        return self.bytes_moved / self.modeled_time_s if self.modeled_time_s else 0.0


@dataclasses.dataclass
class MemoryRegion:
    """A registered MR: a worker's slab of 'HBM' the engine may touch."""

    worker_id: str
    base_address: int
    buffer: np.ndarray  # dtype uint8, 1-D

    def view(self, rng: ByteRange) -> np.ndarray:
        lo = rng.offset - self.base_address
        if lo < 0 or lo + rng.nbytes > self.buffer.nbytes:
            raise IndexError(
                f"range {rng} outside MR of {self.worker_id} "
                f"(base={self.base_address:#x} size={self.buffer.nbytes})"
            )
        return self.buffer[lo : lo + rng.nbytes]


class TransferEngine:
    """Drains a transaction queue into coalesced one-sided reads.

    Ordering rules (§4.2):
      * reads are asynchronous and may complete out of order ACROSS
        requests;
      * a COMPLETE for request R is only executed after every read of R
        already in the queue has executed (the decode worker enqueues
        COMPLETE after TRANSFERs, and the engine's coalescing window
        stops at the first COMPLETE, preserving this);
      * COMPLETEs on one connection are serialized by an ACK so a later
        COMPLETE cannot overwrite an unconsumed mailbox slot (WAW).
        Reads are never blocked by a pending ACK.
    """

    def __init__(
        self,
        *,
        mode: str = "tensor_centric",
        coalescing: str = "fifo",
        link: LinkModel | None = None,
        execute_copies: bool = True,
        staging_blocks: int = 2,
        staging_block_bytes: int = 256 * 1024,
        codec: str = "none",
    ) -> None:
        """codec="int8_transport": beyond-paper KV compression on the wire
        (the paper lists KV compression as complementary, §6) — bf16 spans
        are symmetric-quantized to int8 + one f32 scale per read, halving
        wire bytes; the destination slab is dequantized bf16, so compute
        is unchanged.  Lossy (≤1/127 of the span max; tests bound it)."""
        if mode not in ("tensor_centric", "message"):
            raise ValueError(f"unknown mode {mode!r}")
        if codec not in ("none", "int8_transport"):
            raise ValueError(f"unknown codec {codec!r}")
        self.mode = mode
        self.codec = codec
        self.coalescing = coalescing if mode == "tensor_centric" else "none"
        self.link = link or LinkModel()
        self.execute_copies = execute_copies
        # Message-mode staging buffer capacity (Fig. 7a: "can hold two blocks").
        self.staging_bytes = staging_blocks * staging_block_bytes
        self._regions: dict[str, MemoryRegion] = {}
        self._queue: collections.deque[Txn] = collections.deque()
        self._outstanding_reads: collections.Counter[str] = collections.Counter()
        self._complete_cbs: list[Callable[[CompleteTxn], None]] = []
        self.stats = TransferStats()

    # ------------------------------------------------------------- setup
    def register_memory(self, region: MemoryRegion) -> None:
        if region.worker_id in self._regions:
            raise ValueError(f"worker {region.worker_id!r} already registered an MR")
        # The engine models ONE flat address space (descriptors carry raw
        # addresses, §4.1) — two slabs sharing addresses would make a
        # descriptor ambiguous, so MRs must be disjoint.
        lo, hi = region.base_address, region.base_address + region.buffer.nbytes
        for other in self._regions.values():
            o_lo, o_hi = other.base_address, other.base_address + other.buffer.nbytes
            if lo < o_hi and o_lo < hi:
                raise ValueError(
                    f"MR of {region.worker_id!r} [{lo:#x}, {hi:#x}) overlaps "
                    f"MR of {other.worker_id!r} [{o_lo:#x}, {o_hi:#x})"
                )
        self._regions[region.worker_id] = region

    def deregister_memory(self, worker_id: str) -> None:
        self._regions.pop(worker_id, None)

    def on_complete(self, cb: Callable[[CompleteTxn], None]) -> None:
        self._complete_cbs.append(cb)

    # ------------------------------------------------------------ submit
    def submit(self, txns: Iterable[Txn]) -> None:
        for t in txns:
            if isinstance(t, ReadTxn):
                self._outstanding_reads[t.request_id] += 1
                self.stats.txns_submitted += 1
            self._queue.append(t)

    # ------------------------------------------------------------- drain
    def drain(self) -> TransferStats:
        """Process the whole queue.  Returns cumulative stats."""
        while self._queue:
            window: list[ReadTxn] = []
            while self._queue and isinstance(self._queue[0], ReadTxn):
                window.append(self._queue.popleft())  # type: ignore[arg-type]
            if window:
                if self.mode == "tensor_centric":
                    self._post_reads(window)
                else:
                    self._message_rounds(window)
            if self._queue and isinstance(self._queue[0], CompleteTxn):
                self._do_complete(self._queue.popleft())  # type: ignore[arg-type]
        return self.stats

    # --------------------------------------------------- tensor-centric
    def _post_reads(self, window: Sequence[ReadTxn]) -> None:
        merged = coalesce(window, strategy=self.coalescing)
        t0 = time.perf_counter()
        for op in merged:
            self._copy(op)
            self.stats.reads_posted += 1
            wire = op.nbytes if self.codec == "none" else op.nbytes // 2 + 4
            self.stats.bytes_moved += wire
            self.stats.modeled_time_s += self.link.read_time(wire)
        self.stats.wall_time_s += time.perf_counter() - t0
        for t in window:
            self._outstanding_reads[t.request_id] -= 1

    # ---------------------------------------------------- message mode
    def _message_rounds(self, window: Sequence[ReadTxn]) -> None:
        """Fig. 7a: bounded staging buffer, per-round RPC + gather + send +
        scatter + notify, with REAL double copies under memcpy."""
        t0 = time.perf_counter()
        round_txns: list[ReadTxn] = []
        round_bytes = 0
        for t in list(window) + [None]:  # type: ignore[list-item]
            flush = t is None or (round_bytes + t.nbytes > self.staging_bytes and round_txns)
            if flush and round_txns:
                staging = np.empty(round_bytes, dtype=np.uint8) if self.execute_copies else None
                off = 0
                for rt in round_txns:  # gather (copy #1)
                    if staging is not None:
                        staging[off : off + rt.nbytes] = self._src_view(rt)
                    off += rt.nbytes
                off = 0
                for rt in round_txns:  # scatter (copy #2)
                    if staging is not None:
                        self._dst_view(rt)[...] = staging[off : off + rt.nbytes]
                    off += rt.nbytes
                self.stats.rounds += 1
                self.stats.reads_posted += 1
                self.stats.bytes_moved += round_bytes
                self.stats.modeled_time_s += self.link.message_stream_time(
                    round_bytes, len(round_txns))
                round_txns, round_bytes = [], 0
            if t is not None:
                round_txns.append(t)
                round_bytes += t.nbytes
        self.stats.wall_time_s += time.perf_counter() - t0
        for t in window:
            self._outstanding_reads[t.request_id] -= 1

    # ------------------------------------------------------------ common
    def _src_view(self, op: ReadTxn | CoalescedRead) -> np.ndarray:
        return self._regions[op.src_worker].view(op.remote)

    def _dst_view(self, op: ReadTxn | CoalescedRead) -> np.ndarray:
        return self._regions[op.dst_worker].view(op.local)

    def _copy(self, op: CoalescedRead) -> None:
        if not self.execute_copies:
            return
        src = self._regions.get(op.src_worker)
        dst = self._regions.get(op.dst_worker)
        if src is None or dst is None:
            raise KeyError(
                f"unregistered worker in read {op.src_worker!r}->{op.dst_worker!r} "
                f"(connection torn down?)"
            )
        if self.codec == "none":
            dst.view(op.local)[...] = src.view(op.remote)
            return
        # int8_transport: quantize the bf16 span, move int8, dequantize
        import ml_dtypes

        s = src.view(op.remote).view(ml_dtypes.bfloat16).astype(np.float32)
        scale = float(np.max(np.abs(s))) / 127.0 or 1.0
        q = np.clip(np.round(s / scale), -127, 127).astype(np.int8)
        deq = (q.astype(np.float32) * scale).astype(ml_dtypes.bfloat16)
        dst.view(op.local)[...] = deq.view(np.uint8)

    def _do_complete(self, txn: CompleteTxn) -> None:
        if self._outstanding_reads[txn.request_id] > 0:
            raise RuntimeError(
                f"COMPLETE for {txn.request_id!r} with "
                f"{self._outstanding_reads[txn.request_id]} reads still queued — "
                "the decode worker must enqueue COMPLETE after all TRANSFERs"
            )
        # Serialized by ACK: one mailbox slot per connection, strictly FIFO
        # (we drain in order, so FIFO holds; the cost of the ACK is modeled).
        self.stats.completes += 1
        self.stats.modeled_time_s += self.link.ack_rtt_s
        for cb in self._complete_cbs:
            cb(txn)
