"""The KVDirect communication engine (§4.2).

A transaction queue drained into one-sided reads plus ACK-serialized
COMPLETE messages.  Two *modes* reproduce the paper's comparison:

* ``tensor_centric`` (KVDirect): the decode worker computes every remote
  offset from the connection-time ``TensorDesc`` and posts one-sided reads
  directly — zero remote-side work per block, coalescing across requests.
* ``message`` (the NCCL/UCX/MSCCL++ strawman of Fig. 3/7a): per round,
  a metadata RPC, a gather "kernel" into a bounded staging buffer, a
  buffer send, a scatter "kernel" on the receiver, and a notify — with
  real double-copies when the memcpy backend is active.

Two *backends* separate mechanism from timing:

* ``memcpy``  — actually moves bytes between worker address spaces
  (numpy views standing in for HBM); wall time is measured.  This is what
  the correctness tests and Fig. 15 measurements use.
* ``timed``   — additionally accrues a modeled clock from ``LinkModel``
  (per-verb post overhead, RPC latency, kernel-launch/sync costs from the
  paper's Fig. 3 breakdown, link bandwidth).  The event simulator and the
  Fig. 3/4 reproductions read this clock.

Both run together: memcpy gives ground-truth bytes, timed gives the
latency the same schedule would cost on the paper's hardware.
"""
from __future__ import annotations

import collections
import dataclasses
import time
from typing import Callable, Iterable, Sequence

import numpy as np

from repro.core.coalesce import CoalescedRead, coalesce
from repro.core.descriptors import ByteRange, CompleteTxn, ReadTxn, Txn
from repro.obs.trace import NULL_TRACER

__all__ = [
    "KVDIRECT_UTIL",
    "LinkModel",
    "TransferStats",
    "MemoryRegion",
    "TransferEngine",
    "TransferFuture",
    "ConnectionTornError",
]

# Paper Fig. 15: KVDirect sustains 22.23 GB/s of a 400 Gbps link ≈ 44.5 %
# effective utilization.  Single source of truth — the simulator's cost
# model and the router's transfer scores both reference it.
KVDIRECT_UTIL = 0.445


@dataclasses.dataclass(frozen=True)
class LinkModel:
    """Timing constants.  Defaults reproduce the paper's environment:
    400 Gbps RDMA NIC (50 GB/s), Fig. 3's measured per-step costs for the
    message-passing baseline, and a ~2 µs verb-post overhead for RDMA.

    For the TPU adaptation, construct with ``ici()`` — one-sided remote
    DMA over a 50 GB/s ICI link with a ~1 µs descriptor-post overhead —
    or ``dcn()`` for the cross-pod path.
    """

    bandwidth_Bps: float = 50e9          # 400 Gbps NIC
    post_overhead_s: float = 2e-6        # posting one RDMA verb
    # One-way propagation delay of the path (0 for a rack-local link;
    # tens of ms for a cross-region hop).  Charged ONCE per logical
    # pull by the router's ``modeled_transfer_s`` and the simulator's
    # pair costs — not per read, since in-flight reads pipeline and only
    # the first byte pays the propagation latency.
    latency_s: float = 0.0
    rpc_latency_s: float = 1.0e-3        # Fig. 3 step 1: metadata RPC
    gather_launch_s: float = 3.25e-3     # Fig. 3 step 2: gather kernel + copy to buffer
    cpu_sync_s: float = 1.3e-3           # Fig. 3 step 3: GPU sync + NIC op (fixed part)
    scatter_launch_s: float = 3.31e-3    # Fig. 3 step 4: scatter kernel
    notify_s: float = 1.0e-3             # Fig. 3 step 6: completion notify
    ack_rtt_s: float = 8e-6              # COMPLETE/ACK round trip (one-sided write + ack)
    # Streaming message-passing (UCX) per-block CPU overhead.  4.4 µs
    # reproduces the paper's whole Fig. 4 utilization curve on a 400 Gbps
    # link: util(4 KB) = wire/(wire+4.4 µs) = 1.8 %, util(32 KB) = 13 %.
    message_block_overhead_s: float = 4.4e-6

    @staticmethod
    def nic_400g() -> "LinkModel":
        return LinkModel()

    @staticmethod
    def ici() -> "LinkModel":
        """TPU v5e ICI link: ~50 GB/s, on-chip DMA descriptor post ~1 µs."""
        return LinkModel(bandwidth_Bps=50e9, post_overhead_s=1e-6, ack_rtt_s=4e-6)

    @staticmethod
    def dcn() -> "LinkModel":
        """Cross-pod data-center network: ~25 GB/s effective per host link."""
        return LinkModel(bandwidth_Bps=25e9, post_overhead_s=3e-6, ack_rtt_s=2e-5)

    def read_time(self, nbytes: int) -> float:
        return self.post_overhead_s + nbytes / self.bandwidth_Bps

    def message_round_time(self, nbytes: int) -> float:
        """One NAIVE per-block round (Fig. 3's RPC flow, nothing
        overlapped) — the strawman timeline of Motivation #1."""
        return (
            self.rpc_latency_s
            + self.gather_launch_s
            + self.cpu_sync_s
            + nbytes / self.bandwidth_Bps
            + self.scatter_launch_s
            + self.notify_s
        )

    def message_stream_time(self, nbytes: int, n_blocks: int) -> float:
        """A PIPELINED stream of message sends (UCX-style, Fig. 4): the
        per-block CPU overhead is what bounds throughput."""
        return n_blocks * self.message_block_overhead_s + nbytes / self.bandwidth_Bps


@dataclasses.dataclass
class TransferStats:
    bytes_moved: int = 0
    reads_posted: int = 0           # RDMA-level ops after coalescing
    txns_submitted: int = 0         # original read transactions
    completes: int = 0
    modeled_time_s: float = 0.0     # LinkModel clock
    wall_time_s: float = 0.0        # measured memcpy time
    rounds: int = 0                 # message-mode staging rounds

    @property
    def coalesce_factor(self) -> float:
        return self.txns_submitted / self.reads_posted if self.reads_posted else 1.0

    def modeled_bandwidth_Bps(self) -> float:
        return self.bytes_moved / self.modeled_time_s if self.modeled_time_s else 0.0


class ConnectionTornError(KeyError):
    """An MR was torn down (or never registered) while transactions
    referencing it were still in flight.  Subclasses ``KeyError`` for
    backward compatibility with callers that caught the engine's old bare
    ``KeyError``; carries the torn worker and the affected request ids so
    the serving layer can park / re-route those requests cleanly."""

    def __init__(self, worker_id: str, request_ids: Sequence[str]) -> None:
        self.worker_id = worker_id
        self.request_ids = tuple(request_ids)
        super().__init__(
            f"unregistered worker {worker_id!r} with transactions in flight "
            f"for requests {self.request_ids} (connection torn down?)"
        )

    def __str__(self) -> str:  # KeyError.__str__ repr-quotes; keep it readable
        return self.args[0]


class TransferFuture:
    """Completion handle for one request's in-flight transfer.

    Resolves when the request's COMPLETE executes (success) or when an MR
    it depends on is torn down mid-transfer (failure, ``exception()`` is a
    ``ConnectionTornError``).  ``layers_done`` exposes layer-streamed
    progress: a layer index appears as soon as every read tagged with it
    has executed, so layer-0 KV is observable before the pull finishes.

    ``wait_layer(i)`` is the pipelined consumer's primitive: it advances
    the owning engine until layer ``i``'s bytes are resident (or the
    transfer dies), so a decode step can run layer ``i``'s attention
    while layers ``i+1..L-1`` are still in flight.  ``add_layer_callback``
    is the event-driven form of the same signal.
    """

    __slots__ = ("request_id", "_resolved", "_error", "_layers_done", "_cbs",
                 "_layer_cbs", "_engine")

    def __init__(self, request_id: str, engine: "TransferEngine | None" = None) -> None:
        self.request_id = request_id
        self._resolved = False
        self._error: Exception | None = None
        self._layers_done: list[int] = []
        self._cbs: list[Callable[["TransferFuture"], None]] = []
        self._layer_cbs: list[Callable[["TransferFuture", int], None]] = []
        self._engine = engine

    def done(self) -> bool:
        return self._resolved

    @property
    def failed(self) -> bool:
        return self._resolved and self._error is not None

    def exception(self) -> Exception | None:
        return self._error

    @property
    def layers_done(self) -> tuple[int, ...]:
        return tuple(self._layers_done)

    def layer_done(self, layer: int) -> bool:
        return layer in self._layers_done

    def wait_layer(self, layer: int, *, budget: int | None = 32) -> None:
        """Advance the owning engine until every read tagged ``layer`` has
        executed.  Progresses ``budget`` transactions at a time (None =
        run the queue dry) so foreign work interleaves fairly.  Raises the
        transfer's error if it dies first (``ConnectionTornError`` on a
        mid-pull teardown — possibly BETWEEN layers, which is exactly the
        window the layerwise decode consumer must survive), and
        ``RuntimeError`` if the engine's queue empties without the layer
        completing (the pull was never layer-tagged, or the layer index is
        out of range)."""
        budget = None if budget is None else max(1, budget)
        while not self._resolved and layer not in self._layers_done:
            if self._engine is None or not self._engine.pending:
                raise RuntimeError(
                    f"transfer of {self.request_id!r} cannot reach layer {layer}: "
                    "engine queue is empty (untagged pull or bad layer index?)"
                )
            self._engine.progress(budget)
        if self._error is not None:
            raise self._error
        if layer not in self._layers_done:
            raise RuntimeError(
                f"transfer of {self.request_id!r} completed without layer {layer} "
                "(untagged pull or bad layer index?)"
            )

    def add_layer_callback(self, cb: Callable[["TransferFuture", int], None]) -> None:
        """``cb(future, layer)`` fires when a layer's reads all execute;
        fires immediately for layers already done."""
        for layer in list(self._layers_done):
            cb(self, layer)
        if not self._resolved:
            self._layer_cbs.append(cb)

    def result(self) -> str:
        """The request id, or raises the transfer's error.  Raises
        ``RuntimeError`` if the transfer is still in flight (call
        ``progress()``/``drain()`` first — there is no blocking wait)."""
        if not self._resolved:
            raise RuntimeError(f"transfer of {self.request_id!r} still in flight")
        if self._error is not None:
            raise self._error
        return self.request_id

    def add_done_callback(self, cb: Callable[["TransferFuture"], None]) -> None:
        if self._resolved:
            cb(self)
        else:
            self._cbs.append(cb)

    def __repr__(self) -> str:
        state = ("failed" if self.failed else "done") if self._resolved else "pending"
        return f"TransferFuture({self.request_id!r}, {state}, layers={self._layers_done})"


@dataclasses.dataclass
class MemoryRegion:
    """A registered MR: a worker's slab of 'HBM' the engine may touch."""

    worker_id: str
    base_address: int
    buffer: np.ndarray  # dtype uint8, 1-D

    def view(self, rng: ByteRange) -> np.ndarray:
        lo = rng.offset - self.base_address
        if lo < 0 or lo + rng.nbytes > self.buffer.nbytes:
            raise IndexError(
                f"range {rng} outside MR of {self.worker_id} "
                f"(base={self.base_address:#x} size={self.buffer.nbytes})"
            )
        return self.buffer[lo : lo + rng.nbytes]


class TransferEngine:
    """Event-driven transaction queue drained into coalesced one-sided reads.

    The engine is incremental: ``submit()`` returns a ``TransferFuture``
    per request, ``progress(budget)`` executes up to ``budget`` queued
    transactions (so a decode worker can interleave transfer work with
    decode compute), ``poll()`` drains the completion queue of futures
    that resolved since the last poll, and ``drain()`` is simply
    progress-until-empty for legacy blocking callers — byte movement is
    identical either way.

    Ordering rules (§4.2):
      * reads are asynchronous and may complete out of order ACROSS
        requests;
      * a COMPLETE for request R is only executed after every read of R
        already in the queue has executed (the decode worker enqueues
        COMPLETE after TRANSFERs, and the engine's coalescing window
        stops at the first COMPLETE, preserving this);
      * COMPLETEs on one connection are serialized by an ACK so a later
        COMPLETE cannot overwrite an unconsumed mailbox slot (WAW).
        Reads are never blocked by a pending ACK.

    Teardown during transfer: ``deregister_memory`` drops every queued
    transaction touching the torn MR and fails the affected requests'
    futures with ``ConnectionTornError`` (instead of surfacing a bare
    ``KeyError`` later in ``_copy``), so the serving layer can re-route.
    """

    def __init__(
        self,
        *,
        mode: str = "tensor_centric",
        coalescing: str = "fifo",
        link: LinkModel | None = None,
        execute_copies: bool = True,
        staging_blocks: int = 2,
        staging_block_bytes: int = 256 * 1024,
        codec: str = "none",
        tick_budget: int = 64,
        tracer=None,
        metrics=None,
    ) -> None:
        """codec="int8_transport": beyond-paper KV compression on the wire
        (the paper lists KV compression as complementary, §6) — bf16 spans
        are symmetric-quantized to int8 + one f32 scale per read, halving
        wire bytes; the destination slab is dequantized bf16, so compute
        is unchanged.  Lossy (≤1/127 of the span max; tests bound it)."""
        if mode not in ("tensor_centric", "message"):
            raise ValueError(f"unknown mode {mode!r}")
        if codec not in ("none", "int8_transport"):
            raise ValueError(f"unknown codec {codec!r}")
        self.mode = mode
        self.codec = codec
        self.coalescing = coalescing if mode == "tensor_centric" else "none"
        self.link = link or LinkModel()
        self.execute_copies = execute_copies
        # Message-mode staging buffer capacity (Fig. 7a: "can hold two blocks").
        self.staging_bytes = staging_blocks * staging_block_bytes
        self._regions: dict[str, MemoryRegion] = {}
        self._queue: collections.deque[Txn] = collections.deque()
        self._outstanding_reads: collections.Counter[str] = collections.Counter()
        self._outstanding_layer: collections.Counter[tuple[str, int]] = collections.Counter()
        self._futures: dict[str, TransferFuture] = {}  # unresolved, by request
        # Completion notifications are a convenience view — the futures
        # themselves carry the resolved state — so the queue is bounded:
        # blocking callers that never poll() must not leak one entry per
        # request served over a long-lived engine.
        self._completions: collections.deque[TransferFuture] = collections.deque(
            maxlen=4096)
        # Requests torn mid-execution whose CompleteTxn is still queued:
        # that COMPLETE must be swallowed, not executed — the bytes never
        # fully landed, so completion callbacks (prefill-side free!) must
        # not fire for it.
        self._torn_completes: set[str] = set()
        self._complete_cbs: list[Callable[[CompleteTxn], None]] = []
        # Per-request bytes actually landed (executed reads, retries
        # accumulate).  Entries live until pulled_bytes(pop=True) — the
        # serving layer pops them into the request handle at completion.
        self._pulled_bytes: collections.Counter[str] = collections.Counter()
        # Per-request bytes NOT moved because the destination already held
        # them (delta transfer plans grafting resident prefix / dedup'd
        # blocks).  Same lifecycle as _pulled_bytes: retries accumulate,
        # popped at request completion.
        self._reused_bytes: collections.Counter[str] = collections.Counter()
        self.tick_budget = tick_budget
        self.stats = TransferStats()
        # Observability (optional; see docs/observability.md): the tracer
        # records the per-request pull lifecycle — submit instant, one
        # span per layer as its reads land, complete/torn instant — on
        # the request's track, so a serve trace shows the wire timeline
        # under the decode timeline.  The metrics registry accumulates
        # engine totals (bytes, reads, completes, teardowns).
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics
        self._layer_mark: dict[str, float] = {}  # rid -> last layer-end ts

    # ------------------------------------------------------------- setup
    def register_memory(self, region: MemoryRegion) -> None:
        if region.worker_id in self._regions:
            raise ValueError(f"worker {region.worker_id!r} already registered an MR")
        # The engine models ONE flat address space (descriptors carry raw
        # addresses, §4.1) — two slabs sharing addresses would make a
        # descriptor ambiguous, so MRs must be disjoint.
        lo, hi = region.base_address, region.base_address + region.buffer.nbytes
        for other in self._regions.values():
            o_lo, o_hi = other.base_address, other.base_address + other.buffer.nbytes
            if lo < o_hi and o_lo < hi:
                raise ValueError(
                    f"MR of {region.worker_id!r} [{lo:#x}, {hi:#x}) overlaps "
                    f"MR of {other.worker_id!r} [{o_lo:#x}, {o_hi:#x})"
                )
        self._regions[region.worker_id] = region

    def deregister_memory(self, worker_id: str) -> None:
        """Tear down a worker's MR.  Queued transactions that reference it
        are dropped and the affected requests' futures fail with
        ``ConnectionTornError`` — a crash mid-pull becomes a typed, per-
        request failure the serving layer can re-route, not a late
        ``KeyError`` deep in ``_copy``."""
        self._regions.pop(worker_id, None)
        if not self._queue:
            return
        survivors: collections.deque[Txn] = collections.deque()
        torn: list[Txn] = []
        for t in self._queue:
            if t.src_worker == worker_id or t.dst_worker == worker_id:
                torn.append(t)
            else:
                survivors.append(t)
        if not torn:
            return
        self._queue = survivors
        torn_rids: dict[str, None] = {}  # ordered set
        for t in torn:
            torn_rids[t.request_id] = None
            if isinstance(t, ReadTxn):
                self._outstanding_reads[t.request_id] -= 1
                if t.layer is not None:
                    key = (t.request_id, t.layer)
                    self._outstanding_layer[key] -= 1
                    if self._outstanding_layer[key] <= 0:
                        del self._outstanding_layer[key]  # dropped, NOT done
            else:
                # its COMPLETE was dropped with the reads: a future re-pull
                # under the same request id must not have ITS complete
                # swallowed by a stale torn marker
                self._torn_completes.discard(t.request_id)
        for rid in torn_rids:
            fut = self._futures.get(rid)
            if fut is not None:
                self._resolve(fut, ConnectionTornError(worker_id, (rid,)))

    def on_complete(self, cb: Callable[[CompleteTxn], None]) -> None:
        self._complete_cbs.append(cb)

    # ------------------------------------------------------------ submit
    def submit(self, txns: Iterable[Txn]) -> list[TransferFuture]:
        """Enqueue transactions; returns the futures newly created by this
        call (one per request id not already in flight).  Existing callers
        that ignore the return value are unaffected."""
        created: list[TransferFuture] = []
        for t in txns:
            if isinstance(t, ReadTxn):
                self._outstanding_reads[t.request_id] += 1
                if t.layer is not None:
                    self._outstanding_layer[(t.request_id, t.layer)] += 1
                self.stats.txns_submitted += 1
            if t.request_id not in self._futures:
                fut = TransferFuture(t.request_id, engine=self)
                self._futures[t.request_id] = fut
                created.append(fut)
                if self.tracer.enabled:
                    now = self.tracer.now()
                    self._layer_mark[t.request_id] = now
                    self.tracer.instant("transfer.submit", ts=now,
                                        track=("request", t.request_id))
                if self.metrics is not None:
                    self.metrics.inc("engine.pulls_submitted")
            self._queue.append(t)
        return created

    def future(self, request_id: str) -> TransferFuture | None:
        """The unresolved future for ``request_id``, if any."""
        return self._futures.get(request_id)

    @property
    def pending(self) -> int:
        """Queued transactions not yet executed."""
        return len(self._queue)

    # ----------------------------------------------------------- resolve
    def _resolve(self, fut: TransferFuture, error: Exception | None = None) -> None:
        fut._resolved = True
        fut._error = error
        self._futures.pop(fut.request_id, None)
        self._completions.append(fut)
        self._layer_mark.pop(fut.request_id, None)
        if self.tracer.enabled:
            self.tracer.instant(
                "transfer.torn" if error is not None else "transfer.complete",
                track=("request", fut.request_id),
                bytes=self._pulled_bytes.get(fut.request_id, 0),
                **({"error": str(error)} if error is not None else {}))
        if self.metrics is not None and error is not None:
            self.metrics.inc("engine.pulls_torn")
        for cb in fut._cbs:
            cb(fut)
        fut._cbs.clear()
        fut._layer_cbs.clear()

    def poll(self) -> list[TransferFuture]:
        """Futures resolved (success or failure) since the last poll."""
        out = list(self._completions)
        self._completions.clear()
        return out

    # ---------------------------------------------------------- progress
    def progress(self, budget: int | None = None) -> int:
        """Execute up to ``budget`` queued transactions (all of them when
        ``budget`` is None) and return how many were processed.  This is
        the incremental heart of the engine: a decode worker calls it
        between decode steps so transfer time hides behind compute.

        A budget may split what would have been one coalescing window —
        bytes moved are identical, only ``reads_posted`` can differ from a
        one-shot ``drain()``."""
        processed = 0
        while self._queue and (budget is None or processed < budget):
            if isinstance(self._queue[0], CompleteTxn):
                self._do_complete(self._queue.popleft())  # type: ignore[arg-type]
                processed += 1
                continue
            window: list[ReadTxn] = []
            room = None if budget is None else budget - processed
            while self._queue and isinstance(self._queue[0], ReadTxn) and (
                    room is None or len(window) < room):
                window.append(self._queue.popleft())  # type: ignore[arg-type]
            if self.mode == "tensor_centric":
                self._post_reads(window)
            else:
                self._message_rounds(window)
            processed += len(window)
        return processed

    def tick(self, budget: int | None = None) -> int:
        """Event-loop progress hook: advance up to ``budget`` transactions
        (defaulting to the engine's configured ``tick_budget``) and return
        how many were processed.  This is the hook a serving loop calls
        once per tick so transfer work is metered against admission and
        decode work instead of monopolizing the tick."""
        if not self._queue:
            return 0
        return self.progress(self.tick_budget if budget is None else budget)

    def pulled_bytes(self, request_id: str, *, pop: bool = False) -> int:
        """Bytes landed for ``request_id`` so far (executed reads only;
        retries accumulate).  ``pop=True`` retires the entry — callers
        finishing a request should pop so a long-lived engine doesn't
        grow one counter per request ever served."""
        if pop:
            return self._pulled_bytes.pop(request_id, 0)
        return self._pulled_bytes.get(request_id, 0)

    def note_reused(self, request_id: str, nbytes: int) -> None:
        """Record ``nbytes`` a delta transfer plan for ``request_id``
        skipped on the wire (resident prefix graft / content-hash dedup).
        Accumulates across retries, mirroring ``_pulled_bytes`` — a torn
        suffix that re-admits re-grafts and re-notes, just as its re-pull
        re-counts."""
        if nbytes <= 0:
            return
        self._reused_bytes[request_id] += nbytes
        if self.metrics is not None:
            self.metrics.inc("engine.bytes_reused", nbytes)
        if self.tracer.enabled:
            self.tracer.instant("transfer.reuse", track=("request", request_id),
                                bytes=nbytes)

    def reused_bytes(self, request_id: str, *, pop: bool = False) -> int:
        """Bytes skipped for ``request_id`` by delta plans so far (retries
        accumulate); ``pop=True`` retires the entry at request completion,
        like ``pulled_bytes``."""
        if pop:
            return self._reused_bytes.pop(request_id, 0)
        return self._reused_bytes.get(request_id, 0)

    # ------------------------------------------------------------- drain
    def drain(self) -> TransferStats:
        """Process the whole queue (progress-until-empty).  Returns
        cumulative stats — the legacy blocking API."""
        while self._queue:
            self.progress()
        return self.stats

    def _filter_torn(self, window: Sequence[ReadTxn]) -> tuple[list[ReadTxn], ConnectionTornError | None]:
        """Split out reads whose MR is gone (stale submission after a
        teardown): fail their futures NOW and keep the healthy remainder,
        so one torn request cannot poison requests sharing its window.
        Returns (healthy reads, first torn error or None)."""
        if not self.execute_copies:
            return list(window), None  # timed-only engines never touch MRs
        healthy: list[ReadTxn] = []
        first: ConnectionTornError | None = None
        for t in window:
            missing = next((w for w in (t.src_worker, t.dst_worker)
                            if w not in self._regions), None)
            if missing is None:
                healthy.append(t)
            else:
                err = self._torn(missing, t)
                first = first or err
        return healthy, first

    # --------------------------------------------------- tensor-centric
    def _post_reads(self, window: Sequence[ReadTxn]) -> None:
        healthy, torn_err = self._filter_torn(window)
        for t in healthy:
            self._pulled_bytes[t.request_id] += t.nbytes
        merged = coalesce(healthy, strategy=self.coalescing)
        t0 = time.perf_counter()
        for op in merged:
            self._copy(op)
            self.stats.reads_posted += 1
            quantized = self.codec != "none" or op.qscale is not None
            wire = op.nbytes // 2 + 4 if quantized else op.nbytes
            self.stats.bytes_moved += wire
            self.stats.modeled_time_s += self.link.read_time(wire)
        self.stats.wall_time_s += time.perf_counter() - t0
        if self.metrics is not None and merged:
            self.metrics.inc("engine.reads_posted", len(merged))
            self.metrics.inc("engine.bytes_moved",
                             sum(op.nbytes for op in merged))
        if self.metrics is not None and healthy:
            self.metrics.inc("engine.bytes_pulled",
                             sum(t.nbytes for t in healthy))
        # torn reads are accounted too — consumed (future already failed),
        # not executed — so a queued COMPLETE for them stays inert instead
        # of raising "reads still queued"
        self._account_executed(window)
        if torn_err is not None:
            raise torn_err

    # ---------------------------------------------------- message mode
    def _message_rounds(self, window: Sequence[ReadTxn]) -> None:
        """Fig. 7a: bounded staging buffer, per-round RPC + gather + send +
        scatter + notify, with REAL double copies under memcpy."""
        healthy, torn_err = self._filter_torn(window)
        for t in healthy:
            self._pulled_bytes[t.request_id] += t.nbytes
        t0 = time.perf_counter()
        round_txns: list[ReadTxn] = []
        round_bytes = 0
        for t in list(healthy) + [None]:  # type: ignore[list-item]
            flush = t is None or (round_bytes + t.nbytes > self.staging_bytes and round_txns)
            if flush and round_txns:
                staging = np.empty(round_bytes, dtype=np.uint8) if self.execute_copies else None
                off = 0
                for rt in round_txns:  # gather (copy #1)
                    if staging is not None:
                        staging[off : off + rt.nbytes] = self._src_view(rt)
                    off += rt.nbytes
                off = 0
                for rt in round_txns:  # scatter (copy #2)
                    if staging is not None:
                        self._dst_view(rt)[...] = staging[off : off + rt.nbytes]
                    off += rt.nbytes
                self.stats.rounds += 1
                self.stats.reads_posted += 1
                self.stats.bytes_moved += round_bytes
                self.stats.modeled_time_s += self.link.message_stream_time(
                    round_bytes, len(round_txns))
                if self.metrics is not None:
                    self.metrics.inc("engine.reads_posted")
                    self.metrics.inc("engine.bytes_moved", round_bytes)
                round_txns, round_bytes = [], 0
            if t is not None:
                round_txns.append(t)
                round_bytes += t.nbytes
        self.stats.wall_time_s += time.perf_counter() - t0
        self._account_executed(window)
        if torn_err is not None:
            raise torn_err

    # ------------------------------------------------------------ common
    def _account_executed(self, window: Sequence[ReadTxn]) -> None:
        """Post-execution bookkeeping: outstanding-read counters and
        per-layer completion marks on the requests' futures."""
        for t in window:
            self._outstanding_reads[t.request_id] -= 1
            if t.layer is None:
                continue
            key = (t.request_id, t.layer)
            self._outstanding_layer[key] -= 1
            if self._outstanding_layer[key] <= 0:
                del self._outstanding_layer[key]
                if self.tracer.enabled:
                    # one span per landed layer: previous layer's end (or
                    # the submit mark) -> now, on the request's track
                    now = self.tracer.now()
                    t0 = self._layer_mark.get(t.request_id, now)
                    self.tracer.complete(
                        f"transfer.layer{t.layer}", ("request", t.request_id),
                        t0, now, layer=t.layer)
                    self._layer_mark[t.request_id] = now
                fut = self._futures.get(t.request_id)
                if fut is not None:
                    fut._layers_done.append(t.layer)
                    # layer callbacks may tear down workers (failover
                    # fires from them in tests): snapshot the list
                    for cb in list(fut._layer_cbs):
                        cb(fut, t.layer)

    @staticmethod
    def _op_request_ids(op: ReadTxn | CoalescedRead) -> tuple[str, ...]:
        if isinstance(op, ReadTxn):
            return (op.request_id,)
        return tuple(dict.fromkeys(op.request_ids))

    def _torn(self, worker_id: str, op: ReadTxn | CoalescedRead) -> ConnectionTornError:
        """Fail the affected futures and build the typed error.  The
        requests' queued COMPLETEs are marked for swallowing: their bytes
        never fully landed, so completion callbacks must not fire."""
        rids = self._op_request_ids(op)
        err = ConnectionTornError(worker_id, rids)
        for rid in rids:
            self._torn_completes.add(rid)
            fut = self._futures.get(rid)
            if fut is not None:
                self._resolve(fut, err)
        return err

    def _src_view(self, op: ReadTxn | CoalescedRead) -> np.ndarray:
        region = self._regions.get(op.src_worker)
        if region is None:
            raise self._torn(op.src_worker, op)
        return region.view(op.remote)

    def _dst_view(self, op: ReadTxn | CoalescedRead) -> np.ndarray:
        region = self._regions.get(op.dst_worker)
        if region is None:
            raise self._torn(op.dst_worker, op)
        return region.view(op.local)

    def _copy(self, op: CoalescedRead) -> None:
        if not self.execute_copies:
            return
        src = self._regions.get(op.src_worker)
        dst = self._regions.get(op.dst_worker)
        if src is None or dst is None:
            raise self._torn(op.src_worker if src is None else op.dst_worker, op)
        if self.codec == "none" and op.qscale is None:
            dst.view(op.local)[...] = src.view(op.remote)
            return
        # int8 transport: quantize the bf16 span, move int8, dequantize.
        # A carried op.qscale (delta-plan quantized pull) is used as-is —
        # the PREFILL side computed it per block plane at park time and
        # it rode the Txn descriptor; otherwise (engine-wide
        # codec="int8_transport") the scale is computed inline per
        # coalesced read.
        import ml_dtypes

        s = src.view(op.remote).view(ml_dtypes.bfloat16).astype(np.float32)
        scale = op.qscale if op.qscale is not None else (
            float(np.max(np.abs(s))) / 127.0 or 1.0)
        q = np.clip(np.round(s / scale), -127, 127).astype(np.int8)
        deq = (q.astype(np.float32) * scale).astype(ml_dtypes.bfloat16)
        dst.view(op.local)[...] = deq.view(np.uint8)

    def _do_complete(self, txn: CompleteTxn) -> None:
        if txn.request_id in self._torn_completes:
            # the transfer failed mid-flight (future already failed):
            # swallow its COMPLETE so the prefill side keeps the only
            # surviving KV copy for the re-route
            self._torn_completes.discard(txn.request_id)
            return
        if self._outstanding_reads[txn.request_id] > 0:
            raise RuntimeError(
                f"COMPLETE for {txn.request_id!r} with "
                f"{self._outstanding_reads[txn.request_id]} reads still queued — "
                "the decode worker must enqueue COMPLETE after all TRANSFERs"
            )
        # Serialized by ACK: one mailbox slot per connection, strictly FIFO
        # (we drain in order, so FIFO holds; the cost of the ACK is modeled).
        self.stats.completes += 1
        self.stats.modeled_time_s += self.link.ack_rtt_s
        if self.metrics is not None:
            self.metrics.inc("engine.completes")
        for cb in self._complete_cbs:
            cb(txn)
        fut = self._futures.get(txn.request_id)
        if fut is not None:
            self._resolve(fut)
