"""Tensor-centric transfer descriptors (KVDirect §4.1).

The heart of KVDirect is that the *prefill* worker describes its KV cache
tensor ONCE at connection time — ``(Address, Dims, Shape, Stride)`` — and
from then on the *decode* worker computes every remote byte range locally
(an index·stride dot product) and issues one-sided reads.  No per-block
metadata round trips, no remote-side gather kernels.

This module implements that arithmetic exactly as §4.1 specifies,
including the paper's worked example (see ``TensorDesc`` docstring).
Note: the paper's printed example contains two small arithmetic typos
(147453 B should be 147456 B; the span product is 16·256·2 B, not
16·128·2 B) — the *results* it states (two disjoint 8192 B spans per
block) are what the correct math yields and what we compute here.
"""
from __future__ import annotations

import dataclasses
import itertools
from typing import Iterator, Sequence

__all__ = [
    "ByteRange",
    "TensorDesc",
    "ReadTxn",
    "CompleteTxn",
    "Txn",
    "build_block_reads",
]


@dataclasses.dataclass(frozen=True, order=True)
class ByteRange:
    """A contiguous byte range inside one worker's registered memory."""

    offset: int
    nbytes: int

    def __post_init__(self) -> None:
        if self.offset < 0 or self.nbytes <= 0:
            raise ValueError(f"invalid range: offset={self.offset} nbytes={self.nbytes}")

    @property
    def end(self) -> int:
        return self.offset + self.nbytes

    def abuts(self, other: "ByteRange") -> bool:
        """True if ``other`` starts exactly where this range ends."""
        return self.end == other.offset

    def merged(self, other: "ByteRange") -> "ByteRange":
        if not self.abuts(other):
            raise ValueError(f"cannot merge non-adjacent ranges {self} and {other}")
        return ByteRange(self.offset, self.nbytes + other.nbytes)


@dataclasses.dataclass(frozen=True)
class TensorDesc:
    """Metadata exchanged by ``CONNECT()`` describing one remote tensor.

    Mirrors Figure 5 of the paper.  ``dims`` names each dimension (the
    canonical paged-KV layout is ``("B","KV","L","H","D")`` = blocks,
    K-or-V, tokens-per-block, heads, head-dim, but any order is allowed —
    strides carry the layout).  ``stride`` is in ELEMENTS, ``itemsize``
    in bytes, matching the paper's ``× 2B`` bfloat16 factor.

    Worked example (paper §4.1)::

        >>> d = TensorDesc(address=0x7F06F40000,
        ...                dims=("B", "KV", "L", "H", "D"),
        ...                shape=(10, 2, 16, 2, 128),
        ...                stride=(4096, 40960, 256, 128, 1),
        ...                itemsize=2)
        >>> [r.offset - d.address for r in d.block_ranges(8)]  # K then V of block 8
        [65536, 147456]
        >>> {r.nbytes for r in d.block_ranges(8)}           # one 8192 B span each
        {8192}
    """

    address: int
    dims: tuple[str, ...]
    shape: tuple[int, ...]
    stride: tuple[int, ...]
    itemsize: int
    worker_id: str = ""
    tensor_id: str = ""

    def __post_init__(self) -> None:
        if not (len(self.dims) == len(self.shape) == len(self.stride)):
            raise ValueError("dims/shape/stride rank mismatch")
        if len(set(self.dims)) != len(self.dims):
            raise ValueError(f"duplicate dim names in {self.dims}")
        if any(s <= 0 for s in self.shape):
            raise ValueError(f"non-positive extent in shape {self.shape}")
        if any(s <= 0 for s in self.stride):
            raise ValueError(f"non-positive stride in {self.stride}")
        if self.itemsize <= 0:
            raise ValueError("itemsize must be positive")

    # ------------------------------------------------------------------
    # §4.1 offset arithmetic
    # ------------------------------------------------------------------
    def axis(self, dim: str) -> int:
        try:
            return self.dims.index(dim)
        except ValueError:
            raise KeyError(f"tensor {self.tensor_id!r} has no dim {dim!r} (dims={self.dims})")

    def element_offset(self, index: Sequence[int]) -> int:
        """index · stride — the dot product of §4.1."""
        if len(index) != len(self.shape):
            raise ValueError("index rank mismatch")
        for i, (ix, ext) in enumerate(zip(index, self.shape)):
            if not (0 <= ix < ext):
                raise IndexError(f"index {ix} out of range for dim {self.dims[i]} (extent {ext})")
        return sum(i * s for i, s in zip(index, self.stride))

    def byte_offset(self, index: Sequence[int]) -> int:
        return self.element_offset(index) * self.itemsize

    def _layout_order(self) -> list[int]:
        """Axes sorted by stride, descending (outermost-in-memory first)."""
        return sorted(range(len(self.dims)), key=lambda a: self.stride[a], reverse=True)

    def contiguous_span(self, cover: Sequence[str]) -> int:
        """Bytes of the contiguous span covering dims ``cover`` (§4.1).

        The paper: "find the dimension with the largest stride [among the
        covered dims] and multiply its shape with the stride".  Valid only
        if the covered dims are densely packed (innermost stride 1, each
        outer covered stride equals the span of the dims inside it) —
        verified here, because a silent violation would corrupt transfers.
        """
        axes = sorted((self.axis(d) for d in cover), key=lambda a: self.stride[a])
        span = 1  # elements
        for a in axes:
            if self.stride[a] != span:
                raise ValueError(
                    f"dims {tuple(cover)} of {self.tensor_id!r} are not densely packed: "
                    f"dim {self.dims[a]} stride {self.stride[a]} != inner span {span}"
                )
            span *= self.shape[a]
        return span * self.itemsize

    def block_ranges(self, block_id: int, *, block_dim: str = "B") -> list[ByteRange]:
        """All byte ranges holding block ``block_id``, smallest offset first.

        One range per combination of the non-block, non-inner dims (for the
        canonical layout: one for K, one for V).  The inner contiguous unit
        is the maximal dense suffix below ALL enumerated dims.

        Ranges are ABSOLUTE (``address`` + relative offset) — ready to post
        as RDMA transactions against the worker's registered MR.
        """
        b_axis = self.axis(block_dim)
        order = self._layout_order()
        # Maximal dense suffix (in layout order) that excludes block_dim.
        inner: list[int] = []
        span = 1
        for a in reversed(order):
            if a == b_axis or self.stride[a] != span:
                break
            inner.append(a)
            span *= self.shape[a]
        if not inner:
            raise ValueError(f"tensor {self.tensor_id!r} has no dense inner dims below {block_dim!r}")
        enumerated = [a for a in order if a != b_axis and a not in inner]
        span_bytes = span * self.itemsize

        ranges: list[ByteRange] = []
        for combo in itertools.product(*(range(self.shape[a]) for a in enumerated)):
            index = [0] * len(self.shape)
            index[b_axis] = block_id
            for a, v in zip(enumerated, combo):
                index[a] = v
            ranges.append(ByteRange(self.address + self.byte_offset(index), span_bytes))
        ranges.sort()
        return ranges

    @property
    def nbytes(self) -> int:
        """Total registered bytes (assuming a dense layout overall)."""
        order = self._layout_order()
        top = order[0]
        return self.stride[top] * self.shape[top] * self.itemsize


# ----------------------------------------------------------------------
# Transactions (consumed by core.transactions / core.transfer_engine)
# ----------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class ReadTxn:
    """One-sided read: pull ``remote`` on ``src_worker`` into ``local`` on
    ``dst_worker``.  Posted by the decode worker; the prefill worker does
    no work (§4.1 Fig. 7b).

    ``layer`` optionally tags which model layer this read belongs to:
    layer-streamed pulls submit layer 0 first and the engine reports
    per-layer completion on the request's ``TransferFuture``.

    ``qscale`` optionally carries a symmetric-int8 dequantization scale
    for this read's span: the source side computed ``scale =
    max(|span|)/127`` at park time, the wire moves int8 payload
    (``nbytes // 2`` plus the 4-byte scale the descriptor already
    carries), and the engine dequantizes into the destination slab.
    ``None`` = uncompressed (plain byte copy)."""

    request_id: str
    src_worker: str
    dst_worker: str
    remote: ByteRange
    local: ByteRange
    layer: int | None = None
    qscale: float | None = None

    def __post_init__(self) -> None:
        if self.remote.nbytes != self.local.nbytes:
            raise ValueError("read size mismatch between remote and local ranges")
        if self.qscale is not None and self.qscale <= 0:
            raise ValueError(f"qscale must be positive, got {self.qscale}")

    @property
    def nbytes(self) -> int:
        return self.remote.nbytes


@dataclasses.dataclass(frozen=True)
class CompleteTxn:
    """COMPLETE(): tells the prefill worker that ``request_id`` has been
    fully pulled so its KV blocks can be freed (§4.2, synchronous via ACK)."""

    request_id: str
    src_worker: str
    dst_worker: str


Txn = ReadTxn | CompleteTxn


def build_block_reads(
    request_id: str,
    remote_desc: TensorDesc,
    local_desc: TensorDesc,
    remote_blocks: Sequence[int],
    local_blocks: Sequence[int],
    *,
    block_dim: str = "B",
    layer: int | None = None,
    scales: Sequence[Sequence[float]] | None = None,
) -> Iterator[ReadTxn]:
    """TRANSFER(): translate (remote block id → local block id) pairs into
    read transactions using only descriptor arithmetic — the decode worker
    never asks the prefill worker where anything lives.

    ``scales`` (optional) requests quantized transfer: ``scales[i][pos]``
    is the int8 dequantization scale for block position ``i``'s plane
    ``pos`` (K = 0, V = 1 in the canonical layout), attached to the
    emitted ``ReadTxn.qscale`` so the scale rides the descriptor — no
    side channel on the wire.
    """
    if len(remote_blocks) != len(local_blocks):
        raise ValueError("remote/local block list length mismatch")
    if scales is not None and len(scales) != len(remote_blocks):
        raise ValueError("scales/block list length mismatch")
    per_block: list[tuple[list[ByteRange], list[ByteRange]]] = []
    for rb, lb in zip(remote_blocks, local_blocks):
        remote_ranges = remote_desc.block_ranges(rb, block_dim=block_dim)
        local_ranges = local_desc.block_ranges(lb, block_dim=block_dim)
        if [r.nbytes for r in remote_ranges] != [r.nbytes for r in local_ranges]:
            raise ValueError(
                f"block layout mismatch between {remote_desc.tensor_id!r} and "
                f"{local_desc.tensor_id!r} for blocks {rb}->{lb}"
            )
        per_block.append((remote_ranges, local_ranges))
    # Plane-major emission: all K-plane ranges (block order), then all
    # V-plane ranges.  Consecutive blocks land FIFO-adjacent in each plane,
    # so the engine's in-order coalescer (§4.2) sees the paper's
    # "blocks 0 and 1 merge into one 16384 B transaction" opportunity.
    n_ranges = len(per_block[0][0]) if per_block else 0
    for pos in range(n_ranges):
        for i, (remote_ranges, local_ranges) in enumerate(per_block):
            yield ReadTxn(
                request_id=request_id,
                src_worker=remote_desc.worker_id,
                dst_worker=local_desc.worker_id,
                remote=remote_ranges[pos],
                local=local_ranges[pos],
                layer=layer,
                qscale=None if scales is None else float(scales[i][pos]),
            )
