"""Cluster scheduler — dynamic worker membership (§4.2).

Message-passing collectives (NCCL/MPI) freeze the communication graph at
init; adding a GPU means restarting the service (paper Motivation #2).
KVDirect instead keeps a tiny control-plane registry: workers join and
leave a *running* cluster, the scheduler broadcasts membership changes,
and decode workers react by CONNECTing to new prefill workers.

The scheduler is control-plane only.  Descriptors and reads flow directly
between workers, so a scheduler outage stalls membership changes but not
inference (tested in tests/test_cluster.py).

Failure handling built on the same path:
  * ``remove_worker(id, failed=True)`` — crash: decode workers invalidate
    the connection epoch; the serving layer re-queues in-flight requests.
  * heartbeats with a deadline drive crash detection;
  * stragglers are the serving scheduler's job (hedged prefill dispatch),
    built on the membership info here.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable

from repro.core.connection import WorkerInfo

__all__ = ["ClusterScheduler", "MembershipEvent"]


@dataclasses.dataclass(frozen=True)
class MembershipEvent:
    kind: str  # "added" | "removed" | "failed"
    worker: WorkerInfo


class ClusterScheduler:
    def __init__(self, *, heartbeat_timeout_s: float = 5.0) -> None:
        self._workers: dict[str, WorkerInfo] = {}
        self._subs: list[Callable[[MembershipEvent], None]] = []
        self._last_heartbeat: dict[str, float] = {}
        self._loads: dict[str, object] = {}  # latest LoadReport per worker
        self.heartbeat_timeout_s = heartbeat_timeout_s

    # -------------------------------------------------------- membership
    def add_worker(self, info: WorkerInfo, *, now: float = 0.0) -> None:
        if info.worker_id in self._workers:
            raise ValueError(f"worker {info.worker_id!r} already in cluster")
        self._workers[info.worker_id] = info
        self._last_heartbeat[info.worker_id] = now
        self._broadcast(MembershipEvent("added", info))

    def remove_worker(self, worker_id: str, *, failed: bool = False) -> None:
        info = self._workers.pop(worker_id, None)
        if info is None:
            return
        self._last_heartbeat.pop(worker_id, None)
        self._loads.pop(worker_id, None)
        self._broadcast(MembershipEvent("failed" if failed else "removed", info))

    # --------------------------------------------------------- liveness
    def heartbeat(self, worker_id: str, now: float, load: object | None = None) -> None:
        """Liveness ping, optionally piggybacking a ``sched.LoadReport``
        so the router sees per-worker occupancy without a second control
        channel (the scheduler stores it opaquely)."""
        if worker_id in self._workers:
            self._last_heartbeat[worker_id] = max(self._last_heartbeat[worker_id], now)
            if load is not None:
                self._loads[worker_id] = load

    def report_load(self, worker_id: str, load: object) -> None:
        """Store a LoadReport WITHOUT refreshing liveness — for control
        planes that read worker state directly (a colocated serving
        layer); liveness stays owned by the workers' own heartbeats."""
        if worker_id in self._workers:
            self._loads[worker_id] = load

    def reap_dead(self, now: float) -> list[str]:
        """Crash detection: drop workers whose heartbeat lapsed.

        ALL lapsed workers leave membership before any failure event is
        broadcast — subscribers re-route in-flight work synchronously on
        the event, and must never be offered a worker that is dead but
        not yet reaped in the same sweep."""
        dead = [
            w
            for w, t in self._last_heartbeat.items()
            if now - t > self.heartbeat_timeout_s
        ]
        infos = []
        for w in dead:
            info = self._workers.pop(w, None)
            self._last_heartbeat.pop(w, None)
            self._loads.pop(w, None)
            if info is not None:
                infos.append(info)
        for info in infos:
            self._broadcast(MembershipEvent("failed", info))
        return dead

    # ------------------------------------------------------------ query
    def workers(self, role: str | None = None) -> list[WorkerInfo]:
        ws: Iterable[WorkerInfo] = self._workers.values()
        if role is not None:
            ws = (w for w in ws if w.role == role)
        return sorted(ws, key=lambda w: w.worker_id)

    def get(self, worker_id: str) -> WorkerInfo:
        return self._workers[worker_id]

    def load(self, worker_id: str):
        """Latest heartbeat-piggybacked LoadReport (None if never sent)."""
        return self._loads.get(worker_id)

    def loads(self, role: str | None = None) -> dict[str, object]:
        return {
            w.worker_id: self._loads[w.worker_id]
            for w in self.workers(role)
            if w.worker_id in self._loads
        }

    def __contains__(self, worker_id: str) -> bool:
        return worker_id in self._workers

    # -------------------------------------------------------- broadcast
    def subscribe(self, cb: Callable[[MembershipEvent], None]) -> None:
        self._subs.append(cb)

    def _broadcast(self, ev: MembershipEvent) -> None:
        for cb in list(self._subs):
            cb(ev)
