"""Cluster scheduler — dynamic worker membership (§4.2).

Message-passing collectives (NCCL/MPI) freeze the communication graph at
init; adding a GPU means restarting the service (paper Motivation #2).
KVDirect instead keeps a tiny control-plane registry: workers join and
leave a *running* cluster, the scheduler broadcasts membership changes,
and decode workers react by CONNECTing to new prefill workers.

The scheduler is control-plane only.  Descriptors and reads flow directly
between workers, so a scheduler outage stalls membership changes but not
inference (tested in tests/test_cluster.py).

Failure handling built on the same path:
  * ``remove_worker(id, failed=True)`` — crash: decode workers invalidate
    the connection epoch; the serving layer re-queues in-flight requests.
  * heartbeats with a deadline drive crash detection;
  * stragglers are the serving scheduler's job (hedged prefill dispatch),
    built on the membership info here.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Iterable

from repro.core.connection import WorkerInfo

__all__ = ["ClusterScheduler", "MembershipEvent"]


@dataclasses.dataclass(frozen=True)
class MembershipEvent:
    kind: str  # "added" | "removed" | "failed"
    worker: WorkerInfo


class ClusterScheduler:
    def __init__(self, *, heartbeat_timeout_s: float = 5.0) -> None:
        self._workers: dict[str, WorkerInfo] = {}
        self._subs: list[Callable[[MembershipEvent], None]] = []
        self._last_heartbeat: dict[str, float] = {}
        self.heartbeat_timeout_s = heartbeat_timeout_s

    # -------------------------------------------------------- membership
    def add_worker(self, info: WorkerInfo, *, now: float = 0.0) -> None:
        if info.worker_id in self._workers:
            raise ValueError(f"worker {info.worker_id!r} already in cluster")
        self._workers[info.worker_id] = info
        self._last_heartbeat[info.worker_id] = now
        self._broadcast(MembershipEvent("added", info))

    def remove_worker(self, worker_id: str, *, failed: bool = False) -> None:
        info = self._workers.pop(worker_id, None)
        if info is None:
            return
        self._last_heartbeat.pop(worker_id, None)
        self._broadcast(MembershipEvent("failed" if failed else "removed", info))

    # --------------------------------------------------------- liveness
    def heartbeat(self, worker_id: str, now: float) -> None:
        if worker_id in self._workers:
            self._last_heartbeat[worker_id] = now

    def reap_dead(self, now: float) -> list[str]:
        """Crash detection: drop workers whose heartbeat lapsed."""
        dead = [
            w
            for w, t in self._last_heartbeat.items()
            if now - t > self.heartbeat_timeout_s
        ]
        for w in dead:
            self.remove_worker(w, failed=True)
        return dead

    # ------------------------------------------------------------ query
    def workers(self, role: str | None = None) -> list[WorkerInfo]:
        ws: Iterable[WorkerInfo] = self._workers.values()
        if role is not None:
            ws = (w for w in ws if w.role == role)
        return sorted(ws, key=lambda w: w.worker_id)

    def get(self, worker_id: str) -> WorkerInfo:
        return self._workers[worker_id]

    def __contains__(self, worker_id: str) -> bool:
        return worker_id in self._workers

    # -------------------------------------------------------- broadcast
    def subscribe(self, cb: Callable[[MembershipEvent], None]) -> None:
        self._subs.append(cb)

    def _broadcast(self, ev: MembershipEvent) -> None:
        for cb in list(self._subs):
            cb(ev)
