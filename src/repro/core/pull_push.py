"""Pull-mode vs push-mode KV transfer orchestration (§4.3).

Pull-mode (KVDirect's default):
  1. prefill worker allocates blocks, runs ALL layers of prefill;
  2. block IDs travel to the decode worker (tiny control message);
  3. decode worker allocates its blocks only NOW — KV lifetime on the
     decode worker starts here, not at admission;
  4. decode worker pulls every layer's blocks in one shot (one-sided
     reads), then sends COMPLETE; prefill frees on COMPLETE.

Push-mode (the strawman; Splitwise/DéjàVu-style):
  1. decode worker must RESERVE all blocks at admission (pre-allocation —
     required because incremental allocation deadlocks, Motivation #3);
  2. prefill worker pushes layer-by-layer as it computes;
  3. decode memory is held idle from admission until prefill completes.

Both modes are implemented against the real caches + transfer engine so
the byte movement is identical and testable; the *timing/occupancy*
consequences (Fig. 11/16) are accounted by the caller's clock (the event
simulator at cluster scale, the serving driver at CPU scale).
"""
from __future__ import annotations

from typing import Sequence

from repro.core.connection import Connection
from repro.core.descriptors import CompleteTxn, Txn, build_block_reads
from repro.core.transfer_engine import TransferEngine, TransferFuture, TransferStats
from repro.serving.blocks import BlockPool
from repro.serving.kv_cache import PagedKVCache, SlotCache
from repro.serving.request import Request, RequestState

__all__ = ["pull_kv", "pull_kv_async", "push_reserve", "push_layer", "push_finish",
           "pull_state"]


def _allocate_decode_blocks(
    req: Request, decode_pool: BlockPool, preallocated: list[int] | None
) -> None:
    n = len(req.prefill_blocks)
    if preallocated is not None:
        if len(preallocated) != n:
            raise ValueError(f"need {n} preallocated blocks, got {len(preallocated)}")
        req.decode_blocks = preallocated
    else:
        req.decode_blocks = decode_pool.allocate(n)  # may raise OutOfBlocks


def _pull_txns(
    req: Request,
    conn: Connection,
    decode_cache: PagedKVCache,
    *,
    skip: frozenset[int] | set[int] | None = None,
) -> list[Txn]:
    """Layer-streamed transaction list: layer 0's reads first, every read
    tagged with its layer (per-layer completion lands on the future), a
    single COMPLETE at the tail.

    ``skip`` holds block POSITIONS (indices into ``prefill_blocks`` /
    ``decode_blocks``) a delta transfer plan grafts from blocks already
    resident decode-side — no read is emitted for them, in any layer.
    The COMPLETE still tails the plan: the prefill copy frees once the
    suffix lands (the skipped prefix never needed the prefill copy).

    When the request carries per-block quantization scales
    (``req.kv_scales[layer][position][plane]``, computed at prefill park
    time), each emitted read gets its ``qscale`` so the engine moves int8
    wire bytes and dequantizes with the carried scale."""
    skip = skip or frozenset()
    positions = [i for i in range(len(req.prefill_blocks)) if i not in skip]
    remote_blocks = [req.prefill_blocks[i] for i in positions]
    local_blocks = [req.decode_blocks[i] for i in positions]
    kv_scales = getattr(req, "kv_scales", None)
    txns: list[Txn] = []
    for layer in range(decode_cache.num_layers):
        if not remote_blocks:
            break  # fully resident: nothing to read, COMPLETE only
        remote = conn.desc(f"layer{layer}/kv")
        local = decode_cache.desc(layer)
        scales = None
        if kv_scales is not None:
            scales = [kv_scales[layer][i] for i in positions]
        txns.extend(
            build_block_reads(
                req.request_id, remote, local, remote_blocks,
                local_blocks, layer=layer, scales=scales,
            )
        )
    txns.append(
        CompleteTxn(
            request_id=req.request_id,
            src_worker=conn.prefill_worker,
            dst_worker=conn.decode_worker,
        )
    )
    return txns


def pull_kv(
    req: Request,
    *,
    conn: Connection,
    engine: TransferEngine,
    decode_pool: BlockPool,
    decode_cache: PagedKVCache,
    drain: bool = True,
    preallocated: list[int] | None = None,
) -> TransferStats:
    """Pull-mode transfer of a whole request: allocate decode blocks,
    TRANSFER() every layer's blocks, COMPLETE().

    Raises OutOfBlocks if the decode pool can't hold the request — the
    caller keeps the request in KV_QUEUED (prefill-side KV stays alive;
    the prefill worker is free to compute other requests meanwhile, which
    is exactly pull-mode's utilization win).  Callers that must fail
    BEFORE any request state changes pass ``preallocated`` blocks.
    """
    _allocate_decode_blocks(req, decode_pool, preallocated)
    req.connection_epoch = conn.epoch
    engine.submit(_pull_txns(req, conn, decode_cache))
    return engine.drain() if drain else engine.stats


def pull_kv_async(
    req: Request,
    *,
    conn: Connection,
    engine: TransferEngine,
    decode_pool: BlockPool,
    decode_cache: PagedKVCache,
    preallocated: list[int] | None = None,
    skip: frozenset[int] | set[int] | None = None,
) -> TransferFuture:
    """Non-blocking pull: same allocation contract and byte movement as
    ``pull_kv`` but nothing executes yet — the caller advances the
    transfer with ``engine.progress()`` (interleaved with decode compute)
    and observes completion through the returned future, per layer via
    ``future.layers_done`` and per request via ``future.done()``.

    ``skip`` (delta transfer): block positions already resident on the
    decode worker — grafted into ``decode_blocks`` by the caller, never
    read over the wire.  A fully-resident plan emits ONLY the COMPLETE;
    its future pre-marks every layer done so ``wait_layer`` consumers
    (layer-streamed decode) see the same contract as a real pull."""
    _allocate_decode_blocks(req, decode_pool, preallocated)
    req.connection_epoch = conn.epoch
    engine.submit(_pull_txns(req, conn, decode_cache, skip=skip))
    fut = engine.future(req.request_id)
    assert fut is not None  # just submitted, cannot have resolved
    if skip and len(skip) >= len(req.prefill_blocks):
        # zero reads queued: every layer's bytes are already resident
        fut._layers_done.extend(range(decode_cache.num_layers))
    return fut


def pull_state(
    req: Request,
    *,
    conn: Connection,
    engine: TransferEngine,
    decode_cache: SlotCache,
    remote_slot: int,
    local_slot: int,
    drain: bool = True,
) -> TransferStats:
    """SSM-state pull: one contiguous transaction per layer (degenerate
    best case of the tensor-centric design — see DESIGN.md §4)."""
    txns = []
    for layer in range(decode_cache.num_layers):
        remote = conn.desc(f"layer{layer}/state")
        local = decode_cache.desc(layer)
        txns.extend(
            build_block_reads(req.request_id, remote, local, [remote_slot],
                              [local_slot], layer=layer)
        )
    txns.append(
        CompleteTxn(
            request_id=req.request_id,
            src_worker=conn.prefill_worker,
            dst_worker=conn.decode_worker,
        )
    )
    engine.submit(txns)
    return engine.drain() if drain else engine.stats


# ----------------------------------------------------------------- push
def push_reserve(req: Request, decode_pool: BlockPool, num_blocks: int) -> None:
    """Push-mode step 1: pre-allocate ALL decode blocks at admission.
    This is the memory that sits idle for the whole prefill (Fig. 11a)."""
    req.decode_blocks = decode_pool.reserve(num_blocks)


def push_layer(
    req: Request,
    layer: int,
    *,
    conn: Connection,
    engine: TransferEngine,
    decode_cache: PagedKVCache,
    drain: bool = True,
) -> TransferStats:
    """Push-mode step 2: prefill pushes one finished layer.  On the wire
    this is the same byte movement (our engine copies src→dst); the
    difference is WHO initiates and WHEN memory is held."""
    remote = conn.desc(f"layer{layer}/kv")
    local = decode_cache.desc(layer)
    engine.submit(
        build_block_reads(req.request_id, remote, local, req.prefill_blocks, req.decode_blocks)
    )
    return engine.drain() if drain else engine.stats


def push_finish(
    req: Request,
    *,
    conn: Connection,
    engine: TransferEngine,
    decode_pool: BlockPool,
) -> TransferStats:
    """Push-mode step 3: all layers pushed; commit reservations and
    COMPLETE so the prefill side frees."""
    decode_pool.commit(req.decode_blocks)
    engine.submit(
        [
            CompleteTxn(
                request_id=req.request_id,
                src_worker=conn.prefill_worker,
                dst_worker=conn.decode_worker,
            )
        ]
    )
    return engine.drain()
