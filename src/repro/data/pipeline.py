"""Synthetic tokenized LM data pipeline — deterministic, resumable.

A real deployment swaps ``SyntheticLMDataset`` for a file-backed source;
the iterator state (epoch, step) is part of the training checkpoint so a
restart replays from the exact batch (fault tolerance).
"""
from __future__ import annotations

import dataclasses

import numpy as np

__all__ = ["SyntheticLMDataset"]


@dataclasses.dataclass
class SyntheticLMDataset:
    """Zipf-distributed token stream with long-range repetition structure
    (so the loss actually decreases when training)."""

    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    step: int = 0

    def state(self) -> dict:
        return {"seed": self.seed, "step": self.step}

    def restore(self, state: dict) -> None:
        self.seed = state["seed"]
        self.step = state["step"]

    def next_batch(self) -> dict:
        rng = np.random.default_rng((self.seed, self.step))
        self.step += 1
        # zipf-ish marginal + markov repetition: learnable structure
        base = rng.zipf(1.3, size=(self.batch_size, self.seq_len))
        tokens = np.minimum(base, self.vocab_size - 1).astype(np.int32)
        # inject copy structure: second half repeats first half shifted
        half = self.seq_len // 2
        tokens[:, half:] = np.roll(tokens[:, :half], -1, axis=1)
        return {"tokens": tokens}
