"""AdamW with optional bf16 moments — minimal, pjit-friendly.

The optimizer state is a pytree with the SAME structure (and therefore
the same sharding) as the parameters, so FSDP sharding of params
automatically shards the moments (ZeRO-style).  ``fp32_master`` keeps an
fp32 copy of bf16 params; the 400B-class configs turn it off so the
train state fits a single v5e pod (see configs/llama4_maverick...).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

__all__ = ["AdamWConfig", "adamw_init", "adamw_update", "cosine_schedule", "global_norm"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr_peak: float = 3e-4
    warmup_steps: int = 100
    total_steps: int = 1000
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    fp32_master: bool = True


def cosine_schedule(cfg: AdamWConfig, step):
    warm = jnp.minimum(step / jnp.maximum(cfg.warmup_steps, 1), 1.0)
    frac = jnp.clip((step - cfg.warmup_steps) / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0, 1.0)
    return cfg.lr_peak * warm * 0.5 * (1.0 + jnp.cos(jnp.pi * frac))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves))


def adamw_init(params, cfg: AdamWConfig) -> dict[str, Any]:
    mom_dtype = jnp.float32 if cfg.fp32_master else jnp.bfloat16
    zeros_like = lambda p: jnp.zeros(p.shape, mom_dtype)
    state = {
        "step": jnp.zeros((), jnp.int32),
        "m": jax.tree.map(zeros_like, params),
        "v": jax.tree.map(zeros_like, params),
    }
    if cfg.fp32_master:
        state["master"] = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    return state


def adamw_update(params, grads, state, cfg: AdamWConfig):
    step = state["step"] + 1
    lr = cosine_schedule(cfg, step)

    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    grads = jax.tree.map(lambda g: g.astype(jnp.float32) * scale, grads)

    b1c = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    new_m = jax.tree.map(lambda m, g: cfg.b1 * m.astype(jnp.float32) + (1 - cfg.b1) * g,
                         state["m"], grads)
    new_v = jax.tree.map(lambda v, g: cfg.b2 * v.astype(jnp.float32) + (1 - cfg.b2) * g * g,
                         state["v"], grads)

    base = state["master"] if cfg.fp32_master else params

    def upd(p, m, v):
        u = (m / b1c) / (jnp.sqrt(v / b2c) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return p.astype(jnp.float32) - lr * u

    new_base = jax.tree.map(upd, base, new_m, new_v)
    mom_dtype = jnp.float32 if cfg.fp32_master else jnp.bfloat16
    new_state = {
        "step": step,
        "m": jax.tree.map(lambda m: m.astype(mom_dtype), new_m),
        "v": jax.tree.map(lambda v: v.astype(mom_dtype), new_v),
    }
    if cfg.fp32_master:
        new_state["master"] = new_base
    new_params = jax.tree.map(lambda p, b: b.astype(p.dtype), params, new_base)
    return new_params, new_state, {"grad_norm": gnorm, "lr": lr}
