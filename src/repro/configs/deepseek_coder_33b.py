"""deepseek-coder-33b — dense llama-arch code model [arXiv:2401.14196;
hf:deepseek-ai/deepseek-coder-33b-base].

62L d_model=7168 56H (GQA kv=8) d_ff=19200 vocab=32256.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-coder-33b",
    family="dense",
    num_layers=62,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    d_ff=19200,
    vocab_size=32256,
)

SMOKE = ModelConfig(
    name="deepseek-coder-33b-smoke",
    family="dense",
    num_layers=2,
    d_model=56,          # keeps the 56-head:8-kv ratio shape-odd like the parent
    num_heads=7,
    num_kv_heads=1,
    d_ff=160,
    vocab_size=512,
    head_dim=8,
)
