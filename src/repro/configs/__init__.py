"""Architecture registry — ``--arch <id>`` resolution.

Every assigned architecture (plus the paper's own evaluation model) is a
module here exposing ``CONFIG`` (exact public dims) and ``SMOKE``
(reduced same-family config for CPU tests).
"""
from __future__ import annotations

import importlib

from repro.models.config import ModelConfig

# arch id (CLI spelling) -> module name
ARCHS: dict[str, str] = {
    "granite-34b": "granite_34b",
    "deepseek-67b": "deepseek_67b",
    "deepseek-coder-33b": "deepseek_coder_33b",
    "yi-9b": "yi_9b",
    "whisper-large-v3": "whisper_large_v3",
    "granite-moe-3b-a800m": "granite_moe_3b_a800m",
    "llama4-maverick-400b-a17b": "llama4_maverick_400b_a17b",
    "llava-next-mistral-7b": "llava_next_mistral_7b",
    "mamba2-780m": "mamba2_780m",
    "hymba-1.5b": "hymba_1p5b",
    # the paper's own model (not an assigned cell; used by benchmarks)
    "mistral-large-123b": "mistral_large_123b",
}

ASSIGNED = [a for a in ARCHS if a != "mistral-large-123b"]


def _module(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {', '.join(ARCHS)}")
    return importlib.import_module(f"repro.configs.{ARCHS[arch]}")


def get_config(arch: str) -> ModelConfig:
    return _module(arch).CONFIG


def get_smoke_config(arch: str) -> ModelConfig:
    return _module(arch).SMOKE


def list_archs() -> list[str]:
    return list(ARCHS)
