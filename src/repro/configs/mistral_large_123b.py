"""Mistral-Large-Instruct-2407 (123B) — the PAPER's evaluation model
(§5.1): dense GQA with 8 KV heads, randomized weights.

Public dims: 88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768.
KV per token = 2·8·128·2B·88L = 352 KB — exactly the paper's stated
"352 KB of memory for the KV cache [per token]".  Used by the
paper-faithful benchmarks (Fig. 13-17 reproductions).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mistral-large-123b",
    family="dense",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=32768,
)

SMOKE = ModelConfig(
    name="mistral-large-123b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
)
