"""granite-moe-3b-a800m — MoE [hf:ibm-granite/granite-3.0-3b-a800m-base family].

32L d_model=1536 24H (GQA kv=8) d_ff=512/expert vocab=49155, 40 experts
top-8.  Every layer is MoE.  Experts padded 40→48 for even sharding over
the 16-way data axis (padded experts get -inf router logits; asserted
unreachable in tests).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-moe-3b-a800m",
    family="moe",
    num_layers=32,
    d_model=1536,
    num_heads=24,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49155,
    num_experts=40,
    experts_per_token=8,
    fold_model_axis_into_dp=True,  # DP+EP deployment; see ModelConfig
)

SMOKE = ModelConfig(
    name="granite-moe-3b-a800m-smoke",
    family="moe",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=64,
    vocab_size=512,
    num_experts=5,        # deliberately non-multiple-of-16 like the parent's 40
    experts_per_token=2,
)
