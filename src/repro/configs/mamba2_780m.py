"""mamba2-780m — attention-free SSD [arXiv:2405.21060; hf:state-spaces/mamba2-780m].

48L d_model=1536 vocab=50280, ssm_state=128.  expand=2 ⇒ d_inner=3072,
head_dim=64 ⇒ 48 SSD heads, conv kernel 4, tied embeddings (that's the
780M total).  No attention ⇒ the transferable decode state is the fixed
size (ssd_state, conv_tail) pair per layer — KVDirect's degenerate best
case (one contiguous read per layer), and long_500k RUNS (O(1) state).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    num_layers=48,
    d_model=1536,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="mamba2-780m-smoke",
    family="ssm",
    num_layers=2,
    d_model=64,
    vocab_size=512,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_conv=4,
    tie_embeddings=True,
)
