"""whisper-large-v3 — encoder-decoder audio backbone [arXiv:2212.04356].

32L d_model=1280 20H (kv=20, MHA) d_ff=5120 vocab=51866.  32 encoder +
32 decoder layers; the conv/mel frontend is a STUB per the assignment —
input_specs() provides precomputed frame embeddings [b, 1500, d].
GELU MLP + LayerNorm + biases, per the original architecture.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="audio",
    num_layers=32,           # decoder layers
    encoder_layers=32,
    encoder_seq=1500,
    d_model=1280,
    num_heads=20,
    num_kv_heads=20,
    d_ff=5120,
    vocab_size=51866,
    mlp_type="gelu",
    is_encoder_decoder=True,
    tie_embeddings=True,
    max_positions=65536,     # sized for the assigned decode_32k cell
)

SMOKE = ModelConfig(
    name="whisper-large-v3-smoke",
    family="audio",
    num_layers=2,
    encoder_layers=2,
    encoder_seq=64,
    d_model=64,
    num_heads=4,
    num_kv_heads=4,
    d_ff=128,
    vocab_size=512,
    mlp_type="gelu",
    is_encoder_decoder=True,
    tie_embeddings=True,
    max_positions=256,
)
