"""hymba-1.5b — hybrid parallel attention+SSM heads [arXiv:2411.13676;
hf:nvidia/Hymba-1.5B-Base].

32L d_model=1600 25H (GQA kv=5) d_ff=5504 vocab=32001, ssm_state=16.
Each layer runs attention and a Mamba-2 SSD head bank IN PARALLEL on the
same input and fuses the branch outputs (per-branch RMSNorm, mean), as
in the paper.  Sliding-window attention (1024) + 128 learnable meta
tokens (always visible) keep decode state O(1) ⇒ long_500k RUNS.
head_dim=64 (1600/25); SSM: expand=2 ⇒ d_inner=3200, 50 SSD heads.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    sliding_window=1024,
    num_meta_tokens=128,
    tie_embeddings=True,
)

SMOKE = ModelConfig(
    name="hymba-1.5b-smoke",
    family="hybrid",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    ssm_state=8,
    ssm_expand=2,
    ssm_head_dim=16,
    ssm_conv=4,
    sliding_window=32,
    num_meta_tokens=8,
    tie_embeddings=True,
)
