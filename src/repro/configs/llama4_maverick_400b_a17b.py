"""llama4-maverick-400b-a17b — interleaved MoE, early fusion
[hf:meta-llama/Llama-4-Maverick-17B-128E].

48L d_model=5120 40H (GQA kv=8) vocab=202048, MoE 128 experts top-1.
Llama-4 interleaves: every 2nd layer is MoE (128 routed experts top-1 +
one always-on shared expert, d_ff=8192); the other layers are dense with
d_ff=16384.  That interleave is exactly what makes the listed dims total
~400B with ~17B active — all-MoE at these dims would be ~780B.
bf16 optimizer moments (fp32_master=False) so the train_4k state fits a
v5e pod (see DESIGN.md).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    d_ff=8192,              # per-expert width
    d_ff_dense=16384,       # interleaved dense layers
    vocab_size=202048,
    num_experts=128,
    experts_per_token=1,
    moe_shared_expert=True,
    moe_every=2,
    fp32_master=False,
)

SMOKE = ModelConfig(
    name="llama4-maverick-smoke",
    family="moe",
    num_layers=4,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=64,
    d_ff_dense=128,
    vocab_size=512,
    num_experts=8,
    experts_per_token=1,
    moe_shared_expert=True,
    moe_every=2,
)
