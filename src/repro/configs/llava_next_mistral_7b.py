"""llava-next-mistral-7b — VLM, anyres tiling [hf:llava-hf/llava-v1.6-mistral-7b-hf].

Mistral-7B backbone: 32L d_model=4096 32H (GQA kv=8) d_ff=14336
vocab=32000.  The vision tower + anyres tiling is a STUB per the
assignment: input_specs() provides precomputed patch embeddings
[b, 2880, d] (5 tiles × 576 patches — the anyres 2×2+base grid), which
the model early-fuses ahead of the text tokens.  Long multimodal
prompts make VLM serving a best case for KVDirect (image-token KV
dominates the transfer).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=32000,
    vision_tokens=2880,     # 5 anyres tiles x 576 patches
)

SMOKE = ModelConfig(
    name="llava-next-mistral-7b-smoke",
    family="vlm",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=2,
    d_ff=128,
    vocab_size=512,
    vision_tokens=32,
)
