"""deepseek-67b — dense llama-arch [arXiv:2401.02954; hf:deepseek-ai/deepseek-llm-67b-base].

95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400.  SwiGLU/RMSNorm/RoPE.
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    d_ff=22016,
    vocab_size=102400,
)

SMOKE = ModelConfig(
    name="deepseek-67b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=8,
    num_kv_heads=2,
    d_ff=192,
    vocab_size=512,
)
