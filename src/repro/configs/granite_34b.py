"""granite-34b — dense code LLM [arXiv:2405.04324; hf:ibm-granite/granite-34b-code-base].

88L d_model=6144 48H (GQA kv=1, i.e. MQA) d_ff=24576 vocab=49152.
GPT-BigCode lineage ⇒ 2-matrix GELU MLP (that is what makes the listed
dims total ~34B; a SwiGLU MLP at d_ff=24576 would be ~48B).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-34b",
    family="dense",
    num_layers=88,
    d_model=6144,
    num_heads=48,
    num_kv_heads=1,
    d_ff=24576,
    vocab_size=49152,
    mlp_type="gelu",
)

SMOKE = ModelConfig(
    name="granite-34b-smoke",
    family="dense",
    num_layers=2,
    d_model=64,
    num_heads=4,
    num_kv_heads=1,
    d_ff=256,
    vocab_size=512,
    mlp_type="gelu",
)
