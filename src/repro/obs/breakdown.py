"""Per-request latency breakdown — the live Fig. 14.

The serving path records each request's lifecycle as a gap-free phase
partition on its tracer track (``("request", rid)``, see the phase
machine in obs/trace.py):

    queue → prefill → queue.kv → transfer → decode
      (failover may cycle back through queue/queue.kv)

This module folds those spans into the paper's Fig. 14 components —
queue / prefill / transfer / decode — whose sum IS the request's
time-to-last-token: the phases share boundary timestamps, so the
decomposition is exact, not approximate.  ``fig14_breakdown.py`` uses it
to cross-check the live substrate against the discrete-event simulator,
and the same function works on a sim-produced tracer because both sides
share one span schema.

``spans_from_timeline`` is the bridge for code that records coarse
timestamps instead of spans (the simulator's ``Request`` timeline
fields): it re-emits the same phase schema onto a tracer, so every
consumer — Chrome export, breakdown, tests — sees one format.

Layerwise note: under ``consume="layerwise"`` a request's first decode
step overlaps the tail of its pull; the phase machine attributes the
overlap to *transfer* (the transfer phase ends at promotion, which for
a streamed join is when its first step completes), so components still
partition wall time — the per-layer ``transfer.layer`` sub-spans keep
the true wire timeline visible.
"""
from __future__ import annotations

import dataclasses

from repro.obs.trace import Tracer

__all__ = ["PHASE_CATEGORY", "RequestBreakdown", "request_breakdown",
           "all_request_breakdowns", "mean_fractions", "spans_from_timeline"]

# Phase-span name -> Fig. 14 component.  Names outside this map (e.g.
# the engine's per-layer "transfer.layer" sub-spans) are informational
# overlays, not partition members, and are excluded from the sums.
PHASE_CATEGORY: dict[str, str] = {
    "queue": "queue_s",
    "queue.kv": "queue_s",       # prefill done, waiting for decode admission
    "queue.decode": "queue_s",   # admitted, waiting for a decode slot
    "prefill": "prefill_s",
    "transfer": "transfer_s",
    "decode": "decode_s",
}
COMPONENTS = ("queue_s", "prefill_s", "transfer_s", "decode_s")


@dataclasses.dataclass
class RequestBreakdown:
    """One request's Fig. 14 decomposition (seconds on the trace clock)."""

    request_id: str
    queue_s: float = 0.0
    prefill_s: float = 0.0
    transfer_s: float = 0.0
    decode_s: float = 0.0
    ttlt_s: float = 0.0          # first phase start -> last phase end
    n_spans: int = 0
    n_layer_spans: int = 0       # per-layer transfer sub-spans observed

    def components(self) -> dict[str, float]:
        return {k: getattr(self, k) for k in COMPONENTS}

    @property
    def total_s(self) -> float:
        return sum(self.components().values())

    def fractions(self) -> dict[str, float]:
        tot = max(self.total_s, 1e-12)
        return {k: v / tot for k, v in self.components().items()}


def request_breakdown(tracer: Tracer, request_id: str) -> RequestBreakdown:
    """Fold the closed phase spans of one request's track into Fig. 14
    components.  Because consecutive phases share boundary timestamps,
    ``total_s == ttlt_s`` exactly (up to float addition error)."""
    track = ("request", request_id)
    out = RequestBreakdown(request_id)
    t_lo: float | None = None
    t_hi: float | None = None
    for s in tracer.spans_of(track):
        if s.t1 is None:
            continue
        if s.name.startswith("transfer.layer"):
            out.n_layer_spans += 1
            continue
        cat = PHASE_CATEGORY.get(s.name)
        if cat is None:
            continue
        setattr(out, cat, getattr(out, cat) + (s.t1 - s.t0))
        out.n_spans += 1
        t_lo = s.t0 if t_lo is None else min(t_lo, s.t0)
        t_hi = s.t1 if t_hi is None else max(t_hi, s.t1)
    if t_lo is not None and t_hi is not None:
        out.ttlt_s = t_hi - t_lo
    return out


def all_request_breakdowns(tracer: Tracer) -> dict[str, RequestBreakdown]:
    """Breakdowns for every request track with at least one closed span."""
    rids: dict[str, None] = {}
    for s in tracer.spans:
        if isinstance(s.track, tuple) and len(s.track) == 2 \
                and s.track[0] == "request":
            rids.setdefault(s.track[1])
    return {rid: request_breakdown(tracer, rid) for rid in rids}


def mean_fractions(breakdowns) -> dict[str, float]:
    """Mean per-component fraction across requests — the Fig. 14 bar."""
    items = list(breakdowns.values() if isinstance(breakdowns, dict)
                 else breakdowns)
    items = [b for b in items if b.total_s > 0]
    if not items:
        return {k: 0.0 for k in COMPONENTS}
    acc = {k: 0.0 for k in COMPONENTS}
    for b in items:
        for k, v in b.fractions().items():
            acc[k] += v
    return {k: v / len(items) for k, v in acc.items()}


def spans_from_timeline(tracer: Tracer, req) -> None:
    """Emit the standard request phase spans from a ``Request``'s coarse
    timeline fields (arrival/prefill/transfer/decode timestamps) — the
    simulator's records rendered into the live schema, so sim and real
    traces are directly comparable (and Chrome-exportable) side by side.
    """
    track = ("request", req.request_id)
    pairs = [
        ("queue", req.arrival_s, req.prefill_start_s),
        ("prefill", req.prefill_start_s, req.prefill_end_s),
        ("queue.kv", req.prefill_end_s, req.transfer_start_s),
        ("transfer", req.transfer_start_s, req.transfer_end_s),
        ("queue.decode", req.transfer_end_s, req.decode_start_s),
        ("decode", req.decode_start_s, req.done_s),
    ]
    for name, t0, t1 in pairs:
        if t0 is None or t1 is None:
            continue
        tracer.complete(name, track, t0, max(t0, t1))
