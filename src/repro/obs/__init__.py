"""``repro.obs`` — unified tracing + metrics for the serving substrate.

One span tracer (injectable clock, near-zero disabled path, Chrome
trace-event export), one metrics registry (counters / gauges / windowed
histograms), a per-request Fig.-14 breakdown computed from spans, and
the ``BENCH_*.json`` per-PR benchmark trajectory.  See
docs/observability.md for the contract.
"""
from repro.obs.bench import (
    BENCH_SCHEMA_VERSION,
    BenchEntry,
    BenchTrajectory,
    bench_path,
    load_trajectory,
    validate_bench,
)
from repro.obs.breakdown import (
    PHASE_CATEGORY,
    RequestBreakdown,
    all_request_breakdowns,
    mean_fractions,
    request_breakdown,
    spans_from_timeline,
)
from repro.obs.metrics import Counter, Gauge, Histogram, MetricsRegistry
from repro.obs.trace import NULL_TRACER, Span, Tracer, track_name

__all__ = [
    "BENCH_SCHEMA_VERSION",
    "BenchEntry",
    "BenchTrajectory",
    "bench_path",
    "load_trajectory",
    "validate_bench",
    "PHASE_CATEGORY",
    "RequestBreakdown",
    "all_request_breakdowns",
    "mean_fractions",
    "request_breakdown",
    "spans_from_timeline",
    "Counter",
    "Gauge",
    "Histogram",
    "MetricsRegistry",
    "NULL_TRACER",
    "Span",
    "Tracer",
    "track_name",
]
