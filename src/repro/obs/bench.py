"""``BENCH_*.json`` — the per-PR benchmark trajectory.

ROADMAP asks for kernel_bench / roofline / figure-benchmark outputs to
land in a schema-versioned artifact per PR so speed regressions are
visible ACROSS PRs: ``BENCH_6.json`` is PR 6's point, PR 7 writes
``BENCH_7.json`` with the same schema, and ``load_trajectory()`` reads
the whole series back ordered by PR number.

Writers: ``benchmarks/run.py --json`` (every figure module's rows, incl.
kernel_bench), and ``benchmarks/roofline.py --bench-out`` (per-cell
roofline terms, when dry-run artifacts exist).  Both go through
``BenchTrajectory`` so the schema has one owner.

Schema (version 1)::

    {
      "schema_version": 1,
      "pr": 6,
      "source": "benchmarks.run",
      "created_unix_s": 1754700000.0,
      "entries": [
        {"name": "fig14/arxiv/qps0.5", "value": 29358808.0, "unit": "us",
         "attrs": {"derived": "transfer_frac=0.0233;..."}},
        ...
      ]
    }

``validate_bench`` is the single checker CI's bench-smoke job and the
tests call; it raises ``ValueError`` naming the first offending field.
"""
from __future__ import annotations

import dataclasses
import json
import pathlib
import re
import time
from typing import Any, Iterable

__all__ = ["BENCH_SCHEMA_VERSION", "BenchEntry", "BenchTrajectory",
           "bench_path", "validate_bench", "load_trajectory"]

BENCH_SCHEMA_VERSION = 1
_BENCH_RE = re.compile(r"^BENCH_(\d+)\.json$")


def bench_path(pr: int, root: str = ".") -> pathlib.Path:
    """The repo-root artifact path for one PR's benchmark point."""
    return pathlib.Path(root) / f"BENCH_{pr}.json"


@dataclasses.dataclass
class BenchEntry:
    name: str
    value: float
    unit: str = "us"
    attrs: dict[str, Any] = dataclasses.field(default_factory=dict)

    def to_json(self) -> dict:
        return {"name": self.name, "value": float(self.value),
                "unit": self.unit, "attrs": self.attrs}


class BenchTrajectory:
    """Accumulates benchmark entries and writes one PR's schema-versioned
    ``BENCH_<pr>.json``."""

    def __init__(self, pr: int, *, source: str = "benchmarks.run") -> None:
        self.pr = pr
        self.source = source
        self.entries: list[BenchEntry] = []

    def add(self, name: str, value: float, *, unit: str = "us",
            **attrs) -> BenchEntry:
        e = BenchEntry(name, float(value), unit, dict(attrs))
        self.entries.append(e)
        return e

    def extend_rows(self, rows: Iterable) -> None:
        """Ingest ``benchmarks.common.Row`` objects (name, us_per_call,
        derived) — the figure modules' native output."""
        for r in rows:
            self.add(r.name, r.us_per_call, unit="us", derived=r.derived)

    def to_json(self) -> dict:
        return {
            "schema_version": BENCH_SCHEMA_VERSION,
            "pr": self.pr,
            "source": self.source,
            "created_unix_s": time.time(),
            "entries": [e.to_json() for e in self.entries],
        }

    def write(self, path: str | pathlib.Path | None = None, *,
              merge: bool = True) -> pathlib.Path:
        """Write the artifact.  With ``merge=True`` (default) an existing
        file at ``path`` from the SAME pr/schema keeps its entries whose
        names this run didn't produce — so ``run.py --json`` and
        ``roofline.py --bench-out`` can both feed one file without
        clobbering each other."""
        p = pathlib.Path(path) if path is not None else bench_path(self.pr)
        doc = self.to_json()
        if merge and p.exists():
            try:
                old = json.loads(p.read_text())
                validate_bench(old)
            except (ValueError, json.JSONDecodeError):
                old = None
            if old is not None and old.get("pr") == self.pr:
                mine = {e["name"] for e in doc["entries"]}
                doc["entries"].extend(
                    e for e in old["entries"] if e["name"] not in mine)
                if old.get("source") and old["source"] != self.source:
                    doc["source"] = f"{old['source']}+{self.source}"
        p.write_text(json.dumps(doc, indent=2) + "\n")
        return p


def validate_bench(doc: dict) -> dict:
    """Validate a BENCH_*.json document; raises ``ValueError`` on the
    first schema violation, returns the document unchanged otherwise."""
    if not isinstance(doc, dict):
        raise ValueError(f"bench document must be an object, got {type(doc).__name__}")
    ver = doc.get("schema_version")
    if ver != BENCH_SCHEMA_VERSION:
        raise ValueError(
            f"schema_version must be {BENCH_SCHEMA_VERSION}, got {ver!r}")
    if not isinstance(doc.get("pr"), int):
        raise ValueError(f"pr must be an int, got {doc.get('pr')!r}")
    if not isinstance(doc.get("source"), str) or not doc["source"]:
        raise ValueError(f"source must be a non-empty string, got {doc.get('source')!r}")
    if not isinstance(doc.get("created_unix_s"), (int, float)):
        raise ValueError("created_unix_s must be a number")
    entries = doc.get("entries")
    if not isinstance(entries, list) or not entries:
        raise ValueError("entries must be a non-empty list")
    for i, e in enumerate(entries):
        if not isinstance(e, dict):
            raise ValueError(f"entries[{i}] must be an object")
        if not isinstance(e.get("name"), str) or not e["name"]:
            raise ValueError(f"entries[{i}].name must be a non-empty string")
        if not isinstance(e.get("value"), (int, float)):
            raise ValueError(f"entries[{i}].value must be a number "
                             f"({e.get('name')})")
        if not isinstance(e.get("unit"), str) or not e["unit"]:
            raise ValueError(f"entries[{i}].unit must be a non-empty string")
        if not isinstance(e.get("attrs"), dict):
            raise ValueError(f"entries[{i}].attrs must be an object")
    return doc


def load_trajectory(root: str = ".") -> list[dict]:
    """Every valid BENCH_*.json under ``root``, ordered by PR number —
    the regression trajectory a reviewer (or a future chaos/perf PR)
    reads to see where a number moved."""
    points = []
    for p in pathlib.Path(root).glob("BENCH_*.json"):
        m = _BENCH_RE.match(p.name)
        if not m:
            continue
        try:
            doc = validate_bench(json.loads(p.read_text()))
        except (ValueError, json.JSONDecodeError):
            continue
        points.append((int(m.group(1)), doc))
    return [doc for _, doc in sorted(points, key=lambda x: x[0])]
