"""Metrics registry — counters, gauges, and windowed histograms.

The aggregate companion to the span tracer (obs/trace.py): spans answer
"what did THIS request's timeline look like", the registry answers "what
has the system been doing lately" — dispatch/admission/token counts per
serve-loop phase, engine bytes and teardown totals, router decisions and
hedge outcomes, and latency distributions (TTFT/TTLT/TBT) over a sliding
window with p50/p90/p99.

Everything is in-process and allocation-light: a counter is one float, a
histogram is one bounded deque.  ``snapshot()`` renders the whole
registry to plain dicts for printing (launch/serve.py's end-of-run
report), for the stall forensics attached to ``ServeLoopStalled``, and
for tests.
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Iterable

__all__ = ["Counter", "Gauge", "Histogram", "MetricsRegistry"]


@dataclasses.dataclass
class Counter:
    """Monotonic accumulator (events, bytes, retries)."""

    name: str
    value: float = 0.0

    def inc(self, n: float = 1.0) -> None:
        self.value += n


@dataclasses.dataclass
class Gauge:
    """Last-write-wins instantaneous value (queue depth, free blocks)."""

    name: str
    value: float = 0.0

    def set(self, v: float) -> None:
        self.value = float(v)


class Histogram:
    """Windowed distribution: the last ``window`` observations plus
    all-time count/total.  Percentiles use the nearest-rank method over
    the window — deterministic, no interpolation."""

    __slots__ = ("name", "window", "count", "total")

    def __init__(self, name: str, window: int = 1024) -> None:
        self.name = name
        self.window: collections.deque[float] = collections.deque(maxlen=window)
        self.count = 0
        self.total = 0.0

    def observe(self, v: float) -> None:
        self.window.append(float(v))
        self.count += 1
        self.total += v

    @property
    def mean(self) -> float:
        return self.total / self.count if self.count else 0.0

    def percentile(self, q: float) -> float:
        """Nearest-rank percentile over the current window (q in 0..100)."""
        if not self.window:
            return 0.0
        vals = sorted(self.window)
        rank = max(1, -(-len(vals) * q // 100))  # ceil(n*q/100), min 1
        return vals[min(len(vals), int(rank)) - 1]

    def summary(self) -> dict[str, float]:
        return {
            "count": self.count,
            "mean": self.mean,
            "p50": self.percentile(50),
            "p90": self.percentile(90),
            "p99": self.percentile(99),
            "max": max(self.window) if self.window else 0.0,
        }


class MetricsRegistry:
    """Get-or-create registry of named instruments.

    Instruments are created on first touch so call sites never need
    registration boilerplate; the convenience forms (``inc`` /
    ``set_gauge`` / ``observe``) are what the serving path uses inline.
    """

    def __init__(self, *, histogram_window: int = 1024) -> None:
        self.histogram_window = histogram_window
        self._counters: dict[str, Counter] = {}
        self._gauges: dict[str, Gauge] = {}
        self._histograms: dict[str, Histogram] = {}

    # --------------------------------------------------------- creation
    def counter(self, name: str) -> Counter:
        c = self._counters.get(name)
        if c is None:
            c = self._counters[name] = Counter(name)
        return c

    def gauge(self, name: str) -> Gauge:
        g = self._gauges.get(name)
        if g is None:
            g = self._gauges[name] = Gauge(name)
        return g

    def histogram(self, name: str, *, window: int | None = None) -> Histogram:
        h = self._histograms.get(name)
        if h is None:
            h = self._histograms[name] = Histogram(
                name, window or self.histogram_window)
        return h

    # ------------------------------------------------------ convenience
    def inc(self, name: str, n: float = 1.0) -> None:
        self.counter(name).inc(n)

    def set_gauge(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).observe(v)

    # ----------------------------------------------------------- export
    def counters(self, prefix: str = "") -> dict[str, float]:
        return {n: c.value for n, c in sorted(self._counters.items())
                if n.startswith(prefix)}

    def snapshot(self) -> dict[str, dict]:
        return {
            "counters": {n: c.value for n, c in sorted(self._counters.items())},
            "gauges": {n: g.value for n, g in sorted(self._gauges.items())},
            "histograms": {n: h.summary()
                           for n, h in sorted(self._histograms.items())},
        }

    def format(self, *, prefixes: Iterable[str] = ()) -> str:
        """Human-readable one-metric-per-line rendering (optionally
        restricted to name prefixes) — what launch/serve.py prints."""
        pre = tuple(prefixes)

        def keep(name: str) -> bool:
            return not pre or any(name.startswith(p) for p in pre)

        lines = []
        for n, c in sorted(self._counters.items()):
            if keep(n):
                v = int(c.value) if c.value == int(c.value) else c.value
                lines.append(f"{n} = {v}")
        for n, g in sorted(self._gauges.items()):
            if keep(n):
                lines.append(f"{n} = {g.value:g}")
        for n, h in sorted(self._histograms.items()):
            if keep(n):
                s = h.summary()
                lines.append(
                    f"{n}: n={s['count']} mean={s['mean']:.6f} "
                    f"p50={s['p50']:.6f} p90={s['p90']:.6f} p99={s['p99']:.6f}")
        return "\n".join(lines)
