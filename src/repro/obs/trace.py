"""Zero-dependency span tracer — one timing schema for sim and real runs.

The serving stack's timing claims are TIMELINE claims (the paper's Fig. 3
message timeline, Fig. 14's "transfer is 1.1 %/0.5 % of end-to-end
latency"), so the substrate records them as *spans*: named intervals on
named *tracks*, taken from ONE injectable clock.  A real run passes
``time.perf_counter``; the simulator passes its virtual clock; both
produce byte-identical schemas, so every downstream consumer (the
Chrome-trace exporter, the per-request breakdown, the stall forensics)
works on either without knowing which produced it.

Three primitives cover every call site:

* ``span(name, track=..., **attrs)`` — a context manager for scoped
  work (the serving loop's per-tick phases);
* ``phase(track, name, **attrs)`` — a *phase machine* per track: ends
  the track's open span and begins the next at the same timestamp, so a
  request's lifecycle (queue → prefill → queue.kv → transfer → decode)
  is a gap-free partition of its wall time — which is what lets the
  breakdown components sum EXACTLY to TTLT (obs/breakdown.py);
* ``complete(name, track, t0, t1)`` / ``instant(name, ...)`` — record
  an already-measured interval (the engine's per-layer transfer spans)
  or a point event (COMPLETE executed, connection torn).

Disabled mode (``Tracer(enabled=False)``, or the shared ``NULL_TRACER``)
is the hot-path default: every primitive returns immediately after one
attribute check, no allocation, no clock read — tests bound the overhead
at <5 % of a short serve-loop run.

``export_chrome()`` writes the standard Chrome trace-event JSON (load it
at ``chrome://tracing`` or https://ui.perfetto.dev): one process, one
named thread per track, "X" complete events with microsecond timestamps
— any serve run becomes a browsable timeline, the live analogue of the
paper's Fig. 3.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Callable, Iterable

__all__ = ["Span", "Tracer", "NULL_TRACER", "track_name"]

Clock = Callable[[], float]
Track = "tuple[str, ...] | str"


def track_name(track) -> str:
    """Canonical string form of a track key ("request/r0")."""
    if isinstance(track, tuple):
        return "/".join(str(p) for p in track)
    return str(track)


@dataclasses.dataclass
class Span:
    """One named interval on a track.  ``end()`` (or the context-manager
    exit) closes it; a still-open span has ``t1 is None``."""

    name: str
    track: Any
    t0: float
    t1: float | None = None
    attrs: dict[str, Any] = dataclasses.field(default_factory=dict)
    depth: int = 0              # context-manager nesting depth on this track
    _tracer: "Tracer | None" = dataclasses.field(default=None, repr=False)

    @property
    def duration_s(self) -> float | None:
        return None if self.t1 is None else self.t1 - self.t0

    def set(self, **attrs) -> "Span":
        self.attrs.update(attrs)
        return self

    def end(self, ts: float | None = None) -> "Span":
        if self.t1 is None and self._tracer is not None:
            self._tracer._end(self, ts)
        return self

    def __enter__(self) -> "Span":
        return self

    def __exit__(self, *exc) -> None:
        self.end()


class _NullSpan:
    """Shared no-op span: the disabled tracer hands out one instance."""

    __slots__ = ()
    name = ""
    track = ""
    t0 = 0.0
    t1 = 0.0
    attrs: dict = {}
    duration_s = 0.0

    def set(self, **attrs) -> "_NullSpan":
        return self

    def end(self, ts=None) -> "_NullSpan":
        return self

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, *exc) -> None:
        pass


_NULL_SPAN = _NullSpan()


class Tracer:
    """Span recorder with an injectable clock and a near-zero disabled
    path.

    ``clock`` is any zero-arg callable returning seconds (monotonic or
    virtual); every timestamp the tracer — and anything sharing its
    clock — records comes from it, so spans from a sim run and a real
    run differ only in their numbers, never in their schema.
    """

    def __init__(self, *, clock: Clock | None = None, enabled: bool = True) -> None:
        self.enabled = enabled
        self.clock: Clock = clock or time.perf_counter
        self.spans: list[Span] = []          # closed, in end order
        self.instants: list[Span] = []       # point events (t1 == t0)
        self._open_phase: dict[Any, Span] = {}   # track -> open phase span
        self._stack: dict[Any, list[Span]] = {}  # track -> open scoped spans

    # ------------------------------------------------------------- clock
    def now(self) -> float:
        return self.clock()

    # ----------------------------------------------------- scoped spans
    def span(self, name: str, *, track="main", ts: float | None = None,
             **attrs) -> "Span | _NullSpan":
        """Begin a scoped span (use as a context manager).  Scoped spans
        nest: a span opened while another is open on the same track
        records the deeper ``depth``."""
        if not self.enabled:
            return _NULL_SPAN
        stack = self._stack.setdefault(track, [])
        s = Span(name, track, self.clock() if ts is None else ts,
                 attrs=dict(attrs), depth=len(stack), _tracer=self)
        stack.append(s)
        return s

    def _end(self, s: Span, ts: float | None = None) -> None:
        s.t1 = self.clock() if ts is None else ts
        stack = self._stack.get(s.track)
        if stack and s in stack:
            stack.remove(s)
        self.spans.append(s)

    # ----------------------------------------------------- phase machine
    def phase(self, track, name: str, *, ts: float | None = None,
              **attrs) -> "Span | _NullSpan":
        """End the open phase span on ``track`` (if any) and begin the
        next one at the SAME timestamp — consecutive phases share their
        boundary, so a track's phases partition its wall time with no
        gaps and no overlaps."""
        if not self.enabled:
            return _NULL_SPAN
        t = self.clock() if ts is None else ts
        prev = self._open_phase.pop(track, None)
        if prev is not None:
            prev.t1 = t
            self.spans.append(prev)
        s = Span(name, track, t, attrs=dict(attrs), _tracer=self)
        self._open_phase[track] = s
        return s

    def end_phase(self, track, *, ts: float | None = None, **attrs) -> "Span | None":
        """Close the open phase span on ``track`` (no-op when none)."""
        if not self.enabled:
            return None
        prev = self._open_phase.pop(track, None)
        if prev is None:
            return None
        prev.t1 = self.clock() if ts is None else ts
        prev.attrs.update(attrs)
        self.spans.append(prev)
        return prev

    def open_phase(self, track) -> Span | None:
        return self._open_phase.get(track)

    # ------------------------------------------------- direct recording
    def complete(self, name: str, track, t0: float, t1: float, **attrs) -> None:
        """Record an already-measured interval (e.g. a per-layer transfer
        span computed from the engine's own bookkeeping)."""
        if not self.enabled:
            return
        self.spans.append(Span(name, track, t0, t1, attrs=dict(attrs)))

    def instant(self, name: str, *, track="main", ts: float | None = None,
                **attrs) -> None:
        """Record a point event (COMPLETE executed, connection torn)."""
        if not self.enabled:
            return
        t = self.clock() if ts is None else ts
        self.instants.append(Span(name, track, t, t, attrs=dict(attrs)))

    # ------------------------------------------------------------ access
    def spans_of(self, track) -> list[Span]:
        """Closed spans on ``track``, ordered by start time."""
        return sorted((s for s in self.spans if s.track == track),
                      key=lambda s: (s.t0, s.depth))

    def tracks(self) -> list[Any]:
        seen: dict[Any, None] = {}
        for s in self.spans:
            seen.setdefault(s.track)
        for s in self.instants:
            seen.setdefault(s.track)
        return list(seen)

    def clear(self) -> None:
        self.spans.clear()
        self.instants.clear()
        self._open_phase.clear()
        self._stack.clear()

    # ----------------------------------------------------- chrome export
    def to_chrome(self, *, process_name: str = "kvdirect") -> dict:
        """The trace as a Chrome trace-event JSON object (the
        ``{"traceEvents": [...]}`` container format, Perfetto-loadable).

        Tracks map to named threads of one process; timestamps are
        microseconds relative to the earliest recorded event, so sim
        (virtual-seconds) and real (perf_counter) traces render the
        same way."""
        events: list[dict] = []
        all_spans: Iterable[Span] = [*self.spans, *self.instants]
        t_base = min((s.t0 for s in all_spans), default=0.0)
        tids: dict[str, int] = {}

        def tid_of(track) -> int:
            key = track_name(track)
            if key not in tids:
                tids[key] = len(tids) + 1
                events.append({"ph": "M", "name": "thread_name", "pid": 1,
                               "tid": tids[key], "args": {"name": key}})
            return tids[key]

        events.append({"ph": "M", "name": "process_name", "pid": 1, "tid": 0,
                       "args": {"name": process_name}})
        for s in sorted(self.spans, key=lambda s: s.t0):
            events.append({
                "ph": "X", "name": s.name, "pid": 1, "tid": tid_of(s.track),
                "ts": (s.t0 - t_base) * 1e6,
                "dur": ((s.t1 if s.t1 is not None else s.t0) - s.t0) * 1e6,
                "cat": track_name(s.track),
                "args": {k: _jsonable(v) for k, v in s.attrs.items()},
            })
        for s in sorted(self.instants, key=lambda s: s.t0):
            events.append({
                "ph": "i", "s": "t", "name": s.name, "pid": 1,
                "tid": tid_of(s.track), "ts": (s.t0 - t_base) * 1e6,
                "cat": track_name(s.track),
                "args": {k: _jsonable(v) for k, v in s.attrs.items()},
            })
        return {"traceEvents": events, "displayTimeUnit": "ms"}

    def export_chrome(self, path: str, **kw) -> dict:
        """Write the Chrome trace JSON to ``path``; returns the object."""
        doc = self.to_chrome(**kw)
        with open(path, "w") as f:
            json.dump(doc, f)
        return doc


def _jsonable(v):
    if isinstance(v, (str, int, float, bool)) or v is None:
        return v
    return str(v)


# The shared disabled tracer: the hot-path default everywhere a tracer is
# optional.  One instance so identity checks and the disabled fast path
# stay trivially cheap.
NULL_TRACER = Tracer(enabled=False)
