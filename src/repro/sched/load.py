"""Per-worker load telemetry, piggybacked on the cluster heartbeat.

KVDirect keeps the control plane deliberately tiny (§4.2): workers only
talk to the scheduler for membership and liveness.  The router needs
per-worker occupancy to make placement decisions, so rather than adding a
second control channel we attach a ``LoadReport`` to the heartbeat the
worker already sends — ``ClusterScheduler.heartbeat(wid, now, load=...)``
stores the latest report next to the liveness timestamp, and the router
reads it back through ``ClusterScheduler.load()``.

``modeled_transfer_s`` is the NetKV-style cost the network-aware policy
minimizes: the modeled time to move a request's KV footprint over a
specific decode worker's link, using the SAME ``LinkModel`` the transfer
engine accrues — so routing scores and engine timing cannot drift apart.
"""
from __future__ import annotations

import dataclasses

from repro.core.transfer_engine import KVDIRECT_UTIL, LinkModel

__all__ = ["LoadReport", "modeled_transfer_s"]


@dataclasses.dataclass(frozen=True)
class LoadReport:
    """One worker's occupancy snapshot, as of heartbeat time ``t``.

    Capacity is counted in KV blocks (the unit both worker roles
    allocate); ``queued_tokens`` is work accepted but not yet running —
    prefill queue depth for prefill workers, KV_QUEUED footprint for
    decode workers.
    """

    worker_id: str
    role: str  # "prefill" | "decode"
    free_blocks: int
    total_blocks: int
    resident_requests: int = 0
    queued_tokens: int = 0
    queue_depth: int = 0
    block_size: int = 32
    t: float = 0.0
    # Shared-prefix ids this worker currently holds resident (live
    # requests, in-flight pulls, and the BlockPool-refcounted retention
    # cache) — the signal the "prefix_affinity" policy routes on.
    prefix_ids: tuple[str, ...] = ()
    # Blocks held only by the prefix retention cache: NOT free (they
    # count as load for placement) but reclaimable on demand, so
    # admission planning may spend them (the worker evicts lazily).
    evictable_blocks: int = 0
    # Resident-set advertisement for delta transfer (docs/scheduling.md):
    # (prefix_id, whole blocks retained) per cached prefix.  The router
    # prices a pull to this worker as suffix-only — the resident prefix
    # blocks are grafted decode-side, never moved — and admission
    # planning charges only the suffix against the worker's budget.
    prefix_blocks: tuple[tuple[str, int], ...] = ()

    def resident_blocks_for(self, prefix_id: str | None) -> int:
        """Whole prefix blocks this worker retains for ``prefix_id``
        (0 when unknown) — the wire savings a delta plan realizes here."""
        if prefix_id is None:
            return 0
        for pid, nblocks in self.prefix_blocks:
            if pid == prefix_id:
                return nblocks
        return 0

    @property
    def queued_blocks(self) -> int:
        return -(-self.queued_tokens // max(self.block_size, 1))

    @property
    def load_fraction(self) -> float:
        """In-use plus queued demand, as a fraction of capacity."""
        used = self.total_blocks - self.free_blocks + self.queued_blocks
        return used / max(self.total_blocks, 1)


def modeled_transfer_s(
    kv_bytes: int,
    link: LinkModel,
    *,
    span_bytes: int = 64 * 1024,
    coalesce_factor: float = 8.0,
    utilization: float = KVDIRECT_UTIL,
) -> float:
    """Modeled pull time for ``kv_bytes`` of KV over ``link``.

    ``span_bytes`` is one K-or-V span of a block (one read transaction);
    ``coalesce_factor`` is the average spans-per-RDMA-op the engine
    achieves (§4.2 coalescing).  Post overheads scale with ops, wire time
    with bytes at the link's effective utilization, and the link's
    propagation latency is charged once per pull (pipelined reads mean
    only the first byte pays it) — on a cross-region link this term can
    dominate small deltas, which is exactly what topology-aware routing
    needs to see (docs/topology.md).
    """
    if kv_bytes <= 0:
        return 0.0
    n_spans = -(-kv_bytes // max(span_bytes, 1))
    n_ops = max(1, int(n_spans / max(coalesce_factor, 1.0)))
    return (n_ops * link.post_overhead_s + link.latency_s
            + kv_bytes / (utilization * link.bandwidth_Bps))
