"""``RequestRouter`` — the placement brain between submit() and workers.

The router owns the request backlog and, per request, chooses a
(prefill, decode) pair through a pluggable policy:

  * candidates come from ``ClusterScheduler`` membership, annotated with
    the ``LoadReport`` piggybacked on each worker's heartbeat;
  * decode candidates additionally carry the modeled cost of pulling
    THIS request's KV footprint over the (prefill, decode) link — the
    topology map ``links[(pwid, dwid)]`` holds per-pair ``LinkModel``s
    (rail-aligned NICs, cross-pod DCN hops, ...), defaulting to one
    uniform link;
  * a small projected-busy ledger per prefill worker lets the router
    estimate queue wait, and therefore TTFT = wait + prefill + transfer,
    without a second control round-trip;
  * the policy's ``admit`` vote turns that projection into admission
    control — rejected requests either raise ``AdmissionRejected`` or
    join the backlog for ``drain_backlog`` to retry when load falls.

Failure handling: ``on_worker_failed`` drops the dead worker's ledger
entry; the serving layer re-submits in-flight requests through
``route()`` again, which can only pick live members (the scheduler has
already removed the dead worker).
"""
from __future__ import annotations

import collections
import dataclasses
from typing import Callable, Iterable

from repro.core.cluster import ClusterScheduler
from repro.core.transfer_engine import LinkModel
from repro.sched.load import LoadReport, modeled_transfer_s
from repro.sched.policies import Candidate, Policy, RouteRequest, make_policy

__all__ = ["RequestRouter", "RouteDecision", "AdmissionRejected", "NoWorkersError"]


class NoWorkersError(RuntimeError):
    """No live worker of a required role — nothing to route to."""


class AdmissionRejected(RuntimeError):
    """SLO admission control rejected the request: its projected TTFT
    already exceeds the deadline of its class."""

    def __init__(self, request_id: str, projected_ttft_s: float, deadline_s: float) -> None:
        super().__init__(
            f"{request_id}: projected TTFT {projected_ttft_s:.3f}s exceeds "
            f"SLO deadline {deadline_s:.3f}s"
        )
        self.request_id = request_id
        self.projected_ttft_s = projected_ttft_s
        self.deadline_s = deadline_s


@dataclasses.dataclass(frozen=True)
class RouteDecision:
    request_id: str
    prefill_worker: str
    decode_worker: str
    projected_ttft_s: float
    transfer_cost_s: float


def _default_prefill_time(prompt_len: int) -> float:
    # Generic linear prefill estimate (~50k tok/s) used when the caller
    # has no calibrated CostModel; only relative projections matter for
    # placement, absolute ones for SLO admission (callers with real SLOs
    # pass a calibrated fn).
    return prompt_len / 50_000.0


class RequestRouter:
    def __init__(
        self,
        scheduler: ClusterScheduler,
        policy: str | Policy = "least_loaded",
        *,
        links: dict[tuple[str, str], LinkModel] | None = None,
        default_link: LinkModel | None = None,
        prefill_time_fn: Callable[[int], float] | None = None,
        coalesce_factor: float = 8.0,
        span_bytes: int = 64 * 1024,
        metrics=None,
        stale_after_s: float | None = None,
        **policy_kwargs,
    ) -> None:
        self.scheduler = scheduler
        # LoadReport staleness guard: a report older than this (relative
        # to the routing call's ``now``) is distrusted — the worker is
        # scored as fully loaded and excluded from capacity fits, so the
        # router stops placing work on a silently-dead or wedged worker
        # before liveness reaping catches it.  None derives the cutoff
        # from the scheduler's heartbeat timeout (a small multiple: one
        # missed heartbeat is jitter, several is a signal).
        self.stale_after_s = stale_after_s
        # Draining workers (fleet scale-down): still alive, still serving
        # what they hold, but no NEW placements — candidates skip them
        # unless literally nobody else is left.
        self.draining: set[str] = set()
        # optional repro.obs.MetricsRegistry: routing decisions and hedge
        # outcomes land here when the serving layer wires one in
        self.metrics = metrics
        self.policy = make_policy(policy, **policy_kwargs)
        self.links = dict(links or {})
        self.default_link = default_link or LinkModel()
        self.prefill_time_fn = prefill_time_fn or _default_prefill_time
        self.coalesce_factor = coalesce_factor
        self.span_bytes = span_bytes

        self._busy_until: dict[str, float] = {}  # projected prefill completion
        self._charges: dict[str, tuple[str, float]] = {}  # rid -> (worker, t_prefill)
        self.backlog: collections.deque[RouteRequest] = collections.deque()
        self.decisions: dict[str, RouteDecision] = {}
        self.total_transfer_cost_s = 0.0
        self.rejected_count = 0

    # ------------------------------------------------------------- links
    def link(self, prefill_worker: str, decode_worker: str) -> LinkModel:
        return self.links.get((prefill_worker, decode_worker), self.default_link)

    def _resident_blocks(self, ctx: RouteRequest, worker_id: str) -> int:
        """Whole blocks of this request's prefix the worker advertises as
        resident (``LoadReport.prefix_blocks``), capped at the request's
        own footprint — the blocks a delta plan would graft instead of
        pull."""
        if ctx.prefix_id is None:
            return 0
        rep: LoadReport | None = self.scheduler.load(worker_id)
        if rep is None:
            return 0
        total = -(-ctx.prompt_len // max(rep.block_size, 1))
        return min(rep.resident_blocks_for(ctx.prefix_id), total)

    def transfer_cost_s(self, ctx: RouteRequest, prefill_worker: str,
                        decode_worker: str) -> float:
        """Modeled pull cost, delta-aware: when the decode worker
        advertises part of this request's prefix as resident, only the
        suffix bytes move — the router prices exactly what the decode
        worker's delta plan will put on the wire, so prefix-affinity
        placement and network-aware placement agree on the savings."""
        kv_bytes = ctx.kv_bytes
        resident = self._resident_blocks(ctx, decode_worker)
        if resident:
            rep = self.scheduler.load(decode_worker)
            total = -(-ctx.prompt_len // max(rep.block_size, 1))
            kv_bytes = kv_bytes * (total - resident) // total
        return modeled_transfer_s(
            kv_bytes,
            self.link(prefill_worker, decode_worker),
            span_bytes=self.span_bytes,
            coalesce_factor=self.coalesce_factor,
        )

    # -------------------------------------------------------- candidates
    def _stale_cutoff_s(self) -> float:
        if self.stale_after_s is not None:
            return self.stale_after_s
        # 2.5 heartbeats: one missed beat is jitter, several a signal
        return 2.5 * getattr(self.scheduler, "heartbeat_timeout_s", 5.0)

    def _is_stale(self, rep: LoadReport | None, now: float | None) -> bool:
        if rep is None or now is None:
            return False
        return now - rep.t > self._stale_cutoff_s()

    def _candidate(self, worker_id: str, *, ready_s: float = 0.0,
                   transfer_cost_s: float = 0.0,
                   prefix_hit: float = 0.0,
                   now: float | None = None) -> Candidate:
        rep: LoadReport | None = self.scheduler.load(worker_id)
        if rep is None:
            return Candidate(worker_id, ready_s=ready_s,
                             transfer_cost_s=transfer_cost_s,
                             prefix_hit=prefix_hit)
        if self._is_stale(rep, now):
            # A frozen report must not make the worker look attractive —
            # its blocks may be full (or the worker dead).  Score it as
            # fully loaded so every load-sensitive policy avoids it;
            # _has_room excludes it from capacity fits the same way.
            return Candidate(
                worker_id,
                free_units=0,
                total_units=rep.total_blocks,
                queued_units=rep.total_blocks,
                resident=rep.resident_requests,
                ready_s=ready_s,
                transfer_cost_s=transfer_cost_s,
                prefix_hit=prefix_hit,
            )
        return Candidate(
            worker_id,
            free_units=rep.free_blocks,
            total_units=rep.total_blocks,
            queued_units=rep.queued_blocks,
            resident=rep.resident_requests,
            ready_s=ready_s,
            transfer_cost_s=transfer_cost_s,
            prefix_hit=prefix_hit,
        )

    def _prefix_hit(self, ctx: RouteRequest, worker_id: str) -> float:
        """1.0 iff the worker's latest LoadReport says the request's
        shared prefix is resident there (prefix-affinity routing)."""
        if ctx.prefix_id is None:
            return 0.0
        rep: LoadReport | None = self.scheduler.load(worker_id)
        if rep is None:
            return 0.0
        return 1.0 if ctx.prefix_id in rep.prefix_ids else 0.0

    def _routable(self, role: str) -> list:
        """Live members minus draining workers — unless draining is all
        that's left (better to place than to wedge every request)."""
        members = self.scheduler.workers(role)
        open_ = [w for w in members if w.worker_id not in self.draining]
        return open_ or members

    def prefill_candidates(self, now: float = 0.0) -> list[Candidate]:
        return [
            self._candidate(
                w.worker_id,
                ready_s=max(0.0, self._busy_until.get(w.worker_id, 0.0) - now),
                now=now,
            )
            for w in self._routable("prefill")
        ]

    def decode_candidates(self, ctx: RouteRequest, prefill_worker: str,
                          *, now: float | None = None) -> list[Candidate]:
        return [
            self._candidate(
                w.worker_id,
                transfer_cost_s=self.transfer_cost_s(ctx, prefill_worker, w.worker_id),
                prefix_hit=self._prefix_hit(ctx, w.worker_id),
                now=now,
            )
            for w in self._routable("decode")
        ]

    def _has_room(self, ctx: RouteRequest, worker_id: str,
                  now: float | None = None) -> bool:
        rep: LoadReport | None = self.scheduler.load(worker_id)
        if rep is None:
            return True  # no telemetry yet: assume room
        if self._is_stale(rep, now):
            return False  # frozen occupancy can't vouch for capacity
        needed = -(-ctx.prompt_len // max(rep.block_size, 1))
        # resident prefix blocks are grafted (shared), not allocated:
        # only the suffix draws on the worker's free/evictable budget
        needed -= min(rep.resident_blocks_for(ctx.prefix_id), needed)
        return rep.free_blocks + rep.evictable_blocks >= needed

    def _fitting(self, ctx: RouteRequest, cands: list[Candidate],
                 now: float | None = None) -> list[Candidate]:
        """Only offer candidates that can hold the request's KV right
        now — a cost-first policy (network_aware) must not pin requests
        to a full worker while another has room.  Falls back to the full
        list when nobody fits (the request queues rather than erroring)."""
        fitting = [c for c in cands if self._has_room(ctx, c.worker_id, now)]
        return fitting or cands

    # ------------------------------------------------------------- route
    def route(self, ctx: RouteRequest, *, now: float = 0.0,
              queue_on_reject: bool = False, force: bool = False,
              count_reject: bool = True) -> RouteDecision | None:
        """Place ``ctx`` on a (prefill, decode) pair.

        Raises ``NoWorkersError`` if a role has no live members and
        ``AdmissionRejected`` if the policy's admission vote fails —
        unless ``queue_on_reject``, which parks the request in the
        backlog and returns None (retry via ``drain_backlog``), or
        ``force``, which skips the admission vote entirely (failover
        re-routing of an already-admitted request).
        """
        pcands = self.prefill_candidates(now)
        if not pcands:
            raise NoWorkersError("no live prefill workers")
        p = self.policy.pick_prefill(ctx, self._fitting(ctx, pcands, now))

        dcands = self.decode_candidates(ctx, p.worker_id, now=now)
        if not dcands:
            raise NoWorkersError("no live decode workers")
        d = self.policy.pick_decode(ctx, self._fitting(ctx, dcands, now))

        t_prefill = self.prefill_time_fn(ctx.prompt_len)
        # Projected TTFT follows the paper's definition (§5.1: TTFT
        # "includes the waiting time for the KV cache"), so the transfer
        # term belongs here.  The simulator's own projection omits it
        # because its measured first token is emitted at prefill
        # completion — each estimator targets the metric its surface
        # actually reports.
        projected = p.ready_s + t_prefill + d.transfer_cost_s
        if not force and not self.policy.admit(ctx, projected):
            if count_reject:
                self.rejected_count += 1
            if self.metrics is not None:
                self.metrics.inc("router.rejected")
            if queue_on_reject:
                self.backlog.append(ctx)
                return None
            deadline = getattr(self.policy, "deadline_s", lambda _: float("inf"))(ctx)
            raise AdmissionRejected(ctx.request_id, projected, deadline)

        self._busy_until[p.worker_id] = now + p.ready_s + t_prefill
        self._charges[ctx.request_id] = (p.worker_id, t_prefill)
        decision = RouteDecision(
            ctx.request_id, p.worker_id, d.worker_id, projected, d.transfer_cost_s
        )
        self.decisions[ctx.request_id] = decision
        self.total_transfer_cost_s += d.transfer_cost_s
        if self.metrics is not None:
            self.metrics.inc("router.routed")
            self.metrics.observe("router.projected_ttft_s", projected)
            self.metrics.observe("router.transfer_cost_s", d.transfer_cost_s)
        return decision

    def pick_hedge_prefill(self, ctx: RouteRequest, exclude: set[str],
                           *, now: float = 0.0) -> str | None:
        """Hedged dispatch: choose a SECOND prefill worker (distinct from
        ``exclude``, normally the primary) to run a duplicate prefill of
        ``ctx``.  Returns None when no alternative worker is alive —
        hedging silently degrades to a single dispatch.  The twin's work
        is charged to the ledger under a hedge id so TTFT projections see
        it; ``forget(request_id)`` retires both charges."""
        cands = [c for c in self.prefill_candidates(now)
                 if c.worker_id not in exclude]
        if not cands:
            if self.metrics is not None:
                self.metrics.inc("router.hedge_unavailable")
            return None
        p = self.policy.pick_prefill(ctx, self._fitting(ctx, cands, now))
        t_prefill = self.prefill_time_fn(ctx.prompt_len)
        self._busy_until[p.worker_id] = now + p.ready_s + t_prefill
        self._charges[f"{ctx.request_id}#hedge"] = (p.worker_id, t_prefill)
        if self.metrics is not None:
            self.metrics.inc("router.hedge_picked")
        return p.worker_id

    def forget_hedge(self, request_id: str) -> None:
        """Retire only the hedge charge — the twin never ran (its pool
        was full), so its projected work must not skew placement."""
        charge = self._charges.pop(f"{request_id}#hedge", None)
        if charge is not None:
            wid, t_prefill = charge
            if wid in self._busy_until:
                self._busy_until[wid] -= t_prefill

    def drain_backlog(self, *, now: float = 0.0) -> list[RouteDecision]:
        """Retry queued requests in FIFO order; stops at the first that
        is still rejected (later arrivals must not starve it).  Retries
        don't re-count toward ``rejected_count``."""
        routed: list[RouteDecision] = []
        while self.backlog:
            ctx = self.backlog.popleft()
            try:
                decision = self.route(ctx, now=now, count_reject=False)
            except (AdmissionRejected, NoWorkersError):
                self.backlog.appendleft(ctx)  # still blocked: keep FIFO head
                break
            routed.append(decision)
        return routed

    # --------------------------------------------------------- admission
    def plan_admissions(
        self,
        queued: Iterable[tuple[RouteRequest, str]],
        *,
        max_batch: int | None = None,
    ) -> dict[str, list[str]]:
        """Batch KV_QUEUED admissions per decode worker.

        ``queued`` is (request, assigned decode worker) for every request
        whose prefill KV is ready to pull.  Instead of the serving layer
        admitting them one call at a time, the router hands back one batch
        per worker — FIFO by arrival, capped by the worker's reported free
        blocks (each batch is admissible as a whole, so the decode worker
        can submit every pull before any byte moves and let the transfers
        pipeline behind decode compute) and optionally by ``max_batch``
        (None or 0 = uncapped, matching ``SimConfig.admission_batch``).
        A worker's batch is strictly head-of-line: when its oldest queued
        request doesn't fit the remaining budget, the worker admits
        nothing behind it — admitting younger, smaller requests around it
        would starve it indefinitely under a steady small-request stream
        (the same FIFO-fairness contract as ``DecodeWorker.admit_batch``).
        The one exception is a request larger than the worker's TOTAL
        capacity: it can never fit there, so it is skipped rather than
        wedging the worker forever.  Requests that don't fit stay
        KV_QUEUED for the next planning round; their prefill-side KV
        stays alive meanwhile (§4.3)."""
        max_batch = max_batch or None  # 0 means uncapped, like the sim knob
        batches: dict[str, list[str]] = {}
        budget: dict[str, float] = {}
        reports: dict[str, LoadReport | None] = {}  # one snapshot per worker
        closed: set[str] = set()  # head-of-line blocked this round
        # Stable sort on arrival only: ties keep the caller's submission
        # order (a request_id tie-break would sort "r10" before "r2").
        for ctx, wid in sorted(queued, key=lambda q: q[0].arrival_s):
            if wid in closed:
                continue
            if wid not in reports:
                reports[wid] = self.scheduler.load(wid)
                rep = reports[wid]
                # retained-prefix blocks are spendable: the worker evicts
                # its retention cache before failing an admission
                budget[wid] = float("inf") if rep is None else float(
                    rep.free_blocks + rep.evictable_blocks)
            rep = reports[wid]
            batch = batches.setdefault(wid, [])
            if max_batch is not None and len(batch) >= max_batch:
                closed.add(wid)
                continue
            needed = -(-ctx.prompt_len // max(rep.block_size, 1)) if rep else 0
            if rep is not None:
                # delta admission: the resident prefix grafts for free,
                # so only the suffix charges against the worker's budget
                needed -= min(rep.resident_blocks_for(ctx.prefix_id), needed)
            if rep is not None and needed > rep.total_blocks:
                continue  # can NEVER fit this worker: don't wedge its queue
            if budget[wid] < needed:
                closed.add(wid)  # head of line waits; nobody jumps it
                continue
            budget[wid] -= needed
            batch.append(ctx.request_id)
        return {wid: rids for wid, rids in batches.items() if rids}

    # ---------------------------------------------------------- failover
    def reassign_decode(self, ctx: RouteRequest, prefill_worker: str,
                        *, now: float | None = None) -> str:
        """Re-pick only the decode side for an already-routed request
        (decode failover while its prefill KV is still alive).  Keeps the
        recorded decision and transfer-cost accounting consistent."""
        cands = self.decode_candidates(ctx, prefill_worker, now=now)
        if not cands:
            raise NoWorkersError("no live decode workers")
        d = self.policy.pick_decode(ctx, self._fitting(ctx, cands, now))
        old = self.decisions.get(ctx.request_id)
        if old is not None:
            self.total_transfer_cost_s += d.transfer_cost_s - old.transfer_cost_s
            self.decisions[ctx.request_id] = dataclasses.replace(
                old, decode_worker=d.worker_id, transfer_cost_s=d.transfer_cost_s)
        return d.worker_id

    def on_worker_failed(self, worker_id: str) -> None:
        self._busy_until.pop(worker_id, None)
        self.draining.discard(worker_id)

    # ---------------------------------------------------------- draining
    def mark_draining(self, worker_id: str) -> None:
        """Fleet scale-down: stop offering ``worker_id`` for new
        placements while it drains what it already holds."""
        self.draining.add(worker_id)

    def clear_draining(self, worker_id: str) -> None:
        self.draining.discard(worker_id)

    def forget(self, request_id: str) -> None:
        """Drop a request's decision AND retire its ledger charge, so a
        completed (or abandoned) prefill stops counting against future
        admission projections."""
        self.decisions.pop(request_id, None)
        for rid in (request_id, f"{request_id}#hedge"):
            charge = self._charges.pop(rid, None)
            if charge is not None:
                wid, t_prefill = charge
                if wid in self._busy_until:
                    self._busy_until[wid] -= t_prefill

    # ------------------------------------------------------------- stats
    def requeue(self, ctx: RouteRequest) -> None:
        """Put a failed in-flight request back at the head of the line."""
        self.backlog.appendleft(ctx)

    def summary(self) -> dict[str, float]:
        return {
            "routed": float(len(self.decisions)),
            "rejected": float(self.rejected_count),
            "backlog": float(len(self.backlog)),
            "total_transfer_cost_s": self.total_transfer_cost_s,
        }
