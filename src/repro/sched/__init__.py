"""Load- and network-aware request scheduling (the layer §4.2/§4.3 enable).

KVDirect's pull-based transfer and dynamic membership exist so a fleet of
prefill and decode workers can be scheduled flexibly; this package is the
scheduler that exercises that flexibility:

  * ``load``     — ``LoadReport`` telemetry piggybacked on the cluster
    heartbeat (no second control channel);
  * ``policies`` — pluggable placement policies (round-robin,
    least-loaded, KV-locality/network-aware, SLO-aware admission);
  * ``router``   — ``RequestRouter``: owns request queues, routes each
    request to a (prefill, decode) pair, projects TTFT for admission,
    and re-routes on worker failure.

The same policy objects drive both the real serving layer
(``repro.serving.disagg``) and the discrete-event simulator
(``repro.sim.events``), so policy experiments in the simulator transfer
directly to the live service.
"""
from repro.sched.load import LoadReport, modeled_transfer_s
from repro.sched.policies import (
    DEFAULT_SLO_CLASSES,
    Candidate,
    LeastLoadedPolicy,
    NetworkAwarePolicy,
    Policy,
    PrefixAffinityPolicy,
    RoundRobinPolicy,
    RouteRequest,
    SLOAwarePolicy,
    make_policy,
)
from repro.sched.router import (
    AdmissionRejected,
    NoWorkersError,
    RequestRouter,
    RouteDecision,
)

__all__ = [
    "AdmissionRejected",
    "Candidate",
    "DEFAULT_SLO_CLASSES",
    "LeastLoadedPolicy",
    "LoadReport",
    "NetworkAwarePolicy",
    "NoWorkersError",
    "Policy",
    "PrefixAffinityPolicy",
    "RequestRouter",
    "RoundRobinPolicy",
    "RouteDecision",
    "RouteRequest",
    "SLOAwarePolicy",
    "make_policy",
    "modeled_transfer_s",
]
