"""Pluggable placement policies for the request router.

Every policy sees the same inputs — a ``RouteRequest`` describing the
request and a list of ``Candidate`` workers with their current load and
(for decode candidates) the modeled cost of pulling this request's KV
over that worker's link — and returns the chosen candidate.  The same
objects drive the real serving layer and the discrete-event simulator,
so ``Candidate`` units are whatever the caller uses consistently (blocks
in serving, tokens in the simulator).

Policies:

  * ``round_robin``   — cycles candidates; the no-information baseline.
  * ``least_loaded``  — minimizes in-use + queued capacity fraction
    (FlowKV-style load awareness).
  * ``network_aware`` — decode selection minimizes the modeled transfer
    cost of the request's KV footprint over the candidate's link
    (NetKV-style path awareness), tie-broken by load; prefill selection
    falls back to least-loaded.
  * ``prefix_affinity`` — decode selection prefers the worker already
    holding the request's shared prefix (BlockPool-refcount residency,
    reported via ``LoadReport.prefix_ids``); falls back to least-loaded.
  * ``slo``           — TTFT deadline classes with an admission
    controller: picks the placement minimizing projected TTFT and
    rejects (or queues) requests whose projection exceeds their class
    deadline, protecting already-admitted traffic.

Adding a policy: subclass ``Policy``, implement ``pick_prefill`` /
``pick_decode`` (and optionally ``admit``), and register it in
``POLICIES`` (see docs/scheduling.md).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Mapping, Sequence

__all__ = [
    "RouteRequest",
    "Candidate",
    "Policy",
    "RoundRobinPolicy",
    "LeastLoadedPolicy",
    "NetworkAwarePolicy",
    "PrefixAffinityPolicy",
    "SLOAwarePolicy",
    "DEFAULT_SLO_CLASSES",
    "POLICIES",
    "make_policy",
]

# TTFT deadline classes (seconds).  "batch" traffic is never rejected.
DEFAULT_SLO_CLASSES: dict[str, float] = {
    "interactive": 0.5,
    "standard": 2.0,
    "batch": math.inf,
}


@dataclasses.dataclass(frozen=True)
class RouteRequest:
    """What a policy may know about a request before placing it."""

    request_id: str
    prompt_len: int
    kv_bytes: int = 0          # full KV footprint to be pulled decode-side
    slo_class: str = "standard"
    arrival_s: float = 0.0
    prefix_id: str | None = None  # shared-prefix identity (prefix routing)


@dataclasses.dataclass(frozen=True)
class Candidate:
    """One worker as seen by a policy.  ``*_units`` are capacity in the
    caller's unit (blocks for serving, tokens for the simulator);
    ``ready_s`` is the projected wait until the worker could start this
    request; ``transfer_cost_s`` is the modeled KV pull cost over this
    worker's link (decode candidates only)."""

    worker_id: str
    free_units: float = 1.0
    total_units: float = 1.0
    queued_units: float = 0.0
    resident: int = 0
    ready_s: float = 0.0
    transfer_cost_s: float = 0.0
    prefix_hit: float = 0.0  # 1.0 iff this worker holds the request's prefix

    @property
    def load_score(self) -> float:
        used = self.total_units - self.free_units + self.queued_units
        return used / max(self.total_units, 1e-9)


class Policy:
    """Base class: pick a prefill candidate, pick a decode candidate,
    and vote on admission.  Candidates are never empty."""

    name = "policy"

    def pick_prefill(self, ctx: RouteRequest, cands: Sequence[Candidate]) -> Candidate:
        raise NotImplementedError

    def pick_decode(self, ctx: RouteRequest, cands: Sequence[Candidate]) -> Candidate:
        raise NotImplementedError

    def admit(self, ctx: RouteRequest, projected_ttft_s: float) -> bool:
        return True


class RoundRobinPolicy(Policy):
    name = "round_robin"

    def __init__(self) -> None:
        self._next = {"prefill": 0, "decode": 0}

    def _pick(self, role: str, cands: Sequence[Candidate]) -> Candidate:
        ordered = sorted(cands, key=lambda c: c.worker_id)
        chosen = ordered[self._next[role] % len(ordered)]
        self._next[role] += 1
        return chosen

    def pick_prefill(self, ctx: RouteRequest, cands: Sequence[Candidate]) -> Candidate:
        return self._pick("prefill", cands)

    def pick_decode(self, ctx: RouteRequest, cands: Sequence[Candidate]) -> Candidate:
        return self._pick("decode", cands)


class LeastLoadedPolicy(Policy):
    name = "least_loaded"

    def pick_prefill(self, ctx: RouteRequest, cands: Sequence[Candidate]) -> Candidate:
        return min(cands, key=lambda c: (c.load_score, c.ready_s, c.worker_id))

    def pick_decode(self, ctx: RouteRequest, cands: Sequence[Candidate]) -> Candidate:
        return min(cands, key=lambda c: (c.load_score, c.ready_s, c.worker_id))


class NetworkAwarePolicy(LeastLoadedPolicy):
    """NetKV-style: the decode instance is chosen by the network path the
    KV cache will traverse, not just by free memory.  Load still breaks
    ties so a congested-but-close worker doesn't absorb everything."""

    name = "network_aware"

    def pick_decode(self, ctx: RouteRequest, cands: Sequence[Candidate]) -> Candidate:
        return min(cands, key=lambda c: (c.transfer_cost_s, c.load_score, c.worker_id))


class PrefixAffinityPolicy(LeastLoadedPolicy):
    """Prefix-cache-aware decode placement: prefer the worker whose
    BlockPool still holds the request's shared prefix resident
    (``Candidate.prefix_hit``), so a follow-up request lands where its
    prefix KV already lives.  Routing affinity only for now — the pull
    still moves the full prompt; adopting the retained blocks at admit
    time (skipping the prefix's reads) is the follow-up that turns the
    hit into a transfer saving (see docs/serving.md).  With no hit
    anywhere the sort key degenerates to least-loaded — the documented
    fallback."""

    name = "prefix_affinity"

    def pick_decode(self, ctx: RouteRequest, cands: Sequence[Candidate]) -> Candidate:
        return min(cands, key=lambda c: (
            -c.prefix_hit, c.load_score, c.ready_s, c.worker_id))


class SLOAwarePolicy(LeastLoadedPolicy):
    """TTFT deadline classes + admission control.  Placement minimizes
    projected start time (the TTFT-critical term); ``admit`` rejects a
    request whose projected TTFT already exceeds its class deadline, so
    admitted traffic keeps its SLO instead of everyone missing it."""

    name = "slo"

    def __init__(self, classes: Mapping[str, float] | None = None) -> None:
        self.classes = dict(classes or DEFAULT_SLO_CLASSES)

    def deadline_s(self, ctx: RouteRequest) -> float:
        return self.classes.get(ctx.slo_class, math.inf)

    def pick_prefill(self, ctx: RouteRequest, cands: Sequence[Candidate]) -> Candidate:
        return min(cands, key=lambda c: (c.ready_s, c.load_score, c.worker_id))

    def pick_decode(self, ctx: RouteRequest, cands: Sequence[Candidate]) -> Candidate:
        return min(cands, key=lambda c: (c.transfer_cost_s + c.ready_s, c.load_score, c.worker_id))

    def admit(self, ctx: RouteRequest, projected_ttft_s: float) -> bool:
        return projected_ttft_s <= self.deadline_s(ctx)


POLICIES: dict[str, type[Policy]] = {
    RoundRobinPolicy.name: RoundRobinPolicy,
    LeastLoadedPolicy.name: LeastLoadedPolicy,
    NetworkAwarePolicy.name: NetworkAwarePolicy,
    PrefixAffinityPolicy.name: PrefixAffinityPolicy,
    SLOAwarePolicy.name: SLOAwarePolicy,
}


def make_policy(policy: str | Policy, **kwargs) -> Policy:
    """Resolve a policy name (or pass an instance through)."""
    if isinstance(policy, Policy):
        return policy
    try:
        cls = POLICIES[policy]
    except KeyError:
        raise ValueError(f"unknown policy {policy!r}; known: {sorted(POLICIES)}")
    return cls(**kwargs)
