"""Mixture-of-Experts FFN — capacity-factor dispatch via scatter/gather.

GShard's classic one-hot dispatch EINSUM costs tokens·E·C·d MACs — for
llama4-maverick (E=128) that is ~27× the routed expert compute itself,
which would poison the §Roofline compute term.  Here dispatch/combine
are a scatter-add and a batched gather instead: O(tokens·k·d) data
movement and effectively zero FLOPs, matching what a production ragged
kernel does.  Capacity semantics (per-group buffers, token dropping) are
identical to GShard.

Sharding: tokens' group dim shards over 'data'; expert buffers
[E, ...] shard over 'data' too, so the scatter/gather lower to
all-to-alls on the data axis — the canonical EP pattern.

Experts are PADDED to a multiple of 16 (`cfg.padded_experts`) so the
expert dim shards evenly; padded experts get -inf router logits and are
never selected (asserted in tests).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import sharding
from repro.models.layers import PARAM_DTYPE, dense_init, swiglu, swiglu_init

__all__ = ["moe_init", "moe_apply"]


def moe_init(rng, cfg):
    """Router + stacked expert weights (+ optional shared expert)."""
    e_pad = cfg.padded_experts
    r_router, r_gate, r_up, r_down, r_shared = jax.random.split(rng, 5)
    scale = cfg.d_model ** -0.5

    def stack(r, a, b):
        return (jax.random.normal(r, (e_pad, a, b), dtype=jnp.float32) * scale).astype(PARAM_DTYPE)

    p = {
        "router": dense_init(r_router, cfg.d_model, e_pad, scale=0.02),
        "gate": stack(r_gate, cfg.d_model, cfg.d_ff),
        "up": stack(r_up, cfg.d_model, cfg.d_ff),
        "down": stack(r_down, cfg.d_ff, cfg.d_model),
    }
    if cfg.moe_shared_expert:
        p["shared"] = swiglu_init(r_shared, cfg.d_model, cfg.d_ff)
    return p


def _capacity(tokens_per_group: int, k: int, e: int, cf: float) -> int:
    return max(1, -(-int(tokens_per_group * k * cf) // e))


def _ffn_local(xe, gate, up, down):
    """Per-expert SwiGLU over buffers.  xe: [E, n, d]."""
    h = jax.nn.silu(jnp.einsum("end,edf->enf", xe, gate)) * jnp.einsum(
        "end,edf->enf", xe, up
    )
    return jnp.einsum("enf,efd->end", h, down)


def _expert_ffn(buf, p, g, gs, e_pad, cap, d):
    """Token-sharded buffers → expert compute → token-sharded results.

    §Perf (EXPERIMENTS.md, MoE cell): with bare sharding constraints the
    SPMD partitioner lowered the token↔expert resharding of the dispatch
    buffers into f32 collective-permutes plus multi-GiB gradient
    all-reduces.  This shard_map version pins the exchange to exactly one
    bf16 all_to_all each way (gradients are the mirrored all_to_alls) and
    a small fp32 psum for the TP-sharded expert FFN.
    """
    mesh = sharding.get_mesh()
    dsize = mesh.shape.get("data", 1) if mesh is not None else 1
    dp_total = sharding.dp_size() if mesh is not None else 1
    if mesh is None or g % max(dp_total, 1) or e_pad % dsize or dsize == 1:
        # local / undivisible fallback: plain reshape round-trip
        xe = buf.reshape(g, e_pad, cap, d).transpose(1, 0, 2, 3).reshape(e_pad, g * cap, d)
        ye = _ffn_local(xe, p["gate"], p["up"], p["down"])
        return ye.reshape(e_pad, g, cap, d).transpose(1, 0, 2, 3).reshape(g, e_pad * cap, d)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    tp = sharding.tp_size()
    folded = sharding.tp_folded()
    ff = p["gate"].shape[-1]
    # TP-shard the expert FFN only when the shard is MXU-sized: for tiny
    # experts (granite-moe: 512/16 = 32 lanes) the per-layer fp32 psum of
    # the whole expert buffer costs far more wire than the FLOPs saved
    # (measured: §Perf MoE cell, EXPERIMENTS.md).
    ff_sharded = tp > 1 and ff % tp == 0 and ff // tp >= 128
    ff_spec = "model" if ff_sharded else None
    # DP+EP deployment (fold_model_axis_into_dp): expert weights are
    # FSDP-sharded over 'model'; each shard_map cell gathers them (they
    # are tiny) and computes its own token slice — no psum at all.
    fsdp_w = folded and ff % mesh.shape.get("model", 1) == 0
    w_ff_spec = "model" if fsdp_w else ff_spec
    e_loc = e_pad // dsize
    dp = sharding.dp_axes()  # buffers' token dim shards over pod × data
    # (× model when folded); experts shard over 'data' only — the expert
    # exchange never crosses the DCN ('pod' stays pure DP)

    def local(b, gate, up, down):
        if fsdp_w:  # gather the FSDP weight shards (≤ a few hundred MB)
            gate = jax.lax.all_gather(gate, "model", axis=2, tiled=True)
            up = jax.lax.all_gather(up, "model", axis=2, tiled=True)
            down = jax.lax.all_gather(down, "model", axis=1, tiled=True)
        # b: [g/(P·D·M?), E*C, d] → a2a over data → rows × this shard's E
        y = jax.lax.all_to_all(b, "data", split_axis=1, concat_axis=0, tiled=True)
        rows = y.shape[0]
        y = y.reshape(rows, e_loc, cap, d).transpose(1, 0, 2, 3).reshape(e_loc, rows * cap, d)
        out = _ffn_local(y, gate, up, down)
        if ff_sharded:  # down-proj contracted a TP shard of ff: combine
            out = jax.lax.psum(out.astype(jnp.float32), "model").astype(b.dtype)
        out = out.reshape(e_loc, rows, cap, d).transpose(1, 0, 2, 3)
        out = out.reshape(rows, e_loc * cap, d)
        return jax.lax.all_to_all(out, "data", split_axis=0, concat_axis=1, tiled=True)

    return shard_map(
        local,
        mesh=mesh,
        in_specs=(
            P(dp, None, None),
            P("data", None, w_ff_spec),
            P("data", None, w_ff_spec),
            P("data", w_ff_spec, None),
        ),
        out_specs=P(dp, None, None),
    )(buf, p["gate"], p["up"], p["down"])


def moe_apply(p, x, cfg, *, group_size: int = 512, capacity_factor: float | None = None):
    """x: [b, s, d] → (out [b, s, d], aux load-balance loss)."""
    b, s, d = x.shape
    e_pad, e, k = cfg.padded_experts, cfg.num_experts, cfg.experts_per_token
    cf = cfg.capacity_factor if capacity_factor is None else capacity_factor
    n = b * s
    # group size: prefer ``group_size`` but keep the group COUNT divisible
    # by the full DP extent (the EP shard_map requires it; multipod DP+EP
    # folds 512 ways while a microbatch may only carry 256 groups of 512)
    dp = 1
    if sharding.get_mesh() is not None:
        dp = max(sharding.dp_size(), 1)
    gs = 0
    for cand in (group_size, 512, 256, 128, 64, 32):
        if cand <= n and n % cand == 0 and (n // cand) % dp == 0:
            gs = cand
            break
    if not gs:
        gs = n if n % dp else n // dp  # degenerate small inputs
    g = n // gs
    xg = sharding.shard_batch_seq(x.reshape(g, gs, d))

    logits = xg.astype(jnp.float32) @ p["router"]["w"].astype(jnp.float32)  # [g, gs, e_pad]
    pad_mask = jnp.arange(e_pad) < e
    logits = jnp.where(pad_mask, logits, -jnp.inf)
    probs = jax.nn.softmax(logits, axis=-1)

    top_p, top_idx = jax.lax.top_k(probs, k)                      # [g, gs, k]
    top_p = top_p / jnp.clip(top_p.sum(-1, keepdims=True), 1e-9)

    cap = _capacity(gs, k, e, cf)
    # position of each (token, k) within its expert's buffer, per group
    onehot = jax.nn.one_hot(top_idx, e_pad, dtype=jnp.int32)      # [g, gs, k, e_pad]
    pos = jnp.cumsum(onehot.reshape(g, gs * k, e_pad), axis=1).reshape(g, gs, k, e_pad) - 1
    pos = jnp.sum(pos * onehot, axis=-1)                          # [g, gs, k]
    keep = pos < cap
    slot = top_idx * cap + jnp.where(keep, pos, 0)                # flat [0, e_pad*cap)

    # ---- dispatch: scatter-add tokens into expert buffers --------------
    # vmapped over the group dim so the SPMD partitioner sees a BATCHED
    # scatter (global row indices made it gather the whole buffer).
    contrib = jnp.where(keep[..., None], xg[:, :, None, :], 0).astype(x.dtype)

    def _scatter_row(slots_r, contrib_r):
        return jnp.zeros((e_pad * cap, d), x.dtype).at[slots_r.reshape(-1)].add(
            contrib_r.reshape(-1, d))

    buf = jax.vmap(_scatter_row)(slot, contrib)                    # [g, E*C, d]

    ye = _expert_ffn(buf, p, g, gs, e_pad, cap, d)                 # [g, E*C, d]

    # ---- combine: gather back + weighted sum over k --------------------
    gathered = jnp.take_along_axis(ye, slot.reshape(g, gs * k, 1), axis=1)
    gathered = gathered.reshape(g, gs, k, d).astype(jnp.float32)
    w = (top_p * keep).astype(jnp.float32)
    out = jnp.einsum("gsk,gskd->gsd", w, gathered).reshape(b, s, d).astype(x.dtype)

    if cfg.moe_shared_expert:
        out = out + swiglu(p["shared"], x)

    # load-balancing aux loss (Switch-style)
    me = jnp.mean(onehot.sum(2).astype(jnp.float32), axis=1)  # routed fraction per expert
    ce = jnp.mean(probs, axis=1)
    aux = (e / max(k, 1)) * jnp.mean(jnp.sum(me * ce, axis=-1))
    return out, aux
