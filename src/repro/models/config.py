"""Unified model configuration for every assigned architecture family.

One dataclass covers dense / MoE / enc-dec(audio) / VLM / SSM / hybrid so
that the serving engines, the launch steps, and the dry-run can treat all
ten architectures uniformly (``--arch <id>``).
"""
from __future__ import annotations

import dataclasses
import math

__all__ = ["ModelConfig"]


def _round_up(x: int, m: int) -> int:
    return -(-x // m) * m


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | audio | vlm | ssm | hybrid
    num_layers: int
    d_model: int
    vocab_size: int
    num_heads: int = 0               # 0 for attention-free archs
    num_kv_heads: int = 0
    d_ff: int = 0                    # dense FFN width (per-expert width for MoE)
    head_dim: int = 0                # derived from d_model/num_heads if 0

    # --- MLP flavor ----------------------------------------------------
    mlp_type: str = "swiglu"         # "swiglu" (3-matrix) | "gelu" (2-matrix)

    # --- MoE ---------------------------------------------------------
    num_experts: int = 0
    experts_per_token: int = 0
    moe_shared_expert: bool = False  # Llama-4-style always-on shared expert
    moe_every: int = 1               # MoE every k-th layer (Llama-4: 2)
    d_ff_dense: int = 0              # FFN width of interleaved dense layers
    capacity_factor: float = 1.25

    # --- SSM (Mamba-2 SSD) --------------------------------------------
    ssm_state: int = 0               # N (dstate)
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4

    # --- encoder-decoder (whisper) -------------------------------------
    is_encoder_decoder: bool = False
    encoder_layers: int = 0
    encoder_seq: int = 1500          # precomputed frame embeddings (stub frontend)
    max_positions: int = 65536       # learned decoder position table (sized for
                                     # the assigned decode_32k shape; whisper
                                     # proper uses 448)

    # --- VLM (llava) ----------------------------------------------------
    vision_tokens: int = 0           # anyres patch tokens per image (stub frontend)

    # --- attention flavor ----------------------------------------------
    sliding_window: int = 0          # 0 = full attention
    num_meta_tokens: int = 0         # hymba learnable prefix
    rope_theta: float = 10_000.0

    # --- training -------------------------------------------------------
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    fp32_master: bool = True         # False => bf16 optimizer moments (maverick)

    # --- deployment ------------------------------------------------------
    # True: fold the mesh 'model' axis into data parallelism (DP+EP, no
    # tensor parallelism for weights).  The right call for small-dim MoE
    # (granite-moe: d=1536, ff=512/expert — TP-16 shards are sub-MXU and
    # every activation gradient psums over an axis that shards nothing;
    # measured in EXPERIMENTS.md §Perf).  Sequence-parallel flash-decoding
    # still uses the 'model' axis for KV pages regardless.
    fold_model_axis_into_dp: bool = False

    # ------------------------------------------------------------ derived
    def __post_init__(self):
        if self.num_heads and not self.head_dim:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def attn_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def padded_vocab(self) -> int:
        return _round_up(self.vocab_size, 256)

    @property
    def padded_experts(self) -> int:
        """Experts padded so the expert dim shards over the data axis (16)."""
        return _round_up(self.num_experts, 16) if self.num_experts else 0

    @property
    def ssm_inner(self) -> int:
        return self.ssm_expand * self.d_model if self.ssm_state else 0

    @property
    def ssm_heads(self) -> int:
        return self.ssm_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def has_attention(self) -> bool:
        return self.num_heads > 0

    @property
    def has_ssm(self) -> bool:
        return self.ssm_state > 0

    @property
    def is_subquadratic(self) -> bool:
        """True iff decode-state is O(1) in context (SSM / sliding window)
        — the gate for the long_500k shape (see DESIGN.md §4)."""
        attn_ok = (not self.has_attention) or self.sliding_window > 0
        return attn_ok

    # ------------------------------------------------------- param counts
    def param_count(self) -> int:
        """Total parameters (unpadded vocab, real experts)."""
        d, v = self.d_model, self.vocab_size
        n = v * d  # embedding
        if not self.tie_embeddings:
            n += v * d  # lm head

        mats = 3 if self.mlp_type == "swiglu" else 2

        def attn_params() -> int:
            return d * self.attn_dim + 2 * d * self.kv_dim + self.attn_dim * d

        def mlp_params(ff: int | None = None) -> int:
            return mats * d * (self.d_ff if ff is None else ff)

        def moe_params() -> int:
            per_expert = mats * d * self.d_ff
            shared = per_expert if self.moe_shared_expert else 0
            return d * self.num_experts + self.num_experts * per_expert + shared

        def ssm_params() -> int:
            di, ns, nh = self.ssm_inner, self.ssm_state, self.ssm_heads
            # in_proj (x, z, B, C, dt) + conv + out_proj + A,D
            return (
                d * (2 * di + 2 * ns + nh)
                + self.ssm_conv * (di + 2 * ns)
                + di * d
                + 2 * nh
            )

        if self.family in ("dense", "vlm"):
            n += self.num_layers * (attn_params() + mlp_params())
        elif self.family == "moe":
            n_moe = self.num_layers // self.moe_every
            n_dense = self.num_layers - n_moe
            n += n_moe * (attn_params() + moe_params())
            n += n_dense * (attn_params() + mlp_params(self.d_ff_dense))
        elif self.family == "ssm":
            n += self.num_layers * ssm_params()
        elif self.family == "hybrid":
            n += self.num_layers * (attn_params() + ssm_params() + mlp_params())
        elif self.family == "audio":
            # decoder layers have self+cross attention
            n += self.num_layers * (2 * attn_params() + mlp_params())
            n += self.encoder_layers * (attn_params() + mlp_params())
        return n

    def active_param_count(self) -> int:
        """Active params per token (= N for dense; routed subset for MoE)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        mats = 3 if self.mlp_type == "swiglu" else 2
        per_expert = mats * d * self.d_ff
        attn = d * self.attn_dim + 2 * d * self.kv_dim + self.attn_dim * d
        active_moe = (self.experts_per_token + (1 if self.moe_shared_expert else 0)) * per_expert
        n_moe = self.num_layers // self.moe_every
        n_dense = self.num_layers - n_moe
        n = 2 * self.vocab_size * d
        n += n_moe * (attn + d * self.num_experts + active_moe)
        n += n_dense * (attn + mats * d * self.d_ff_dense)
        return n

    def model_flops(self, num_tokens: int) -> float:
        """MODEL_FLOPS = 6·N_active·D (§Roofline)."""
        return 6.0 * self.active_param_count() * num_tokens

    def kv_bytes_per_token_per_layer(self, itemsize: int = 2) -> int:
        if self.has_attention:
            return 2 * self.kv_dim * itemsize
        return 0

    def describe(self) -> str:
        n = self.param_count()
        return (
            f"{self.name}: {self.family}, {self.num_layers}L d={self.d_model} "
            f"H={self.num_heads}/{self.num_kv_heads} ff={self.d_ff} "
            f"vocab={self.vocab_size} params={n/1e9:.2f}B "
            f"(active {self.active_param_count()/1e9:.2f}B)"
        )
