"""Mamba-2 SSD (state-space duality) block — chunked prefill + O(1) decode.

Follows the SSD "minimal discrete" formulation of arXiv:2405.21060:
within-chunk attention-like einsums + across-chunk state recurrence
(associative over chunks, here a lax.scan).  The block returns its FINAL
STATE from prefill — that state (plus the depthwise-conv tail) is exactly
what KVDirect transfers to the decode worker for SSM architectures (a
single contiguous slot per layer; see serving.kv_cache.SlotCache).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import sharding
from repro.models.layers import PARAM_DTYPE, dense, dense_init, rmsnorm, rmsnorm_init

__all__ = ["ssm_init", "ssm_prefill", "ssm_step", "ssm_state_shapes"]


def ssm_init(rng, cfg):
    d, di, ns, nh = cfg.d_model, cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    conv_dim = di + 2 * ns  # x, B, C share the depthwise conv (ngroups=1)
    r_in, r_out, r_conv, r_dt, r_a = jax.random.split(rng, 5)
    return {
        # in_proj emits [z | xBC | dt]
        "in_proj": dense_init(r_in, d, 2 * di + 2 * ns + nh),
        "conv_w": (jax.random.normal(r_conv, (cfg.ssm_conv, conv_dim), dtype=jnp.float32) * 0.1
                   ).astype(PARAM_DTYPE),
        "conv_b": jnp.zeros((conv_dim,), dtype=PARAM_DTYPE),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh, dtype=jnp.float32)),
        "dt_bias": jax.random.uniform(r_dt, (nh,), dtype=jnp.float32, minval=-4.0, maxval=-1.0),
        "d_skip": jnp.ones((nh,), dtype=jnp.float32),
        "out_norm": rmsnorm_init(di),
        "out_proj": dense_init(r_out, di, d),
    }


def ssm_state_shapes(cfg, batch: int):
    """(ssd_state, conv_state) shapes for serving allocation/transfer."""
    di, ns = cfg.ssm_inner, cfg.ssm_state
    return (
        (batch, cfg.ssm_heads, cfg.ssm_head_dim, ns),
        (batch, cfg.ssm_conv - 1, di + 2 * ns),
    )


def _split(p, x, cfg):
    di, ns, nh = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads
    zxbcdt = dense(p["in_proj"], x)
    z = zxbcdt[..., :di]
    xbc = zxbcdt[..., di : 2 * di + 2 * ns]
    dt = zxbcdt[..., 2 * di + 2 * ns :]
    return z, xbc, dt


def _segsum(a):
    """a: [..., T] log-decays → [..., T, T] lower-triangular cumulative sums."""
    T = a.shape[-1]
    cs = jnp.cumsum(a, axis=-1)
    d = cs[..., :, None] - cs[..., None, :]
    mask = jnp.tril(jnp.ones((T, T), dtype=bool))
    return jnp.where(mask, d, -jnp.inf)


def _ssd_chunked(xh, dt, a, B, C, chunk: int):
    """Core SSD over one sequence batch.

    xh: [b, s, nh, hd]; dt: [b, s, nh] (post-softplus); a: [nh] (negative);
    B, C: [b, s, ns] (ngroups=1, shared across heads).
    Returns y [b, s, nh, hd] and final state [b, nh, hd, ns].
    """
    b, s, nh, hd = xh.shape
    ns = B.shape[-1]
    l = min(chunk, s)
    if s % l:
        raise ValueError(f"seq {s} not a multiple of chunk {l}")
    nc = s // l

    # chunked views
    xc = xh.reshape(b, nc, l, nh, hd).astype(jnp.float32)
    dtc = dt.reshape(b, nc, l, nh)
    Bc = B.reshape(b, nc, l, ns).astype(jnp.float32)
    Cc = C.reshape(b, nc, l, ns).astype(jnp.float32)

    da = dtc * a  # [b, nc, l, nh] log-decay per step
    da_h = jnp.moveaxis(da, -1, 2)  # [b, nc, nh, l]
    da_cum = jnp.cumsum(da_h, axis=-1)

    xbar = xc * dtc[..., None]  # dt-scaled inputs

    # (1) within-chunk (diagonal blocks): attention-like with decay kernel
    L = jnp.exp(_segsum(da_h))  # [b, nc, nh, l, l]
    y_diag = jnp.einsum("bcin,bcjn,bchij,bcjhp->bcihp", Cc, Bc, L, xbar)

    # (2) per-chunk summary states: decay to end-of-chunk
    decay_states = jnp.exp(da_cum[..., -1:] - da_cum)  # [b, nc, nh, l]
    states = jnp.einsum("bcjn,bchj,bcjhp->bchpn", Bc, decay_states, xbar)

    # (3) inter-chunk recurrence (scan over chunks)
    chunk_decay = jnp.exp(da_cum[..., -1])  # [b, nc, nh]

    def step(carry, inp):
        st_k, dec_k = inp  # [b, nh, hd, ns], [b, nh]
        new = carry * dec_k[..., None, None] + st_k
        return new, carry  # emit the state BEFORE this chunk

    init = jnp.zeros((b, nh, hd, ns), dtype=jnp.float32)
    final, prior_states = jax.lax.scan(
        step,
        init,
        (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    prior_states = jnp.moveaxis(prior_states, 0, 1)  # [b, nc, nh, hd, ns]

    # (4) off-diagonal contribution: read prior state with in-chunk decay
    state_decay = jnp.exp(da_cum)  # decay from chunk start to position i
    y_off = jnp.einsum("bcin,bchi,bchpn->bcihp", Cc, state_decay, prior_states)

    y = (y_diag + y_off).reshape(b, s, nh, hd)
    return y, final


def _causal_conv(xbc, conv_w, conv_b, conv_state=None):
    """Depthwise causal conv, kernel k.  xbc: [b, s, c]; conv_w: [k, c].
    Returns output [b, s, c] and the new conv tail [b, k-1, c]."""
    k = conv_w.shape[0]
    if conv_state is None:
        pad = jnp.zeros((xbc.shape[0], k - 1, xbc.shape[-1]), dtype=xbc.dtype)
    else:
        pad = conv_state.astype(xbc.dtype)
    xp = jnp.concatenate([pad, xbc], axis=1)  # [b, s+k-1, c]
    out = sum(
        xp[:, i : i + xbc.shape[1], :] * conv_w[i].astype(xbc.dtype) for i in range(k)
    ) + conv_b.astype(xbc.dtype)
    new_tail = xp[:, -(k - 1) :, :]
    return jax.nn.silu(out), new_tail


def ssm_prefill(p, x, cfg, *, chunk: int = 128, conv_state=None, ssd_state=None):
    """x: [b, s, d] → (y [b, s, d], (ssd_state, conv_tail))."""
    di, ns, nh, hd = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    z, xbc, dt_raw = _split(p, x, cfg)
    xbc, conv_tail = _causal_conv(xbc, p["conv_w"], p["conv_b"], conv_state)
    xs, B, C = xbc[..., :di], xbc[..., di : di + ns], xbc[..., di + ns :]
    dt = sharding.shard_heads(jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"]), 2)
    a = -jnp.exp(p["a_log"])  # [nh], negative
    xh = sharding.shard_heads(xs.reshape(*xs.shape[:-1], nh, hd), 2)
    B = sharding.shard_batch_seq(B)
    C = sharding.shard_batch_seq(C)
    y, final = _ssd_chunked(xh, dt, a, B.astype(jnp.float32), C.astype(jnp.float32), chunk)
    if ssd_state is not None:  # continue from transferred state
        # fold initial state in: y += C · decay · state0 ; final updated
        da_cum = jnp.cumsum(jnp.moveaxis(dt * a, -1, 1), axis=-1)  # [b, nh, s]
        decay = jnp.exp(da_cum)
        y = y + jnp.einsum("bsn,bhs,bhpn->bshp", C.astype(jnp.float32), decay,
                           ssd_state.astype(jnp.float32))
        final = final + ssd_state.astype(jnp.float32) * jnp.exp(da_cum[..., -1])[..., None, None]
    y = y + xh.astype(jnp.float32) * p["d_skip"][None, None, :, None]
    y = y.reshape(*x.shape[:-1], di).astype(x.dtype)
    y = rmsnorm(p["out_norm"], y * jax.nn.silu(z))
    return dense(p["out_proj"], y), (final, conv_tail)


def ssm_step(p, x, cfg, state):
    """One-token decode.  x: [b, d]; state = (ssd_state [b,nh,hd,ns],
    conv_state [b,k-1,c]) → (y [b, d], new state)."""
    di, ns, nh, hd = cfg.ssm_inner, cfg.ssm_state, cfg.ssm_heads, cfg.ssm_head_dim
    ssd_state, conv_state = state
    z, xbc, dt_raw = _split(p, x[:, None, :], cfg)
    z, xbc, dt_raw = z[:, 0], xbc[:, 0], dt_raw[:, 0]

    # conv step: shift buffer, apply kernel at last position
    k = p["conv_w"].shape[0]
    window = jnp.concatenate([conv_state.astype(xbc.dtype), xbc[:, None, :]], axis=1)  # [b,k,c]
    conv_out = jnp.einsum("bkc,kc->bc", window, p["conv_w"].astype(xbc.dtype)) + p["conv_b"].astype(xbc.dtype)
    xbc = jax.nn.silu(conv_out)
    new_conv = window[:, 1:, :]

    xs, B, C = xbc[..., :di], xbc[..., di : di + ns], xbc[..., di + ns :]
    dt = jax.nn.softplus(dt_raw.astype(jnp.float32) + p["dt_bias"])  # [b, nh]
    a = -jnp.exp(p["a_log"])
    da = jnp.exp(dt * a)  # [b, nh]
    xh = xs.reshape(-1, nh, hd).astype(jnp.float32)
    # state' = decay * state + dt * x ⊗ B ; y = state' · C
    upd = jnp.einsum("bhp,bn,bh->bhpn", xh, B.astype(jnp.float32), dt)
    new_state = ssd_state.astype(jnp.float32) * da[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", new_state, C.astype(jnp.float32))
    y = y + xh * p["d_skip"][None, :, None]
    y = y.reshape(-1, di).astype(x.dtype)
    y = rmsnorm(p["out_norm"], y * jax.nn.silu(z))
    return dense(p["out_proj"], y), (new_state, new_conv)
