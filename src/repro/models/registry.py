"""Model construction: config → model instance (DecoderLM or EncDecLM)."""
from __future__ import annotations

from repro.models.config import ModelConfig
from repro.models.transformer import DecoderLM
from repro.models.whisper import EncDecLM

__all__ = ["build_model"]


def build_model(cfg: ModelConfig, *, unroll: bool = False):
    if cfg.is_encoder_decoder:
        return EncDecLM(cfg, unroll=unroll)
    return DecoderLM(cfg, unroll=unroll)
