"""Shared NN building blocks — plain functional JAX, param pytrees are
nested dicts of jnp arrays (bf16 storage, fp32 where numerics demand).

Everything here must be safe under ``jax.eval_shape`` (the dry-run never
materializes the 400B-parameter inits) and under ``jax.lax.scan`` over
stacked layer params.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

PARAM_DTYPE = jnp.bfloat16

__all__ = [
    "PARAM_DTYPE", "dense_init", "dense", "rmsnorm_init", "rmsnorm",
    "layernorm_init", "layernorm", "embed_init", "swiglu_init", "swiglu",
]


def dense_init(rng, in_dim: int, out_dim: int, *, bias: bool = False, scale: float | None = None):
    scale = (in_dim ** -0.5) if scale is None else scale
    p = {"w": (jax.random.normal(rng, (in_dim, out_dim), dtype=jnp.float32) * scale).astype(PARAM_DTYPE)}
    if bias:
        p["b"] = jnp.zeros((out_dim,), dtype=PARAM_DTYPE)
    return p


def dense(p, x):
    y = x @ p["w"]
    if "b" in p:
        y = y + p["b"]
    return y


def rmsnorm_init(dim: int):
    return {"scale": jnp.ones((dim,), dtype=PARAM_DTYPE)}


def rmsnorm(p, x, eps: float = 1e-5):
    h = x.astype(jnp.float32)
    h = h * jax.lax.rsqrt(jnp.mean(h * h, axis=-1, keepdims=True) + eps)
    return (h * p["scale"].astype(jnp.float32)).astype(x.dtype)


def layernorm_init(dim: int):
    return {"scale": jnp.ones((dim,), dtype=PARAM_DTYPE),
            "bias": jnp.zeros((dim,), dtype=PARAM_DTYPE)}


def layernorm(p, x, eps: float = 1e-5):
    h = x.astype(jnp.float32)
    mu = jnp.mean(h, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(h - mu), axis=-1, keepdims=True)
    h = (h - mu) * jax.lax.rsqrt(var + eps)
    return (h * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)).astype(x.dtype)


def embed_init(rng, vocab: int, dim: int):
    return {"table": (jax.random.normal(rng, (vocab, dim), dtype=jnp.float32) * 0.02).astype(PARAM_DTYPE)}


def swiglu_init(rng, d_model: int, d_ff: int):
    r1, r2, r3 = jax.random.split(rng, 3)
    return {
        "gate": dense_init(r1, d_model, d_ff),
        "up": dense_init(r2, d_model, d_ff),
        "down": dense_init(r3, d_ff, d_model),
    }


def swiglu(p, x):
    from repro.models import sharding

    h = jax.nn.silu(dense(p["gate"], x)) * dense(p["up"], x)
    if h.ndim == 3:
        h = sharding.shard_ff(h)  # keep d_ff TP-sharded between the matmuls
    return dense(p["down"], h)
