"""Whisper-style encoder-decoder backbone (audio frontend stubbed).

Per the assignment, the conv/mel frontend is a STUB: ``input_specs()``
provides precomputed frame embeddings [b, enc_seq, d].  The encoder is a
non-causal transformer over frames; the decoder is a causal transformer
with cross-attention.

Disaggregation story (DESIGN.md §4): prefill = encode + decoder prompt
pass; the transferable state is the decoder self-KV (paged) PLUS the
cross-attention KV of the encoder output — both are tensors the KVDirect
engine moves via descriptors.

Whisper proper uses LayerNorm+GELU+biases and learned positions; we keep
those (sinusoidal positions on the encoder side).
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import sharding
from repro.models.attention import KVPages, gqa_attention, paged_decode_with_write
from repro.models.config import ModelConfig
from repro.models.flash import flash_attention
from repro.models.layers import PARAM_DTYPE, dense, dense_init, embed_init, layernorm, layernorm_init
from repro.models.transformer import DecodeState

__all__ = ["EncDecLM", "EncDecState"]


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class EncDecState:
    context_lens: jax.Array            # [b] decoder tokens present
    k_pages: jax.Array                 # [L, b, per_seq, bs, g, hd] decoder self-KV
    v_pages: jax.Array
    block_tables: jax.Array            # [b, per_seq] within-seq page ids
    cross_k: jax.Array                 # [L, b, enc_seq, g, hd]
    cross_v: jax.Array


def _sinusoid(seq: int, dim: int):
    pos = jnp.arange(seq)[:, None].astype(jnp.float32)
    i = jnp.arange(dim // 2)[None, :].astype(jnp.float32)
    angles = pos / jnp.power(10000.0, 2 * i / dim)
    return jnp.concatenate([jnp.sin(angles), jnp.cos(angles)], axis=-1)


class EncDecLM:
    BLOCK_SIZE = 32

    def __init__(self, cfg: ModelConfig, *, unroll: bool = False):
        if not cfg.is_encoder_decoder:
            raise ValueError("EncDecLM requires an encoder-decoder config")
        self.cfg = cfg
        self.unroll = unroll  # see DecoderLM: dry-run depth-1/2 variants

    def _scan_layers(self, body, carry, xs, length: int):
        if not self.unroll:
            return jax.lax.scan(body, carry, xs)
        ys = []
        for i in range(length):
            step_x = jax.tree.map(lambda a: a[i], xs)
            carry, y = body(carry, step_x)
            ys.append(y)
        if not ys or not jax.tree.leaves(ys[0]):
            return carry, ys[0] if ys else {}
        return carry, jax.tree.map(lambda *zs: jnp.stack(zs), *ys)

    # ------------------------------------------------------------- init
    def _attn_init(self, rng):
        cfg = self.cfg
        from repro.models.attention import attn_init

        return attn_init(rng, cfg.d_model, cfg.num_heads, cfg.num_kv_heads,
                         cfg.head_dim, bias=True)

    def _mlp_init(self, rng):
        cfg = self.cfg
        r1, r2 = jax.random.split(rng)
        return {
            "up": dense_init(r1, cfg.d_model, cfg.d_ff, bias=True),
            "down": dense_init(r2, cfg.d_ff, cfg.d_model, bias=True),
        }

    def _enc_layer_init(self, rng):
        r1, r2 = jax.random.split(rng)
        return {
            "attn_norm": layernorm_init(self.cfg.d_model),
            "attn": self._attn_init(r1),
            "mlp_norm": layernorm_init(self.cfg.d_model),
            "mlp": self._mlp_init(r2),
        }

    def _dec_layer_init(self, rng):
        r1, r2, r3 = jax.random.split(rng, 3)
        return {
            "self_norm": layernorm_init(self.cfg.d_model),
            "self_attn": self._attn_init(r1),
            "cross_norm": layernorm_init(self.cfg.d_model),
            "cross_attn": self._attn_init(r2),
            "mlp_norm": layernorm_init(self.cfg.d_model),
            "mlp": self._mlp_init(r3),
        }

    def init_params(self, rng):
        cfg = self.cfg
        r_e, r_d, r_emb, r_pos = jax.random.split(rng, 4)
        return {
            "enc_layers": jax.vmap(self._enc_layer_init)(
                jax.random.split(r_e, cfg.encoder_layers)
            ),
            "dec_layers": jax.vmap(self._dec_layer_init)(
                jax.random.split(r_d, cfg.num_layers)
            ),
            "embed": embed_init(r_emb, cfg.padded_vocab, cfg.d_model),
            "dec_pos": (jax.random.normal(r_pos, (cfg.max_positions, cfg.d_model), jnp.float32)
                        * 0.02).astype(PARAM_DTYPE),
            "enc_final_norm": layernorm_init(cfg.d_model),
            "dec_final_norm": layernorm_init(cfg.d_model),
        }

    # ------------------------------------------------------------ pieces
    def _mlp(self, p, x):
        return dense(p["down"], jax.nn.gelu(dense(p["up"], x)))

    def _proj_qkv(self, p, xq, xkv):
        cfg = self.cfg
        b, s = xq.shape[:2]
        t = xkv.shape[1]
        q = dense(p["q"], xq).reshape(b, s, cfg.num_heads, cfg.head_dim)
        k = dense(p["k"], xkv).reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
        v = dense(p["v"], xkv).reshape(b, t, cfg.num_kv_heads, cfg.head_dim)
        return q, k, v

    def encode(self, params, frames):
        """frames: [b, enc_seq, d] precomputed embeddings (stub frontend)."""
        cfg = self.cfg
        x = frames.astype(PARAM_DTYPE) + _sinusoid(frames.shape[1], cfg.d_model).astype(PARAM_DTYPE)
        x = sharding.shard_batch_seq(x)

        def body(h, p):
            hn = layernorm(p["attn_norm"], h, cfg.norm_eps)
            q, k, v = self._proj_qkv(p["attn"], hn, hn)
            a = gqa_attention(q, k, v, causal=False)
            h = h + dense(p["attn"]["o"], a.reshape(h.shape[0], h.shape[1], -1))
            h = h + self._mlp(p["mlp"], layernorm(p["mlp_norm"], h, cfg.norm_eps))
            return sharding.shard_batch_seq(h), None

        x, _ = self._scan_layers(body, x, params["enc_layers"], cfg.encoder_layers)
        return layernorm(params["enc_final_norm"], x, cfg.norm_eps)

    def _decoder(self, params, tokens, enc_out, *, return_kv: bool, remat: bool = True):
        cfg = self.cfg
        b, s = tokens.shape
        x = params["embed"]["table"][tokens] + params["dec_pos"][:s][None]
        x = sharding.shard_batch_seq(x)

        def body(h, p):
            hn = layernorm(p["self_norm"], h, cfg.norm_eps)
            q, k, v = self._proj_qkv(p["self_attn"], hn, hn)
            if s >= 2048 and s % 1024 == 0:
                a = flash_attention(q, k, v, causal=True)
            else:
                a = gqa_attention(q, k, v, causal=True)
            h = h + dense(p["self_attn"]["o"], a.reshape(b, s, -1))
            hn = layernorm(p["cross_norm"], h, cfg.norm_eps)
            cq, ck, cv = self._proj_qkv(p["cross_attn"], hn, enc_out)
            ca = gqa_attention(cq, ck, cv, causal=False)
            h = h + dense(p["cross_attn"]["o"], ca.reshape(b, s, -1))
            h = h + self._mlp(p["mlp"], layernorm(p["mlp_norm"], h, cfg.norm_eps))
            caches = {"k": k, "v": v, "ck": ck, "cv": cv} if return_kv else {}
            return sharding.shard_batch_seq(h), caches

        if remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        x, caches = self._scan_layers(body, x, params["dec_layers"], cfg.num_layers)
        x = layernorm(params["dec_final_norm"], x, cfg.norm_eps)
        return x, caches

    def _logits(self, params, x):
        return x @ params["embed"]["table"].T.astype(x.dtype)

    # ------------------------------------------------------------- train
    def train_loss(self, params, batch, *, remat: bool = True):
        cfg = self.cfg
        enc_out = self.encode(params, batch["frames"])
        x, _ = self._decoder(params, batch["tokens"], enc_out, return_kv=False, remat=remat)
        logits = self._logits(params, x[:, :-1]).astype(jnp.float32)
        labels = batch["tokens"][:, 1:]
        from repro.models.transformer import _sharded_nll

        nll = _sharded_nll(logits, labels, cfg.vocab_size)
        return nll.mean(), {"nll": nll.mean()}

    # ----------------------------------------------------------- prefill
    def prefill(self, params, batch, *, max_blocks_margin: int = 16, remat: bool = True):
        cfg = self.cfg
        bs = self.BLOCK_SIZE
        enc_out = self.encode(params, batch["frames"])
        tokens = batch["tokens"]
        b, s = tokens.shape
        x, caches = self._decoder(params, tokens, enc_out, return_kv=True, remat=remat)
        logits = self._logits(params, x[:, -1])

        k, v = caches["k"], caches["v"]  # [L, b, s, g, hd]
        L, _, _, g, hd = k.shape
        spb = -(-s // bs)
        pad = spb * bs - s
        if pad:
            k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
            v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        per_seq = spb + max_blocks_margin
        padb = ((0, 0), (0, 0), (0, max_blocks_margin), (0, 0), (0, 0), (0, 0))
        state = EncDecState(
            context_lens=jnp.full((b,), s, jnp.int32),
            k_pages=jnp.pad(k.reshape(L, b, spb, bs, g, hd), padb),
            v_pages=jnp.pad(v.reshape(L, b, spb, bs, g, hd), padb),
            block_tables=jnp.broadcast_to(
                jnp.arange(per_seq, dtype=jnp.int32)[None, :], (b, per_seq)
            ),
            cross_k=caches["ck"],
            cross_v=caches["cv"],
        )
        return logits, state

    def decode_state_shape(self, batch: int, context_len: int, *, margin: int = 16,
                           dtype=jnp.bfloat16) -> EncDecState:
        cfg = self.cfg
        sds = jax.ShapeDtypeStruct
        L, g, hd, bs = cfg.num_layers, cfg.num_kv_heads, cfg.head_dim, self.BLOCK_SIZE
        per_seq = -(-context_len // bs) + margin
        return EncDecState(
            context_lens=sds((batch,), jnp.int32),
            k_pages=sds((L, batch, per_seq, bs, g, hd), dtype),
            v_pages=sds((L, batch, per_seq, bs, g, hd), dtype),
            block_tables=sds((batch, per_seq), jnp.int32),
            cross_k=sds((L, batch, cfg.encoder_seq, g, hd), dtype),
            cross_v=sds((L, batch, cfg.encoder_seq, g, hd), dtype),
        )

    # -------------------------------------------------------- decode step
    def decode_step(self, params, state: EncDecState, tokens):
        cfg = self.cfg
        b = tokens.shape[0]
        pos = state.context_lens
        x = params["embed"]["table"][tokens] + params["dec_pos"][pos]

        # KV pages as scan carry (in-place per-layer update) — see
        # DecoderLM.decode_step §Perf iter 1.
        def body(carry, inp):
            h, kp_all, vp_all = carry
            p, cache, idx = inp
            hn = layernorm(p["self_norm"], h, cfg.norm_eps)
            q, k, v = self._proj_qkv(p["self_attn"], hn[:, None, :], hn[:, None, :])
            pages = KVPages(
                jax.lax.dynamic_index_in_dim(kp_all, idx, 0, False),
                jax.lax.dynamic_index_in_dim(vp_all, idx, 0, False),
            )
            a, pages = paged_decode_with_write(
                q[:, 0], k[:, 0], v[:, 0], pages, state.block_tables, pos,
            )
            kp_all = jax.lax.dynamic_update_index_in_dim(kp_all, pages.k_pages, idx, 0)
            vp_all = jax.lax.dynamic_update_index_in_dim(vp_all, pages.v_pages, idx, 0)
            h = h + dense(p["self_attn"]["o"], a.reshape(b, -1))
            hn = layernorm(p["cross_norm"], h, cfg.norm_eps)
            cq = dense(p["cross_attn"]["q"], hn).reshape(b, 1, cfg.num_heads, cfg.head_dim)
            ca = gqa_attention(cq, cache["cross_k"], cache["cross_v"], causal=False)
            h = h + dense(p["cross_attn"]["o"], ca.reshape(b, -1))
            h = h + self._mlp(p["mlp"], layernorm(p["mlp_norm"], h, cfg.norm_eps))
            return (h, kp_all, vp_all), {}

        caches = {"cross_k": state.cross_k, "cross_v": state.cross_v}
        idxs = jnp.arange(cfg.num_layers, dtype=jnp.int32)
        (x, kp_all, vp_all), _ = self._scan_layers(
            body, (x, state.k_pages, state.v_pages),
            (params["dec_layers"], caches, idxs), cfg.num_layers)
        x = layernorm(params["dec_final_norm"], x, cfg.norm_eps)
        logits = self._logits(params, x)
        new_state = dataclasses.replace(
            state,
            k_pages=kp_all,
            v_pages=vp_all,
            context_lens=state.context_lens + 1,
        )
        return logits, new_state
