"""Activation-sharding context for model code.

XLA's sharding propagation reliably carries *parameter* shardings into
matmuls but loses the batch/TP factorization across gathers, reshapes
and scans (measured: an unconstrained yi-9b train step materialized
f32[256,4096,11008] — global batch × global d_ff — on every device).
Model code therefore asks for constraints at layer boundaries through
this context.  When no mesh is set (unit tests, CPU examples) every
helper is a no-op, so model code never depends on distribution.

Also hosts the GQA sharding policy:
  * heads divisible by TP → shard heads;
  * else if kv-groups divisible → shard groups;
  * else leave attention unsharded on heads (batch DP still applies).
"""
from __future__ import annotations

import contextlib
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "set_mesh", "get_mesh", "mesh_context", "dp_axes", "tp_size",
    "constrain", "shard_batch_seq", "shard_heads", "shard_ff", "shard_dim0",
]

_STATE: dict = {"mesh": None, "dp": ("data",), "tp": "model", "tp_folded": False}


def set_mesh(mesh: Mesh | None, *, fold_model_axis: bool = False) -> None:
    """fold_model_axis=True: the 'model' axis joins data parallelism
    (DP+EP deployment for archs whose dims can't use TP — see
    ModelConfig.fold_model_axis_into_dp)."""
    _STATE["mesh"] = mesh
    _STATE["tp_folded"] = fold_model_axis
    if mesh is not None:
        dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
        if fold_model_axis and "model" in mesh.shape:
            dp = dp + ("model",)
        _STATE["dp"] = dp


def tp_folded() -> bool:
    return _STATE["tp_folded"]


@contextlib.contextmanager
def mesh_context(mesh: Mesh | None):
    prev = _STATE["mesh"]
    set_mesh(mesh)
    try:
        yield
    finally:
        set_mesh(prev)


def get_mesh() -> Mesh | None:
    return _STATE["mesh"]


def dp_axes() -> tuple[str, ...]:
    return _STATE["dp"]


def tp_axis() -> str:
    return _STATE["tp"]


def tp_size() -> int:
    mesh = get_mesh()
    if mesh is None or _STATE["tp_folded"]:
        return 1
    return mesh.shape[_STATE["tp"]]


def dp_size() -> int:
    mesh = get_mesh()
    if mesh is None:
        return 1
    n = 1
    for a in dp_axes():
        n *= mesh.shape[a]
    return n


def constrain(x: jax.Array, spec: P) -> jax.Array:
    mesh = get_mesh()
    if mesh is None:
        return x
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def _fits(dim: int, size: int) -> bool:
    return size > 1 and dim % size == 0 and dim >= size


def _best_dp_axes(batch: int) -> tuple[str, ...]:
    """Longest prefix of the DP axes whose product divides ``batch``."""
    mesh = get_mesh()
    axes = dp_axes()
    while axes:
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if batch % n == 0 and batch >= n:
            return axes
        axes = axes[:-1]
    return ()


def shard_batch_seq(x: jax.Array) -> jax.Array:
    """[b, ...] → batch over the largest dividing prefix of the DP axes."""
    mesh = get_mesh()
    if mesh is None:
        return x
    axes = _best_dp_axes(x.shape[0])
    if not axes:
        return constrain(x, P(*([None] * x.ndim)))
    return constrain(x, P(axes, *([None] * (x.ndim - 1))))


def shard_heads(x: jax.Array, axis: int) -> jax.Array:
    """[b, ..., h(axis), ...] → batch over dp, heads over TP if divisible."""
    mesh = get_mesh()
    if mesh is None:
        return x
    spec: list = [None] * x.ndim
    axes = _best_dp_axes(x.shape[0])
    if axes:
        spec[0] = axes
    tp = tp_size()
    if _fits(x.shape[axis], tp):
        spec[axis] = tp_axis()
    return constrain(x, P(*spec))


def shard_heads2(x: jax.Array, axis_a: int, axis_b: int) -> jax.Array:
    """Shard the first of (axis_a, axis_b) that divides TP; batch over DP."""
    mesh = get_mesh()
    if mesh is None:
        return x
    spec: list = [None] * x.ndim
    axes = _best_dp_axes(x.shape[0])
    if axes:
        spec[0] = axes
    tp = tp_size()
    if _fits(x.shape[axis_a], tp):
        spec[axis_a] = tp_axis()
    elif _fits(x.shape[axis_b], tp):
        spec[axis_b] = tp_axis()
    return constrain(x, P(*spec))


def shard_ff(x: jax.Array) -> jax.Array:
    """[..., ff] → ff over TP; batch over dp."""
    mesh = get_mesh()
    if mesh is None:
        return x
    spec: list = [None] * x.ndim
    if _fits(x.shape[0], dp_size()):
        spec[0] = dp_axes()
    if _fits(x.shape[-1], tp_size()):
        spec[-1] = tp_axis()
    return constrain(x, P(*spec))


def shard_dim0(x: jax.Array, axis_name: str = "data") -> jax.Array:
    mesh = get_mesh()
    if mesh is None or not _fits(x.shape[0], mesh.shape.get(axis_name, 1)):
        return x
    spec: list = [None] * x.ndim
    spec[0] = axis_name
    return constrain(x, P(*spec))


def constrain_moe_hidden(h: jax.Array) -> jax.Array:
    """[E, n, ff] expert hidden: E over 'data' (EP), ff over TP."""
    mesh = get_mesh()
    if mesh is None:
        return h
    spec: list = [None, None, None]
    if _fits(h.shape[0], mesh.shape.get("data", 1)):
        spec[0] = "data"
    if _fits(h.shape[-1], tp_size()):
        spec[-1] = tp_axis()
    return constrain(h, P(*spec))
