"""Blockwise (flash) attention in pure JAX with a custom VJP.

Why this exists (and why it's built this way):

* 32K-token prefill / 4K train shapes cannot materialize [s, t] score
  matrices — attention must be blockwise online-softmax.
* The block schedule is a STATIC triangular (or banded, for sliding
  window) list of (q_chunk, kv_chunk) pairs.  Compared with "scan q,
  mask future kv" this executes EXACTLY the useful FLOPs — no 2× causal
  waste — which matters because the §Roofline compute term is read off
  the compiled HLO.
* Backward is a custom VJP (FlashAttention-2 style recomputation from
  saved logsumexp), so scan-over-layers + remat never stores per-pair
  residuals.

The Pallas kernel in repro.kernels.flash_prefill implements the same
schedule for TPU; this module is its oracle (tests assert allclose) and
the dry-run body.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

__all__ = ["flash_attention", "pair_schedule"]

NEG_INF = -1e30


def pair_schedule(
    s: int, t: int, q_chunk: int, k_chunk: int,
    *, causal: bool, window: int = 0, prefix: int = 0, q_offset: int = 0,
) -> tuple[tuple[int, ...], tuple[int, ...]]:
    """Static (i, j) block pairs that contain ≥1 visible (q, k) position.

    q position of chunk i spans [q_offset + i·cq, q_offset + (i+1)·cq);
    k position of chunk j spans [j·ck, (j+1)·ck).  Visibility:
    k ≤ q (causal) ∧ (k > q − window ∨ k < prefix) (sliding window).
    """
    pi, pj = [], []
    nq, nk = s // q_chunk, t // k_chunk
    for i in range(nq):
        q_lo = q_offset + i * q_chunk
        q_hi = q_lo + q_chunk - 1
        for j in range(nk):
            k_lo = j * k_chunk
            k_hi = k_lo + k_chunk - 1
            if causal and k_lo > q_hi:
                continue  # fully in the future
            if window:
                fully_out = k_hi <= q_lo - window
                covers_prefix = prefix > 0 and k_lo < prefix
                if fully_out and not covers_prefix:
                    continue
            pi.append(i)
            pj.append(j)
    return tuple(pi), tuple(pj)


def _block_mask(q_pos, k_pos, *, causal, window, prefix):
    """[cq, ck] visibility for absolute positions."""
    qp, kp = q_pos[:, None], k_pos[None, :]
    m = jnp.ones((q_pos.shape[0], k_pos.shape[0]), bool)
    if causal:
        m &= kp <= qp
    if window:
        vis = kp > qp - window
        if prefix:
            vis |= kp < prefix
        m &= vis
    return m


def _fwd_scan(q, k, v, pi, pj, cq, ck, causal, window, prefix, q_offset):
    """q: [b, g, qpg, s, d]; k, v: [b, g, t, d] → (out, lse)."""
    b, g, qpg, s, d = q.shape
    t = k.shape[2]
    nq = s // cq
    scale = d ** -0.5
    # carry laid out nq-major for dynamic row updates
    m0 = jnp.full((nq, b, g, qpg, cq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nq, b, g, qpg, cq), jnp.float32)
    a0 = jnp.zeros((nq, b, g, qpg, cq, d), jnp.float32)

    def step(carry, ij):
        m, l, acc = carry
        i, j = ij
        qi = jax.lax.dynamic_slice_in_dim(q, i * cq, cq, axis=3)
        kj = jax.lax.dynamic_slice_in_dim(k, j * ck, ck, axis=2)
        vj = jax.lax.dynamic_slice_in_dim(v, j * ck, ck, axis=2)
        sij = jnp.einsum("bgqcd,bgkd->bgqck", qi, kj).astype(jnp.float32) * scale
        mask = _block_mask(
            q_offset + i * cq + jnp.arange(cq), j * ck + jnp.arange(ck),
            causal=causal, window=window, prefix=prefix,
        )
        sij = jnp.where(mask, sij, NEG_INF)

        mi = jnp.maximum(m[i], sij.max(-1))
        p = jnp.exp(sij - mi[..., None])
        corr = jnp.exp(m[i] - mi)
        li = l[i] * corr + p.sum(-1)
        ai = acc[i] * corr[..., None] + jnp.einsum(
            "bgqck,bgkd->bgqcd", p.astype(v.dtype), vj
        ).astype(jnp.float32)
        return (m.at[i].set(mi), l.at[i].set(li), acc.at[i].set(ai)), None

    (m, l, acc), _ = jax.lax.scan(
        step, (m0, l0, a0), (jnp.asarray(pi, jnp.int32), jnp.asarray(pj, jnp.int32))
    )
    l = jnp.maximum(l, 1e-30)
    out = acc / l[..., None]
    lse = m + jnp.log(l)
    # back to [b, g, qpg, s, d]
    out = jnp.moveaxis(out, 0, 3).reshape(b, g, qpg, s, d)
    lse = jnp.moveaxis(lse, 0, 3).reshape(b, g, qpg, s)
    return out.astype(q.dtype), lse


def _bwd_scan(q, k, v, out, lse, dout, pi, pj, cq, ck, causal, window, prefix, q_offset):
    b, g, qpg, s, d = q.shape
    t = k.shape[2]
    scale = d ** -0.5
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1)  # [b,g,qpg,s]

    dq0 = jnp.zeros(q.shape, jnp.float32)
    dk0 = jnp.zeros(k.shape, jnp.float32)
    dv0 = jnp.zeros(v.shape, jnp.float32)

    def step(carry, ij):
        dq, dk, dv = carry
        i, j = ij
        qi = jax.lax.dynamic_slice_in_dim(q, i * cq, cq, axis=3)
        kj = jax.lax.dynamic_slice_in_dim(k, j * ck, ck, axis=2)
        vj = jax.lax.dynamic_slice_in_dim(v, j * ck, ck, axis=2)
        lse_i = jax.lax.dynamic_slice_in_dim(lse, i * cq, cq, axis=3)
        do_i = jax.lax.dynamic_slice_in_dim(dout, i * cq, cq, axis=3).astype(jnp.float32)
        dl_i = jax.lax.dynamic_slice_in_dim(delta, i * cq, cq, axis=3)

        sij = jnp.einsum("bgqcd,bgkd->bgqck", qi, kj).astype(jnp.float32) * scale
        mask = _block_mask(
            q_offset + i * cq + jnp.arange(cq), j * ck + jnp.arange(ck),
            causal=causal, window=window, prefix=prefix,
        )
        sij = jnp.where(mask, sij, NEG_INF)
        p = jnp.exp(sij - lse_i[..., None])                       # [b,g,qpg,cq,ck]
        dvj = jnp.einsum("bgqck,bgqcd->bgkd", p, do_i)
        dp = jnp.einsum("bgqcd,bgkd->bgqck", do_i, vj.astype(jnp.float32))
        ds = p * (dp - dl_i[..., None]) * scale
        dqi = jnp.einsum("bgqck,bgkd->bgqcd", ds, kj.astype(jnp.float32))
        dkj = jnp.einsum("bgqck,bgqcd->bgkd", ds, qi.astype(jnp.float32))

        dq = jax.lax.dynamic_update_slice_in_dim(
            dq, jax.lax.dynamic_slice_in_dim(dq, i * cq, cq, 3) + dqi, i * cq, 3
        )
        dk = jax.lax.dynamic_update_slice_in_dim(
            dk, jax.lax.dynamic_slice_in_dim(dk, j * ck, ck, 2) + dkj, j * ck, 2
        )
        dv = jax.lax.dynamic_update_slice_in_dim(
            dv, jax.lax.dynamic_slice_in_dim(dv, j * ck, ck, 2) + dvj, j * ck, 2
        )
        return (dq, dk, dv), None

    (dq, dk, dv), _ = jax.lax.scan(
        step, (dq0, dk0, dv0), (jnp.asarray(pi, jnp.int32), jnp.asarray(pj, jnp.int32))
    )
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def _flash(q, k, v, cq, ck, causal, window, prefix, q_offset):
    pi, pj = pair_schedule(q.shape[3], k.shape[2], cq, ck, causal=causal,
                           window=window, prefix=prefix, q_offset=q_offset)
    out, _ = _fwd_scan(q, k, v, pi, pj, cq, ck, causal, window, prefix, q_offset)
    return out


def _flash_fwd(q, k, v, cq, ck, causal, window, prefix, q_offset):
    pi, pj = pair_schedule(q.shape[3], k.shape[2], cq, ck, causal=causal,
                           window=window, prefix=prefix, q_offset=q_offset)
    out, lse = _fwd_scan(q, k, v, pi, pj, cq, ck, causal, window, prefix, q_offset)
    return out, (q, k, v, out, lse)


def _flash_bwd(cq, ck, causal, window, prefix, q_offset, res, dout):
    q, k, v, out, lse = res
    pi, pj = pair_schedule(q.shape[3], k.shape[2], cq, ck, causal=causal,
                           window=window, prefix=prefix, q_offset=q_offset)
    return _bwd_scan(q, k, v, out, lse, dout, pi, pj, cq, ck, causal, window, prefix, q_offset)


_flash.defvjp(_flash_fwd, _flash_bwd)


def flash_attention(
    q: jax.Array,   # [b, s, h, d]
    k: jax.Array,   # [b, t, g, d]
    v: jax.Array,
    *,
    causal: bool = True,
    sliding_window: int = 0,
    prefix_len: int = 0,
    q_offset: int = 0,
    q_chunk: int = 1024,
    k_chunk: int = 1024,
) -> jax.Array:
    """GQA blockwise attention; drop-in for gqa_attention on chunk-aligned
    full-sequence inputs (prefill / train)."""
    from repro.models import sharding

    b, s, h, d = q.shape
    t, g = k.shape[1], k.shape[2]
    cq, ck = min(q_chunk, s), min(k_chunk, t)
    if s % cq or t % ck:
        raise ValueError(f"seq ({s},{t}) not chunk-aligned ({cq},{ck})")
    # GQA/TP sharding policy (§Perf iter, MQA cell — EXPERIMENTS.md):
    #   1. kv groups divide TP        → shard g on both sides (clean).
    #   2. within-group q-heads divide → shard qpg; K/V stay REPLICATED
    #      (for MQA/GQA they are tiny: g·d ≤ 1K lanes).  This replaced a
    #      physical h//g-fold K/V repeat that re-materialized and
    #      resharded per layer (granite-34b: 48× for MQA).
    #   3. only total heads divide    → repeat K/V (deepseek: 64h/8g on
    #      TP-16; the Pallas kernel does this mapping in-register on TPU).
    tp = sharding.tp_size()
    qpg = h // g
    if tp > 1 and g % tp and qpg % tp and h % tp == 0:
        k = jnp.repeat(k, h // g, axis=2)
        v = jnp.repeat(v, h // g, axis=2)
        g, qpg = h, 1
    qg = jnp.moveaxis(q.reshape(b, s, g, qpg, d), 1, 3)     # [b,g,qpg,s,d]
    kg = jnp.moveaxis(k, 1, 2)                              # [b,g,t,d]
    vg = jnp.moveaxis(v, 1, 2)
    qg = sharding.shard_heads2(qg, 1, 2)   # prefer g, else qpg
    kg = sharding.shard_heads(kg, 1)
    vg = sharding.shard_heads(vg, 1)
    out = _flash(qg, kg, vg, cq, ck, causal, sliding_window, prefix_len, q_offset)
    return jnp.moveaxis(out, 3, 1).reshape(b, s, h, d)
