"""Attention: GQA + RoPE, full/causal/sliding-window, and paged decode.

Prefill uses a dense causal attention (the flash_prefill Pallas kernel is
the TPU hot-path; this jnp path is the oracle and the dry-run body — same
FLOPs, so roofline terms are identical).  Decode reads the paged KV cache
through block tables — the same blocks the KVDirect transfer engine fills.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

__all__ = [
    "rope", "attn_init", "gqa_attention", "paged_decode_attention",
    "write_prefill_kv", "write_token_kv", "KVPages",
    "paged_decode_with_write",
]

from repro.models import sharding
from repro.models.layers import PARAM_DTYPE, dense, dense_init


def attn_init(rng, d_model: int, num_heads: int, num_kv_heads: int, head_dim: int,
              *, bias: bool = False):
    rq, rk, rv, ro = jax.random.split(rng, 4)
    return {
        "q": dense_init(rq, d_model, num_heads * head_dim, bias=bias),
        "k": dense_init(rk, d_model, num_kv_heads * head_dim, bias=bias),
        "v": dense_init(rv, d_model, num_kv_heads * head_dim, bias=bias),
        "o": dense_init(ro, num_heads * head_dim, d_model, bias=bias),
    }


def rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: [..., seq, heads, head_dim]; positions: [..., seq]."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    # [..., seq, 1, half] — broadcasts over the heads axis of x
    angles = positions[..., :, None, None].astype(jnp.float32) * freqs
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def _grouped_scores(q, k):
    """q: [b, s, h, d]; k: [b, t, g, d] with h = g * q_per_g → [b, g, qpg, s, t]."""
    b, s, h, d = q.shape
    g = k.shape[2]
    qg = q.reshape(b, s, g, h // g, d)
    return jnp.einsum("bsgqd,btgd->bgqst", qg, k)


def gqa_attention(
    q: jax.Array,           # [b, s, h, d]
    k: jax.Array,           # [b, t, g, d]
    v: jax.Array,           # [b, t, g, d]
    *,
    causal: bool = True,
    sliding_window: int = 0,
    q_offset: jax.Array | int = 0,   # absolute position of q[0] (decode)
    kv_len: jax.Array | None = None,  # valid kv length per batch [b]
    prefix_len: int = 0,             # always-visible prefix (meta tokens / enc-dec)
) -> jax.Array:
    b, s, h, d = q.shape
    t, g = k.shape[1], k.shape[2]
    scores = _grouped_scores(q, k).astype(jnp.float32) * (d ** -0.5)

    qp = jnp.asarray(q_offset).reshape(-1, 1) + jnp.arange(s)   # [b or 1, s]
    kp = jnp.arange(t)                                          # [t]
    valid = jnp.ones((qp.shape[0], s, t), dtype=bool)
    if causal:
        valid &= kp[None, None, :] <= qp[:, :, None]
    if sliding_window:
        in_window = kp[None, None, :] > qp[:, :, None] - sliding_window
        if prefix_len:
            in_window |= kp[None, None, :] < prefix_len  # meta tokens always visible
        valid &= in_window
    if kv_len is not None:
        valid &= kp[None, None, :] < jnp.asarray(kv_len).reshape(-1, 1, 1)
    valid = valid[:, None, None, :, :]  # → broadcast with [b, g, qpg, s, t]
    scores = jnp.where(valid, scores, -jnp.inf)
    w = jax.nn.softmax(scores, axis=-1)
    w = jnp.where(jnp.isnan(w), 0.0, w).astype(v.dtype)  # fully-masked rows
    out = jnp.einsum("bgqst,btgd->bsgqd", w, v)
    return out.reshape(b, s, h, d)


# ----------------------------------------------------------------------
# Paged KV cache (decode path)
# ----------------------------------------------------------------------
@dataclasses.dataclass
class KVPages:
    """Paged KV for ONE layer on device: the jnp mirror of
    serving.kv_cache.PagedKVCache's per-layer planes.

    k_pages / v_pages: [batch, pages_per_seq, block_size, kv_heads, head_dim]

    The page pool is PER SEQUENCE (block tables hold within-sequence page
    ids).  This is deliberate for sharding: under pjit the batch dim of
    pages, tables and queries all shard over 'data', so the page gather
    is a purely local batched gather — a global pool (vLLM-style) would
    make XLA all-gather the whole cache across data shards.  The
    host-side serving engine still manages a global pool; its block ids
    are translated to per-sequence slots when the device state is built.
    """

    k_pages: jax.Array
    v_pages: jax.Array

    @property
    def block_size(self) -> int:
        return self.k_pages.shape[2]


def write_prefill_kv(k: jax.Array, v: jax.Array, pages_per_seq: int, *, block_size: int = 32) -> KVPages:
    """Lay out prefill KV [b, s, g, d] into per-sequence pages."""
    b, s, g, d = k.shape
    bs = block_size
    if s % bs:
        raise ValueError(f"seq {s} not a multiple of block_size {bs}")
    spb = s // bs
    k_pages = k.reshape(b, spb, bs, g, d)
    v_pages = v.reshape(b, spb, bs, g, d)
    pad = pages_per_seq - spb
    if pad > 0:
        k_pages = jnp.pad(k_pages, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
        v_pages = jnp.pad(v_pages, ((0, 0), (0, pad), (0, 0), (0, 0), (0, 0)))
    return KVPages(k_pages, v_pages)


def write_token_kv(
    pages: KVPages,
    k_new: jax.Array,        # [b, g, d]
    v_new: jax.Array,
    block_tables: jax.Array,  # [b, pages_per_seq] within-sequence page ids
    context_lens: jax.Array,  # [b] tokens already present
) -> KVPages:
    """Scatter one new token's K/V into each sequence's current page."""
    b = k_new.shape[0]
    blk_idx = context_lens // pages.block_size
    blk = jnp.take_along_axis(block_tables, blk_idx[:, None], axis=1)[:, 0]
    off = context_lens % pages.block_size
    rows = jnp.arange(b)
    k_pages = pages.k_pages.at[rows, blk, off].set(k_new.astype(pages.k_pages.dtype))
    v_pages = pages.v_pages.at[rows, blk, off].set(v_new.astype(pages.v_pages.dtype))
    return KVPages(k_pages, v_pages)


def paged_decode_attention(
    q: jax.Array,            # [b, h, d] — one new token per sequence
    pages: KVPages,
    block_tables: jax.Array,  # [b, pages_per_seq]
    context_lens: jax.Array,  # [b] tokens INCLUDING the one just written
    *,
    sliding_window: int = 0,
    prefix_len: int = 0,
) -> jax.Array:
    """Reference paged attention (jnp).  The Pallas kernel in
    repro.kernels.paged_attention implements the same contract."""
    b, h, d = q.shape
    bs = pages.block_size
    g = pages.k_pages.shape[3]
    mb = block_tables.shape[1]
    # batched within-sequence gather: [b, mb, bs, g, d]
    idx = block_tables[:, :, None, None, None]
    k = jnp.take_along_axis(pages.k_pages, idx, axis=1)
    v = jnp.take_along_axis(pages.v_pages, idx, axis=1)
    k = k.reshape(b, mb * bs, g, d)
    v = v.reshape(b, mb * bs, g, d)
    out = gqa_attention(
        q[:, None], k, v,
        causal=True,
        sliding_window=sliding_window,
        q_offset=context_lens - 1,
        kv_len=context_lens,
        prefix_len=prefix_len,
    )
    return out[:, 0]


def simple_attention_params_flops(cfg, seq: int, batch: int) -> float:
    """Attention matmul FLOPs helper used by the simulator cost model."""
    h, d = cfg.num_heads, cfg.head_dim
    return 4.0 * batch * seq * seq * h * d  # QK^T + PV (x2 each for MAC)


# ----------------------------------------------------------------------
# Distributed decode: sequence-parallel "flash decoding" via shard_map
# ----------------------------------------------------------------------
def paged_decode_with_write(
    q: jax.Array,            # [b, h, d]
    k_new: jax.Array,        # [b, g, d]
    v_new: jax.Array,
    pages: KVPages,
    block_tables: jax.Array,  # [b, per_seq]
    context_lens: jax.Array,  # [b] tokens BEFORE this step's write
) -> tuple[jax.Array, KVPages]:
    """Write the new token's KV, then attend over the paged context.

    Distributed path (mesh set, per_seq % TP == 0): the page dim shards
    over the TP axis — 32K-context KV at deepseek-67b scale (1.6 TB) only
    fits HBM when sharded over BOTH data and model axes.  Each shard runs
    a local flash pass over its KV slice, then partial softmax stats
    (m, l, acc — ~b·h·hd floats) combine with tiny psums: the
    "flash-decoding" scheme, mapped onto shard_map.  Naive alternatives
    all-reduce O(b·h·ctx) scores per layer (≈67 MB at this scale) or
    all-gather pages (ruinous).

    Requires the identity page layout the prefill step produces (shard i
    owns within-seq pages [i·pps, (i+1)·pps)).  Falls back to the pure
    jnp path otherwise (CPU engines, tests).
    """
    mesh = sharding.get_mesh()
    # KV sequence-parallelism uses the raw 'model' axis even when TP for
    # weights is folded into DP (small-dim archs): the page shards and
    # the tiny stat psums are orthogonal to weight sharding.
    tp = mesh.shape.get("model", 1) if mesh is not None else 1
    per_seq = pages.k_pages.shape[1]
    if mesh is None or tp == 1 or per_seq % tp:
        new_pages = write_token_kv(pages, k_new, v_new, block_tables, context_lens)
        out = paged_decode_attention(q, new_pages, block_tables, context_lens + 1)
        return out, new_pages

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    b, h, d = q.shape
    g = pages.k_pages.shape[3]
    bs = pages.block_size
    pps = per_seq // tp
    dp = tuple(a for a in ("pod", "data") if a in mesh.shape)
    dpn = 1
    for a in dp:
        dpn *= mesh.shape[a]
    bsp = dp if (dp and b % dpn == 0) else None

    q_spec = P(bsp, None, None)
    kv_new_spec = P(bsp, None, None)
    page_spec = P(bsp, "model", None, None, None)
    tbl_spec = P(bsp, "model")
    len_spec = P(bsp)

    def local(q_l, kn_l, vn_l, kp_l, vp_l, tbl_l, cl_l):
        i = jax.lax.axis_index("model")
        b_l = q_l.shape[0]
        rows = jnp.arange(b_l)
        # ---- ownership-masked write of the new token -------------------
        blk_global = cl_l // bs
        off = cl_l % bs
        own = (blk_global >= i * pps) & (blk_global < (i + 1) * pps)
        blk_local = jnp.clip(blk_global - i * pps, 0, pps - 1)
        cur_k = kp_l[rows, blk_local, off]
        cur_v = vp_l[rows, blk_local, off]
        sel = own[:, None, None]
        kp_l = kp_l.at[rows, blk_local, off].set(
            jnp.where(sel, kn_l.astype(kp_l.dtype), cur_k))
        vp_l = vp_l.at[rows, blk_local, off].set(
            jnp.where(sel, vn_l.astype(vp_l.dtype), cur_v))

        # ---- local flash over this shard's KV slice ---------------------
        # §Perf iter 1: the distributed layout is canonical identity
        # paging (prefill emits it, the write above maintains it), so the
        # shard's KV is already contiguous — a reshape view, NOT a
        # take_along_axis gather (which materialized a full per-layer KV
        # copy: ~2× decode HBM traffic at 32K context).  tbl_l is kept in
        # the signature for layout-compat with the host engine's path.
        del tbl_l
        k_loc = kp_l.reshape(b_l, pps * bs, g, d)
        v_loc = vp_l.reshape(b_l, pps * bs, g, d)
        qg = q_l.reshape(b_l, g, h // g, d)
        scores = jnp.einsum("bgqd,btgd->bgqt", qg, k_loc).astype(jnp.float32)
        scores = scores * (d ** -0.5)
        kpos = i * (pps * bs) + jnp.arange(pps * bs)
        valid = kpos[None, :] <= cl_l[:, None]  # includes the just-written token
        scores = jnp.where(valid[:, None, None, :], scores, -1e30)
        m_l = scores.max(-1)                                     # [b, g, qpg]
        p = jnp.where(valid[:, None, None, :], jnp.exp(scores - m_l[..., None]), 0.0)
        l_l = p.sum(-1)
        acc = jnp.einsum("bgqt,btgd->bgqd", p.astype(v_loc.dtype), v_loc).astype(jnp.float32)

        # ---- combine partial softmax stats across shards ----------------
        m_g = jax.lax.pmax(m_l, "model")
        corr = jnp.exp(m_l - m_g)
        l_g = jax.lax.psum(l_l * corr, "model")
        acc_g = jax.lax.psum(acc * corr[..., None], "model")
        out = (acc_g / jnp.maximum(l_g, 1e-30)[..., None]).reshape(b_l, h, d)
        return out.astype(q_l.dtype), kp_l, vp_l

    out, k_pages, v_pages = shard_map(
        local,
        mesh=mesh,
        in_specs=(q_spec, kv_new_spec, kv_new_spec, page_spec, page_spec, tbl_spec, len_spec),
        out_specs=(q_spec, page_spec, page_spec),
    )(q, k_new, v_new, pages.k_pages, pages.v_pages, block_tables, context_lens)
    return out, KVPages(k_pages, v_pages)
