"""Unified decoder-only LM covering dense / MoE / VLM / SSM / hybrid.

Design rules:
  * layers are UNIFORM per model so params stack as [L, ...] leaves and
    every full-depth pass is a single ``jax.lax.scan`` (compile time and
    HLO size stay sane at 95 layers, remat applies per-layer).  For
    interleaved-MoE models (Llama-4: dense/MoE alternating) the scan unit
    is a GROUP of ``cfg.moe_every`` layers so the stack stays uniform;
  * prefill RETURNS the per-layer KV pages / SSM states — the exact
    tensors KVDirect transfers to the decode worker;
  * decode consumes a paged KV cache (block tables) or a ring buffer
    (sliding-window) or SSM state slots — matching what the transfer
    engine fills;
  * everything runs under ``jax.eval_shape`` for the dry-run.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import sharding
from repro.models.attention import (
    KVPages,
    attn_init,
    gqa_attention,
    paged_decode_with_write,
    rope,
)
from repro.models.config import ModelConfig
from repro.models.flash import flash_attention
from repro.models.layers import (
    PARAM_DTYPE,
    dense,
    embed_init,
    rmsnorm,
    rmsnorm_init,
    swiglu,
    swiglu_init,
)
from repro.models.moe import moe_apply, moe_init
from repro.models.ssm import ssm_init, ssm_prefill, ssm_state_shapes, ssm_step

__all__ = ["DecoderLM", "DecodeState"]


# ----------------------------------------------------------------------
# Decode-time state (a pytree; every leaf is a jnp array)
# ----------------------------------------------------------------------
@jax.tree_util.register_dataclass
@dataclasses.dataclass
class DecodeState:
    context_lens: jax.Array                 # [b] tokens present (incl. prompt)
    # paged attention KV (dense/moe/vlm); pages are per-sequence (see
    # attention.KVPages for the sharding rationale)
    k_pages: jax.Array | None = None        # [L, b, per_seq, bs, g, hd]
    v_pages: jax.Array | None = None
    block_tables: jax.Array | None = None   # [b, per_seq] within-seq ids
    # ring buffer KV (sliding-window archs)
    ring_k: jax.Array | None = None         # [L, b, cap, g, hd]
    ring_v: jax.Array | None = None
    ring_pos: jax.Array | None = None       # [b, cap] absolute positions (-1 empty)
    # meta-token KV (hymba; always visible)
    meta_k: jax.Array | None = None         # [L, b, m, g, hd]
    meta_v: jax.Array | None = None
    # SSM state
    ssd_state: jax.Array | None = None      # [L, b, nh, hd, ns]
    conv_state: jax.Array | None = None     # [L, b, k-1, c]


def _sharded_nll(logits: jax.Array, labels: jax.Array, vocab_size: int) -> jax.Array:
    """Cross-entropy that never gathers the vocab axis.

    ``take_along_axis`` on a vocab-sharded [b, s, V] logits tensor makes
    the SPMD partitioner all-gather the full fp32 logits (hundreds of GB
    at V≈64K, b·s≈1M).  The one-hot-select formulation keeps every op
    elementwise/reduction on the sharded axis.
    """
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape, logits.ndim - 1)
    valid = vocab_iota < vocab_size
    masked = jnp.where(valid, logits, -jnp.inf)
    lse = jax.nn.logsumexp(masked, axis=-1)
    label_logit = jnp.sum(
        jnp.where(vocab_iota == labels[..., None], logits, 0.0), axis=-1
    )
    return lse - label_logit


def _barrier(x: jax.Array) -> jax.Array:
    return jax.lax.optimization_barrier(x)


def _gelu_mlp(p, x):
    return dense(p["down"], jax.nn.gelu(dense(p["up"], x)))


def _gelu_mlp_init(rng, d_model, d_ff):
    import jax.random as jr

    r1, r2 = jr.split(rng)
    from repro.models.layers import dense_init

    return {"up": dense_init(r1, d_model, d_ff), "down": dense_init(r2, d_ff, d_model)}


class DecoderLM:
    BLOCK_SIZE = 32

    def __init__(self, cfg: ModelConfig, *, unroll: bool = False):
        if cfg.is_encoder_decoder:
            raise ValueError("use EncDecLM for encoder-decoder configs")
        self.cfg = cfg
        # scan unit: a group of `moe_every` layers for interleaved MoE
        self.group = cfg.moe_every if (cfg.family == "moe" and cfg.moe_every > 1) else 1
        if cfg.num_layers % self.group:
            raise ValueError("num_layers must divide by moe_every")
        self.n_steps = cfg.num_layers // self.group
        # unroll=True replaces scan-over-layers with a python loop — used
        # by the dry-run's depth-1/2 analysis variants, where FLOPs/bytes
        # must be visible to cost_analysis (which counts a while-loop body
        # exactly once regardless of trip count; see EXPERIMENTS.md).
        self.unroll = unroll

    def _scan_layers(self, body, carry, xs):
        if not self.unroll:
            return jax.lax.scan(body, carry, xs)
        ys = []
        for i in range(self.n_steps):
            step_x = jax.tree.map(lambda a: a[i], xs)
            carry, y = body(carry, step_x)
            ys.append(y)
        if not ys or not jax.tree.leaves(ys[0]):
            return carry, ys[0] if ys else {}
        return carry, jax.tree.map(lambda *zs: jnp.stack(zs), *ys)

    def _sub_kind(self, i: int) -> str:
        """FFN kind of sub-layer i within a group: MoE is the LAST of each
        group (Llama-4 places MoE on every `moe_every`-th layer)."""
        if self.cfg.family != "moe":
            return {"dense": "mlp", "vlm": "mlp", "hybrid": "mlp", "ssm": "none"}[self.cfg.family]
        return "moe" if i == self.group - 1 else "mlp"

    # ------------------------------------------------------------- init
    def init_params(self, rng) -> dict:
        cfg = self.cfg
        r_embed, r_layers, r_head, r_meta = jax.random.split(rng, 4)
        step_rngs = jax.random.split(r_layers, self.n_steps)
        params = {
            "embed": embed_init(r_embed, cfg.padded_vocab, cfg.d_model),
            "layers": jax.vmap(self._init_group)(step_rngs),
            "final_norm": rmsnorm_init(cfg.d_model),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = embed_init(r_head, cfg.padded_vocab, cfg.d_model)
        if cfg.num_meta_tokens:
            params["meta"] = (
                jax.random.normal(r_meta, (cfg.num_meta_tokens, cfg.d_model), dtype=jnp.float32)
                * 0.02
            ).astype(PARAM_DTYPE)
        return params

    def _init_group(self, rng) -> dict:
        if self.group == 1:
            return self._init_sub(rng, self._sub_kind(0))
        rngs = jax.random.split(rng, self.group)
        return {f"sub{i}": self._init_sub(rngs[i], self._sub_kind(i)) for i in range(self.group)}

    def _init_sub(self, rng, ffn_kind: str) -> dict:
        cfg = self.cfg
        r_attn, r_mlp, r_ssm = jax.random.split(rng, 3)
        p: dict[str, Any] = {}
        if cfg.has_attention:
            p["attn_norm"] = rmsnorm_init(cfg.d_model)
            p["attn"] = attn_init(r_attn, cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim)
        if cfg.has_ssm:
            p["ssm_norm"] = rmsnorm_init(cfg.d_model)
            p["ssm"] = ssm_init(r_ssm, cfg)
        if cfg.family == "hybrid":
            p["attn_out_norm"] = rmsnorm_init(cfg.d_model)
            p["ssm_out_norm"] = rmsnorm_init(cfg.d_model)
        if ffn_kind == "moe":
            p["mlp_norm"] = rmsnorm_init(cfg.d_model)
            p["moe"] = moe_init(r_mlp, cfg)
        elif ffn_kind == "mlp":
            ff = cfg.d_ff_dense if (cfg.family == "moe" and cfg.d_ff_dense) else cfg.d_ff
            p["mlp_norm"] = rmsnorm_init(cfg.d_model)
            p["mlp"] = (
                swiglu_init(r_mlp, cfg.d_model, ff)
                if cfg.mlp_type == "swiglu"
                else _gelu_mlp_init(r_mlp, cfg.d_model, ff)
            )
        return p

    def _apply_mlp(self, p, x):
        return swiglu(p["mlp"], x) if self.cfg.mlp_type == "swiglu" else _gelu_mlp(p["mlp"], x)

    # ------------------------------------------------- full-seq forward
    def _sub_full(self, p, x, positions, ffn_kind: str, return_kv: bool):
        cfg = self.cfg
        outs, caches = [], {}
        if cfg.has_attention:
            h = rmsnorm(p["attn_norm"], x, cfg.norm_eps)
            b, s, _ = h.shape
            q = dense(p["attn"]["q"], h).reshape(b, s, cfg.num_heads, cfg.head_dim)
            k = dense(p["attn"]["k"], h).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
            v = dense(p["attn"]["v"], h).reshape(b, s, cfg.num_kv_heads, cfg.head_dim)
            q = sharding.shard_heads(rope(q, positions, cfg.rope_theta), 2)
            k = sharding.shard_heads(rope(k, positions, cfg.rope_theta), 2)
            v = sharding.shard_heads(v, 2)
            # largest power-of-two chunk dividing s (meta tokens make
            # hymba's seq 4096+128=4224, which is 128-aligned only)
            chunk = next((c for c in (1024, 512, 256, 128, 64) if s % c == 0), 0)
            if s >= 2048 and chunk:
                # blockwise flash: exact-FLOPs triangular schedule,
                # O(chunk²) memory — required for 4K train / 32K prefill
                a = flash_attention(
                    q, k, v, causal=True,
                    sliding_window=cfg.sliding_window,
                    prefix_len=cfg.num_meta_tokens,
                    q_chunk=chunk, k_chunk=chunk,
                )
            else:
                a = gqa_attention(
                    q, k, v, causal=True,
                    sliding_window=cfg.sliding_window,
                    prefix_len=cfg.num_meta_tokens,
                )
            a = dense(p["attn"]["o"], a.reshape(b, s, -1))
            outs.append(("attn", a))
            if return_kv:
                caches["k"], caches["v"] = k, v
        if cfg.has_ssm:
            h = rmsnorm(p["ssm_norm"], x, cfg.norm_eps)
            y, (ssd_final, conv_tail) = ssm_prefill(p["ssm"], h, cfg)
            outs.append(("ssm", y))
            if return_kv:
                caches["ssd"], caches["conv"] = ssd_final, conv_tail
        if cfg.family == "hybrid":
            mixed = 0.5 * (
                rmsnorm(p["attn_out_norm"], dict(outs)["attn"], cfg.norm_eps)
                + rmsnorm(p["ssm_out_norm"], dict(outs)["ssm"], cfg.norm_eps)
            )
        else:
            mixed = outs[0][1]
        # optimization_barrier pins the residual stream to bf16 at the
        # TP-psum boundaries: without it XLA hoists the rmsnorm fp32
        # upcast INTO the all-reduce, doubling every per-layer collective
        # (§Perf, prefill cell — measured 2× wire).
        x = _barrier(sharding.shard_batch_seq(x + mixed))

        aux = jnp.zeros((), jnp.float32)
        if ffn_kind == "moe":
            hn = rmsnorm(p["mlp_norm"], x, cfg.norm_eps)
            y, aux = moe_apply(p["moe"], hn, cfg)
            x = x + y
        elif ffn_kind == "mlp":
            x = x + self._apply_mlp(p, rmsnorm(p["mlp_norm"], x, cfg.norm_eps))
        return _barrier(sharding.shard_batch_seq(x)), caches, aux

    def _group_full(self, x, p, positions, return_kv: bool):
        if self.group == 1:
            return self._sub_full(p, x, positions, self._sub_kind(0), return_kv)
        caches_list, aux = [], jnp.zeros((), jnp.float32)
        for i in range(self.group):
            x, c, a = self._sub_full(p[f"sub{i}"], x, positions, self._sub_kind(i), return_kv)
            caches_list.append(c)
            aux = aux + a
        stacked = {}
        if return_kv and caches_list[0]:
            stacked = {
                key: jnp.stack([c[key] for c in caches_list]) for key in caches_list[0]
            }
        return x, stacked, aux

    def _embed_inputs(self, params, tokens, vision_embeds=None):
        """Token embeddings (+ VLM early fusion, + meta-token prefix).
        Returns (x, offset) where offset is where text starts."""
        cfg = self.cfg
        x = params["embed"]["table"][tokens]
        offset = 0
        if cfg.family == "vlm" and vision_embeds is not None:
            x = jnp.concatenate([vision_embeds.astype(x.dtype), x], axis=1)
            offset += vision_embeds.shape[1]
        if cfg.num_meta_tokens:
            meta = jnp.broadcast_to(
                params["meta"][None], (x.shape[0], cfg.num_meta_tokens, cfg.d_model)
            ).astype(x.dtype)
            x = jnp.concatenate([meta, x], axis=1)
            offset += cfg.num_meta_tokens
        return sharding.shard_batch_seq(x), offset

    def _backbone(self, params, x, positions, *, return_kv: bool, remat: bool):
        def body(carry, p):
            h, aux = carry
            h, caches, a = self._group_full(h, p, positions, return_kv)
            return (h, aux + a), caches

        if remat:
            body = jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        (x, aux), caches = self._scan_layers(body, (x, jnp.zeros((), jnp.float32)), params["layers"])
        if self.group > 1 and caches:
            # [steps, group, ...] → [L, ...]
            caches = jax.tree.map(lambda a: a.reshape((-1,) + a.shape[2:]), caches)
        x = rmsnorm(params["final_norm"], x, self.cfg.norm_eps)
        return x, caches, aux / self.cfg.num_layers

    def _logits(self, params, x):
        table = params.get("lm_head", params["embed"])["table"]
        return x @ table.T.astype(x.dtype)

    # ------------------------------------------------------------ train
    def train_loss(self, params, batch, *, remat: bool = True):
        """batch: tokens [b, s] (+ optional vision_embeds).  Next-token CE."""
        cfg = self.cfg
        tokens = batch["tokens"]
        x, offset = self._embed_inputs(params, tokens, batch.get("vision_embeds"))
        positions = jnp.arange(x.shape[1])[None, :].repeat(x.shape[0], 0)
        x, _, aux = self._backbone(params, x, positions, return_kv=False, remat=remat)
        x = x[:, offset:, :]  # loss only on text positions
        logits = self._logits(params, x[:, :-1, :]).astype(jnp.float32)
        labels = tokens[:, 1:]
        nll = _sharded_nll(logits, labels, cfg.vocab_size)
        loss = nll.mean()
        if cfg.family == "moe":
            loss = loss + 0.01 * aux
        return loss, {"nll": nll.mean(), "aux": aux}

    # ---------------------------------------------------------- prefill
    def prefill(self, params, batch, *, max_blocks_margin: int = 16, remat: bool = True):
        """Run the prompt, return (last-token logits, DecodeState).

        The KV pages / SSM states inside the returned DecodeState are the
        transferable artifacts: on a disaggregated cluster they live on
        the prefill worker and the decode worker pulls them (KVDirect).
        """
        tokens = batch["tokens"]
        b = tokens.shape[0]
        x, _ = self._embed_inputs(params, tokens, batch.get("vision_embeds"))
        s_total = x.shape[1]
        positions = jnp.arange(s_total)[None, :].repeat(b, 0)
        x, caches, _ = self._backbone(params, x, positions, return_kv=True, remat=remat)
        logits = self._logits(params, x[:, -1, :])
        state = self._caches_to_state(caches, b, s_total, max_blocks_margin)
        return logits, state

    def _caches_to_state(self, caches, b, s_total, margin):
        cfg = self.cfg
        bs = self.BLOCK_SIZE
        state = DecodeState(context_lens=jnp.full((b,), s_total, jnp.int32))
        if cfg.has_attention:
            k, v = caches["k"], caches["v"]  # [L, b, s, g, hd]
            L = k.shape[0]
            m = cfg.num_meta_tokens
            if cfg.sliding_window:
                cap = cfg.sliding_window + bs
                if m:
                    state.meta_k, state.meta_v = k[:, :, :m], v[:, :, :m]
                    k, v = k[:, :, m:], v[:, :, m:]
                s = k.shape[2]
                take = min(cap, s)
                tail_pos = jnp.arange(s - take, s) + m  # absolute positions
                slots = tail_pos % cap
                ring_k = jnp.zeros((L, b, cap) + k.shape[3:], k.dtype)
                ring_v = jnp.zeros_like(ring_k)
                ring_pos = jnp.full((b, cap), -1, jnp.int32)
                ring_k = ring_k.at[:, :, slots].set(k[:, :, s - take :])
                ring_v = ring_v.at[:, :, slots].set(v[:, :, s - take :])
                ring_pos = ring_pos.at[:, slots].set(tail_pos[None, :])
                state.ring_k, state.ring_v, state.ring_pos = ring_k, ring_v, ring_pos
            else:
                L, _, s, g, hd = k.shape
                spb = -(-s // bs)
                pad_s = spb * bs - s
                if pad_s:
                    k = jnp.pad(k, ((0, 0), (0, 0), (0, pad_s), (0, 0), (0, 0)))
                    v = jnp.pad(v, ((0, 0), (0, 0), (0, pad_s), (0, 0), (0, 0)))
                per_seq = spb + margin
                k_pages = k.reshape(L, b, spb, bs, g, hd)
                v_pages = v.reshape(L, b, spb, bs, g, hd)
                padb = ((0, 0), (0, 0), (0, margin), (0, 0), (0, 0), (0, 0))
                state.k_pages = jnp.pad(k_pages, padb)
                state.v_pages = jnp.pad(v_pages, padb)
                state.block_tables = jnp.broadcast_to(
                    jnp.arange(per_seq, dtype=jnp.int32)[None, :], (b, per_seq)
                )
        if cfg.has_ssm:
            state.ssd_state = caches["ssd"]    # [L, b, nh, hd, ns]
            state.conv_state = caches["conv"]  # [L, b, k-1, c]
        return state

    # -------------------------------------------------- dry-run plumbing
    def decode_state_shape(self, batch: int, context_len: int, *, margin: int = 16,
                           dtype=jnp.bfloat16) -> DecodeState:
        """ShapeDtypeStruct pytree for a decode state holding
        ``context_len`` tokens — what input_specs() hands the dry-run."""
        cfg = self.cfg
        bs = self.BLOCK_SIZE
        sds = jax.ShapeDtypeStruct
        L = cfg.num_layers
        g, hd = cfg.num_kv_heads, cfg.head_dim
        state = DecodeState(context_lens=sds((batch,), jnp.int32))
        if cfg.has_attention:
            if cfg.sliding_window:
                cap = cfg.sliding_window + bs
                state.ring_k = sds((L, batch, cap, g, hd), dtype)
                state.ring_v = sds((L, batch, cap, g, hd), dtype)
                state.ring_pos = sds((batch, cap), jnp.int32)
                if cfg.num_meta_tokens:
                    state.meta_k = sds((L, batch, cfg.num_meta_tokens, g, hd), dtype)
                    state.meta_v = sds((L, batch, cfg.num_meta_tokens, g, hd), dtype)
            else:
                per_seq = -(-context_len // bs) + margin
                state.k_pages = sds((L, batch, per_seq, bs, g, hd), dtype)
                state.v_pages = sds((L, batch, per_seq, bs, g, hd), dtype)
                state.block_tables = sds((batch, per_seq), jnp.int32)
        if cfg.has_ssm:
            ssd_shape, conv_shape = ssm_state_shapes(cfg, batch)
            state.ssd_state = sds((L,) + ssd_shape, jnp.float32)
            state.conv_state = sds((L,) + conv_shape, dtype)
        return state

    # ------------------------------------------------------ decode step
    def decode_step(self, params, state: DecodeState, tokens):
        """One token for every sequence.  tokens: [b] → (logits [b, V],
        new DecodeState)."""
        cfg = self.cfg
        x = params["embed"]["table"][tokens]  # [b, d]
        pos = state.context_lens  # absolute position of the new token

        caches = self._per_layer_caches(state)
        # §Perf iter 1: KV pages travel as scan CARRY with per-layer
        # dynamic slice/update, not as xs→ys streams — the xs→ys form made
        # XLA copy the full per-layer page buffers every step (a second
        # full pass over the KV cache per decode token).  Carry buffers
        # alias across scan iterations, so the update is in place.
        paged = "k_pages" in caches
        kp_all = caches.pop("k_pages", None)
        vp_all = caches.pop("v_pages", None)
        if self.group > 1 and caches:
            caches = jax.tree.map(
                lambda a: a.reshape((self.n_steps, self.group) + a.shape[1:]), caches
            )

        def sub(h, p, cache, kind, kp_all, vp_all, layer_idx):
            if paged:
                cache = dict(cache)
                cache["k_pages"] = jax.lax.dynamic_index_in_dim(kp_all, layer_idx, 0, False)
                cache["v_pages"] = jax.lax.dynamic_index_in_dim(vp_all, layer_idx, 0, False)
            h, nc = self._sub_decode(p, h, pos, state, cache, kind)
            if paged:
                kp_all = jax.lax.dynamic_update_index_in_dim(
                    kp_all, nc.pop("k_pages"), layer_idx, 0)
                vp_all = jax.lax.dynamic_update_index_in_dim(
                    vp_all, nc.pop("v_pages"), layer_idx, 0)
            return h, nc, kp_all, vp_all

        def body(carry, inp):
            h, kp_all, vp_all = carry
            p, cache, step_idx = inp
            if self.group == 1:
                h, nc, kp_all, vp_all = sub(
                    h, p, cache, self._sub_kind(0), kp_all, vp_all, step_idx)
                return (h, kp_all, vp_all), nc
            new_caches = []
            for i in range(self.group):
                sub_cache = jax.tree.map(lambda a: a[i], cache)
                h, nc, kp_all, vp_all = sub(
                    h, p[f"sub{i}"], sub_cache, self._sub_kind(i),
                    kp_all, vp_all, step_idx * self.group + i)
                new_caches.append(nc)
            stacked = (
                {k: jnp.stack([c[k] for c in new_caches]) for k in new_caches[0]}
                if new_caches[0] else {}
            )
            return (h, kp_all, vp_all), stacked

        step_ids = jnp.arange(self.n_steps, dtype=jnp.int32)
        (x, kp_all, vp_all), new_caches = self._scan_layers(
            body, (x, kp_all, vp_all), (params["layers"], caches, step_ids))
        if self.group > 1 and new_caches:
            new_caches = jax.tree.map(
                lambda a: a.reshape((-1,) + a.shape[2:]), new_caches
            )
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = self._logits(params, x)
        new_state = self._store_caches(state, new_caches or {})
        if paged:
            new_state.k_pages, new_state.v_pages = kp_all, vp_all
        new_state.context_lens = state.context_lens + 1
        return logits, new_state

    # ------------------------------------------- layerwise decode step
    def decode_step_layerwise(self, params, state: DecodeState, tokens,
                              fetch_layer):
        """One decode step for paged-attention archs where layer ``l``'s
        KV pages are produced ON DEMAND by ``fetch_layer(l) ->
        (k_pages_l, v_pages_l)`` (each ``[b, per_seq, bs, g, hd]``)
        immediately before layer ``l``'s attention runs.

        This is the compute half of KVDirect's layer-streamed pull: the
        transfer engine lands layer 0 first, so a decode worker's
        ``fetch_layer`` can block on ``TransferFuture.wait_layer(l)`` and
        start attending over early layers while later layers are still in
        flight.  The math is the per-layer body of ``decode_step`` run as
        a python loop instead of a ``lax.scan`` — same primitives on the
        same values, so logits and the new KV pages are bit-identical to
        the full-state step (tests/test_layerwise.py pins this).

        ``state.k_pages``/``v_pages`` may be None; the returned state
        carries the stacked per-layer pages, so subsequent steps go
        through the ordinary ``decode_step``.
        """
        cfg = self.cfg
        if not cfg.has_attention or cfg.sliding_window or cfg.has_ssm:
            raise NotImplementedError(
                "layerwise decode covers paged-KV attention archs; ring/SSM "
                "caches have no layer-streamed pull to consume")
        x = params["embed"]["table"][tokens]
        pos = state.context_lens
        new_k: list = [None] * cfg.num_layers
        new_v: list = [None] * cfg.num_layers
        for step in range(self.n_steps):
            p = jax.tree.map(lambda a: a[step], params["layers"])
            for i in range(self.group):
                layer = step * self.group + i
                sub_p = p if self.group == 1 else p[f"sub{i}"]
                k_pages, v_pages = fetch_layer(layer)
                cache = {"k_pages": k_pages, "v_pages": v_pages}
                x, nc = self._sub_decode(sub_p, x, pos, state, cache,
                                         self._sub_kind(i))
                new_k[layer], new_v[layer] = nc["k_pages"], nc["v_pages"]
        x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
        logits = self._logits(params, x)
        new_state = dataclasses.replace(
            state,
            k_pages=jnp.stack(new_k),
            v_pages=jnp.stack(new_v),
            context_lens=state.context_lens + 1,
        )
        return logits, new_state

    def _per_layer_caches(self, state: DecodeState) -> dict:
        c = {}
        if state.k_pages is not None:
            c["k_pages"], c["v_pages"] = state.k_pages, state.v_pages
        if state.ring_k is not None:
            c["ring_k"], c["ring_v"] = state.ring_k, state.ring_v
        if state.meta_k is not None:
            c["meta_k"], c["meta_v"] = state.meta_k, state.meta_v
        if state.ssd_state is not None:
            c["ssd"], c["conv"] = state.ssd_state, state.conv_state
        return c

    def _store_caches(self, state: DecodeState, new_caches: dict) -> DecodeState:
        s = dataclasses.replace(state)
        if "k_pages" in new_caches:
            s.k_pages, s.v_pages = new_caches["k_pages"], new_caches["v_pages"]
        if "ring_k" in new_caches:
            s.ring_k, s.ring_v = new_caches["ring_k"], new_caches["ring_v"]
            # every layer writes the same slot/pos; keep one copy
            s.ring_pos = new_caches["ring_pos"][0]
        if "ssd" in new_caches:
            s.ssd_state, s.conv_state = new_caches["ssd"], new_caches["conv"]
        return s

    def _sub_decode(self, p, h, pos, state: DecodeState, cache: dict, ffn_kind: str):
        cfg = self.cfg
        b, d = h.shape
        new_cache = {}
        outs = []
        if cfg.has_attention:
            hn = rmsnorm(p["attn_norm"], h, cfg.norm_eps)
            q = dense(p["attn"]["q"], hn).reshape(b, 1, cfg.num_heads, cfg.head_dim)
            k = dense(p["attn"]["k"], hn).reshape(b, 1, cfg.num_kv_heads, cfg.head_dim)
            v = dense(p["attn"]["v"], hn).reshape(b, 1, cfg.num_kv_heads, cfg.head_dim)
            q = rope(q, pos[:, None], cfg.rope_theta)[:, 0]
            k = rope(k, pos[:, None], cfg.rope_theta)[:, 0]
            v = v[:, 0]
            if cfg.sliding_window:
                a, nc = self._ring_attention(q, k, v, pos, state, cache)
                new_cache.update(nc)
            else:
                pages = KVPages(cache["k_pages"], cache["v_pages"])
                a, pages = paged_decode_with_write(
                    q, k, v, pages, state.block_tables, state.context_lens,
                )
                new_cache["k_pages"], new_cache["v_pages"] = pages.k_pages, pages.v_pages
            a = dense(p["attn"]["o"], a.reshape(b, -1))
            outs.append(("attn", a))
        if cfg.has_ssm:
            hn = rmsnorm(p["ssm_norm"], h, cfg.norm_eps)
            y, (ssd, conv) = ssm_step(p["ssm"], hn, cfg, (cache["ssd"], cache["conv"]))
            new_cache["ssd"], new_cache["conv"] = ssd, conv
            outs.append(("ssm", y))
        if cfg.family == "hybrid":
            mixed = 0.5 * (
                rmsnorm(p["attn_out_norm"], dict(outs)["attn"], cfg.norm_eps)
                + rmsnorm(p["ssm_out_norm"], dict(outs)["ssm"], cfg.norm_eps)
            )
        else:
            mixed = outs[0][1]
        h = h + mixed
        if ffn_kind == "moe":
            y, _ = moe_apply(p["moe"], rmsnorm(p["mlp_norm"], h, cfg.norm_eps)[:, None, :], cfg)
            h = h + y[:, 0]
        elif ffn_kind == "mlp":
            h = h + self._apply_mlp(p, rmsnorm(p["mlp_norm"], h, cfg.norm_eps))
        return h, new_cache

    def _ring_attention(self, q, k_new, v_new, pos, state: DecodeState, cache: dict):
        """Sliding-window decode via ring buffer + always-visible meta KV."""
        cfg = self.cfg
        b = q.shape[0]
        cap = cache["ring_k"].shape[1]
        slot = pos % cap
        ring_k = cache["ring_k"].at[jnp.arange(b), slot].set(k_new.astype(cache["ring_k"].dtype))
        ring_v = cache["ring_v"].at[jnp.arange(b), slot].set(v_new.astype(cache["ring_v"].dtype))
        ring_pos = state.ring_pos.at[jnp.arange(b), slot].set(pos)

        ks, vs, ps = ring_k, ring_v, ring_pos
        meta_len = 0
        if "meta_k" in cache:
            meta_len = cache["meta_k"].shape[1]
            ks = jnp.concatenate([cache["meta_k"], ks], axis=1)
            vs = jnp.concatenate([cache["meta_v"], vs], axis=1)
        g = ks.shape[2]
        hq = q.reshape(b, g, cfg.num_heads // g, cfg.head_dim)
        scores = jnp.einsum("bgqd,bsgd->bgqs", hq, ks).astype(jnp.float32) * (cfg.head_dim ** -0.5)
        slot_valid = (ps >= 0) & (ps <= pos[:, None]) & (ps > pos[:, None] - cfg.sliding_window)
        if meta_len:
            meta_valid = jnp.ones((b, meta_len), bool)
            slot_valid = jnp.concatenate([meta_valid, slot_valid], axis=1)
        scores = jnp.where(slot_valid[:, None, None, :], scores, -jnp.inf)
        w = jax.nn.softmax(scores, axis=-1).astype(vs.dtype)
        out = jnp.einsum("bgqs,bsgd->bgqd", w, vs).reshape(b, cfg.num_heads, cfg.head_dim)
        return out, {"ring_k": ring_k, "ring_v": ring_v, "ring_pos": ring_pos}
