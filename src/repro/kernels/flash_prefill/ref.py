"""Pure-jnp oracles for the flash_prefill kernel: the dense attention AND
the blockwise custom-VJP implementation (models.flash) — the kernel must
match both."""
from __future__ import annotations

from repro.models.attention import gqa_attention
from repro.models.flash import flash_attention

__all__ = ["dense_ref", "blockwise_ref"]


def dense_ref(q, k, v, *, causal=True, sliding_window=0, prefix_len=0):
    return gqa_attention(q, k, v, causal=causal, sliding_window=sliding_window,
                         prefix_len=prefix_len)


def blockwise_ref(q, k, v, *, causal=True, sliding_window=0, prefix_len=0,
                  q_chunk=256, k_chunk=256):
    return flash_attention(q, k, v, causal=causal, sliding_window=sliding_window,
                           prefix_len=prefix_len, q_chunk=q_chunk, k_chunk=k_chunk)
