"""Pallas TPU kernel: blockwise causal (flash) attention for prefill.

The TPU-native sibling of repro.models.flash (which is the oracle and
the dry-run body).  Grid = (batch·kv_groups, q_blocks, kv_blocks) with
the kv dim sequential; online-softmax stats live in VMEM scratch.  GQA
is handled in the K/V index_map (q-group → kv-head), so K/V are streamed
once per group without physical repetition — on real hardware this is
the memory-bandwidth advantage over the jnp path's repeat.

Causal + sliding-window + meta-prefix masking matches
models.flash.pair_schedule semantics; fully-masked kv blocks are skipped
with pl.when (predication — no MXU work issued).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    q_ref,    # [1, 1, bq, d]  (b, g-q-slice)
    k_ref,    # [1, 1, bk, d]
    v_ref,    # [1, 1, bk, d]
    o_ref,    # [1, 1, bq, d]
    m_ref,    # [bq, 128] f32
    l_ref,    # [bq, 128] f32
    acc_ref,  # [bq, d] f32
    *,
    block_q: int,
    block_k: int,
    n_k: int,
    causal: bool,
    window: int,
    prefix: int,
):
    i = pl.program_id(2)
    j = pl.program_id(3)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_lo = i * block_q
    k_lo = j * block_k
    # block-level visibility (mirror of models.flash.pair_schedule)
    visible = True
    if causal:
        visible = k_lo <= q_lo + block_q - 1
    if window:
        fully_out = (k_lo + block_k - 1) <= q_lo - window
        covers_prefix = (prefix > 0) & (k_lo < prefix)
        visible = visible & (~fully_out | covers_prefix)

    @pl.when(visible)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)   # [bq, d]
        k = k_ref[0, 0].astype(jnp.float32)   # [bk, d]
        v = v_ref[0, 0].astype(jnp.float32)
        d = q.shape[-1]
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
        ) * (d ** -0.5)                        # [bq, bk]
        qp = q_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 0)
        kp = k_lo + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_k), 1)
        mask = jnp.ones((block_q, block_k), jnp.bool_)
        if causal:
            mask &= kp <= qp
        if window:
            vis = kp > qp - window
            if prefix:
                vis |= kp < prefix
            mask &= vis
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[:, 0]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        p = jnp.where(mask, jnp.exp(s - m_new[:, None]), 0.0)
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=-1)
        m_ref[:, 0] = m_new
        acc_ref[...] = acc_ref[...] * corr[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    @pl.when(j == n_k - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "sliding_window", "prefix_len", "block_q", "block_k", "interpret"),
)
def flash_prefill(
    q: jax.Array,   # [b, s, h, d]
    k: jax.Array,   # [b, t, g, d]
    v: jax.Array,
    *,
    causal: bool = True,
    sliding_window: int = 0,
    prefix_len: int = 0,
    block_q: int = 256,
    block_k: int = 256,
    interpret: bool = False,
) -> jax.Array:
    b, s, h, d = q.shape
    t, g = k.shape[1], k.shape[2]
    qpg = h // g
    bq, bk = min(block_q, s), min(block_k, t)
    if s % bq or t % bk:
        raise ValueError(f"seq ({s},{t}) not block-aligned ({bq},{bk})")
    n_q, n_k = s // bq, t // bk

    # layouts: q [b, h, s, d] so (group, in-group head) factor per grid;
    # k/v [b, g, t, d]; grid maps head-index → kv-group in the index_map.
    qt = q.transpose(0, 2, 1, 3)
    kt = k.transpose(0, 2, 1, 3)
    vt = v.transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _kernel, block_q=bq, block_k=bk, n_k=n_k,
        causal=causal, window=sliding_window, prefix=prefix_len,
    )
    out = pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, i, j: (b_, h_ // (h // k.shape[2]), j, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h_, i, j: (b_, h_ // (h // k.shape[2]), j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h_, i, j: (b_, h_, i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, s, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, 128), jnp.float32),
            pltpu.VMEM((bq, d), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)
