"""jit'd public entry point for blockwise prefill attention."""
from __future__ import annotations

import jax

from repro.kernels.flash_prefill.kernel import flash_prefill as _kernel

__all__ = ["flash_prefill_op"]


def flash_prefill_op(q, k, v, *, causal=True, sliding_window=0, prefix_len=0,
                     block_q=256, block_k=256):
    interpret = jax.default_backend() != "tpu"
    return _kernel(q, k, v, causal=causal, sliding_window=sliding_window,
                   prefix_len=prefix_len, block_q=block_q, block_k=block_k,
                   interpret=interpret)
