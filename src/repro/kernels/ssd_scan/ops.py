"""jit'd public entry point for the SSD chunked scan."""
from __future__ import annotations

import jax

from repro.kernels.ssd_scan.kernel import ssd_scan as _kernel

__all__ = ["ssd_scan_op"]


def ssd_scan_op(x, dt, a, B, C, d_skip, *, chunk: int = 128):
    interpret = jax.default_backend() != "tpu"
    return _kernel(x, dt, a, B, C, d_skip, chunk=chunk, interpret=interpret)
