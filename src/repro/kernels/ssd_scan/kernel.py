"""Pallas TPU kernel: Mamba-2 SSD chunked scan (state-space duality).

Grid = (batch, heads, chunks) with the chunk dim sequential; the running
SSD state [hd, ns] lives in VMEM scratch across chunks.  Within a chunk
everything is MXU matmuls ([l,l] decay-masked score matrix, [l,hd]
outputs, [hd,ns] state update) — the SSD insight that the recurrence
becomes attention-like block compute.

Oracle: repro.models.ssm._ssd_chunked (pure jnp, also the model body).
B and C are shared across heads (ngroups=1), matching the models/ssm
layout; the A decay and D skip are scalar-prefetched per head.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(
    a_vec,      # [nh] scalar prefetch: per-head A (negative)
    d_vec,      # [nh] scalar prefetch: per-head D skip
    x_ref,      # [1, 1, l, hd]
    dt_ref,     # [1, 1, l]
    b_ref,      # [1, l, ns]
    c_ref,      # [1, l, ns]
    y_ref,      # [1, 1, l, hd]
    state_out,  # [1, 1, hd, ns]
    state_ref,  # scratch [hd, ns] f32
    *,
    n_chunks: int,
    chunk: int,
):
    h_idx = pl.program_id(1)
    c_idx = pl.program_id(2)

    @pl.when(c_idx == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)        # [l, hd]
    dt = dt_ref[0, 0].astype(jnp.float32)      # [l]
    B = b_ref[0].astype(jnp.float32)           # [l, ns]
    C = c_ref[0].astype(jnp.float32)           # [l, ns]
    a = a_vec[h_idx].astype(jnp.float32)
    d_skip = d_vec[h_idx].astype(jnp.float32)

    da = dt * a                                # [l] log-decay per step
    cum = jnp.cumsum(da)                       # [l]
    seg = cum[:, None] - cum[None, :]          # decay j→i
    tril = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0) >= \
        jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(tril, jnp.exp(seg), 0.0)     # [l, l]

    xbar = x * dt[:, None]                     # [l, hd]
    scores = jax.lax.dot_general(              # C·Bᵀ ∘ L
        C, B, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * L
    y = jax.lax.dot_general(                   # within-chunk
        scores, xbar, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )
    # read prior state with in-chunk decay: y += exp(cum)·(C·stateᵀ)
    state_t = state_ref[...]                   # [hd, ns]
    y_off = jax.lax.dot_general(
        C, state_t, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * jnp.exp(cum)[:, None]                  # [l, hd]
    y = y + y_off + d_skip * x
    y_ref[0, 0] = y.astype(y_ref.dtype)

    # state' = exp(Σda)·state + Σ_j exp(cum[-1]-cum[j])·x̄_jᵀ B_j
    decay_states = jnp.exp(cum[-1] - cum)      # [l]
    upd = jax.lax.dot_general(
        xbar * decay_states[:, None], B,
        (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32,
    )                                          # [hd, ns]
    state_ref[...] = state_t * jnp.exp(cum[-1]) + upd

    @pl.when(c_idx == n_chunks - 1)
    def _emit_state():
        state_out[0, 0] = state_ref[...]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(
    x: jax.Array,    # [b, s, nh, hd]
    dt: jax.Array,   # [b, s, nh]  (post-softplus)
    a: jax.Array,    # [nh] negative decay
    B: jax.Array,    # [b, s, ns]
    C: jax.Array,    # [b, s, ns]
    d_skip: jax.Array,  # [nh]
    *,
    chunk: int = 128,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Returns (y [b, s, nh, hd], final_state [b, nh, hd, ns])."""
    b, s, nh, hd = x.shape
    ns = B.shape[-1]
    l = min(chunk, s)
    if s % l:
        raise ValueError(f"seq {s} not chunk-aligned ({l})")
    nc = s // l

    xt = x.transpose(0, 2, 1, 3)       # [b, nh, s, hd]
    dtt = dt.transpose(0, 2, 1)        # [b, nh, s]

    kernel = functools.partial(_kernel, n_chunks=nc, chunk=l)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, nh, nc),
        in_specs=[
            pl.BlockSpec((1, 1, l, hd), lambda b_, h_, c_, av, dv: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, l), lambda b_, h_, c_, av, dv: (b_, h_, c_)),
            pl.BlockSpec((1, l, ns), lambda b_, h_, c_, av, dv: (b_, c_, 0)),
            pl.BlockSpec((1, l, ns), lambda b_, h_, c_, av, dv: (b_, c_, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, l, hd), lambda b_, h_, c_, av, dv: (b_, h_, c_, 0)),
            pl.BlockSpec((1, 1, hd, ns), lambda b_, h_, c_, av, dv: (b_, h_, 0, 0)),
        ],
        scratch_shapes=[pltpu.VMEM((hd, ns), jnp.float32)],
    )
    y, state = pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=[
            jax.ShapeDtypeStruct((b, nh, s, hd), x.dtype),
            jax.ShapeDtypeStruct((b, nh, hd, ns), jnp.float32),
        ],
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(a, d_skip, xt, dtt, B, C)
    return y.transpose(0, 2, 1, 3), state
