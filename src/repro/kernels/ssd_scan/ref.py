"""Pure-jnp oracle for the ssd_scan kernel (the model's own SSD body)."""
from __future__ import annotations

import jax.numpy as jnp

from repro.models.ssm import _ssd_chunked

__all__ = ["ssd_scan_ref"]


def ssd_scan_ref(x, dt, a, B, C, d_skip, *, chunk: int = 128):
    y, state = _ssd_chunked(x, dt, a, B, C, chunk)
    y = y + x.astype(jnp.float32) * d_skip[None, None, :, None]
    return y, state
