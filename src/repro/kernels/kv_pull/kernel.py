"""Pallas TPU kernel: descriptor-driven KV page pull — KVDirect's
TRANSFER() on a TPU.

The decode worker computed (remote page id → local page id) pairs from
the connection-time tensor descriptor (core/descriptors.py).  This
kernel executes that transaction list on-device: each grid step DMAs one
page (or one COALESCED RUN of adjacent pages) from the source KV pool
into the destination pool, with the ids scalar-prefetched so the
BlockSpec index_map drives the DMA engine directly — no gather kernel,
no staging buffer, exactly the paper's "one-sided read" data path.

On a real multi-chip deployment the source pool lives on the *prefill*
chip: swap the plain copy for ``pltpu.make_async_remote_copy`` with the
link-aligned ``device_id`` (the decode chip pulls over ICI;
DESIGN.md §2).  The local form below is what we can VALIDATE in
interpret mode; the remote form differs only in the copy primitive.

Two variants:
  * ``kv_pull``       — one page per transaction (uncoalesced).
  * ``kv_pull_runs``  — (src_start, dst_start) runs of ``run_len``
    adjacent pages: the block-coalescing win (§4.2 / Fig. 17) as fewer,
    longer DMA bursts.

The destination pool is input/output-aliased (donated): pages not named
by any transaction keep their existing contents, exactly like an RDMA
write into registered memory.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _copy_kernel(src_ids, dst_ids, src_ref, dst_in_ref, dst_ref):
    """One grid step = one transaction; BlockSpecs did the addressing."""
    del dst_in_ref  # aliased with dst_ref; only written
    dst_ref[...] = src_ref[...]


def _pull(src_pages, dst_pages, src_ids, dst_ids, pages_per_txn, interpret):
    n_txn = src_ids.shape[0]
    _, bs, g, d = src_pages.shape
    blk = (pages_per_txn, bs, g, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(n_txn,),
        in_specs=[
            pl.BlockSpec(blk, lambda i, sid, did: (sid[i], 0, 0, 0)),
            pl.BlockSpec(blk, lambda i, sid, did: (did[i], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec(blk, lambda i, sid, did: (did[i], 0, 0, 0)),
    )
    return pl.pallas_call(
        _copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst_pages.shape, dst_pages.dtype),
        input_output_aliases={3: 0},  # (sid, did, src, DST) -> out
        interpret=interpret,
    )(src_ids, dst_ids, src_pages, dst_pages)


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(1,))
def kv_pull(
    src_pages: jax.Array,   # [n_src, bs, g, d]  (prefill worker pool)
    dst_pages: jax.Array,   # [n_dst, bs, g, d]  (decode worker pool; donated)
    src_ids: jax.Array,     # [n_txn] int32
    dst_ids: jax.Array,     # [n_txn] int32
    *,
    interpret: bool = False,
) -> jax.Array:
    """dst_pages[dst_ids[i]] = src_pages[src_ids[i]] per transaction."""
    return _pull(src_pages, dst_pages, src_ids, dst_ids, 1, interpret)


def _dequant_copy_kernel(src_ids, dst_ids, scales, src_ref, dst_in_ref, dst_ref):
    """One grid step = one QUANTIZED transaction: the landed int8 page is
    dequantized with its per-transaction scale as it is written into the
    destination pool (the delta-transfer wire format, docs/transfer.md)."""
    del src_ids, dst_ids  # consumed by the BlockSpec index maps
    del dst_in_ref        # aliased with dst_ref; only written
    i = pl.program_id(0)
    scale = scales[i]
    dst_ref[...] = (src_ref[...].astype(jnp.float32) * scale).astype(dst_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",), donate_argnums=(1,))
def kv_pull_dequant(
    src_pages: jax.Array,   # [n_src, bs, g, d] int8 (quantized wire pages)
    dst_pages: jax.Array,   # [n_dst, bs, g, d] bf16/f32 (decode pool; donated)
    src_ids: jax.Array,     # [n_txn] int32
    dst_ids: jax.Array,     # [n_txn] int32
    scales: jax.Array,      # [n_txn] f32 — per-transaction dequant scale
    *,
    interpret: bool = False,
) -> jax.Array:
    """dst_pages[dst_ids[i]] = src_pages[src_ids[i]] * scales[i], per
    transaction — the on-device half of quantized delta transfer.  The
    scales ride the scalar-prefetch channel next to the page ids, exactly
    where ``ReadTxn.qscale`` puts them in the CPU engine."""
    n_txn = src_ids.shape[0]
    _, bs, g, d = src_pages.shape
    blk = (1, bs, g, d)

    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=3,
        grid=(n_txn,),
        in_specs=[
            pl.BlockSpec(blk, lambda i, sid, did, sc: (sid[i], 0, 0, 0)),
            pl.BlockSpec(blk, lambda i, sid, did, sc: (did[i], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec(blk, lambda i, sid, did, sc: (did[i], 0, 0, 0)),
    )
    return pl.pallas_call(
        _dequant_copy_kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct(dst_pages.shape, dst_pages.dtype),
        input_output_aliases={4: 0},  # (sid, did, sc, src, DST) -> out
        interpret=interpret,
    )(src_ids, dst_ids, scales, src_pages, dst_pages)


@functools.partial(jax.jit, static_argnames=("run_len", "interpret"), donate_argnums=(1,))
def kv_pull_runs(
    src_pages: jax.Array,    # [n_src, bs, g, d]
    dst_pages: jax.Array,    # [n_dst, bs, g, d]
    src_starts: jax.Array,   # [n_runs] int32 — in units of run_len pages
    dst_starts: jax.Array,   # [n_runs] int32 — in units of run_len pages
    *,
    run_len: int,
    interpret: bool = False,
) -> jax.Array:
    """Coalesced: each grid step moves ``run_len`` ADJACENT pages in one
    DMA burst.  Starts are in run-granularity units (Pallas block index
    semantics), i.e. page_id = start * run_len."""
    return _pull(src_pages, dst_pages, src_starts, dst_starts, run_len, interpret)
