"""Pure-jnp oracle for the kv_pull kernels."""
from __future__ import annotations

import jax

__all__ = ["kv_pull_ref", "kv_pull_runs_ref", "kv_pull_dequant_ref"]


def kv_pull_ref(src_pages, dst_pages, src_ids, dst_ids) -> jax.Array:
    return dst_pages.at[dst_ids].set(src_pages[src_ids])


def kv_pull_dequant_ref(src_pages, dst_pages, src_ids, dst_ids, scales) -> jax.Array:
    """Quantized-transfer oracle: landed int8 pages dequantize with their
    per-transaction scale on the way into the destination pool."""
    import jax.numpy as jnp

    deq = src_pages[src_ids].astype(jnp.float32) * scales[:, None, None, None]
    return dst_pages.at[dst_ids].set(deq.astype(dst_pages.dtype))


def kv_pull_runs_ref(src_pages, dst_pages, src_starts, dst_starts, *, run_len: int) -> jax.Array:
    """Starts are in run-granularity units: page_id = start * run_len."""
    import jax.numpy as jnp

    offs = jnp.arange(run_len)
    src_idx = (src_starts[:, None] * run_len + offs).reshape(-1)
    dst_idx = (dst_starts[:, None] * run_len + offs).reshape(-1)
    return dst_pages.at[dst_idx].set(src_pages[src_idx])
