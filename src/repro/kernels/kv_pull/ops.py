"""jit'd public entry points for the descriptor-driven KV pull."""
from __future__ import annotations

import jax

from repro.kernels.kv_pull.kernel import (
    kv_pull as _pull,
    kv_pull_dequant as _pull_dequant,
    kv_pull_runs as _pull_runs,
)

__all__ = ["kv_pull_op", "kv_pull_runs_op", "kv_pull_dequant_op"]


def kv_pull_op(src_pages, dst_pages, src_ids, dst_ids):
    interpret = jax.default_backend() != "tpu"
    return _pull(src_pages, dst_pages, src_ids, dst_ids, interpret=interpret)


def kv_pull_dequant_op(src_pages, dst_pages, src_ids, dst_ids, scales):
    """Quantized pull: int8 wire pages land dequantized (per-transaction
    scale), matching the CPU engine's ``ReadTxn.qscale`` path."""
    interpret = jax.default_backend() != "tpu"
    return _pull_dequant(src_pages, dst_pages, src_ids, dst_ids, scales,
                         interpret=interpret)


def kv_pull_runs_op(src_pages, dst_pages, src_starts, dst_starts, *, run_len: int):
    interpret = jax.default_backend() != "tpu"
    return _pull_runs(src_pages, dst_pages, src_starts, dst_starts,
                      run_len=run_len, interpret=interpret)
