"""Pallas TPU kernel: paged decode attention over per-sequence page tables.

One grid step = one (sequence, page) pair.  The page id comes from a
SCALAR-PREFETCHED block table — the BlockSpec index_map dereferences the
table, so the DMA engine streams exactly the pages the sequence owns
(HBM→VMEM), never a gathered copy of the whole cache.  Online softmax
stats (m, l, acc) live in VMEM scratch across the page-sequential grid
dimension.

This is the TPU-native sibling of the jnp reference in
repro.models.attention.paged_decode_attention (= ref.py here) and the
same contract the KVDirect transfer engine fills pages for.

Layouts (matching the serving stack):
    q            [b, h, d]
    k_pages      [b, per_seq, bs, g, d]    (per-sequence pools)
    v_pages      [b, per_seq, bs, g, d]
    block_tables [b, per_seq] int32        (within-sequence page ids)
    context_lens [b] int32                 (tokens INCLUDING current)
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(
    # scalar prefetch
    block_tables,      # [b, per_seq]
    context_lens,      # [b]
    # VMEM blocks
    q_ref,             # [1, h, d]
    k_ref,             # [1, 1, bs, g, d]
    v_ref,             # [1, 1, bs, g, d]
    o_ref,             # [1, h, d]
    # scratch
    m_ref,             # [h, 128] f32
    l_ref,             # [h, 128] f32
    acc_ref,           # [h, d] f32
    *,
    pages_per_seq: int,
    block_size: int,
):
    b_idx = pl.program_id(0)
    p_idx = pl.program_id(1)

    @pl.when(p_idx == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    ctx = context_lens[b_idx]
    page_start = p_idx * block_size
    # Skip pages entirely beyond the context.
    @pl.when(page_start < ctx)
    def _compute():
        q = q_ref[0].astype(jnp.float32)                    # [h, d]
        k = k_ref[0, 0].astype(jnp.float32)                 # [bs, g, d]
        v = v_ref[0, 0].astype(jnp.float32)
        h, d = q.shape
        bs, g, _ = k.shape
        qpg = h // g
        qg = q.reshape(g, qpg, d)
        scores = jax.lax.dot_general(
            qg, k.transpose(1, 2, 0),                       # [g, qpg, d] x [g, d, bs]
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        ) * (d ** -0.5)                                     # [g, qpg, bs]
        pos = page_start + jax.lax.broadcasted_iota(jnp.int32, (g, qpg, bs), 2)
        scores = jnp.where(pos < ctx, scores, NEG_INF)
        scores = scores.reshape(h, bs)

        m_prev = m_ref[:, 0]                                # [h]
        m_new = jnp.maximum(m_prev, scores.max(axis=-1))
        p = jnp.exp(scores - m_new[:, None])
        p = jnp.where(scores <= NEG_INF, 0.0, p)
        corr = jnp.exp(m_prev - m_new)
        l_ref[:, 0] = l_ref[:, 0] * corr + p.sum(axis=-1)
        m_ref[:, 0] = m_new
        pv = jax.lax.dot_general(
            p.reshape(g, qpg, bs), v.transpose(1, 0, 2),    # [g, qpg, bs] x [g, bs, d]
            (((2,), (1,)), ((0,), (0,))),
            preferred_element_type=jnp.float32,
        ).reshape(h, d)
        acc_ref[...] = acc_ref[...] * corr[:, None] + pv

    @pl.when(p_idx == pages_per_seq - 1)
    def _finalize():
        l = jnp.maximum(l_ref[:, 0], 1e-30)
        o_ref[0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("interpret",))
def paged_attention(
    q: jax.Array,             # [b, h, d]
    k_pages: jax.Array,       # [b, per_seq, bs, g, d]
    v_pages: jax.Array,
    block_tables: jax.Array,  # [b, per_seq] int32
    context_lens: jax.Array,  # [b] int32
    *,
    interpret: bool = False,
) -> jax.Array:
    b, h, d = q.shape
    _, per_seq, bs, g, _ = k_pages.shape

    kernel = functools.partial(_kernel, pages_per_seq=per_seq, block_size=bs)
    grid_spec = pltpu.PrefetchScalarGridSpec(
        num_scalar_prefetch=2,
        grid=(b, per_seq),
        in_specs=[
            pl.BlockSpec((1, h, d), lambda b_, p_, tbl, cl: (b_, 0, 0)),
            pl.BlockSpec((1, 1, bs, g, d), lambda b_, p_, tbl, cl: (b_, tbl[b_, p_], 0, 0, 0)),
            pl.BlockSpec((1, 1, bs, g, d), lambda b_, p_, tbl, cl: (b_, tbl[b_, p_], 0, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, h, d), lambda b_, p_, tbl, cl: (b_, 0, 0)),
        scratch_shapes=[
            pltpu.VMEM((h, 128), jnp.float32),
            pltpu.VMEM((h, 128), jnp.float32),
            pltpu.VMEM((h, d), jnp.float32),
        ],
    )
    return pl.pallas_call(
        kernel,
        grid_spec=grid_spec,
        out_shape=jax.ShapeDtypeStruct((b, h, d), q.dtype),
        compiler_params=pltpu.CompilerParams(
            dimension_semantics=("parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(block_tables, context_lens, q, k_pages, v_pages)
