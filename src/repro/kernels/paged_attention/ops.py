"""jit'd public entry point for paged decode attention.

TPU runs the Pallas kernel; any other backend (this container's CPU)
runs it in interpret mode, so the BlockSpec pipeline is exercised
everywhere while results stay bit-comparable to ref.py.
"""
from __future__ import annotations

import jax

from repro.kernels.paged_attention.kernel import paged_attention as _kernel

__all__ = ["paged_attention_op"]


def paged_attention_op(q, k_pages, v_pages, block_tables, context_lens):
    interpret = jax.default_backend() != "tpu"
    return _kernel(q, k_pages, v_pages, block_tables, context_lens, interpret=interpret)
