"""Pure-jnp oracle for the paged_attention kernel."""
from __future__ import annotations

import jax

from repro.models.attention import KVPages, paged_decode_attention

__all__ = ["paged_attention_ref"]


def paged_attention_ref(q, k_pages, v_pages, block_tables, context_lens) -> jax.Array:
    return paged_decode_attention(q, KVPages(k_pages, v_pages), block_tables, context_lens)
