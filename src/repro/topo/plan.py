"""Placement planning over a heterogeneous cluster topology.

Helix formulates role assignment on a heterogeneous cluster as max-flow
over the topology graph and solves placement with a MILP.  We keep the
max-flow *objective* — it is the right way to combine the three
bottlenecks (prefill compute, decode KV capacity, cross-partition link
bandwidth) into one number — but replace the MILP with a greedy +
local-search heuristic, so there is no solver dependency and planning a
dozen-machine cluster takes milliseconds.

The flow network for a candidate placement (P = prefill set, D = decode
set), all capacities in requests/second:

    source ──(prefill rate of p)──▶ p ──(link p→d bw / KV bytes)──▶ d
                                          d ──(decode rate of d)──▶ sink

Max-flow through this graph is the cluster's sustainable request rate
under the placement: it is automatically ≤ aggregate prefill throughput,
≤ aggregate decode capacity, and ≤ what the inter-partition links can
carry — and it correctly charges a fast prefill machine that only has
slow paths to decode.  ``PlacementPlanner.plan`` maximizes it.

Rates derive from ``WorkloadShape``: reference-machine request costs
(seconds on the 8×H100 reference node) scaled by each machine's
capability ratios.  ``WorkloadShape.from_cost`` calibrates the reference
costs from a ``sim.costs.CostModel`` so the planner and the simulator
price the same workload identically.
"""
from __future__ import annotations

import dataclasses
import math
from collections import deque

from repro.core.transfer_engine import KVDIRECT_UTIL

from .spec import REF_FLOPS, REF_HBM_BPS, REF_VRAM, ClusterSpec, MachineSpec

__all__ = ["WorkloadShape", "Placement", "PlacementPlanner", "random_placement"]


@dataclasses.dataclass(frozen=True)
class WorkloadShape:
    """The request shape the planner sizes for, with reference-machine
    costs (defaults: 16K/512 requests of a ~123B dense model on the
    8×H100 reference node, matching the paper's headline workload)."""

    prompt_len: int = 16_384
    response_len: int = 512
    decode_batch: int = 8
    kv_bytes_per_token: int = 352 * 1024      # paper §5.1, Mistral-Large-123B
    prefill_s_ref: float = 1.0                # 16K prompt on the reference node
    decode_step_s_ref: float = 0.012          # one iteration at decode_batch
    cap_tokens_ref: int = 800_000             # KV capacity of the reference node

    @classmethod
    def from_cost(cls, cost, *, prompt_len: int = 16_384,
                  response_len: int = 512, decode_batch: int = 8) -> "WorkloadShape":
        """Calibrate reference costs from a simulator ``CostModel`` (the
        capability ratios rescale ``cost.hw`` to the reference node, so
        any profile works as the calibration source)."""
        mean_active = decode_batch * (prompt_len + response_len // 2)
        return cls(
            prompt_len=prompt_len,
            response_len=response_len,
            decode_batch=decode_batch,
            kv_bytes_per_token=cost.kv_bytes_per_token(),
            prefill_s_ref=cost.prefill_s(prompt_len)
            * (cost.hw.peak_flops / REF_FLOPS),
            decode_step_s_ref=cost.decode_step_s(mean_active, decode_batch)
            * (cost.hw.hbm_bw / REF_HBM_BPS),
            cap_tokens_ref=int(cost.kv_capacity_tokens()
                               * (REF_VRAM / cost.hw.hbm_bytes)),
        )

    @property
    def kv_bytes_per_request(self) -> float:
        return float(self.prompt_len * self.kv_bytes_per_token)


@dataclasses.dataclass(frozen=True)
class Placement:
    """A role assignment: machine ids per role (sorted — worker ids bind
    positionally as p0..pN / d0..dM) plus the planner's score in req/s."""

    prefill: tuple[str, ...]
    decode: tuple[str, ...]
    score: float = 0.0

    def __post_init__(self):
        if not self.prefill or not self.decode:
            raise ValueError("a placement needs >=1 prefill and >=1 decode")
        if set(self.prefill) & set(self.decode):
            raise ValueError("a machine cannot hold both roles")
        object.__setattr__(self, "prefill", tuple(sorted(self.prefill)))
        object.__setattr__(self, "decode", tuple(sorted(self.decode)))


def _max_flow(caps: dict[tuple[str, str], float], source: str, sink: str) -> float:
    """Edmonds–Karp on a dict-of-edges graph; fine at cluster scale."""
    residual: dict[tuple[str, str], float] = {}
    adj: dict[str, set[str]] = {}
    for (u, v), c in caps.items():
        residual[(u, v)] = residual.get((u, v), 0.0) + c
        residual.setdefault((v, u), 0.0)
        adj.setdefault(u, set()).add(v)
        adj.setdefault(v, set()).add(u)
    flow = 0.0
    while True:
        parent: dict[str, str | None] = {source: None}
        queue = deque([source])
        while queue and sink not in parent:
            u = queue.popleft()
            for v in sorted(adj.get(u, ())):
                if v not in parent and residual.get((u, v), 0.0) > 1e-12:
                    parent[v] = u
                    queue.append(v)
        if sink not in parent:
            return flow
        path = []
        v = sink
        while parent[v] is not None:
            path.append((parent[v], v))
            v = parent[v]  # type: ignore[assignment]
        aug = min(residual[e] for e in path)
        for u, w in path:
            residual[(u, w)] -= aug
            residual[(w, u)] += aug
        flow += aug


@dataclasses.dataclass
class PlacementPlanner:
    """Greedy + local-search max-flow placement (no MILP dependency).

    ``plan`` seeds a greedy assignment (machines ranked by per-role
    rates), then hill-climbs over single-machine moves and role swaps
    until no strictly-improving step exists; a few seeded random restarts
    guard against poor local optima, which also guarantees the plan never
    scores below a same-seed random baseline.  Deterministic given
    (spec, seed).
    """

    shape: WorkloadShape = dataclasses.field(default_factory=WorkloadShape)
    restarts: int = 4
    max_steps: int = 200

    # ------------------------------------------------------- machine rates
    def prefill_rate(self, m: MachineSpec) -> float:
        """Sustained prefill throughput, requests/s."""
        return m.profile.peak_flops / (REF_FLOPS * self.shape.prefill_s_ref)

    def decode_rate(self, m: MachineSpec) -> float:
        """Sustained decode completion rate, requests/s: batch-limited
        compute rate capped by how many requests the machine's KV pool
        can hold concurrently (Little's law)."""
        s = self.shape
        step_s = s.decode_step_s_ref * (REF_HBM_BPS / m.profile.hbm_Bps)
        cap_tokens = s.cap_tokens_ref * (m.profile.vram_bytes / REF_VRAM)
        resident = min(float(s.decode_batch),
                       cap_tokens / max(s.prompt_len + s.response_len, 1))
        if resident < 1.0:
            return 0.0  # cannot hold even one request's KV
        return resident / (s.response_len * step_s)

    # ------------------------------------------------------------- scoring
    def score(self, spec: ClusterSpec, prefill, decode) -> float:
        """Max-flow request rate of the candidate role split."""
        prefill, decode = set(prefill), set(decode)
        if not prefill or not decode or (prefill & decode):
            return 0.0
        per_req = self.shape.kv_bytes_per_request
        caps: dict[tuple[str, str], float] = {}
        for pid in prefill:
            caps[("source", pid)] = self.prefill_rate(spec.machine(pid))
        for did in decode:
            caps[(did, "sink")] = self.decode_rate(spec.machine(did))
        for pid in prefill:
            for did in decode:
                bw = spec.link(pid, did).bandwidth_Bps * KVDIRECT_UTIL
                caps[(pid, did)] = math.inf if per_req <= 0 else bw / per_req
        return _max_flow(caps, "source", "sink")

    def score_placement(self, spec: ClusterSpec, placement: Placement) -> float:
        return self.score(spec, placement.prefill, placement.decode)

    # ------------------------------------------------------------ planning
    def plan(self, spec: ClusterSpec, *, seed: int = 0,
             n_prefill: int | None = None,
             n_decode: int | None = None) -> Placement:
        """Best placement found.  With ``n_prefill``/``n_decode`` pinned
        the plan uses exactly those counts (remaining machines are
        spares); otherwise every machine gets a role."""
        import numpy as np

        ids = sorted(spec.ids())
        n = len(ids)
        if n < 2:
            raise ValueError("placement needs >=2 machines")
        k_p, k_d = n_prefill, n_decode
        if k_p is None and k_d is not None:
            k_p = n - k_d
        if k_d is None and k_p is not None:
            k_d = n - k_p
        if k_p is not None:
            if k_p < 1 or k_d < 1 or k_p + k_d > n:
                raise ValueError(
                    f"cannot place {k_p}P+{k_d}D on {n} machines")

        rng = np.random.default_rng(seed)
        starts = [self._greedy_start(spec, ids, k_p, k_d)]
        for _ in range(self.restarts):
            perm = [ids[int(i)] for i in rng.permutation(n)]
            kp = k_p if k_p is not None else int(rng.integers(1, n))
            kd = k_d if k_d is not None else n - kp
            starts.append((perm[:kp], perm[kp:kp + kd]))

        best: tuple[float, tuple, tuple] | None = None
        for prefill, decode in starts:
            sc, p, d = self._local_search(spec, list(prefill), list(decode),
                                          pinned=k_p is not None)
            cand = (sc, tuple(sorted(p)), tuple(sorted(d)))
            if best is None or cand[0] > best[0] or \
                    (cand[0] == best[0] and cand[1:] < best[1:]):
                best = cand
        assert best is not None
        return Placement(prefill=best[1], decode=best[2], score=best[0])

    def _greedy_start(self, spec, ids, k_p, k_d):
        """Rank-based seed: best prefill-rate machines take the prefill
        role, best decode-rate machines take decode."""
        by_prefill = sorted(ids, key=lambda i: (-self.prefill_rate(spec.machine(i)), i))
        if k_p is None:
            # split all machines: try every prefix size, keep the best
            best = None
            for k in range(1, len(ids)):
                p, d = by_prefill[:k], by_prefill[k:]
                sc = self.score(spec, p, d)
                if best is None or sc > best[0]:
                    best = (sc, p, d)
            return best[1], best[2]
        rest = by_prefill[k_p:]
        by_decode = sorted(rest, key=lambda i: (-self.decode_rate(spec.machine(i)), i))
        return by_prefill[:k_p], by_decode[:k_d]

    def _local_search(self, spec, prefill: list, decode: list, *, pinned: bool):
        sc = self.score(spec, prefill, decode)
        spares = sorted(set(spec.ids()) - set(prefill) - set(decode))
        for _ in range(self.max_steps):
            best_step = None  # (score, kind, a, b)
            p_sorted, d_sorted = sorted(prefill), sorted(decode)

            def consider(kind, a, b, new_p, new_d):
                nonlocal best_step
                s2 = self.score(spec, new_p, new_d)
                if s2 > sc and (best_step is None or s2 > best_step[0]):
                    best_step = (s2, kind, a, b)

            for p in p_sorted:
                for d in d_sorted:  # swap roles of p and d
                    consider("swap", p, d,
                             [x for x in prefill if x != p] + [d],
                             [x for x in decode if x != d] + [p])
            for s in spares:
                for p in p_sorted:  # spare replaces a prefill machine
                    consider("sub_p", p, s,
                             [x for x in prefill if x != p] + [s], decode)
                for d in d_sorted:  # spare replaces a decode machine
                    consider("sub_d", d, s,
                             prefill, [x for x in decode if x != d] + [s])
            if not pinned:
                for p in p_sorted:  # demote prefill -> decode
                    if len(prefill) > 1:
                        consider("move_pd", p, p,
                                 [x for x in prefill if x != p], decode + [p])
                for d in d_sorted:  # promote decode -> prefill
                    if len(decode) > 1:
                        consider("move_dp", d, d,
                                 prefill + [d], [x for x in decode if x != d])
            if best_step is None:
                break
            sc, kind, a, b = best_step
            if kind == "swap":
                prefill = [x for x in prefill if x != a] + [b]
                decode = [x for x in decode if x != b] + [a]
            elif kind == "sub_p":
                prefill = [x for x in prefill if x != a] + [b]
                spares = sorted(set(spares) - {b} | {a})
            elif kind == "sub_d":
                decode = [x for x in decode if x != a] + [b]
                spares = sorted(set(spares) - {b} | {a})
            elif kind == "move_pd":
                prefill = [x for x in prefill if x != a]
                decode = decode + [a]
            else:  # move_dp
                prefill = prefill + [a]
                decode = [x for x in decode if x != a]
        return sc, prefill, decode


def random_placement(spec: ClusterSpec, seed: int = 0, *,
                     n_prefill: int | None = None,
                     n_decode: int | None = None,
                     planner: PlacementPlanner | None = None) -> Placement:
    """Uniform random role assignment (>=1 per role) — the equal-hardware
    baseline the planner must beat.  Scored when a planner is supplied."""
    import numpy as np

    rng = np.random.default_rng(seed)
    ids = sorted(spec.ids())
    n = len(ids)
    if n < 2:
        raise ValueError("need >=2 machines")
    perm = [ids[int(i)] for i in rng.permutation(n)]
    k_p = n_prefill if n_prefill is not None else int(rng.integers(1, n))
    k_d = n_decode if n_decode is not None else n - k_p
    if k_p < 1 or k_d < 1 or k_p + k_d > n:
        raise ValueError(f"cannot place {k_p}P+{k_d}D on {n} machines")
    prefill, decode = perm[:k_p], perm[k_p:k_p + k_d]
    score = planner.score(spec, prefill, decode) if planner else 0.0
    return Placement(prefill=tuple(prefill), decode=tuple(decode), score=score)
