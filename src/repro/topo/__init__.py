"""Topology subsystem: heterogeneous cluster model, placement planning,
and per-pair link-cost wiring (docs/topology.md).

  * ``topo.spec``    — ``ClusterSpec`` (machines, directed links) and the
    seeded ``ClusterGenerator`` / ``generate_cluster`` presets.
  * ``topo.plan``    — ``PlacementPlanner``: greedy + local-search
    max-flow role assignment; ``random_placement`` baseline.
  * ``topo.binding`` — ``TopologyBinding``: worker-id ↔ machine map,
    router links, sim scales, topology-aware hot-add spare picks.
"""
from .binding import NoSpareMachine, TopologyBinding
from .plan import Placement, PlacementPlanner, WorkloadShape, random_placement
from .spec import (
    PRESETS,
    PROFILES,
    ClusterGenerator,
    ClusterSpec,
    Link,
    MachineProfile,
    MachineSpec,
    generate_cluster,
)

__all__ = [
    "ClusterGenerator", "ClusterSpec", "Link", "MachineProfile",
    "MachineSpec", "NoSpareMachine", "PRESETS", "PROFILES", "Placement",
    "PlacementPlanner", "TopologyBinding", "WorkloadShape",
    "generate_cluster", "random_placement",
]
