"""Bind a placement to live worker ids, and wire it into the stack.

``TopologyBinding`` is the one object the serving substrate, the router
and the simulator all hold: it maps worker ids (``p0..``/``d0..``,
assigned positionally over the placement's sorted machine ids) to
machines, derives per-pair ``LinkModel``s for ``RequestRouter``, per-
machine capability scales for ``ClusterSim``, and — when the fleet layer
hot-adds a worker — picks WHICH spare machine to add topology-aware (the
spare whose addition maximizes the planner's max-flow score).
"""
from __future__ import annotations

from repro.core.transfer_engine import LinkModel

from .plan import Placement, PlacementPlanner
from .spec import ClusterSpec, Link, MachineSpec

__all__ = ["NoSpareMachine", "TopologyBinding"]


class NoSpareMachine(RuntimeError):
    """Raised when a hot-add is requested but every machine in the
    cluster spec already holds a role."""


class TopologyBinding:
    def __init__(self, spec: ClusterSpec, placement: Placement, *,
                 planner: PlacementPlanner | None = None):
        self.spec = spec
        self.placement = placement
        self.planner = planner
        self._wid_to_mid: dict[str, str] = {}
        for i, mid in enumerate(placement.prefill):
            self._wid_to_mid[f"p{i}"] = mid
        for i, mid in enumerate(placement.decode):
            self._wid_to_mid[f"d{i}"] = mid
        assigned = set(self._wid_to_mid.values())
        self._spares = sorted(set(spec.ids()) - assigned)

    # ------------------------------------------------------------- lookups
    @property
    def n_prefill(self) -> int:
        return sum(1 for w in self._wid_to_mid if w.startswith("p"))

    @property
    def n_decode(self) -> int:
        return sum(1 for w in self._wid_to_mid if w.startswith("d"))

    @property
    def spares(self) -> tuple[str, ...]:
        return tuple(self._spares)

    def machine(self, wid: str) -> MachineSpec | None:
        mid = self._wid_to_mid.get(wid)
        return self.spec.machine(mid) if mid is not None else None

    def _require(self, wid: str) -> MachineSpec:
        m = self.machine(wid)
        if m is None:
            raise KeyError(f"worker {wid!r} not bound to any machine")
        return m

    def pair_link(self, pwid: str, dwid: str) -> Link:
        return self.spec.link(self._require(pwid).machine_id,
                              self._require(dwid).machine_id)

    def links(self) -> dict[tuple[str, str], LinkModel]:
        """Per-pair router topology map for every bound (prefill, decode)
        pair — the ``RequestRouter(links=...)`` argument."""
        pids = sorted(w for w in self._wid_to_mid if w.startswith("p"))
        dids = sorted(w for w in self._wid_to_mid if w.startswith("d"))
        return {(p, d): self.pair_link(p, d).to_link_model()
                for p in pids for d in dids}

    # ------------------------------------------------- simulator interface
    # ClusterSim stays calibrated against a single reference CostModel and
    # applies the topology as RELATIVE scales; the caller supplies the
    # reference machine's numbers (cost.hw.*) so sim and plan agree.
    def prefill_slowdown(self, wid: str, ref_flops: float) -> float:
        return ref_flops / self._require(wid).profile.peak_flops

    def decode_slowdown(self, wid: str, ref_hbm_Bps: float) -> float:
        return ref_hbm_Bps / self._require(wid).profile.hbm_Bps

    def cap_scale(self, wid: str, ref_vram_bytes: float) -> float:
        return self._require(wid).profile.vram_bytes / ref_vram_bytes

    def pair_scale(self, pwid: str, dwid: str, ref_bandwidth_Bps: float) -> float:
        return ref_bandwidth_Bps / self.pair_link(pwid, dwid).bandwidth_Bps

    def pair_latency_s(self, pwid: str, dwid: str) -> float:
        return self.pair_link(pwid, dwid).latency_s

    # ----------------------------------------------------------- hot adds
    def has_spare(self, role: str) -> bool:
        return bool(self._spares)

    def pick_spare(self, role: str) -> str:
        """Which spare machine a hot-add of ``role`` should claim: the
        one whose addition maximizes the planner's max-flow score (ties
        broken by id).  Falls back to capability rank without a planner."""
        if not self._spares:
            raise NoSpareMachine(
                f"no spare machine in {self.spec.name!r} for a {role} add")
        if self.planner is not None:
            p_mids = sorted(self._wid_to_mid[w] for w in self._wid_to_mid
                            if w.startswith("p"))
            d_mids = sorted(self._wid_to_mid[w] for w in self._wid_to_mid
                            if w.startswith("d"))
            best = None
            for mid in self._spares:
                if role == "prefill":
                    sc = self.planner.score(self.spec, p_mids + [mid], d_mids)
                else:
                    sc = self.planner.score(self.spec, p_mids, d_mids + [mid])
                if best is None or sc > best[0]:
                    best = (sc, mid)
            return best[1]
        key = (lambda mid: (-self.spec.machine(mid).profile.peak_flops, mid)) \
            if role == "prefill" else \
            (lambda mid: (-self.spec.machine(mid).profile.vram_bytes, mid))
        return sorted(self._spares, key=key)[0]

    def add_worker(self, role: str, wid: str) -> MachineSpec:
        """Consume the best spare for ``role`` and bind it to ``wid``.
        Raises ``NoSpareMachine`` when the cluster is fully assigned."""
        if wid in self._wid_to_mid:
            raise ValueError(f"worker {wid!r} already bound")
        mid = self.pick_spare(role)
        self._spares.remove(mid)
        self._wid_to_mid[wid] = mid
        return self.spec.machine(mid)

    def release_worker(self, wid: str) -> None:
        """Return ``wid``'s machine to the spare pool (drain-then-retire)."""
        mid = self._wid_to_mid.pop(wid, None)
        if mid is not None:
            self._spares = sorted(set(self._spares) | {mid})
