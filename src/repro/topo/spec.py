"""Heterogeneous cluster topology: machine profiles, per-pair links.

KVDirect's premise is that fast KV transfer makes *distributed*
disaggregation viable — but "distributed" clusters are rarely uniform.
Helix (ASPLOS'25) models a heterogeneous, possibly geo-distributed GPU
cluster as a directed graph of typed machines and per-pair links, then
plans over that graph.  This module is our version of the cluster half:

  * ``MachineProfile``  — a machine *type* (peak FLOPs, VRAM, NIC Gbps).
  * ``MachineSpec``     — one concrete machine: id + profile + region.
  * ``Link``            — one DIRECTED edge: bandwidth, propagation
                          latency, and a tier tag (rack / region /
                          cross_region).  Directed because real paths
                          are asymmetric (different return routes,
                          asymmetric provisioning); the router prices
                          each direction separately.
  * ``ClusterSpec``     — machines + links, validated, with a stable
                          JSON round-trip so the SAME spec drives the
                          simulator and the real serving substrate
                          byte-for-byte.
  * ``ClusterGenerator``— Helix-style seeded synthesizer of reproducible
                          heterogeneous / geo-distributed clusters.

Everything here is pure data + numpy; planning lives in ``topo.plan``
and wiring into the serving/sim layers in ``topo.binding``.
"""
from __future__ import annotations

import dataclasses
import json

import numpy as np

from repro.core.transfer_engine import LinkModel

__all__ = [
    "MachineProfile", "MachineSpec", "Link", "ClusterSpec",
    "ClusterGenerator", "PROFILES", "PRESETS", "generate_cluster",
]

# Reference machine for relative scaling: the paper's 8×H100-80G node
# with a 400 Gbps NIC (sim.costs.H100_NODE uses the same numbers).
REF_FLOPS = 8 * 989e12
REF_HBM_BPS = 8 * 3.35e12
REF_VRAM = 8 * 80 * 2**30
REF_NIC_GBPS = 400.0


@dataclasses.dataclass(frozen=True)
class MachineProfile:
    """A machine *type*: the three capability axes the planner scores on
    (compute → prefill rate, VRAM → decode KV capacity, NIC → attachable
    link bandwidth) plus HBM bandwidth for the decode-step roofline."""

    name: str
    peak_flops: float        # aggregate bf16 FLOP/s
    vram_bytes: int          # aggregate accelerator memory
    nic_gbps: float          # NIC line rate, Gbit/s
    hbm_Bps: float = 0.0     # aggregate HBM bandwidth; 0 → derived

    def __post_init__(self):
        if self.peak_flops <= 0 or self.vram_bytes <= 0 or self.nic_gbps <= 0:
            raise ValueError(f"non-positive capability in profile {self.name!r}")
        if self.hbm_Bps <= 0:
            # H100-like compute:HBM ratio keeps derived profiles on the
            # same roofline shape as the reference node.
            object.__setattr__(self, "hbm_Bps",
                               self.peak_flops * (REF_HBM_BPS / REF_FLOPS))

    @property
    def nic_Bps(self) -> float:
        return self.nic_gbps * 1e9 / 8.0


# A small catalog spanning ~8× in compute and ~3× in VRAM — enough
# heterogeneity that role assignment matters.  Names are host shapes,
# not marketing SKUs.
PROFILES: dict[str, MachineProfile] = {
    "8xh100": MachineProfile("8xh100", peak_flops=REF_FLOPS,
                             vram_bytes=REF_VRAM, nic_gbps=400.0,
                             hbm_Bps=REF_HBM_BPS),
    "8xa100": MachineProfile("8xa100", peak_flops=8 * 312e12,
                             vram_bytes=8 * 40 * 2**30, nic_gbps=200.0,
                             hbm_Bps=8 * 2.0e12),
    "4xa100": MachineProfile("4xa100", peak_flops=4 * 312e12,
                             vram_bytes=4 * 40 * 2**30, nic_gbps=100.0,
                             hbm_Bps=4 * 2.0e12),
    "8xl4": MachineProfile("8xl4", peak_flops=8 * 121e12,
                           vram_bytes=8 * 24 * 2**30, nic_gbps=100.0,
                           hbm_Bps=8 * 300e9),
    "16xv5e": MachineProfile("16xv5e", peak_flops=16 * 197e12,
                             vram_bytes=16 * 16 * 2**30, nic_gbps=400.0,
                             hbm_Bps=16 * 819e9),
}


@dataclasses.dataclass(frozen=True)
class MachineSpec:
    """One concrete machine in a cluster."""

    machine_id: str
    profile: MachineProfile
    region: str = "r0"


# Per-op posting overhead by tier: rack-local links behave like the
# engine's default NIC; cross-region paths pay a DCN-ish per-op cost.
_TIER_POST_OVERHEAD_S = {"rack": 2e-6, "region": 2e-6, "cross_region": 3e-6}
_TIERS = tuple(_TIER_POST_OVERHEAD_S)


@dataclasses.dataclass(frozen=True)
class Link:
    """One directed src→dst path."""

    src: str
    dst: str
    bandwidth_Bps: float
    latency_s: float = 0.0
    tier: str = "rack"

    def __post_init__(self):
        if self.src == self.dst:
            raise ValueError(f"self-link {self.src!r}")
        if self.bandwidth_Bps <= 0:
            raise ValueError(f"non-positive bandwidth on {self.src}->{self.dst}")
        if self.latency_s < 0:
            raise ValueError(f"negative latency on {self.src}->{self.dst}")
        if self.tier not in _TIERS:
            raise ValueError(f"unknown tier {self.tier!r} (want one of {_TIERS})")

    def to_link_model(self) -> LinkModel:
        """The transfer-engine/router view of this path: same timing
        fields the engine accrues, so routing and mechanism agree."""
        return LinkModel(bandwidth_Bps=self.bandwidth_Bps,
                         post_overhead_s=_TIER_POST_OVERHEAD_S[self.tier],
                         latency_s=self.latency_s)


@dataclasses.dataclass(frozen=True)
class ClusterSpec:
    """Machines + directed links, validated at construction.

    ``links`` need not be complete: ``link(a, b)`` falls back to a
    rack-tier path at the slower endpoint's NIC rate, so a spec may list
    only the pairs that deviate from "NIC-limited, same rack".
    """

    name: str
    machines: tuple[MachineSpec, ...]
    links: tuple[Link, ...] = ()
    seed: int | None = None

    def __post_init__(self):
        ids = [m.machine_id for m in self.machines]
        if not ids:
            raise ValueError("empty cluster")
        if len(set(ids)) != len(ids):
            raise ValueError(f"duplicate machine ids in {self.name!r}")
        known = set(ids)
        seen: set[tuple[str, str]] = set()
        for lk in self.links:
            if lk.src not in known or lk.dst not in known:
                raise ValueError(
                    f"link {lk.src}->{lk.dst} references unknown machine "
                    f"(known: {sorted(known)})")
            if (lk.src, lk.dst) in seen:
                raise ValueError(f"duplicate link {lk.src}->{lk.dst}")
            seen.add((lk.src, lk.dst))

    # ------------------------------------------------------------ lookups
    def ids(self) -> tuple[str, ...]:
        return tuple(m.machine_id for m in self.machines)

    def machine(self, machine_id: str) -> MachineSpec:
        for m in self.machines:
            if m.machine_id == machine_id:
                return m
        raise KeyError(machine_id)

    def link(self, src: str, dst: str) -> Link:
        for lk in self.links:
            if lk.src == src and lk.dst == dst:
                return lk
        # NIC-limited rack-local default for unlisted pairs.
        bw = min(self.machine(src).profile.nic_Bps,
                 self.machine(dst).profile.nic_Bps)
        return Link(src, dst, bandwidth_Bps=bw)

    @property
    def max_vram(self) -> int:
        return max(m.profile.vram_bytes for m in self.machines)

    @property
    def max_flops(self) -> float:
        return max(m.profile.peak_flops for m in self.machines)

    # --------------------------------------------------------- round-trip
    def to_json(self) -> str:
        """Stable serialization — the byte-for-byte artifact that the sim
        and the real service both consume (and that tests diff)."""
        return json.dumps({
            "name": self.name,
            "seed": self.seed,
            "machines": [
                {"machine_id": m.machine_id, "region": m.region,
                 "profile": dataclasses.asdict(m.profile)}
                for m in self.machines
            ],
            "links": [dataclasses.asdict(lk) for lk in self.links],
        }, sort_keys=True, indent=1)

    @classmethod
    def from_json(cls, text: str) -> "ClusterSpec":
        d = json.loads(text)
        machines = tuple(
            MachineSpec(m["machine_id"], MachineProfile(**m["profile"]),
                        region=m.get("region", "r0"))
            for m in d["machines"])
        links = tuple(Link(**lk) for lk in d.get("links", []))
        return cls(name=d["name"], machines=machines, links=links,
                   seed=d.get("seed"))


@dataclasses.dataclass(frozen=True)
class ClusterGenerator:
    """Seeded synthesizer of heterogeneous / geo-distributed clusters,
    after Helix's FakeClusterGenerator: same seed → identical spec.

    Machines draw a profile from ``profile_mix`` (name → weight) and are
    dealt round-robin into ``n_regions`` regions.  Every ordered pair
    gets a directed link: intra-region pairs sample from the ``intra_*``
    ranges, cross-region pairs from the much slower/laggier ``cross_*``
    ranges.  Each direction samples independently (``asymmetric=True``),
    so A→B cheap / B→A expensive arises naturally.  Link bandwidth is
    always capped by the slower endpoint's NIC.
    """

    name: str = "generated"
    n_machines: int = 6
    n_regions: int = 1
    profile_mix: tuple[tuple[str, float], ...] = (
        ("8xh100", 1.0), ("8xa100", 1.0), ("8xl4", 1.0))
    intra_bw_gbps: tuple[float, float] = (100.0, 400.0)
    intra_latency_s: tuple[float, float] = (0.0, 50e-6)
    cross_bw_gbps: tuple[float, float] = (10.0, 40.0)
    cross_latency_s: tuple[float, float] = (10e-3, 40e-3)
    asymmetric: bool = True

    def __post_init__(self):
        if self.n_machines < 2:
            raise ValueError("need at least 2 machines")
        if self.n_regions < 1 or self.n_regions > self.n_machines:
            raise ValueError(f"n_regions {self.n_regions} out of range")
        for name, w in self.profile_mix:
            if name not in PROFILES:
                raise ValueError(f"unknown profile {name!r}")
            if w < 0:
                raise ValueError(f"negative weight for {name!r}")

    def generate(self, seed: int = 0) -> ClusterSpec:
        rng = np.random.default_rng(seed)
        names = [n for n, _ in self.profile_mix]
        weights = np.array([w for _, w in self.profile_mix], dtype=float)
        weights = weights / weights.sum()
        picks = rng.choice(len(names), size=self.n_machines, p=weights)
        machines = tuple(
            MachineSpec(f"m{i}", PROFILES[names[int(picks[i])]],
                        region=f"r{i % self.n_regions}")
            for i in range(self.n_machines))

        def sample(lo_hi: tuple[float, float]) -> float:
            lo, hi = lo_hi
            return float(rng.uniform(lo, hi))

        links = []
        for a in machines:
            for b in machines:
                if a.machine_id == b.machine_id:
                    continue
                # b->a reuses a->b's draws when symmetric: consume the
                # randomness only on the canonical direction.
                if not self.asymmetric and a.machine_id > b.machine_id:
                    fwd = next(lk for lk in links
                               if lk.src == b.machine_id and lk.dst == a.machine_id)
                    links.append(Link(a.machine_id, b.machine_id,
                                      bandwidth_Bps=fwd.bandwidth_Bps,
                                      latency_s=fwd.latency_s, tier=fwd.tier))
                    continue
                same_region = a.region == b.region
                bw_gbps = sample(self.intra_bw_gbps if same_region
                                 else self.cross_bw_gbps)
                lat = sample(self.intra_latency_s if same_region
                             else self.cross_latency_s)
                nic_cap = min(a.profile.nic_Bps, b.profile.nic_Bps)
                links.append(Link(
                    a.machine_id, b.machine_id,
                    bandwidth_Bps=min(bw_gbps * 1e9 / 8.0, nic_cap),
                    latency_s=lat,
                    tier="rack" if same_region else "cross_region"))
        return ClusterSpec(name=f"{self.name}-s{seed}", machines=machines,
                           links=tuple(links), seed=seed)


# Three reference shapes for the fig_topology sweep and tests: one
# heterogeneous rack, one 2-region geo split, one 3-region split with a
# skewed profile mix.  All reproducible from (preset, seed).
PRESETS: dict[str, ClusterGenerator] = {
    "hetero_rack": ClusterGenerator(
        name="hetero_rack", n_machines=6, n_regions=1,
        profile_mix=(("8xh100", 1.0), ("8xa100", 1.0), ("8xl4", 1.0))),
    "geo_pair": ClusterGenerator(
        name="geo_pair", n_machines=8, n_regions=2,
        profile_mix=(("8xh100", 1.0), ("8xa100", 2.0), ("4xa100", 1.0))),
    "geo_triad": ClusterGenerator(
        name="geo_triad", n_machines=9, n_regions=3,
        profile_mix=(("8xh100", 1.0), ("8xa100", 1.0),
                     ("8xl4", 1.0), ("16xv5e", 1.0))),
}


def generate_cluster(preset: str, seed: int = 0) -> ClusterSpec:
    """One shared cluster source for benchmarks and tests: Fig-12 cells
    and the topology sweep both call this, so they cannot drift."""
    if preset not in PRESETS:
        raise ValueError(f"unknown preset {preset!r} (want one of {sorted(PRESETS)})")
    return PRESETS[preset].generate(seed)
