"""Checkpoint save/restore — fault-tolerant training substrate.

msgpack-serialized pytrees with dtype/shape manifests, atomic writes
(tmp+rename), step-indexed directories, retention, and an integrity
check on restore.  Elastic resume: arrays are saved with their GLOBAL
shapes, so a restart on a different mesh re-shards via
``jax.device_put`` against the new sharding tree.
"""
from __future__ import annotations

import json
import pathlib
import shutil

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

__all__ = ["save_checkpoint", "restore_checkpoint", "latest_step"]

_MANIFEST = "manifest.json"
_DATA = "arrays.msgpack"


def _flatten(tree):
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    return leaves, treedef


def save_checkpoint(ckpt_dir: str | pathlib.Path, step: int, tree, *, keep: int = 3) -> pathlib.Path:
    ckpt_dir = pathlib.Path(ckpt_dir)
    final = ckpt_dir / f"step_{step:08d}"
    tmp = ckpt_dir / f".tmp_step_{step:08d}"
    if tmp.exists():
        shutil.rmtree(tmp)
    tmp.mkdir(parents=True)

    leaves, treedef = _flatten(tree)
    arrays = [np.asarray(x) for x in leaves]
    manifest = {
        "step": step,
        "treedef": str(treedef),
        "leaves": [{"shape": list(a.shape), "dtype": str(a.dtype)} for a in arrays],
    }
    payload = [a.tobytes() for a in arrays]
    (tmp / _DATA).write_bytes(msgpack.packb(payload))
    (tmp / _MANIFEST).write_text(json.dumps(manifest))
    if final.exists():
        shutil.rmtree(final)
    tmp.rename(final)  # atomic publish

    # retention
    steps = sorted(p for p in ckpt_dir.glob("step_*"))
    for old in steps[:-keep]:
        shutil.rmtree(old)
    return final


def latest_step(ckpt_dir: str | pathlib.Path) -> int | None:
    ckpt_dir = pathlib.Path(ckpt_dir)
    steps = sorted(ckpt_dir.glob("step_*"))
    if not steps:
        return None
    return int(steps[-1].name.split("_")[1])


def restore_checkpoint(ckpt_dir: str | pathlib.Path, step: int, like_tree, *, shardings=None):
    """Restore into the structure (and shardings) of ``like_tree``."""
    path = pathlib.Path(ckpt_dir) / f"step_{step:08d}"
    manifest = json.loads((path / _MANIFEST).read_text())
    payload = msgpack.unpackb((path / _DATA).read_bytes())
    like_leaves, treedef = _flatten(like_tree)
    if len(payload) != len(like_leaves):
        raise ValueError(
            f"checkpoint has {len(payload)} leaves, target tree {len(like_leaves)}")
    arrays = []
    for buf, meta, like in zip(payload, manifest["leaves"], like_leaves):
        a = np.frombuffer(buf, dtype=meta["dtype"]).reshape(meta["shape"])
        like_shape = tuple(np.shape(like))  # handles raw python scalars
        if tuple(a.shape) != like_shape:
            raise ValueError(f"shape mismatch {a.shape} vs {like_shape}")
        arrays.append(a)
    if shardings is not None:
        shard_leaves = jax.tree_util.tree_leaves(shardings)
        arrays = [jax.device_put(a, s) for a, s in zip(arrays, shard_leaves)]
    else:
        arrays = [jnp.asarray(a) for a in arrays]
    return jax.tree_util.tree_unflatten(treedef, arrays)
