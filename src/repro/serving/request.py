"""Request lifecycle and per-request service metrics (TTFT / TBT / total).

Matches the paper's measurement definitions (§5.1): measured TTFT
*includes the waiting time for the KV cache*, TBT is the mean gap between
tokens after the first, total latency is arrival→last token.
"""
from __future__ import annotations

import dataclasses
import enum

__all__ = ["RequestState", "Request"]


class RequestState(enum.Enum):
    QUEUED_PREFILL = "queued_prefill"
    PREFILLING = "prefilling"
    KV_QUEUED = "kv_queued"        # prefill done, waiting for decode-side blocks
    KV_TRANSFER = "kv_transfer"
    QUEUED_DECODE = "queued_decode"
    DECODING = "decoding"
    DONE = "done"
    FAILED = "failed"


# Legal transitions; anything else is a scheduler bug.
_TRANSITIONS: dict[RequestState, set[RequestState]] = {
    RequestState.QUEUED_PREFILL: {RequestState.PREFILLING, RequestState.FAILED},
    RequestState.PREFILLING: {RequestState.KV_QUEUED, RequestState.KV_TRANSFER, RequestState.FAILED},
    # KV_QUEUED -> DONE: stream complete before any pull (EOS produced
    # by prefill, or a zero decode budget); the prefill copy is released
    # by the serving layer since no COMPLETE will ever fire
    RequestState.KV_QUEUED: {RequestState.KV_TRANSFER, RequestState.QUEUED_PREFILL, RequestState.DONE, RequestState.FAILED},
    # KV_TRANSFER -> KV_QUEUED: hedged-prefill failover — the pull died
    # with its source but a hedge twin's KV copy survives, so the request
    # goes back to waiting for admission instead of re-prefilling
    RequestState.KV_TRANSFER: {RequestState.QUEUED_DECODE, RequestState.KV_QUEUED, RequestState.QUEUED_PREFILL, RequestState.FAILED},
    RequestState.QUEUED_DECODE: {RequestState.DECODING, RequestState.FAILED},
    RequestState.DECODING: {RequestState.DONE, RequestState.FAILED},
    RequestState.DONE: set(),
    # retry after worker failure: full re-prefill, or straight back to
    # KV_QUEUED when the prefill copy survived (decode-side failover)
    RequestState.FAILED: {RequestState.QUEUED_PREFILL, RequestState.KV_QUEUED},
}


@dataclasses.dataclass
class Request:
    request_id: str
    prompt_len: int
    max_new_tokens: int
    arrival_s: float = 0.0
    slo_class: str = "standard"  # TTFT deadline class (sched.policies)
    # Shared-prefix identity for prefix-aware routing: requests carrying
    # the same prefix_id share their first prefix_len prompt tokens
    # (0 = the whole prompt).  Used by the "prefix_affinity" policy and
    # the decode workers' prefix retention cache.
    prefix_id: str | None = None
    prefix_len: int = 0

    state: RequestState = RequestState.QUEUED_PREFILL
    prefill_worker: str | None = None
    decode_worker: str | None = None
    connection_epoch: int | None = None
    prefill_blocks: list[int] = dataclasses.field(default_factory=list)
    decode_blocks: list[int] = dataclasses.field(default_factory=list)
    # Content hashes of the parked prefill KV, one per block position
    # (digest over the block's K+V bytes across ALL layers).  Byte
    # equality ⇒ identical prefix context, so decode workers dedup
    # transfer plans against any resident block with the same hash —
    # even across requests with no shared prefix_id.
    block_hashes: list[str] = dataclasses.field(default_factory=list)
    # Per-(layer, block position, plane) int8 dequant scales computed at
    # prefill park time — present only under quantized transfer; they
    # ride the ReadTxn descriptors (see core.descriptors.ReadTxn.qscale).
    kv_scales: list | None = None
    tokens_generated: int = 0
    retries: int = 0

    # -- timeline (absolute seconds on the serving clock) ---------------
    prefill_start_s: float | None = None
    prefill_end_s: float | None = None
    transfer_start_s: float | None = None
    transfer_end_s: float | None = None
    decode_start_s: float | None = None
    token_times_s: list[float] = dataclasses.field(default_factory=list)
    done_s: float | None = None

    def to(self, new: RequestState) -> None:
        if new not in _TRANSITIONS[self.state]:
            raise ValueError(f"{self.request_id}: illegal transition {self.state} -> {new}")
        self.state = new

    # -- metrics ---------------------------------------------------------
    @property
    def ttft_s(self) -> float | None:
        """Arrival → first token; includes KV-cache wait (paper §5.1)."""
        if not self.token_times_s:
            return None
        return self.token_times_s[0] - self.arrival_s

    @property
    def tbt_s(self) -> float | None:
        if len(self.token_times_s) < 2:
            return None
        gaps = [b - a for a, b in zip(self.token_times_s, self.token_times_s[1:])]
        return sum(gaps) / len(gaps)

    @property
    def total_latency_s(self) -> float | None:
        if self.done_s is None:
            return None
        return self.done_s - self.arrival_s

    def breakdown(self) -> dict[str, float]:
        """Fig. 14 segments: prefill queue / prefill / transfer / decode
        queue / decode."""
        def span(a: float | None, b: float | None) -> float:
            return (b - a) if (a is not None and b is not None) else 0.0

        return {
            "prefill_queue_s": span(self.arrival_s, self.prefill_start_s),
            "prefill_s": span(self.prefill_start_s, self.prefill_end_s),
            "transfer_s": span(self.transfer_start_s, self.transfer_end_s)
            + span(self.prefill_end_s, self.transfer_start_s),  # KV alloc wait folded in
            "decode_queue_s": span(self.transfer_end_s, self.decode_start_s),
            "decode_s": span(self.decode_start_s, self.done_s),
        }
