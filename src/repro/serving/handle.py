"""Per-request serving handles — the caller-facing half of the streaming
serving API.

``DisaggService.submit()`` returns a ``RequestHandle`` immediately; the
request then moves through the serving pipeline as ``ServeLoop.tick()``
(or one of the ``generate``/``generate_many`` shims driving it) makes
progress.  The handle exposes:

  * a coarse caller-facing status machine —

        QUEUED -> PREFILLING -> TRANSFERRING -> DECODING -> DONE
                                                         \\-> FAILED

    projected from the finer internal ``RequestState`` (KV_QUEUED /
    KV_TRANSFER / QUEUED_DECODE all read as TRANSFERRING: the caller
    sees "my KV is on the move", not the engine's bookkeeping).  FAILED
    is terminal only until ``DisaggService.retry_parked`` revives the
    request — a parked handle resumes streaming where capacity returns;

  * an incremental token stream — ``next_tokens()`` returns tokens
    produced since the last call, and iterating the handle drives the
    service loop until the request finishes (true streaming: tokens
    yield as decode steps land, not when the batch returns);

  * per-request service metrics (``HandleMetrics``): wall-clock TTFT,
    mean per-token latency, KV bytes actually pulled through the
    transfer engine (retries included), retry count, hedge outcome.

Failover note: a restart-from-prefill replays decode from scratch, so
the handle truncates its decoded tokens back to the first token and the
replay re-produces the identical stream (decode is deterministic).  A
consumer iterating across a failover may therefore observe a token
at-least-once; ``tokens`` itself never contains duplicates.
"""
from __future__ import annotations

import dataclasses
import enum
import time
from typing import Iterator

from repro.serving.request import Request, RequestState

__all__ = ["HandleStatus", "HandleMetrics", "RequestHandle"]


class HandleStatus(enum.Enum):
    QUEUED = "queued"              # submitted, prefill not dispatched yet
    PREFILLING = "prefilling"
    TRANSFERRING = "transferring"  # prefill done, KV queued / on the wire
    DECODING = "decoding"
    DONE = "done"
    FAILED = "failed"              # rejected, or parked by failover


_STATUS_OF: dict[RequestState, HandleStatus] = {
    RequestState.QUEUED_PREFILL: HandleStatus.QUEUED,
    RequestState.PREFILLING: HandleStatus.PREFILLING,
    RequestState.KV_QUEUED: HandleStatus.TRANSFERRING,
    RequestState.KV_TRANSFER: HandleStatus.TRANSFERRING,
    RequestState.QUEUED_DECODE: HandleStatus.TRANSFERRING,
    RequestState.DECODING: HandleStatus.DECODING,
    RequestState.DONE: HandleStatus.DONE,
    RequestState.FAILED: HandleStatus.FAILED,
}


@dataclasses.dataclass
class HandleMetrics:
    """Wall-clock service metrics for one request (monotonic seconds)."""

    submitted_at: float
    first_token_at: float | None = None
    last_token_at: float | None = None
    token_times: list[float] = dataclasses.field(default_factory=list)
    kv_bytes_pulled: int = 0   # bytes landed decode-side, retries included
    kv_bytes_reused: int = 0   # bytes a delta plan skipped (resident graft)
    hedged: bool = False       # a prefill twin was dispatched
    hedge_adopted: bool = False  # failover switched to the twin's KV
    swapped_out: int = 0       # preempted to host memory (resumed later)
    sacrificed: int = 0        # preempted by drop + truncate-and-replay

    @property
    def kv_reuse_frac(self) -> float:
        """Fraction of this request's KV served from resident blocks
        instead of the wire (0.0 when nothing was reused)."""
        total = self.kv_bytes_pulled + self.kv_bytes_reused
        return self.kv_bytes_reused / total if total else 0.0

    @property
    def ttft_s(self) -> float | None:
        """Submit → first token (wall clock)."""
        if self.first_token_at is None:
            return None
        return self.first_token_at - self.submitted_at

    @property
    def ttlt_s(self) -> float | None:
        """Submit → last token so far (time-to-last-token once DONE)."""
        if self.last_token_at is None:
            return None
        return self.last_token_at - self.submitted_at

    @property
    def tbt_s(self) -> float | None:
        """Mean per-token latency after the first token."""
        if len(self.token_times) < 2:
            return None
        gaps = [b - a for a, b in zip(self.token_times, self.token_times[1:])]
        return sum(gaps) / len(gaps)


class RequestHandle:
    """Caller-side view of one submitted request.

    Unknown attributes delegate to the underlying ``Request`` (``state``,
    ``prefill_worker``, ``retries``, ...), so existing callers that held
    a ``Request`` keep working unchanged.
    """

    def __init__(self, request: Request, service, *,
                 max_new: int | None = None, eos_token: int | None = None,
                 hedge: int = 1, clock=None) -> None:
        self.request = request
        self.service = service
        self.max_new = max_new      # decode-token budget (None = until EOS)
        self.eos_token = eos_token
        self.hedge = hedge
        self.tokens: list[int] = []  # [first_token, *decoded]
        self.error: Exception | None = None
        # One clock for every per-request timestamp: the service passes
        # its observability clock so handle metrics, tracer spans, and
        # the span-derived breakdown are mutually consistent (a sim can
        # inject a virtual clock and get the same schema).
        self._clock = clock or time.monotonic
        self.metrics = HandleMetrics(submitted_at=self._clock())
        self._consumed = 0

    # ------------------------------------------------------------ status
    @property
    def request_id(self) -> str:
        return self.request.request_id

    @property
    def status(self) -> HandleStatus:
        return _STATUS_OF[self.request.state]

    @property
    def done(self) -> bool:
        return self.request.state is RequestState.DONE

    @property
    def failed(self) -> bool:
        return self.request.state is RequestState.FAILED

    @property
    def finished(self) -> bool:
        """Terminal for now: DONE, or FAILED (parked — revivable)."""
        return self.request.state in (RequestState.DONE, RequestState.FAILED)

    @property
    def decoded(self) -> int:
        """Decode tokens produced so far (excludes the prefill token)."""
        return max(0, len(self.tokens) - 1)

    def decode_finished(self) -> bool:
        """Budget reached or EOS produced — the loop retires us next.
        The prefill-produced first token counts: a stream whose very
        first token is EOS terminates without a decode step."""
        if self.eos_token is not None and self.tokens \
                and self.tokens[-1] == self.eos_token:
            return True
        return self.max_new is not None and self.decoded >= self.max_new

    # ------------------------------------------------------------ stream
    def next_tokens(self) -> list[int]:
        """Tokens produced since the last call (non-blocking)."""
        new = self.tokens[self._consumed:]
        self._consumed = len(self.tokens)
        return list(new)

    def _raise_failed(self) -> None:
        if self.error is not None:
            raise self.error  # terminal (e.g. AdmissionRejected at dispatch)
        raise RuntimeError(
            f"{self.request_id} is parked after failover (no capacity); "
            "add workers / free capacity and call retry_parked()")

    def __iter__(self) -> Iterator[int]:
        """Stream tokens, driving the service loop between yields.
        Raises (like ``result``) if the request fails — a truncated
        stream must not look like a completed one."""
        i = 0
        while True:
            while i < len(self.tokens):
                yield self.tokens[i]
                i += 1
            if self.done:
                return
            if self.failed:
                self._raise_failed()
            self.service.loop.advance(self)
            i = min(i, len(self.tokens))  # failover truncation: re-stream

    def result(self) -> list[int]:
        """Drive the loop until this request finishes; the full token
        list (first token included).  Raises the rejection error for a
        terminally rejected request, or RuntimeError for one parked by
        failover (revivable via ``retry_parked``)."""
        if not self.finished:
            self.service.loop.advance(self, until_done=True)
        if self.failed:
            self._raise_failed()
        return list(self.tokens)

    # ----------------------------------------------------- loop plumbing
    def _push(self, token: int, at: float | None = None) -> None:
        at = self._clock() if at is None else at
        self.tokens.append(token)
        if self.metrics.first_token_at is None:
            self.metrics.first_token_at = at
        self.metrics.last_token_at = at
        self.metrics.token_times.append(at)

    def _reset_decoded(self) -> None:
        """Failover restart: decode replays from scratch, so drop the
        decoded suffix (the replay regenerates the identical tokens)."""
        del self.tokens[1:]
        self._consumed = min(self._consumed, len(self.tokens))
        del self.metrics.token_times[1:]

    # ------------------------------------------------------- delegation
    def __getattr__(self, name: str):
        # only called when normal lookup fails: fall through to Request
        return getattr(self.request, name)

    def __repr__(self) -> str:
        return (f"RequestHandle({self.request_id!r}, {self.status.value}, "
                f"tokens={len(self.tokens)})")
