"""Paged KV cache + fixed-size state cache, backed by one real byte slab.

Each worker owns a single contiguous ``uint8`` slab standing in for its
HBM.  KV tensors are strided views into the slab, and each layer exports
the exact ``TensorDesc`` of Fig. 5 — ``(Address, Dims, Shape, Stride)``
with dims ``("B","KV","L","H","D")`` — so the transfer engine can move
*real bytes* between workers with descriptor-computed one-sided reads.

Layout choice (TPU adaptation): ``block_size`` defaults to 32 tokens so a
(32, kv_heads·head_dim) block is an (8,128)-tile multiple — the DMA- and
MXU-friendly unit — instead of the paper's 4 KB GPU pages.

``SlotCache`` is the SSM analogue: attention-free archs (mamba2, hymba's
SSM half) transfer one *contiguous fixed-size state* per request instead
of paged blocks — the degenerate (best) case for KVDirect, one coalesced
transaction per layer.
"""
from __future__ import annotations

import numpy as np

try:  # bfloat16 matches the paper's "× 2B" arithmetic
    import ml_dtypes

    DEFAULT_DTYPE = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover
    DEFAULT_DTYPE = np.dtype(np.float16)

from repro.core.descriptors import TensorDesc
from repro.core.transfer_engine import MemoryRegion

__all__ = ["PagedKVCache", "SlotCache", "DEFAULT_DTYPE"]


class PagedKVCache:
    """All-layer paged KV storage for one worker.

    Logical shape per layer: ``[B, KV, L, H, D]`` = ``[num_blocks, 2,
    block_size, kv_heads, head_dim]`` (paper Fig. 5's dim names), with the
    paper's KV-major MEMORY layout: all K blocks contiguous, then all V
    blocks (stride(KV) > stride(B), exactly like Fig. 5's example where
    stride = (4096, 40960, 256, 128, 1)).  This both matches the paper's
    worked arithmetic — two disjoint spans per block, K-runs of adjacent
    blocks coalescable — and gives attention kernels separate dense K/V
    planes.
    """

    def __init__(
        self,
        worker_id: str,
        *,
        num_layers: int,
        num_blocks: int,
        block_size: int = 32,
        kv_heads: int = 8,
        head_dim: int = 128,
        dtype: np.dtype = DEFAULT_DTYPE,
        base_address: int = 0x7F06F40000,  # paper Fig. 5's example base
    ) -> None:
        self.worker_id = worker_id
        self.num_layers = num_layers
        self.num_blocks = num_blocks
        self.block_size = block_size
        self.kv_heads = kv_heads
        self.head_dim = head_dim
        self.dtype = np.dtype(dtype)
        self.base_address = base_address

        self.layer_shape = (num_blocks, 2, block_size, kv_heads, head_dim)
        self._layer_elems = int(np.prod(self.layer_shape))
        self._slab = np.zeros(
            self.slab_nbytes(num_layers=num_layers, num_blocks=num_blocks,
                             block_size=block_size, kv_heads=kv_heads,
                             head_dim=head_dim, dtype=self.dtype),
            dtype=np.uint8)
        # Memory order [KV, B, L, H, D]; logical [B, KV, L, H, D] views are
        # transposes of it (strides carry the layout, per Fig. 5).
        self._mem = self._slab.view(self.dtype).reshape(
            (num_layers, 2, num_blocks, block_size, kv_heads, head_dim)
        )
        self._view = self._mem.transpose(0, 2, 1, 3, 4, 5)  # [layer, B, KV, L, H, D]

    @classmethod
    def slab_nbytes(cls, *, num_layers: int, num_blocks: int, block_size: int = 32,
                    kv_heads: int = 8, head_dim: int = 128,
                    dtype: np.dtype = DEFAULT_DTYPE) -> int:
        """Bytes a cache with these dims allocates (one K and one V span
        per block per layer) — the single source of truth callers use to
        size address windows and KV footprints."""
        return int(num_layers * num_blocks * 2 * block_size * kv_heads
                   * head_dim * np.dtype(dtype).itemsize)

    # ------------------------------------------------------- descriptors
    def desc(self, layer: int) -> TensorDesc:
        if not (0 <= layer < self.num_layers):
            raise IndexError(f"layer {layer} out of range")
        # element strides of one layer's [B, KV, L, H, D] view
        s = self._view[layer].strides
        stride = tuple(x // self.dtype.itemsize for x in s)
        return TensorDesc(
            address=self.base_address + layer * self._layer_elems * self.dtype.itemsize,
            dims=("B", "KV", "L", "H", "D"),
            shape=self.layer_shape,
            stride=stride,
            itemsize=self.dtype.itemsize,
            worker_id=self.worker_id,
            tensor_id=f"layer{layer}/kv",
        )

    def descriptors(self) -> list[TensorDesc]:
        return [self.desc(l) for l in range(self.num_layers)]

    def memory_region(self) -> MemoryRegion:
        return MemoryRegion(self.worker_id, self.base_address, self._slab)

    # ------------------------------------------------------------ access
    def write_block(self, layer: int, block_id: int, k: np.ndarray, v: np.ndarray) -> None:
        """k, v: [block_size, kv_heads, head_dim] (short final blocks are
        zero-padded by the caller)."""
        self._view[layer, block_id, 0] = k.astype(self.dtype)
        self._view[layer, block_id, 1] = v.astype(self.dtype)

    def read_block(self, layer: int, block_id: int) -> tuple[np.ndarray, np.ndarray]:
        blk = self._view[layer, block_id]
        return np.array(blk[0]), np.array(blk[1])

    def layer_array(self, layer: int) -> np.ndarray:
        """Zero-copy [B, KV, L, H, D] view for compute."""
        return self._view[layer]

    def kv_planes(self, layer: int) -> tuple[np.ndarray, np.ndarray]:
        """Zero-copy dense K and V planes, each [B, L, H, D] — the layout
        attention kernels consume."""
        return self._mem[layer, 0], self._mem[layer, 1]

    @property
    def block_nbytes(self) -> int:
        """Bytes of one K *or* V span of a block (one read transaction)."""
        return self.block_size * self.kv_heads * self.head_dim * self.dtype.itemsize

    @property
    def nbytes(self) -> int:
        return self._slab.nbytes


class SlotCache:
    """Fixed-size per-request recurrent state (SSM/conv), contiguous per
    slot.  dims ("B","E"): slot id × flattened state elements — a single
    dense span per slot, so each transfer is exactly one transaction."""

    def __init__(
        self,
        worker_id: str,
        *,
        num_layers: int,
        num_slots: int,
        state_elems: int,
        dtype: np.dtype = DEFAULT_DTYPE,
        base_address: int = 0x7F20000000,
    ) -> None:
        self.worker_id = worker_id
        self.num_layers = num_layers
        self.num_slots = num_slots
        self.state_elems = state_elems
        self.dtype = np.dtype(dtype)
        self.base_address = base_address
        self._slab = np.zeros(
            num_layers * num_slots * state_elems * self.dtype.itemsize, dtype=np.uint8
        )
        self._view = self._slab.view(self.dtype).reshape(num_layers, num_slots, state_elems)

    def desc(self, layer: int) -> TensorDesc:
        per_layer = self.num_slots * self.state_elems
        return TensorDesc(
            address=self.base_address + layer * per_layer * self.dtype.itemsize,
            dims=("B", "E"),
            shape=(self.num_slots, self.state_elems),
            stride=(self.state_elems, 1),
            itemsize=self.dtype.itemsize,
            worker_id=self.worker_id,
            tensor_id=f"layer{layer}/state",
        )

    def descriptors(self) -> list[TensorDesc]:
        return [self.desc(l) for l in range(self.num_layers)]

    def memory_region(self) -> MemoryRegion:
        return MemoryRegion(self.worker_id, self.base_address, self._slab)

    def write_slot(self, layer: int, slot: int, state: np.ndarray) -> None:
        self._view[layer, slot] = state.reshape(-1).astype(self.dtype)

    def read_slot(self, layer: int, slot: int) -> np.ndarray:
        return np.array(self._view[layer, slot])
