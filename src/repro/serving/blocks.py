"""Paged KV-cache block pool (vLLM-style, per worker).

The allocator distinguishes **reservation** from **allocation**:

* ``allocate(n)``   — blocks that hold data *now* (prefill output, decode
  growth).  This is all pull-mode ever needs on the decode worker.
* ``reserve(n)``    — push-mode's pre-allocation (§4.3): blocks held for a
  request whose prefill hasn't finished.  They consume capacity without
  holding data — exactly the "held but idling" memory of Motivation #3.

All-or-nothing: a request either gets every block or none, which is the
paper's deadlock-avoidance argument — incremental on-demand allocation
deadlocks when concurrent requests each hold partial sets and the pool is
exhausted (§3 Motivation #3).

Contiguity: ``allocate`` hands out the longest contiguous runs available
(best-fit on run length).  Contiguous block IDs ⇒ adjacent byte ranges ⇒
coalescing opportunities in the transfer engine (§4.2: long prompts see
less fragmentation and coalesce more, Fig. 17).

Refcounts support prefix sharing (paper §7 future work — implemented here
because the decode worker can map several requests onto one pulled
prefix).
"""
from __future__ import annotations

import dataclasses

__all__ = ["BlockPool", "OutOfBlocks"]


class OutOfBlocks(Exception):
    """Not enough free blocks; caller must queue, never spin-wait holding
    a partial allocation (deadlock — Motivation #3)."""


@dataclasses.dataclass
class PoolStats:
    capacity: int
    allocated: int = 0
    reserved: int = 0
    peak_in_use: int = 0

    @property
    def free(self) -> int:
        return self.capacity - self.allocated - self.reserved

    @property
    def in_use(self) -> int:
        return self.allocated + self.reserved


class BlockPool:
    def __init__(self, num_blocks: int, *, block_size: int = 32) -> None:
        if num_blocks <= 0:
            raise ValueError("num_blocks must be positive")
        self.block_size = block_size
        self._free: set[int] = set(range(num_blocks))
        self._refcount: dict[int, int] = {}
        self._reserved_only: set[int] = set()
        self.stats = PoolStats(capacity=num_blocks)

    # ------------------------------------------------------------ sizing
    @staticmethod
    def blocks_for_tokens(num_tokens: int, block_size: int) -> int:
        return -(-num_tokens // block_size)  # ceil div

    # ---------------------------------------------------------- allocate
    def _take(self, n: int) -> list[int]:
        if n > len(self._free):
            raise OutOfBlocks(f"need {n} blocks: pool {self.describe()}")
        # Find contiguous runs among free IDs; prefer the tightest run that
        # fits (best-fit) to keep long runs available for long prompts.
        runs: list[tuple[int, int]] = []  # (start, length)
        start = prev = None
        for b in sorted(self._free):
            if prev is None or b != prev + 1:
                if start is not None:
                    runs.append((start, prev - start + 1))
                start = b
            prev = b
        if start is not None:
            runs.append((start, prev - start + 1))
        fitting = [r for r in runs if r[1] >= n]
        if fitting:
            s, _ = min(fitting, key=lambda r: r[1])
            taken = list(range(s, s + n))
        else:  # stitch together the longest runs first
            taken = []
            for s, ln in sorted(runs, key=lambda r: -r[1]):
                take = min(ln, n - len(taken))
                taken.extend(range(s, s + take))
                if len(taken) == n:
                    break
        for b in taken:
            self._free.discard(b)
            self._refcount[b] = 1
        return taken

    def allocate(self, n: int) -> list[int]:
        blocks = self._take(n)
        self.stats.allocated += n
        self.stats.peak_in_use = max(self.stats.peak_in_use, self.stats.in_use)
        return blocks

    def reserve(self, n: int) -> list[int]:
        """Push-mode pre-allocation: capacity held before data exists."""
        blocks = self._take(n)
        self._reserved_only.update(blocks)
        self.stats.reserved += n
        self.stats.peak_in_use = max(self.stats.peak_in_use, self.stats.in_use)
        return blocks

    def commit(self, blocks: list[int]) -> None:
        """Reserved → allocated (push-mode data has landed)."""
        for b in blocks:
            if b in self._reserved_only:
                self._reserved_only.discard(b)
                self.stats.reserved -= 1
                self.stats.allocated += 1

    # -------------------------------------------------------------- free
    def share(self, blocks: list[int]) -> None:
        """Bump refcounts (prefix sharing)."""
        for b in blocks:
            if b not in self._refcount:
                raise KeyError(f"block {b} not allocated")
            self._refcount[b] += 1

    def free(self, blocks: list[int]) -> list[int]:
        """Release one reference per block; returns the blocks that were
        ACTUALLY freed (refcount reached zero) — shared blocks merely
        decrement and are not in the returned list.  Callers indexing
        block contents (e.g. the decode workers' content-hash dedup
        index) purge exactly the returned ids."""
        released: list[int] = []
        for b in blocks:
            rc = self._refcount.get(b)
            if rc is None:
                raise KeyError(f"double free of block {b}")
            if rc > 1:
                self._refcount[b] = rc - 1
                continue
            del self._refcount[b]
            if b in self._reserved_only:
                self._reserved_only.discard(b)
                self.stats.reserved -= 1
            else:
                self.stats.allocated -= 1
            self._free.add(b)
            released.append(b)
        return released

    # ------------------------------------------------------------- query
    @property
    def num_free(self) -> int:
        return len(self._free)

    @property
    def num_shared(self) -> int:
        """Blocks held by more than one reference (prefix grafts, the
        retention cache) — occupancy that frees only when every holder
        releases."""
        return sum(1 for rc in self._refcount.values() if rc > 1)

    def describe(self) -> str:
        """One-line occupancy summary (used/free/shared) — what every
        ``OutOfBlocks`` message embeds so preemption-threshold debugging
        reads the pool state straight off the exception."""
        s = self.stats
        return (f"{s.in_use}/{s.capacity} used "
                f"({len(self._free)} free, {self.num_shared} shared, "
                f"{s.reserved} reserved)")

    def can_allocate(self, n: int) -> bool:
        return n <= len(self._free)

    def check_invariants(self) -> None:
        """Used by property tests."""
        held = set(self._refcount)
        assert held.isdisjoint(self._free), "block both free and held"
        assert len(held) + len(self._free) == self.stats.capacity
        assert self.stats.allocated + self.stats.reserved == len(held)
        assert self._reserved_only <= held
        assert all(rc >= 1 for rc in self._refcount.values())
