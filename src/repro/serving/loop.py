"""``ServeLoop`` — the event-driven heart of the streaming serving API.

One ``tick()`` advances EVERY live request one notch through the
pipeline, interleaving the four kinds of work a disaggregated serving
node juggles:

  1. **prefill dispatch** — queued submissions (``dispatch="queued"``)
     are routed and prefilled; SLO admission rejections surface on the
     handle as FAILED instead of raising at the caller;
  2. **retirement** — requests whose stream completed (budget/EOS on
     the previous step, EOS straight from prefill, or finished through
     the legacy direct-worker path) leave before anything else runs;
  3. **admission planning** — the router batches every KV_QUEUED request
     per decode worker (capacity-capped, FIFO) and the pulls are
     SUBMITTED, not drained;
  4. **transfer progress** — the engine's per-tick budget hook
     (``TransferEngine.tick``) advances queued transactions, but only
     when no decode worker has compute to hide them behind — otherwise
     the workers' own between-step pumps do the hiding;
  5. **per-step decode** — each decode worker runs ONE continuous-
     batching ``step()``: requests join the running batch the moment
     their KV lands (or stream it in layer-by-layer), produce one token
     each, and leave at EOS / ``max_new`` without stalling cohabitants.

``run_until_idle()`` ticks until every driven handle is DONE (or parked
by failover), with the same stall detection the old round-synchronous
``generate_many`` had: if a full tick makes no progress of any kind and
no request moved (failover counts as movement), ``ServeLoopStalled``
raises naming the stuck requests.

The loop is deliberately synchronous and deterministic — one tick is one
pass, tokens are appended to handles as steps land — which is what lets
``generate``/``generate_many`` remain thin, token-identical shims on
top of it.
"""
from __future__ import annotations

import collections
import dataclasses
import time

from repro.fleet.admission import AdmissionDeferred
from repro.obs.trace import NULL_TRACER
from repro.sched import AdmissionRejected, NoWorkersError
from repro.serving.blocks import OutOfBlocks
from repro.serving.request import RequestState

__all__ = ["ServeLoop", "ServeLoopStalled", "TickReport"]


class ServeLoopStalled(RuntimeError):
    """No request can make progress: typically every stuck request's
    decode pool is too small for its KV footprint.

    Stall forensics: the exception carries the FINAL ``TickReport``
    (``report``) and the loop's cumulative per-phase progress counters
    (``phase_counters``), and renders both into the message — so a CI
    log alone shows *which* pipeline phase stopped moving (nothing ever
    admitted?  tokens flowed then stopped?  engine still churning?)."""

    def __init__(self, request_ids, report: "TickReport | None" = None,
                 phase_counters: dict | None = None) -> None:
        self.request_ids = tuple(sorted(request_ids))
        self.report = report
        self.phase_counters = dict(phase_counters or {})
        stuck = ", ".join(self.request_ids)
        msg = (f"serve loop stalled: {stuck} cannot make progress "
               "(decode pools too small for the request?)")
        if report is not None:
            msg += f"\n  last tick: {report.describe()}"
        if self.phase_counters:
            totals = ", ".join(f"{k}={int(v)}"
                               for k, v in sorted(self.phase_counters.items()))
            msg += f"\n  phase totals: {totals}"
        super().__init__(msg)


@dataclasses.dataclass
class TickReport:
    """What one tick did — the loop's observable progress."""

    now: float
    dispatched: list[str] = dataclasses.field(default_factory=list)
    rejected: list[str] = dataclasses.field(default_factory=list)
    admitted: list[str] = dataclasses.field(default_factory=list)
    promoted: list[str] = dataclasses.field(default_factory=list)
    tokens: dict[str, int] = dataclasses.field(default_factory=dict)
    finished: list[str] = dataclasses.field(default_factory=list)
    engine_processed: int = 0
    # requests auto-revived from a failover park this tick
    revived: list[str] = dataclasses.field(default_factory=list)
    # fleet control-plane action counts (FleetController.step): nonzero
    # entries like {"swapped_out": 1, "added": 1, "retired": 1}
    fleet: dict[str, int] = dataclasses.field(default_factory=dict)

    @property
    def progressed(self) -> bool:
        return bool(self.dispatched or self.rejected or self.admitted
                    or self.promoted or self.tokens or self.finished
                    or self.engine_processed or self.revived or self.fleet)

    def describe(self) -> str:
        """Every field on one line — what ServeLoopStalled embeds."""
        return (f"now={self.now:.6f} dispatched={self.dispatched} "
                f"rejected={self.rejected} admitted={self.admitted} "
                f"promoted={self.promoted} tokens={self.tokens} "
                f"finished={self.finished} "
                f"engine_processed={self.engine_processed} "
                f"revived={self.revived} fleet={self.fleet}")


class ServeLoop:
    def __init__(self, service, *, pump_budget: int | None = 32,
                 engine_budget: int | None = None,
                 max_admit: int | None = None) -> None:
        self.service = service
        self.pump_budget = pump_budget      # worker between-step pumps
        # per-tick transfer budget; None mirrors pump_budget so transfer
        # work stays metered at the same grain as the between-step pumps
        # (a free-running engine would drain whole pulls before the first
        # decode step could hide them)
        self.engine_budget = engine_budget
        self.max_admit = max_admit          # per-worker admission cap
        self.ticks = 0
        # Stall forensics (see ServeLoopStalled): the most recent tick's
        # report plus cumulative per-phase progress totals.
        self.last_report: TickReport | None = None
        self.phase_counters: collections.Counter[str] = collections.Counter()
        # (n_prefill, n_decode) at the end of the last tick — parked
        # requests auto-revive when this changes (capacity returned)
        self._fleet_size: tuple[int, int] | None = None

    # ------------------------------------------------------------- tick
    def tick(self, now: float | None = None) -> TickReport:
        """One pass over the pipeline; returns what moved."""
        svc = self.service
        if now is not None:
            svc.clock = max(svc.clock, now)
        self.ticks += 1
        report = TickReport(now=svc.clock)
        tracer = getattr(svc, "tracer", NULL_TRACER)
        clock = getattr(svc, "obs_clock", time.monotonic)
        tick_span = tracer.span("tick", track="loop", tick=self.ticks)

        # Snapshot the dispatch backlog BEFORE step 1 drains it: the
        # autoscaler's prefill-pressure signal (docs/fleet.md) must see
        # the queue depth arrivals produced, not the post-dispatch zero.
        backlog = sum(1 for req, _ in svc.pending.values()
                      if req.state is RequestState.QUEUED_PREFILL)

        # 1. dispatch queued submissions (prefill + routing)
        with tracer.span("tick.dispatch", track="loop"):
            for rid, h in list(svc.handles.items()):
                if h.request.state is not RequestState.QUEUED_PREFILL:
                    continue
                entry = svc.pending.get(rid)
                if entry is None:
                    continue
                try:
                    svc._dispatch(h.request, entry[1], hedge=h.hedge)
                    report.dispatched.append(rid)
                except AdmissionDeferred:
                    pass  # soft verdict: stays QUEUED, retried next tick
                except AdmissionRejected as e:
                    svc._reject_queued(rid, e)
                    report.rejected.append(rid)
                except (NoWorkersError, OutOfBlocks):
                    pass  # stays QUEUED; capacity may come back next tick

        # 2. retire finished requests BEFORE admission and decode: a
        # request whose stream is already complete (EOS/budget reached
        # on the previous tick's step, EOS straight from prefill, or a
        # zero budget) must not be admitted or stepped again.  DECODING
        # is the normal exit; KV_QUEUED means no pull ever started (the
        # prefill copy is released by _finish_request); a handle already
        # DONE (finished through the legacy direct-worker path) is swept
        # so it can't wedge run_until_idle.
        with tracer.span("tick.retire", track="loop"):
            for rid, h in list(svc.handles.items()):
                st = h.request.state
                if st is RequestState.DONE or (
                        st in (RequestState.DECODING, RequestState.KV_QUEUED)
                        and h.decode_finished()):
                    svc._finish_request(rid)
                    report.finished.append(rid)

        # 2½. fleet control plane (docs/fleet.md) — preemption governor,
        # autoscaler, drain advancement — BETWEEN retire and admit, so
        # capacity it frees (a swap-out, a retired drain, a hot-added
        # worker) is usable for admission in this same tick.
        if getattr(svc, "fleet", None) is not None:
            with tracer.span("tick.fleet", track="loop") as s:
                report.fleet = svc.fleet.step(dispatch_backlog=backlog)
                s.set(**report.fleet)

        # 2¾. auto-revive parked requests when capacity returned this
        # tick: the fleet changed size, a fleet action freed blocks, or
        # a request finished (its blocks are back in the pool).  A bare
        # retry every tick would inflate retry counters for nothing.
        fleet_size = (len(svc.prefills), len(svc.decodes))
        capacity_changed = (fleet_size != self._fleet_size
                            or bool(report.finished) or bool(report.fleet))
        self._fleet_size = fleet_size
        if capacity_changed and any(
                req.state is RequestState.FAILED
                for req, _ in svc.pending.values()):
            with tracer.span("tick.revive", track="loop"):
                report.revived = svc.retry_parked()

        # 3. router-planned admission batches (KV_QUEUED -> pulls queued)
        with tracer.span("tick.admit", track="loop"):
            admitted = svc.admit_queued(only=set(svc.handles),
                                        max_batch=self.max_admit)
            for rids in admitted.values():
                report.admitted.extend(rids)

        # 4. engine tick budget — run it when there is no decode compute
        # to hide the transfer behind, or when some full-consumption
        # worker's pulls would otherwise starve (it has nothing resident,
        # so it won't pump between steps).  In every other case the
        # workers' own between-step pumps advance the engine — that's
        # where the transfer/compute overlap comes from.
        no_compute = not any(dw.resident for dw in svc.decodes.values())
        starved = any(dw.inflight and not dw.resident and dw.consume == "full"
                      for dw in svc.decodes.values())
        if svc.engine.pending and (no_compute or starved):
            budget = self.engine_budget
            if budget is None:
                budget = self.pump_budget  # None again -> engine.tick_budget
            with tracer.span("tick.transfer", track="loop") as s:
                report.engine_processed = svc.engine.tick(budget)
                s.set(processed=report.engine_processed)

        # 5. promote pulls that resolved
        with tracer.span("tick.promote", track="loop"):
            report.promoted = svc.pump(0)

        # 6. one continuous-batching decode step per worker with work
        for dw in list(svc.decodes.values()):
            if not (dw.resident or (dw.consume == "layerwise" and dw.inflight)):
                continue
            with tracer.span("tick.step", track=("worker", dw.info.worker_id),
                             batch=len(dw.resident)) as s:
                out = dw.step(pump_budget=self.pump_budget)
                s.set(tokens=len(out))
            at = clock()
            for rid, tok in out.items():
                h = svc.handles.get(rid)
                if h is None:
                    continue
                h._push(tok, at)
                h.request.token_times_s.append(svc.clock)
                report.tokens[rid] = tok

        tick_span.end()
        self._account(report)
        return report

    def _account(self, report: TickReport) -> None:
        """Fold one tick's movement into the cumulative phase counters
        and the service metrics registry."""
        self.last_report = report
        pc = self.phase_counters
        pc["ticks"] += 1
        moved = {
            "dispatched": len(report.dispatched),
            "rejected": len(report.rejected),
            "admitted": len(report.admitted),
            "promoted": len(report.promoted),
            "tokens": len(report.tokens),
            "finished": len(report.finished),
            "engine_processed": report.engine_processed,
            "revived": len(report.revived),
        }
        for k, n in report.fleet.items():
            pc[f"fleet.{k}"] += n
        for k, n in moved.items():
            if n:
                pc[k] += n
        metrics = getattr(self.service, "metrics", None)
        if metrics is not None:
            metrics.inc("loop.ticks")
            for k, n in moved.items():
                if n:
                    metrics.inc(f"loop.{k}", n)
            metrics.set_gauge("loop.active_requests",
                              len(self.service.handles))

    # ------------------------------------------------------------ drive
    def _signature(self, rids) -> dict[str, tuple]:
        svc = self.service
        sig = {}
        for rid in rids:
            h = svc.handles.get(rid)
            if h is None:
                sig[rid] = ("gone",)
                continue
            r = h.request
            sig[rid] = (r.state, r.prefill_worker, r.decode_worker,
                        len(h.tokens))
        return sig

    def _active(self, only: set[str] | None) -> list[str]:
        """Handles still being driven: not DONE (a legacy direct-worker
        finish leaves a DONE handle registered until the next tick
        sweeps it), not parked."""
        return [rid for rid, h in self.service.handles.items()
                if (only is None or rid in only)
                and h.request.state not in (RequestState.FAILED,
                                            RequestState.DONE)]

    def run_until_idle(self, only: set[str] | None = None, *,
                       max_ticks: int = 100_000) -> list[str]:
        """Tick until every driven handle (all of them, or just ``only``)
        is DONE or parked.  Returns the request ids that finished DONE.
        Raises ``ServeLoopStalled`` when a tick moves nothing at all."""
        svc = self.service
        finished: list[str] = []
        for _ in range(max_ticks):
            active = self._active(only)
            if not active:
                return finished
            unbounded = [rid for rid in active
                         if svc.handles[rid].max_new is None
                         and svc.handles[rid].eos_token is None]
            if unbounded:
                raise ValueError(
                    f"run_until_idle would never terminate: {sorted(unbounded)} "
                    "have neither max_new nor eos_token — set a budget "
                    "(e.g. via generate_many) or drive tick() directly")
            before = self._signature(active)
            report = self.tick()
            finished.extend(report.finished)
            if report.progressed:
                continue
            if self._signature(active) != before:
                continue  # failover moved a request mid-tick: progress
            raise ServeLoopStalled(self._active(only), report=self.last_report,
                                   phase_counters=self.phase_counters)
        raise ServeLoopStalled(self._active(only), report=self.last_report,
                               phase_counters=self.phase_counters)

    def advance(self, handle, *, until_done: bool = False,
                max_ticks: int = 100_000) -> None:
        """Tick until ``handle`` produces at least one new token (or
        finishes); ``until_done`` keeps going to the end.  The streaming
        iterator's engine."""
        start = len(handle.tokens)
        for _ in range(max_ticks):
            if handle.finished or (not until_done
                                   and len(handle.tokens) > start):
                return
            active = self._active(None)
            before = self._signature(active)
            report = self.tick()
            if report.progressed or self._signature(active) != before:
                continue
            raise ServeLoopStalled([handle.request_id], report=self.last_report,
                                   phase_counters=self.phase_counters)
        raise ServeLoopStalled([handle.request_id], report=self.last_report,
                               phase_counters=self.phase_counters)
