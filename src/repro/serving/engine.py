"""Serving workers: real JAX compute + real KV bytes through KVDirect.

``PrefillWorker`` runs the model's prefill, lands the produced KV pages
in its numpy-backed PagedKVCache slab (the registered MR the transfer
engine reads from), and registers descriptors.  ``DecodeWorker`` pulls
KV through the transfer engine (pull_kv → one-sided reads + COMPLETE),
reconstructs a device DecodeState from its own slab, and decodes with
continuous batching.

This is the CPU-scale end-to-end path (examples/serve_disaggregated.py);
the pod-scale path is launch/serve.py + the sharded serve_step.  Both
consume the same caches, descriptors, and engine.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.connection import Connection, DescriptorRegistry, WorkerInfo
from repro.core.pull_push import pull_kv_async
from repro.core.transfer_engine import TransferEngine, TransferFuture
from repro.models.transformer import DecodeState
from repro.serving.blocks import BlockPool, OutOfBlocks
from repro.serving.kv_cache import PagedKVCache
from repro.serving.request import Request, RequestState

__all__ = ["PrefillWorker", "DecodeWorker"]


class PrefillWorker:
    def __init__(self, info: WorkerInfo, model, params, *, num_blocks: int = 256,
                 base_address: int = 0x7F06F40000):
        cfg = model.cfg
        if not cfg.has_attention or cfg.sliding_window:
            raise NotImplementedError(
                "CPU serving path covers paged-KV archs; SSM/SWA archs use "
                "SlotCache transfer (see tests/test_pull_push.py)")
        self.info = info
        self.model = model
        self.params = params
        self.block_size = model.BLOCK_SIZE
        self.cache = PagedKVCache(
            info.worker_id,
            num_layers=cfg.num_layers,
            num_blocks=num_blocks,
            block_size=self.block_size,
            kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim,
            base_address=base_address,
        )
        self.pool = BlockPool(num_blocks, block_size=self.block_size)
        self.registry = DescriptorRegistry(info.worker_id)
        for d in self.cache.descriptors():
            self.registry.register(d)

    def prefill(self, req: Request, tokens: np.ndarray) -> int:
        """Run prefill, park KV blocks in the slab, return the first token."""
        req.to(RequestState.PREFILLING)
        logits, state = self.model.prefill(
            self.params, {"tokens": jnp.asarray(tokens[None], jnp.int32)},
            max_blocks_margin=0, remat=False,
        )
        k_pages = np.asarray(state.k_pages[:, 0])  # [L, spb, bs, g, hd]
        v_pages = np.asarray(state.v_pages[:, 0])
        spb = k_pages.shape[1]
        req.prefill_blocks = self.pool.allocate(spb)
        for layer in range(self.cache.num_layers):
            for j, blk in enumerate(req.prefill_blocks):
                self.cache.write_block(layer, blk, k_pages[layer, j], v_pages[layer, j])
        first = int(jnp.argmax(logits[0, : self.model.cfg.vocab_size]))
        return first

    def release(self, req: Request) -> None:
        """COMPLETE() arrived: free the request's prefill-side blocks."""
        if req.prefill_blocks:
            self.pool.free(req.prefill_blocks)
            req.prefill_blocks = []


@dataclasses.dataclass
class _Resident:
    req: Request
    blocks: list[int]
    context_len: int
    last_token: int
    # float32 page cache built lazily from the slab: [L, n, bs, heads, hd].
    # Rebuilt only when blocks are appended — decode_round no longer
    # re-gathers and re-casts every resident block every round.
    k_cached: np.ndarray | None = None
    v_cached: np.ndarray | None = None


@dataclasses.dataclass
class _InFlight:
    """An admission whose KV pull is still in the air."""

    req: Request
    first_token: int
    future: TransferFuture


class DecodeWorker:
    def __init__(self, info: WorkerInfo, model, params, *, num_blocks: int = 256,
                 engine: TransferEngine | None = None,
                 base_address: int = 0x7F80000000):
        cfg = model.cfg
        self.info = info
        self.model = model
        self.params = params
        self.block_size = model.BLOCK_SIZE
        self.cache = PagedKVCache(
            info.worker_id,
            num_layers=cfg.num_layers,
            num_blocks=num_blocks,
            block_size=self.block_size,
            kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim,
            base_address=base_address,
        )
        self.pool = BlockPool(num_blocks, block_size=self.block_size)
        self.engine = engine or TransferEngine()
        self.engine.register_memory(self.cache.memory_region())
        self.resident: dict[str, _Resident] = {}
        self.inflight: dict[str, _InFlight] = {}

    # ------------------------------------------------------------ admit
    def admit_async(self, req: Request, conn: Connection, first_token: int) -> TransferFuture:
        """Event-driven pull-mode admission: allocate, submit the layer-
        streamed pull, return immediately.  The transfer advances when the
        worker calls ``pump()`` (typically interleaved with decode steps),
        and the request is promoted to DECODING the moment its future
        resolves.

        Allocation happens BEFORE any state transition so an OutOfBlocks
        failure leaves the request exactly as it was (KV_QUEUED, prefill
        KV alive) — the caller's retry contract depends on it."""
        blocks = self.pool.allocate(len(req.prefill_blocks))  # may raise
        req.to(RequestState.KV_TRANSFER)
        fut = pull_kv_async(req, conn=conn, engine=self.engine,
                            decode_pool=self.pool, decode_cache=self.cache,
                            preallocated=blocks)
        self.inflight[req.request_id] = _InFlight(req, first_token, fut)
        return fut

    def admit_batch(
        self, admissions: Sequence[tuple[Request, Connection, int]]
    ) -> list[TransferFuture]:
        """Admit a batch of KV_QUEUED requests in one go: every pull is
        submitted before any byte moves, so the whole batch pipelines
        behind decode compute instead of serializing admission-by-
        admission (coalescing itself stays per-request — each COMPLETE
        ends a window).  Admits in order, stopping at the first request
        that doesn't fit (FIFO fairness — later arrivals must not starve
        it); returns the futures of the admitted prefix."""
        futures: list[TransferFuture] = []
        for req, conn, first_token in admissions:
            try:
                futures.append(self.admit_async(req, conn, first_token))
            except OutOfBlocks:
                break
        return futures

    def admit(self, req: Request, conn: Connection, first_token: int) -> None:
        """Blocking admission (legacy): submit the pull and drain it to
        completion before returning.  Byte-identical to the async path —
        it IS the async path, progressed until resolved."""
        fut = self.admit_async(req, conn, first_token)
        try:
            self.engine.drain()
        except Exception:
            # drain may raise ANOTHER request's torn error; only clean up
            # our admission if OUR pull actually died (abort requires a
            # resolved future — queued reads must not write freed blocks)
            if fut.failed:
                self.abort(req.request_id)
            raise
        if fut.failed:
            self.abort(req.request_id)
            raise fut.exception()
        self.pump(0)  # promote (no more transfer work to do)
        assert req.request_id in self.resident

    def abort(self, request_id: str) -> bool:
        """Drop an in-flight admission whose pull died (connection torn /
        failover): free the decode-side blocks and forget the entry.  The
        caller must only abort once the future is resolved — queued reads
        into the freed blocks would otherwise still execute."""
        fl = self.inflight.pop(request_id, None)
        if fl is None:
            return False
        if fl.req.decode_blocks:
            self.pool.free(fl.req.decode_blocks)
            fl.req.decode_blocks = []
        return True

    # -------------------------------------------------------------- pump
    def pump(self, budget: int | None = None) -> list[str]:
        """Advance in-flight pulls by up to ``budget`` transactions and
        promote every request whose future resolved to DECODING.  Returns
        the promoted request ids.  Failed futures (torn connections) are
        aborted here — their requests stay in KV_TRANSFER for the serving
        layer's failover to re-route."""
        if self.inflight and self.engine.pending:
            self.engine.progress(budget)
        self.engine.poll()  # keep the shared completion queue drained
        promoted: list[str] = []
        for rid, fl in list(self.inflight.items()):
            if not fl.future.done():
                continue
            if fl.future.failed:
                self.abort(rid)  # one owner for the torn-pull cleanup
                continue
            del self.inflight[rid]
            req = fl.req
            req.to(RequestState.QUEUED_DECODE)
            self.resident[rid] = _Resident(
                req, req.decode_blocks, req.prompt_len, fl.first_token)
            req.to(RequestState.DECODING)
            promoted.append(rid)
        return promoted

    # ------------------------------------------------------------ decode
    def _gather_pages(self, blocks: list[int]) -> tuple[np.ndarray, np.ndarray]:
        """Slab → float32 pages for ``blocks``: [L, n, bs, heads, hd]."""
        cfg = self.model.cfg
        k = np.empty((cfg.num_layers, len(blocks), self.block_size,
                      cfg.num_kv_heads, cfg.head_dim), np.float32)
        v = np.empty_like(k)
        for layer in range(cfg.num_layers):
            kplane, vplane = self.cache.kv_planes(layer)  # [blocks, bs, g, hd]
            k[layer] = kplane[blocks].astype(np.float32)
            v[layer] = vplane[blocks].astype(np.float32)
        return k, v

    def _resident_pages(self, r: _Resident) -> tuple[np.ndarray, np.ndarray]:
        """Per-request page cache: gather/cast from the slab only for
        blocks not seen before, reuse the rest.  Today a resident's block
        list is fixed at promotion, so the append branch runs once; it
        future-proofs decode-time block growth / layer-streamed
        consumption without a rewrite."""
        cached = 0 if r.k_cached is None else r.k_cached.shape[1]
        if cached < len(r.blocks):
            k_new, v_new = self._gather_pages(r.blocks[cached:])
            r.k_cached = k_new if r.k_cached is None else np.concatenate(
                [r.k_cached, k_new], axis=1)
            r.v_cached = v_new if r.v_cached is None else np.concatenate(
                [r.v_cached, v_new], axis=1)
        return r.k_cached, r.v_cached

    def _build_state(self, batch: list[_Resident], margin_blocks: int) -> DecodeState:
        """Assemble a per-seq paged DecodeState from the residents' page
        caches (slab reads only for newly pulled blocks)."""
        cfg = self.model.cfg
        bs = self.block_size
        L = cfg.num_layers
        per_seq = max(len(r.blocks) for r in batch) + margin_blocks
        b = len(batch)
        k_pages = np.zeros((L, b, per_seq, bs, cfg.num_kv_heads, cfg.head_dim), np.float32)
        v_pages = np.zeros_like(k_pages)
        for i, r in enumerate(batch):
            k, v = self._resident_pages(r)
            n = len(r.blocks)
            k_pages[:, i, :n] = k[:, :n]
            v_pages[:, i, :n] = v[:, :n]
        tables = np.broadcast_to(np.arange(per_seq, dtype=np.int32)[None], (b, per_seq))
        return DecodeState(
            context_lens=jnp.asarray([r.context_len for r in batch], jnp.int32),
            k_pages=jnp.asarray(k_pages, jnp.bfloat16),
            v_pages=jnp.asarray(v_pages, jnp.bfloat16),
            block_tables=jnp.asarray(tables),
        )

    def decode_round(self, max_new: int = 8, *,
                     pump_budget: int | None = 32) -> dict[str, list[int]]:
        """Continuous-batching decode until every resident request has
        produced ``max_new`` tokens or finished.  Returns generated ids.

        Between decode steps the worker pumps the transfer engine by
        ``pump_budget`` transactions, so in-flight pulls make progress
        behind decode compute; requests whose pull resolves mid-round are
        promoted immediately and join the batch at the next round."""
        if not self.resident:
            self.pump(pump_budget)
            if not self.resident:
                return {}
        batch = list(self.resident.values())
        state = self._build_state(batch, margin_blocks=-(-max_new // self.block_size))
        tokens = jnp.asarray([r.last_token for r in batch], jnp.int32)
        out: dict[str, list[int]] = {r.req.request_id: [] for r in batch}
        for _ in range(max_new):
            logits, state = self.model.decode_step(self.params, state, tokens)
            if self.inflight:
                self.pump(pump_budget)  # transfer hides behind the step
            tokens = jnp.argmax(
                logits[:, : self.model.cfg.vocab_size].astype(jnp.float32), axis=-1
            ).astype(jnp.int32)
            for i, r in enumerate(batch):
                out[r.req.request_id].append(int(tokens[i]))
                r.req.tokens_generated += 1
        for i, r in enumerate(batch):
            r.context_len = int(state.context_lens[i])
            r.last_token = int(tokens[i])
        return out

    def finish(self, req_id: str) -> None:
        r = self.resident.pop(req_id, None)
        if r is not None:
            self.pool.free(r.blocks)
            r.req.to(RequestState.DONE)
