"""Serving workers: real JAX compute + real KV bytes through KVDirect.

``PrefillWorker`` runs the model's prefill, lands the produced KV pages
in its numpy-backed PagedKVCache slab (the registered MR the transfer
engine reads from), and registers descriptors.  ``DecodeWorker`` pulls
KV through the transfer engine (pull_kv → one-sided reads + COMPLETE),
reconstructs a device DecodeState from its own slab, and decodes with
continuous batching.

This is the CPU-scale end-to-end path (examples/serve_disaggregated.py);
the pod-scale path is launch/serve.py + the sharded serve_step.  Both
consume the same caches, descriptors, and engine.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.connection import Connection, DescriptorRegistry, WorkerInfo
from repro.core.pull_push import pull_kv_async
from repro.core.transfer_engine import ConnectionTornError, TransferEngine, TransferFuture
from repro.models.transformer import DecodeState
from repro.serving.blocks import BlockPool, OutOfBlocks
from repro.serving.kv_cache import PagedKVCache
from repro.serving.request import Request, RequestState

__all__ = ["PrefillWorker", "DecodeWorker"]


class PrefillWorker:
    def __init__(self, info: WorkerInfo, model, params, *, num_blocks: int = 256,
                 base_address: int = 0x7F06F40000):
        cfg = model.cfg
        if not cfg.has_attention or cfg.sliding_window:
            raise NotImplementedError(
                "CPU serving path covers paged-KV archs; SSM/SWA archs use "
                "SlotCache transfer (see tests/test_pull_push.py)")
        self.info = info
        self.model = model
        self.params = params
        self.block_size = model.BLOCK_SIZE
        self.cache = PagedKVCache(
            info.worker_id,
            num_layers=cfg.num_layers,
            num_blocks=num_blocks,
            block_size=self.block_size,
            kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim,
            base_address=base_address,
        )
        self.pool = BlockPool(num_blocks, block_size=self.block_size)
        self.registry = DescriptorRegistry(info.worker_id)
        for d in self.cache.descriptors():
            self.registry.register(d)

    def prefill(self, req: Request, tokens: np.ndarray) -> int:
        """Run prefill, park KV blocks in the slab, return the first token."""
        req.to(RequestState.PREFILLING)
        logits, state = self.model.prefill(
            self.params, {"tokens": jnp.asarray(tokens[None], jnp.int32)},
            max_blocks_margin=0, remat=False,
        )
        k_pages = np.asarray(state.k_pages[:, 0])  # [L, spb, bs, g, hd]
        v_pages = np.asarray(state.v_pages[:, 0])
        spb = k_pages.shape[1]
        req.prefill_blocks = self.pool.allocate(spb)
        for layer in range(self.cache.num_layers):
            for j, blk in enumerate(req.prefill_blocks):
                self.cache.write_block(layer, blk, k_pages[layer, j], v_pages[layer, j])
        first = int(jnp.argmax(logits[0, : self.model.cfg.vocab_size]))
        return first

    def release(self, req: Request) -> None:
        """COMPLETE() arrived: free the request's prefill-side blocks."""
        if req.prefill_blocks:
            self.pool.free(req.prefill_blocks)
            req.prefill_blocks = []


@dataclasses.dataclass
class _Resident:
    req: Request
    blocks: list[int]
    context_len: int
    last_token: int
    # float32 page cache built lazily from the slab: [L, n, bs, heads, hd].
    # Rebuilt only when blocks are appended — decode_round no longer
    # re-gathers and re-casts every resident block every round.
    k_cached: np.ndarray | None = None
    v_cached: np.ndarray | None = None


@dataclasses.dataclass
class _InFlight:
    """An admission whose KV pull is still in the air."""

    req: Request
    first_token: int
    future: TransferFuture


class DecodeWorker:
    """Continuous-batching decode over KV pulled through the engine.

    ``consume`` picks the synchronization contract between a request's KV
    pull and its first decode step:

    * ``"full"`` (default) — a request joins decode only after its whole
      pull resolved (COMPLETE executed).  Transfer still overlaps OTHER
      requests' decode compute via ``pump``.
    * ``"layerwise"`` — the pipelined consumer: an in-flight admission
      joins the next ``decode_round`` as soon as its KV starts landing;
      the round's FIRST step fetches layer *l*'s pages via
      ``TransferFuture.wait_layer(l)`` right before layer *l*'s attention
      runs, so early layers compute while late layers are still on the
      wire.  A teardown BETWEEN layers fails the torn request's future
      (``ConnectionTornError``); the step is re-run without it, so
      survivors' tokens are unchanged (see docs/transfer.md).
    """

    def __init__(self, info: WorkerInfo, model, params, *, num_blocks: int = 256,
                 engine: TransferEngine | None = None,
                 base_address: int = 0x7F80000000,
                 consume: str = "full"):
        if consume not in ("full", "layerwise"):
            raise ValueError(f"consume must be 'full' or 'layerwise', got {consume!r}")
        self.consume = consume
        cfg = model.cfg
        self.info = info
        self.model = model
        self.params = params
        self.block_size = model.BLOCK_SIZE
        self.cache = PagedKVCache(
            info.worker_id,
            num_layers=cfg.num_layers,
            num_blocks=num_blocks,
            block_size=self.block_size,
            kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim,
            base_address=base_address,
        )
        self.pool = BlockPool(num_blocks, block_size=self.block_size)
        self.engine = engine or TransferEngine()
        self.engine.register_memory(self.cache.memory_region())
        self.resident: dict[str, _Resident] = {}
        self.inflight: dict[str, _InFlight] = {}

    # ------------------------------------------------------------ admit
    def admit_async(self, req: Request, conn: Connection, first_token: int) -> TransferFuture:
        """Event-driven pull-mode admission: allocate, submit the layer-
        streamed pull, return immediately.  The transfer advances when the
        worker calls ``pump()`` (typically interleaved with decode steps),
        and the request is promoted to DECODING the moment its future
        resolves.

        Allocation happens BEFORE any state transition so an OutOfBlocks
        failure leaves the request exactly as it was (KV_QUEUED, prefill
        KV alive) — the caller's retry contract depends on it."""
        blocks = self.pool.allocate(len(req.prefill_blocks))  # may raise
        req.to(RequestState.KV_TRANSFER)
        fut = pull_kv_async(req, conn=conn, engine=self.engine,
                            decode_pool=self.pool, decode_cache=self.cache,
                            preallocated=blocks)
        self.inflight[req.request_id] = _InFlight(req, first_token, fut)
        return fut

    def admit_batch(
        self, admissions: Sequence[tuple[Request, Connection, int]]
    ) -> list[TransferFuture]:
        """Admit a batch of KV_QUEUED requests in one go: every pull is
        submitted before any byte moves, so the whole batch pipelines
        behind decode compute instead of serializing admission-by-
        admission (coalescing itself stays per-request — each COMPLETE
        ends a window).  Admits in order, stopping at the first request
        that doesn't fit (FIFO fairness — later arrivals must not starve
        it); returns the futures of the admitted prefix."""
        futures: list[TransferFuture] = []
        for req, conn, first_token in admissions:
            try:
                futures.append(self.admit_async(req, conn, first_token))
            except OutOfBlocks:
                break
        return futures

    def admit(self, req: Request, conn: Connection, first_token: int) -> None:
        """Blocking admission (legacy): submit the pull and drain it to
        completion before returning.  Byte-identical to the async path —
        it IS the async path, progressed until resolved."""
        fut = self.admit_async(req, conn, first_token)
        try:
            self.engine.drain()
        except Exception:
            # drain may raise ANOTHER request's torn error; only clean up
            # our admission if OUR pull actually died (abort requires a
            # resolved future — queued reads must not write freed blocks)
            if fut.failed:
                self.abort(req.request_id)
            raise
        if fut.failed:
            self.abort(req.request_id)
            raise fut.exception()
        self.pump(0)  # promote (no more transfer work to do)
        assert req.request_id in self.resident

    def abort(self, request_id: str) -> bool:
        """Drop an in-flight admission whose pull died (connection torn /
        failover): free the decode-side blocks and forget the entry.  The
        caller must only abort once the future is resolved — queued reads
        into the freed blocks would otherwise still execute."""
        fl = self.inflight.pop(request_id, None)
        if fl is None:
            return False
        if fl.req.decode_blocks:
            self.pool.free(fl.req.decode_blocks)
            fl.req.decode_blocks = []
        return True

    # -------------------------------------------------------------- pump
    def pump(self, budget: int | None = None) -> list[str]:
        """Advance in-flight pulls by up to ``budget`` transactions and
        promote every request whose future resolved to DECODING.  Returns
        the promoted request ids.  Failed futures (torn connections) are
        aborted here — their requests stay in KV_TRANSFER for the serving
        layer's failover to re-route."""
        if self.inflight and self.engine.pending:
            self.engine.progress(budget)
        self.engine.poll()  # keep the shared completion queue drained
        promoted: list[str] = []
        for rid, fl in list(self.inflight.items()):
            if not fl.future.done():
                continue
            if fl.future.failed:
                self.abort(rid)  # one owner for the torn-pull cleanup
                continue
            del self.inflight[rid]
            req = fl.req
            req.to(RequestState.QUEUED_DECODE)
            self.resident[rid] = _Resident(
                req, req.decode_blocks, req.prompt_len, fl.first_token)
            req.to(RequestState.DECODING)
            promoted.append(rid)
        return promoted

    # ------------------------------------------------------------ decode
    def _gather_pages(self, blocks: list[int]) -> tuple[np.ndarray, np.ndarray]:
        """Slab → float32 pages for ``blocks``: [L, n, bs, heads, hd]."""
        cfg = self.model.cfg
        k = np.empty((cfg.num_layers, len(blocks), self.block_size,
                      cfg.num_kv_heads, cfg.head_dim), np.float32)
        v = np.empty_like(k)
        for layer in range(cfg.num_layers):
            kplane, vplane = self.cache.kv_planes(layer)  # [blocks, bs, g, hd]
            k[layer] = kplane[blocks].astype(np.float32)
            v[layer] = vplane[blocks].astype(np.float32)
        return k, v

    def _resident_pages(self, r: _Resident) -> tuple[np.ndarray, np.ndarray]:
        """Per-request page cache: gather/cast from the slab only for
        blocks not seen before, reuse the rest.  Today a resident's block
        list is fixed at promotion, so the append branch runs once; it
        future-proofs decode-time block growth / layer-streamed
        consumption without a rewrite."""
        cached = 0 if r.k_cached is None else r.k_cached.shape[1]
        if cached < len(r.blocks):
            k_new, v_new = self._gather_pages(r.blocks[cached:])
            r.k_cached = k_new if r.k_cached is None else np.concatenate(
                [r.k_cached, k_new], axis=1)
            r.v_cached = v_new if r.v_cached is None else np.concatenate(
                [r.v_cached, v_new], axis=1)
        return r.k_cached, r.v_cached

    def _round_margin(self, max_new: int) -> int:
        """Page-margin for one decode round: room for max_new appends."""
        return -(-max_new // self.block_size)

    @staticmethod
    def _batch_tables(batch: list[_Resident], margin_blocks: int):
        """Shared batch layout (per_seq width + identity block tables) —
        ONE definition so the full and layerwise paths cannot diverge."""
        per_seq = max(len(r.blocks) for r in batch) + margin_blocks
        tables = np.broadcast_to(
            np.arange(per_seq, dtype=np.int32)[None], (len(batch), per_seq))
        return per_seq, jnp.asarray(tables)

    def _build_state(self, batch: list[_Resident], margin_blocks: int) -> DecodeState:
        """Assemble a per-seq paged DecodeState from the residents' page
        caches (slab reads only for newly pulled blocks)."""
        cfg = self.model.cfg
        bs = self.block_size
        L = cfg.num_layers
        per_seq, tables = self._batch_tables(batch, margin_blocks)
        b = len(batch)
        k_pages = np.zeros((L, b, per_seq, bs, cfg.num_kv_heads, cfg.head_dim), np.float32)
        v_pages = np.zeros_like(k_pages)
        for i, r in enumerate(batch):
            k, v = self._resident_pages(r)
            n = len(r.blocks)
            k_pages[:, i, :n] = k[:, :n]
            v_pages[:, i, :n] = v[:, :n]
        return DecodeState(
            context_lens=jnp.asarray([r.context_len for r in batch], jnp.int32),
            k_pages=jnp.asarray(k_pages, jnp.bfloat16),
            v_pages=jnp.asarray(v_pages, jnp.bfloat16),
            block_tables=tables,
        )

    def _argmax_tokens(self, logits) -> jnp.ndarray:
        return jnp.argmax(
            logits[:, : self.model.cfg.vocab_size].astype(jnp.float32), axis=-1
        ).astype(jnp.int32)

    # ----------------------------------------- layerwise first step
    def _layerwise_first_step(self, streaming: list[_InFlight], max_new: int,
                              pump_budget: int | None):
        """One decode step where ``streaming`` (in-flight) admissions join
        the resident batch, consuming each layer's KV as its reads land
        (``wait_layer`` pumps the engine between layers).  Returns
        ``(batch, state, tokens, out)`` with the first round token already
        recorded; raises ``ConnectionTornError`` if any streaming pull
        dies mid-step (the caller retries without it)."""
        cfg = self.model.cfg
        bs = self.block_size
        residents = list(self.resident.values())
        batch = residents + [
            _Resident(fl.req, fl.req.decode_blocks, fl.req.prompt_len,
                      fl.first_token)
            for fl in streaming
        ]
        b = len(batch)
        per_seq, tables = self._batch_tables(batch, self._round_margin(max_new))

        def fetch(layer: int):
            # the synchronization point of the whole design: block until
            # THIS layer's reads executed, not until the pull resolves
            for fl in streaming:
                fl.future.wait_layer(layer, budget=pump_budget)
            k = np.zeros((b, per_seq, bs, cfg.num_kv_heads, cfg.head_dim),
                         np.float32)
            v = np.zeros_like(k)
            kplane, vplane = self.cache.kv_planes(layer)
            for i, r in enumerate(batch):
                n = len(r.blocks)
                if i < len(residents):
                    # resident: reuse the float32 page cache instead of
                    # re-gathering/re-casting from the slab every round
                    rk, rv = self._resident_pages(r)
                    k[i, :n], v[i, :n] = rk[layer, :n], rv[layer, :n]
                else:  # streaming: this layer's bytes just landed
                    k[i, :n] = kplane[r.blocks].astype(np.float32)
                    v[i, :n] = vplane[r.blocks].astype(np.float32)
            return jnp.asarray(k, jnp.bfloat16), jnp.asarray(v, jnp.bfloat16)

        state = DecodeState(
            context_lens=jnp.asarray([r.context_len for r in batch], jnp.int32),
            block_tables=tables,
        )
        tokens = jnp.asarray([r.last_token for r in batch], jnp.int32)
        logits, state = self.model.decode_step_layerwise(
            self.params, state, tokens, fetch)
        # All layers landed; the pulls' COMPLETE tails resolve now.  A
        # failure here (torn after the last layer, COMPLETE swallowed)
        # invalidates the admission exactly like a mid-layer tear.
        for fl in streaming:
            while not fl.future.done():
                if not self.engine.pending:
                    raise RuntimeError(
                        f"transfer of {fl.req.request_id!r} has no COMPLETE queued")
                self.engine.progress(pump_budget)
        for fl in streaming:
            if fl.future.failed:
                raise fl.future.exception()
        self.pump(0)  # promote the resolved admissions (no transfer work)
        for r in batch[len(residents):]:
            # keep OUR entry: it reflects the step this round already ran
            self.resident[r.req.request_id] = r
        tokens = self._argmax_tokens(logits)
        out: dict[str, list[int]] = {r.req.request_id: [] for r in batch}
        for i, r in enumerate(batch):
            out[r.req.request_id].append(int(tokens[i]))
            r.req.tokens_generated += 1
        return batch, state, tokens, out

    def _streaming_step(self, max_new: int, pump_budget: int | None):
        """Run the layerwise first step over every in-flight admission,
        dropping (and aborting) admissions whose pull is torn mid-step and
        retrying with the survivors — a teardown BETWEEN layers must not
        change the survivors' tokens, so the step restarts cleanly (no
        tokens or state were committed yet)."""
        while self.inflight and max_new > 0:
            streaming = list(self.inflight.values())
            try:
                return self._layerwise_first_step(streaming, max_new, pump_budget)
            except ConnectionTornError:
                # torn futures are resolved; pump aborts their admissions
                # (frees decode blocks) and keeps the healthy ones in
                # flight for the retry
                self.pump(0)
        return None

    def decode_round(self, max_new: int = 8, *,
                     pump_budget: int | None = 32) -> dict[str, list[int]]:
        """Continuous-batching decode until every resident request has
        produced ``max_new`` tokens or finished.  Returns generated ids.

        Between decode steps the worker pumps the transfer engine by
        ``pump_budget`` transactions, so in-flight pulls make progress
        behind decode compute.  With ``consume="full"`` requests whose
        pull resolves mid-round are promoted immediately and join the
        batch at the NEXT round; with ``consume="layerwise"`` in-flight
        admissions join THIS round — the first step consumes their KV
        layer by layer while the tail of the pull is still in flight."""
        stream = None
        if self.consume == "layerwise" and self.inflight:
            stream = self._streaming_step(max_new, pump_budget)
        if stream is not None:
            batch, state, tokens, out = stream
            steps_left = max_new - 1
        else:
            if not self.resident:
                self.pump(pump_budget)
                if not self.resident:
                    return {}
            batch = list(self.resident.values())
            state = self._build_state(batch, margin_blocks=self._round_margin(max_new))
            tokens = jnp.asarray([r.last_token for r in batch], jnp.int32)
            out = {r.req.request_id: [] for r in batch}
            steps_left = max_new
        for _ in range(steps_left):
            logits, state = self.model.decode_step(self.params, state, tokens)
            if self.inflight:
                self.pump(pump_budget)  # transfer hides behind the step
            tokens = self._argmax_tokens(logits)
            for i, r in enumerate(batch):
                out[r.req.request_id].append(int(tokens[i]))
                r.req.tokens_generated += 1
        for i, r in enumerate(batch):
            r.context_len = int(state.context_lens[i])
            r.last_token = int(tokens[i])
        return out

    def finish(self, req_id: str) -> None:
        r = self.resident.pop(req_id, None)
        if r is not None:
            self.pool.free(r.blocks)
            r.req.to(RequestState.DONE)
