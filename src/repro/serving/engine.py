"""Serving workers: real JAX compute + real KV bytes through KVDirect.

``PrefillWorker`` runs the model's prefill, lands the produced KV pages
in its numpy-backed PagedKVCache slab (the registered MR the transfer
engine reads from), and registers descriptors.  ``DecodeWorker`` pulls
KV through the transfer engine (pull_kv → one-sided reads + COMPLETE),
reconstructs a device DecodeState from its own slab, and decodes with
continuous batching.

This is the CPU-scale end-to-end path (examples/serve_disaggregated.py);
the pod-scale path is launch/serve.py + the sharded serve_step.  Both
consume the same caches, descriptors, and engine.
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.connection import Connection, DescriptorRegistry, WorkerInfo
from repro.core.pull_push import pull_kv_async
from repro.core.transfer_engine import ConnectionTornError, TransferEngine, TransferFuture
from repro.models.transformer import DecodeState
from repro.obs.trace import NULL_TRACER
from repro.serving.blocks import BlockPool, OutOfBlocks
from repro.serving.kv_cache import PagedKVCache
from repro.serving.request import Request, RequestState

__all__ = ["PrefillWorker", "DecodeWorker", "SwappedKV"]


class PrefillWorker:
    def __init__(self, info: WorkerInfo, model, params, *, num_blocks: int = 256,
                 base_address: int = 0x7F06F40000,
                 quantize_transfer: bool = False):
        """``quantize_transfer``: compute per-(layer, block, plane) int8
        scales at park time so decode-side pulls move quantized wire
        bytes with the scale carried in each ``ReadTxn`` descriptor
        (docs/transfer.md § quantized transfer)."""
        cfg = model.cfg
        if not cfg.has_attention or cfg.sliding_window:
            raise NotImplementedError(
                "CPU serving path covers paged-KV archs; SSM/SWA archs use "
                "SlotCache transfer (see tests/test_pull_push.py)")
        self.info = info
        self.model = model
        self.params = params
        self.block_size = model.BLOCK_SIZE
        self.cache = PagedKVCache(
            info.worker_id,
            num_layers=cfg.num_layers,
            num_blocks=num_blocks,
            block_size=self.block_size,
            kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim,
            base_address=base_address,
        )
        self.pool = BlockPool(num_blocks, block_size=self.block_size)
        self.quantize_transfer = quantize_transfer
        self.registry = DescriptorRegistry(info.worker_id)
        for d in self.cache.descriptors():
            self.registry.register(d)

    def _digest_blocks(self, blocks: list[int]) -> list[str]:
        """Content hash per parked block: blake2b over the block's K and V
        slab bytes across ALL layers.  A block's KV encodes its full
        prefix context (causal attention), so byte equality between two
        parked blocks at the same position means the prompts agree up
        through that block — a hash hit is safe to dedup on the wire."""
        hashers = [hashlib.blake2b(digest_size=16) for _ in blocks]
        for layer in range(self.cache.num_layers):
            kplane, vplane = self.cache.kv_planes(layer)
            for h, blk in zip(hashers, blocks):
                h.update(kplane[blk].tobytes())
                h.update(vplane[blk].tobytes())
        return [h.hexdigest() for h in hashers]

    def _quant_scales(self, blocks: list[int]) -> list[list[tuple[float, float]]]:
        """Per-(layer, block position, plane) symmetric-int8 scales:
        ``scales[layer][pos] = (k_scale, v_scale)``, plane order matching
        ``TensorDesc.block_ranges`` (ascending offset = K then V)."""
        scales: list[list[tuple[float, float]]] = []
        for layer in range(self.cache.num_layers):
            kplane, vplane = self.cache.kv_planes(layer)
            per_block = []
            for blk in blocks:
                per_block.append(tuple(
                    float(np.max(np.abs(plane[blk].astype(np.float32)))) / 127.0
                    or 1.0
                    for plane in (kplane, vplane)))
            scales.append(per_block)
        return scales

    def _compute_and_park(
        self, tokens: np.ndarray
    ) -> tuple[int, list[int], list[str], list | None]:
        """Run the model prefill and land the KV pages in the slab.
        Returns (first token, allocated blocks, per-block content hashes,
        quant scales or None).  Capacity is checked UP FRONT: a full pool
        must raise before any state transition or model compute — a
        queued dispatch retries from QUEUED_PREFILL, which an
        after-the-fact OutOfBlocks would strand in PREFILLING."""
        need = BlockPool.blocks_for_tokens(len(tokens), self.block_size)
        if not self.pool.can_allocate(need):
            raise OutOfBlocks(f"need {need} blocks: pool {self.pool.describe()}")
        logits, state = self.model.prefill(
            self.params, {"tokens": jnp.asarray(tokens[None], jnp.int32)},
            max_blocks_margin=0, remat=False,
        )
        k_pages = np.asarray(state.k_pages[:, 0])  # [L, spb, bs, g, hd]
        v_pages = np.asarray(state.v_pages[:, 0])
        spb = k_pages.shape[1]
        blocks = self.pool.allocate(spb)
        for layer in range(self.cache.num_layers):
            for j, blk in enumerate(blocks):
                self.cache.write_block(layer, blk, k_pages[layer, j], v_pages[layer, j])
        first = int(jnp.argmax(logits[0, : self.model.cfg.vocab_size]))
        hashes = self._digest_blocks(blocks)
        scales = self._quant_scales(blocks) if self.quantize_transfer else None
        return first, blocks, hashes, scales

    def prefill(self, req: Request, tokens: np.ndarray) -> int:
        """Run prefill, park KV blocks in the slab, return the first
        token.  Raises OutOfBlocks BEFORE the PREFILLING transition when
        the pool cannot hold the prompt, so the request stays re-
        dispatchable (QUEUED_PREFILL) for the serving loop's next tick."""
        need = BlockPool.blocks_for_tokens(len(tokens), self.block_size)
        if not self.pool.can_allocate(need):
            raise OutOfBlocks(f"need {need} blocks: pool {self.pool.describe()}")
        req.to(RequestState.PREFILLING)
        first, req.prefill_blocks, req.block_hashes, req.kv_scales = \
            self._compute_and_park(tokens)
        return first

    def prefill_shadow(
        self, tokens: np.ndarray
    ) -> tuple[int, list[int], list[str], list | None]:
        """Hedge-twin prefill: same compute and slab landing as
        ``prefill`` but WITHOUT touching any request state — the serving
        layer tracks the twin copy and frees it when the primary's
        transfer COMPLETEs (loser aborted) or adopts it on failover."""
        return self._compute_and_park(tokens)

    def release(self, req: Request) -> None:
        """COMPLETE() arrived: free the request's prefill-side blocks."""
        if req.prefill_blocks:
            self.pool.free(req.prefill_blocks)
            req.prefill_blocks = []


@dataclasses.dataclass
class _Resident:
    req: Request
    blocks: list[int]
    context_len: int
    last_token: int
    # float32 page cache built lazily from the slab: [L, n, bs, heads, hd].
    # Rebuilt only when blocks are appended — decode_round no longer
    # re-gathers and re-casts every resident block every round.
    k_cached: np.ndarray | None = None
    v_cached: np.ndarray | None = None
    # The block ids the cache columns were gathered from.  The cache is
    # valid only while ``blocks`` still starts with exactly these ids —
    # a mutated block list (delta-grafted prefix swapped, failover
    # reassignment) must invalidate, not serve stale pages.
    cached_from: tuple[int, ...] = ()


@dataclasses.dataclass
class _InFlight:
    """An admission whose KV pull is still in the air."""

    req: Request
    first_token: int
    future: TransferFuture


@dataclasses.dataclass
class SwappedKV:
    """A preempted resident's full KV, parked in host memory.

    ``k_pages``/``v_pages`` are the float32 page arrays the resident's
    compute path was using ([L, pages, bs, heads, hd]) — pulled AND
    decode-appended pages, flushed through ``_invalidate_step`` first, so
    a resume continues from byte-identical state.  The entry is worker-
    agnostic: any decode worker can ``swap_in`` it (the pages carry no
    worker-local identity), which is what lets a drain migrate swapped
    victims off a retiring worker."""

    req: Request
    k_pages: np.ndarray
    v_pages: np.ndarray
    context_len: int
    last_token: int

    @property
    def nbytes(self) -> int:
        return int(self.k_pages.nbytes + self.v_pages.nbytes)


class DecodeWorker:
    """Continuous-batching decode over KV pulled through the engine.

    ``consume`` picks the synchronization contract between a request's KV
    pull and its first decode step:

    * ``"full"`` (default) — a request joins decode only after its whole
      pull resolved (COMPLETE executed).  Transfer still overlaps OTHER
      requests' decode compute via ``pump``.
    * ``"layerwise"`` — the pipelined consumer: an in-flight admission
      joins the next ``decode_round`` as soon as its KV starts landing;
      the round's FIRST step fetches layer *l*'s pages via
      ``TransferFuture.wait_layer(l)`` right before layer *l*'s attention
      runs, so early layers compute while late layers are still on the
      wire.  A teardown BETWEEN layers fails the torn request's future
      (``ConnectionTornError``); the step is re-run without it, so
      survivors' tokens are unchanged (see docs/transfer.md).
    """

    def __init__(self, info: WorkerInfo, model, params, *, num_blocks: int = 256,
                 engine: TransferEngine | None = None,
                 base_address: int = 0x7F80000000,
                 consume: str = "full",
                 step_margin_blocks: int = 2,
                 prefix_cache_cap: int = 4,
                 delta_transfer: bool = True,
                 tracer=None,
                 metrics=None):
        if consume not in ("full", "layerwise"):
            raise ValueError(f"consume must be 'full' or 'layerwise', got {consume!r}")
        self.consume = consume
        self.delta_transfer = delta_transfer
        self.tracer = tracer if tracer is not None else NULL_TRACER
        self.metrics = metrics
        cfg = model.cfg
        self.info = info
        self.model = model
        self.params = params
        self.block_size = model.BLOCK_SIZE
        self.cache = PagedKVCache(
            info.worker_id,
            num_layers=cfg.num_layers,
            num_blocks=num_blocks,
            block_size=self.block_size,
            kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim,
            base_address=base_address,
        )
        self.pool = BlockPool(num_blocks, block_size=self.block_size)
        self.engine = engine or TransferEngine()
        self.engine.register_memory(self.cache.memory_region())
        self.resident: dict[str, _Resident] = {}
        self.inflight: dict[str, _InFlight] = {}
        # Continuous-batching step state (see step()): the device
        # DecodeState persists ACROSS steps and is rebuilt — losslessly —
        # only when batch membership changes or the page margin runs out.
        self.step_margin_blocks = max(1, step_margin_blocks)
        self._step_ids: list[str] = []
        self._step_state: DecodeState | None = None
        self._step_tokens: jnp.ndarray | None = None
        self._step_per_seq = 0
        # Prefix retention: finished requests' shared-prefix blocks stay
        # refcounted in the pool (LRU, bounded) so prefix-affinity
        # routing has something real to aim at; evicted under pressure.
        self.prefix_cache: collections.OrderedDict[str, list[int]] = \
            collections.OrderedDict()
        self.prefix_cache_cap = prefix_cache_cap
        # Content-hash dedup index: prefill-computed block hash -> a slab
        # block currently holding that content.  Exact inverses — only the
        # indexed block is recorded in _block_hash.  Entries register at
        # promotion (never for in-flight pulls: their bytes haven't
        # landed) and purge when the pool actually releases the block.
        self._hash_index: dict[str, int] = {}
        self._block_hash: dict[int, str] = {}

    # ------------------------------------------------------------ admit
    @property
    def _block_nbytes(self) -> int:
        """Slab bytes one block occupies across all layers and both
        planes — the logical bytes a full pull would move for it (the
        same basis ``TransferEngine._pulled_bytes`` counts, so pulled +
        reused always sums to the request's total KV footprint)."""
        cfg = self.model.cfg
        kplane, _ = self.cache.kv_planes(0)
        return int(kplane[0].nbytes) * 2 * cfg.num_layers

    def _plan_reuse(self, req: Request) -> dict[int, int]:
        """Delta transfer plan: block POSITION -> resident slab block
        already holding that position's KV bytes.  Two sources, prefix
        graft first (it needs no hashes and so covers pre-hash senders):

        * prefix graft — the request's ``prefix_id`` is retained here;
          its whole-block prefix run maps positionally onto the cached
          blocks (PR 5's retention contract: same prefix_id ⇒ identical
          first prefix_len tokens);
        * content-hash dedup — any remaining position whose prefill
          block hash matches a landed resident block, across requests
          with no shared prefix_id at all.
        """
        n = len(req.prefill_blocks)
        reuse: dict[int, int] = {}
        pid = req.prefix_id
        if pid and pid in self.prefix_cache:
            pblocks = self.prefix_cache[pid]
            limit = min(len(pblocks), n,
                        (req.prefix_len or req.prompt_len) // self.block_size)
            for pos in range(limit):
                reuse[pos] = pblocks[pos]
            self.prefix_cache.move_to_end(pid)
        for pos in range(min(n, len(req.block_hashes))):
            if pos in reuse:
                continue
            blk = self._hash_index.get(req.block_hashes[pos])
            if blk is not None:
                reuse[pos] = blk
        return reuse

    def admit_async(self, req: Request, conn: Connection, first_token: int) -> TransferFuture:
        """Event-driven pull-mode admission: allocate, submit the layer-
        streamed pull, return immediately.  The transfer advances when the
        worker calls ``pump()`` (typically interleaved with decode steps),
        and the request is promoted to DECODING the moment its future
        resolves.

        Delta transfer: positions already resident (retained prefix /
        hash dedup) are GRAFTED — ``pool.share``d into the request's
        block list — and skipped on the wire; only the suffix is pulled.
        The share happens BEFORE the suffix allocation so the eviction
        fallback below can only decrement the grafted blocks' refcounts,
        never corrupt them; a torn suffix therefore aborts cleanly (the
        grafted prefix just un-shares) and a re-admission re-grafts and
        re-notes reused bytes, mirroring pulled-bytes retry accounting.

        Allocation happens BEFORE any state transition so an OutOfBlocks
        failure leaves the request exactly as it was (KV_QUEUED, prefill
        KV alive) — the caller's retry contract depends on it.  Retained
        prefix blocks are evicted (LRU) before giving up: the retention
        cache is opportunistic and must never starve live admissions."""
        req = getattr(req, "request", req)  # a RequestHandle delegates
        # reads but not WRITES (pull_kv_async assigns decode_blocks), so
        # admission must operate on the underlying Request
        n = len(req.prefill_blocks)
        reuse = self._plan_reuse(req) if self.delta_transfer else {}
        grafted = [reuse[p] for p in sorted(reuse)]
        if grafted:
            self.pool.share(grafted)
        need = n - len(grafted)
        try:
            try:
                fresh = self.pool.allocate(need) if need else []
            except OutOfBlocks:
                if not self._evict_prefixes(need):
                    raise
                fresh = self.pool.allocate(need)
        except OutOfBlocks:
            if grafted:
                self._free_blocks(grafted)  # un-share; request unchanged
            raise
        it = iter(fresh)
        blocks = [reuse[p] if p in reuse else next(it) for p in range(n)]
        req.to(RequestState.KV_TRANSFER)
        fut = pull_kv_async(req, conn=conn, engine=self.engine,
                            decode_pool=self.pool, decode_cache=self.cache,
                            preallocated=blocks, skip=frozenset(reuse))
        if grafted:
            self.engine.note_reused(req.request_id,
                                    len(grafted) * self._block_nbytes)
        self.inflight[req.request_id] = _InFlight(req, first_token, fut)
        # the lifecycle track's "transfer" phase: queue.kv ends the moment
        # the pull is SUBMITTED (bytes may start moving this tick)
        self.tracer.phase(("request", req.request_id), "transfer",
                          worker=self.info.worker_id, blocks=len(blocks),
                          reused_blocks=len(grafted))
        if self.metrics is not None:
            self.metrics.inc("decode.admitted")
            if grafted:
                self.metrics.inc("decode.blocks_grafted", len(grafted))
        return fut

    def admit_batch(
        self, admissions: Sequence[tuple[Request, Connection, int]]
    ) -> list[TransferFuture]:
        """Admit a batch of KV_QUEUED requests in one go: every pull is
        submitted before any byte moves, so the whole batch pipelines
        behind decode compute instead of serializing admission-by-
        admission (coalescing itself stays per-request — each COMPLETE
        ends a window).  Admits in order, stopping at the first request
        that doesn't fit (FIFO fairness — later arrivals must not starve
        it); returns the futures of the admitted prefix."""
        futures: list[TransferFuture] = []
        for req, conn, first_token in admissions:
            try:
                futures.append(self.admit_async(req, conn, first_token))
            except OutOfBlocks:
                break
        return futures

    def admit(self, req: Request, conn: Connection, first_token: int) -> None:
        """Blocking admission (legacy): submit the pull and drain it to
        completion before returning.  Byte-identical to the async path —
        it IS the async path, progressed until resolved."""
        fut = self.admit_async(req, conn, first_token)
        try:
            self.engine.drain()
        except Exception:
            # drain may raise ANOTHER request's torn error; only clean up
            # our admission if OUR pull actually died (abort requires a
            # resolved future — queued reads must not write freed blocks)
            if fut.failed:
                self.abort(req.request_id)
            raise
        if fut.failed:
            self.abort(req.request_id)
            raise fut.exception()
        self.pump(0)  # promote (no more transfer work to do)
        assert req.request_id in self.resident

    def abort(self, request_id: str) -> bool:
        """Drop an in-flight admission whose pull died (connection torn /
        failover): free the decode-side blocks and forget the entry.  The
        caller must only abort once the future is resolved — queued reads
        into the freed blocks would otherwise still execute."""
        fl = self.inflight.pop(request_id, None)
        if fl is None:
            return False
        if fl.req.decode_blocks:
            # grafted (shared) blocks merely decrement — the retained
            # prefix / dedup source they came from stays intact, so a
            # torn suffix never corrupts resident state
            self._free_blocks(fl.req.decode_blocks)
            fl.req.decode_blocks = []
        return True

    # -------------------------------------------------------------- pump
    def pump(self, budget: int | None = None) -> list[str]:
        """Advance in-flight pulls by up to ``budget`` transactions and
        promote every request whose future resolved to DECODING.  Returns
        the promoted request ids.  Failed futures (torn connections) are
        aborted here — their requests stay in KV_TRANSFER for the serving
        layer's failover to re-route."""
        if self.inflight and self.engine.pending:
            self.engine.progress(budget)
        self.engine.poll()  # keep the shared completion queue drained
        promoted: list[str] = []
        for rid, fl in list(self.inflight.items()):
            if not fl.future.done():
                continue
            if fl.future.failed:
                self.abort(rid)  # one owner for the torn-pull cleanup
                continue
            del self.inflight[rid]
            req = fl.req
            req.to(RequestState.QUEUED_DECODE)
            self.resident[rid] = _Resident(
                req, req.decode_blocks, req.prompt_len, fl.first_token)
            req.to(RequestState.DECODING)
            self._register_hashes(req)  # bytes landed: dedupable now
            # transfer ends when the request JOINS decode (promotion), so
            # resolve→promote latency is charged to transfer, not decode
            self.tracer.phase(("request", rid), "decode",
                              worker=self.info.worker_id)
            if self.metrics is not None:
                self.metrics.inc("decode.promoted")
            promoted.append(rid)
        return promoted

    # ------------------------------------------------------------ decode
    def _gather_pages(self, blocks: list[int]) -> tuple[np.ndarray, np.ndarray]:
        """Slab → float32 pages for ``blocks``: [L, n, bs, heads, hd]."""
        cfg = self.model.cfg
        k = np.empty((cfg.num_layers, len(blocks), self.block_size,
                      cfg.num_kv_heads, cfg.head_dim), np.float32)
        v = np.empty_like(k)
        for layer in range(cfg.num_layers):
            kplane, vplane = self.cache.kv_planes(layer)  # [blocks, bs, g, hd]
            k[layer] = kplane[blocks].astype(np.float32)
            v[layer] = vplane[blocks].astype(np.float32)
        return k, v

    def _resident_pages(self, r: _Resident) -> tuple[np.ndarray, np.ndarray]:
        """Per-request page cache: gather/cast from the slab only for
        blocks not seen before, reuse the rest.  The cache is keyed on
        WHICH blocks its columns came from (``cached_from``), not just
        how many: if the resident's block list no longer starts with the
        blocks the cache was gathered from (delta graft swapped the
        prefix, failover reassigned blocks), the whole cache is rebuilt —
        a count-only check would silently serve the old blocks' pages."""
        if r.k_cached is not None and \
                list(r.cached_from) != r.blocks[: len(r.cached_from)]:
            r.k_cached = r.v_cached = None
            r.cached_from = ()
        cached = 0 if r.k_cached is None else r.k_cached.shape[1]
        if cached < len(r.blocks):
            k_new, v_new = self._gather_pages(r.blocks[cached:])
            r.k_cached = k_new if r.k_cached is None else np.concatenate(
                [r.k_cached, k_new], axis=1)
            r.v_cached = v_new if r.v_cached is None else np.concatenate(
                [r.v_cached, v_new], axis=1)
            r.cached_from = tuple(r.blocks)
        return r.k_cached, r.v_cached

    def _round_margin(self, max_new: int) -> int:
        """Page-margin for one decode round: room for max_new appends."""
        return -(-max_new // self.block_size)

    def _pages_of(self, r: _Resident) -> int:
        """Valid KV pages of a resident: its pulled slab blocks, plus any
        pages grown past them by decode-appended tokens (those live only
        in the float32 page cache after a state writeback)."""
        return max(len(r.blocks), -(-r.context_len // self.block_size))

    def _batch_tables(self, batch: list[_Resident], margin_blocks: int):
        """Shared batch layout (per_seq width + identity block tables) —
        ONE definition so the full and layerwise paths cannot diverge."""
        per_seq = max(self._pages_of(r) for r in batch) + margin_blocks
        tables = np.broadcast_to(
            np.arange(per_seq, dtype=np.int32)[None], (len(batch), per_seq))
        return per_seq, jnp.asarray(tables)

    def _build_state(self, batch: list[_Resident], margin_blocks: int) -> DecodeState:
        """Assemble a per-seq paged DecodeState from the residents' page
        caches (slab reads only for newly pulled blocks)."""
        cfg = self.model.cfg
        bs = self.block_size
        L = cfg.num_layers
        per_seq, tables = self._batch_tables(batch, margin_blocks)
        b = len(batch)
        k_pages = np.zeros((L, b, per_seq, bs, cfg.num_kv_heads, cfg.head_dim), np.float32)
        v_pages = np.zeros_like(k_pages)
        for i, r in enumerate(batch):
            k, v = self._resident_pages(r)
            n = k.shape[1]
            k_pages[:, i, :n] = k
            v_pages[:, i, :n] = v
        return DecodeState(
            context_lens=jnp.asarray([r.context_len for r in batch], jnp.int32),
            k_pages=jnp.asarray(k_pages, jnp.bfloat16),
            v_pages=jnp.asarray(v_pages, jnp.bfloat16),
            block_tables=tables,
        )

    def _argmax_tokens(self, logits) -> jnp.ndarray:
        return jnp.argmax(
            logits[:, : self.model.cfg.vocab_size].astype(jnp.float32), axis=-1
        ).astype(jnp.int32)

    # ----------------------------------------- layerwise first step
    def _layerwise_first_step(self, streaming: list[_InFlight],
                              margin_blocks: int, pump_budget: int | None):
        """One decode step where ``streaming`` (in-flight) admissions join
        the resident batch, consuming each layer's KV as its reads land
        (``wait_layer`` pumps the engine between layers).  Returns
        ``(batch, state, tokens, out)`` with the first round token already
        recorded; raises ``ConnectionTornError`` if any streaming pull
        dies mid-step (the caller retries without it)."""
        cfg = self.model.cfg
        bs = self.block_size
        residents = list(self.resident.values())
        batch = residents + [
            _Resident(fl.req, fl.req.decode_blocks, fl.req.prompt_len,
                      fl.first_token)
            for fl in streaming
        ]
        b = len(batch)
        per_seq, tables = self._batch_tables(batch, margin_blocks)

        def fetch(layer: int):
            # the synchronization point of the whole design: block until
            # THIS layer's reads executed, not until the pull resolves
            for fl in streaming:
                fl.future.wait_layer(layer, budget=pump_budget)
            k = np.zeros((b, per_seq, bs, cfg.num_kv_heads, cfg.head_dim),
                         np.float32)
            v = np.zeros_like(k)
            kplane, vplane = self.cache.kv_planes(layer)
            for i, r in enumerate(batch):
                if i < len(residents):
                    # resident: reuse the float32 page cache (pulled AND
                    # decode-appended pages) instead of re-gathering/
                    # re-casting from the slab every round
                    rk, rv = self._resident_pages(r)
                    n = rk.shape[1]
                    k[i, :n], v[i, :n] = rk[layer], rv[layer]
                else:  # streaming: this layer's bytes just landed
                    n = len(r.blocks)
                    k[i, :n] = kplane[r.blocks].astype(np.float32)
                    v[i, :n] = vplane[r.blocks].astype(np.float32)
            return jnp.asarray(k, jnp.bfloat16), jnp.asarray(v, jnp.bfloat16)

        state = DecodeState(
            context_lens=jnp.asarray([r.context_len for r in batch], jnp.int32),
            block_tables=tables,
        )
        tokens = jnp.asarray([r.last_token for r in batch], jnp.int32)
        logits, state = self.model.decode_step_layerwise(
            self.params, state, tokens, fetch)
        # All layers landed; the pulls' COMPLETE tails resolve now.  A
        # failure here (torn after the last layer, COMPLETE swallowed)
        # invalidates the admission exactly like a mid-layer tear.
        for fl in streaming:
            while not fl.future.done():
                if not self.engine.pending:
                    raise RuntimeError(
                        f"transfer of {fl.req.request_id!r} has no COMPLETE queued")
                self.engine.progress(pump_budget)
        for fl in streaming:
            if fl.future.failed:
                raise fl.future.exception()
        self.pump(0)  # promote the resolved admissions (no transfer work)
        for r in batch[len(residents):]:
            # keep OUR entry: it reflects the step this round already ran
            self.resident[r.req.request_id] = r
        tokens = self._argmax_tokens(logits)
        out: dict[str, list[int]] = {r.req.request_id: [] for r in batch}
        for i, r in enumerate(batch):
            out[r.req.request_id].append(int(tokens[i]))
            r.req.tokens_generated += 1
        return batch, state, tokens, out

    def _streaming_step(self, margin_blocks: int, pump_budget: int | None):
        """Run the layerwise first step over every in-flight admission,
        dropping (and aborting) admissions whose pull is torn mid-step and
        retrying with the survivors — a teardown BETWEEN layers must not
        change the survivors' tokens, so the step restarts cleanly (no
        tokens or state were committed yet)."""
        while self.inflight:
            streaming = list(self.inflight.values())
            try:
                return self._layerwise_first_step(
                    streaming, margin_blocks, pump_budget)
            except ConnectionTornError:
                # torn futures are resolved; pump aborts their admissions
                # (frees decode blocks) and keeps the healthy ones in
                # flight for the retry
                self.pump(0)
        return None

    # --------------------------------------------- persistent step state
    def _install_step(self, batch: list[_Resident], state: DecodeState,
                      tokens: jnp.ndarray) -> None:
        self._step_ids = [r.req.request_id for r in batch]
        self._step_state = state
        self._step_tokens = tokens
        self._step_per_seq = int(state.block_tables.shape[1])

    def _invalidate_step(self) -> None:
        """Flush the persistent step state back into the residents' page
        caches and drop it.  The writeback copies the state's KV — pulled
        AND decode-appended pages — so the batch can be rebuilt around a
        membership change (join / leave / finish) without losing appended
        tokens.  bf16 -> f32 -> bf16 round-trips exactly, so a rebuild
        never perturbs the survivors' subsequent tokens."""
        state = self._step_state
        if state is None:
            return
        ids, self._step_ids = self._step_ids, []
        self._step_state = self._step_tokens = None
        self._step_per_seq = 0
        k_all = np.asarray(state.k_pages).astype(np.float32)
        v_all = np.asarray(state.v_pages).astype(np.float32)
        for i, rid in enumerate(ids):
            r = self.resident.get(rid)
            if r is None:
                continue  # finished / aborted while the state was live
            pages = -(-r.context_len // self.block_size)
            r.k_cached = np.ascontiguousarray(k_all[:, i, :pages])
            r.v_cached = np.ascontiguousarray(v_all[:, i, :pages])
            r.cached_from = tuple(r.blocks)  # writeback covers all blocks

    def _commit_step(self, batch: list[_Resident], state: DecodeState,
                     tokens: jnp.ndarray) -> dict[str, int]:
        """Record one step's outputs on the residents; returns
        request_id -> token."""
        ctx = np.asarray(state.context_lens)
        out: dict[str, int] = {}
        for i, r in enumerate(batch):
            tok = int(tokens[i])
            out[r.req.request_id] = tok
            r.req.tokens_generated += 1
            r.context_len = int(ctx[i])
            r.last_token = tok
        return out

    # ------------------------------------------------- continuous stepping
    def step(self, *, pump_budget: int | None = 32) -> dict[str, int]:
        """ONE continuous-batching decode step: every resident advances by
        one token and the mapping ``{request_id: token}`` is returned.

        This is ``decode_round`` split open for the event-driven serving
        loop: requests JOIN the running batch the moment their pull
        resolves (``consume="full"``) or stream their KV in layer-by-layer
        during this very step (``consume="layerwise"``, preserving the
        PR 3 ``ConnectionTornError`` retry semantics), and LEAVE whenever
        the caller stops stepping them (``finish``) — cohabitants never
        stall on either event.  The device DecodeState persists across
        steps; membership changes or an exhausted page margin trigger a
        lossless rebuild (see ``_invalidate_step``), so a join/leave never
        changes the tokens of requests already in the batch."""
        if self.consume == "layerwise" and self.inflight:
            self._invalidate_step()  # caches must be current to co-batch
            stream = self._streaming_step(self.step_margin_blocks, pump_budget)
            if stream is not None:
                batch, state, tokens, out = stream
                # commit the step's context_len/last_token NOW: a rebuild
                # on the very next step (another join, a leave, margin)
                # writes back and restarts from these fields — stale
                # values would replay the token and drop an appended page
                ctx = np.asarray(state.context_lens)
                for i, r in enumerate(batch):
                    r.context_len = int(ctx[i])
                    r.last_token = int(tokens[i])
                self._install_step(batch, state, tokens)
                return {rid: toks[0] for rid, toks in out.items()}
        else:
            # promote pulls that resolved since the last step (and nudge
            # the engine while there is in-flight work to hide)
            self.pump(pump_budget if self.inflight else 0)
        if not self.resident:
            return {}
        ids = list(self.resident)
        exhausted = self._step_state is not None and any(
            r.context_len >= self._step_per_seq * self.block_size
            for r in self.resident.values())
        if ids != self._step_ids or exhausted:
            self._invalidate_step()
            batch = list(self.resident.values())
            state = self._build_state(batch, margin_blocks=self.step_margin_blocks)
            tokens = jnp.asarray([r.last_token for r in batch], jnp.int32)
            self._install_step(batch, state, tokens)
        batch = [self.resident[rid] for rid in self._step_ids]
        logits, state = self.model.decode_step(
            self.params, self._step_state, self._step_tokens)
        if self.inflight:
            self.pump(pump_budget)  # transfer hides behind the step
        tokens = self._argmax_tokens(logits)
        out = self._commit_step(batch, state, tokens)
        self._step_state, self._step_tokens = state, tokens
        return out

    def decode_round(self, max_new: int = 8, *,
                     pump_budget: int | None = 32) -> dict[str, list[int]]:
        """Round-style decode: the CURRENT residents (plus, for
        ``consume="layerwise"``, in-flight admissions streamed into the
        first step) each produce ``max_new`` tokens.  Returns generated
        ids.  The batch is fixed for the round — pulls resolving mid-round
        are promoted but join at the NEXT round; the event-driven path
        (``step``) is what admits them mid-stream.

        Between decode steps the worker pumps the transfer engine by
        ``pump_budget`` transactions, so in-flight pulls make progress
        behind decode compute."""
        self._invalidate_step()  # interop with step(): flush its state
        stream = None
        if self.consume == "layerwise" and self.inflight and max_new > 0:
            stream = self._streaming_step(self._round_margin(max_new), pump_budget)
        if stream is not None:
            batch, state, tokens, out = stream
            steps_left = max_new - 1
        else:
            if not self.resident:
                self.pump(pump_budget)
                if not self.resident:
                    return {}
            batch = list(self.resident.values())
            state = self._build_state(batch, margin_blocks=self._round_margin(max_new))
            tokens = jnp.asarray([r.last_token for r in batch], jnp.int32)
            out = {r.req.request_id: [] for r in batch}
            steps_left = max_new
        for _ in range(steps_left):
            logits, state = self.model.decode_step(self.params, state, tokens)
            if self.inflight:
                self.pump(pump_budget)  # transfer hides behind the step
            tokens = self._argmax_tokens(logits)
            for i, r in enumerate(batch):
                out[r.req.request_id].append(int(tokens[i]))
                r.req.tokens_generated += 1
        for i, r in enumerate(batch):
            r.context_len = int(state.context_lens[i])
            r.last_token = int(tokens[i])
        # park the final state in the step slot and flush it, so page
        # caches include this round's appended KV — a later round (or
        # step) over the same residents rebuilds losslessly
        self._install_step(batch, state, tokens)
        self._invalidate_step()
        return out

    # ------------------------------------------------- memory-pressure
    @property
    def occupancy(self) -> float:
        """KV-pool occupancy fraction (allocated + reserved over
        capacity) — the signal memory-pressure preemption triggers on."""
        s = self.pool.stats
        return s.in_use / max(s.capacity, 1)

    def swap_out(self, request_id: str) -> SwappedKV | None:
        """Preempt a resident: copy its full KV — pulled AND decode-
        appended pages — out of the slab, free its blocks, and remove it
        from the batch.  Returns the host-memory entry (None if the
        request isn't resident).  The request stays DECODING; it is
        simply not stepped until ``swap_in`` restores it, so the token
        stream pauses and resumes byte-identically (the pages round-trip
        through the same float32 cache the compute path reads)."""
        r = self.resident.get(request_id)
        if r is None:
            return None
        self._invalidate_step()  # flush appended KV into the page cache
        k, v = self._resident_pages(r)
        del self.resident[request_id]
        self._free_blocks(r.blocks)
        r.req.decode_blocks = []
        if self.metrics is not None:
            self.metrics.inc("fleet.swapped_out")
        return SwappedKV(r.req, k, v, r.context_len, r.last_token)

    def swap_in(self, entry: SwappedKV) -> bool:
        """Restore a swapped-out request into this worker's batch: land
        its pages back in the slab (so later prefix retention and delta
        grafts read real bytes), allocate fresh blocks, and re-insert the
        resident with its page cache intact.  False when the pool can't
        hold it yet (caller retries when capacity returns).  Restoring on
        a DIFFERENT worker than the one that swapped it out is legal —
        the entry is worker-agnostic (see ``SwappedKV``)."""
        pages = int(entry.k_pages.shape[1])
        if not self.pool.can_allocate(pages) and not self._evict_prefixes(pages):
            return False
        blocks = self.pool.allocate(pages)
        for layer in range(self.cache.num_layers):
            for j, blk in enumerate(blocks):
                self.cache.write_block(layer, blk,
                                       entry.k_pages[layer, j],
                                       entry.v_pages[layer, j])
        req = entry.req
        req.decode_blocks = blocks
        req.decode_worker = self.info.worker_id
        self.resident[req.request_id] = _Resident(
            req, blocks, entry.context_len, entry.last_token,
            k_cached=entry.k_pages, v_cached=entry.v_pages,
            cached_from=tuple(blocks))
        if self.metrics is not None:
            self.metrics.inc("fleet.swapped_in")
        return True

    def evict_resident(self, request_id: str) -> bool:
        """Sacrifice a resident under memory pressure: drop its decode-
        side KV entirely (blocks freed, batch membership removed).  The
        serving layer replays it via truncate-and-replay (PR 5's
        ``_restart``) — decode is deterministic, so the replay regenerates
        the identical stream."""
        r = self.resident.pop(request_id, None)
        if r is None:
            return False
        self._invalidate_step()  # survivors keep their appended pages
        self._free_blocks(r.blocks)
        r.req.decode_blocks = []
        return True

    # ------------------------------------------------------------ finish
    def finish(self, req_id: str) -> None:
        r = self.resident.pop(req_id, None)
        if r is not None:
            self._retain_prefix(r)
            self._free_blocks(r.blocks)
            # retire the engine's per-request byte counters here too, so
            # legacy callers driving finish() directly (no serving-layer
            # completion) don't grow one entry per request served
            self.engine.pulled_bytes(req_id, pop=True)
            self.engine.reused_bytes(req_id, pop=True)
            r.req.to(RequestState.DONE)

    # ------------------------------------------------------ prefix cache
    def _free_blocks(self, blocks: list[int]) -> list[int]:
        """The ONLY free path for decode-side blocks: release through the
        pool and purge the hash-dedup index for every block that actually
        left the pool.  Shared blocks that merely decrement stay indexed
        — their bytes are still resident and still graftable."""
        released = self.pool.free(blocks)
        for blk in released:
            h = self._block_hash.pop(blk, None)
            if h is not None:
                self._hash_index.pop(h, None)
        return released

    def _register_hashes(self, req: Request) -> None:
        """Index a promoted request's landed blocks by prefill content
        hash (first holder wins — re-registering a grafted block under
        the same hash is a no-op).  Never called for in-flight pulls:
        indexing a block whose bytes haven't landed would graft garbage.

        Quantized-transfer note: the slab holds DEQUANTIZED bytes, not
        the prefill bytes the hash was computed over — still sound,
        because equal prefill bytes quantize to equal wire bytes and
        scales, so a hash hit serves exactly what the new request's own
        quantized pull would have landed."""
        for blk, h in zip(req.decode_blocks, req.block_hashes):
            if h not in self._hash_index:
                self._hash_index[h] = blk
                self._block_hash[blk] = h

    def _retain_prefix(self, r: _Resident) -> None:
        """Keep a finishing request's shared-prefix blocks refcounted in
        the pool (bounded LRU) so prefix-affinity routing can steer the
        next request with the same prefix here."""
        req = r.req
        if not req.prefix_id or self.prefix_cache_cap <= 0:
            return
        if req.prefix_id in self.prefix_cache:
            self.prefix_cache.move_to_end(req.prefix_id)
            return
        prefix_len = req.prefix_len or req.prompt_len
        blocks = r.blocks[: prefix_len // self.block_size]  # whole blocks
        if not blocks:
            return
        self.pool.share(blocks)  # cache's refcount survives the free below
        self.prefix_cache[req.prefix_id] = list(blocks)
        while len(self.prefix_cache) > self.prefix_cache_cap:
            _, evicted = self.prefix_cache.popitem(last=False)
            self._free_blocks(evicted)

    def _evict_prefixes(self, need: int) -> bool:
        """Free retained prefixes (LRU-first) until ``need`` blocks fit;
        True if they now do."""
        while self.prefix_cache and not self.pool.can_allocate(need):
            _, blocks = self.prefix_cache.popitem(last=False)
            self._free_blocks(blocks)
        return self.pool.can_allocate(need)

    @property
    def resident_prefix_blocks(self) -> tuple[tuple[str, int], ...]:
        """(prefix_id, whole blocks retained) pairs, sorted — advertised
        through ``LoadReport.prefix_blocks`` so the router can price a
        delta pull (only the suffix moves) instead of a full pull."""
        return tuple(sorted(
            (pid, len(blocks)) for pid, blocks in self.prefix_cache.items()))

    @property
    def evictable_blocks(self) -> int:
        """Blocks reclaimable from the prefix retention cache (upper
        bound: shared blocks only free once every holder releases)."""
        return sum(len(b) for b in self.prefix_cache.values())

    @property
    def known_prefixes(self) -> frozenset[str]:
        """Prefix ids resident on this worker (live requests, in-flight
        pulls, and the retention cache) — reported via LoadReport."""
        ids = {r.req.prefix_id for r in self.resident.values() if r.req.prefix_id}
        ids.update(fl.req.prefix_id for fl in self.inflight.values()
                   if fl.req.prefix_id)
        ids.update(self.prefix_cache)
        return frozenset(ids)
