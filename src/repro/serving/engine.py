"""Serving workers: real JAX compute + real KV bytes through KVDirect.

``PrefillWorker`` runs the model's prefill, lands the produced KV pages
in its numpy-backed PagedKVCache slab (the registered MR the transfer
engine reads from), and registers descriptors.  ``DecodeWorker`` pulls
KV through the transfer engine (pull_kv → one-sided reads + COMPLETE),
reconstructs a device DecodeState from its own slab, and decodes with
continuous batching.

This is the CPU-scale end-to-end path (examples/serve_disaggregated.py);
the pod-scale path is launch/serve.py + the sharded serve_step.  Both
consume the same caches, descriptors, and engine.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.connection import Connection, DescriptorRegistry, WorkerInfo
from repro.core.pull_push import pull_kv
from repro.core.transfer_engine import TransferEngine
from repro.models.transformer import DecodeState
from repro.serving.blocks import BlockPool
from repro.serving.kv_cache import PagedKVCache
from repro.serving.request import Request, RequestState

__all__ = ["PrefillWorker", "DecodeWorker"]


class PrefillWorker:
    def __init__(self, info: WorkerInfo, model, params, *, num_blocks: int = 256,
                 base_address: int = 0x7F06F40000):
        cfg = model.cfg
        if not cfg.has_attention or cfg.sliding_window:
            raise NotImplementedError(
                "CPU serving path covers paged-KV archs; SSM/SWA archs use "
                "SlotCache transfer (see tests/test_pull_push.py)")
        self.info = info
        self.model = model
        self.params = params
        self.block_size = model.BLOCK_SIZE
        self.cache = PagedKVCache(
            info.worker_id,
            num_layers=cfg.num_layers,
            num_blocks=num_blocks,
            block_size=self.block_size,
            kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim,
            base_address=base_address,
        )
        self.pool = BlockPool(num_blocks, block_size=self.block_size)
        self.registry = DescriptorRegistry(info.worker_id)
        for d in self.cache.descriptors():
            self.registry.register(d)

    def prefill(self, req: Request, tokens: np.ndarray) -> int:
        """Run prefill, park KV blocks in the slab, return the first token."""
        req.to(RequestState.PREFILLING)
        logits, state = self.model.prefill(
            self.params, {"tokens": jnp.asarray(tokens[None], jnp.int32)},
            max_blocks_margin=0, remat=False,
        )
        k_pages = np.asarray(state.k_pages[:, 0])  # [L, spb, bs, g, hd]
        v_pages = np.asarray(state.v_pages[:, 0])
        spb = k_pages.shape[1]
        req.prefill_blocks = self.pool.allocate(spb)
        for layer in range(self.cache.num_layers):
            for j, blk in enumerate(req.prefill_blocks):
                self.cache.write_block(layer, blk, k_pages[layer, j], v_pages[layer, j])
        first = int(jnp.argmax(logits[0, : self.model.cfg.vocab_size]))
        return first

    def release(self, req: Request) -> None:
        """COMPLETE() arrived: free the request's prefill-side blocks."""
        if req.prefill_blocks:
            self.pool.free(req.prefill_blocks)
            req.prefill_blocks = []


@dataclasses.dataclass
class _Resident:
    req: Request
    blocks: list[int]
    context_len: int
    last_token: int


class DecodeWorker:
    def __init__(self, info: WorkerInfo, model, params, *, num_blocks: int = 256,
                 engine: TransferEngine | None = None,
                 base_address: int = 0x7F80000000):
        cfg = model.cfg
        self.info = info
        self.model = model
        self.params = params
        self.block_size = model.BLOCK_SIZE
        self.cache = PagedKVCache(
            info.worker_id,
            num_layers=cfg.num_layers,
            num_blocks=num_blocks,
            block_size=self.block_size,
            kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim,
            base_address=base_address,
        )
        self.pool = BlockPool(num_blocks, block_size=self.block_size)
        self.engine = engine or TransferEngine()
        self.engine.register_memory(self.cache.memory_region())
        self.resident: dict[str, _Resident] = {}

    # ------------------------------------------------------------ admit
    def admit(self, req: Request, conn: Connection, first_token: int) -> None:
        """Pull-mode admission: allocate, TRANSFER all layers, COMPLETE.

        Allocation happens BEFORE any state transition so an OutOfBlocks
        failure leaves the request exactly as it was (KV_QUEUED, prefill
        KV alive) — the caller's retry contract depends on it."""
        blocks = self.pool.allocate(len(req.prefill_blocks))  # may raise
        req.to(RequestState.KV_TRANSFER)
        pull_kv(req, conn=conn, engine=self.engine,
                decode_pool=self.pool, decode_cache=self.cache,
                preallocated=blocks)
        req.to(RequestState.QUEUED_DECODE)
        self.resident[req.request_id] = _Resident(
            req, req.decode_blocks, req.prompt_len, first_token)
        req.to(RequestState.DECODING)

    # ------------------------------------------------------------ decode
    def _build_state(self, batch: list[_Resident], margin_blocks: int) -> DecodeState:
        """Assemble a per-seq paged DecodeState from slab views."""
        cfg = self.model.cfg
        bs = self.block_size
        L = cfg.num_layers
        per_seq = max(len(r.blocks) for r in batch) + margin_blocks
        b = len(batch)
        k_pages = np.zeros((L, b, per_seq, bs, cfg.num_kv_heads, cfg.head_dim), np.float32)
        v_pages = np.zeros_like(k_pages)
        for layer in range(L):
            kplane, vplane = self.cache.kv_planes(layer)  # [blocks, bs, g, hd]
            for i, r in enumerate(batch):
                n = len(r.blocks)
                k_pages[layer, i, :n] = kplane[r.blocks].astype(np.float32)
                v_pages[layer, i, :n] = vplane[r.blocks].astype(np.float32)
        tables = np.broadcast_to(np.arange(per_seq, dtype=np.int32)[None], (b, per_seq))
        return DecodeState(
            context_lens=jnp.asarray([r.context_len for r in batch], jnp.int32),
            k_pages=jnp.asarray(k_pages, jnp.bfloat16),
            v_pages=jnp.asarray(v_pages, jnp.bfloat16),
            block_tables=jnp.asarray(tables),
        )

    def decode_round(self, max_new: int = 8) -> dict[str, list[int]]:
        """Continuous-batching decode until every resident request has
        produced ``max_new`` tokens or finished.  Returns generated ids."""
        if not self.resident:
            return {}
        batch = list(self.resident.values())
        state = self._build_state(batch, margin_blocks=-(-max_new // self.block_size))
        tokens = jnp.asarray([r.last_token for r in batch], jnp.int32)
        out: dict[str, list[int]] = {r.req.request_id: [] for r in batch}
        for _ in range(max_new):
            logits, state = self.model.decode_step(self.params, state, tokens)
            tokens = jnp.argmax(
                logits[:, : self.model.cfg.vocab_size].astype(jnp.float32), axis=-1
            ).astype(jnp.int32)
            for i, r in enumerate(batch):
                out[r.req.request_id].append(int(tokens[i]))
                r.req.tokens_generated += 1
        for i, r in enumerate(batch):
            r.context_len = int(state.context_lens[i])
            r.last_token = int(tokens[i])
        return out

    def finish(self, req_id: str) -> None:
        r = self.resident.pop(req_id, None)
        if r is not None:
            self.pool.free(r.blocks)
            r.req.to(RequestState.DONE)
