"""End-to-end disaggregated serving — the paper's full pipeline on real
substrate: cluster scheduler + prefill/decode workers + KVDirect engine.

Flow per request (pull-mode, §4.3):
  submit → least-loaded prefill worker → model prefill (real JAX) → KV
  blocks land in the prefill worker's registered slab → decode worker
  allocates + pulls via one-sided reads → COMPLETE frees the prefill
  copy → continuous-batching decode.

Fault tolerance: a prefill worker failure invalidates its connection
epoch; in-flight requests whose KV lived there are re-queued and
re-prefilled on a surviving worker (tested in tests/test_disagg.py).
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.core.cluster import ClusterScheduler, MembershipEvent
from repro.core.connection import ChipInfo, ConnectionManager, WorkerInfo
from repro.core.transfer_engine import TransferEngine
from repro.serving.blocks import OutOfBlocks
from repro.serving.engine import DecodeWorker, PrefillWorker
from repro.serving.request import Request, RequestState

__all__ = ["DisaggService"]


def _winfo(wid: str, role: str) -> WorkerInfo:
    return WorkerInfo(wid, role, f"host-{wid}", (ChipInfo(0, f"ici://{wid}/0"),))


class DisaggService:
    def __init__(self, model, params, *, n_prefill: int = 1, num_blocks: int = 256):
        self.model = model
        self.params = params
        self.scheduler = ClusterScheduler()
        self.engine = TransferEngine(coalescing="sorted")
        self._ids = itertools.count()

        self.decode = DecodeWorker(_winfo("d0", "decode"), model, params,
                                   num_blocks=num_blocks, engine=self.engine)
        self.conn_mgr = ConnectionManager(self.decode.info)
        self.prefills: dict[str, PrefillWorker] = {}
        self.pending: dict[str, tuple[Request, np.ndarray]] = {}  # awaiting retry
        self.first_tokens: dict[str, int] = {}

        # COMPLETE() → prefill worker frees its blocks
        self.engine.on_complete(self._on_complete)
        # membership → connections
        self.scheduler.subscribe(self._on_membership)
        # failure → re-queue requests whose KV died with the worker
        self.conn_mgr.on_invalidate(self._on_invalidate)

        self.scheduler.add_worker(self.decode.info)
        for i in range(n_prefill):
            self.add_prefill_worker(num_blocks=num_blocks)

    # ------------------------------------------------------- membership
    def add_prefill_worker(self, *, num_blocks: int = 256) -> str:
        wid = f"p{len(self.prefills)}"
        w = PrefillWorker(_winfo(wid, "prefill"), self.model, self.params,
                          num_blocks=num_blocks)
        w.cache.base_address = w.cache.base_address  # registered below
        self.prefills[wid] = w
        self.engine.register_memory(w.cache.memory_region())
        self.scheduler.add_worker(w.info)
        return wid

    def fail_prefill_worker(self, wid: str) -> None:
        """Simulate a crash: scheduler reaps it; engine deregisters its MR;
        epochs invalidate; in-flight requests re-queue."""
        self.engine.deregister_memory(wid)
        self.scheduler.remove_worker(wid, failed=True)
        self.prefills.pop(wid, None)

    def _on_membership(self, ev: MembershipEvent) -> None:
        if ev.worker.role != "prefill":
            return
        if ev.kind == "added":
            self.conn_mgr.connect(ev.worker, self.prefills[ev.worker.worker_id].registry)
        else:
            self.conn_mgr.disconnect(ev.worker.worker_id, failed=ev.kind == "failed")

    def _on_complete(self, txn) -> None:
        w = self.prefills.get(txn.src_worker)
        req = next((r for r, _ in self.pending.values() if r.request_id == txn.request_id), None)
        if w is not None and req is not None:
            w.release(req)

    def _on_invalidate(self, dead_worker: str, epoch: int) -> None:
        for rid, (req, tokens) in list(self.pending.items()):
            if req.prefill_worker == dead_worker and req.state in (
                RequestState.PREFILLING, RequestState.KV_QUEUED, RequestState.KV_TRANSFER,
            ):
                req.retries += 1
                req.prefill_blocks = []
                req.to(RequestState.FAILED)
                req.to(RequestState.QUEUED_PREFILL)
                self._run_prefill(req, tokens)

    # ------------------------------------------------------------ serve
    def _pick_prefill(self) -> PrefillWorker:
        if not self.prefills:
            raise RuntimeError("no prefill workers alive")
        return min(self.prefills.values(), key=lambda w: w.pool.stats.in_use)

    def _run_prefill(self, req: Request, tokens: np.ndarray) -> None:
        w = self._pick_prefill()
        req.prefill_worker = w.info.worker_id
        self.first_tokens[req.request_id] = w.prefill(req, tokens)
        req.to(RequestState.KV_QUEUED)

    def submit(self, tokens: np.ndarray) -> Request:
        """Prefill immediately (pull-mode: no decode-side reservation)."""
        req = Request(f"r{next(self._ids)}", len(tokens), 0)
        self.pending[req.request_id] = (req, tokens)
        self._run_prefill(req, tokens)
        return req

    def admit_to_decode(self, req: Request) -> bool:
        """Pull the KV and make the request resident; False if the decode
        pool is full (request stays KV_QUEUED; prefill KV stays alive)."""
        conn = self.conn_mgr.connection(req.prefill_worker)
        try:
            self.decode.admit(req, conn, self.first_tokens[req.request_id])
        except OutOfBlocks:
            return False
        return True

    def generate(self, req: Request, max_new: int = 8) -> list[int]:
        if req.request_id in self.pending and req.state == RequestState.KV_QUEUED:
            if not self.admit_to_decode(req):
                raise OutOfBlocks("decode pool full")
        out = self.decode.decode_round(max_new)[req.request_id]
        self.decode.finish(req.request_id)
        self.pending.pop(req.request_id, None)
        return [self.first_tokens[req.request_id]] + out
