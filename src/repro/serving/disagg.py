"""End-to-end disaggregated serving — the paper's full pipeline on real
substrate: cluster scheduler + N prefill × M decode workers + KVDirect
engine + the ``repro.sched`` request router.

Flow per request (pull-mode, §4.3):
  submit → router picks a (prefill, decode) pair via the configured
  policy (round-robin / least-loaded / network-aware / prefix-affinity /
  SLO admission) → model prefill (real JAX) → KV blocks land in the
  prefill worker's registered slab → the ASSIGNED decode worker
  allocates + pulls via one-sided reads over its own connection table →
  COMPLETE frees the prefill copy → continuous-batching decode.

The front door is the STREAMING API (docs/serving.md): ``submit()``
returns a ``RequestHandle`` and the event-driven ``ServeLoop``
(``self.loop``) interleaves prefill dispatch, router-planned admission,
transfer progress, and per-step decode — requests join the running
batch as their KV lands and leave at EOS/max_new.  ``generate`` /
``generate_many`` survive as token-identical shims over the loop.
``submit(hedge=2)`` races twin prefills (first COMPLETE wins, the
loser's slab is freed, a dead primary's copy is adopted from the twin).

Topology: every decode worker owns a ``ConnectionManager`` with a live
connection to every prefill worker (§4.2's decode-side connection table),
so the router is free to pair any prefill with any decode.  Each worker's
KV slab gets a distinct, non-overlapping base address from a simple
bump allocator; the transfer engine rejects overlapping MRs.

Fault tolerance (both roles):
  * prefill crash → its connection epoch invalidates on every decode
    worker; in-flight requests whose KV lived there are re-routed and
    re-prefilled on a survivor;
  * decode crash → requests assigned there are re-routed: KV_QUEUED
    requests keep their prefill KV and just get a new decode worker;
    requests already pulled (prefill copy freed by COMPLETE) restart
    from prefill;
  * both paths also fire from liveness reaping
    (``ClusterScheduler.reap_dead``), not just explicit fail calls.
"""
from __future__ import annotations

import dataclasses
import itertools
import time

import numpy as np

from repro.core.cluster import ClusterScheduler, MembershipEvent
from repro.core.connection import ChipInfo, ConnectionManager, WorkerInfo
from repro.core.transfer_engine import LinkModel, TransferEngine
from repro.fleet import FleetController
from repro.fleet.admission import AdmissionDeferred
from repro.obs.metrics import MetricsRegistry
from repro.obs.trace import NULL_TRACER
from repro.sched import LoadReport, NoWorkersError, RequestRouter, RouteRequest
from repro.serving.blocks import OutOfBlocks
from repro.serving.engine import DecodeWorker, PrefillWorker
from repro.serving.handle import RequestHandle
from repro.serving.kv_cache import PagedKVCache
from repro.serving.loop import ServeLoop, ServeLoopStalled
from repro.serving.request import Request, RequestState

__all__ = ["DisaggService"]


@dataclasses.dataclass
class _HedgeTwin:
    """A hedged prefill's duplicate KV copy: worker + slab blocks + the
    (identical) first token.  Freed when the primary's transfer COMPLETEs
    (loser aborted); adopted by failover when the primary copy dies.
    Carries the twin's block hashes and quant scales so adoption swaps
    the FULL transfer-plan identity, not just the block ids — stale
    hashes/scales from the dead primary would dedup or dequantize against
    the wrong bytes."""

    worker_id: str
    blocks: list[int]
    first_token: int
    hashes: list[str] = dataclasses.field(default_factory=list)
    scales: list | None = None

_RETRYABLE = (
    RequestState.PREFILLING,
    RequestState.KV_QUEUED,
    RequestState.KV_TRANSFER,
)


def _winfo(wid: str, role: str) -> WorkerInfo:
    return WorkerInfo(wid, role, f"host-{wid}", (ChipInfo(0, f"ici://{wid}/0"),))


class DisaggService:
    def __init__(
        self,
        model,
        params,
        *,
        n_prefill: int = 1,
        n_decode: int = 1,
        num_blocks: int = 256,
        policy: str = "least_loaded",
        links: dict[tuple[str, str], LinkModel] | None = None,
        prefill_time_fn=None,
        slo_classes: dict[str, float] | None = None,
        consume: str = "full",
        delta_transfer: bool = True,
        quantize_transfer: bool = False,
        tracer=None,
        metrics=None,
        clock=None,
        fleet=None,
    ):
        """``consume`` ("full" | "layerwise") is the decode workers' pull
        consumption mode: "layerwise" starts a request's first decode step
        on early layers while the tail of its KV pull is still in flight
        (see DecodeWorker).

        ``delta_transfer`` lets decode workers graft resident blocks
        (retained prefixes, content-hash dedup hits) into admissions and
        pull only the missing suffix; ``quantize_transfer`` makes prefill
        workers compute per-block int8 scales at park time so pulls move
        quantized wire bytes (docs/transfer.md).  Both default to the
        paper-faithful full-precision pull being the fallback: a request
        with nothing resident behaves exactly as before.

        Observability (docs/observability.md): pass a ``repro.obs.Tracer``
        as ``tracer`` to record per-request lifecycle spans and loop/engine
        phase spans (the default is the disabled no-op tracer); ``metrics``
        is the ``MetricsRegistry`` serve-path counters/histograms land in
        (one is created when omitted); ``clock`` is THE wall clock for
        every observability timestamp — tracer spans, handle metrics, and
        token times share it, so the span-derived breakdown and
        ``HandleMetrics`` agree exactly (a sim harness can inject a
        virtual clock and produce the identical span schema).

        ``fleet`` is an optional ``repro.fleet.FleetConfig``: when given,
        a ``FleetController`` (autoscaling, memory-pressure preemption,
        KV-budget admission — docs/fleet.md) is built and stepped by the
        serving loop every tick.  Without it the service behaves exactly
        as before (no control plane)."""
        if consume not in ("full", "layerwise"):
            raise ValueError(f"consume must be 'full' or 'layerwise', got {consume!r}")
        self.consume = consume
        self.delta_transfer = delta_transfer
        self.quantize_transfer = quantize_transfer
        self.model = model
        self.params = params
        self.obs_clock = clock if clock is not None else time.perf_counter
        self.tracer = tracer if tracer is not None else NULL_TRACER
        if tracer is not None and clock is not None:
            tracer.clock = clock  # one clock: spans == handle metrics
        self.metrics = metrics if metrics is not None else MetricsRegistry()
        self.scheduler = ClusterScheduler()
        self.engine = TransferEngine(coalescing="sorted", tracer=self.tracer,
                                     metrics=self.metrics)
        self._ids = itertools.count()
        self._wid_seq = {"p": itertools.count(), "d": itertools.count()}
        self._next_base = 0x7F00_0000_0000  # bump allocator for KV slabs
        self.clock = 0.0

        # Heterogeneous-cluster binding (topo.TopologyBinding), set by
        # from_cluster_spec: maps worker ids to machines, sizes pools by
        # VRAM, feeds the router per-pair links, and picks which spare
        # machine a fleet hot-add claims.  None = homogeneous service.
        self.topology = None

        self.prefills: dict[str, PrefillWorker] = {}
        self.decodes: dict[str, DecodeWorker] = {}
        self.conn_mgrs: dict[str, ConnectionManager] = {}
        self.pending: dict[str, tuple[Request, np.ndarray]] = {}  # in flight
        self.first_tokens: dict[str, int] = {}
        self.handles: dict[str, RequestHandle] = {}  # live (not yet DONE)
        self.hedges: dict[str, _HedgeTwin] = {}      # rid -> twin KV copy
        # The event-driven serving loop: every handle is driven by it,
        # whether the caller ticks it directly (streaming) or goes
        # through the generate/generate_many shims.
        self.loop = ServeLoop(self)
        # Fleet control plane (docs/fleet.md), stepped by the loop each
        # tick; admission is consulted by _dispatch.  Tests may attach a
        # bare AdmissionController to self.admission without a fleet.
        self.fleet = FleetController(self, fleet) if fleet is not None else None
        self.admission = self.fleet.admission if self.fleet is not None else None

        policy_kwargs = {"classes": slo_classes} if (
            policy == "slo" and slo_classes is not None) else {}
        self.router = RequestRouter(
            self.scheduler, policy, links=links,
            prefill_time_fn=prefill_time_fn, metrics=self.metrics,
            **policy_kwargs,
        )

        # COMPLETE() → prefill worker frees its blocks
        self.engine.on_complete(self._on_complete)
        # membership → connections + failover (explicit fails AND reaping)
        self.scheduler.subscribe(self._on_membership)

        for _ in range(n_decode):
            self.add_decode_worker(num_blocks=num_blocks)
        for _ in range(n_prefill):
            self.add_prefill_worker(num_blocks=num_blocks)

    # ---------------------------------------------------- topology entry
    @classmethod
    def from_cluster_spec(cls, model, params, spec, *, placement=None,
                          planner=None, seed: int = 0, num_blocks: int = 256,
                          policy: str = "network_aware", **kwargs):
        """Build a service from a ``topo.ClusterSpec``: plan prefill/
        decode roles over the topology (or take an explicit
        ``placement``), size each worker's KV pool by its machine's VRAM
        (``num_blocks`` = the largest machine's pool), and feed the
        router the per-pair ``LinkModel``s so ``network_aware`` /
        ``prefix_affinity`` routing prices real bandwidth + latency.

        The SAME spec replays in the simulator
        (``ClusterSim(..., topology=TopologyBinding(spec, placement))``)
        byte-for-byte — ``spec.to_json()`` is the shared artifact.
        """
        from repro.topo import PlacementPlanner, TopologyBinding
        planner = planner if planner is not None else PlacementPlanner()
        if placement is None:
            placement = planner.plan(spec, seed=seed)
        binding = TopologyBinding(spec, placement, planner=planner)
        svc = cls(model, params, n_prefill=0, n_decode=0, policy=policy,
                  **kwargs)
        svc.topology = binding
        for _ in placement.decode:
            svc.add_decode_worker(num_blocks=num_blocks)
        for _ in placement.prefill:
            svc.add_prefill_worker(num_blocks=num_blocks)
        return svc

    # -------------------------------------------------- address space
    def _slab_bytes(self, num_blocks: int) -> int:
        cfg = self.model.cfg
        return PagedKVCache.slab_nbytes(
            num_layers=cfg.num_layers, num_blocks=num_blocks,
            block_size=self.model.BLOCK_SIZE, kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim)

    def _alloc_base(self, num_blocks: int) -> int:
        """Distinct, non-overlapping slab base per worker (1 MiB guard)."""
        base = self._next_base
        one_mib = 1 << 20
        span = -(-self._slab_bytes(num_blocks) // one_mib) * one_mib + one_mib
        self._next_base += span
        return base

    # ------------------------------------------------------- membership
    def _bind_topology(self, role: str, wid: str, num_blocks: int) -> int:
        """Topology-bound pool sizing: ``num_blocks`` is the reference
        (largest-VRAM) machine's pool; the bound machine gets a
        VRAM-proportional share.  Hot-adds claim the best spare machine
        (raising ``topo.NoSpareMachine`` on an exhausted cluster) and
        refresh the router's per-pair link map."""
        topo = self.topology
        if topo is None:
            return num_blocks
        m = topo.machine(wid)
        if m is None:  # hot-add beyond the placement: claim a spare
            m = topo.add_worker(role, wid)
        return max(1, round(num_blocks * m.profile.vram_bytes
                            / topo.spec.max_vram))

    def add_prefill_worker(self, *, num_blocks: int = 256) -> str:
        wid = f"p{next(self._wid_seq['p'])}"  # monotonic: ids never reused
        num_blocks = self._bind_topology("prefill", wid, num_blocks)
        w = PrefillWorker(_winfo(wid, "prefill"), self.model, self.params,
                          num_blocks=num_blocks,
                          base_address=self._alloc_base(num_blocks),
                          quantize_transfer=self.quantize_transfer)
        self.prefills[wid] = w
        self.engine.register_memory(w.cache.memory_region())
        # seed liveness at the CURRENT clock, else a worker added late is
        # instantly reapable
        self.scheduler.add_worker(w.info, now=self.clock)  # broadcast → CONNECT
        if self.topology is not None:
            self.router.links.update(self.topology.links())
        return wid

    def add_decode_worker(self, *, num_blocks: int = 256) -> str:
        wid = f"d{next(self._wid_seq['d'])}"
        num_blocks = self._bind_topology("decode", wid, num_blocks)
        w = DecodeWorker(_winfo(wid, "decode"), self.model, self.params,
                         num_blocks=num_blocks, engine=self.engine,
                         base_address=self._alloc_base(num_blocks),
                         consume=self.consume,
                         delta_transfer=self.delta_transfer,
                         tracer=self.tracer, metrics=self.metrics)
        cm = ConnectionManager(w.info)
        cm.on_invalidate(self._on_prefill_invalidate)
        for pwid, pw in self.prefills.items():
            cm.connect(pw.info, pw.registry)
        self.decodes[wid] = w
        self.conn_mgrs[wid] = cm
        self.scheduler.add_worker(w.info, now=self.clock)
        if self.topology is not None:
            self.router.links.update(self.topology.links())
        return wid

    def fail_prefill_worker(self, wid: str) -> None:
        """Simulate a crash: scheduler reaps it; engine deregisters its MR;
        epochs invalidate on every decode worker; in-flight requests
        re-route."""
        self.scheduler.remove_worker(wid, failed=True)

    def fail_decode_worker(self, wid: str) -> None:
        """Simulate a decode crash: requests assigned there re-route."""
        self.scheduler.remove_worker(wid, failed=True)

    def reap_dead(self, now: float) -> list[str]:
        """Liveness-driven failover: lapsed heartbeats → same teardown
        path as an explicit failure."""
        self.clock = max(self.clock, now)
        return self.scheduler.reap_dead(now)

    def _on_membership(self, ev: MembershipEvent) -> None:
        wid = ev.worker.worker_id
        if ev.worker.role == "prefill":
            if ev.kind == "added":
                for cm in self.conn_mgrs.values():
                    cm.connect(ev.worker, self.prefills[wid].registry)
            else:
                self.engine.deregister_memory(wid)
                self.prefills.pop(wid, None)
                self.router.on_worker_failed(wid)
                for cm in self.conn_mgrs.values():
                    cm.disconnect(wid, failed=ev.kind == "failed")
                if ev.kind == "removed":
                    # graceful leave: no epoch invalidation fires, but the
                    # KV is leaving with the worker all the same — migrate
                    self._on_prefill_invalidate(wid, 0)
        elif ev.kind in ("removed", "failed"):  # decode leaving
            self.engine.deregister_memory(wid)
            self.decodes.pop(wid, None)
            self.conn_mgrs.pop(wid, None)
            self.router.on_worker_failed(wid)
            self._on_decode_failed(wid)  # graceful or crash: re-route

    # --------------------------------------------------------- failover
    def _on_prefill_invalidate(self, dead_worker: str, epoch: int) -> None:
        """A prefill epoch died (fired once per decode worker's table);
        re-route every request whose KV lived there.  Idempotent: after
        the first re-dispatch the request points at a live worker."""
        # hedge twins that lived on the dead worker are gone with it —
        # drop their entries so failover below can't adopt a dead copy
        for rid, twin in list(self.hedges.items()):
            if twin.worker_id == dead_worker:
                self.hedges.pop(rid, None)
        for rid, (req, tokens) in list(self.pending.items()):
            if req.prefill_worker == dead_worker and req.state in _RETRYABLE:
                self._restart(req, tokens)

    def _on_decode_failed(self, dead_worker: str) -> None:
        for rid, (req, tokens) in list(self.pending.items()):
            if req.decode_worker != dead_worker:
                continue
            if req.state == RequestState.KV_QUEUED:
                # prefill copy still alive — only the decode side moves
                req.retries += 1
                try:
                    self._assign_decode(req)
                    self.tracer.phase(("request", rid), "queue.kv",
                                      decode_worker=req.decode_worker)
                    self.metrics.inc("failover.decode_reassigned")
                except NoWorkersError:
                    self._park(req)
            elif req.state in (RequestState.KV_TRANSFER,
                               RequestState.QUEUED_DECODE,
                               RequestState.DECODING):
                # pulled KV died with the worker and the prefill copy was
                # freed by COMPLETE — restart from prefill
                self._restart(req, tokens)

    def _park(self, req: Request) -> None:
        """No capacity to re-route right now: park the request (stays in
        ``pending``; ``retry_parked`` revives it once capacity returns)."""
        if req.state is not RequestState.FAILED:
            req.to(RequestState.FAILED)
        req.decode_worker = None
        # parked wall time reads as queue time: the lifecycle track stays
        # a gap-free partition across a park/revive cycle
        self.tracer.phase(("request", req.request_id), "queue", parked=True)
        self.metrics.inc("failover.parked")

    def _restart(self, req: Request, tokens: np.ndarray) -> None:
        req.retries += 1
        self.metrics.inc("failover.restarts")
        self.tracer.instant("failover.restart", track=("request", req.request_id),
                            retries=req.retries)
        dw = self.decodes.get(req.decode_worker) if req.decode_worker else None
        if dw is not None:
            dw.abort(req.request_id)  # drop a dead in-flight pull, free blocks
        req.decode_blocks = []
        h = self.handles.get(req.request_id)
        if h is not None:
            h._reset_decoded()  # decode replays from scratch, identically
        primary_alive = bool(req.prefill_blocks) and req.prefill_worker in self.prefills
        if not primary_alive:
            twin = self.hedges.pop(req.request_id, None)
            if twin is not None and twin.worker_id in self.prefills:
                # hedged dispatch pays off: adopt the twin's surviving KV
                # copy — no re-prefill, the request just re-queues for
                # admission from the twin's slab
                req.prefill_worker = twin.worker_id
                req.prefill_blocks = list(twin.blocks)
                req.block_hashes = list(twin.hashes)
                req.kv_scales = twin.scales
                self.first_tokens[req.request_id] = twin.first_token
                self.metrics.inc("hedge.adopted")
                self.tracer.phase(("request", req.request_id), "queue.kv",
                                  adopted_twin=twin.worker_id)
                if h is not None:
                    h.metrics.hedge_adopted = True
                if req.state is not RequestState.KV_QUEUED:
                    req.to(RequestState.KV_QUEUED)
                try:
                    self._assign_decode(req)
                except NoWorkersError:
                    self._park(req)
                return
        if primary_alive:
            self.prefills[req.prefill_worker].release(req)  # stale live copy
        self._drop_hedge(req.request_id)  # re-dispatch may hedge afresh
        req.prefill_blocks = []
        if req.state is not RequestState.QUEUED_PREFILL:
            if req.state is not RequestState.FAILED:
                req.to(RequestState.FAILED)
            req.to(RequestState.QUEUED_PREFILL)
        self.router.forget(req.request_id)
        try:
            # already admitted once; re-hedge if the caller paid for it
            self._dispatch(req, tokens, force=True,
                           hedge=h.hedge if h is not None else 1)
        except (NoWorkersError, OutOfBlocks):
            # must not escape: callers include the membership broadcast —
            # a throw there would abort failover for the other requests
            self._park(req)

    def retry_parked(self, now: float | None = None) -> list[str]:
        """Re-dispatch requests parked by failover (call after adding
        workers or freeing capacity).  Returns the revived request ids."""
        if now is not None:
            self.clock = max(self.clock, now)
        revived = []
        for rid, (req, tokens) in list(self.pending.items()):
            if req.state is not RequestState.FAILED:
                continue
            if req.prefill_blocks and req.prefill_worker in self.prefills:
                # prefill KV survived (decode-side park): only the decode
                # assignment was lost — no need to recompute the prefill
                try:
                    self._assign_decode(req)
                except NoWorkersError:
                    continue
                req.to(RequestState.KV_QUEUED)
                self.tracer.phase(("request", rid), "queue.kv",
                                  decode_worker=req.decode_worker)
            else:
                self._restart(req, tokens)
                if req.state is RequestState.FAILED:
                    continue
            revived.append(rid)
        return revived

    # -------------------------------------------------------- fleet ops
    # Mechanism for repro.fleet (docs/fleet.md): the MemoryGovernor and
    # FleetController decide WHAT to preempt/drain; these methods own the
    # page copies, ledger updates, tracer phases, and handle metrics.

    def swap_out_request(self, rid: str) -> bool:
        """Preempt a DECODING resident to the host swap pool.  The
        request stays pending (state DECODING, stream paused); False when
        it isn't resident or the pool's byte budget refuses the entry —
        the caller degrades to park behavior."""
        entry = self.pending.get(rid)
        if entry is None or self.fleet is None:
            return False
        req = entry[0]
        dw = self.decodes.get(req.decode_worker) if req.decode_worker else None
        if dw is None:
            return False
        swapped = dw.swap_out(rid)
        if swapped is None:
            return False
        if not self.fleet.swap_pool.put(rid, swapped, swapped.nbytes):
            dw.swap_in(swapped)  # budget refused; its blocks just freed, so this fits
            return False
        h = self.handles.get(rid)
        if h is not None:
            h.metrics.swapped_out += 1
        # paused wall time reads as queue time — the lifecycle track
        # stays a gap-free partition across a swap cycle (same
        # convention as parking)
        self.tracer.phase(("request", rid), "queue", swapped=True)
        self.metrics.inc("fleet.preempt_swap")
        return True

    def swap_in_request(self, rid: str, worker_id: str) -> bool:
        """Resume a swapped request on ``worker_id`` (any decode worker —
        the entry is worker-agnostic, which lets drains migrate swapped
        victims).  False when that worker can't hold it yet."""
        if self.fleet is None:
            return False
        swapped = self.fleet.swap_pool.get(rid)
        dw = self.decodes.get(worker_id)
        if swapped is None or dw is None:
            return False
        if not dw.swap_in(swapped):
            return False
        self.fleet.swap_pool.pop(rid)
        self.tracer.phase(("request", rid), "decode", worker=worker_id,
                          resumed=True)
        self.metrics.inc("fleet.resume_swap")
        return True

    def sacrifice_request(self, rid: str) -> bool:
        """Preempt by sacrifice: drop the resident's decode KV and replay
        through truncate-and-replay (``_restart``) — the replay re-pulls
        the KV and regenerates the identical stream (decode is
        deterministic)."""
        entry = self.pending.get(rid)
        if entry is None:
            return False
        req, tokens = entry
        dw = self.decodes.get(req.decode_worker) if req.decode_worker else None
        if dw is None or not dw.evict_resident(rid):
            return False
        h = self.handles.get(rid)
        if h is not None:
            h.metrics.sacrificed += 1
        self.metrics.inc("fleet.preempt_sacrifice")
        self._restart(req, tokens)
        return True

    def reassign_queued_off(self, worker_id: str) -> list[str]:
        """Move every KV_QUEUED request off a draining decode worker
        (their prefill KV stays put — only the pull destination changes).
        Stragglers the router can't place yet stay assigned; the drain
        waits for them."""
        moved = []
        for rid, (req, _) in list(self.pending.items()):
            if req.decode_worker != worker_id \
                    or req.state is not RequestState.KV_QUEUED:
                continue
            try:
                self._assign_decode(req)
            except NoWorkersError:
                continue
            if req.decode_worker != worker_id:
                self.tracer.phase(("request", rid), "queue.kv",
                                  decode_worker=req.decode_worker)
                moved.append(rid)
        return moved

    # ------------------------------------------------------------ loads
    def _report_loads(self, now: float | None = None) -> None:
        """Refresh every worker's LoadReport (the payload a worker's own
        heartbeat would piggyback, §4.2-style single control channel).
        Deliberately does NOT touch liveness timestamps: the serving
        layer reporting on a worker's behalf must not mask a dead worker
        from ``reap_dead`` — liveness comes from real heartbeats."""
        now = self.clock if now is None else now
        queued = {}  # KV_QUEUED footprint per decode worker: (tokens, count)
        for req, _ in self.pending.values():
            if req.state == RequestState.KV_QUEUED and req.decode_worker:
                t, c = queued.get(req.decode_worker, (0, 0))
                queued[req.decode_worker] = (t + req.prompt_len, c + 1)
        for wid, w in self.prefills.items():
            self.scheduler.report_load(wid, LoadReport(
                wid, "prefill", free_blocks=w.pool.num_free,
                total_blocks=w.pool.stats.capacity,
                block_size=w.block_size, t=now))
        for wid, w in self.decodes.items():
            q_tokens, q_depth = queued.get(wid, (0, 0))
            self.scheduler.report_load(wid, LoadReport(
                wid, "decode", free_blocks=w.pool.num_free,
                total_blocks=w.pool.stats.capacity,
                resident_requests=len(w.resident),
                queued_tokens=q_tokens, queue_depth=q_depth,
                block_size=w.block_size, t=now,
                prefix_ids=tuple(sorted(w.known_prefixes)),
                evictable_blocks=w.evictable_blocks,
                prefix_blocks=w.resident_prefix_blocks))

    # ------------------------------------------------------------ serve
    def _ctx(self, req: Request) -> RouteRequest:
        blocks = -(-req.prompt_len // self.model.BLOCK_SIZE)
        return RouteRequest(req.request_id, req.prompt_len,
                            kv_bytes=self._slab_bytes(blocks),
                            slo_class=req.slo_class, arrival_s=req.arrival_s,
                            prefix_id=req.prefix_id)

    def _assign_decode(self, req: Request) -> None:
        self._report_loads()
        req.decode_worker = self.router.reassign_decode(
            self._ctx(req), req.prefill_worker)

    def _dispatch(self, req: Request, tokens: np.ndarray, *,
                  force: bool = False, hedge: int = 1) -> None:
        self._report_loads()
        if self.admission is not None and not force:
            # KV-budget admission (docs/fleet.md): reject/defer before
            # any prefill compute is spent.  force (failover re-dispatch)
            # bypasses it — the request was already admitted once.
            need = -(-req.prompt_len // self.model.BLOCK_SIZE)
            self.admission.check(self.scheduler.loads("decode"), need,
                                 req.request_id)
        decision = self.router.route(self._ctx(req), now=self.clock, force=force)
        req.prefill_worker = decision.prefill_worker
        req.decode_worker = decision.decode_worker
        tr = ("request", req.request_id)
        w = self.prefills[decision.prefill_worker]
        self.tracer.phase(tr, "prefill", worker=decision.prefill_worker)
        try:
            self.first_tokens[req.request_id] = w.prefill(req, tokens)
        except Exception:
            self.tracer.phase(tr, "queue")  # prefill never ran: back to queued
            self.router.forget(req.request_id)  # retire the ledger charge
            raise
        req.to(RequestState.KV_QUEUED)
        self.tracer.phase(tr, "queue.kv", decode_worker=decision.decode_worker)
        self.metrics.inc("requests.dispatched")
        if hedge > 1:
            self._dispatch_hedge(req, tokens)
        h = self.handles.get(req.request_id)
        if h is not None and not h.tokens:
            h._push(self.first_tokens[req.request_id])

    def _dispatch_hedge(self, req: Request, tokens: np.ndarray) -> None:
        """Run a duplicate prefill on a SECOND worker picked by the
        router.  The twin's KV copy rides along until the primary's
        transfer COMPLETEs (then it is aborted and its slab freed) or the
        primary dies first (then failover adopts it without re-prefill).
        Degrades silently when no second worker exists or its pool is
        full — hedging is opportunistic."""
        twin_wid = self.router.pick_hedge_prefill(
            self._ctx(req), {req.prefill_worker}, now=self.clock)
        if twin_wid is None:
            return
        try:
            first, blocks, hashes, scales = \
                self.prefills[twin_wid].prefill_shadow(tokens)
        except OutOfBlocks:
            self.router.forget_hedge(req.request_id)  # twin never ran
            return
        self.hedges[req.request_id] = _HedgeTwin(twin_wid, blocks, first,
                                                 hashes, scales)
        self.metrics.inc("hedge.dispatched")
        self.tracer.instant("hedge.dispatch", track=("request", req.request_id),
                            twin=twin_wid)
        h = self.handles.get(req.request_id)
        if h is not None:
            h.metrics.hedged = True

    def _drop_hedge(self, rid: str) -> None:
        """The race is decided (COMPLETE, finish, or restart): abort the
        losing twin and free its slab."""
        twin = self.hedges.pop(rid, None)
        if twin is None:
            return
        self.metrics.inc("hedge.aborted")
        w = self.prefills.get(twin.worker_id)
        if w is not None:
            w.pool.free(twin.blocks)

    def submit(self, tokens: np.ndarray, *, slo_class: str = "standard",
               now: float | None = None, max_new: int | None = None,
               eos_token: int | None = None, hedge: int = 1,
               prefix_id: str | None = None, prefix_len: int = 0,
               dispatch: str = "eager") -> RequestHandle:
        """Submit one request; returns a ``RequestHandle`` immediately.

        ``dispatch="eager"`` (default, the historical behavior) routes
        and prefills synchronously — ``sched.AdmissionRejected`` raises
        here if the SLO controller projects a missed deadline.
        ``dispatch="queued"`` returns with the request still QUEUED; the
        serving loop's next ``tick()`` routes and prefills it (an
        admission rejection then surfaces on the handle as FAILED).

        ``max_new``/``eos_token`` bound decode for loop-driven serving
        (``max_new=None`` defers the budget to the generate shims);
        ``hedge=2`` dispatches a twin prefill via the router (first
        COMPLETE wins, the loser's slab is freed); ``prefix_id`` (with
        optional ``prefix_len``, 0 = whole prompt) tags the request's
        shared prefix for prefix-affinity routing and retention."""
        if dispatch not in ("eager", "queued"):
            raise ValueError(f"dispatch must be 'eager' or 'queued', got {dispatch!r}")
        if now is not None:
            self.clock = max(self.clock, now)  # never rewind the clock
        req = Request(f"r{next(self._ids)}", len(tokens), max_new or 0,
                      arrival_s=self.clock, slo_class=slo_class,
                      prefix_id=prefix_id, prefix_len=prefix_len)
        handle = RequestHandle(req, self, max_new=max_new,
                               eos_token=eos_token, hedge=hedge,
                               clock=self.obs_clock)
        self.pending[req.request_id] = (req, tokens)
        self.handles[req.request_id] = handle
        # the request's lifecycle track opens at the SAME timestamp the
        # handle metrics anchor on, so breakdown ttlt == HandleMetrics.ttlt_s
        self.tracer.phase(("request", req.request_id), "queue",
                          ts=handle.metrics.submitted_at,
                          prompt_len=req.prompt_len, slo=slo_class)
        self.metrics.inc("requests.submitted")
        if dispatch == "eager":
            try:
                self._dispatch(req, tokens, hedge=hedge)
            except AdmissionDeferred:
                pass  # stays QUEUED_PREFILL; the loop dispatches later
            except Exception:
                self.pending.pop(req.request_id, None)
                self.handles.pop(req.request_id, None)
                raise
        return handle

    def _on_complete(self, txn) -> None:
        w = self.prefills.get(txn.src_worker)
        req = next((r for r, _ in self.pending.values()
                    if r.request_id == txn.request_id), None)
        if w is not None and req is not None:
            w.release(req)
        # the primary's pull landed: the hedge race (if any) is decided —
        # "first COMPLETE wins" — so the twin is aborted and freed
        self._drop_hedge(txn.request_id)

    def admit_to_decode(self, req) -> bool:
        """Pull the KV into the assigned decode worker; False if its pool
        is full (request stays KV_QUEUED; prefill KV stays alive)."""
        req = getattr(req, "request", req)  # accept handle or Request
        cm = self.conn_mgrs[req.decode_worker]
        conn = cm.connection(req.prefill_worker)
        try:
            self.decodes[req.decode_worker].admit(
                req, conn, self.first_tokens[req.request_id])
        except OutOfBlocks:
            return False
        return True

    # -------------------------------------------------- batched admission
    def admit_queued(self, *, max_batch: int | None = None,
                     only: set[str] | None = None) -> dict[str, list[str]]:
        """Router-planned admission batches: every KV_QUEUED request
        (restricted to ``only`` when given) is grouped by its assigned
        decode worker (capacity-capped, FIFO by arrival) and its pull is
        SUBMITTED — not drained.  The transfers advance via ``pump()`` /
        the decode workers' interleaved rounds, so transfer time hides
        behind decode compute.  Returns the request ids actually admitted
        per worker."""
        self._report_loads()
        queued = [
            (self._ctx(req), req.decode_worker)
            for req, _ in self.pending.values()
            if req.state is RequestState.KV_QUEUED
            and req.decode_worker in self.decodes
            and (only is None or req.request_id in only)
        ]
        if not queued:
            return {}
        plan = self.router.plan_admissions(queued, max_batch=max_batch)
        admitted: dict[str, list[str]] = {}
        for wid, rids in plan.items():
            dw = self.decodes[wid]
            cm = self.conn_mgrs[wid]
            batch = [
                (self.pending[rid][0],
                 cm.connection(self.pending[rid][0].prefill_worker),
                 self.first_tokens[rid])
                for rid in rids
            ]
            futures = dw.admit_batch(batch)
            if futures:
                admitted[wid] = [f.request_id for f in futures]
        return admitted

    def pump(self, budget: int | None = None) -> list[str]:
        """Advance in-flight pulls on every decode worker; returns request
        ids promoted to DECODING."""
        promoted: list[str] = []
        for dw in list(self.decodes.values()):
            promoted.extend(dw.pump(budget))
        return promoted

    def _reject_queued(self, rid: str, err: Exception) -> None:
        """A queued submission failed admission at dispatch time: mark
        the handle FAILED (terminally — rejection is a decision, not a
        capacity blip) and drop the service-side ledger entries."""
        entry = self.pending.pop(rid, None)
        if entry is not None and entry[0].state is not RequestState.FAILED:
            entry[0].to(RequestState.FAILED)
        self.tracer.end_phase(("request", rid), rejected=str(err))
        self.metrics.inc("requests.rejected")
        h = self.handles.pop(rid, None)
        if h is not None:
            h.error = err

    # --------------------------------------------------------- completion
    def _finish_request(self, rid: str) -> None:
        """Retire a request that finished decoding (budget reached or
        EOS): free its decode blocks, drop every ledger entry, and seal
        the handle's pulled-bytes metric."""
        h = self.handles.pop(rid, None)
        if h is not None:
            # seal BEFORE DecodeWorker.finish pops the engine's counters
            h.metrics.kv_bytes_pulled = self.engine.pulled_bytes(rid)
            h.metrics.kv_bytes_reused = self.engine.reused_bytes(rid)
            # close the lifecycle track AT the last token's timestamp, so
            # the span partition's extent equals HandleMetrics.ttlt_s
            self.tracer.end_phase(("request", rid), ts=h.metrics.last_token_at)
            m, hm = self.metrics, h.metrics
            m.inc("requests.finished")
            m.inc("request.kv_bytes_pulled", hm.kv_bytes_pulled)
            m.inc("request.kv_bytes_reused", hm.kv_bytes_reused)
            if hm.kv_bytes_pulled or hm.kv_bytes_reused:
                m.observe("request.kv_reuse_frac", hm.kv_reuse_frac)
            if hm.ttft_s is not None:
                m.observe("request.ttft_s", hm.ttft_s)
            if hm.ttlt_s is not None:
                m.observe("request.ttlt_s", hm.ttlt_s)
            if hm.tbt_s is not None:
                m.observe("request.tbt_s", hm.tbt_s)
        req_entry = self.pending.pop(rid, None)
        if req_entry is not None:
            req = req_entry[0]
            dw = self.decodes.get(req.decode_worker) if req.decode_worker else None
            if dw is not None:
                dw.finish(rid)
            if req.state is not RequestState.DONE:
                # early finish (EOS from prefill / zero budget): no pull
                # ever ran, so no COMPLETE will free the prefill copy —
                # release it here
                if req.prefill_blocks and req.prefill_worker in self.prefills:
                    self.prefills[req.prefill_worker].release(req)
                req.to(RequestState.DONE)
        self.engine.pulled_bytes(rid, pop=True)
        self.engine.reused_bytes(rid, pop=True)
        self.router.forget(rid)
        self._drop_hedge(rid)
        self.first_tokens.pop(rid, None)

    def _handle_of(self, req) -> RequestHandle:
        """Normalize a caller-held object (RequestHandle or bare Request)
        to its live handle."""
        if isinstance(req, RequestHandle):
            return req
        h = self.handles.get(req.request_id)
        if h is None:  # a bare Request never submitted through us
            raise KeyError(f"unknown request {req.request_id!r}")
        return h

    # ------------------------------------------------------------- shims
    def generate_many(self, reqs: list, max_new: int = 8, *,
                      pump_budget: int | None = 32) -> dict[str, list[int]]:
        """Batch shim over the event-driven serving loop: give every
        request a ``max_new`` decode budget and tick ``ServeLoop`` until
        each is DONE (or parked).  Under the hood this is CONTINUOUS
        batching — requests join decode as their pulls land and leave at
        their budget without stalling cohabitants — but the call shape
        (and, per request, the tokens) match the old round-synchronous
        API exactly.

        Requests parked by failover (no capacity) are skipped — revive
        them with ``retry_parked()`` and call again.  Returns
        request_id → [first_token, *decoded] for every finished request."""
        handles = [self._handle_of(r) for r in reqs]
        for h in handles:
            if not h.done:
                h.max_new = max_new
        prev_budget = self.loop.pump_budget
        self.loop.pump_budget = pump_budget
        try:
            self.loop.run_until_idle(only={h.request_id for h in handles})
        finally:
            self.loop.pump_budget = prev_budget  # shared loop: don't leak
        return {h.request_id: list(h.tokens[: 1 + max_new])
                for h in handles if h.done}

    def generate(self, req, max_new: int = 8) -> list[int]:
        """Single-request shim — the SAME loop path as ``generate_many``
        (no separate dispatch code to drift).  Preserves the historical
        error contract: RuntimeError for a parked request, OutOfBlocks
        when the decode pool cannot hold it."""
        h = self._handle_of(req)
        if h.request.state is RequestState.FAILED:
            h._raise_failed()  # rejection error, or "parked" RuntimeError
        try:
            out = self.generate_many([h], max_new=max_new)
        except ServeLoopStalled:
            if h.request.state is RequestState.KV_QUEUED:
                raise OutOfBlocks("decode pool full")
            raise
        if h.request_id not in out:
            h._raise_failed()  # parked (or rejected) during the drive
        return out[h.request_id]

    # ------------------------------------------------- single-decode API
    @property
    def decode(self) -> DecodeWorker:
        """Oldest decode worker (compat for single-decode callers).
        Numeric sort: ids are monotonic, so lexicographic would misorder
        d10 before d2."""
        if not self.decodes:
            raise NoWorkersError("no live decode workers")
        return self.decodes[min(self.decodes, key=lambda w: int(w[1:]))]

    @property
    def conn_mgr(self) -> ConnectionManager:
        """Oldest decode worker's connection table (compat)."""
        if not self.conn_mgrs:
            raise NoWorkersError("no live decode workers")
        return self.conn_mgrs[min(self.conn_mgrs, key=lambda w: int(w[1:]))]
