"""End-to-end disaggregated serving — the paper's full pipeline on real
substrate: cluster scheduler + N prefill × M decode workers + KVDirect
engine + the ``repro.sched`` request router.

Flow per request (pull-mode, §4.3):
  submit → router picks a (prefill, decode) pair via the configured
  policy (round-robin / least-loaded / network-aware / SLO admission) →
  model prefill (real JAX) → KV blocks land in the prefill worker's
  registered slab → the ASSIGNED decode worker allocates + pulls via
  one-sided reads over its own connection table → COMPLETE frees the
  prefill copy → continuous-batching decode.

Topology: every decode worker owns a ``ConnectionManager`` with a live
connection to every prefill worker (§4.2's decode-side connection table),
so the router is free to pair any prefill with any decode.  Each worker's
KV slab gets a distinct, non-overlapping base address from a simple
bump allocator; the transfer engine rejects overlapping MRs.

Fault tolerance (both roles):
  * prefill crash → its connection epoch invalidates on every decode
    worker; in-flight requests whose KV lived there are re-routed and
    re-prefilled on a survivor;
  * decode crash → requests assigned there are re-routed: KV_QUEUED
    requests keep their prefill KV and just get a new decode worker;
    requests already pulled (prefill copy freed by COMPLETE) restart
    from prefill;
  * both paths also fire from liveness reaping
    (``ClusterScheduler.reap_dead``), not just explicit fail calls.
"""
from __future__ import annotations

import itertools

import numpy as np

from repro.core.cluster import ClusterScheduler, MembershipEvent
from repro.core.connection import ChipInfo, ConnectionManager, WorkerInfo
from repro.core.transfer_engine import LinkModel, TransferEngine
from repro.sched import LoadReport, NoWorkersError, RequestRouter, RouteRequest
from repro.serving.blocks import OutOfBlocks
from repro.serving.engine import DecodeWorker, PrefillWorker
from repro.serving.kv_cache import PagedKVCache
from repro.serving.request import Request, RequestState

__all__ = ["DisaggService"]

_RETRYABLE = (
    RequestState.PREFILLING,
    RequestState.KV_QUEUED,
    RequestState.KV_TRANSFER,
)


def _winfo(wid: str, role: str) -> WorkerInfo:
    return WorkerInfo(wid, role, f"host-{wid}", (ChipInfo(0, f"ici://{wid}/0"),))


class DisaggService:
    def __init__(
        self,
        model,
        params,
        *,
        n_prefill: int = 1,
        n_decode: int = 1,
        num_blocks: int = 256,
        policy: str = "least_loaded",
        links: dict[tuple[str, str], LinkModel] | None = None,
        prefill_time_fn=None,
        slo_classes: dict[str, float] | None = None,
        consume: str = "full",
    ):
        """``consume`` ("full" | "layerwise") is the decode workers' pull
        consumption mode: "layerwise" starts a request's first decode step
        on early layers while the tail of its KV pull is still in flight
        (see DecodeWorker)."""
        if consume not in ("full", "layerwise"):
            raise ValueError(f"consume must be 'full' or 'layerwise', got {consume!r}")
        self.consume = consume
        self.model = model
        self.params = params
        self.scheduler = ClusterScheduler()
        self.engine = TransferEngine(coalescing="sorted")
        self._ids = itertools.count()
        self._wid_seq = {"p": itertools.count(), "d": itertools.count()}
        self._next_base = 0x7F00_0000_0000  # bump allocator for KV slabs
        self.clock = 0.0

        self.prefills: dict[str, PrefillWorker] = {}
        self.decodes: dict[str, DecodeWorker] = {}
        self.conn_mgrs: dict[str, ConnectionManager] = {}
        self.pending: dict[str, tuple[Request, np.ndarray]] = {}  # in flight
        self.first_tokens: dict[str, int] = {}

        policy_kwargs = {"classes": slo_classes} if (
            policy == "slo" and slo_classes is not None) else {}
        self.router = RequestRouter(
            self.scheduler, policy, links=links,
            prefill_time_fn=prefill_time_fn, **policy_kwargs,
        )

        # COMPLETE() → prefill worker frees its blocks
        self.engine.on_complete(self._on_complete)
        # membership → connections + failover (explicit fails AND reaping)
        self.scheduler.subscribe(self._on_membership)

        for _ in range(n_decode):
            self.add_decode_worker(num_blocks=num_blocks)
        for _ in range(n_prefill):
            self.add_prefill_worker(num_blocks=num_blocks)

    # -------------------------------------------------- address space
    def _slab_bytes(self, num_blocks: int) -> int:
        cfg = self.model.cfg
        return PagedKVCache.slab_nbytes(
            num_layers=cfg.num_layers, num_blocks=num_blocks,
            block_size=self.model.BLOCK_SIZE, kv_heads=cfg.num_kv_heads,
            head_dim=cfg.head_dim)

    def _alloc_base(self, num_blocks: int) -> int:
        """Distinct, non-overlapping slab base per worker (1 MiB guard)."""
        base = self._next_base
        one_mib = 1 << 20
        span = -(-self._slab_bytes(num_blocks) // one_mib) * one_mib + one_mib
        self._next_base += span
        return base

    # ------------------------------------------------------- membership
    def add_prefill_worker(self, *, num_blocks: int = 256) -> str:
        wid = f"p{next(self._wid_seq['p'])}"  # monotonic: ids never reused
        w = PrefillWorker(_winfo(wid, "prefill"), self.model, self.params,
                          num_blocks=num_blocks,
                          base_address=self._alloc_base(num_blocks))
        self.prefills[wid] = w
        self.engine.register_memory(w.cache.memory_region())
        # seed liveness at the CURRENT clock, else a worker added late is
        # instantly reapable
        self.scheduler.add_worker(w.info, now=self.clock)  # broadcast → CONNECT
        return wid

    def add_decode_worker(self, *, num_blocks: int = 256) -> str:
        wid = f"d{next(self._wid_seq['d'])}"
        w = DecodeWorker(_winfo(wid, "decode"), self.model, self.params,
                         num_blocks=num_blocks, engine=self.engine,
                         base_address=self._alloc_base(num_blocks),
                         consume=self.consume)
        cm = ConnectionManager(w.info)
        cm.on_invalidate(self._on_prefill_invalidate)
        for pwid, pw in self.prefills.items():
            cm.connect(pw.info, pw.registry)
        self.decodes[wid] = w
        self.conn_mgrs[wid] = cm
        self.scheduler.add_worker(w.info, now=self.clock)
        return wid

    def fail_prefill_worker(self, wid: str) -> None:
        """Simulate a crash: scheduler reaps it; engine deregisters its MR;
        epochs invalidate on every decode worker; in-flight requests
        re-route."""
        self.scheduler.remove_worker(wid, failed=True)

    def fail_decode_worker(self, wid: str) -> None:
        """Simulate a decode crash: requests assigned there re-route."""
        self.scheduler.remove_worker(wid, failed=True)

    def reap_dead(self, now: float) -> list[str]:
        """Liveness-driven failover: lapsed heartbeats → same teardown
        path as an explicit failure."""
        self.clock = max(self.clock, now)
        return self.scheduler.reap_dead(now)

    def _on_membership(self, ev: MembershipEvent) -> None:
        wid = ev.worker.worker_id
        if ev.worker.role == "prefill":
            if ev.kind == "added":
                for cm in self.conn_mgrs.values():
                    cm.connect(ev.worker, self.prefills[wid].registry)
            else:
                self.engine.deregister_memory(wid)
                self.prefills.pop(wid, None)
                self.router.on_worker_failed(wid)
                for cm in self.conn_mgrs.values():
                    cm.disconnect(wid, failed=ev.kind == "failed")
                if ev.kind == "removed":
                    # graceful leave: no epoch invalidation fires, but the
                    # KV is leaving with the worker all the same — migrate
                    self._on_prefill_invalidate(wid, 0)
        elif ev.kind in ("removed", "failed"):  # decode leaving
            self.engine.deregister_memory(wid)
            self.decodes.pop(wid, None)
            self.conn_mgrs.pop(wid, None)
            self.router.on_worker_failed(wid)
            self._on_decode_failed(wid)  # graceful or crash: re-route

    # --------------------------------------------------------- failover
    def _on_prefill_invalidate(self, dead_worker: str, epoch: int) -> None:
        """A prefill epoch died (fired once per decode worker's table);
        re-route every request whose KV lived there.  Idempotent: after
        the first re-dispatch the request points at a live worker."""
        for rid, (req, tokens) in list(self.pending.items()):
            if req.prefill_worker == dead_worker and req.state in _RETRYABLE:
                self._restart(req, tokens)

    def _on_decode_failed(self, dead_worker: str) -> None:
        for rid, (req, tokens) in list(self.pending.items()):
            if req.decode_worker != dead_worker:
                continue
            if req.state == RequestState.KV_QUEUED:
                # prefill copy still alive — only the decode side moves
                req.retries += 1
                try:
                    self._assign_decode(req)
                except NoWorkersError:
                    self._park(req)
            elif req.state in (RequestState.KV_TRANSFER,
                               RequestState.QUEUED_DECODE,
                               RequestState.DECODING):
                # pulled KV died with the worker and the prefill copy was
                # freed by COMPLETE — restart from prefill
                self._restart(req, tokens)

    def _park(self, req: Request) -> None:
        """No capacity to re-route right now: park the request (stays in
        ``pending``; ``retry_parked`` revives it once capacity returns)."""
        if req.state is not RequestState.FAILED:
            req.to(RequestState.FAILED)
        req.decode_worker = None

    def _restart(self, req: Request, tokens: np.ndarray) -> None:
        req.retries += 1
        if req.prefill_blocks and req.prefill_worker in self.prefills:
            self.prefills[req.prefill_worker].release(req)  # stale live copy
        dw = self.decodes.get(req.decode_worker) if req.decode_worker else None
        if dw is not None:
            dw.abort(req.request_id)  # drop a dead in-flight pull, free blocks
        req.prefill_blocks = []
        req.decode_blocks = []
        if req.state is not RequestState.QUEUED_PREFILL:
            if req.state is not RequestState.FAILED:
                req.to(RequestState.FAILED)
            req.to(RequestState.QUEUED_PREFILL)
        self.router.forget(req.request_id)
        try:
            self._dispatch(req, tokens, force=True)  # already admitted once
        except (NoWorkersError, OutOfBlocks):
            # must not escape: callers include the membership broadcast —
            # a throw there would abort failover for the other requests
            self._park(req)

    def retry_parked(self, now: float | None = None) -> list[str]:
        """Re-dispatch requests parked by failover (call after adding
        workers or freeing capacity).  Returns the revived request ids."""
        if now is not None:
            self.clock = max(self.clock, now)
        revived = []
        for rid, (req, tokens) in list(self.pending.items()):
            if req.state is not RequestState.FAILED:
                continue
            if req.prefill_blocks and req.prefill_worker in self.prefills:
                # prefill KV survived (decode-side park): only the decode
                # assignment was lost — no need to recompute the prefill
                try:
                    self._assign_decode(req)
                except NoWorkersError:
                    continue
                req.to(RequestState.KV_QUEUED)
            else:
                self._restart(req, tokens)
                if req.state is RequestState.FAILED:
                    continue
            revived.append(rid)
        return revived

    # ------------------------------------------------------------ loads
    def _report_loads(self, now: float | None = None) -> None:
        """Refresh every worker's LoadReport (the payload a worker's own
        heartbeat would piggyback, §4.2-style single control channel).
        Deliberately does NOT touch liveness timestamps: the serving
        layer reporting on a worker's behalf must not mask a dead worker
        from ``reap_dead`` — liveness comes from real heartbeats."""
        now = self.clock if now is None else now
        queued = {}  # KV_QUEUED footprint per decode worker: (tokens, count)
        for req, _ in self.pending.values():
            if req.state == RequestState.KV_QUEUED and req.decode_worker:
                t, c = queued.get(req.decode_worker, (0, 0))
                queued[req.decode_worker] = (t + req.prompt_len, c + 1)
        for wid, w in self.prefills.items():
            self.scheduler.report_load(wid, LoadReport(
                wid, "prefill", free_blocks=w.pool.num_free,
                total_blocks=w.pool.stats.capacity,
                block_size=w.block_size, t=now))
        for wid, w in self.decodes.items():
            q_tokens, q_depth = queued.get(wid, (0, 0))
            self.scheduler.report_load(wid, LoadReport(
                wid, "decode", free_blocks=w.pool.num_free,
                total_blocks=w.pool.stats.capacity,
                resident_requests=len(w.resident),
                queued_tokens=q_tokens, queue_depth=q_depth,
                block_size=w.block_size, t=now))

    # ------------------------------------------------------------ serve
    def _ctx(self, req: Request) -> RouteRequest:
        blocks = -(-req.prompt_len // self.model.BLOCK_SIZE)
        return RouteRequest(req.request_id, req.prompt_len,
                            kv_bytes=self._slab_bytes(blocks),
                            slo_class=req.slo_class, arrival_s=req.arrival_s)

    def _assign_decode(self, req: Request) -> None:
        self._report_loads()
        req.decode_worker = self.router.reassign_decode(
            self._ctx(req), req.prefill_worker)

    def _dispatch(self, req: Request, tokens: np.ndarray, *, force: bool = False) -> None:
        self._report_loads()
        decision = self.router.route(self._ctx(req), now=self.clock, force=force)
        req.prefill_worker = decision.prefill_worker
        req.decode_worker = decision.decode_worker
        w = self.prefills[decision.prefill_worker]
        try:
            self.first_tokens[req.request_id] = w.prefill(req, tokens)
        except Exception:
            self.router.forget(req.request_id)  # retire the ledger charge
            raise
        req.to(RequestState.KV_QUEUED)

    def submit(self, tokens: np.ndarray, *, slo_class: str = "standard",
               now: float | None = None) -> Request:
        """Route + prefill immediately (pull-mode: no decode-side
        reservation).  Raises ``sched.AdmissionRejected`` if the SLO
        admission controller projects a missed deadline."""
        if now is not None:
            self.clock = max(self.clock, now)  # never rewind the clock
        req = Request(f"r{next(self._ids)}", len(tokens), 0,
                      arrival_s=self.clock, slo_class=slo_class)
        self.pending[req.request_id] = (req, tokens)
        try:
            self._dispatch(req, tokens)
        except Exception:
            self.pending.pop(req.request_id, None)
            raise
        return req

    def _on_complete(self, txn) -> None:
        w = self.prefills.get(txn.src_worker)
        req = next((r for r, _ in self.pending.values()
                    if r.request_id == txn.request_id), None)
        if w is not None and req is not None:
            w.release(req)

    def admit_to_decode(self, req: Request) -> bool:
        """Pull the KV into the assigned decode worker; False if its pool
        is full (request stays KV_QUEUED; prefill KV stays alive)."""
        cm = self.conn_mgrs[req.decode_worker]
        conn = cm.connection(req.prefill_worker)
        try:
            self.decodes[req.decode_worker].admit(
                req, conn, self.first_tokens[req.request_id])
        except OutOfBlocks:
            return False
        return True

    # -------------------------------------------------- batched admission
    def admit_queued(self, *, max_batch: int | None = None,
                     only: set[str] | None = None) -> dict[str, list[str]]:
        """Router-planned admission batches: every KV_QUEUED request
        (restricted to ``only`` when given) is grouped by its assigned
        decode worker (capacity-capped, FIFO by arrival) and its pull is
        SUBMITTED — not drained.  The transfers advance via ``pump()`` /
        the decode workers' interleaved rounds, so transfer time hides
        behind decode compute.  Returns the request ids actually admitted
        per worker."""
        self._report_loads()
        queued = [
            (self._ctx(req), req.decode_worker)
            for req, _ in self.pending.values()
            if req.state is RequestState.KV_QUEUED
            and req.decode_worker in self.decodes
            and (only is None or req.request_id in only)
        ]
        if not queued:
            return {}
        plan = self.router.plan_admissions(queued, max_batch=max_batch)
        admitted: dict[str, list[str]] = {}
        for wid, rids in plan.items():
            dw = self.decodes[wid]
            cm = self.conn_mgrs[wid]
            batch = [
                (self.pending[rid][0],
                 cm.connection(self.pending[rid][0].prefill_worker),
                 self.first_tokens[rid])
                for rid in rids
            ]
            futures = dw.admit_batch(batch)
            if futures:
                admitted[wid] = [f.request_id for f in futures]
        return admitted

    def pump(self, budget: int | None = None) -> list[str]:
        """Advance in-flight pulls on every decode worker; returns request
        ids promoted to DECODING."""
        promoted: list[str] = []
        for dw in list(self.decodes.values()):
            promoted.extend(dw.pump(budget))
        return promoted

    def generate_many(self, reqs: list[Request], max_new: int = 8, *,
                      pump_budget: int | None = 32) -> dict[str, list[int]]:
        """Overlapped serving loop for a set of submitted requests:
        batched admission per decode worker, decode rounds interleaved
        with transfer progress (wave N's decode hides wave N+1's pulls),
        each request decoded for ``max_new`` tokens then finished.

        The loop only nudges the engine by ``pump_budget`` transactions
        per pass — the bulk of the transfer work is done INSIDE
        ``decode_round`` between decode steps, which is where the hiding
        happens.  Only when no worker has anything resident to decode
        (first wave, or a transfer-bound tail) does it run the engine
        freely — there is no compute to overlap with.

        One driver per decode worker: ``decode_round`` batches ALL of a
        worker's residents, so requests made resident by a concurrent
        caller would be decoded here with their tokens discarded — don't
        interleave ``generate_many`` with other admission/decode drivers
        on the same workers (admission of requests outside ``reqs`` is
        already excluded via ``only=``).

        Requests parked by failover (no capacity) are skipped — revive
        them with ``retry_parked()`` and call again.  Returns
        request_id → [first_token, *decoded] for every finished request."""
        remaining = {r.request_id: r for r in reqs}
        results: dict[str, list[int]] = {}
        while remaining:
            for rid, req in list(remaining.items()):
                if req.state in (RequestState.FAILED, RequestState.DONE):
                    remaining.pop(rid)  # parked (or externally finished)
            if not remaining:
                break
            snapshot = {rid: (req.state, req.prefill_worker, req.decode_worker)
                        for rid, req in remaining.items()}
            # only OUR requests: a concurrent caller's KV_QUEUED request
            # must not be admitted (and its tokens silently dropped) here
            admitted = bool(self.admit_queued(only=set(remaining)))
            promoted = bool(self.pump(pump_budget))
            decoded = False
            for wid, dw in list(self.decodes.items()):
                has_work = any(rid in remaining for rid in dw.resident) or (
                    dw.consume == "layerwise"
                    and any(rid in remaining for rid in dw.inflight))
                if not has_work:
                    continue
                # pumps in-flight pulls between decode steps; layerwise
                # workers additionally stream in-flight admissions into
                # the round's first step, so finish by what the round
                # actually completed, not by who was resident before it
                out = dw.decode_round(max_new, pump_budget=pump_budget)
                for rid in out:
                    if rid not in remaining:
                        continue
                    remaining.pop(rid)
                    dw.finish(rid)
                    self.pending.pop(rid, None)
                    self.router.forget(rid)
                    results[rid] = [self.first_tokens.pop(rid)] + out[rid]
                    decoded = True
            if decoded or not remaining:
                continue
            if self.engine.pending:
                # nothing resident anywhere: no compute to hide behind, so
                # run the engine directly — worker pump()s only progress
                # their OWN inflight pulls and would spin on foreign txns
                self.engine.progress()
                self.pump(0)  # promote whatever resolved
            elif not (admitted or promoted):
                if any(req.state in (RequestState.FAILED, RequestState.DONE)
                       for req in remaining.values()):
                    continue  # parked/finished mid-round: prune next pass
                if any(snapshot[rid] != (req.state, req.prefill_worker,
                                         req.decode_worker)
                       for rid, req in remaining.items()):
                    # failover moved a request mid-pass (e.g. a teardown
                    # fired from inside pump/decode_round and re-routed
                    # it): that's progress — admission retries next pass
                    continue
                stuck = ", ".join(sorted(remaining))
                raise RuntimeError(
                    f"generate_many stalled: {stuck} cannot be admitted "
                    "(decode pools too small for the request?)")
        return results

    def generate(self, req: Request, max_new: int = 8) -> list[int]:
        if req.state is RequestState.FAILED:
            raise RuntimeError(
                f"{req.request_id} is parked after failover (no capacity); "
                "add workers / free capacity and call retry_parked()")
        if req.request_id in self.pending and req.state == RequestState.KV_QUEUED:
            if not self.admit_to_decode(req):
                raise OutOfBlocks("decode pool full")
        d = self.decodes[req.decode_worker]
        out = d.decode_round(max_new)[req.request_id]
        d.finish(req.request_id)
        self.pending.pop(req.request_id, None)
        self.router.forget(req.request_id)  # also retires the ledger charge
        return [self.first_tokens.pop(req.request_id)] + out

    # ------------------------------------------------- single-decode API
    @property
    def decode(self) -> DecodeWorker:
        """Oldest decode worker (compat for single-decode callers).
        Numeric sort: ids are monotonic, so lexicographic would misorder
        d10 before d2."""
        if not self.decodes:
            raise NoWorkersError("no live decode workers")
        return self.decodes[min(self.decodes, key=lambda w: int(w[1:]))]

    @property
    def conn_mgr(self) -> ConnectionManager:
        """Oldest decode worker's connection table (compat)."""
        if not self.conn_mgrs:
            raise NoWorkersError("no live decode workers")
        return self.conn_mgrs[min(self.conn_mgrs, key=lambda w: int(w[1:]))]
