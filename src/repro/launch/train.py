"""Training driver — real steps on the local mesh, checkpoint/restart.

    PYTHONPATH=src python -m repro.launch.train --arch yi-9b --smoke \
        --steps 50 --ckpt-dir /tmp/ckpt

On a pod, the same code runs under `make_production_mesh()` with the
dry-run's shardings; here the local 1-device mesh exercises the identical
pjit path.  Fault tolerance: checkpoints carry (params, opt_state, data
state); `--resume` continues from the latest step.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.ckpt.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.configs import get_config, get_smoke_config
from repro.data.pipeline import SyntheticLMDataset
from repro.launch.mesh import make_local_mesh
from repro.launch.steps import make_train_step
from repro.models.registry import build_model
from repro.optim.adamw import AdamWConfig, adamw_init


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-9b")
    ap.add_argument("--smoke", action="store_true", help="reduced config (CPU-scale)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=25)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--lr", type=float, default=1e-3)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    opt_cfg = AdamWConfig(lr_peak=args.lr, warmup_steps=10, total_steps=args.steps,
                          fp32_master=cfg.fp32_master)
    mesh = make_local_mesh()

    params = model.init_params(jax.random.PRNGKey(0))
    opt_state = adamw_init(params, opt_cfg)
    data = SyntheticLMDataset(cfg.vocab_size, args.seq, args.batch)

    start = 0
    if args.resume and args.ckpt_dir:
        step = latest_step(args.ckpt_dir)
        if step is not None:
            params, opt_state, dstate = restore_checkpoint(
                args.ckpt_dir, step, (params, opt_state, data.state()))
            data.restore(jax.tree.map(int, dstate))
            start = step
            print(f"[train] resumed from step {step}")

    step_fn = jax.jit(make_train_step(model, opt_cfg, remat=True))

    n_params = sum(int(x.size) for x in jax.tree.leaves(params))
    print(f"[train] {cfg.name}: {n_params/1e6:.1f}M params, "
          f"{len(jax.devices())} devices, batch {args.batch}x{args.seq}")
    t0 = time.perf_counter()
    for step in range(start, args.steps):
        batch = {k: jnp.asarray(v) for k, v in data.next_batch().items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        if step % 10 == 0 or step == args.steps - 1:
            print(f"[train] step {step:5d} loss {float(metrics['loss']):.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f} "
                  f"({(time.perf_counter()-t0):.1f}s)")
        if args.ckpt_dir and (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1,
                            (params, opt_state, data.state()))
            print(f"[train] checkpointed step {step + 1}")
    print(f"[train] done: final loss {float(metrics['loss']):.4f}")


if __name__ == "__main__":
    main()
