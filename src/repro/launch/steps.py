"""Step functions + input specs for every (arch × shape) cell.

``input_specs(arch, shape)`` returns weak-type-correct ShapeDtypeStruct
stand-ins — no allocation — for the shape set assigned to the LM family:

  train_4k     seq 4096  × global_batch 256   → train_step
  prefill_32k  seq 32768 × global_batch 32    → prefill_step
  decode_32k   ctx 32768 × global_batch 128   → serve_step (1 new token)
  long_500k    ctx 524288 × global_batch 1    → serve_step; only for
               sub-quadratic archs (see DESIGN.md §4)
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models.config import ModelConfig
from repro.models.registry import build_model
from repro.optim.adamw import AdamWConfig, adamw_init, adamw_update

__all__ = [
    "SHAPES", "ShapeSpec", "input_specs", "make_train_step", "make_prefill_step",
    "make_serve_step", "cell_is_runnable", "skip_reason",
]


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}


def skip_reason(cfg: ModelConfig, shape: ShapeSpec) -> str | None:
    """None if the cell runs; else why it is skipped (recorded per-cell)."""
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return "full quadratic attention: 500K decode needs sub-quadratic arch"
    return None


def cell_is_runnable(arch: str, shape_name: str) -> bool:
    return skip_reason(get_config(arch), SHAPES[shape_name]) is None


# ----------------------------------------------------------------------
# input specs (ShapeDtypeStruct only — never allocates)
# ----------------------------------------------------------------------
def input_specs(cfg: ModelConfig, shape: ShapeSpec, model=None) -> dict[str, Any]:
    sds = jax.ShapeDtypeStruct
    b = shape.global_batch
    model = model or build_model(cfg)
    if shape.kind == "train":
        specs = {"tokens": sds((b, shape.seq_len), jnp.int32)}
        if cfg.family == "vlm":
            specs["vision_embeds"] = sds((b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.is_encoder_decoder:
            specs["frames"] = sds((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        return specs
    if shape.kind == "prefill":
        specs = {"tokens": sds((b, shape.seq_len), jnp.int32)}
        if cfg.family == "vlm":
            specs["vision_embeds"] = sds((b, cfg.vision_tokens, cfg.d_model), jnp.bfloat16)
        if cfg.is_encoder_decoder:
            specs["frames"] = sds((b, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
        return specs
    if shape.kind == "decode":
        return {
            "tokens": sds((b,), jnp.int32),
            "state": model.decode_state_shape(b, shape.seq_len),
        }
    raise ValueError(shape.kind)


# ----------------------------------------------------------------------
# steps
# ----------------------------------------------------------------------
def make_train_step(model, opt_cfg: AdamWConfig, *, remat: bool = True,
                    num_microbatches: int = 1):
    """num_microbatches > 1 = gradient accumulation: the remat stack saves
    per-layer inputs for the WHOLE resident batch, so at 4K×256 the
    full-batch backward needs ~24 GiB/device of saved activations alone;
    microbatching divides that by the accumulation factor (fp32 grad
    accumulator, one optimizer step per global batch)."""

    def loss_grads(params, mb):
        return jax.value_and_grad(
            lambda p: model.train_loss(p, mb, remat=remat), has_aux=True
        )(params)

    def train_step(params, opt_state, batch):
        if num_microbatches == 1:
            (loss, metrics), grads = loss_grads(params, batch)
        else:
            def split(x):
                mb = x.shape[0] // num_microbatches
                return x.reshape((num_microbatches, mb) + x.shape[1:])

            batch_mb = {k: split(v) for k, v in batch.items()}
            acc0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)

            def mb_step(carry, mb):
                acc, loss_sum = carry
                (loss, _), grads = loss_grads(params, mb)
                acc = jax.tree.map(lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return (acc, loss_sum + loss), None

            (acc, loss_sum), _ = jax.lax.scan(
                mb_step, (acc0, jnp.zeros((), jnp.float32)), batch_mb
            )
            grads = jax.tree.map(lambda a: a / num_microbatches, acc)
            loss = loss_sum / num_microbatches
            metrics = {"nll": loss, "aux": jnp.zeros((), jnp.float32)}
        params, opt_state, om = adamw_update(params, grads, opt_state, opt_cfg)
        return params, opt_state, {"loss": loss, **metrics, **om}

    return train_step


def make_prefill_step(model, *, remat: bool = True):
    def prefill_step(params, batch):
        logits, state = model.prefill(params, batch, remat=remat)
        first_token = jnp.argmax(
            logits[:, : model.cfg.vocab_size].astype(jnp.float32), axis=-1
        ).astype(jnp.int32)
        return first_token, state

    return prefill_step


def make_serve_step(model):
    """One decode iteration: next-token (greedy) + updated KV state."""

    def serve_step(params, state, tokens):
        logits, state = model.decode_step(params, state, tokens)
        next_token = jnp.argmax(
            logits[:, : model.cfg.vocab_size].astype(jnp.float32), axis=-1
        ).astype(jnp.int32)
        return next_token, state

    return serve_step


def init_train_state_specs(model, opt_cfg: AdamWConfig):
    """eval_shape the params + optimizer state (no allocation)."""
    params = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))
    opt_state = jax.eval_shape(lambda p: adamw_init(p, opt_cfg), params)
    return params, opt_state
