"""Production mesh construction.

A FUNCTION, not a module-level constant: importing this module must not
touch jax device state (the dry-run sets XLA_FLAGS before first init).

Axes:
  * ``model`` — tensor parallel (attention inner dim / d_ff / vocab)
  * ``data``  — batch DP + FSDP for params in training + expert parallel
  * ``pod``   — pure DP across pods; only gradient all-reduce crosses DCN
"""
from __future__ import annotations

import jax

__all__ = ["make_production_mesh", "make_local_mesh"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_local_mesh():
    """1-device mesh with the same axis names — smoke tests and examples
    run the exact same pjit code path on CPU."""
    n = len(jax.devices())
    return jax.make_mesh((1, n), ("data", "model"))
