"""Parse compiled/lowered HLO text for collective traffic (§Roofline).

``compiled.cost_analysis()`` has no collective-bytes entry, so we sum the
operand sizes of every collective op in the HLO text ourselves.

Bytes-on-the-wire model (ring algorithms, n = participants):
  all-gather         : out_bytes                 (each device receives ≈ out)
  all-reduce         : 2 × bytes                 (reduce-scatter + all-gather)
  reduce-scatter     : in_bytes
  all-to-all         : bytes
  collective-permute : bytes

Caveat (methodology, documented in EXPERIMENTS.md): ops inside a while
loop (lax.scan) appear once in the text but run `trip_count` times — the
roofline pipeline therefore reads collectives from the UNROLLED depth-1/2
analysis variants and extrapolates, never from the scanned full-depth
program.
"""
from __future__ import annotations

import dataclasses
import re

__all__ = ["CollectiveStats", "collective_bytes", "parse_shape_bytes"]

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1, "c128": 16,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COLLECTIVE_KINDS = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all", "collective-permute",
)
_WIRE_FACTOR = {
    "all-gather": 1.0, "all-reduce": 2.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}
# op name like: "%all-gather.3 = (bf16[...], bf16[...]) all-gather(...)"
_OP_RE = re.compile(
    r"=\s*(?P<shape>\([^)]*\)|\S+)\s+(?P<kind>"
    + "|".join(_COLLECTIVE_KINDS)
    + r")(?:-start|-done)?\("
)


def parse_shape_bytes(shape_str: str) -> int:
    """'bf16[128,1024]' or '(f32[4], bf16[8,8])' → total bytes."""
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue  # token[] etc.
        elems = 1
        if dims:
            for d in dims.split(","):
                if d:
                    elems *= int(d)
        total += elems * _DTYPE_BYTES[dtype]
    return total


@dataclasses.dataclass
class CollectiveStats:
    by_kind_bytes: dict[str, int]
    by_kind_count: dict[str, int]
    wire_bytes: float  # with ring-model factors
    f32_wire_bytes: float = 0.0  # share of wire moving f32 payloads

    @property
    def total_bytes(self) -> int:
        return sum(self.by_kind_bytes.values())

    @property
    def wire_bytes_bf16_adjusted(self) -> float:
        """XLA:CPU emulates bf16 dots in f32, so activation collectives in
        this container's HLO are 2× their TPU size (TPU MXU emits bf16).
        This bound halves the f32 share — exact for activation traffic,
        conservative for fp32 gradient reductions a trainer may keep."""
        return self.wire_bytes - 0.5 * self.f32_wire_bytes


def collective_bytes(hlo_text: str) -> CollectiveStats:
    by_bytes: dict[str, int] = {k: 0 for k in _COLLECTIVE_KINDS}
    by_count: dict[str, int] = {k: 0 for k in _COLLECTIVE_KINDS}
    wire = 0.0
    f32_wire = 0.0
    for m in _OP_RE.finditer(hlo_text):
        kind = m.group("kind")
        # "-start" ops carry the payload; matching "-done" would double count
        if hlo_text[m.end() - 7 : m.end() - 1].endswith("done"):
            continue
        span = m.group("shape")
        # async -start ops have tuple shapes ((operand), out, ...) — the
        # output component is enough for our wire model
        nbytes = parse_shape_bytes(span)
        if "-start" in hlo_text[m.start() : m.end()]:
            nbytes //= 2  # tuple carries (in, out) copies of the payload
        by_bytes[kind] += nbytes
        by_count[kind] += 1
        wire += nbytes * _WIRE_FACTOR[kind]
        if "f32[" in span and "bf16[" not in span:
            f32_wire += nbytes * _WIRE_FACTOR[kind]
    return CollectiveStats(by_bytes, by_count, wire, f32_wire)
