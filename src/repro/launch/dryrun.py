import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run (deliverable e).

Lowers + compiles the real step function for every (architecture × input
shape) cell on the production meshes:

    16×16        ("data","model")        — one v5e pod, 256 chips
    2×16×16      ("pod","data","model")  — two pods, 512 chips

and records memory_analysis / cost_analysis / per-collective byte sums
into a JSON artifact consumed by the §Roofline pipeline.

Depth variants (--depth):
    full  — scan-over-layers at the full assigned depth: proves lowering,
            sharding coherence and per-device memory.
    1 | 2 — UNROLLED 1- or 2-unit variants: FLOPs/bytes/collectives are
            exactly visible to cost_analysis (a while-loop body is
            counted once regardless of trip count), so the roofline
            pipeline extrapolates total = f(1) + (units-1)·(f(2)-f(1)).

Usage:
    python -m repro.launch.dryrun --arch deepseek-67b --shape train_4k \
        --mesh pod --depth full --out results/dryrun
"""
import argparse
import dataclasses
import json
import pathlib
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.hlo_analysis import collective_bytes
from repro.launch.mesh import make_production_mesh
from repro.launch.shardings import batch_spec, decode_state_sharding, param_sharding
from repro.launch.steps import (
    SHAPES,
    init_train_state_specs,
    input_specs,
    make_prefill_step,
    make_serve_step,
    make_train_step,
    skip_reason,
)
from repro.models.registry import build_model
from repro.optim.adamw import AdamWConfig


def _cfg_at_depth(cfg, depth: str):
    """full → as assigned; 1|2 → that many scan units, unrolled."""
    if depth == "full":
        return cfg, False
    units = int(depth)
    group = cfg.moe_every if (cfg.family == "moe" and cfg.moe_every > 1) else 1
    kw = {"num_layers": units * group}
    if cfg.is_encoder_decoder:
        kw["encoder_layers"] = units
    return dataclasses.replace(cfg, **kw), True


def _units(cfg) -> int:
    group = cfg.moe_every if (cfg.family == "moe" and cfg.moe_every > 1) else 1
    return cfg.num_layers // group


def run_cell(arch: str, shape_name: str, mesh_kind: str, depth: str, out_dir: str,
             *, remat: bool = True, num_microbatches: int = 4) -> dict:
    full_cfg = get_config(arch)
    shape = SHAPES[shape_name]
    reason = skip_reason(full_cfg, shape)
    result = {
        "arch": arch, "shape": shape_name, "mesh": mesh_kind, "depth": depth,
        "units_total": _units(full_cfg),
        "model_params": full_cfg.param_count(),
        "model_params_active": full_cfg.active_param_count(),
    }
    if reason:
        result["skipped"] = reason
        out = pathlib.Path(out_dir)
        out.mkdir(parents=True, exist_ok=True)
        (out / f"{arch}__{shape_name}__{mesh_kind}__d{depth}.json").write_text(
            json.dumps(result, indent=2))
        print(f"[dryrun] {arch} × {shape_name} × {mesh_kind}: SKIPPED ({reason})")
        return result

    cfg, unroll = _cfg_at_depth(full_cfg, depth)
    model = build_model(cfg, unroll=unroll)
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multipod"))
    from repro.models import sharding as act_sharding

    fold = cfg.fold_model_axis_into_dp
    act_sharding.set_mesh(mesh, fold_model_axis=fold)
    bspec = batch_spec(mesh, shape.global_batch, fold_model=fold)
    from jax.sharding import NamedSharding, PartitionSpec as P

    ns = lambda spec: NamedSharding(mesh, spec)
    repl = ns(P())

    t0 = time.perf_counter()
    if shape.kind == "train":
        opt_cfg = AdamWConfig(fp32_master=cfg.fp32_master)
        params_s, opt_s = init_train_state_specs(model, opt_cfg)
        p_shard = param_sharding(params_s, mesh, mode="train", fold_model=fold)
        o_shard = {
            "step": repl,
            "m": p_shard, "v": p_shard,
            **({"master": p_shard} if opt_cfg.fp32_master else {}),
        }
        batch_s = input_specs(cfg, shape, model)
        b_shard = {k: ns(bspec if v.ndim >= 1 else P()) for k, v in batch_s.items()}
        # analysis variants keep microbatches=1 so the per-layer body is
        # fully visible to cost_analysis (inner scan bodies count once);
        # the full-depth compile uses grad accumulation for memory.
        n_micro = num_microbatches if depth == "full" else 1
        step = make_train_step(model, opt_cfg, remat=remat, num_microbatches=n_micro)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, o_shard, b_shard),
            out_shardings=(p_shard, o_shard, repl),
        )
        lowered = jitted.lower(params_s, opt_s, batch_s)
    elif shape.kind == "prefill":
        params_s = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))
        p_shard = param_sharding(params_s, mesh, mode="serve", fold_model=fold)
        batch_s = input_specs(cfg, shape, model)
        b_shard = {k: ns(bspec) for k in batch_s}
        state_s = jax.eval_shape(
            lambda p, b: model.prefill(p, b, remat=remat)[1], params_s, batch_s
        )
        s_shard = decode_state_sharding(state_s, mesh)
        step = make_prefill_step(model, remat=remat)
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, b_shard),
            out_shardings=(ns(bspec), s_shard),
        )
        lowered = jitted.lower(params_s, batch_s)
    else:  # decode
        params_s = jax.eval_shape(lambda: model.init_params(jax.random.PRNGKey(0)))
        p_shard = param_sharding(params_s, mesh, mode="serve", fold_model=fold)
        specs = input_specs(cfg, shape, model)
        state_s = specs["state"]
        s_shard = decode_state_sharding(state_s, mesh)
        tok_shard = ns(bspec)
        step = make_serve_step(model)
        # §Perf iter 2 (decode): donate the KV state — the serving loop
        # never reuses the previous step's state, and without donation the
        # in-place carry updates double-buffer the whole KV cache.
        jitted = jax.jit(
            step,
            in_shardings=(p_shard, s_shard, tok_shard),
            out_shardings=(tok_shard, s_shard),
            donate_argnums=(1,),
        )
        lowered = jitted.lower(params_s, state_s, specs["tokens"])

    t_lower = time.perf_counter() - t0
    hlo_pre = lowered.as_text()
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())

    result.update(
        {
            "lower_s": round(t_lower, 2),
            "compile_s": round(t_compile, 2),
            "flops": float(cost.get("flops", -1.0)),
            "bytes_accessed": float(cost.get("bytes accessed", -1.0)),
            "memory": {
                "argument_bytes": mem.argument_size_in_bytes,
                "output_bytes": mem.output_size_in_bytes,
                "temp_bytes": mem.temp_size_in_bytes,
                "generated_code_bytes": mem.generated_code_size_in_bytes,
            },
            "collectives": {
                "bytes_by_kind": coll.by_kind_bytes,
                "count_by_kind": coll.by_kind_count,
                "wire_bytes": coll.wire_bytes,
                "wire_bytes_bf16_adjusted": coll.wire_bytes_bf16_adjusted,
            },
            "n_devices": mesh.devices.size,
        }
    )

    out = pathlib.Path(out_dir)
    out.mkdir(parents=True, exist_ok=True)
    fname = out / f"{arch}__{shape_name}__{mesh_kind}__d{depth}.json"
    fname.write_text(json.dumps(result, indent=2))
    print(f"[dryrun] {arch} × {shape_name} × {mesh_kind} (depth={depth}): "
          f"compile {t_compile:.1f}s, flops {result['flops']:.3e}, "
          f"temp {mem.temp_size_in_bytes/2**30:.2f} GiB/dev, "
          f"wire {coll.wire_bytes/2**20:.1f} MiB")
    print("memory_analysis:", mem)
    return result


def sweep(archs, shapes, meshes, depths, out_dir, *, num_microbatches=8) -> None:
    """Run many cells in one process (saves ~20 s of startup per cell);
    each cell is fail-isolated and writes its JSON incrementally."""
    failures = []
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                for depth in depths:
                    tag = f"{arch}×{shape}×{mesh_kind}×d{depth}"
                    try:
                        run_cell(arch, shape, mesh_kind, depth, out_dir,
                                 num_microbatches=num_microbatches)
                    except Exception as e:  # noqa: BLE001 — record and continue
                        failures.append(tag)
                        err = f"{type(e).__name__}: {e}"
                        print(f"[dryrun] FAILED {tag}: {err[:500]}")
                        pathlib.Path(out_dir).mkdir(parents=True, exist_ok=True)
                        (pathlib.Path(out_dir) /
                         f"{arch}__{shape}__{mesh_kind}__d{depth}.FAILED.json"
                         ).write_text(json.dumps({"error": err[:2000], "cell": tag}))
    print(f"[dryrun] sweep done; {len(failures)} failures: {failures}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, help="arch id, or comma list, or 'all'")
    ap.add_argument("--shape", default="all", help="shape name, comma list, or 'all'")
    ap.add_argument("--mesh", default="pod", help="pod | multipod | pod,multipod")
    ap.add_argument("--depth", default="full", help="full | 1 | 2 | comma list")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--microbatches", type=int, default=8)
    args = ap.parse_args()

    from repro.configs import ASSIGNED

    archs = ASSIGNED if args.arch == "all" else args.arch.split(",")
    shapes = list(SHAPES) if args.shape == "all" else args.shape.split(",")
    meshes = args.mesh.split(",")
    depths = args.depth.split(",")
    sweep(archs, shapes, meshes, depths, args.out, num_microbatches=args.microbatches)


if __name__ == "__main__":
    main()

