"""Serving driver — disaggregated KVDirect service at CPU scale.

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-67b --smoke \
        --requests 4 --prompt-len 96 --max-new 8

Runs the REAL pipeline: prefill workers fill registered KV slabs, the
decode worker pulls with one-sided reads through the transfer engine
(coalesced), COMPLETE frees prefill memory, continuous-batching decode.
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models.registry import build_model
from repro.serving.disagg import DisaggService


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-67b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prefill-workers", type=int, default=2)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    svc = DisaggService(model, params, n_prefill=args.prefill_workers, num_blocks=256)

    rng = np.random.default_rng(0)
    t0 = time.time()
    for i in range(args.requests):
        tokens = rng.integers(0, cfg.vocab_size, args.prompt_len).astype(np.int32)
        req = svc.submit(tokens)
        out = svc.generate(req, max_new=args.max_new)
        stats = svc.engine.stats
        print(f"[serve] {req.request_id}: prefill@{req.prefill_worker} "
              f"tokens={out} "
              f"(engine: {stats.txns_submitted} txns → {stats.reads_posted} reads, "
              f"coalesce {stats.coalesce_factor:.1f}x, "
              f"{stats.bytes_moved/2**20:.1f} MiB)")
    print(f"[serve] {args.requests} requests in {time.time()-t0:.1f}s; "
          f"transfer modeled {svc.engine.stats.modeled_time_s*1e3:.2f} ms total")


if __name__ == "__main__":
    main()
