"""Serving driver — disaggregated KVDirect service at CPU scale.

    PYTHONPATH=src python -m repro.launch.serve --arch deepseek-67b --smoke \
        --requests 4 --prompt-len 96 --max-new 8

Runs the REAL pipeline: prefill workers fill registered KV slabs, the
decode worker pulls with one-sided reads through the transfer engine
(coalesced), COMPLETE frees prefill memory, continuous-batching decode.

Observability: per-request and engine counters flow through the
service's ``repro.obs.MetricsRegistry`` (printed at exit); pass
``--trace-out trace.json`` to record lifecycle spans and export the
Chrome trace-event timeline (chrome://tracing / ui.perfetto.dev).
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from repro.configs import get_config, get_smoke_config
from repro.models.registry import build_model
from repro.obs import Tracer, all_request_breakdowns, mean_fractions
from repro.serving.disagg import DisaggService


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="deepseek-67b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--requests", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=96)
    ap.add_argument("--max-new", type=int, default=8)
    ap.add_argument("--prefill-workers", type=int, default=2)
    ap.add_argument("--shared-prefix-frac", type=float, default=0.0,
                    metavar="FRAC",
                    help="give every request the same first FRAC of its "
                         "prompt (tagged prefix_id) so delta transfer "
                         "grafts it after the first pull")
    ap.add_argument("--quantize-transfer", action="store_true",
                    help="int8-quantize pulled KV on the wire "
                         "(docs/transfer.md)")
    ap.add_argument("--trace-out", default=None, metavar="PATH",
                    help="record lifecycle spans and write a Chrome "
                         "trace-event JSON timeline here")
    ap.add_argument("--autoscale", action="store_true",
                    help="enable the fleet autoscaler (docs/fleet.md): "
                         "grow/drain workers from LoadReport pressure")
    ap.add_argument("--preempt", default="none",
                    choices=("none", "swap", "sacrifice"),
                    help="memory-pressure preemption mode on decode "
                         "workers (victims resume via host-memory swap "
                         "or truncate-and-replay)")
    ap.add_argument("--victim-policy", default="lifo",
                    choices=("lifo", "fifo", "priority"),
                    help="preemption victim selection")
    ap.add_argument("--admission-budget", type=float, default=None,
                    metavar="FRAC",
                    help="reject dispatch when projected decode KV "
                         "occupancy exceeds FRAC of fleet capacity")
    ap.add_argument("--topology", default=None, metavar="SPEC",
                    help="serve on a heterogeneous cluster topology "
                         "(docs/topology.md): either PRESET[:SEED] for a "
                         "generated cluster (e.g. hetero_rack:3) or a "
                         "path to a ClusterSpec JSON file; the placement "
                         "planner assigns roles, per-pair link costs "
                         "drive routing (overrides --prefill-workers)")
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch) if args.smoke else get_config(args.arch)
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    tracer = Tracer() if args.trace_out else None
    fleet = None
    if args.autoscale or args.preempt != "none" \
            or args.admission_budget is not None:
        from repro.fleet import FleetConfig
        fleet = FleetConfig(autoscale=args.autoscale, preempt=args.preempt,
                            victim_policy=args.victim_policy,
                            admission_budget=args.admission_budget)
    if args.topology is not None:
        import os

        from repro.topo import ClusterSpec, PRESETS, generate_cluster
        if os.path.exists(args.topology):
            with open(args.topology) as f:
                spec = ClusterSpec.from_json(f.read())
        else:
            preset, _, seed = args.topology.partition(":")
            if preset not in PRESETS:
                raise SystemExit(
                    f"--topology {args.topology!r}: no such file, and not a "
                    f"PRESET[:SEED] (presets: {sorted(PRESETS)})")
            spec = generate_cluster(preset, int(seed) if seed else 0)
        svc = DisaggService.from_cluster_spec(
            model, params, spec, num_blocks=256, tracer=tracer,
            quantize_transfer=args.quantize_transfer, fleet=fleet)
        b = svc.topology
        print(f"[serve] topology {spec.name}: "
              f"prefill={[f'{w}={b.machine(w).machine_id}' for w in sorted(svc.prefills)]} "
              f"decode={[f'{w}={b.machine(w).machine_id}' for w in sorted(svc.decodes)]}")
    else:
        svc = DisaggService(model, params, n_prefill=args.prefill_workers,
                            num_blocks=256, tracer=tracer,
                            quantize_transfer=args.quantize_transfer,
                            fleet=fleet)

    rng = np.random.default_rng(0)
    prefix_len = int(args.prompt_len * args.shared_prefix_frac)
    shared = rng.integers(0, cfg.vocab_size, prefix_len).astype(np.int32)
    t0 = time.perf_counter()
    for i in range(args.requests):
        suffix = rng.integers(0, cfg.vocab_size,
                              args.prompt_len - prefix_len).astype(np.int32)
        tokens = np.concatenate([shared, suffix])
        req = svc.submit(tokens,
                         prefix_id="shared" if prefix_len else None,
                         prefix_len=prefix_len)
        out = svc.generate(req, max_new=args.max_new)
        stats = svc.engine.stats
        hm = req.metrics
        print(f"[serve] {req.request_id}: prefill@{req.prefill_worker} "
              f"tokens={out} "
              f"(engine: {stats.txns_submitted} txns → {stats.reads_posted} reads, "
              f"coalesce {stats.coalesce_factor:.1f}x, "
              f"{stats.bytes_moved/2**20:.1f} MiB; "
              f"kv pulled={hm.kv_bytes_pulled} reused={hm.kv_bytes_reused} "
              f"reuse_frac={hm.kv_reuse_frac:.2f})")
    print(f"[serve] {args.requests} requests in {time.perf_counter()-t0:.1f}s; "
          f"transfer modeled {svc.engine.stats.modeled_time_s*1e3:.2f} ms total")
    # the serve-path counters/histograms, from the one registry every
    # layer (loop, engine, router, request completion) reports into
    print("[serve] metrics:")
    for line in svc.metrics.format(
            prefixes=("requests.", "request.", "engine.", "loop.",
                      "fleet.")).splitlines():
        print(f"[serve]   {line}")
    if tracer is not None:
        breakdowns = all_request_breakdowns(tracer)
        if breakdowns:
            fr = mean_fractions(breakdowns.values())
            print("[serve] breakdown (mean fractions): "
                  + " ".join(f"{k}={v:.3f}" for k, v in fr.items()))
        tracer.export_chrome(args.trace_out)
        print(f"[serve] wrote Chrome trace ({len(tracer.spans)} spans) "
              f"to {args.trace_out}")


if __name__ == "__main__":
    main()
