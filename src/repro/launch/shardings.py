"""Sharding rules: param/state pytrees → NamedSharding.

Strategy (DESIGN.md §3):
  * 'model' = tensor parallel.  Column-parallel weights (q/k/v, gate/up,
    in_proj, embedding vocab) shard their OUT dim on 'model'; row-parallel
    weights (o, down, out_proj) shard their IN dim — the classic
    Megatron pairing that needs one collective per block, not two.
  * 'data' = FSDP in training: every ≥2-D param additionally shards a
    non-'model' dim over 'data' (ZeRO-3; optimizer state inherits the
    sharding because its pytree mirrors params).  In serving, params
    replicate over 'data' (weights-stationary decode — no per-step
    all-gathers).
  * MoE expert stacks [E, in, out] shard E over 'data' (EP) and in/out
    over 'model' by the same column/row rule.
  * 'pod' (multi-pod mesh) is pure DP: params NEVER shard over 'pod', so
    no parameter collective crosses DCN; only gradient all-reduce does.
  * Divisibility is always checked: a dim that doesn't divide stays
    unsharded (e.g. hymba's 25 heads; its head_dim shards instead).
"""
from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

__all__ = [
    "param_sharding", "batch_spec", "decode_state_sharding", "logical_spec",
]

# leaf names (last path component up the tree) → role
_COLUMN = {"q", "k", "v", "gate", "up", "in_proj"}
_ROW = {"o", "down", "out_proj"}


def _path_names(path) -> list[str]:
    out = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            out.append(str(p.key))
        elif isinstance(p, jax.tree_util.GetAttrKey):
            out.append(p.name)
    return out


def _fits(dim: int, mesh: Mesh, axis) -> bool:
    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    n = 1
    for a in axes:
        if a not in mesh.shape:
            return False
        n *= mesh.shape[a]
    return dim % n == 0 and dim >= n


def _assign(shape, mesh, prefs):
    """prefs: ordered (dim_index, axis_name_or_tuple).  First fit wins per
    axis and per dim; a tuple shards one dim over several mesh axes
    (e.g. batch over ('pod','data'))."""
    spec: list[Any] = [None] * len(shape)
    used: set[str] = set()
    for dim, axis in prefs:
        axes = (axis,) if isinstance(axis, str) else tuple(axis)
        if used & set(axes) or dim >= len(shape) or spec[dim] is not None:
            continue
        if _fits(shape[dim], mesh, axes):
            spec[dim] = axis if isinstance(axis, str) else tuple(axes)
            used.update(axes)
    return P(*spec)


def logical_spec(path_names: list[str], shape: tuple[int, ...], mesh: Mesh,
                 *, mode: str, fold_model: bool = False) -> P:
    """Sharding spec for one parameter leaf.

    Per-layer params live under a "layers"/"enc_layers"/"dec_layers"
    stack, so their leaves carry a LEADING layer dim ([L, in, out]) — all
    dim indices below shift by that lead.

    ``fold_model``: DP+EP deployment — no tensor parallelism; weights are
    pure-FSDP over BOTH axes in training and replicated in serving.
    """
    name = path_names[-1] if path_names else ""
    parent = path_names[-2] if len(path_names) >= 2 else ""
    in_moe = "moe" in path_names and "shared" not in path_names
    fsdp = ("data",) if mode == "train" else ()
    lead = 1 if any(n.endswith("layers") for n in path_names) else 0

    if fold_model:
        # MoE expert stacks keep EP over 'data' + FSDP over 'model'
        if in_moe and name in ("gate", "up"):
            return _assign(shape, mesh, [(lead, "data"), (lead + 2, "model")])
        if in_moe and name == "down":
            return _assign(shape, mesh, [(lead, "data"), (lead + 1, "model")])
        if mode != "train":
            return P(*([None] * len(shape)))  # replicated weights (no TP)
        # non-MoE weights: FSDP over 'data' only.  (Adding 'model' FSDP on
        # the d_model dim trips an XLA SPMD verifier bug under
        # microbatch-scan × multipod — "slice dim 1536 > 96"; these
        # weights are tiny for fold-deployed archs, so 16-way sharding of
        # the fp32 optimizer state suffices.)
        if name == "table":
            return _assign(shape, mesh, [(0, "data")])
        if name == "w" and len(shape) == 2 + lead:
            return _assign(shape, mesh, [(lead, "data")])
        return P(*([None] * len(shape)))

    # embedding / lm head tables [V, d]: vocab over model
    if name == "table":
        prefs = [(0, "model")] + [(1, a) for a in fsdp]
        return _assign(shape, mesh, prefs)
    if name in ("meta", "dec_pos"):
        return _assign(shape, mesh, [(0, a) for a in fsdp])

    # MoE expert stacks [L?, E, in, out]
    if in_moe and name in ("gate", "up"):
        return _assign(shape, mesh, [(lead, "data"), (lead + 2, "model")])
    if in_moe and name == "down":
        return _assign(shape, mesh, [(lead, "data"), (lead + 1, "model")])
    if in_moe and parent == "router":
        return P(*([None] * len(shape)))

    # dense weights [L?, in, out]: the actual leaf is {"w": ..., "b": ...}
    if name == "w" and len(shape) == 2 + lead:
        if parent in _ROW:
            prefs = [(lead, "model")] + [(lead + 1, a) for a in fsdp]
        else:  # _COLUMN and anything unclassified defaults to column
            prefs = [(lead + 1, "model")] + [(lead, a) for a in fsdp]
        return _assign(shape, mesh, prefs)
    if name == "b" and len(shape) == 1 + lead:
        if parent in _COLUMN:
            return _assign(shape, mesh, [(lead, "model")])
        return P(*([None] * len(shape)))

    # conv kernels, norms, scalars, ssm vectors: replicate
    return P(*([None] * len(shape)))


def param_sharding(params_shape: Any, mesh: Mesh, *, mode: str,
                   fold_model: bool = False) -> Any:
    """params pytree of ShapeDtypeStruct/arrays → pytree of NamedSharding."""

    def leaf(path, x):
        spec = logical_spec(_path_names(path), tuple(x.shape), mesh,
                            mode=mode, fold_model=fold_model)
        return NamedSharding(mesh, spec)

    return jax.tree_util.tree_map_with_path(leaf, params_shape)


def batch_spec(mesh: Mesh, batch: int | None = None, *, fold_model: bool = False) -> P:
    """Batch dim over the largest prefix of the DP axes that divides it
    (long_500k has batch 1 → replicated).  With fold_model, 'model'
    joins the DP axes."""
    axes = [a for a in ("pod", "data") if a in mesh.shape]
    if fold_model and "model" in mesh.shape:
        axes.append("model")
    while axes:
        n = 1
        for a in axes:
            n *= mesh.shape[a]
        if batch is None or (batch % n == 0 and batch >= n):
            return P(tuple(axes))
        axes = axes[:-1]
    return P()


def _dp_axes(mesh: Mesh):
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def decode_state_sharding(state_shape: Any, mesh: Mesh) -> Any:
    """DecodeState/EncDecState of ShapeDtypeStructs → NamedShardings.

    Pages/states shard batch over (pod, data) and heads (or head_dim when
    heads don't divide) over 'model'.
    """
    dp = _dp_axes(mesh)

    def leaf(path, x):
        names = _path_names(path)
        name = names[-1] if names else ""
        shape = tuple(x.shape)
        # batch shards over the DP axes JOINTLY (tuple) with per-axis
        # prefix fallback for small batches
        batch_prefs = lambda d: [(d, dp[:k]) for k in range(len(dp), 0, -1)]
        if name in ("k_pages", "v_pages"):
            # [L, b, per_seq, bs, g, hd] — per_seq over 'model' is the
            # sequence-parallel flash-decoding layout (attention.
            # paged_decode_with_write); 32K-ctx KV only fits sharded on
            # BOTH the DP axes and the model axis.
            prefs = batch_prefs(1) + [(2, "model")]
        elif name == "block_tables":
            prefs = batch_prefs(0) + [(1, "model")]
        elif name in ("ring_k", "ring_v", "meta_k", "meta_v", "cross_k", "cross_v"):
            # [L, b, slots, g, hd] — small (window/meta/enc): replicate TP
            prefs = batch_prefs(1)
        elif name == "ssd_state":
            # [L, b, nh, hd, ns]
            prefs = batch_prefs(1) + [(2, "model"), (3, "model")]
        elif name == "conv_state":
            # [L, b, k-1, c]
            prefs = batch_prefs(1) + [(3, "model")]
        elif name in ("ring_pos", "context_lens"):
            prefs = batch_prefs(0)
        else:
            prefs = []
        return NamedSharding(mesh, _assign(shape, mesh, prefs))

    return jax.tree_util.tree_map_with_path(leaf, state_shape)
