"""``repro.fleet`` — elastic control plane above the request router.

Three cooperating pieces, one config (docs/fleet.md):

  * ``Autoscaler``          — grows/shrinks the prefill and decode fleets
    from the same ``LoadReport`` stream the router places on; shrink is
    drain-then-retire through the router's draining set and the
    scheduler's graceful-leave membership path;
  * ``MemoryGovernor``      — memory-pressure preemption: a decode worker
    near its KV budget swaps a victim to the ``HostSwapPool`` (resumes
    token-identically) or sacrifices it to truncate-and-replay, instead
    of letting queued work park;
  * ``AdmissionController`` — rejects (``KVBudgetExceeded``, typed, on
    the handle) or defers dispatch when projected decode-fleet KV
    occupancy exceeds a budget.

``FleetController`` composes them per service; ``DisaggService`` builds
one when given a ``FleetConfig`` and ``ServeLoop.tick()`` steps it.  The
same policy space (swap vs sacrifice × thresholds × victim order) is
mirrored in ``repro.sim.ClusterSim`` so policy choices can be made in
simulation and carried to the real substrate (benchmarks/fig_elastic.py
checks the ranking agrees).
"""
from repro.fleet.admission import (
    AdmissionController,
    AdmissionDeferred,
    KVBudgetExceeded,
)
from repro.fleet.autoscale import Autoscaler
from repro.fleet.config import FleetConfig
from repro.fleet.controller import FleetController
from repro.fleet.hostmem import HostSwapPool
from repro.fleet.preempt import MemoryGovernor

__all__ = [
    "AdmissionController",
    "AdmissionDeferred",
    "Autoscaler",
    "FleetConfig",
    "FleetController",
    "HostSwapPool",
    "KVBudgetExceeded",
    "MemoryGovernor",
]
