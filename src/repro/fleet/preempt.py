"""Memory-pressure preemption — the decode-side governor.

Without it, a decode worker whose KV pool fills simply stops admitting:
queued requests wait (or park) behind residents that may run for
hundreds more steps.  The governor trades resident progress for queue
progress when — and only when — both hold:

  * the worker's pool occupancy is at or above ``preempt_high``, and
  * its oldest KV_QUEUED waiter cannot fit in free + evictable blocks.

Then a victim is chosen among the residents (``victim_policy``:
LIFO protects long-running work, FIFO protects fresh arrivals,
priority sheds the lowest SLO class first) and either

  * **swapped** — its full KV moves to the ``HostSwapPool`` and the
    token stream pauses; the governor restores it (oldest-swapped
    first, original worker preferred) once a worker has room AND no
    waiters of its own, and the stream resumes token-identically; or
  * **sacrificed** — its decode KV is dropped and the request replays
    through the serving layer's truncate-and-replay restart (cheaper
    than swap for short contexts; the KV is re-pulled on replay).

Anti-thrash: a request is preempted at most ``max_preemptions`` times,
and never again before it has produced at least one new token since its
last preemption — an oscillating pool degrades to park behavior instead
of livelocking.

The governor is policy only: all mechanism (page copies, tracer phases,
handle metrics, restart) lives in ``DisaggService.swap_out_request`` /
``swap_in_request`` / ``sacrifice_request``.
"""
from __future__ import annotations

from repro.fleet.config import DEFAULT_CLASS_RANK
from repro.serving.request import RequestState

__all__ = ["MemoryGovernor"]


class MemoryGovernor:
    def __init__(self, cfg, pool, *, metrics=None) -> None:
        self.cfg = cfg
        self.pool = pool  # HostSwapPool (swap mode; unused for sacrifice)
        self.metrics = metrics
        self._preemptions: dict[str, int] = {}     # rid -> times preempted
        self._decoded_at_preempt: dict[str, int] = {}

    # ------------------------------------------------------------- driver
    def step(self, svc, *, draining: set | frozenset = frozenset()) -> dict[str, int]:
        """One governor pass over the service: purge stale swap entries,
        resume what fits, preempt where pressure demands.  Returns action
        counts for the tick report."""
        self._purge(svc)
        counts = {"swapped_in": self._resume(svc, draining)}
        counts.update(self._relieve(svc, draining))
        return counts

    # -------------------------------------------------------------- purge
    def _purge(self, svc) -> None:
        """Drop swap entries whose request left the swapped state by any
        other path — finished, failed over (decode-worker death restarts
        it from prefill), or rejected.  An entry is live only while its
        request is still pending, still DECODING, and resident nowhere."""
        for rid in self.pool.ids():
            entry = svc.pending.get(rid)
            stale = (entry is None
                     or entry[0].state is not RequestState.DECODING
                     or any(rid in dw.resident or rid in dw.inflight
                            for dw in svc.decodes.values()))
            if stale:
                self.pool.pop(rid)
        for rid in list(self._preemptions):
            if rid not in svc.pending:
                self._preemptions.pop(rid, None)
                self._decoded_at_preempt.pop(rid, None)

    # ------------------------------------------------------------- resume
    def _resume(self, svc, draining) -> int:
        """Swap back every entry that fits somewhere, oldest-swapped
        first.  A worker with KV_QUEUED waiters of its own is skipped —
        resuming there would re-trigger the very pressure that caused
        the swap.  The original worker is preferred (its retained
        prefixes may still be warm); any other non-draining worker is
        legal (SwappedKV is worker-agnostic)."""
        resumed = 0
        for rid in self.pool.ids():
            entry = self.pool.get(rid)
            home = entry.req.decode_worker
            order = sorted(
                (wid for wid in svc.decodes if wid not in draining),
                key=lambda w: (w != home, svc.decodes[w].occupancy))
            for wid in order:
                if self._waiters(svc, wid):
                    continue
                if svc.swap_in_request(rid, wid):
                    resumed += 1
                    break
        return resumed

    # ------------------------------------------------------------ relieve
    @staticmethod
    def _waiters(svc, wid: str) -> list:
        """KV_QUEUED requests assigned to ``wid``, oldest first."""
        w = [req for req, _ in svc.pending.values()
             if req.state is RequestState.KV_QUEUED and req.decode_worker == wid]
        w.sort(key=lambda r: r.arrival_s)
        return w

    def _relieve(self, svc, draining) -> dict[str, int]:
        counts = {"swapped_out": 0, "sacrificed": 0}
        for wid, dw in list(svc.decodes.items()):
            if wid in draining:
                continue  # its waiters are being reassigned away
            waiters = self._waiters(svc, wid)
            if not waiters or dw.occupancy < self.cfg.preempt_high:
                continue
            head = waiters[0]
            need = -(-head.prompt_len // dw.block_size)
            while dw.pool.num_free + dw.evictable_blocks < need:
                victim = self._pick_victim(svc, dw)
                if victim is None:
                    break  # nobody eligible: degrade to park behavior
                decoded = self._decoded(svc, victim)
                if self.cfg.preempt == "swap":
                    if not svc.swap_out_request(victim):
                        break  # host pool full: park behavior
                    counts["swapped_out"] += 1
                else:
                    svc.sacrifice_request(victim)
                    counts["sacrificed"] += 1
                self._preemptions[victim] = self._preemptions.get(victim, 0) + 1
                self._decoded_at_preempt[victim] = decoded
        return counts

    @staticmethod
    def _decoded(svc, rid: str) -> int:
        h = svc.handles.get(rid)
        return len(h.tokens) if h is not None else 0

    def _pick_victim(self, svc, dw) -> str | None:
        """Choose among this worker's residents per ``victim_policy``,
        skipping anyone over the preemption cap or without progress since
        their last preemption (anti-thrash)."""
        eligible = []
        for i, rid in enumerate(dw.resident):  # insertion order = admission order
            if self._preemptions.get(rid, 0) >= self.cfg.max_preemptions:
                continue
            if rid in self._decoded_at_preempt and \
                    self._decoded(svc, rid) <= self._decoded_at_preempt[rid]:
                continue
            eligible.append((i, rid))
        if not eligible:
            return None
        policy = self.cfg.victim_policy
        if policy == "fifo":
            return eligible[0][1]
        if policy == "priority":
            def rank(item):
                req = dw.resident[item[1]].req
                # higher class rank (batch) preempted first; newest
                # breaks ties so interactive work keeps its momentum
                return (DEFAULT_CLASS_RANK.get(req.slo_class, 1), item[0])
            return max(eligible, key=rank)[1]
        return eligible[-1][1]  # lifo
