"""Load-driven fleet sizing (P/D-Serve-style dynamic P/D ratio).

The autoscaler watches the same ``LoadReport`` stream the router places
on (no second telemetry channel) and emits *decisions*, not side
effects: ``plan()`` returns a list of actions —

    ("add", role)            grow the role by one hot-added worker
    ("drain", role, wid)     stop routing to ``wid``; retire when empty

— which ``FleetController`` applies through the existing membership path
(``DisaggService.add_*_worker`` / router draining / scheduler removal).
Keeping the policy pure makes it trivially testable and lets the
discrete-event simulator run the IDENTICAL decision code against
synthetic load.

Signals:
  * decode pressure   — mean decode ``load_fraction`` (in-use + queued
    demand over capacity: rising backlog shows up before pools fill);
  * prefill pressure  — mean prefill ``load_fraction``, plus the
    dispatch backlog (QUEUED_PREFILL requests nobody routed yet) spread
    over the prefill fleet.

Hysteresis: a role must hold pressure above ``scale_up`` (or below
``scale_down``) for ``patience`` consecutive evaluations before any
action fires, and a role with a drain still in progress is left alone —
otherwise a bursty arrival process whipsaws the fleet.

Equal-peak-hardware mode (``total_cap``): when the fleet is at its cap,
growing one role first requires draining the other — the autoscaler
shifts the P/D *ratio* instead of adding hardware, which is the regime
benchmarks/fig_elastic.py scores (static vs autoscaled at the same peak
worker count).
"""
from __future__ import annotations

__all__ = ["Autoscaler"]


def _mean_load(reports) -> float:
    fracs = [rep.load_fraction for rep in reports.values() if rep is not None]
    return sum(fracs) / len(fracs) if fracs else 0.0


class Autoscaler:
    def __init__(self, cfg, *, metrics=None) -> None:
        self.cfg = cfg
        self.metrics = metrics
        # consecutive over-/under-pressure counts per role
        self._hot = {"prefill": 0, "decode": 0}
        self._cold = {"prefill": 0, "decode": 0}

    # ------------------------------------------------------------ signals
    def pressures(self, prefill_reports, decode_reports,
                  dispatch_backlog: int) -> dict[str, float]:
        n_p = max(len(prefill_reports), 1)
        backlog_frac = dispatch_backlog / n_p  # queued requests per worker
        return {
            "prefill": _mean_load(prefill_reports) + backlog_frac,
            "decode": _mean_load(decode_reports),
        }

    # ------------------------------------------------------------- limits
    def _bounds(self, role: str) -> tuple[int, int]:
        c = self.cfg
        return ((c.min_prefill, c.max_prefill) if role == "prefill"
                else (c.min_decode, c.max_decode))

    # --------------------------------------------------------------- plan
    def plan(self, prefill_reports, decode_reports, *,
             dispatch_backlog: int = 0,
             draining: dict[str, str] | None = None) -> list[tuple]:
        """One evaluation: update hysteresis counters, return actions.

        ``draining`` maps worker_id -> role for drains already in
        flight; a role that is mid-drain neither grows nor shrinks
        (its capacity is already changing).
        """
        cfg = self.cfg
        draining = draining or {}
        drain_roles = set(draining.values())
        sizes = {"prefill": len(prefill_reports), "decode": len(decode_reports)}
        pressures = self.pressures(prefill_reports, decode_reports,
                                   dispatch_backlog)
        actions: list[tuple] = []
        for role in ("prefill", "decode"):
            p = pressures[role]
            self._hot[role] = self._hot[role] + 1 if p >= cfg.scale_up else 0
            self._cold[role] = self._cold[role] + 1 if p <= cfg.scale_down else 0
            if role in drain_roles:
                continue  # capacity already in motion
            lo, hi = self._bounds(role)
            other = "decode" if role == "prefill" else "prefill"
            if self._hot[role] >= cfg.patience and sizes[role] < hi:
                total = sizes["prefill"] + sizes["decode"]
                if cfg.total_cap is not None and total >= cfg.total_cap:
                    # at peak hardware: shift the ratio — drain the
                    # other role's least useful worker to make room
                    o_lo, _ = self._bounds(other)
                    if sizes[other] > o_lo and other not in drain_roles:
                        victim = self._least_loaded(
                            prefill_reports if other == "prefill"
                            else decode_reports, draining)
                        if victim is not None:
                            actions.append(("drain", other, victim))
                            actions.append(("add", role))
                            self._hot[role] = 0
                else:
                    actions.append(("add", role))
                    self._hot[role] = 0
            elif self._cold[role] >= cfg.patience and sizes[role] > lo:
                reports = (prefill_reports if role == "prefill"
                           else decode_reports)
                victim = self._least_loaded(reports, draining)
                if victim is not None:
                    actions.append(("drain", role, victim))
                    self._cold[role] = 0
        if actions and self.metrics is not None:
            for act in actions:
                self.metrics.inc(f"fleet.autoscale_{act[0]}_{act[1]}")
        return actions

    @staticmethod
    def _least_loaded(reports, draining) -> str | None:
        """Drain victim: the least-loaded worker not already draining —
        fewest residents to wait out, least routed traffic to shed."""
        candidates = [(rep.load_fraction, wid)
                      for wid, rep in reports.items()
                      if rep is not None and wid not in draining]
        if not candidates:
            return None
        return min(candidates)[1]
