"""Host-memory swap pool for memory-pressure preemption.

A decode worker near its KV-occupancy budget can ``swap_out`` a victim:
the victim's full KV pages are copied here (host DRAM standing in for
the GPU/TPU host side, exactly the paper's CPU-memory pool role) and its
slab blocks free immediately.  The entry is opaque to the pool — it
stores whatever the worker hands it (``serving.engine.SwappedKV``) plus
a byte count against the budget — so this module needs no model or
serving imports.

Insertion order is preserved: the governor resumes victims FIFO, so the
longest-swapped request gets the first shot at returning capacity.
"""
from __future__ import annotations

__all__ = ["HostSwapPool"]


class HostSwapPool:
    def __init__(self, capacity_bytes: int | None = None) -> None:
        self.capacity_bytes = capacity_bytes
        self._entries: dict[str, object] = {}  # rid -> entry (FIFO order)
        self._nbytes: dict[str, int] = {}
        self.used_bytes = 0
        self.peak_bytes = 0

    def __len__(self) -> int:
        return len(self._entries)

    def __contains__(self, request_id: str) -> bool:
        return request_id in self._entries

    def ids(self) -> list[str]:
        """Swapped request ids, oldest first (resume order)."""
        return list(self._entries)

    def put(self, request_id: str, entry, nbytes: int) -> bool:
        """Park an entry; False (and no mutation) when the byte budget
        can't hold it — the caller falls back to park behavior."""
        if request_id in self._entries:
            raise KeyError(f"{request_id} already swapped")
        if self.capacity_bytes is not None and \
                self.used_bytes + nbytes > self.capacity_bytes:
            return False
        self._entries[request_id] = entry
        self._nbytes[request_id] = nbytes
        self.used_bytes += nbytes
        self.peak_bytes = max(self.peak_bytes, self.used_bytes)
        return True

    def get(self, request_id: str):
        return self._entries.get(request_id)

    def pop(self, request_id: str):
        """Remove and return an entry (None if absent)."""
        entry = self._entries.pop(request_id, None)
        if entry is not None:
            self.used_bytes -= self._nbytes.pop(request_id)
        return entry
