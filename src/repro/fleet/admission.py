"""KV-budget admission control.

The SLO policy (``sched.policies.SLOAwarePolicy``) rejects on projected
*latency*; this controller rejects on projected *memory*: a request is
only dispatched while the decode fleet's projected KV occupancy —
blocks in use, minus lazily-reclaimable prefix cache, plus everything
already queued, plus this request's own footprint — stays under a
budget fraction.  Past the budget a new request would only deepen the
queue the memory governor then has to preempt its way out of, so the
cheapest intervention point is the front door.

``KVBudgetExceeded`` subclasses ``sched.AdmissionRejected`` so every
existing "rejected at dispatch" code path (handle ``error``, queued
rejection, eager-submit raise) handles it unchanged; ``AdmissionDeferred``
is the soft variant — the serving loop leaves the request QUEUED_PREFILL
and retries next tick.
"""
from __future__ import annotations

from repro.sched import AdmissionRejected

__all__ = ["KVBudgetExceeded", "AdmissionDeferred", "AdmissionController"]


class KVBudgetExceeded(AdmissionRejected):
    """Typed rejection: projected decode-fleet KV occupancy over budget.

    Surfaces on the ``RequestHandle`` (FAILED, ``error`` set) for queued
    dispatch, or raises from ``submit()`` for eager dispatch — exactly
    the SLO rejection's contract.
    """

    def __init__(self, request_id: str, projected_frac: float,
                 budget_frac: float) -> None:
        # Skip AdmissionRejected.__init__ (its message is TTFT-shaped).
        RuntimeError.__init__(
            self,
            f"{request_id}: projected decode KV occupancy "
            f"{projected_frac:.2f} exceeds admission budget "
            f"{budget_frac:.2f}")
        self.request_id = request_id
        self.projected_frac = projected_frac
        self.budget_frac = budget_frac


class AdmissionDeferred(RuntimeError):
    """Soft admission verdict: not now, try again next tick.  Never
    surfaces to the caller — the serving loop swallows it and leaves the
    request queued."""

    def __init__(self, request_id: str, projected_frac: float,
                 budget_frac: float) -> None:
        super().__init__(
            f"{request_id}: deferred at projected occupancy "
            f"{projected_frac:.2f} (budget {budget_frac:.2f})")
        self.request_id = request_id
        self.projected_frac = projected_frac
        self.budget_frac = budget_frac


class AdmissionController:
    def __init__(self, budget_frac: float, *, mode: str = "reject",
                 metrics=None) -> None:
        if not 0.0 < budget_frac <= 1.0:
            raise ValueError(f"budget_frac must be in (0, 1], got {budget_frac}")
        if mode not in ("reject", "defer"):
            raise ValueError(f"mode must be reject|defer, got {mode!r}")
        self.budget_frac = budget_frac
        self.mode = mode
        self.metrics = metrics

    def projected_fraction(self, reports, need_blocks: int) -> float:
        """Decode-fleet occupancy if ``need_blocks`` more were admitted.

        ``reports`` is the decode-role LoadReport map; evictable prefix
        blocks count as spendable (the worker reclaims them on demand),
        queued-but-unpulled footprint counts as committed.
        """
        total = used = 0
        for rep in reports.values():
            if rep is None:
                continue
            total += rep.total_blocks
            used += (rep.total_blocks - rep.free_blocks
                     - rep.evictable_blocks + rep.queued_blocks)
        if total <= 0:
            return 1.0  # no capacity visible: everything is over budget
        return (used + need_blocks) / total

    def check(self, reports, need_blocks: int, request_id: str) -> None:
        """Raise ``KVBudgetExceeded`` / ``AdmissionDeferred`` when the
        projection lands over budget; silently pass otherwise."""
        projected = self.projected_fraction(reports, need_blocks)
        if projected <= self.budget_frac:
            return
        if self.metrics is not None:
            self.metrics.inc("fleet.admission_rejected"
                             if self.mode == "reject"
                             else "fleet.admission_deferred")
        cls = KVBudgetExceeded if self.mode == "reject" else AdmissionDeferred
        raise cls(request_id, projected, self.budget_frac)
