"""``FleetController`` — the control plane above the router.

One controller per ``DisaggService`` (built when the service is given a
``FleetConfig``), stepped once per serving-loop tick between retirement
and admission — so capacity it frees (a resumed swap, a finished drain,
a hot-added worker) is usable for admission in the SAME tick.

It composes the three fleet pieces and owns the only mutable fleet
state, the drain ledger:

  * the ``MemoryGovernor`` (swap / sacrifice under KV pressure);
  * the ``Autoscaler`` (pure planner) — this controller APPLIES its
    actions through the paths that already exist: hot-add goes through
    ``DisaggService.add_*_worker`` (scheduler membership broadcast →
    connection tables), drain marks the worker in the router
    (``mark_draining``: no new routes) and reassigns its queued work,
    and retirement happens only once the worker is empty, via
    ``ClusterScheduler.remove_worker`` — the same graceful-leave event
    every other teardown uses.  A drained worker that dies mid-drain
    needs nothing special: hedged adoption and ``retry_parked`` already
    cover it, and the drain ledger entry is simply cleaned up;
  * the ``AdmissionController``, consulted by ``DisaggService._dispatch``
    (the controller just builds and exposes it).
"""
from __future__ import annotations

from repro.fleet.admission import AdmissionController
from repro.fleet.autoscale import Autoscaler
from repro.fleet.hostmem import HostSwapPool
from repro.fleet.preempt import MemoryGovernor
from repro.serving.request import RequestState

__all__ = ["FleetController"]


class FleetController:
    def __init__(self, service, cfg) -> None:
        self.service = service
        self.cfg = cfg
        m = service.metrics
        self.swap_pool = HostSwapPool(cfg.swap_pool_bytes)
        self.governor = (MemoryGovernor(cfg, self.swap_pool, metrics=m)
                         if cfg.preempt != "none" else None)
        self.autoscaler = Autoscaler(cfg, metrics=m) if cfg.autoscale else None
        self.admission = (AdmissionController(cfg.admission_budget,
                                              mode=cfg.admission_mode, metrics=m)
                          if cfg.admission_budget is not None else None)
        self.draining: dict[str, str] = {}  # worker_id -> role

    # --------------------------------------------------------------- step
    def step(self, now: float | None = None, *,
             dispatch_backlog: int | None = None) -> dict[str, int]:
        """One control-plane pass; returns nonzero action counts (the
        serving loop folds them into its ``TickReport.fleet``).

        ``dispatch_backlog`` is the QUEUED_PREFILL count snapshotted at
        tick start — the loop drains the queue before this step runs,
        so recounting here would always read zero.
        """
        svc = self.service
        if now is not None:
            svc.clock = max(svc.clock, now)
        svc._report_loads()
        counts: dict[str, int] = {}
        if self.governor is not None:
            for k, n in self.governor.step(
                    svc, draining=set(self.draining)).items():
                counts[k] = counts.get(k, 0) + n
        if self.autoscaler is not None:
            self._autoscale(counts, dispatch_backlog)
        self._advance_drains(counts)
        m = svc.metrics
        m.set_gauge("fleet.prefill_workers", len(svc.prefills))
        m.set_gauge("fleet.decode_workers", len(svc.decodes))
        m.set_gauge("fleet.draining", len(self.draining))
        m.set_gauge("fleet.swapped", len(self.swap_pool))
        return {k: n for k, n in counts.items() if n}

    # ---------------------------------------------------------- autoscale
    def _autoscale(self, counts: dict[str, int],
                   dispatch_backlog: int | None = None) -> None:
        svc = self.service
        p_reports = {wid: svc.scheduler.load(wid) for wid in svc.prefills}
        d_reports = {wid: svc.scheduler.load(wid) for wid in svc.decodes}
        backlog = dispatch_backlog
        if backlog is None:
            backlog = sum(1 for req, _ in svc.pending.values()
                          if req.state is RequestState.QUEUED_PREFILL)
        actions = self.autoscaler.plan(p_reports, d_reports,
                                       dispatch_backlog=backlog,
                                       draining=self.draining)
        for act in actions:
            if act[0] == "add":
                if self._add(act[1]) is not None:
                    counts["added"] = counts.get("added", 0) + 1
            else:  # ("drain", role, wid)
                self._drain(act[1], act[2])
                counts["draining"] = counts.get("draining", 0) + 1

    def _add(self, role: str) -> str | None:
        svc = self.service
        if svc.topology is not None and not svc.topology.has_spare(role):
            # topology-bound fleet: every machine in the ClusterSpec
            # already holds a role — there is nothing to hot-add onto.
            # Skip (with a metric) rather than conjure hardware.
            svc.metrics.inc("fleet.autoscale_no_spare")
            return None
        if role == "prefill":
            wid = svc.add_prefill_worker(num_blocks=self.cfg.worker_blocks)
        else:
            wid = svc.add_decode_worker(num_blocks=self.cfg.worker_blocks)
        svc.metrics.inc("fleet.workers_added")
        svc.tracer.instant("fleet.add", track="loop", worker=wid, role=role)
        return wid

    def _drain(self, role: str, wid: str) -> None:
        """Begin a drain: no NEW routes to the worker (router draining
        set), queued decode work moves to siblings; residents run to
        completion (or get swapped off by the governor) before
        ``_advance_drains`` retires it."""
        svc = self.service
        svc.router.mark_draining(wid)
        self.draining[wid] = role
        if role == "decode":
            svc.reassign_queued_off(wid)
        svc.metrics.inc("fleet.drains_started")
        svc.tracer.instant("fleet.drain", track="loop", worker=wid, role=role)

    # -------------------------------------------------------------- drain
    def _decode_busy(self, wid: str) -> bool:
        svc = self.service
        dw = svc.decodes.get(wid)
        if dw is None:
            return False  # died mid-drain: failover already moved its work
        if dw.resident or dw.inflight:
            return True
        # KV_QUEUED stragglers still assigned here (reassignment found no
        # room): the drain waits — retiring now would park them instead
        return any(req.decode_worker == wid
                   and req.state is RequestState.KV_QUEUED
                   for req, _ in svc.pending.values())

    def _prefill_busy(self, wid: str) -> bool:
        pw = self.service.prefills.get(wid)
        # in_use covers parked request KV awaiting pull AND live hedge
        # twins — both must leave before the slab (and its MR) goes away
        return pw is not None and pw.pool.stats.in_use > 0

    def _advance_drains(self, counts: dict[str, int]) -> None:
        svc = self.service
        for wid, role in list(self.draining.items()):
            alive = wid in (svc.decodes if role == "decode" else svc.prefills)
            busy = (self._decode_busy(wid) if role == "decode"
                    else self._prefill_busy(wid))
            if busy:
                continue
            if alive:
                # graceful leave: same membership event as any teardown
                svc.scheduler.remove_worker(wid)
                if svc.topology is not None:
                    svc.topology.release_worker(wid)  # machine -> spare pool
                svc.metrics.inc("fleet.workers_retired")
                svc.tracer.instant("fleet.retire", track="loop",
                                   worker=wid, role=role)
                counts["retired"] = counts.get("retired", 0) + 1
            svc.router.clear_draining(wid)
            del self.draining[wid]
