"""Fleet control-plane configuration.

One frozen config covers the three cooperating pieces of ``repro.fleet``
(docs/fleet.md): the autoscaler's thresholds and bounds, the memory-
pressure preemption mode and victim policy, and the admission-control
budget.  Everything defaults OFF — a ``DisaggService`` without a
``FleetConfig`` behaves exactly as before.
"""
from __future__ import annotations

import dataclasses

__all__ = ["FleetConfig"]

# Victim ranking for victim_policy="priority": higher rank = preempted
# first.  Matches the SLO classes sched.policies ships by default.
DEFAULT_CLASS_RANK = {"interactive": 0, "standard": 1, "batch": 2}


@dataclasses.dataclass(frozen=True)
class FleetConfig:
    # ---------------------------------------------------------- autoscaler
    autoscale: bool = False
    min_prefill: int = 1
    max_prefill: int = 4
    min_decode: int = 1
    max_decode: int = 4
    # Equal-peak-hardware mode (P/D-Serve's dynamic ratio adjustment):
    # when set, prefill + decode never exceeds this total — growing one
    # role drains the other, shifting the P/D split instead of adding
    # hardware.  None = roles grow independently up to their maxima.
    total_cap: int | None = None
    # Pressure thresholds (see Autoscaler for the signals): grow a role
    # when its pressure stays above scale_up for `patience` consecutive
    # evaluations; drain its least-loaded worker when pressure stays
    # below scale_down (and the role is above its minimum).
    scale_up: float = 0.85
    scale_down: float = 0.25
    patience: int = 2
    # KV pool size for hot-added workers (blocks).
    worker_blocks: int = 256

    # ------------------------------------------------------- preemption
    # "none" — a full decode pool parks/queues (the pre-fleet behavior);
    # "swap" — copy the victim's KV pages to the host pool, restore on
    #          resume (token stream pauses, never truncates);
    # "sacrifice" — drop the victim's decode KV and replay it through
    #          PR 5's truncate-and-replay (cheaper than swap for short
    #          contexts, re-pulls the KV on replay).
    preempt: str = "none"
    # Victim selection among residents: "lifo" (newest first — protects
    # long-running work), "fifo" (oldest first — protects fresh
    # arrivals), "priority" (lowest-priority SLO class first).
    victim_policy: str = "lifo"
    # Occupancy watermark: preemption only fires while the worker's pool
    # is at least this full AND a queued request can't be admitted.
    # Lower = aggressive (preempts early), higher = conservative.
    preempt_high: float = 0.92
    # Host swap pool byte budget (None = unbounded).  A swap that would
    # exceed it is refused and the waiter keeps queueing (park behavior).
    swap_pool_bytes: int | None = None
    # A request is preempted at most this many times — an oscillating
    # governor (victim re-admits, gets preempted again, ...) must
    # terminate at park behavior rather than livelock.
    max_preemptions: int = 2

    # -------------------------------------------------------- admission
    # Reject/defer dispatch when the decode fleet's projected KV
    # occupancy (in-use + queued + this request) exceeds this fraction.
    # None disables admission control.
    admission_budget: float | None = None
    # "reject" — typed KVBudgetExceeded surfaces on the handle (FAILED);
    # "defer" — the request stays QUEUED_PREFILL for a later tick.
    admission_mode: str = "reject"

    def __post_init__(self) -> None:
        if self.preempt not in ("none", "swap", "sacrifice"):
            raise ValueError(
                f"preempt must be none|swap|sacrifice, got {self.preempt!r}")
        if self.victim_policy not in ("lifo", "fifo", "priority"):
            raise ValueError(
                f"victim_policy must be lifo|fifo|priority, got "
                f"{self.victim_policy!r}")
        if self.admission_mode not in ("reject", "defer"):
            raise ValueError(
                f"admission_mode must be reject|defer, got "
                f"{self.admission_mode!r}")
