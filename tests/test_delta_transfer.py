"""Delta KV transfer: resident prefix grafts, content-hash dedup,
quantized suffix pulls, and torn-pull safety.

The load-bearing claims:

* a delta plan changes which bytes MOVE, never which bytes the model
  sees — token streams are identical to a full pull;
* pulled + reused always sums to the request's full KV footprint
  (exact accounting on one shared basis: logical slab bytes);
* eviction racing an admission degrades to a full pull, never a wrong
  graft;
* a torn suffix pull cannot corrupt the grafted prefix — the retained
  blocks survive (same ids, same bytes) and the replay moves only the
  suffix again;
* int8 quantized pulls land within the documented tolerance
  (≤ max(|plane|)/127 per element) while halving wire bytes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.transformer import DecoderLM
from repro.serving.blocks import BlockPool
from repro.serving.disagg import DisaggService
from repro.serving.request import RequestState


@pytest.fixture(scope="module")
def setup():
    cfg = get_smoke_config("deepseek-67b")
    # unroll=True: python-loop layers, so the layerwise consumer in
    # test_fully_resident_layerwise is bit-comparable to full consume
    model = DecoderLM(cfg, unroll=True)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def monolithic_generate(model, params, tokens, n):
    logits, state = model.prefill(params, {"tokens": jnp.asarray(tokens[None])},
                                  remat=False)
    out = [int(jnp.argmax(logits[0, : model.cfg.vocab_size]))]
    tok = jnp.asarray([out[-1]], jnp.int32)
    for _ in range(n):
        logits, state = model.decode_step(params, state, tok)
        tok = jnp.argmax(logits[:, : model.cfg.vocab_size], axis=-1).astype(jnp.int32)
        out.append(int(tok[0]))
    return out


def shared_prefix_prompts(cfg, model, *, n, prompt_len, prefix_frac, seed):
    """Prompts sharing a block-aligned prefix; returns (prompts, prefix_len)."""
    rng = np.random.default_rng(seed)
    prefix_len = (int(prompt_len * prefix_frac)
                  // model.BLOCK_SIZE) * model.BLOCK_SIZE
    shared = rng.integers(0, cfg.vocab_size, prefix_len).astype(np.int32)
    prompts = [np.concatenate([
        shared,
        rng.integers(0, cfg.vocab_size, prompt_len - prefix_len)
        .astype(np.int32)]) for _ in range(n)]
    return prompts, prefix_len


class TestDeltaPlan:
    def test_warm_pulls_skip_prefix_and_tokens_match_full(self, setup):
        cfg, model, params = setup
        prompts, prefix_len = shared_prefix_prompts(
            cfg, model, n=3, prompt_len=64, prefix_frac=0.5, seed=0)

        streams = {}
        per_req = {}
        for delta in (False, True):
            svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                                num_blocks=64, delta_transfer=delta)
            outs, mets = [], []
            for t in prompts:  # sequential: request i warms request i+1
                h = svc.submit(t, prefix_id="sys", prefix_len=prefix_len)
                outs.append(svc.generate(h, max_new=3))
                mets.append((h.metrics.kv_bytes_pulled,
                             h.metrics.kv_bytes_reused))
            streams[delta] = outs
            per_req[delta] = mets

        # the plan changed which bytes moved, not what the model computed
        assert streams[True] == streams[False]

        full = per_req[False][0][0]  # cold full-pull footprint, exact
        assert full > 0
        dw_bytes = full * prefix_len // 64  # resident prefix share
        for pulled, reused in per_req[False]:
            assert (pulled, reused) == (full, 0)
        cold_p, cold_r = per_req[True][0]
        assert (cold_p, cold_r) == (full, 0)  # nothing resident yet
        for pulled, reused in per_req[True][1:]:
            assert pulled + reused == full  # exact split, one basis
            assert reused == dw_bytes       # the whole resident prefix

    def test_eviction_between_routing_and_admission_falls_back(self, setup):
        cfg, model, params = setup
        prompts, prefix_len = shared_prefix_prompts(
            cfg, model, n=2, prompt_len=64, prefix_frac=0.5, seed=1)
        ref = monolithic_generate(model, params, prompts[1], 3)

        svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                            num_blocks=64, delta_transfer=True)
        h0 = svc.submit(prompts[0], prefix_id="sys", prefix_len=prefix_len)
        svc.generate(h0, max_new=3)
        dw = svc.decode
        assert "sys" in dw.prefix_cache  # retained, and ADVERTISED to the
        # router via the next LoadReport — the routing decision below may
        # price a delta pull that will no longer be possible
        h1 = svc.submit(prompts[1], prefix_id="sys", prefix_len=prefix_len)
        assert h1.request.state is RequestState.KV_QUEUED
        # the race: retention evicted after routing, before admission
        for pid in list(dw.prefix_cache):
            dw._free_blocks(dw.prefix_cache.pop(pid))
        got = svc.generate(h1, max_new=3)
        assert got == ref  # stale advertisement degrades to a full pull
        assert h1.metrics.kv_bytes_reused == 0
        assert h1.metrics.kv_bytes_pulled == h0.metrics.kv_bytes_pulled

    def test_hash_dedup_without_prefix_id(self, setup):
        cfg, model, params = setup
        rng = np.random.default_rng(2)
        tokens = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
        ref = monolithic_generate(model, params, tokens, 3)

        svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                            num_blocks=64, delta_transfer=True)
        dw = svc.decode
        # first request lands and PROMOTES (hashes register at promotion)
        h0 = svc.submit(tokens)  # no prefix_id anywhere
        svc.admit_queued()
        svc.engine.drain()
        dw.pump(0)
        assert h0.request.state is RequestState.DECODING
        assert dw._hash_index  # landed blocks are indexed by content

        # identical prompt, still no prefix_id: every prompt block dedups
        h1 = svc.submit(tokens)
        svc.admit_queued()
        fl = dw.inflight[h1.request_id]
        assert fl.req.decode_blocks[: len(h0.request.decode_blocks)] \
            == h0.request.decode_blocks  # grafted THE resident blocks
        out = svc.generate_many([h0, h1], max_new=3)
        assert out[h0.request_id] == ref
        assert out[h1.request_id] == ref
        # zero-suffix admission: nothing moved, everything reused
        assert h1.metrics.kv_bytes_pulled == 0
        assert h1.metrics.kv_bytes_reused == h0.metrics.kv_bytes_pulled
        # no retention without a prefix_id: once both free, the dedup
        # index is purged with the blocks (no stale graftable entries)
        assert not dw._hash_index and not dw._block_hash
        assert dw.pool.stats.in_use == 0

    def test_fully_resident_layerwise_consumption(self, setup):
        cfg, model, params = setup
        rng = np.random.default_rng(3)
        tokens = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
        ref = monolithic_generate(model, params, tokens, 3)

        svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                            num_blocks=64, delta_transfer=True,
                            consume="layerwise")
        h0 = svc.submit(tokens, prefix_id="sys")  # prefix = whole prompt
        assert svc.generate(h0, max_new=3) == ref
        # warm request: zero suffix — the pull is ONLY a COMPLETE, and the
        # layerwise consumer must see every layer pre-marked done
        h1 = svc.submit(tokens, prefix_id="sys")
        assert svc.generate(h1, max_new=3) == ref
        assert h1.metrics.kv_bytes_pulled == 0
        assert h1.metrics.kv_reuse_frac == 1.0


class TestTornSuffix:
    def test_torn_mid_suffix_preserves_graft_and_replays(self, setup):
        cfg, model, params = setup
        prompts, prefix_len = shared_prefix_prompts(
            cfg, model, n=2, prompt_len=64, prefix_frac=0.5, seed=4)
        ref = monolithic_generate(model, params, prompts[1], 3)

        svc = DisaggService(model, params, n_prefill=2, n_decode=1,
                            num_blocks=64, delta_transfer=True)
        dw = svc.decode
        h0 = svc.submit(prompts[0], prefix_id="sys", prefix_len=prefix_len)
        svc.generate(h0, max_new=3)
        graft = list(dw.prefix_cache["sys"])
        before = [dw.cache.read_block(layer, b)
                  for layer in range(cfg.num_layers) for b in graft]

        h1 = svc.submit(prompts[1], prefix_id="sys", prefix_len=prefix_len)
        svc.admit_queued()  # suffix pull submitted, skip covers the graft
        assert h1.request.state is RequestState.KV_TRANSFER
        svc.engine.progress(2)  # part of the suffix lands...
        victim = h1.request.prefill_worker
        svc.fail_prefill_worker(victim)  # ...then the connection tears
        assert h1.request.prefill_worker != victim
        assert h1.request.retries == 1

        # the graft survived the abort: same retained ids, same bytes
        assert list(dw.prefix_cache["sys"]) == graft
        after = [dw.cache.read_block(layer, b)
                 for layer in range(cfg.num_layers) for b in graft]
        for (bk, bv), (ak, av) in zip(before, after):
            np.testing.assert_array_equal(bk, ak)
            np.testing.assert_array_equal(bv, av)

        got = svc.generate_many([h1], max_new=3)[h1.request_id]
        assert got == ref
        # retry accounting: the re-admission re-grafted (reused counts
        # twice, mirroring how re-pulled suffix bytes count twice) and
        # only suffix bytes ever moved
        full = h0.metrics.kv_bytes_pulled
        graft_bytes = full * prefix_len // 64
        assert h1.metrics.kv_bytes_reused == 2 * graft_bytes
        suffix_bytes = full - graft_bytes
        assert suffix_bytes <= h1.metrics.kv_bytes_pulled <= 2 * suffix_bytes


class TestQuantizedTransfer:
    def test_roundtrip_within_documented_tolerance(self, setup):
        cfg, model, params = setup
        rng = np.random.default_rng(5)
        tokens = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)

        svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                            num_blocks=64, quantize_transfer=True)
        h = svc.submit(tokens)
        req = h.request
        pw = svc.prefills[req.prefill_worker]
        src = {}  # exact parked bytes, captured before COMPLETE frees them
        for layer in range(cfg.num_layers):
            kp, vp = pw.cache.kv_planes(layer)
            for blk in req.prefill_blocks:
                src[(layer, blk, 0)] = np.array(kp[blk], np.float32)
                src[(layer, blk, 1)] = np.array(vp[blk], np.float32)
        svc.admit_queued()
        svc.engine.drain()
        dw = svc.decode
        dw.pump(0)
        for layer in range(cfg.num_layers):
            kp, vp = dw.cache.kv_planes(layer)
            for pos, blk in enumerate(req.prefill_blocks):
                dst_blk = req.decode_blocks[pos]
                for plane, landed in ((0, kp[dst_blk]), (1, vp[dst_blk])):
                    s = src[(layer, blk, plane)]
                    tol = float(np.max(np.abs(s))) / 127.0 + 1e-6
                    err = np.max(np.abs(landed.astype(np.float32) - s))
                    assert err <= tol, \
                        f"layer {layer} block {pos} plane {plane}: " \
                        f"|err|={err} > {tol}"
        # the wire moved ~half the logical bytes (int8 payload + scale)
        logical = h.metrics.kv_bytes_pulled or svc.engine.pulled_bytes(
            req.request_id)
        assert svc.engine.stats.bytes_moved < 0.6 * logical

    def test_quantized_delta_still_deduplicates(self, setup):
        cfg, model, params = setup
        prompts, prefix_len = shared_prefix_prompts(
            cfg, model, n=2, prompt_len=64, prefix_frac=0.5, seed=6)
        svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                            num_blocks=64, delta_transfer=True,
                            quantize_transfer=True)
        h0 = svc.submit(prompts[0], prefix_id="sys", prefix_len=prefix_len)
        out0 = svc.generate(h0, max_new=3)
        h1 = svc.submit(prompts[1], prefix_id="sys", prefix_len=prefix_len)
        svc.generate(h1, max_new=3)
        assert h1.metrics.kv_bytes_reused > 0
        # same prompt again: graft serves exactly what a fresh quantized
        # pull would land, so the output is reproducible
        svc2 = DisaggService(model, params, n_prefill=1, n_decode=1,
                             num_blocks=64, delta_transfer=False,
                             quantize_transfer=True)
        h2 = svc2.submit(prompts[0], prefix_id="sys", prefix_len=prefix_len)
        assert svc2.generate(h2, max_new=3) == out0


class TestResidentPageCache:
    def test_cache_invalidates_when_block_list_changes(self, setup):
        """Regression: the per-resident float32 page cache is keyed on
        WHICH blocks its columns came from.  Rewriting the block list
        (not just appending) must force a re-gather, not serve stale
        columns for the old blocks."""
        cfg, model, params = setup
        rng = np.random.default_rng(7)
        tokens = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
        svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                            num_blocks=64)
        h = svc.submit(tokens)
        svc.admit_queued()
        svc.engine.drain()
        dw = svc.decode
        dw.pump(0)
        r = dw.resident[h.request_id]
        k0, _ = dw._resident_pages(r)  # populate the cache
        assert r.cached_from == tuple(r.blocks)

        # swap block 0 for a fresh block holding DIFFERENT bytes
        (new_blk,) = dw.pool.allocate(1)
        marker_k = np.full((dw.block_size, cfg.num_kv_heads, cfg.head_dim),
                           3.0, np.float32)
        for layer in range(cfg.num_layers):
            dw.cache.write_block(layer, new_blk, marker_k, -marker_k)
        old = r.blocks[0]
        r.blocks = [new_blk] + r.blocks[1:]
        k1, v1 = dw._resident_pages(r)
        np.testing.assert_array_equal(k1[:, 0], np.broadcast_to(
            marker_k, (cfg.num_layers,) + marker_k.shape))
        np.testing.assert_array_equal(v1[:, 0], -k1[:, 0])
        # untouched columns re-gathered losslessly
        np.testing.assert_array_equal(k1[:, 1:], k0[:, 1:])
        dw.pool.free([new_blk])
        r.blocks = [old] + r.blocks[1:]


class TestPoolDeltaLifecycleInvariants:
    """Direct pool-level exercise of the graft lifecycle's sharp edge:
    share-before-allocate means an eviction mid-admission only ever
    decrements, and free() reports exactly the ids whose last reference
    dropped (the contract the hash index purge rides on)."""

    def test_free_reports_exact_releases_under_sharing(self):
        pool = BlockPool(8, block_size=4)
        a = pool.allocate(4)        # request A's blocks
        pool.share(a[:2])           # retained prefix keeps 2 of them
        released = pool.free(a)     # A finishes
        assert released == a[2:]    # shared prefix NOT released
        pool.check_invariants()
        released = pool.free(a[:2])  # cache evicts
        assert released == a[:2]
        assert pool.num_free == 8

    def test_graft_survives_eviction_mid_admission(self):
        pool = BlockPool(4, block_size=4)
        prefix = pool.allocate(2)   # the retention cache's reference
        pool.share(prefix)          # an admission grafts it...
        assert pool.free(prefix) == []  # ...then eviction frees the
        # cache's reference: nothing actually releases — the graft holds
        pool.check_invariants()
        rest = pool.allocate(2)     # the suffix still fits
        assert set(rest).isdisjoint(prefix)
        assert pool.free(prefix + rest) == prefix + rest
