"""Beyond-paper features: straggler hedging + int8 transport codec."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.core.descriptors import ByteRange, ReadTxn
from repro.core.transfer_engine import MemoryRegion, TransferEngine
from repro.sim.costs import CostModel, H100_NODE
from repro.sim.events import ClusterSim, SimConfig
from repro.sim.workloads import fixed_requests

try:
    import ml_dtypes

    BF16 = np.dtype(ml_dtypes.bfloat16)
except ImportError:  # pragma: no cover
    BF16 = None


class TestHedgedPrefill:
    def _run(self, hedge: bool):
        cost = CostModel(get_config("mistral-large-123b"), H100_NODE)
        reqs = fixed_requests(16384, 64, qps=0.5, duration_s=120, seed=9)
        sim = ClusterSim(
            cost,
            SimConfig(n_prefill=3, n_decode=1, hedge_prefill=hedge, hedge_factor=2.0),
            prefill_slowdowns={"p0": 10.0},  # one straggling node
        )
        return sim.run(list(reqs))

    def test_hedging_beats_straggler(self):
        base = self._run(hedge=False).summary()
        hedged = self._run(hedge=True).summary()
        assert hedged["p90_ttft_s"] < base["p90_ttft_s"]

    def test_all_requests_finish_and_pools_drain(self):
        res = self._run(hedge=True)
        assert all(r.done_s is not None for r in res.requests)
        # no KV leaked by losing hedge twins
        sim_reqs = fixed_requests(16384, 64, qps=0.5, duration_s=120, seed=9)
        assert len(res.requests) == len(sim_reqs)

    def test_hedged_requests_marked(self):
        res = self._run(hedge=True)
        assert any(r.retries > 0 for r in res.requests)


@pytest.mark.skipif(BF16 is None, reason="ml_dtypes unavailable")
class TestInt8TransportCodec:
    def _engines(self):
        rng = np.random.default_rng(0)
        vals = (rng.standard_normal(32768) * 3).astype(BF16)
        src = vals.view(np.uint8).copy()
        dst = np.zeros_like(src)
        eng = TransferEngine(codec="int8_transport")
        eng.register_memory(MemoryRegion("p", 0, src))
        eng.register_memory(MemoryRegion("d", src.nbytes, dst))
        return eng, vals, dst

    def test_halves_wire_bytes(self):
        eng, vals, dst = self._engines()
        n = vals.nbytes
        eng.submit([ReadTxn("r", "p", "d", ByteRange(0, n), ByteRange(n, n))])
        eng.drain()
        assert eng.stats.bytes_moved == n // 2 + 4

    def test_error_bounded(self):
        eng, vals, dst = self._engines()
        n = vals.nbytes
        eng.submit([ReadTxn("r", "p", "d", ByteRange(0, n), ByteRange(n, n))])
        eng.drain()
        got = dst.view(BF16).astype(np.float32)
        ref = vals.astype(np.float32)
        max_err = np.abs(got - ref).max()
        assert max_err <= np.abs(ref).max() / 127 + 0.05  # quantization bound

    def test_lossless_codec_unchanged(self):
        rng = np.random.default_rng(1)
        src = rng.integers(0, 255, 4096, dtype=np.uint8)
        dst = np.zeros_like(src)
        eng = TransferEngine()  # codec none
        eng.register_memory(MemoryRegion("p", 0, src))
        eng.register_memory(MemoryRegion("d", 4096, dst))
        eng.submit([ReadTxn("r", "p", "d", ByteRange(0, 4096), ByteRange(4096, 4096))])
        eng.drain()
        np.testing.assert_array_equal(dst, src)
