"""Cluster scheduler + CONNECT(): dynamic membership, epochs, failures."""
import pytest

from repro.core.cluster import ClusterScheduler, MembershipEvent
from repro.core.connection import (
    ChipInfo,
    ConnectionManager,
    DescriptorRegistry,
    StaleConnectionError,
    WorkerInfo,
)
from repro.core.descriptors import TensorDesc


def winfo(wid, role, nchips=2):
    return WorkerInfo(
        worker_id=wid, role=role, host_addr=f"10.0.0.{hash(wid) % 250}",
        chips=tuple(ChipInfo(i, f"ici://{wid}/chip{i}") for i in range(nchips)),
    )


def registry(wid, ntensors=2):
    reg = DescriptorRegistry(wid)
    for l in range(ntensors):
        reg.register(TensorDesc(
            address=0x1000 + l * 0x10000, dims=("B", "KV", "L", "H", "D"),
            shape=(4, 2, 16, 2, 128), stride=(4096, 16384, 256, 128, 1),
            itemsize=2, worker_id=wid, tensor_id=f"layer{l}/kv",
        ))
    return reg


class TestConnect:
    def test_handshake_exchanges_descriptors(self):
        cm = ConnectionManager(winfo("d0", "decode"))
        conn = cm.connect(winfo("p0", "prefill"), registry("p0"))
        assert set(conn.descriptors) == {"layer0/kv", "layer1/kv"}
        assert conn.desc("layer0/kv").worker_id == "p0"

    def test_link_aligned_pairing(self):
        # §4.2: chip i <-> chip i only (rail alignment).
        cm = ConnectionManager(winfo("d0", "decode", nchips=4))
        conn = cm.connect(winfo("p0", "prefill", nchips=4), registry("p0"))
        assert conn.chip_pairs == ((0, 0), (1, 1), (2, 2), (3, 3))

    def test_decode_to_decode_rejected(self):
        cm = ConnectionManager(winfo("d0", "decode"))
        with pytest.raises(ValueError):
            cm.connect(winfo("d1", "decode"), registry("d1"))

    def test_epoch_bumps_on_reconnect(self):
        cm = ConnectionManager(winfo("d0", "decode"))
        c1 = cm.connect(winfo("p0", "prefill"), registry("p0"))
        cm.disconnect("p0", failed=True)
        c2 = cm.connect(winfo("p0", "prefill"), registry("p0"))
        assert c2.epoch > c1.epoch
        with pytest.raises(StaleConnectionError):
            cm.validate_epoch("p0", c1.epoch)

    def test_failure_invalidation_callback(self):
        cm = ConnectionManager(winfo("d0", "decode"))
        cm.connect(winfo("p0", "prefill"), registry("p0"))
        dead = []
        cm.on_invalidate(lambda w, e: dead.append((w, e)))
        cm.disconnect("p0", failed=True)
        assert dead == [("p0", 1)]
        # graceful disconnect does NOT fire invalidation
        cm.connect(winfo("p1", "prefill"), registry("p1"))
        cm.disconnect("p1", failed=False)
        assert len(dead) == 1


class TestClusterScheduler:
    def test_dynamic_add_broadcasts(self):
        cs = ClusterScheduler()
        events: list[MembershipEvent] = []
        cs.subscribe(events.append)
        cs.add_worker(winfo("p0", "prefill"))
        cs.add_worker(winfo("d0", "decode"))
        assert [e.kind for e in events] == ["added", "added"]
        assert [w.worker_id for w in cs.workers("prefill")] == ["p0"]

    def test_decode_autoconnects_to_new_prefill(self):
        # The paper's flow: scheduler broadcast -> running decode worker
        # connects to the new prefill worker without a restart.
        cs = ClusterScheduler()
        cm = ConnectionManager(winfo("d0", "decode"))
        registries = {"p0": registry("p0"), "p1": registry("p1")}

        def on_event(ev: MembershipEvent):
            if ev.kind == "added" and ev.worker.role == "prefill":
                cm.connect(ev.worker, registries[ev.worker.worker_id])
            elif ev.kind in ("removed", "failed") and ev.worker.role == "prefill":
                cm.disconnect(ev.worker.worker_id, failed=ev.kind == "failed")

        cs.subscribe(on_event)
        cs.add_worker(winfo("d0", "decode"))
        cs.add_worker(winfo("p0", "prefill"))
        assert cm.peers == ("p0",)
        cs.add_worker(winfo("p1", "prefill"))   # elastic scale-up
        assert set(cm.peers) == {"p0", "p1"}
        cs.remove_worker("p0")                   # elastic scale-down
        assert cm.peers == ("p1",)

    def test_duplicate_worker_rejected(self):
        cs = ClusterScheduler()
        cs.add_worker(winfo("p0", "prefill"))
        with pytest.raises(ValueError):
            cs.add_worker(winfo("p0", "prefill"))

    def test_heartbeat_reaping(self):
        cs = ClusterScheduler(heartbeat_timeout_s=1.0)
        cs.add_worker(winfo("p0", "prefill"), now=0.0)
        cs.add_worker(winfo("p1", "prefill"), now=0.0)
        cs.heartbeat("p1", now=2.5)
        dead = cs.reap_dead(now=3.0)
        assert dead == ["p0"]
        assert "p0" not in cs and "p1" in cs

    def test_scheduler_outage_does_not_break_data_plane(self):
        # Connections live on the decode worker; dropping the scheduler
        # leaves them usable (§4.2 single-point-of-failure note).
        cs = ClusterScheduler()
        cm = ConnectionManager(winfo("d0", "decode"))
        cs.add_worker(winfo("p0", "prefill"))
        conn = cm.connect(cs.get("p0"), registry("p0"))
        del cs  # scheduler gone
        assert cm.connection("p0") is conn  # data plane unaffected
