"""Suite-wide fixtures.

The XLA CPU backend segfaults inside ``backend_compile`` once enough
live compiled executables accumulate in a single process (observed with
jax 0.4.37: a full-suite run crashes deterministically compiling a
computation that compiles fine in isolation).  Clearing the jit caches
between test modules keeps the live-executable set bounded; each module
recompiles what it needs, which costs a little wall clock and removes
the cliff.
"""
import jax
import pytest


@pytest.fixture(scope="module", autouse=True)
def _bound_live_xla_executables():
    yield
    jax.clear_caches()
