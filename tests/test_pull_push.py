"""End-to-end KV movement: prefill cache -> (pull|push) -> decode cache.

These are mechanism tests with REAL bytes: we fill the prefill worker's
paged KV cache with known values, run the pull- or push-mode flow through
the transfer engine, and check the decode worker's cache bit-for-bit.
"""
import numpy as np
import pytest

from repro.core.connection import ChipInfo, ConnectionManager, DescriptorRegistry, WorkerInfo
from repro.core.pull_push import pull_kv, pull_state, push_finish, push_layer, push_reserve
from repro.core.transfer_engine import TransferEngine
from repro.serving.blocks import BlockPool, OutOfBlocks
from repro.serving.kv_cache import PagedKVCache, SlotCache
from repro.serving.request import Request

LAYERS, BLOCKS, BS, KVH, HD = 3, 16, 16, 2, 64


def winfo(wid, role):
    return WorkerInfo(wid, role, "10.0.0.1", (ChipInfo(0, f"ici://{wid}/0"),))


def setup(mode="tensor_centric", coalescing="fifo"):
    pre = PagedKVCache("p0", num_layers=LAYERS, num_blocks=BLOCKS, block_size=BS,
                       kv_heads=KVH, head_dim=HD, base_address=0x1000_0000)
    dec = PagedKVCache("d0", num_layers=LAYERS, num_blocks=BLOCKS, block_size=BS,
                       kv_heads=KVH, head_dim=HD, base_address=0x2000_0000)
    eng = TransferEngine(mode=mode, coalescing=coalescing)
    eng.register_memory(pre.memory_region())
    eng.register_memory(dec.memory_region())
    reg = DescriptorRegistry("p0")
    for d in pre.descriptors():
        reg.register(d)
    cm = ConnectionManager(winfo("d0", "decode"))
    conn = cm.connect(winfo("p0", "prefill"), reg)
    return pre, dec, eng, conn


def fill_blocks(cache: PagedKVCache, blocks, seed=0):
    rng = np.random.default_rng(seed)
    data = {}
    for layer in range(cache.num_layers):
        for b in blocks:
            k = rng.standard_normal((BS, KVH, HD)).astype(np.float32)
            v = rng.standard_normal((BS, KVH, HD)).astype(np.float32)
            cache.write_block(layer, b, k, v)
            data[(layer, b)] = cache.read_block(layer, b)  # post-cast truth
    return data


class TestPullMode:
    @pytest.mark.parametrize("coalescing", ["none", "fifo", "sorted"])
    def test_bytes_arrive_exactly(self, coalescing):
        pre, dec, eng, conn = setup(coalescing=coalescing)
        pre_pool, dec_pool = BlockPool(BLOCKS, block_size=BS), BlockPool(BLOCKS, block_size=BS)
        req = Request("r1", prompt_len=4 * BS, max_new_tokens=8)
        req.prefill_blocks = pre_pool.allocate(4)
        truth = fill_blocks(pre, req.prefill_blocks)

        freed = []
        eng.on_complete(lambda c: freed.append(c.request_id))
        stats = pull_kv(req, conn=conn, engine=eng, decode_pool=dec_pool, decode_cache=dec)

        assert freed == ["r1"]  # prefill can release its blocks
        assert len(req.decode_blocks) == 4
        for layer in range(LAYERS):
            for pb, db in zip(req.prefill_blocks, req.decode_blocks):
                k_t, v_t = truth[(layer, pb)]
                k, v = dec.read_block(layer, db)
                np.testing.assert_array_equal(k, k_t)
                np.testing.assert_array_equal(v, v_t)
        # 4 blocks x (K+V) x layers original txns
        assert stats.txns_submitted == 4 * 2 * LAYERS
        assert stats.bytes_moved == 4 * 2 * LAYERS * pre.block_nbytes

    def test_coalescing_reduces_posted_reads(self):
        results = {}
        for strat in ("none", "fifo", "sorted"):
            pre, dec, eng, conn = setup(coalescing=strat)
            pre_pool, dec_pool = BlockPool(BLOCKS), BlockPool(BLOCKS)
            req = Request("r1", prompt_len=8 * BS, max_new_tokens=8)
            req.prefill_blocks = pre_pool.allocate(8)  # contiguous run
            fill_blocks(pre, req.prefill_blocks)
            stats = pull_kv(req, conn=conn, engine=eng, decode_pool=dec_pool, decode_cache=dec)
            results[strat] = stats.reads_posted
        assert results["fifo"] < results["none"]
        assert results["sorted"] <= results["fifo"]
        # Contiguous K runs and V runs merge: 2 reads per layer ideally.
        assert results["sorted"] == 2 * LAYERS

    def test_pool_exhaustion_raises_not_deadlocks(self):
        pre, dec, eng, conn = setup()
        pre_pool, dec_pool = BlockPool(BLOCKS), BlockPool(2)
        req = Request("r1", prompt_len=4 * BS, max_new_tokens=8)
        req.prefill_blocks = pre_pool.allocate(4)
        with pytest.raises(OutOfBlocks):
            pull_kv(req, conn=conn, engine=eng, decode_pool=dec_pool, decode_cache=dec)
        assert dec_pool.num_free == 2  # nothing leaked


class TestPushMode:
    def test_layerwise_push_then_commit(self):
        pre, dec, eng, conn = setup()
        pre_pool, dec_pool = BlockPool(BLOCKS), BlockPool(BLOCKS)
        req = Request("r1", prompt_len=4 * BS, max_new_tokens=8)
        push_reserve(req, dec_pool, 4)      # admission-time reservation
        assert dec_pool.stats.reserved == 4
        req.prefill_blocks = pre_pool.allocate(4)
        truth = fill_blocks(pre, req.prefill_blocks)
        for layer in range(LAYERS):        # prefill pushes as layers finish
            push_layer(req, layer, conn=conn, engine=eng, decode_cache=dec)
        push_finish(req, conn=conn, engine=eng, decode_pool=dec_pool)
        assert dec_pool.stats.reserved == 0 and dec_pool.stats.allocated == 4
        for layer in range(LAYERS):
            for pb, db in zip(req.prefill_blocks, req.decode_blocks):
                k_t, _ = truth[(layer, pb)]
                k, _ = dec.read_block(layer, db)
                np.testing.assert_array_equal(k, k_t)

    def test_push_reserves_longer_than_pull(self):
        # Occupancy semantics: push holds decode blocks from admission;
        # pull holds nothing until prefill is done.
        _, _, _, _ = setup()
        dec_pool = BlockPool(8)
        r1 = Request("r1", prompt_len=4 * BS, max_new_tokens=4)
        push_reserve(r1, dec_pool, 6)
        r2 = Request("r2", prompt_len=4 * BS, max_new_tokens=4)
        with pytest.raises(OutOfBlocks):
            push_reserve(r2, dec_pool, 6)   # blocked for the WHOLE prefill of r1


class TestStatePull:
    def test_ssm_state_single_txn_per_layer(self):
        # Mamba-style fixed-size state: one contiguous read per layer.
        pre = SlotCache("p0", num_layers=4, num_slots=8, state_elems=2048,
                        base_address=0x3000_0000)
        dec = SlotCache("d0", num_layers=4, num_slots=8, state_elems=2048,
                        base_address=0x4000_0000)
        eng = TransferEngine()
        eng.register_memory(pre.memory_region())
        eng.register_memory(dec.memory_region())
        reg = DescriptorRegistry("p0")
        for d in pre.descriptors():
            reg.register(d)
        cm = ConnectionManager(winfo("d0", "decode"))
        conn = cm.connect(winfo("p0", "prefill"), reg)

        rng = np.random.default_rng(7)
        states = [rng.standard_normal(2048).astype(np.float32) for _ in range(4)]
        for layer, s in enumerate(states):
            pre.write_slot(layer, 5, s)
        req = Request("r1", prompt_len=128, max_new_tokens=4)
        stats = pull_state(req, conn=conn, engine=eng, decode_cache=dec,
                           remote_slot=5, local_slot=2)
        assert stats.txns_submitted == 4  # exactly one txn per layer
        for layer, s in enumerate(states):
            got = dec.read_slot(layer, 2)
            np.testing.assert_array_equal(got, pre.read_slot(layer, 5))
