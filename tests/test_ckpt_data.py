"""Checkpointing + data pipeline: the fault-tolerance substrate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.ckpt.checkpoint import latest_step, restore_checkpoint, save_checkpoint
from repro.data.pipeline import SyntheticLMDataset


def tree():
    return {
        "w": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.bfloat16), "step": jnp.int32(7)},
    }


class TestCheckpoint:
    def test_roundtrip(self, tmp_path):
        t = tree()
        save_checkpoint(tmp_path, 10, t)
        got = restore_checkpoint(tmp_path, 10, t)
        for a, b in zip(jax.tree.leaves(t), jax.tree.leaves(got)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
            assert a.dtype == b.dtype

    def test_latest_and_retention(self, tmp_path):
        t = tree()
        for s in (1, 2, 3, 4, 5):
            save_checkpoint(tmp_path, s, t, keep=3)
        assert latest_step(tmp_path) == 5
        assert len(list(tmp_path.glob("step_*"))) == 3  # retention

    def test_shape_mismatch_rejected(self, tmp_path):
        save_checkpoint(tmp_path, 1, tree())
        bad = tree()
        bad["w"] = jnp.zeros((4, 4), jnp.float32)
        with pytest.raises(ValueError, match="shape mismatch"):
            restore_checkpoint(tmp_path, 1, bad)

    def test_atomicity_no_tmp_left(self, tmp_path):
        save_checkpoint(tmp_path, 3, tree())
        assert not list(tmp_path.glob(".tmp_*"))

    def test_elastic_resharding(self, tmp_path):
        """Restore onto explicit shardings (re-mesh on resume)."""
        from jax.sharding import NamedSharding, PartitionSpec as P

        t = {"w": jnp.arange(8, dtype=jnp.float32)}
        save_checkpoint(tmp_path, 1, t)
        mesh = jax.make_mesh((1,), ("data",))
        sh = {"w": NamedSharding(mesh, P("data"))}
        got = restore_checkpoint(tmp_path, 1, t, shardings=sh)
        assert got["w"].sharding == sh["w"]


class TestData:
    def test_deterministic_and_resumable(self):
        d1 = SyntheticLMDataset(1000, 64, 4, seed=3)
        b1 = [d1.next_batch()["tokens"] for _ in range(3)]
        d2 = SyntheticLMDataset(1000, 64, 4, seed=3)
        d2.restore({"seed": 3, "step": 2})
        np.testing.assert_array_equal(d2.next_batch()["tokens"], b1[2])

    def test_tokens_in_range(self):
        d = SyntheticLMDataset(512, 32, 2)
        t = d.next_batch()["tokens"]
        assert t.min() >= 0 and t.max() < 512
        assert t.shape == (2, 32)

    def test_learnable_structure(self):
        d = SyntheticLMDataset(1000, 64, 4)
        t = d.next_batch()["tokens"]
        half = 32
        np.testing.assert_array_equal(t[:, half:], np.roll(t[:, :half], -1, axis=1))
