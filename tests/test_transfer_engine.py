"""Transfer engine: real byte movement, coalescing, ordering, modes."""
import numpy as np
import pytest

from repro.core.coalesce import coalesce_fifo, coalesce_sorted
from repro.core.descriptors import ByteRange, CompleteTxn, ReadTxn
from repro.core.transfer_engine import LinkModel, MemoryRegion, TransferEngine


DST_BASE = 1 << 20  # MRs must be disjoint in the engine's flat address space


def make_engine(mode="tensor_centric", **kw):
    eng = TransferEngine(mode=mode, **kw)
    src = np.arange(64 * 1024, dtype=np.uint8) % 251
    dst = np.zeros(64 * 1024, dtype=np.uint8)
    eng.register_memory(MemoryRegion("p0", 0, src))
    eng.register_memory(MemoryRegion("d0", DST_BASE, dst))
    return eng, src, dst


def read(rid, roff, loff, n=4096):
    return ReadTxn(rid, "p0", "d0", ByteRange(roff, n), ByteRange(DST_BASE + loff, n))


class TestByteMovement:
    @pytest.mark.parametrize("mode", ["tensor_centric", "message"])
    def test_bytes_land_exactly(self, mode):
        eng, src, dst = make_engine(mode)
        eng.submit([read("r1", 0, 8192), read("r1", 4096, 12288),
                    CompleteTxn("r1", "p0", "d0")])
        eng.drain()
        np.testing.assert_array_equal(dst[8192:16384], src[0:8192])
        assert eng.stats.bytes_moved == 8192
        assert eng.stats.completes == 1

    def test_non_adjacent_not_merged_but_correct(self):
        eng, src, dst = make_engine()
        eng.submit([read("r1", 0, 0), read("r1", 8192, 8192)])  # gap at 4096
        eng.drain()
        np.testing.assert_array_equal(dst[0:4096], src[0:4096])
        np.testing.assert_array_equal(dst[8192:12288], src[8192:12288])
        assert eng.stats.reads_posted == 2

    def test_adjacent_coalesce_to_one_read(self):
        eng, src, dst = make_engine()
        eng.submit([read("r1", 0, 0), read("r2", 4096, 4096)])
        eng.drain()
        assert eng.stats.reads_posted == 1  # one RDMA op for two txns
        assert eng.stats.coalesce_factor == 2.0
        np.testing.assert_array_equal(dst[0:8192], src[0:8192])


class TestOrderingRules:
    def test_complete_blocks_window(self):
        # Reads after a COMPLETE must not coalesce across it.
        eng, _, _ = make_engine()
        eng.submit([read("r1", 0, 0), CompleteTxn("r1", "p0", "d0"),
                    read("r2", 4096, 4096)])
        eng.drain()
        assert eng.stats.reads_posted == 2  # window split at COMPLETE

    def test_complete_before_reads_is_a_bug(self):
        eng, _, _ = make_engine()
        eng.submit([CompleteTxn("r1", "p0", "d0"), read("r1", 0, 0)])
        with pytest.raises(RuntimeError, match="COMPLETE"):
            eng.drain()

    def test_cross_request_interleaving_ok(self):
        # §4.2: transactions of different requests may interleave freely.
        eng, src, dst = make_engine()
        eng.submit([read("r1", 0, 0), read("r2", 4096, 4096),
                    read("r1", 8192, 8192),
                    CompleteTxn("r1", "p0", "d0"), CompleteTxn("r2", "p0", "d0")])
        eng.drain()
        assert eng.stats.completes == 2
        np.testing.assert_array_equal(dst[:12288], src[:12288])


class TestMessageModeBaseline:
    def test_staging_rounds_bounded_buffer(self):
        # Fig. 7a: buffer holds 2 blocks -> 4 blocks = 2 rounds.
        eng, src, dst = make_engine("message", staging_blocks=2,
                                    staging_block_bytes=4096)
        eng.submit([read(f"r", i * 4096, i * 4096) for i in range(4)])
        eng.drain()
        assert eng.stats.rounds == 2
        np.testing.assert_array_equal(dst[:16384], src[:16384])

    def test_message_mode_modeled_slower(self):
        # Same bytes, message mode pays per-round handshakes (Fig. 3).
        link = LinkModel()
        e1, _, _ = make_engine("tensor_centric", link=link)
        e2, _, _ = make_engine("message", link=link, staging_blocks=2,
                               staging_block_bytes=4096)
        txns = [read("r", i * 4096, i * 4096) for i in range(8)]
        e1.submit(list(txns)); e1.drain()
        e2.submit(list(txns)); e2.drain()
        assert e2.stats.modeled_time_s > 10 * e1.stats.modeled_time_s


class TestCompletionCallbacks:
    def test_on_complete_fires_with_request_id(self):
        eng, _, _ = make_engine()
        seen = []
        eng.on_complete(lambda c: seen.append(c.request_id))
        eng.submit([read("rX", 0, 0), CompleteTxn("rX", "p0", "d0")])
        eng.drain()
        assert seen == ["rX"]

    def test_unregistered_worker_fails(self):
        eng = TransferEngine()
        eng.submit([read("r", 0, 0)])
        with pytest.raises(KeyError, match="unregistered"):
            eng.drain()


class TestCoalesceStrategies:
    def test_fifo_misses_out_of_order_adjacency(self):
        txns = [read("a", 4096, 4096), read("b", 0, 0)]  # reversed order
        assert len(coalesce_fifo(txns)) == 2
        assert len(coalesce_sorted(txns)) == 1  # beyond-paper strategy

    def test_sorted_requires_both_sides_contiguous(self):
        txns = [read("a", 0, 0), read("b", 4096, 12288)]  # remote adj, local not
        assert len(coalesce_sorted(txns)) == 2

    def test_merge_preserves_total_bytes(self):
        txns = [read(f"r{i}", i * 4096, i * 4096) for i in range(10)]
        merged = coalesce_sorted(txns)
        assert sum(m.nbytes for m in merged) == 10 * 4096
        assert len(merged) == 1 and merged[0].n_merged == 10


class TestLinkModel:
    def test_read_time_scales_with_bytes(self):
        lm = LinkModel()
        assert lm.read_time(50_000_000_000) == pytest.approx(1.0, rel=0.01)

    def test_message_round_dominated_by_overheads_for_small_blocks(self):
        lm = LinkModel()
        t = lm.message_round_time(4096)
        overhead = lm.rpc_latency_s + lm.gather_launch_s + lm.cpu_sync_s + \
            lm.scatter_launch_s + lm.notify_s
        assert overhead / t > 0.99  # the 13.2%-effective pathology of Fig. 3
