"""Per-architecture smoke tests (reduced configs, CPU).

For each assigned arch: instantiate the reduced same-family config, run
one forward/train step and a prefill→decode step, assert output shapes
and no NaNs.  Also checks param-count formulas against the real inits.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED, get_config, get_smoke_config
from repro.models.registry import build_model

jax.config.update("jax_platform_name", "cpu")


def make_batch(cfg, b=2, s=64, rng=None):
    rng = np.random.default_rng(0) if rng is None else rng
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s)), jnp.int32)}
    if cfg.family == "vlm":
        batch["vision_embeds"] = jnp.asarray(
            rng.standard_normal((b, cfg.vision_tokens, cfg.d_model)), jnp.bfloat16
        )
    if cfg.is_encoder_decoder:
        batch["frames"] = jnp.asarray(
            rng.standard_normal((b, cfg.encoder_seq, cfg.d_model)), jnp.bfloat16
        )
    return batch


@pytest.fixture(scope="module")
def built():
    cache = {}

    def get(arch):
        if arch not in cache:
            cfg = get_smoke_config(arch)
            model = build_model(cfg)
            params = model.init_params(jax.random.PRNGKey(0))
            cache[arch] = (cfg, model, params)
        return cache[arch]

    return get


# Known-failing on the CPU container since the seed: the train step
# differentiates through an optimization_barrier the CPU lowering of this
# jax version has no VJP rule for.  Keyed on backend so accelerator
# runners still execute it; non-strict because some archs (whisper) take
# a barrier-free path and pass even on CPU.
cpu_train_step_xfail = pytest.mark.xfail(
    jax.default_backend() == "cpu",
    reason="optimization_barrier has no differentiation rule on the CPU "
           "backend of this jax version (seed-known failure)",
    strict=False,
)


@cpu_train_step_xfail
@pytest.mark.parametrize("arch", ASSIGNED)
def test_train_step_shapes_and_finite(arch, built):
    cfg, model, params = built(arch)
    batch = make_batch(cfg)
    (loss, metrics), grads = jax.value_and_grad(
        lambda p: model.train_loss(p, batch, remat=False), has_aux=True
    )(params)
    assert loss.shape == ()
    assert jnp.isfinite(loss), f"{arch}: loss={loss}"
    flat = jax.tree.leaves(grads)
    assert all(jnp.all(jnp.isfinite(g.astype(jnp.float32))) for g in flat), f"{arch}: NaN grads"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_prefill_then_decode(arch, built):
    cfg, model, params = built(arch)
    b, s = 2, 64
    batch = make_batch(cfg, b, s)
    logits, state = model.prefill(params, batch, remat=False)
    assert logits.shape == (b, cfg.padded_vocab)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32))), f"{arch}: prefill NaN"

    next_tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)
    for _ in range(3):
        logits, state = model.decode_step(params, state, next_tok)
        assert logits.shape == (b, cfg.padded_vocab)
        assert jnp.all(jnp.isfinite(logits.astype(jnp.float32))), f"{arch}: decode NaN"
        next_tok = jnp.argmax(logits[:, : cfg.vocab_size], axis=-1).astype(jnp.int32)


@pytest.mark.parametrize("arch", ASSIGNED + ["mistral-large-123b"])
def test_full_config_exact_dims(arch):
    """The FULL configs carry the exact assigned dims (no allocation)."""
    cfg = get_config(arch)
    table = {
        "granite-34b": (88, 6144, 48, 1, 24576, 49152),
        "deepseek-67b": (95, 8192, 64, 8, 22016, 102400),
        "deepseek-coder-33b": (62, 7168, 56, 8, 19200, 32256),
        "yi-9b": (48, 4096, 32, 4, 11008, 64000),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "granite-moe-3b-a800m": (32, 1536, 24, 8, 512, 49155),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "llava-next-mistral-7b": (32, 4096, 32, 8, 14336, 32000),
        "mamba2-780m": (48, 1536, 0, 0, 0, 50280),
        "hymba-1.5b": (32, 1600, 25, 5, 5504, 32001),
        "mistral-large-123b": (88, 12288, 96, 8, 28672, 32768),
    }
    L, d, h, kv, ff, v = table[arch]
    assert cfg.num_layers == L and cfg.d_model == d and cfg.vocab_size == v
    assert cfg.num_heads == h and cfg.num_kv_heads == kv and cfg.d_ff == ff
    if arch == "granite-moe-3b-a800m":
        assert cfg.num_experts == 40 and cfg.experts_per_token == 8
    if arch == "llama4-maverick-400b-a17b":
        assert cfg.num_experts == 128 and cfg.experts_per_token == 1
    if arch == "mamba2-780m":
        assert cfg.ssm_state == 128
    if arch == "hymba-1.5b":
        assert cfg.ssm_state == 16


@pytest.mark.parametrize(
    "arch,lo,hi",
    [
        ("granite-34b", 32e9, 36e9),
        ("deepseek-67b", 64e9, 70e9),
        ("deepseek-coder-33b", 31e9, 35e9),
        ("yi-9b", 8.2e9, 9.5e9),
        ("whisper-large-v3", 1.4e9, 1.7e9),
        ("granite-moe-3b-a800m", 3.0e9, 3.6e9),
        ("llama4-maverick-400b-a17b", 385e9, 410e9),
        ("llava-next-mistral-7b", 6.7e9, 7.6e9),
        ("mamba2-780m", 0.72e9, 0.84e9),
        ("hymba-1.5b", 1.4e9, 1.7e9),
        ("mistral-large-123b", 118e9, 126e9),
    ],
)
def test_param_count_matches_public_size(arch, lo, hi):
    """The config formulas land at the model's public parameter count."""
    n = get_config(arch).param_count()
    assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9}, {hi/1e9}]"


def test_maverick_active_params_about_17b():
    cfg = get_config("llama4-maverick-400b-a17b")
    a = cfg.active_param_count()
    assert 15e9 <= a <= 19e9, f"active {a/1e9:.1f}B"


@pytest.mark.parametrize("arch", ASSIGNED)
def test_smoke_param_count_formula_matches_init(arch, built):
    """param_count() (unpadded) vs actual init (padded vocab/experts):
    init must be >= formula and within the padding slack."""
    cfg, model, params = built(arch)
    actual = sum(int(np.prod(p.shape)) for p in jax.tree.leaves(params))
    formula = cfg.param_count()
    pad_slack = (cfg.padded_vocab - cfg.vocab_size) * cfg.d_model * 2 + 1_000_000
    if cfg.num_experts:
        mats = 3 if cfg.mlp_type == "swiglu" else 2
        pad_slack += (
            (cfg.padded_experts - cfg.num_experts)
            * (mats * cfg.d_model * cfg.d_ff + cfg.d_model)
            * (cfg.num_layers // cfg.moe_every)
        )
    assert formula * 0.85 <= actual <= formula + pad_slack, (
        f"{arch}: formula {formula} vs actual {actual} (slack {pad_slack})"
    )


def test_long_context_gate():
    """long_500k runs only for sub-quadratic archs (DESIGN.md §4)."""
    sub = {a for a in ASSIGNED if get_config(a).is_subquadratic}
    assert sub == {"mamba2-780m", "hymba-1.5b"}
