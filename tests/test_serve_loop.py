"""Streaming serving API: ServeLoop continuous batching + RequestHandle.

Covers the PR 5 redesign:
  * per-request handles (status machine, incremental token stream,
    metrics) over an event-driven tick loop;
  * continuous batching observables — a request submitted mid-decode
    produces its first token BEFORE the earlier cohort finishes, and
    joins/leaves never perturb cohabitants' tokens;
  * shim-vs-loop token identity on seeded workloads (generate /
    generate_many are thin shims over the loop);
  * edge cases: EOS leave while a co-batched request retries a torn
    pull, queued-dispatch admission rejection, handle status
    transitions;
  * satellites: hedged prefill dispatch, prefix-affinity routing.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.registry import build_model
from repro.models.transformer import DecoderLM
from repro.serving.disagg import DisaggService
from repro.serving.handle import HandleStatus, RequestHandle
from repro.serving.request import RequestState


@pytest.fixture(scope="module")
def service_setup():
    cfg = get_smoke_config("deepseek-67b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


@pytest.fixture(scope="module")
def dense_setup():
    """unroll=True model: the layerwise step is bit-identical, so token
    streams are comparable across consumer modes."""
    cfg = get_smoke_config("deepseek-67b")
    model = DecoderLM(cfg, unroll=True)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def monolithic_generate(model, params, tokens, n):
    logits, state = model.prefill(params, {"tokens": jnp.asarray(tokens[None])},
                                  remat=False)
    out = [int(jnp.argmax(logits[0, : model.cfg.vocab_size]))]
    tok = jnp.asarray([out[-1]], jnp.int32)
    for _ in range(n):
        logits, state = model.decode_step(params, state, tok)
        tok = jnp.argmax(logits[:, : model.cfg.vocab_size], axis=-1).astype(jnp.int32)
        out.append(int(tok[0]))
    return out


def _toks(cfg, seed, n=64):
    return np.random.default_rng(seed).integers(
        0, cfg.vocab_size, n).astype(np.int32)


class TestHandleStreaming:
    def test_submit_returns_streaming_handle(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        tokens = _toks(cfg, 0)
        ref = monolithic_generate(model, params, tokens, 4)

        h = svc.submit(tokens, max_new=4)
        assert isinstance(h, RequestHandle)
        assert h.tokens == ref[:1]  # eager dispatch: first token immediately
        seen = list(h.next_tokens())
        while not h.finished:
            svc.loop.tick()
            seen.extend(h.next_tokens())
        assert seen == ref and h.tokens == ref
        assert h.status is HandleStatus.DONE and h.done
        # metrics: TTFT/TTLT recorded, KV bytes measured by the engine
        assert h.metrics.ttft_s is not None and h.metrics.ttft_s >= 0
        assert h.metrics.ttlt_s >= h.metrics.ttft_s
        assert len(h.metrics.token_times) == len(ref)
        assert h.metrics.kv_bytes_pulled > 0
        assert not svc.pending and not svc.handles

    def test_handle_iterator_drives_the_loop(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        tokens = _toks(cfg, 1)
        ref = monolithic_generate(model, params, tokens, 3)
        h = svc.submit(tokens, max_new=3)
        assert list(h) == ref  # __iter__ ticks until DONE
        assert h.done

    def test_result_drives_to_completion(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        tokens = _toks(cfg, 2)
        h = svc.submit(tokens, max_new=2)
        assert h.result() == monolithic_generate(model, params, tokens, 2)

    def test_status_transitions_queued_dispatch(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        svc.loop.engine_budget = 2  # slow the pull so TRANSFERRING shows
        h = svc.submit(_toks(cfg, 3), max_new=2, dispatch="queued")
        observed = [h.status]
        assert h.status is HandleStatus.QUEUED  # nothing ran yet
        while not h.finished:
            svc.loop.tick()
            if h.status is not observed[-1]:
                observed.append(h.status)
        # monotone walk of the public machine (PREFILLING is transited
        # synchronously inside a tick, so it may not be observable)
        order = [HandleStatus.QUEUED, HandleStatus.PREFILLING,
                 HandleStatus.TRANSFERRING, HandleStatus.DECODING,
                 HandleStatus.DONE]
        assert observed == [s for s in order if s in observed]
        assert observed[0] is HandleStatus.QUEUED
        assert HandleStatus.TRANSFERRING in observed
        assert observed[-1] is HandleStatus.DONE

    def test_queued_dispatch_admission_rejection_fails_handle(self, service_setup):
        cfg, model, params = service_setup
        from repro.sched import AdmissionRejected
        svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                            num_blocks=64, policy="slo",
                            prefill_time_fn=lambda n: n / 10.0,  # ~10 tok/s
                            slo_classes={"interactive": 0.5})
        h = svc.submit(_toks(cfg, 4), slo_class="interactive",
                       max_new=2, dispatch="queued")
        assert h.status is HandleStatus.QUEUED
        svc.loop.tick()
        assert h.status is HandleStatus.FAILED and h.failed
        assert h.request_id not in svc.handles  # rejection is terminal
        # result()/iteration surface the REJECTION, not dead advice to
        # retry_parked (the request is gone from pending)
        with pytest.raises(AdmissionRejected):
            h.result()
        with pytest.raises(AdmissionRejected):
            list(h)

    def test_generate_many_restores_loop_pump_budget(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        before = svc.loop.pump_budget
        svc.generate_many([svc.submit(_toks(cfg, 70))], max_new=1,
                          pump_budget=None)
        assert svc.loop.pump_budget == before  # shared loop: no leak

    def test_finish_retires_engine_byte_counter(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        h = svc.submit(_toks(cfg, 71), max_new=1)
        svc.loop.run_until_idle()
        assert h.metrics.kv_bytes_pulled > 0    # sealed on the handle...
        assert svc.engine.pulled_bytes(h.request_id) == 0  # ...counter gone

    def test_eos_as_first_token_finishes_without_decode(self, service_setup):
        """EOS produced by PREFILL terminates the stream before any pull
        or decode step; the prefill copy is released even though no
        COMPLETE ever fires."""
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        tokens = _toks(cfg, 80)
        first = monolithic_generate(model, params, tokens, 0)[0]
        h = svc.submit(tokens, max_new=8, eos_token=first)
        svc.loop.run_until_idle()
        assert h.done and h.tokens == [first]
        assert svc.prefills[h.prefill_worker].pool.stats.in_use == 0
        assert svc.decode.pool.stats.in_use == 0  # no pull ever ran

    def test_queued_dispatch_retries_after_prefill_pool_frees(self, service_setup):
        """A queued submission whose prefill pool is momentarily full
        stays QUEUED (not wedged in PREFILLING) and dispatches once
        capacity returns."""
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        hog = svc.prefills["p0"].pool.allocate(63)  # 1 free, need 2
        h = svc.submit(_toks(cfg, 81), max_new=2, dispatch="queued")
        svc.loop.tick()
        assert h.status is HandleStatus.QUEUED  # full pool: still queued
        svc.prefills["p0"].pool.free(hog)
        svc.loop.run_until_idle()
        assert h.done and len(h.tokens) == 3

    def test_parked_request_auto_revives_on_tick(self, service_setup):
        """Regression: a request parked FAILED by failover overflow
        revives through ``tick()`` ALONE once live requests finish and
        their blocks return — no user-driven ``retry_parked()`` call."""
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=2, n_decode=1,
                            num_blocks=8)
        hs = [svc.submit(_toks(cfg, 90 + i), max_new=2) for i in range(6)]
        svc.fail_prefill_worker("p0")  # survivor can't absorb everyone
        parked = [h for h in hs if h.request.state is RequestState.FAILED]
        assert parked  # overflow parked at least one request
        for _ in range(400):
            if all(h.finished for h in hs):
                break
            svc.loop.tick()
        assert all(h.done and len(h.tokens) == 3 for h in hs)

    def test_legacy_direct_finish_does_not_wedge_the_loop(self, service_setup):
        """A request finished through the direct DecodeWorker path (the
        fig_overlap/fig_continuous benchmark pattern) is swept by the
        next tick instead of blocking run_until_idle forever."""
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        h = svc.submit(_toks(cfg, 82))
        svc.admit_queued(only={h.request_id})
        svc.pump(None)
        out = svc.decode.decode_round(2)
        svc.decode.finish(h.request_id)
        assert h.request_id in out and h.done
        svc.loop.run_until_idle()  # must return, not stall on the DONE handle
        svc.loop.tick()            # ...and the next tick sweeps it out
        assert h.request_id not in svc.handles

    def test_eos_token_leaves_early(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        tokens = _toks(cfg, 5)
        ref = monolithic_generate(model, params, tokens, 4)
        h = svc.submit(tokens, max_new=8, eos_token=ref[2])  # 2nd decode token
        svc.loop.run_until_idle()
        assert h.done
        assert h.tokens == ref[:3]  # stopped AT the EOS token
        assert svc.decode.pool.stats.in_use == 0  # blocks freed on leave


class TestContinuousBatching:
    def test_mid_decode_join_first_token_before_cohort_ends(self, service_setup):
        """The acceptance observable: B submitted while A is mid-decode
        gets its first DECODE token before A finishes — late admissions
        no longer wait for the running cohort."""
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        tok_a, tok_b = _toks(cfg, 6), _toks(cfg, 7)
        ref_a = monolithic_generate(model, params, tok_a, 8)
        ref_b = monolithic_generate(model, params, tok_b, 3)

        ha = svc.submit(tok_a, max_new=8)
        while ha.decoded < 3:  # A mid-decode
            svc.loop.tick()
        assert not ha.finished
        hb = svc.submit(tok_b, max_new=3)
        svc.loop.run_until_idle()
        assert ha.tokens == ref_a and hb.tokens == ref_b
        # B's first decode token (token_times[1]; [0] is the prefill
        # token) landed strictly before A's last — continuous batching,
        # observable purely via handle metrics
        assert len(hb.metrics.token_times) == 4
        assert hb.metrics.token_times[1] < ha.metrics.last_token_at

    def test_leave_does_not_stall_or_perturb_cohabitants(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        tok_a, tok_b = _toks(cfg, 8), _toks(cfg, 9)
        ref_a = monolithic_generate(model, params, tok_a, 2)
        ref_b = monolithic_generate(model, params, tok_b, 6)
        ha = svc.submit(tok_a, max_new=2)   # leaves early
        hb = svc.submit(tok_b, max_new=6)   # keeps decoding after A left
        svc.loop.run_until_idle()
        assert ha.tokens == ref_a
        assert hb.tokens == ref_b  # rebuild after A's leave was lossless

    def test_staggered_joins_match_monolithic(self, service_setup):
        """Requests trickling in over many ticks (join at different
        batch sizes) all produce monolithic-identical streams."""
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=2, n_decode=2, num_blocks=64)
        toks = [_toks(cfg, 10 + i) for i in range(4)]
        refs = [monolithic_generate(model, params, t, 4) for t in toks]
        handles = []
        for t in toks:
            handles.append(svc.submit(t, max_new=4))
            svc.loop.tick()  # earlier submissions are already decoding
        svc.loop.run_until_idle()
        for h, ref in zip(handles, refs):
            assert h.tokens == ref

    def test_shims_are_token_identical_to_loop(self, service_setup):
        """generate/generate_many are thin shims over the loop: same
        seeded workload, three drive styles, identical streams."""
        cfg, model, params = service_setup
        toks = [_toks(cfg, 20 + i) for i in range(3)]
        outs = []
        # (a) batch shim
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        reqs = [svc.submit(t) for t in toks]
        got = svc.generate_many(reqs, max_new=3)
        outs.append([got[r.request_id] for r in reqs])
        # (b) single-request shim (the SAME path, satellite fix)
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        outs.append([svc.generate(svc.submit(t), max_new=3) for t in toks])
        # (c) raw loop
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        handles = [svc.submit(t, max_new=3) for t in toks]
        svc.loop.run_until_idle()
        outs.append([list(h.tokens) for h in handles])
        assert outs[0] == outs[1] == outs[2]
        for i, t in enumerate(toks):
            assert outs[0][i] == monolithic_generate(model, params, t, 3)

    def test_consecutive_layerwise_joins_are_lossless(self, dense_setup):
        """Regression: a layerwise streaming join must COMMIT its step
        (context_len/last_token) immediately — a second join on the next
        tick rebuilds from those fields, and stale values replayed the
        joiner's token and dropped its appended KV page."""
        cfg, model, params = dense_setup
        svc = DisaggService(model, params, n_prefill=2, n_decode=1,
                            num_blocks=64, consume="layerwise")
        toks = [_toks(cfg, 90 + i) for i in range(3)]
        refs = [monolithic_generate(model, params, t, 5) for t in toks]
        handles = [svc.submit(toks[0], max_new=5)]
        while handles[0].decoded < 1:
            svc.loop.tick()
        handles.append(svc.submit(toks[1], max_new=5))
        svc.loop.tick()  # B streams into this tick's step...
        handles.append(svc.submit(toks[2], max_new=5))
        svc.loop.run_until_idle()  # ...and C's join rebuilds around it
        for h, ref in zip(handles, refs):
            assert h.tokens == ref

    def test_eos_leave_while_cobatched_pull_retries_torn(self, dense_setup):
        """Edge case from the issue: request A leaves at EOS in the same
        window where co-batched B is retrying a torn layerwise pull —
        survivors' streams must be unperturbed and B must still finish."""
        cfg, model, params = dense_setup
        svc = DisaggService(model, params, n_prefill=2, n_decode=1,
                            num_blocks=64, consume="layerwise")
        tok_a, tok_b = _toks(cfg, 30), _toks(cfg, 31)
        ref_a = monolithic_generate(model, params, tok_a, 2)
        ref_b = monolithic_generate(model, params, tok_b, 4)

        # A decoding; stop it at its 2nd decode token via EOS
        ha = svc.submit(tok_a, max_new=8, eos_token=ref_a[2])
        while ha.decoded < 1:
            svc.loop.tick()
        # B's pull will tear at layer 1 (prefill source dies mid-stream)
        hb = svc.submit(tok_b, max_new=4)
        victim = hb.prefill_worker
        svc.admit_queued(only={hb.request_id})
        fut = svc.decode.inflight[hb.request_id].future
        fut.add_layer_callback(
            lambda f, layer: layer == 1 and svc.fail_prefill_worker(victim))
        svc.loop.run_until_idle()
        assert ha.done and ha.tokens == ref_a[:3]  # left at EOS
        assert hb.done and hb.tokens == ref_b     # torn, re-routed, finished
        assert hb.retries == 1
        assert svc.decode.pool.stats.in_use == 0


class TestHedgedPrefill:
    def test_hedge_twin_freed_when_primary_completes(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=2, n_decode=1, num_blocks=64)
        tokens = _toks(cfg, 40)
        ref = monolithic_generate(model, params, tokens, 3)
        h = svc.submit(tokens, hedge=2)
        assert h.metrics.hedged
        twin = svc.hedges[h.request_id]
        assert twin.worker_id != h.prefill_worker
        assert twin.first_token == h.tokens[0]  # same compute, same token
        tw_pool = svc.prefills[twin.worker_id].pool
        assert tw_pool.stats.in_use > 0  # twin KV parked
        out = svc.generate(h, max_new=3)
        assert out == ref
        # primary's COMPLETE decided the race: loser aborted, slab freed
        assert h.request_id not in svc.hedges
        assert tw_pool.stats.in_use == 0

    def test_hedge_adopted_on_primary_death_no_reprefill(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=2, n_decode=1, num_blocks=64)
        tokens = _toks(cfg, 41)
        ref = monolithic_generate(model, params, tokens, 3)
        h = svc.submit(tokens, hedge=2)
        primary, twin_wid = h.prefill_worker, svc.hedges[h.request_id].worker_id
        tw_pool = svc.prefills[twin_wid].pool
        held_before = tw_pool.stats.in_use
        svc.fail_prefill_worker(primary)
        # failover adopted the twin's copy instead of re-prefilling: same
        # worker, same slab footprint, no new prefill compute charged
        assert h.prefill_worker == twin_wid
        assert h.request.state is RequestState.KV_QUEUED
        assert h.metrics.hedge_adopted
        assert tw_pool.stats.in_use == held_before  # adopted, not recomputed
        assert h.request_id not in svc.hedges  # twin consumed
        assert svc.generate(h, max_new=3) == ref

    def test_hedge_degrades_gracefully_with_one_worker(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        tokens = _toks(cfg, 42)
        h = svc.submit(tokens, hedge=2)  # no second worker: no twin
        assert h.request_id not in svc.hedges
        assert not h.metrics.hedged
        assert len(svc.generate(h, max_new=2)) == 3


class TestPrefixAffinityRouting:
    def test_repeat_prefix_routes_to_retaining_worker(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=2,
                            num_blocks=64, policy="prefix_affinity")
        tokens = _toks(cfg, 50)
        h1 = svc.submit(tokens, prefix_id="sys-prompt")
        w1 = h1.decode_worker
        svc.generate(h1, max_new=2)
        dw = svc.decodes[w1]
        # the finished request's prefix blocks stay refcounted in the pool
        assert "sys-prompt" in dw.prefix_cache
        assert dw.pool.stats.in_use == len(dw.prefix_cache["sys-prompt"]) > 0
        # same prefix -> same worker (affinity); fresh prefix -> the
        # other, less-loaded worker (fallback to least_loaded)
        h2 = svc.submit(tokens, prefix_id="sys-prompt")
        assert h2.decode_worker == w1
        h3 = svc.submit(_toks(cfg, 51), prefix_id="other")
        assert h3.decode_worker != w1
        svc.generate_many([h2, h3], max_new=2)

    def test_prefix_cache_evicted_under_pressure(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        dw = svc.decode
        h1 = svc.submit(_toks(cfg, 52), prefix_id="p0")
        svc.generate(h1, max_new=2)
        retained = dw.pool.stats.in_use
        assert retained > 0 and "p0" in dw.prefix_cache
        # hog the pool so the next admission only fits if the retained
        # prefix is evicted
        hog = dw.pool.allocate(dw.pool.num_free - 1)
        h2 = svc.submit(_toks(cfg, 53))
        assert len(svc.generate(h2, max_new=2)) == 3  # evicted, not stuck
        assert "p0" not in dw.prefix_cache
        dw.pool.free(hog)

    def test_load_reports_carry_prefix_ids(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        h = svc.submit(_toks(cfg, 54), prefix_id="pfx")
        svc.generate(h, max_new=2)
        svc._report_loads()
        rep = svc.scheduler.load(svc.decode.info.worker_id)
        assert "pfx" in rep.prefix_ids


class TestWorkerStep:
    def test_step_equals_decode_round_tokens(self, service_setup):
        """decode_round is step() run to a fixed budget: same residents,
        same tokens, either way."""
        cfg, model, params = service_setup
        tokens = _toks(cfg, 60)
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        r = svc.submit(tokens)
        assert svc.admit_to_decode(r.request)
        round_out = svc.decode.decode_round(4)[r.request_id]

        svc2 = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        r2 = svc2.submit(tokens)
        assert svc2.admit_to_decode(r2.request)
        step_out = []
        for _ in range(4):
            step_out.append(svc2.decode.step()[r2.request_id])
        assert step_out == round_out

    def test_margin_exhaustion_rebuild_is_lossless(self, service_setup):
        """Decode far enough past the page margin to force mid-stream
        state rebuilds; the stream must still match monolithic."""
        cfg, model, params = service_setup
        bs = model.BLOCK_SIZE
        n_steps = 2 * bs + 3  # crosses >= 2 page boundaries
        tokens = _toks(cfg, 61, n=bs)
        ref = monolithic_generate(model, params, tokens, n_steps)
        svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                            num_blocks=64)
        h = svc.submit(tokens, max_new=n_steps)
        svc.loop.run_until_idle()
        assert h.tokens == ref
