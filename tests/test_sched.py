"""Scheduling subsystem: load reports, policies, router, N x M serving.

Acceptance anchors:
  (a) the network-aware policy beats round-robin on modeled aggregate
      transfer cost for a skewed topology/workload;
  (b) the SLO admission controller keeps admitted-request projected TTFT
      under the deadline while round-robin admits violations;
plus end-to-end failover for both roles, liveness-driven (reap_dead)
failover, monotonic worker ids, and MR overlap rejection.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.core.cluster import ClusterScheduler
from repro.core.connection import ChipInfo, WorkerInfo
from repro.core.transfer_engine import LinkModel, MemoryRegion, TransferEngine
from repro.models.registry import build_model
from repro.sched import (
    AdmissionRejected,
    Candidate,
    LoadReport,
    NetworkAwarePolicy,
    RequestRouter,
    RoundRobinPolicy,
    RouteRequest,
    SLOAwarePolicy,
    make_policy,
)
from repro.sched.policies import LeastLoadedPolicy
from repro.serving.blocks import OutOfBlocks
from repro.serving.disagg import DisaggService
from repro.serving.request import RequestState
from repro.sim.costs import CostModel, H100_NODE
from repro.sim.events import ClusterSim, SimConfig
from repro.sim.workloads import fixed_requests


def winfo(wid, role):
    return WorkerInfo(wid, role, f"host-{wid}", (ChipInfo(0, f"ici://{wid}/0"),))


def cluster(n_prefill=2, n_decode=2, *, free=64, total=64):
    cs = ClusterScheduler()
    for i in range(n_prefill):
        cs.add_worker(winfo(f"p{i}", "prefill"))
        cs.heartbeat(f"p{i}", 0.0, load=LoadReport(f"p{i}", "prefill", free, total))
    for i in range(n_decode):
        cs.add_worker(winfo(f"d{i}", "decode"))
        cs.heartbeat(f"d{i}", 0.0, load=LoadReport(f"d{i}", "decode", free, total))
    return cs


def ctx(rid="r0", prompt=256, kv_bytes=1 << 20, slo="standard"):
    return RouteRequest(rid, prompt, kv_bytes=kv_bytes, slo_class=slo)


# ---------------------------------------------------------------- load
class TestLoadPiggyback:
    def test_heartbeat_carries_load_report(self):
        cs = ClusterScheduler()
        cs.add_worker(winfo("p0", "prefill"))
        rep = LoadReport("p0", "prefill", free_blocks=10, total_blocks=64,
                         queued_tokens=96, t=1.0)
        cs.heartbeat("p0", 1.0, load=rep)
        assert cs.load("p0") is rep
        assert cs.loads("prefill") == {"p0": rep}
        assert rep.queued_blocks == 3
        cs.remove_worker("p0")
        assert cs.load("p0") is None

    def test_plain_heartbeat_keeps_previous_report(self):
        cs = ClusterScheduler()
        cs.add_worker(winfo("d0", "decode"))
        rep = LoadReport("d0", "decode", 5, 64)
        cs.heartbeat("d0", 1.0, load=rep)
        cs.heartbeat("d0", 2.0)  # liveness-only ping
        assert cs.load("d0") is rep


# ------------------------------------------------------------- policies
class TestPolicies:
    def test_round_robin_cycles(self):
        p = RoundRobinPolicy()
        cands = [Candidate("d1"), Candidate("d0")]
        picks = [p.pick_decode(ctx(), cands).worker_id for _ in range(4)]
        assert picks == ["d0", "d1", "d0", "d1"]

    def test_least_loaded_counts_queue(self):
        p = LeastLoadedPolicy()
        cands = [
            Candidate("d0", free_units=32, total_units=64, queued_units=40),
            Candidate("d1", free_units=30, total_units=64, queued_units=0),
        ]
        # d0 has more free blocks but a deep queue — d1 wins
        assert p.pick_decode(ctx(), cands).worker_id == "d1"

    def test_network_aware_minimizes_transfer_cost(self):
        p = NetworkAwarePolicy()
        cands = [
            Candidate("d0", free_units=64, total_units=64, transfer_cost_s=0.010),
            Candidate("d1", free_units=10, total_units=64, transfer_cost_s=0.002),
        ]
        assert p.pick_decode(ctx(), cands).worker_id == "d1"

    def test_slo_admission_boundary(self):
        p = SLOAwarePolicy({"interactive": 0.5, "batch": float("inf")})
        assert p.admit(ctx(slo="interactive"), 0.4)
        assert not p.admit(ctx(slo="interactive"), 0.6)
        assert p.admit(ctx(slo="batch"), 1e9)

    def test_make_policy_rejects_unknown(self):
        with pytest.raises(ValueError, match="unknown policy"):
            make_policy("lifo")


# --------------------------------------------------------------- router
class TestRouter:
    def test_routes_to_least_loaded(self):
        cs = cluster(2, 2)
        cs.heartbeat("p0", 0.0, load=LoadReport("p0", "prefill", 4, 64))
        cs.heartbeat("d1", 0.0, load=LoadReport("d1", "decode", 4, 64))
        r = RequestRouter(cs, "least_loaded")
        d = r.route(ctx())
        assert d.prefill_worker == "p1" and d.decode_worker == "d0"

    def test_frozen_load_report_distrusted_past_cutoff(self):
        """Staleness guard: a LoadReport frozen longer than 2.5
        heartbeats must stop attracting work — the router scores the
        worker as fully loaded, so a fresh-but-busier sibling wins even
        though the frozen report advertises an empty pool."""
        cs = cluster(2, 2)
        now = 2.5 * cs.heartbeat_timeout_s + 1.0  # past the default cutoff
        # d0 keeps its liveness pings but its report stays frozen at
        # t=0 (advertising 64/64 free); d1 is nearly full but FRESH
        cs.heartbeat("d0", now)
        cs.heartbeat("d1", now, load=LoadReport("d1", "decode", 8, 64, t=now))
        for wid in ("p0", "p1"):
            cs.heartbeat(wid, now,
                         load=LoadReport(wid, "prefill", 32, 64, t=now))
        r = RequestRouter(cs, "least_loaded")
        d = r.route(ctx(), now=now)
        assert d.decode_worker == "d1"

    def test_stale_cutoff_override(self):
        """``stale_after_s`` overrides the heartbeat-derived cutoff: the
        same frozen report is distrusted under a tight cutoff and still
        trusted under a lax one."""
        cs = cluster(1, 2)
        cs.heartbeat("d0", 3.0)  # liveness only: report stays t=0
        cs.heartbeat("d1", 3.0, load=LoadReport("d1", "decode", 8, 64, t=3.0))
        cs.heartbeat("p0", 3.0, load=LoadReport("p0", "prefill", 32, 64, t=3.0))
        tight = RequestRouter(cs, "least_loaded", stale_after_s=1.0)
        assert tight.route(ctx("rt"), now=3.0).decode_worker == "d1"
        lax = RequestRouter(cs, "least_loaded", stale_after_s=10.0)
        assert lax.route(ctx("rl"), now=3.0).decode_worker == "d0"

    def test_network_aware_beats_round_robin_on_transfer_cost(self):
        """Acceptance (a): skewed workload — all KV lands on one hot
        prefill worker whose link to d1 is ~10x slower; the
        network-aware router's aggregate modeled transfer cost must come
        out well below round-robin's (which alternates onto the slow
        path half the time)."""
        fast, slow = LinkModel.ici(), LinkModel(bandwidth_Bps=5e9, post_overhead_s=2e-5)
        links = {("p0", "d0"): fast, ("p0", "d1"): slow}
        costs = {}
        for pol in ("round_robin", "network_aware"):
            r = RequestRouter(cluster(1, 2), pol, links=links)
            for i in range(16):
                r.route(ctx(f"r{i}", prompt=4096, kv_bytes=32 << 20), now=float(i))
            costs[pol] = r.total_transfer_cost_s
        assert costs["network_aware"] < 0.5 * costs["round_robin"]

    def test_slo_admission_keeps_projected_ttft_under_deadline(self):
        """Acceptance (b): under a burst, every ADMITTED request's
        projected TTFT stays under the deadline (the rest are rejected),
        while round-robin admits requests that already miss it."""
        deadline = 0.5
        prefill_fn = lambda n: 0.2  # 0.2 s per prefill, burst at t=0

        slo = RequestRouter(cluster(2, 2), "slo", prefill_time_fn=prefill_fn,
                            classes={"interactive": deadline})
        admitted, rejected = [], 0
        for i in range(12):
            try:
                admitted.append(slo.route(ctx(f"r{i}", slo="interactive"), now=0.0))
            except AdmissionRejected:
                rejected += 1
        assert admitted and rejected
        assert all(d.projected_ttft_s <= deadline for d in admitted)

        rr = RequestRouter(cluster(2, 2), "round_robin", prefill_time_fn=prefill_fn)
        rr_decisions = [rr.route(ctx(f"r{i}", slo="interactive"), now=0.0)
                        for i in range(12)]
        assert any(d.projected_ttft_s > deadline for d in rr_decisions)

    def test_backlog_queues_and_drains(self):
        prefill_fn = lambda n: 0.2
        r = RequestRouter(cluster(1, 1), "slo", prefill_time_fn=prefill_fn,
                          classes={"interactive": 0.5})
        routed = [r.route(ctx(f"r{i}", slo="interactive"), now=0.0,
                          queue_on_reject=True) for i in range(4)]
        assert sum(d is not None for d in routed) == 2  # 0.2s, 0.4s fit
        assert len(r.backlog) == 2
        assert r.drain_backlog(now=0.0) == []  # still saturated
        drained = r.drain_backlog(now=10.0)   # ledger drained by then
        assert len(drained) == 2 and not r.backlog

    def test_forget_retires_ledger_charge(self):
        """Regression: a completed prefill must stop counting against
        future SLO admission projections."""
        r = RequestRouter(cluster(1, 1), "slo", prefill_time_fn=lambda n: 0.3,
                          classes={"interactive": 0.5})
        r.route(ctx("a", slo="interactive"), now=0.0)
        with pytest.raises(AdmissionRejected):
            r.route(ctx("b", slo="interactive"), now=0.0)  # a still charged
        r.forget("a")  # a's prefill completed
        d = r.route(ctx("c", slo="interactive"), now=0.0)
        assert d is not None and d.projected_ttft_s <= 0.5

    def test_no_workers_raises(self):
        from repro.sched import NoWorkersError

        cs = ClusterScheduler()
        cs.add_worker(winfo("p0", "prefill"))
        with pytest.raises(NoWorkersError):
            RequestRouter(cs).route(ctx())

    def test_requeue_puts_request_at_head(self):
        r = RequestRouter(cluster(1, 1), "least_loaded")
        r.backlog.append(ctx("r-old"))
        r.requeue(ctx("r-failed"))
        assert [c.request_id for c in r.backlog] == ["r-failed", "r-old"]
        s = r.summary()
        assert s["backlog"] == 2.0 and s["rejected"] == 0.0

    def test_pick_hedge_prefill_excludes_primary(self):
        r = RequestRouter(cluster(2, 1), "least_loaded")
        d = r.route(ctx("r0"))
        twin = r.pick_hedge_prefill(ctx("r0"), {d.prefill_worker})
        assert twin is not None and twin != d.prefill_worker
        # both charges retire together
        assert r._charges.keys() == {"r0", "r0#hedge"}
        r.forget("r0")
        assert not r._charges

    def test_pick_hedge_prefill_none_without_alternative(self):
        r = RequestRouter(cluster(1, 1), "least_loaded")
        d = r.route(ctx("r0"))
        assert r.pick_hedge_prefill(ctx("r0"), {d.prefill_worker}) is None

    def test_prefix_affinity_prefers_reported_prefix(self):
        cs = cluster(1, 2)
        # d1 reports the prefix resident (and equal load otherwise)
        cs.heartbeat("d1", 0.0, load=LoadReport(
            "d1", "decode", 64, 64, prefix_ids=("sys",)))
        r = RequestRouter(cs, "prefix_affinity")
        hit = RouteRequest("r0", 256, prefix_id="sys")
        miss = RouteRequest("r1", 256, prefix_id="other")
        assert r.route(hit).decode_worker == "d1"
        assert r.route(miss).decode_worker == "d0"  # least-loaded fallback

    def test_evictable_blocks_count_toward_admission_budget(self):
        cs = cluster(1, 1, free=64, total=64)
        # 1 free block but 8 evictable: a 2-block request must be planned
        cs.heartbeat("d0", 0.0, load=LoadReport(
            "d0", "decode", free_blocks=1, total_blocks=64,
            evictable_blocks=8))
        r = RequestRouter(cs, "least_loaded")
        plan = r.plan_admissions([(ctx("r0", prompt=64), "d0")])
        assert plan == {"d0": ["r0"]}


# ------------------------------------------------------ transfer engine
class TestMemoryRegionOverlap:
    def test_overlapping_mrs_rejected(self):
        eng = TransferEngine()
        eng.register_memory(MemoryRegion("p0", 0x1000, np.zeros(4096, np.uint8)))
        with pytest.raises(ValueError, match="overlaps"):
            eng.register_memory(MemoryRegion("p1", 0x1800, np.zeros(4096, np.uint8)))

    def test_disjoint_mrs_accepted(self):
        eng = TransferEngine()
        eng.register_memory(MemoryRegion("p0", 0x1000, np.zeros(4096, np.uint8)))
        eng.register_memory(MemoryRegion("p1", 0x2000, np.zeros(4096, np.uint8)))


# ------------------------------------------------- end-to-end (real model)
@pytest.fixture(scope="module")
def service_setup():
    cfg = get_smoke_config("deepseek-67b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


class TestMultiWorkerService:
    def test_n_by_m_round_robin_spreads_both_roles(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=2, n_decode=2,
                            num_blocks=64, policy="round_robin")
        rng = np.random.default_rng(0)
        reqs = [svc.submit(rng.integers(0, cfg.vocab_size, 64).astype(np.int32))
                for _ in range(2)]
        assert {r.prefill_worker for r in reqs} == {"p0", "p1"}
        assert {r.decode_worker for r in reqs} == {"d0", "d1"}
        for r in reqs:
            out = svc.generate(r, max_new=2)
            assert len(out) == 3 and r.state == RequestState.DONE

    def test_worker_slabs_are_disjoint(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=3, n_decode=2, num_blocks=64)
        spans = sorted(
            (w.cache.base_address, w.cache.base_address + w.cache.nbytes)
            for w in [*svc.prefills.values(), *svc.decodes.values()]
        )
        for (_, hi), (lo, _) in zip(spans, spans[1:]):
            assert hi <= lo

    def test_worker_ids_monotonic_after_failure(self, service_setup):
        """Regression: p0 must NOT be reminted after fail_prefill_worker
        (the old id would collide with the dead worker's epoch)."""
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=2, n_decode=1, num_blocks=64)
        svc.fail_prefill_worker("p0")
        wid = svc.add_prefill_worker(num_blocks=64)
        assert wid == "p2"
        assert set(svc.prefills) == {"p1", "p2"}
        # and the fresh worker is connected + usable
        rng = np.random.default_rng(1)
        svc.prefills["p1"].pool.allocate(60)  # saturate p1 so p2 is picked
        req = svc.submit(rng.integers(0, cfg.vocab_size, 32).astype(np.int32))
        assert req.prefill_worker == "p2"
        assert len(svc.generate(req, max_new=2)) == 3

    def test_decode_failover_kv_queued(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=2, num_blocks=64)
        rng = np.random.default_rng(2)
        req = svc.submit(rng.integers(0, cfg.vocab_size, 64).astype(np.int32))
        victim, survivor = req.decode_worker, None
        svc.fail_decode_worker(victim)
        survivor = req.decode_worker
        assert survivor != victim and survivor in svc.decodes
        assert req.retries == 1 and req.state == RequestState.KV_QUEUED
        assert len(svc.generate(req, max_new=2)) == 3

    def test_decode_failover_resident_restarts_from_prefill(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=2, num_blocks=64)
        rng = np.random.default_rng(3)
        req = svc.submit(rng.integers(0, cfg.vocab_size, 64).astype(np.int32))
        assert svc.admit_to_decode(req)
        victim = req.decode_worker
        svc.fail_decode_worker(victim)
        # pulled KV died with the worker; request re-prefilled + re-routed
        assert req.decode_worker != victim
        assert req.retries == 1 and req.state == RequestState.KV_QUEUED
        assert len(svc.generate(req, max_new=2)) == 3

    def test_failover_capacity_exhaustion_parks_and_revives(self, service_setup):
        """Regression: when the survivor can't hold every re-prefill,
        OutOfBlocks must not escape the membership broadcast — overflow
        requests park as FAILED and revive via retry_parked()."""
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=2, n_decode=1, num_blocks=8)
        rng = np.random.default_rng(6)
        reqs = [svc.submit(rng.integers(0, cfg.vocab_size, 64).astype(np.int32))
                for _ in range(6)]  # 2 blocks each: both workers 6/8 full
        svc.fail_prefill_worker("p0")  # must not raise
        live = [r for r in reqs if r.state == RequestState.KV_QUEUED]
        parked = [r for r in reqs if r.state == RequestState.FAILED]
        assert parked and live  # survivor absorbed some, not all
        assert all(r.prefill_worker == "p1" for r in live)
        for cm in svc.conn_mgrs.values():
            assert cm.peers == ("p1",)  # teardown completed despite overflow
        with pytest.raises(RuntimeError, match="parked"):
            svc.generate(parked[0], max_new=2)  # meaningful, not KeyError
        for r in live:  # draining live requests frees survivor capacity
            assert len(svc.generate(r, max_new=2)) == 3
        # the serve loop auto-revives parked requests the same tick the
        # freed blocks land (docs/fleet.md), so by now nothing is left
        # for a manual retry_parked() sweep
        assert all(r.state is not RequestState.FAILED for r in parked)
        assert svc.retry_parked() == []
        for r in parked:
            assert len(svc.generate(r, max_new=2)) == 3

    def test_admit_out_of_blocks_keeps_kv_queued_and_retries(self, service_setup):
        """Regression: a full decode pool must leave the request in
        KV_QUEUED (not strand it in KV_TRANSFER) so the retry path
        works once capacity frees."""
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        rng = np.random.default_rng(9)
        req = svc.submit(rng.integers(0, cfg.vocab_size, 64).astype(np.int32))
        hog = svc.decode.pool.allocate(63)  # leave 1 free block (need 2)
        with pytest.raises(OutOfBlocks):
            svc.generate(req, max_new=2)
        assert req.state == RequestState.KV_QUEUED
        svc.decode.pool.free(hog)
        assert len(svc.generate(req, max_new=2)) == 3  # retry succeeds

    def test_reap_multiple_dead_no_cascading_restarts(self, service_setup):
        """Regression: when several workers lapse, failover must not
        re-route in-flight work onto a dead-but-not-yet-reaped worker
        (one wasted prefill per cascade step)."""
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=3, n_decode=1, num_blocks=64)
        rng = np.random.default_rng(10)
        req = svc.submit(rng.integers(0, cfg.vocab_size, 64).astype(np.int32),
                         now=0.0)
        assert req.prefill_worker == "p0"  # least-loaded tie-break
        svc.scheduler.heartbeat("p2", 10.0)
        svc.scheduler.heartbeat("d0", 10.0)
        dead = svc.reap_dead(10.0)
        assert set(dead) == {"p0", "p1"}
        assert req.prefill_worker == "p2"
        assert req.retries == 1  # exactly one re-route, no p1 detour
        assert len(svc.generate(req, max_new=2)) == 3

    def test_graceful_removal_migrates_requests(self, service_setup):
        """Regression: scale-DOWN (removed, not failed) must migrate
        in-flight requests too, for both roles."""
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=2, n_decode=2, num_blocks=64)
        rng = np.random.default_rng(8)
        req = svc.submit(rng.integers(0, cfg.vocab_size, 64).astype(np.int32))
        victim = req.prefill_worker
        svc.scheduler.remove_worker(victim)  # graceful drain
        assert req.prefill_worker != victim
        req2 = svc.submit(rng.integers(0, cfg.vocab_size, 64).astype(np.int32))
        victim2 = req2.decode_worker
        svc.scheduler.remove_worker(victim2)
        assert req2.decode_worker != victim2
        assert len(svc.generate(req, max_new=2)) == 3
        assert len(svc.generate(req2, max_new=2)) == 3

    def test_last_decode_worker_death_parks_request(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        rng = np.random.default_rng(7)
        req = svc.submit(rng.integers(0, cfg.vocab_size, 64).astype(np.int32))
        svc.fail_decode_worker("d0")
        assert req.state == RequestState.FAILED and req.decode_worker is None
        with pytest.raises(RuntimeError, match="parked"):
            svc.generate(req, max_new=2)
        kept_blocks = list(req.prefill_blocks)
        assert kept_blocks  # prefill KV survived the decode failure
        svc.add_decode_worker(num_blocks=64)
        assert svc.retry_parked() == [req.request_id]
        # revived WITHOUT recomputing prefill: same blocks, no extra retry
        assert req.prefill_blocks == kept_blocks and req.retries == 1
        assert len(svc.generate(req, max_new=2)) == 3

    def test_reap_dead_drives_end_to_end_failover(self, service_setup):
        """Liveness path: lapsed heartbeat → reap_dead → epoch
        invalidation → router re-routes the in-flight request."""
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=2, n_decode=2, num_blocks=64)
        rng = np.random.default_rng(4)
        req = svc.submit(rng.integers(0, cfg.vocab_size, 64).astype(np.int32),
                         now=0.0)
        victim = req.prefill_worker
        for wid in [*svc.prefills, *svc.decodes]:
            if wid != victim:
                svc.scheduler.heartbeat(wid, 10.0)
        dead = svc.reap_dead(10.0)  # timeout 5s: only the victim lapsed
        assert dead == [victim]
        assert victim not in svc.prefills
        assert req.prefill_worker != victim and req.retries == 1
        out = svc.generate(req, max_new=2)
        assert len(out) == 3 and req.state == RequestState.DONE

    def test_slo_service_rejects_and_serves(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64,
                            policy="slo", prefill_time_fn=lambda n: 0.3,
                            slo_classes={"interactive": 0.5})
        rng = np.random.default_rng(5)
        tok = lambda: rng.integers(0, cfg.vocab_size, 32).astype(np.int32)
        req = svc.submit(tok(), slo_class="interactive", now=0.0)
        with pytest.raises(AdmissionRejected):
            svc.submit(tok(), slo_class="interactive", now=0.0)
        assert len(svc.generate(req, max_new=2)) == 3


# ------------------------------------------------------------- simulator
class TestSimPolicies:
    @pytest.fixture(scope="class")
    def cost(self):
        from repro.configs import get_config

        return CostModel(get_config("mistral-large-123b"), H100_NODE)

    @pytest.mark.parametrize("policy", ["round_robin", "least_loaded", "network_aware"])
    def test_all_requests_finish_under_every_policy(self, cost, policy):
        reqs = fixed_requests(8192, 64, qps=1.0, duration_s=60, seed=8)
        sim = ClusterSim(cost, SimConfig(n_prefill=2, n_decode=2, policy=policy))
        res = sim.run(list(reqs))
        assert len(res.requests) == len(reqs) and not res.rejected
        for d in sim.decodes:
            assert d.used_tokens == 0 and not d.active

    def test_network_aware_beats_round_robin_under_skew(self, cost):
        # hot prefill worker, one slow decode path: round-robin sends
        # half the pulls over the 5x-slower link, network-aware none
        reqs = fixed_requests(32768, 64, qps=0.5, duration_s=120, seed=9)
        scales = {("p0", "d1"): 5.0}
        out = {}
        for pol in ("round_robin", "network_aware"):
            sim = ClusterSim(cost, SimConfig(n_prefill=1, n_decode=2, policy=pol),
                             link_scales=scales)
            out[pol] = sim.run(list(reqs)).summary()["mean_total_s"]
        assert out["network_aware"] < out["round_robin"]

    def test_slo_admission_bounds_served_ttft_at_overload(self, cost):
        reqs = fixed_requests(40000, 64, qps=1.0, duration_s=120, seed=10)
        base = ClusterSim(cost, SimConfig(n_prefill=1, n_decode=1,
                                          policy="round_robin")).run(list(reqs)).summary()
        slo = ClusterSim(cost, SimConfig(n_prefill=1, n_decode=1, policy="slo",
                                         slo_s=10.0)).run(list(reqs))
        s = slo.summary()
        assert s["n_rejected"] > 0                  # overload: some rejected
        assert s["p90_ttft_s"] < base["p90_ttft_s"]  # survivors protected
        assert s["p90_ttft_s"] < 15.0
