"""Block pool: invariants (hypothesis), reservation semantics, contiguity."""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.serving.blocks import BlockPool, OutOfBlocks


class TestBasics:
    def test_allocate_free_roundtrip(self):
        p = BlockPool(16)
        bs = p.allocate(4)
        assert len(bs) == 4 and p.num_free == 12
        p.free(bs)
        assert p.num_free == 16

    def test_all_or_nothing(self):
        p = BlockPool(4)
        p.allocate(3)
        with pytest.raises(OutOfBlocks) as ei:
            p.allocate(2)
        assert p.num_free == 1  # nothing partially taken
        # the failure message carries the pool occupancy snapshot so a
        # preemption-threshold tune doesn't need a debugger attached
        msg = str(ei.value)
        assert "need 2 blocks" in msg
        assert "3/4 used" in msg and "1 free" in msg
        assert "shared" in msg and "reserved" in msg

    def test_contiguous_preferred(self):
        p = BlockPool(16)
        bs = p.allocate(8)
        assert bs == list(range(bs[0], bs[0] + 8))

    def test_best_fit_leaves_long_runs(self):
        p = BlockPool(16)
        a = p.allocate(4)        # [0..3]
        b = p.allocate(4)        # [4..7]
        p.free(a)                # free run of 4 at head, run of 8 at tail
        c = p.allocate(3)
        assert c == [0, 1, 2]    # tight 4-run used, 8-run preserved

    def test_fragmented_allocation_still_succeeds(self):
        p = BlockPool(8)
        a = p.allocate(2)  # 0,1
        b = p.allocate(2)  # 2,3
        c = p.allocate(2)  # 4,5
        p.free(a); p.free(c)
        got = p.allocate(4)  # must stitch 0,1,4,5 (+6,7 run)
        assert len(got) == 4 and set(got).isdisjoint(b)

    def test_double_free_rejected(self):
        p = BlockPool(4)
        bs = p.allocate(2)
        p.free(bs)
        with pytest.raises(KeyError):
            p.free(bs)

    def test_blocks_for_tokens(self):
        assert BlockPool.blocks_for_tokens(1, 32) == 1
        assert BlockPool.blocks_for_tokens(32, 32) == 1
        assert BlockPool.blocks_for_tokens(33, 32) == 2


class TestReservation:
    def test_reserve_consumes_capacity(self):
        p = BlockPool(8)
        r = p.reserve(6)  # push-mode pre-allocation
        assert p.num_free == 2
        assert p.stats.reserved == 6 and p.stats.allocated == 0
        p.commit(r)
        assert p.stats.reserved == 0 and p.stats.allocated == 6

    def test_free_uncommitted_reservation(self):
        p = BlockPool(8)
        r = p.reserve(4)
        p.free(r)  # request cancelled before push finished
        assert p.num_free == 8 and p.stats.reserved == 0

    def test_pull_mode_admits_more_than_push_mode(self):
        # Motivation #3 in miniature: with 8 blocks and 4-block requests,
        # push-mode reserves for both at admission and fails the third;
        # pull-mode only holds blocks for requests actually decoding.
        push = BlockPool(8)
        push.reserve(4); push.reserve(4)
        with pytest.raises(OutOfBlocks):
            push.reserve(4)
        pull = BlockPool(8)
        a = pull.allocate(4)        # request 1 decoding
        pull.free(a)                # finished before request 2 transfers
        pull.allocate(4); pull.allocate(4)  # 2 and 3 fit fine


class TestPrefixSharing:
    def test_share_and_staged_free(self):
        p = BlockPool(8)
        bs = p.allocate(4)
        p.share(bs)
        p.free(bs)          # first consumer done
        assert p.num_free == 4  # still held by second consumer
        p.free(bs)
        assert p.num_free == 8


@settings(max_examples=200, deadline=None)
@given(st.lists(st.tuples(st.sampled_from(["alloc", "reserve", "free", "commit"]),
                          st.integers(1, 6)), max_size=60))
def test_pool_invariants_random_ops(ops):
    """Property: under any interleaving, capacity is conserved, no block is
    both free and held, and stats match the ground truth."""
    p = BlockPool(24)
    live: list[list[int]] = []
    reserved: list[list[int]] = []
    for op, n in ops:
        try:
            if op == "alloc":
                live.append(p.allocate(n))
            elif op == "reserve":
                reserved.append(p.reserve(n))
            elif op == "free" and (live or reserved):
                src = live if live else reserved
                p.free(src.pop())
            elif op == "commit" and reserved:
                bs = reserved.pop()
                p.commit(bs)
                live.append(bs)
        except OutOfBlocks:
            pass
        p.check_invariants()
    held = sum(len(x) for x in live) + sum(len(x) for x in reserved)
    assert p.num_free == 24 - held
