"""Property-based tests (hypothesis) for the placement planner.

For ANY generated heterogeneous cluster, ``PlacementPlanner.plan`` must
(1) give every machine exactly one role with >=1 prefill and >=1 decode,
(2) be deterministic given (spec, seed), (3) never score below the
same-seed uniform-random role assignment on the same spec, and (4)
report the score of the placement it returns.
"""
import math

import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.topo import (
    ClusterGenerator,
    ClusterSpec,
    PlacementPlanner,
    random_placement,
)


def _spec(n_machines: int, n_regions: int, seed: int) -> ClusterSpec:
    gen = ClusterGenerator(
        name="prop", n_machines=n_machines,
        n_regions=min(n_regions, n_machines),
        profile_mix=(("8xh100", 1.0), ("8xa100", 1.0), ("8xl4", 1.0)))
    return gen.generate(seed)


@given(n=st.integers(2, 8), regions=st.integers(1, 3),
       cluster_seed=st.integers(0, 50), plan_seed=st.integers(0, 10))
@settings(max_examples=25, deadline=None)
def test_plan_invariants(n, regions, cluster_seed, plan_seed):
    spec = _spec(n, regions, cluster_seed)
    planner = PlacementPlanner()
    p = planner.plan(spec, seed=plan_seed)
    # every machine exactly one role; >=1 prefill and >=1 decode
    assert sorted(p.prefill + p.decode) == sorted(spec.ids())
    assert not (set(p.prefill) & set(p.decode))
    assert len(p.prefill) >= 1 and len(p.decode) >= 1
    # deterministic given (spec, seed)
    assert planner.plan(spec, seed=plan_seed) == p
    # never below the same-seed random baseline
    rand = random_placement(spec, seed=plan_seed, planner=planner)
    assert p.score >= rand.score - 1e-9
    # the reported score is the score of the reported placement
    assert math.isclose(p.score, planner.score_placement(spec, p),
                        rel_tol=1e-12, abs_tol=1e-12)


@given(n=st.integers(3, 8), cluster_seed=st.integers(0, 50),
       k_p=st.integers(1, 3))
@settings(max_examples=15, deadline=None)
def test_pinned_plan_respects_counts(n, cluster_seed, k_p):
    spec = _spec(n, 1, cluster_seed)
    k_p = min(k_p, n - 1)
    p = PlacementPlanner().plan(spec, n_prefill=k_p)
    assert len(p.prefill) == k_p
    assert len(p.decode) == n - k_p
    assert not (set(p.prefill) & set(p.decode))
    assert set(p.prefill + p.decode) <= set(spec.ids())
