"""HLO collective-bytes parser: the §Roofline instrument must be right."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.launch.hlo_analysis import collective_bytes, parse_shape_bytes


class TestShapeParsing:
    @pytest.mark.parametrize("s,expected", [
        ("bf16[128,1024]", 128 * 1024 * 2),
        ("f32[16]", 64),
        ("(f32[4], bf16[8,8])", 16 + 128),
        ("pred[32]", 32),
        ("s32[2,2,2]", 32),
        ("token[]", 0),
        ("u8[100]", 100),
    ])
    def test_bytes(self, s, expected):
        assert parse_shape_bytes(s) == expected


class TestCollectiveExtraction:
    def _compile_psum(self):
        # build a real 8-device SPMD program with an all-reduce
        from jax.sharding import NamedSharding, PartitionSpec as P

        devs = jax.devices()
        if len(devs) < 2:
            pytest.skip("needs >1 device (run under dryrun's XLA_FLAGS)")
        mesh = jax.make_mesh((len(devs),), ("d",))
        x = jax.ShapeDtypeStruct((len(devs) * 4, 128), jnp.float32)
        f = jax.jit(
            lambda x: (x @ x.T).sum(),
            in_shardings=NamedSharding(mesh, P("d", None)),
        )
        return f.lower(x).compile().as_text()

    def test_synthetic_text(self):
        txt = """
  %ag = bf16[64,128]{1,0} all-gather(bf16[4,128]{1,0} %p), dimensions={0}
  %ar = f32[256]{0} all-reduce(f32[256]{0} %q), to_apply=%add
  %rs = f32[32]{0} reduce-scatter(f32[256]{0} %r), dimensions={0}
  %done = f32[8]{0} all-reduce-done(f32[8]{0} %s)
"""
        st = collective_bytes(txt)
        assert st.by_kind_count["all-gather"] == 1
        assert st.by_kind_bytes["all-gather"] == 64 * 128 * 2
        assert st.by_kind_count["all-reduce"] >= 1
        # ring model: all-reduce charged 2x
        assert st.wire_bytes >= st.total_bytes
        # f32 share tracked for the bf16 adjustment
        assert 0 < st.f32_wire_bytes <= st.wire_bytes
        assert st.wire_bytes_bf16_adjusted < st.wire_bytes

    def test_real_compiled_program(self):
        txt = self._compile_psum()
        st = collective_bytes(txt)
        assert st.total_bytes > 0, "expected a collective in a sharded matmul+sum"
