"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode.

Every kernel runs through pl.pallas_call with its real BlockSpec grid in
interpret mode (this container is CPU; TPU is the target) and must match
its ref.py oracle to tight tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_prefill.kernel import flash_prefill
from repro.kernels.flash_prefill.ref import dense_ref
from repro.kernels.kv_pull.kernel import kv_pull, kv_pull_dequant, kv_pull_runs
from repro.kernels.kv_pull.ref import (
    kv_pull_dequant_ref,
    kv_pull_ref,
    kv_pull_runs_ref,
)
from repro.kernels.paged_attention.kernel import paged_attention
from repro.kernels.paged_attention.ref import paged_attention_ref
from repro.kernels.ssd_scan.kernel import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_scan_ref

RNG = np.random.default_rng(42)

# Known-failing on the CPU container since the seed: these kernels build
# ``pltpu.CompilerParams`` from the TPU toolchain the repo targets, which
# this environment's jax doesn't expose (and interpret mode never reaches
# a real TPU compile).  Keyed on backend so a TPU runner still executes
# them; non-strict so a toolchain upgrade turns them green without churn.
pallas_tpu_only = pytest.mark.xfail(
    jax.default_backend() == "cpu",
    reason="pallas TPU kernel params unavailable on the CPU backend "
           "(seed-known failure; runs on TPU)",
    strict=False,
)


def arr(shape, dtype=jnp.float32, scale=1.0):
    return jnp.asarray(RNG.standard_normal(shape) * scale, dtype)


TOL = {jnp.float32: 2e-4, jnp.bfloat16: 2e-2}


@pallas_tpu_only
class TestPagedAttention:
    @pytest.mark.parametrize("b,h,g,d,per,bs", [
        (2, 4, 2, 64, 4, 32),
        (3, 8, 1, 128, 3, 32),   # MQA, granite-style
        (1, 8, 8, 64, 5, 16),    # MHA
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_matches_ref(self, b, h, g, d, per, bs, dtype):
        q = arr((b, h, d), dtype)
        kp, vp = arr((b, per, bs, g, d), dtype), arr((b, per, bs, g, d), dtype)
        tbl = jnp.broadcast_to(jnp.arange(per, dtype=jnp.int32)[None], (b, per))
        ctx = jnp.asarray(RNG.integers(1, per * bs, b), jnp.int32)
        ref = paged_attention_ref(q, kp, vp, tbl, ctx)
        out = paged_attention(q, kp, vp, tbl, ctx, interpret=True)
        tol = TOL[dtype]
        np.testing.assert_allclose(
            out.astype(jnp.float32), ref.astype(jnp.float32), rtol=tol, atol=tol)

    def test_permuted_block_table(self):
        """Pages stored out of order; the table restores the sequence."""
        b, h, g, d, per, bs = 1, 4, 2, 32, 4, 16
        q = arr((b, h, d))
        kp, vp = arr((b, per, bs, g, d)), arr((b, per, bs, g, d))
        perm = jnp.asarray([[2, 0, 3, 1]], jnp.int32)
        ctx = jnp.asarray([per * bs], jnp.int32)
        ref = paged_attention_ref(q, kp, vp, perm, ctx)
        out = paged_attention(q, kp, vp, perm, ctx, interpret=True)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)

    def test_single_token_context(self):
        b, h, g, d, per, bs = 2, 2, 1, 32, 2, 16
        q = arr((b, h, d))
        kp, vp = arr((b, per, bs, g, d)), arr((b, per, bs, g, d))
        tbl = jnp.broadcast_to(jnp.arange(per, dtype=jnp.int32)[None], (b, per))
        ctx = jnp.ones((b,), jnp.int32)
        ref = paged_attention_ref(q, kp, vp, tbl, ctx)
        out = paged_attention(q, kp, vp, tbl, ctx, interpret=True)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


class TestKVPull:
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.int8])
    def test_txn_list(self, dtype):
        src = jnp.asarray(RNG.integers(-100, 100, (12, 16, 2, 32)), dtype)
        dst = jnp.asarray(RNG.integers(-100, 100, (10, 16, 2, 32)), dtype)
        sid = jnp.asarray([0, 5, 11, 3], jnp.int32)
        did = jnp.asarray([9, 1, 4, 0], jnp.int32)
        ref = kv_pull_ref(src, dst, sid, did)
        out = kv_pull(src, dst, sid, did, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    @pytest.mark.parametrize("run_len", [2, 4])
    def test_coalesced_runs(self, run_len):
        src = arr((16, 8, 2, 64))
        dst = arr((16, 8, 2, 64))
        ss = jnp.asarray([0, 2], jnp.int32)
        ds = jnp.asarray([3, 1], jnp.int32)
        ref = kv_pull_runs_ref(src, dst, ss, ds, run_len=run_len)
        out = kv_pull_runs(src, dst, ss, ds, run_len=run_len, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    @pytest.mark.parametrize("dst_dtype", [jnp.float32, jnp.bfloat16])
    def test_dequant_txn_list(self, dst_dtype):
        """Quantized delta pull: int8 wire pages land dequantized with
        their per-transaction scale (ReadTxn.qscale on device)."""
        src = jnp.asarray(RNG.integers(-127, 128, (12, 16, 2, 32)), jnp.int8)
        dst = jnp.asarray(RNG.standard_normal((10, 16, 2, 32)), dst_dtype)
        sid = jnp.asarray([0, 5, 11, 3], jnp.int32)
        did = jnp.asarray([9, 1, 4, 0], jnp.int32)
        scales = jnp.asarray([0.013, 1.0, 0.5, 0.0021], jnp.float32)
        ref = kv_pull_dequant_ref(src, dst, sid, did, scales)
        out = kv_pull_dequant(src, dst, sid, did, scales, interpret=True)
        np.testing.assert_array_equal(np.asarray(out), np.asarray(ref))

    def test_dequant_untouched_pages_survive(self):
        """Destination is aliased (RDMA-write semantics): pages no
        transaction names keep their contents bit-for-bit."""
        src = jnp.asarray(RNG.integers(-127, 128, (4, 8, 2, 16)), jnp.int8)
        dst = jnp.asarray(RNG.standard_normal((6, 8, 2, 16)), jnp.float32)
        keep = np.array(dst)
        sid, did = jnp.asarray([2], jnp.int32), jnp.asarray([3], jnp.int32)
        out = kv_pull_dequant(src, dst, sid, did,
                              jnp.asarray([0.25], jnp.float32),
                              interpret=True)
        out = np.asarray(out)
        np.testing.assert_array_equal(out[[0, 1, 2, 4, 5]],
                                      keep[[0, 1, 2, 4, 5]])
        np.testing.assert_allclose(out[3], src[2].astype(np.float32) * 0.25)

    def test_dequant_roundtrip_bound(self):
        """Symmetric int8 round-trip of bf16-scale data stays within the
        documented tolerance: |err| <= max(|x|)/127 per page."""
        x = np.asarray(RNG.standard_normal((3, 8, 2, 16)), np.float32)
        scales = np.abs(x).reshape(3, -1).max(axis=1) / 127.0
        q = np.clip(np.round(x / scales[:, None, None, None]),
                    -127, 127).astype(np.int8)
        dst = jnp.zeros((3, 8, 2, 16), jnp.float32)
        ids = jnp.arange(3, dtype=jnp.int32)
        out = kv_pull_dequant(jnp.asarray(q), dst, ids, ids,
                              jnp.asarray(scales), interpret=True)
        err = np.max(np.abs(np.asarray(out) - x), axis=(1, 2, 3))
        assert (err <= np.abs(x).reshape(3, -1).max(axis=1) / 127.0
                + 1e-7).all()

    def test_full_request_transfer_shape(self):
        """Paper-scale mini: 1024-block request pulled in 8-block runs."""
        src = arr((64, 16, 2, 32))
        dst = jnp.zeros((64, 16, 2, 32), jnp.float32)
        ss = jnp.arange(8, dtype=jnp.int32)
        ds = jnp.arange(8, dtype=jnp.int32)
        out = kv_pull_runs(src, dst, ss, ds, run_len=8, interpret=True)
        np.testing.assert_allclose(np.asarray(out), np.asarray(src))


@pallas_tpu_only
class TestFlashPrefill:
    @pytest.mark.parametrize("s,h,g,d,bq", [
        (256, 4, 2, 64, 64),
        (128, 8, 8, 32, 32),
        (256, 6, 1, 128, 128),  # MQA
    ])
    @pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
    def test_causal(self, s, h, g, d, bq, dtype):
        q, k, v = arr((2, s, h, d), dtype), arr((2, s, g, d), dtype), arr((2, s, g, d), dtype)
        ref = dense_ref(q, k, v, causal=True)
        out = flash_prefill(q, k, v, causal=True, block_q=bq, block_k=bq, interpret=True)
        tol = TOL[dtype]
        np.testing.assert_allclose(
            out.astype(jnp.float32), ref.astype(jnp.float32), rtol=tol, atol=tol)

    def test_sliding_window_and_prefix(self):
        s, h, g, d = 256, 4, 2, 32
        q, k, v = arr((1, s, h, d)), arr((1, s, g, d)), arr((1, s, g, d))
        ref = dense_ref(q, k, v, causal=True, sliding_window=64, prefix_len=16)
        out = flash_prefill(q, k, v, causal=True, sliding_window=64, prefix_len=16,
                            block_q=32, block_k=32, interpret=True)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)

    def test_non_causal(self):
        s, h, g, d = 128, 4, 4, 32
        q, k, v = arr((1, s, h, d)), arr((1, s, g, d)), arr((1, s, g, d))
        ref = dense_ref(q, k, v, causal=False)
        out = flash_prefill(q, k, v, causal=False, block_q=64, block_k=64, interpret=True)
        np.testing.assert_allclose(out, ref, rtol=2e-4, atol=2e-4)


@pallas_tpu_only
class TestSSDScan:
    @pytest.mark.parametrize("s,nh,hd,ns,chunk", [
        (128, 4, 32, 16, 32),
        (64, 2, 64, 128, 64),   # mamba2-780m-like dstate
        (96, 50, 64, 16, 32),   # hymba-like head count
    ])
    def test_matches_ref(self, s, nh, hd, ns, chunk):
        b = 2
        x = arr((b, s, nh, hd), scale=0.5)
        dt = jnp.asarray(np.abs(RNG.standard_normal((b, s, nh))) * 0.1 + 0.01, jnp.float32)
        a = -jnp.asarray(np.abs(RNG.standard_normal(nh)) + 0.5, jnp.float32)
        B = arr((b, s, ns), scale=0.3)
        C = arr((b, s, ns), scale=0.3)
        d_skip = arr((nh,))
        y_ref, st_ref = ssd_scan_ref(x, dt, a, B, C, d_skip, chunk=chunk)
        y, st = ssd_scan(x, dt, a, B, C, d_skip, chunk=chunk, interpret=True)
        np.testing.assert_allclose(y, y_ref, rtol=1e-3, atol=1e-3)
        np.testing.assert_allclose(st, st_ref, rtol=1e-3, atol=1e-3)

    def test_decay_extremes_stable(self):
        """Very small dt (state persists) and large dt (state forgets)."""
        b, s, nh, hd, ns = 1, 64, 2, 16, 8
        x = arr((b, s, nh, hd), scale=0.5)
        B, C = arr((b, s, ns), scale=0.3), arr((b, s, ns), scale=0.3)
        a = jnp.asarray([-0.01, -8.0], jnp.float32)
        d_skip = jnp.zeros((nh,), jnp.float32)
        for dt_scale in (1e-3, 5.0):
            dt = jnp.full((b, s, nh), dt_scale, jnp.float32)
            y, st = ssd_scan(x, dt, a, B, C, d_skip, chunk=16, interpret=True)
            assert bool(jnp.all(jnp.isfinite(y))) and bool(jnp.all(jnp.isfinite(st)))
