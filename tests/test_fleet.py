"""Fleet control plane: autoscaler planning, host swap pool, admission
control, and end-to-end preemption correctness on the real substrate.

Acceptance anchors (ISSUE 9):
  * a swapped-out victim resumes token-identical (the page-cache
    writeback preserved its appended KV) with NO extra wire pull;
  * a sacrificed victim replays via truncate-and-replay and regenerates
    the identical stream, with pulled_bytes counted exactly once per
    actual pull (original + replay, never double);
  * an admission-rejected handle reaches FAILED carrying the typed
    ``KVBudgetExceeded`` (an ``AdmissionRejected`` subclass).
"""
import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.fleet import (
    AdmissionController,
    AdmissionDeferred,
    Autoscaler,
    FleetConfig,
    HostSwapPool,
    KVBudgetExceeded,
)
from repro.models.registry import build_model
from repro.sched import AdmissionRejected, LoadReport
from repro.serving.disagg import DisaggService
from repro.serving.handle import HandleStatus


def _toks(cfg, seed, n=64):
    return np.random.default_rng(seed).integers(
        0, cfg.vocab_size, n).astype(np.int32)


# ----------------------------------------------------------- pure pieces
class TestFleetConfig:
    def test_enum_validation(self):
        with pytest.raises(ValueError):
            FleetConfig(preempt="evaporate")
        with pytest.raises(ValueError):
            FleetConfig(victim_policy="coinflip")
        with pytest.raises(ValueError):
            FleetConfig(admission_mode="maybe")


class TestHostSwapPool:
    def test_put_get_pop_fifo(self):
        pool = HostSwapPool()
        assert pool.put("a", "entry-a", 100)
        assert pool.put("b", "entry-b", 50)
        assert pool.ids() == ["a", "b"]  # FIFO resume order
        assert pool.get("a") == "entry-a"
        assert pool.pop("a") == "entry-a"
        assert "a" not in pool and len(pool) == 1
        assert pool.used_bytes == 50 and pool.peak_bytes == 150

    def test_budget_refusal_leaves_pool_unchanged(self):
        pool = HostSwapPool(capacity_bytes=100)
        assert pool.put("a", "x", 80)
        assert not pool.put("b", "y", 30)  # would exceed the budget
        assert pool.ids() == ["a"] and pool.used_bytes == 80

    def test_duplicate_put_rejected(self):
        pool = HostSwapPool()
        pool.put("a", "x", 1)
        with pytest.raises(KeyError):
            pool.put("a", "y", 1)


def _reports(role, loads, *, t=0.0, total=100):
    """wid -> LoadReport with the given load fractions (no queue)."""
    return {
        f"{role[0]}{i}": LoadReport(f"{role[0]}{i}", role,
                                    free_blocks=int(total * (1 - f)),
                                    total_blocks=total, t=t)
        for i, f in enumerate(loads)
    }


class TestAutoscaler:
    def test_hot_role_adds_after_patience(self):
        a = Autoscaler(FleetConfig(autoscale=True, patience=2))
        hot = _reports("decode", [0.95, 0.9])
        cold = _reports("prefill", [0.1, 0.1])
        assert a.plan(cold, hot) == []          # patience 1/2
        assert ("add", "decode") in a.plan(cold, hot)

    def test_backlog_counts_as_prefill_pressure(self):
        a = Autoscaler(FleetConfig(autoscale=True, patience=1))
        idle = _reports("prefill", [0.0, 0.0])
        acts = a.plan(idle, _reports("decode", [0.5]), dispatch_backlog=4)
        assert ("add", "prefill") in acts  # 4 queued / 2 workers = 2.0

    def test_cold_role_drains_least_loaded(self):
        a = Autoscaler(FleetConfig(autoscale=True, patience=1, min_decode=1))
        acts = a.plan(_reports("prefill", [0.5]),
                      _reports("decode", [0.4, 0.05]))
        assert ("drain", "decode", "d1") in acts

    def test_total_cap_shifts_ratio(self):
        # at peak hardware, growing prefill drains a decode worker first
        a = Autoscaler(FleetConfig(autoscale=True, patience=1,
                                   total_cap=4, min_decode=1,
                                   scale_down=0.0))  # decode never "cold"
        acts = a.plan(_reports("prefill", [0.95, 0.95]),
                      _reports("decode", [0.5, 0.4]))
        assert ("drain", "decode", "d1") in acts
        assert ("add", "prefill") in acts

    def test_draining_role_left_alone(self):
        a = Autoscaler(FleetConfig(autoscale=True, patience=1))
        acts = a.plan(_reports("prefill", [0.1]),
                      _reports("decode", [0.95, 0.95]),
                      draining={"d1": "decode"})
        assert acts == []  # decode capacity already in motion

    def test_respects_max_bound(self):
        a = Autoscaler(FleetConfig(autoscale=True, patience=1, max_decode=2))
        acts = a.plan(_reports("prefill", [0.5]),
                      _reports("decode", [0.95, 0.95]))
        assert ("add", "decode") not in acts


class TestAdmissionController:
    def test_projected_fraction(self):
        ac = AdmissionController(0.8)
        reports = _reports("decode", [0.5, 0.5], total=100)
        # 100 used + 40 needed over 200 total
        assert ac.projected_fraction(reports, 40) == pytest.approx(0.7)

    def test_reject_is_typed_admission_rejected(self):
        ac = AdmissionController(0.6)
        reports = _reports("decode", [0.5, 0.5], total=100)
        with pytest.raises(KVBudgetExceeded) as ei:
            ac.check(reports, 40, "r0")
        assert isinstance(ei.value, AdmissionRejected)
        assert "occupancy" in str(ei.value) and "r0" in str(ei.value)

    def test_defer_mode_raises_soft_error(self):
        ac = AdmissionController(0.6, mode="defer")
        with pytest.raises(AdmissionDeferred) as ei:
            ac.check(_reports("decode", [0.9]), 10, "r1")
        # soft verdict: NOT an AdmissionRejected — the loop retries it
        assert not isinstance(ei.value, AdmissionRejected)

    def test_under_budget_passes(self):
        ac = AdmissionController(0.9)
        ac.check(_reports("decode", [0.1]), 5, "r2")  # no raise


# ------------------------------------------------------- real substrate
@pytest.fixture(scope="module")
def service_setup():
    cfg = get_smoke_config("deepseek-67b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _drive(svc, h, cap=200):
    for _ in range(cap):
        if h.finished:
            return
        svc.loop.tick()
    raise AssertionError(f"{h.request_id} did not finish in {cap} ticks")


class TestPreemptionCorrectness:
    def test_swap_resume_token_identical_no_repull(self, service_setup):
        cfg, model, params = service_setup
        base = DisaggService(model, params, n_prefill=1, n_decode=1)
        hb = base.submit(_toks(cfg, 7), max_new=6)
        _drive(base, hb)
        baseline_pulled = hb.metrics.kv_bytes_pulled

        # preempt="none": the controller owns the swap pool but the
        # governor is off, so the test controls the swap points
        svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                            fleet=FleetConfig(preempt="none"))
        h = svc.submit(_toks(cfg, 7), max_new=6)
        while h.decoded < 2:
            svc.loop.tick()
        wid = h.request.decode_worker
        assert svc.swap_out_request(h.request_id)
        assert h.request_id in svc.fleet.swap_pool
        frozen = len(h.tokens)
        for _ in range(3):
            svc.loop.tick()
        assert len(h.tokens) == frozen, "stream advanced while swapped out"
        assert h.status is HandleStatus.DECODING  # paused, not failed
        assert svc.swap_in_request(h.request_id, wid)
        _drive(svc, h)
        assert h.tokens == hb.tokens
        assert h.metrics.swapped_out == 1
        # swap moves pages host<->device, never the wire: no extra pull
        assert h.metrics.kv_bytes_pulled == baseline_pulled

    def test_sacrifice_replay_identical_pull_counted_once(self, service_setup):
        cfg, model, params = service_setup
        base = DisaggService(model, params, n_prefill=1, n_decode=1)
        hb = base.submit(_toks(cfg, 8), max_new=6)
        _drive(base, hb)
        baseline_pulled = hb.metrics.kv_bytes_pulled

        svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                            fleet=FleetConfig(preempt="none"))
        h = svc.submit(_toks(cfg, 8), max_new=6)
        while h.decoded < 2:
            svc.loop.tick()
        assert svc.sacrifice_request(h.request_id)
        _drive(svc, h)
        assert h.tokens == hb.tokens
        assert h.metrics.sacrificed == 1 and h.request.retries >= 1
        # exactly one replay pull on top of the original — each pulled
        # byte counted once per actual wire crossing, never double
        assert h.metrics.kv_bytes_pulled == 2 * baseline_pulled

    def test_governor_relieves_pressure_automatically(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=0,
                            fleet=FleetConfig(preempt="swap",
                                              preempt_high=0.5,
                                              victim_policy="fifo"))
        svc.add_decode_worker(num_blocks=4)
        # A fills the 4-block pool (3 prompt blocks + growth); B (2
        # blocks) cannot admit until the governor swaps A out
        a = svc.submit(_toks(cfg, 9, 96), max_new=24, slo_class="batch")
        b = svc.submit(_toks(cfg, 10, 64), max_new=4)
        _drive(svc, b)
        assert b.done
        assert a.metrics.swapped_out >= 1
        assert svc.metrics.counter("fleet.preempt_swap").value >= 1
        _drive(svc, a, cap=400)  # the victim resumes and finishes too
        assert a.done

    def test_admission_rejected_handle_fails_typed(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                            num_blocks=8,
                            fleet=FleetConfig(admission_budget=0.25))
        h = svc.submit(_toks(cfg, 11, 96), max_new=4, dispatch="queued")
        svc.loop.tick()  # queued dispatch: rejection surfaces on the handle
        assert h.failed and h.status is HandleStatus.FAILED
        assert isinstance(h.error, KVBudgetExceeded)
        assert isinstance(h.error, AdmissionRejected)
        with pytest.raises(KVBudgetExceeded):
            h.result()
        assert svc.metrics.counter("fleet.admission_rejected").value >= 1

    def test_admission_defer_holds_then_serves(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                            num_blocks=8,
                            fleet=FleetConfig(admission_budget=0.25,
                                              admission_mode="defer"))
        h = svc.submit(_toks(cfg, 12, 96), max_new=2)
        # deferred, not failed: the request waits for occupancy headroom
        assert not h.failed
        assert svc.metrics.counter("fleet.admission_deferred").value >= 1


class TestFleetController:
    def test_autoscale_adds_prefill_under_backlog(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                            fleet=FleetConfig(autoscale=True, patience=1,
                                              max_prefill=2, max_decode=1))
        for s in (13, 14, 15):
            svc.submit(_toks(cfg, s), max_new=2, dispatch="queued")
        before = len(svc.prefills)
        for _ in range(40):
            svc.loop.tick()
            if len(svc.prefills) > before:
                break
        assert len(svc.prefills) > before
        assert svc.metrics.counter("fleet.autoscale_add_prefill").value >= 1

    def test_drain_then_retire_decode_worker(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=2,
                            fleet=FleetConfig(preempt="none"))
        wid = next(iter(svc.decodes))
        svc.router.mark_draining(wid)
        svc.fleet.draining[wid] = "decode"
        for _ in range(4):
            svc.loop.tick()
        assert wid not in svc.decodes  # idle drain retires immediately
        assert wid not in svc.fleet.draining
        # the fleet still serves
        h = svc.submit(_toks(cfg, 16), max_new=2)
        _drive(svc, h)
        assert h.done
