"""Unified tracing + metrics layer (repro.obs).

Covers:
  * span nesting, the phase machine's gap-free partition, and
    injectable-clock determinism — a sim (virtual clock) and a real
    (perf_counter) run share ONE span schema;
  * disabled-mode no-op behavior and its overhead bound (<5 % of a
    short ServeLoop run);
  * Chrome trace-event export structure, incl. per-layer transfer spans;
  * breakdown-vs-HandleMetrics consistency on the real substrate
    (components sum to TTLT within 1 %, and TTLT == HandleMetrics.ttlt_s);
  * BENCH_*.json schema validation, merge-on-write, trajectory loading,
    and ``benchmarks.run --only`` strictness;
  * stall forensics — ServeLoopStalled carries the final TickReport and
    the loop's per-phase counters;
  * ``TransferEngine.pulled_bytes(pop=True)`` accounting under hedged
    prefill (loser aborted) and torn-pull retry: bytes neither
    double-counted into ``HandleMetrics.kv_bytes_pulled`` nor leaked in
    the engine's per-request counter.
"""
import json

import jax
import numpy as np
import pytest

from repro.configs import get_smoke_config
from repro.models.registry import build_model
from repro.obs import (
    NULL_TRACER,
    BenchTrajectory,
    MetricsRegistry,
    Tracer,
    all_request_breakdowns,
    bench_path,
    load_trajectory,
    mean_fractions,
    request_breakdown,
    spans_from_timeline,
    validate_bench,
)
from repro.obs.trace import _NULL_SPAN
from repro.serving.disagg import DisaggService
from repro.serving.loop import ServeLoopStalled, TickReport
from repro.serving.request import Request, RequestState


@pytest.fixture(scope="module")
def service_setup():
    cfg = get_smoke_config("deepseek-67b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def _toks(cfg, seed, n=24):
    return np.random.default_rng(seed).integers(
        0, cfg.vocab_size, size=n).astype(np.int32)


class _VirtualClock:
    def __init__(self, t=0.0):
        self.t = t

    def __call__(self):
        return self.t

    def advance(self, dt):
        self.t += dt
        return self.t


# --------------------------------------------------------------- tracer
class TestTracer:
    def test_scoped_spans_nest_with_depth(self):
        clk = _VirtualClock()
        tr = Tracer(clock=clk)
        with tr.span("outer", track="loop"):
            clk.advance(1.0)
            with tr.span("inner", track="loop") as s:
                assert s.depth == 1
                clk.advance(1.0)
        outer = next(s for s in tr.spans if s.name == "outer")
        inner = next(s for s in tr.spans if s.name == "inner")
        assert outer.depth == 0 and inner.depth == 1
        assert outer.t0 <= inner.t0 and inner.t1 <= outer.t1
        assert outer.duration_s == 2.0 and inner.duration_s == 1.0

    def test_phase_machine_partitions_without_gaps(self):
        clk = _VirtualClock()
        tr = Tracer(clock=clk)
        track = ("request", "r0")
        for name, dt in (("queue", 1.0), ("prefill", 2.0),
                         ("transfer", 0.5), ("decode", 4.0)):
            tr.phase(track, name)
            clk.advance(dt)
        tr.end_phase(track)
        spans = tr.spans_of(track)
        assert [s.name for s in spans] == ["queue", "prefill", "transfer",
                                           "decode"]
        for a, b in zip(spans, spans[1:]):
            assert a.t1 == b.t0  # shared boundary: no gap, no overlap
        b = request_breakdown(tr, "r0")
        assert b.total_s == b.ttlt_s == 7.5

    def test_injectable_clock_determinism(self):
        """Two runs with the same virtual clock script produce identical
        spans — and the schema (names/tracks/shape) is the same one a
        perf_counter-clocked tracer emits."""
        def record(tr, clk):
            t = ("request", "r1")
            tr.phase(t, "queue")
            clk.advance(1.0)
            tr.phase(t, "decode")
            clk.advance(2.0)
            tr.end_phase(t)
            tr.instant("transfer.complete", track=t, bytes=64)

        runs = []
        for _ in range(2):
            clk = _VirtualClock(10.0)
            tr = Tracer(clock=clk)
            record(tr, clk)
            runs.append([(s.name, s.track, s.t0, s.t1) for s in tr.spans]
                        + [(s.name, s.track, s.t0) for s in tr.instants])
        assert runs[0] == runs[1]  # deterministic under an injected clock

        real = Tracer()  # perf_counter
        record(real, _VirtualClock())  # clk arg unused for real timing
        assert [(s.name, s.track) for s in real.spans] == \
               [(name, track) for name, track, *_ in runs[0][:2]]

    def test_sim_timeline_emits_same_schema(self):
        """spans_from_timeline renders a sim-style Request timeline into
        the live phase schema: same names, same track, breakdown works."""
        req = Request("r9", prompt_len=32, max_new_tokens=8)
        req.arrival_s = 0.0
        req.prefill_start_s = 1.0
        req.prefill_end_s = 3.0
        req.transfer_start_s = 3.5
        req.transfer_end_s = 4.0
        req.decode_start_s = 4.0
        req.done_s = 10.0
        tr = Tracer(clock=_VirtualClock())
        spans_from_timeline(tr, req)
        b = request_breakdown(tr, "r9")
        assert b.queue_s == 1.0 + 0.5  # queue + queue.kv
        assert b.prefill_s == 2.0 and b.transfer_s == 0.5 and b.decode_s == 6.0
        assert b.ttlt_s == 10.0
        assert abs(b.total_s - b.ttlt_s) < 1e-12

    def test_disabled_tracer_is_noop(self):
        calls = []
        tr = Tracer(clock=lambda: calls.append(1) or 0.0, enabled=False)
        s = tr.span("x", track="loop", a=1)
        assert s is _NULL_SPAN and s.set(b=2) is s and s.end() is s
        with tr.span("y"):
            pass
        assert tr.phase("t", "queue") is _NULL_SPAN
        assert tr.end_phase("t") is None
        tr.complete("z", "t", 0.0, 1.0)
        tr.instant("i")
        assert tr.spans == [] and tr.instants == []
        assert calls == []  # disabled path never reads the clock
        assert NULL_TRACER.enabled is False

    def test_open_spans_are_not_exported(self):
        tr = Tracer(clock=_VirtualClock())
        tr.span("never-ended", track="loop")
        assert tr.spans == []
        assert tr.to_chrome()["traceEvents"][-1]["name"] == "process_name"

    def test_chrome_export_structure(self):
        clk = _VirtualClock(100.0)
        tr = Tracer(clock=clk)
        with tr.span("tick", track="loop"):
            clk.advance(0.25)
        tr.complete("transfer.layer0", ("request", "r0"), 100.05, 100.10,
                    layer=0)
        tr.instant("transfer.complete", track=("request", "r0"), bytes=4096)
        doc = tr.to_chrome(process_name="proc")
        evs = doc["traceEvents"]
        meta = [e for e in evs if e["ph"] == "M"]
        assert {"process_name", "thread_name"} <= {e["name"] for e in meta}
        x = next(e for e in evs if e["ph"] == "X" and e["name"] == "tick")
        assert x["ts"] == pytest.approx(0.0) and x["dur"] == pytest.approx(0.25e6)
        layer = next(e for e in evs if e["name"] == "transfer.layer0")
        assert layer["args"]["layer"] == 0
        inst = next(e for e in evs if e["ph"] == "i")
        assert inst["args"]["bytes"] == 4096
        json.dumps(doc)  # must be serializable as-is

    def test_chrome_export_roundtrip_file(self, tmp_path):
        tr = Tracer(clock=_VirtualClock())
        with tr.span("tick", track="loop"):
            pass
        p = tmp_path / "trace.json"
        tr.export_chrome(str(p))
        assert json.loads(p.read_text())["traceEvents"]


# -------------------------------------------------------------- metrics
class TestMetrics:
    def test_counters_gauges_histograms(self):
        m = MetricsRegistry()
        m.inc("a.count")
        m.inc("a.count", 2)
        m.set_gauge("a.depth", 7)
        for v in range(1, 101):
            m.observe("a.lat", v / 100.0)
        assert m.counter("a.count").value == 3
        assert m.gauge("a.depth").value == 7
        h = m.histogram("a.lat")
        assert h.count == 100
        assert h.percentile(50) == pytest.approx(0.50)
        assert h.percentile(99) == pytest.approx(0.99)
        snap = m.snapshot()
        assert snap["counters"]["a.count"] == 3
        assert snap["histograms"]["a.lat"]["p90"] == pytest.approx(0.90)
        assert "a.count = 3" in m.format()
        assert "a.count" not in m.format(prefixes=("b.",))

    def test_histogram_window_bounds_memory(self):
        m = MetricsRegistry(histogram_window=8)
        for v in range(100):
            m.observe("x", float(v))
        h = m.histogram("x")
        assert len(h.window) == 8 and h.count == 100
        assert h.percentile(50) == 95.0  # window holds the last 8 only


# ---------------------------------------------------------------- bench
class TestBenchTrajectory:
    def test_write_validate_load(self, tmp_path):
        traj = BenchTrajectory(6, source="benchmarks.run")
        traj.add("fig14/x", 123.0, unit="us", derived="d=1")
        p = traj.write(tmp_path / "BENCH_6.json")
        doc = validate_bench(json.loads(p.read_text()))
        assert doc["pr"] == 6 and doc["entries"][0]["name"] == "fig14/x"
        traj2 = BenchTrajectory(7, source="benchmarks.run")
        traj2.add("fig14/x", 140.0)
        traj2.write(tmp_path / "BENCH_7.json")
        series = load_trajectory(tmp_path)
        assert [d["pr"] for d in series] == [6, 7]  # ordered by PR number

    def test_merge_preserves_other_writers_entries(self, tmp_path):
        p = tmp_path / "BENCH_6.json"
        a = BenchTrajectory(6, source="benchmarks.run")
        a.add("fig14/x", 1.0)
        a.write(p)
        b = BenchTrajectory(6, source="benchmarks.roofline")
        b.add("roofline/y", 2.0)
        b.write(p)
        doc = validate_bench(json.loads(p.read_text()))
        assert {e["name"] for e in doc["entries"]} == {"fig14/x", "roofline/y"}
        assert "benchmarks.run" in doc["source"]
        assert "benchmarks.roofline" in doc["source"]

    @pytest.mark.parametrize("mutate, err", [
        (lambda d: d.update(schema_version=2), "schema_version"),
        (lambda d: d.update(pr="6"), "pr"),
        (lambda d: d.update(source=""), "source"),
        (lambda d: d.update(entries=[]), "entries"),
        (lambda d: d["entries"][0].update(value="fast"), "value"),
        (lambda d: d["entries"][0].pop("unit"), "unit"),
    ])
    def test_validate_rejects_bad_schema(self, mutate, err):
        traj = BenchTrajectory(6)
        traj.add("x", 1.0)
        doc = traj.to_json()
        mutate(doc)
        with pytest.raises(ValueError, match=err):
            validate_bench(doc)

    def test_bench_path_shape(self):
        assert bench_path(6).name == "BENCH_6.json"

    def test_run_only_rejects_unknown_prefix(self):
        from benchmarks.run import select_modules
        assert select_modules(["fig14"]) == ["fig14_breakdown"]
        assert select_modules([]) != []
        with pytest.raises(SystemExit, match="no benchmark module"):
            select_modules(["fig99_nonexistent"])


# ------------------------------------------------------ stall forensics
class TestStallForensics:
    def test_message_carries_tick_report_and_phase_totals(self):
        rep = TickReport(now=1.5, dispatched=["r1"], tokens={"r2": 7},
                         engine_processed=3)
        exc = ServeLoopStalled(["r2", "r1"], report=rep,
                               phase_counters={"ticks": 9, "tokens": 4})
        msg = str(exc)
        assert "r1, r2" in msg
        assert "last tick:" in msg and "dispatched=['r1']" in msg
        assert "engine_processed=3" in msg
        assert "phase totals:" in msg and "ticks=9" in msg and "tokens=4" in msg
        assert exc.report is rep and exc.phase_counters["ticks"] == 9

    def test_loop_stall_raises_with_forensics(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                            num_blocks=64)
        # a prompt the pools can never hold: dispatch fails every tick,
        # nothing progresses, the loop must stall with forensics attached
        h = svc.submit(_toks(cfg, 0, n=64 * model.BLOCK_SIZE + 1),
                       max_new=2, dispatch="queued")
        with pytest.raises(ServeLoopStalled) as ei:
            svc.loop.run_until_idle()
        exc = ei.value
        assert h.request_id in exc.request_ids
        assert exc.report is not None and "last tick:" in str(exc)
        assert exc.phase_counters.get("ticks", 0) >= 1


# ------------------------------------------------- live substrate traces
class TestLiveTracing:
    @pytest.fixture(scope="class")
    def traced_run(self, service_setup):
        cfg, model, params = service_setup
        tracer = Tracer()
        svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                            num_blocks=64, tracer=tracer)
        handles = [svc.submit(_toks(cfg, 10 + i), max_new=3)
                   for i in range(3)]
        svc.loop.run_until_idle()
        assert all(h.done for h in handles)
        return svc, tracer, handles

    def test_breakdown_matches_handle_metrics(self, traced_run):
        """Acceptance criterion: components sum to measured TTLT within
        1 %, and the span-derived TTLT is the handle's TTLT (one clock)."""
        _, tracer, handles = traced_run
        breakdowns = all_request_breakdowns(tracer)
        assert len(breakdowns) == len(handles)
        for h in handles:
            b = breakdowns[h.request_id]
            assert b.ttlt_s > 0
            assert abs(b.total_s - b.ttlt_s) <= 0.01 * b.ttlt_s
            assert b.ttlt_s == pytest.approx(h.metrics.ttlt_s, abs=1e-9)
            comp = b.components()
            assert all(v >= 0 for v in comp.values())
            assert comp["decode_s"] > 0 and comp["prefill_s"] > 0

    def test_chrome_export_has_per_request_lifecycle(self, traced_run, tmp_path):
        _, tracer, handles = traced_run
        doc = tracer.export_chrome(str(tmp_path / "serve_trace.json"))
        evs = doc["traceEvents"]
        for h in handles:
            cat = f"request/{h.request_id}"
            names = {e["name"] for e in evs if e.get("cat") == cat}
            assert {"queue", "prefill", "transfer", "decode"} <= names
            assert any(n.startswith("transfer.layer") for n in names)
        assert any(e.get("cat") == "loop" and e["name"] == "tick" for e in evs)

    def test_engine_and_loop_metrics_populated(self, traced_run):
        svc, _, handles = traced_run
        c = svc.metrics.counters()
        assert c["requests.submitted"] == len(handles)
        assert c["requests.finished"] == len(handles)
        assert c["engine.pulls_submitted"] == len(handles)
        assert c["engine.bytes_moved"] > 0
        assert c["loop.tokens"] >= sum(h.decoded for h in handles)
        assert svc.metrics.histogram("request.ttlt_s").count == len(handles)

    def test_mean_fractions_sum_to_one(self, traced_run):
        _, tracer, _ = traced_run
        fr = mean_fractions(all_request_breakdowns(tracer))
        assert sum(fr.values()) == pytest.approx(1.0, abs=1e-9)

    def test_disabled_tracer_overhead_under_5pct(self, service_setup):
        """The no-op path must cost <5 % of a short ServeLoop run even if
        every event the enabled run records were a disabled-path call.
        Measured as per-call cost x recorded-event count vs loop wall
        time — immune to run-to-run loop variance."""
        import time as _t

        cfg, model, params = service_setup
        tracer = Tracer()
        svc = DisaggService(model, params, n_prefill=1, n_decode=1,
                            num_blocks=64, tracer=tracer)
        h = svc.submit(_toks(cfg, 99), max_new=3)
        t0 = _t.perf_counter()
        svc.loop.run_until_idle()
        loop_s = _t.perf_counter() - t0
        assert h.done
        n_events = len(tracer.spans) + len(tracer.instants)

        n_calls = 100_000
        t0 = _t.perf_counter()
        for _ in range(n_calls):
            with NULL_TRACER.span("tick", track="loop", tick=1):
                pass
        per_call = (_t.perf_counter() - t0) / n_calls
        assert n_events * per_call < 0.05 * loop_s, (
            f"{n_events} events x {per_call:.2e}s/call vs {loop_s:.3f}s loop")


# --------------------------------------------- pulled-bytes accounting
class TestPulledBytesAccounting:
    def test_hedged_abort_no_double_count_no_leak(self, service_setup):
        """First COMPLETE wins: the loser twin's slab is freed without a
        second pull, so kv_bytes_pulled equals the un-hedged cost and the
        engine's per-request counter is retired at finish."""
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=2, n_decode=1,
                            num_blocks=64)
        base = svc.submit(_toks(cfg, 60), max_new=2)
        svc.generate(base, max_new=2)
        unhedged_bytes = base.metrics.kv_bytes_pulled
        assert unhedged_bytes > 0

        h = svc.submit(_toks(cfg, 60), max_new=2, hedge=2)
        assert h.metrics.hedged
        svc.generate(h, max_new=2)
        assert h.metrics.hedge_adopted is False
        assert h.metrics.kv_bytes_pulled == unhedged_bytes  # not doubled
        assert h.request_id not in svc.engine._pulled_bytes  # retired
        assert base.request_id not in svc.engine._pulled_bytes
        # loser's slab freed, nothing resident anywhere prefill-side
        assert all(w.pool.stats.in_use == 0 for w in svc.prefills.values())

    def test_torn_pull_retry_counts_retries_without_leak(self, service_setup):
        """A pull torn mid-flight retries from a fresh prefill; the bytes
        metric counts BOTH attempts (retries included, per HandleMetrics
        contract) and the per-request counter still pops exactly once."""
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=2, n_decode=1,
                            num_blocks=64)
        ref = svc.submit(_toks(cfg, 61), max_new=2)
        svc.generate(ref, max_new=2)
        full_bytes = ref.metrics.kv_bytes_pulled

        h = svc.submit(_toks(cfg, 61), max_new=2)
        victim = h.prefill_worker
        svc.admit_queued(only={h.request_id})
        svc.engine.tick(2)  # execute a couple of reads -> partial bytes land
        partial = svc.engine.pulled_bytes(h.request_id)
        assert 0 < partial < full_bytes
        svc.fail_prefill_worker(victim)  # tear mid-pull -> restart path
        svc.loop.run_until_idle(only={h.request_id})
        assert h.done and h.retries == 1
        assert h.metrics.kv_bytes_pulled == partial + full_bytes
        assert h.request_id not in svc.engine._pulled_bytes  # no leak
        assert svc.decode.pool.stats.in_use == 0
