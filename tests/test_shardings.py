"""Sharding rule engine: the launch-layer PartitionSpec assignments.

These rules decide whether 512 chips do useful work — worth pinning.
"""
import jax
import pytest
from jax.sharding import PartitionSpec as P

jax.config.update("jax_platform_name", "cpu")

from repro.launch.shardings import batch_spec, logical_spec  # noqa: E402


@pytest.fixture(scope="module")
def mesh():
    # shape-only use: axis sizes matter, device count doesn't — build the
    # largest mesh the local device allows and spoof sizes via a stub
    class _M:
        shape = {"data": 16, "model": 16}
    return _M()


@pytest.fixture(scope="module")
def mp_mesh():
    class _M:
        shape = {"pod": 2, "data": 16, "model": 16}
    return _M()


class TestParamRules:
    def test_column_parallel_qkv(self, mesh):
        # [L, d_model, attn_dim]: out over model, in over data (train)
        spec = logical_spec(["layers", "attn", "q", "w"], (48, 4096, 4096),
                            mesh, mode="train")
        assert spec == P(None, "data", "model")

    def test_row_parallel_o(self, mesh):
        spec = logical_spec(["layers", "attn", "o", "w"], (48, 4096, 4096),
                            mesh, mode="train")
        assert spec == P(None, "model", "data")

    def test_serve_mode_drops_fsdp(self, mesh):
        spec = logical_spec(["layers", "attn", "q", "w"], (48, 4096, 4096),
                            mesh, mode="serve")
        assert spec == P(None, None, "model")

    def test_moe_expert_stack(self, mesh):
        # [L, E, d, ff]: E over data (EP), ff over model (TP)
        spec = logical_spec(["layers", "moe", "gate"], (48, 128, 5120, 8192),
                            mesh, mode="train")
        assert spec == P(None, "data", None, "model")

    def test_moe_shared_expert_is_dense_rule(self, mesh):
        spec = logical_spec(["layers", "moe", "shared", "gate", "w"],
                            (48, 5120, 8192), mesh, mode="train")
        assert spec == P(None, "data", "model")

    def test_embedding_vocab_over_model(self, mesh):
        spec = logical_spec(["embed", "table"], (49408, 6144), mesh, mode="serve")
        assert spec == P("model", None)

    def test_indivisible_dim_stays_unsharded(self, mesh):
        # hymba o-proj: 25·64=1600 divides, but a 25-head dim would not
        spec = logical_spec(["layers", "attn", "q", "w"], (32, 1600, 25),
                            mesh, mode="serve")
        assert spec == P(None, None, None)  # 25 % 16 != 0 and 1600 is FSDP-only

    def test_norms_replicated(self, mesh):
        spec = logical_spec(["layers", "attn_norm", "scale"], (48, 4096),
                            mesh, mode="train")
        assert spec == P(None, None)

    def test_fold_mode_serve_replicates(self, mesh):
        spec = logical_spec(["layers", "attn", "q", "w"], (32, 1536, 1536),
                            mesh, mode="serve", fold_model=True)
        assert spec == P(None, None, None)

    def test_fold_mode_keeps_ep(self, mesh):
        spec = logical_spec(["layers", "moe", "down"], (32, 48, 512, 1536),
                            mesh, mode="train", fold_model=True)
        assert spec == P(None, "data", "model", None)


class TestBatchSpec:
    def test_divisible_batch(self, mesh):
        assert batch_spec(mesh, 256) == P(("data",))

    def test_multipod(self, mp_mesh):
        assert batch_spec(mp_mesh, 256) == P(("pod", "data"))

    def test_batch_one_replicates(self, mp_mesh):
        assert batch_spec(mp_mesh, 1) == P()

    def test_fold_extends_dp(self, mp_mesh):
        assert batch_spec(mp_mesh, 1024, fold_model=True) == P(("pod", "data", "model"))

    def test_fold_falls_back_per_divisibility(self, mp_mesh):
        # 256 doesn't divide 512 → drop 'model'; 256 < pod*data*model
        assert batch_spec(mp_mesh, 256, fold_model=True) == P(("pod", "data"))
