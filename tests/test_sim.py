"""Cluster simulator: conservation, stability, and the paper's effects."""
import numpy as np
import pytest

from repro.configs import get_config
from repro.sim.costs import CostModel, H100_NODE, V5E_POD_SLICE
from repro.sim.events import ClusterSim, SimConfig
from repro.sim.workloads import (
    ARXIV,
    SHAREGPT,
    SimRequest,
    bursty_requests,
    diurnal_requests,
    fixed_requests,
    sample_requests,
)


@pytest.fixture(scope="module")
def cost():
    return CostModel(get_config("mistral-large-123b"), H100_NODE)


class TestCostModel:
    def test_prefill_anchor(self, cost):
        # paper: 0.9 s prefill for a 16K prompt on a 70B model; ours is a
        # 123B model on H100s — same ballpark
        assert 0.5 < cost.prefill_s(16384) < 2.0

    def test_kv_per_token_matches_paper(self, cost):
        # paper §5.1: 352 KB per token for Mistral-Large-123B
        assert cost.kv_bytes_per_token() == 352 * 1024

    def test_capacity_subtracts_weights(self, cost):
        cap_bytes = cost.kv_capacity_tokens() * cost.kv_bytes_per_token()
        assert cap_bytes < H100_NODE.hbm_bytes - 2 * cost.cfg.param_count()

    def test_transfer_modes_ordered(self, cost):
        t_kv = cost.transfer_s(16384)
        t_msg = cost.transfer_s(16384, mode="message")
        assert t_kv < t_msg < 20 * t_kv

    def test_decode_memory_bound_at_small_batch(self, cost):
        t1 = cost.decode_step_s(10_000, 1)
        t64 = cost.decode_step_s(640_000, 64)
        # batched decode amortizes weights: per-request cost falls
        assert t64 < 64 * t1

    def test_v5e_profile_works(self):
        c = CostModel(get_config("yi-9b"), V5E_POD_SLICE)
        assert c.kv_capacity_tokens() > 0
        assert c.prefill_s(8192) > 0


class TestConservation:
    @pytest.mark.parametrize("mode", ["pull", "push", "colocated"])
    def test_every_request_finishes(self, cost, mode):
        reqs = sample_requests(SHAREGPT, qps=0.5, duration_s=120, seed=5)
        sim = ClusterSim(cost, SimConfig(mode=mode))
        res = sim.run(list(reqs))
        assert len(res.requests) == len(reqs)
        assert all(r.done_s is not None for r in res.requests)
        # pools fully drained
        for d in sim.decodes:
            assert d.used_tokens == 0 and not d.active and not d.kv_queue
        for p in sim.prefills:
            assert p.held_tokens == 0

    def test_token_counts(self, cost):
        reqs = fixed_requests(1024, 64, qps=0.5, duration_s=60, seed=1)
        res = ClusterSim(cost, SimConfig()).run(reqs)
        for r in res.requests:
            assert r.tokens_generated == r.max_new_tokens - 1
            assert len(r.token_times_s) == r.max_new_tokens

    @pytest.mark.parametrize("preemption", ["swap", "sacrifice"])
    def test_preemption_conserves_every_request(self, cost, preemption):
        """Under memory pressure the preemption path must still land
        every request (swap victims resume, sacrifice victims replay)
        and drain the pools — and the pressure must actually have
        triggered preemptions, or the test proves nothing."""
        cap = cost.kv_capacity_tokens()
        reqs = [SimRequest(f"hog-{i}", 0.5 * i, int(0.45 * cap), 2000,
                           slo_class="batch") for i in range(2)]
        reqs += [SimRequest(f"short-{i}", 2.0 + i, int(0.18 * cap), 64,
                            slo_class="interactive") for i in range(4)]
        sim = ClusterSim(cost, SimConfig(
            mode="pull", n_prefill=2, n_decode=1,
            preemption=preemption, preempt_high=0.7,
            victim_policy="priority"))
        res = sim.run(list(reqs))
        assert len(res.requests) == len(reqs)
        assert all(r.done_s is not None for r in res.requests)
        preempted = res.n_swapped if preemption == "swap" else res.n_sacrificed
        assert preempted > 0
        for d in sim.decodes:
            assert d.used_tokens == 0 and not d.active and not d.kv_queue
            assert not d.swapped
        for p in sim.prefills:
            assert p.held_tokens == 0

    def test_autoscale_conserves_every_request(self, cost):
        """Elastic sizing (hot-adds, drain-then-retire) must not lose or
        duplicate requests; retired workers leave nothing behind."""
        reqs = bursty_requests(SHAREGPT, qps_on=1.0, qps_off=0.05,
                               mean_on_s=30.0, mean_off_s=30.0,
                               duration_s=120.0, seed=11)
        sim = ClusterSim(cost, SimConfig(
            mode="pull", n_prefill=2, n_decode=2, autoscale=True,
            total_cap=4, min_prefill=1, max_prefill=3,
            min_decode=1, max_decode=3, autoscale_interval_s=2.0))
        res = sim.run(list(reqs))
        assert len(res.requests) == len(reqs)
        assert all(r.done_s is not None for r in res.requests)
        for d in sim.decodes:
            assert d.used_tokens == 0 and not d.active and not d.kv_queue
        for p in sim.prefills:
            assert p.held_tokens == 0

    def test_timeline_monotone(self, cost):
        reqs = sample_requests(ARXIV, qps=0.2, duration_s=120, seed=2)
        res = ClusterSim(cost, SimConfig()).run(reqs)
        for r in res.requests:
            ts = [r.arrival_s, r.prefill_start_s, r.prefill_end_s,
                  r.transfer_start_s, r.transfer_end_s, r.decode_start_s, r.done_s]
            assert all(a <= b + 1e-9 for a, b in zip(ts, ts[1:])), ts


class TestPaperEffects:
    def test_latency_grows_with_qps(self, cost):
        means = []
        for qps in (0.25, 1.0):
            reqs = fixed_requests(16384, 512, qps=qps, duration_s=120, seed=3)
            res = ClusterSim(cost, SimConfig(mode="push")).run(reqs)
            means.append(res.summary()["mean_total_s"])
        assert means[1] > means[0]

    def test_colocated_tbt_worse(self, cost):
        reqs = sample_requests(SHAREGPT, qps=0.5, duration_s=120, seed=4)
        disagg = ClusterSim(cost, SimConfig(mode="pull")).run(list(reqs)).summary()
        co = ClusterSim(cost, SimConfig(mode="colocated")).run(list(reqs)).summary()
        assert co["p90_tbt_s"] > disagg["p90_tbt_s"]

    def test_more_prefill_workers_cut_prefill_stage(self, cost):
        reqs = fixed_requests(16384, 128, qps=1.0, duration_s=120, seed=5)
        r1 = ClusterSim(cost, SimConfig(n_prefill=1)).run(list(reqs))
        r2 = ClusterSim(cost, SimConfig(n_prefill=2)).run(list(reqs))
        b1, b2 = r1.mean_breakdown(), r2.mean_breakdown()
        stage1 = b1["prefill_queue_s"] + b1["prefill_s"]
        stage2 = b2["prefill_queue_s"] + b2["prefill_s"]
        assert stage2 < stage1

    def test_coalescing_reduces_transfer_time(self, cost):
        t1 = cost.transfer_s(40_000, coalesce_factor=1.0)
        t64 = cost.transfer_s(40_000, coalesce_factor=64.0)
        assert t64 < t1

    def test_determinism(self, cost):
        reqs = sample_requests(SHAREGPT, qps=0.4, duration_s=60, seed=6)
        a = ClusterSim(cost, SimConfig()).run(list(reqs)).summary()
        b = ClusterSim(cost, SimConfig()).run(list(reqs)).summary()
        assert a == b


class TestWorkloads:
    def test_means_match_paper(self):
        reqs = sample_requests(ARXIV, qps=2.0, duration_s=2000, seed=0)
        mp = np.mean([r.prompt_len for r in reqs])
        mr = np.mean([r.response_len for r in reqs])
        assert 0.6 * 40642 < mp < 1.4 * 40642
        assert 0.6 * 241 < mr < 1.6 * 241

    def test_poisson_rate(self):
        reqs = sample_requests(SHAREGPT, qps=1.0, duration_s=4000, seed=1)
        assert 0.9 * 4000 < len(reqs) < 1.1 * 4000

    def test_bursty_seeded_deterministic(self):
        kw = dict(qps_on=2.0, qps_off=0.1, mean_on_s=30.0, mean_off_s=30.0,
                  duration_s=600.0, seed=3)
        a = bursty_requests(SHAREGPT, **kw)
        b = bursty_requests(SHAREGPT, **kw)
        # byte-for-byte: the SAME list drives sim AND real substrate
        assert [(r.request_id, r.arrival_s, r.prompt_len, r.response_len)
                for r in a] == \
               [(r.request_id, r.arrival_s, r.prompt_len, r.response_len)
                for r in b]
        assert bursty_requests(SHAREGPT, **{**kw, "seed": 4}) != a

    def test_bursty_rate_between_phases(self):
        reqs = bursty_requests(SHAREGPT, qps_on=2.0, qps_off=0.1,
                               mean_on_s=50.0, mean_off_s=50.0,
                               duration_s=4000.0, seed=5)
        ts = [r.arrival_s for r in reqs]
        assert ts == sorted(ts) and 0.0 <= ts[0] and ts[-1] < 4000.0
        # mean rate sits strictly between the off and on phase rates
        assert 0.1 * 4000 < len(reqs) < 2.0 * 4000

    def test_diurnal_rate_between_trough_and_peak(self):
        reqs = diurnal_requests(SHAREGPT, qps_peak=2.0, qps_trough=0.2,
                                period_s=1000.0, duration_s=4000.0, seed=6)
        ts = [r.arrival_s for r in reqs]
        assert ts == sorted(ts) and ts[-1] < 4000.0
        assert 0.2 * 4000 < len(reqs) < 2.0 * 4000
        assert reqs == diurnal_requests(SHAREGPT, qps_peak=2.0,
                                        qps_trough=0.2, period_s=1000.0,
                                        duration_s=4000.0, seed=6)

    def test_diurnal_rejects_inverted_rates(self):
        with pytest.raises(ValueError, match="exceeds"):
            diurnal_requests(SHAREGPT, qps_peak=0.5, qps_trough=1.0,
                             period_s=100.0, duration_s=10.0)
