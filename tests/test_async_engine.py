"""Async transfer engine: futures, incremental progress, layer-streamed
pulls, teardown-during-transfer, router admission batches, and the
overlapped serving path end to end.

The byte-movement invariant throughout: the incremental (budgeted) path
and the legacy one-shot ``drain()`` produce IDENTICAL destination bytes —
only scheduling differs.
"""
import jax
import numpy as np
import pytest

from repro.configs import get_config, get_smoke_config
from repro.core.connection import ChipInfo, ConnectionManager, DescriptorRegistry, WorkerInfo
from repro.core.cluster import ClusterScheduler
from repro.core.descriptors import ByteRange, CompleteTxn, ReadTxn
from repro.core.pull_push import pull_kv, pull_kv_async
from repro.core.transfer_engine import (
    ConnectionTornError,
    MemoryRegion,
    TransferEngine,
)
from repro.models.registry import build_model
from repro.sched import LoadReport, RequestRouter
from repro.sched.policies import RouteRequest
from repro.serving.blocks import BlockPool
from repro.serving.disagg import DisaggService
from repro.serving.kv_cache import PagedKVCache
from repro.serving.request import Request, RequestState
from repro.sim.costs import CostModel, H100_NODE
from repro.sim.events import ClusterSim, SimConfig
from repro.sim.workloads import SHAREGPT, sample_requests

DST_BASE = 1 << 20


def make_engine(**kw):
    eng = TransferEngine(**kw)
    src = np.arange(64 * 1024, dtype=np.uint8) % 251
    dst = np.zeros(64 * 1024, dtype=np.uint8)
    eng.register_memory(MemoryRegion("p0", 0, src))
    eng.register_memory(MemoryRegion("d0", DST_BASE, dst))
    return eng, src, dst


def read(rid, roff, loff, n=4096, layer=None):
    return ReadTxn(rid, "p0", "d0", ByteRange(roff, n), ByteRange(DST_BASE + loff, n),
                   layer=layer)


def winfo(wid, role):
    return WorkerInfo(wid, role, "10.0.0.1", (ChipInfo(0, f"ici://{wid}/0"),))


class TestFutures:
    def test_submit_returns_future_resolved_on_complete(self):
        eng, _, _ = make_engine()
        (fut,) = eng.submit([read("r1", 0, 0), CompleteTxn("r1", "p0", "d0")])
        assert fut.request_id == "r1" and not fut.done()
        eng.drain()
        assert fut.done() and not fut.failed
        assert fut.result() == "r1"

    def test_resolve_order_is_submission_independent(self):
        # r1's reads are submitted FIRST but its COMPLETE arrives last:
        # r2 must resolve before r1 even though it was submitted later.
        eng, _, _ = make_engine()
        (f1,) = eng.submit([read("r1", 0, 0)])
        (f2,) = eng.submit([read("r2", 4096, 4096), CompleteTxn("r2", "p0", "d0")])
        eng.submit([CompleteTxn("r1", "p0", "d0")])
        eng.drain()
        resolved = [f.request_id for f in eng.poll()]
        assert resolved == ["r2", "r1"]
        assert f1.done() and f2.done()

    def test_complete_before_reads_still_a_bug_incrementally(self):
        eng, _, _ = make_engine()
        eng.submit([CompleteTxn("r1", "p0", "d0"), read("r1", 0, 0)])
        with pytest.raises(RuntimeError, match="COMPLETE"):
            while eng.pending:
                eng.progress(1)

    def test_done_callback_fires_on_resolution(self):
        eng, _, _ = make_engine()
        (fut,) = eng.submit([read("r1", 0, 0), CompleteTxn("r1", "p0", "d0")])
        seen = []
        fut.add_done_callback(lambda f: seen.append(f.request_id))
        assert seen == []
        eng.drain()
        assert seen == ["r1"]
        # late registration fires immediately
        fut.add_done_callback(lambda f: seen.append("late"))
        assert seen == ["r1", "late"]

    def test_result_raises_while_in_flight(self):
        eng, _, _ = make_engine()
        (fut,) = eng.submit([read("r1", 0, 0)])
        with pytest.raises(RuntimeError, match="in flight"):
            fut.result()


class TestIncrementalProgress:
    def test_budget_caps_processed_txns(self):
        eng, _, _ = make_engine()
        eng.submit([read("r", i * 4096, i * 4096) for i in range(8)])
        assert eng.progress(3) == 3
        assert eng.pending == 5
        assert eng.progress() == 5
        assert eng.pending == 0

    @pytest.mark.parametrize("budget", [1, 3])
    def test_budgeted_progress_byte_identical_to_drain(self, budget):
        # Same transactions through drain() and through a budgeted
        # progress loop: destination bytes, bytes_moved, and completes
        # must match exactly (only reads_posted/coalescing may differ).
        txns = [read("r1", 0, 8192), read("r1", 4096, 12288),
                read("r2", 20480, 0, 2048), CompleteTxn("r1", "p0", "d0"),
                CompleteTxn("r2", "p0", "d0")]
        e1, _, dst1 = make_engine()
        e1.submit(list(txns))
        e1.drain()
        e2, _, dst2 = make_engine()
        e2.submit(list(txns))
        while eng_pending := e2.pending:
            e2.progress(budget)
            assert e2.pending < eng_pending  # always advances
        np.testing.assert_array_equal(dst1, dst2)
        assert e1.stats.bytes_moved == e2.stats.bytes_moved
        assert e1.stats.completes == e2.stats.completes

    def test_drain_is_progress_until_empty(self):
        eng, src, dst = make_engine()
        eng.submit([read("r1", 0, 0), CompleteTxn("r1", "p0", "d0")])
        eng.drain()
        np.testing.assert_array_equal(dst[:4096], src[:4096])
        assert eng.pending == 0


class TestTeardownDuringTransfer:
    def test_deregister_fails_queued_futures_typed(self):
        eng, _, _ = make_engine()
        (fut,) = eng.submit([read("rX", 0, 0), CompleteTxn("rX", "p0", "d0")])
        eng.deregister_memory("p0")
        assert fut.done() and fut.failed
        err = fut.exception()
        assert isinstance(err, ConnectionTornError)
        assert isinstance(err, KeyError)  # legacy callers still catch it
        assert err.worker_id == "p0"
        assert err.request_ids == ("rX",)
        assert eng.pending == 0  # torn transactions dropped, not executed
        with pytest.raises(ConnectionTornError):
            fut.result()

    def test_deregister_spares_unrelated_requests(self):
        eng = TransferEngine()
        src0 = np.arange(8192, dtype=np.uint8) % 251
        src1 = np.arange(8192, dtype=np.uint8) % 199
        dst = np.zeros(16384, dtype=np.uint8)
        eng.register_memory(MemoryRegion("p0", 0, src0))
        eng.register_memory(MemoryRegion("p1", 1 << 16, src1))
        eng.register_memory(MemoryRegion("d0", DST_BASE, dst))
        (f0,) = eng.submit([
            ReadTxn("r0", "p0", "d0", ByteRange(0, 4096), ByteRange(DST_BASE, 4096)),
            CompleteTxn("r0", "p0", "d0")])
        (f1,) = eng.submit([
            ReadTxn("r1", "p1", "d0", ByteRange(1 << 16, 4096),
                    ByteRange(DST_BASE + 4096, 4096)),
            CompleteTxn("r1", "p1", "d0")])
        eng.deregister_memory("p0")
        assert f0.failed and not f1.done()
        eng.drain()
        assert f1.done() and not f1.failed
        np.testing.assert_array_equal(dst[4096:8192], src1[:4096])

    def test_stale_submission_spares_cowindowed_request(self):
        # Reads submitted AFTER an MR was torn down share a coalescing
        # window with a healthy request: the torn read must fail only its
        # own future, the healthy request's bytes land and its COMPLETE
        # resolves normally on the next progress.
        eng = TransferEngine()
        src1 = np.arange(8192, dtype=np.uint8) % 199
        dst = np.zeros(16384, dtype=np.uint8)
        eng.register_memory(MemoryRegion("p0", 0, np.zeros(8192, np.uint8)))
        eng.register_memory(MemoryRegion("p1", 1 << 16, src1))
        eng.register_memory(MemoryRegion("d0", DST_BASE, dst))
        eng.deregister_memory("p0")  # queue empty: nothing to drop yet
        completed = []
        eng.on_complete(lambda c: completed.append(c.request_id))
        (f0,) = eng.submit([  # stale connection still posting to p0
            ReadTxn("r0", "p0", "d0", ByteRange(0, 4096), ByteRange(DST_BASE, 4096)),
            CompleteTxn("r0", "p0", "d0")])
        (f1,) = eng.submit([
            ReadTxn("r1", "p1", "d0", ByteRange(1 << 16, 4096),
                    ByteRange(DST_BASE + 4096, 4096)),
            CompleteTxn("r1", "p1", "d0")])
        with pytest.raises(ConnectionTornError):
            eng.drain()
        assert f0.failed and not f1.done()
        eng.drain()  # caller recovers: the healthy request is unharmed
        assert f1.done() and not f1.failed
        np.testing.assert_array_equal(dst[4096:8192], src1[:4096])
        # the torn request's COMPLETE was swallowed: its bytes never fully
        # landed, so the prefill-free callback must only fire for r1
        assert completed == ["r1"]

    def test_unregistered_read_raises_typed_error(self):
        eng = TransferEngine()
        (fut,) = eng.submit([read("r", 0, 0)])
        with pytest.raises(ConnectionTornError, match="unregistered"):
            eng.drain()
        assert fut.failed and fut.exception().request_ids == ("r",)


LAYERS, BLOCKS, BS, KVH, HD = 3, 16, 16, 2, 64


def kv_setup():
    pre = PagedKVCache("p0", num_layers=LAYERS, num_blocks=BLOCKS, block_size=BS,
                       kv_heads=KVH, head_dim=HD, base_address=0x1000_0000)
    dec = PagedKVCache("d0", num_layers=LAYERS, num_blocks=BLOCKS, block_size=BS,
                       kv_heads=KVH, head_dim=HD, base_address=0x2000_0000)
    eng = TransferEngine(coalescing="fifo")
    eng.register_memory(pre.memory_region())
    eng.register_memory(dec.memory_region())
    reg = DescriptorRegistry("p0")
    for d in pre.descriptors():
        reg.register(d)
    cm = ConnectionManager(winfo("d0", "decode"))
    conn = cm.connect(winfo("p0", "prefill"), reg)
    return pre, dec, eng, conn


def fill_blocks(cache, blocks, seed=0):
    rng = np.random.default_rng(seed)
    data = {}
    for layer in range(cache.num_layers):
        for b in blocks:
            k = rng.standard_normal((BS, KVH, HD)).astype(np.float32)
            v = rng.standard_normal((BS, KVH, HD)).astype(np.float32)
            cache.write_block(layer, b, k, v)
            data[(layer, b)] = cache.read_block(layer, b)
    return data


class TestLayerStreamedPull:
    def test_layers_complete_in_order_layer0_first(self):
        pre, dec, eng, conn = kv_setup()
        pre_pool, dec_pool = BlockPool(BLOCKS, block_size=BS), BlockPool(BLOCKS, block_size=BS)
        req = Request("r1", prompt_len=4 * BS, max_new_tokens=8)
        req.prefill_blocks = pre_pool.allocate(4)
        truth = fill_blocks(pre, req.prefill_blocks)

        fut = pull_kv_async(req, conn=conn, engine=eng, decode_pool=dec_pool,
                            decode_cache=dec)
        assert fut.layers_done == ()
        seen_layer0_before_done = False
        layer_history = []
        while eng.pending:
            eng.progress(2)
            layer_history.append(fut.layers_done)
            if 0 in fut.layers_done and not fut.done():
                # layer-0 KV must already be byte-exact in the decode slab
                # while the rest of the pull is still in flight
                for pb, db in zip(req.prefill_blocks, req.decode_blocks):
                    k, v = dec.read_block(0, db)
                    k_t, v_t = truth[(0, pb)]
                    np.testing.assert_array_equal(k, k_t)
                    np.testing.assert_array_equal(v, v_t)
                seen_layer0_before_done = True
        assert seen_layer0_before_done
        assert fut.done() and fut.layers_done == (0, 1, 2)  # strictly layer order
        # monotone growth, never reordered
        for a, b in zip(layer_history, layer_history[1:]):
            assert b[: len(a)] == a

    def test_async_pull_byte_identical_to_blocking_pull(self):
        # legacy pull_kv(drain=True) vs pull_kv_async + budgeted progress
        results = []
        for mode in ("drain", "async"):
            pre, dec, eng, conn = kv_setup()
            pre_pool, dec_pool = BlockPool(BLOCKS, block_size=BS), BlockPool(BLOCKS, block_size=BS)
            req = Request("r1", prompt_len=4 * BS, max_new_tokens=8)
            req.prefill_blocks = pre_pool.allocate(4)
            fill_blocks(pre, req.prefill_blocks)
            if mode == "drain":
                pull_kv(req, conn=conn, engine=eng, decode_pool=dec_pool,
                        decode_cache=dec)
            else:
                fut = pull_kv_async(req, conn=conn, engine=eng,
                                    decode_pool=dec_pool, decode_cache=dec)
                while not fut.done():
                    eng.progress(3)
            results.append(
                np.concatenate([dec.memory_region().buffer]))
        np.testing.assert_array_equal(results[0], results[1])


class TestRouterAdmissionBatches:
    def _router(self):
        sched = ClusterScheduler()
        for wid in ("d0", "d1"):
            sched.add_worker(winfo(wid, "decode"))
        return sched, RequestRouter(sched, "least_loaded")

    def _ctx(self, rid, prompt_len, arrival):
        return RouteRequest(rid, prompt_len, arrival_s=arrival)

    def test_batches_grouped_per_worker_fifo(self):
        _, router = self._router()
        queued = [
            (self._ctx("r2", 32, 2.0), "d0"),
            (self._ctx("r0", 32, 0.0), "d0"),
            (self._ctx("r1", 32, 1.0), "d1"),
        ]
        plan = router.plan_admissions(queued)
        assert plan == {"d0": ["r0", "r2"], "d1": ["r1"]}

    def test_capacity_caps_the_batch(self):
        sched, router = self._router()
        # d0 reports 3 free blocks of 32 tokens: only 3 one-block requests fit
        sched.report_load("d0", LoadReport("d0", "decode", free_blocks=3,
                                           total_blocks=8, block_size=32))
        queued = [(self._ctx(f"r{i}", 32, float(i)), "d0") for i in range(5)]
        plan = router.plan_admissions(queued)
        assert plan == {"d0": ["r0", "r1", "r2"]}

    def test_max_batch_cap(self):
        _, router = self._router()
        queued = [(self._ctx(f"r{i}", 32, float(i)), "d0") for i in range(5)]
        plan = router.plan_admissions(queued, max_batch=2)
        assert plan == {"d0": ["r0", "r1"]}

    def test_impossible_request_skipped_not_wedging_the_worker(self):
        sched, router = self._router()
        sched.report_load("d0", LoadReport("d0", "decode", free_blocks=4,
                                           total_blocks=8, block_size=32))
        queued = [
            (self._ctx("big", 32 * 100, 0.0), "d0"),   # needs 100 > total 8
            (self._ctx("small", 32, 1.0), "d0"),
        ]
        plan = router.plan_admissions(queued)
        assert plan == {"d0": ["small"]}  # can NEVER fit: don't wedge d0

    def test_head_of_line_blocks_batch_no_starvation(self):
        # The head request fits the worker (6 <= total 8) but not the
        # CURRENT budget (4 free): younger smaller requests must NOT jump
        # it, or it starves under a steady small-request stream.
        sched, router = self._router()
        sched.report_load("d0", LoadReport("d0", "decode", free_blocks=4,
                                           total_blocks=8, block_size=32))
        queued = [
            (self._ctx("head", 32 * 6, 0.0), "d0"),
            (self._ctx("young", 32, 1.0), "d0"),
        ]
        assert router.plan_admissions(queued) == {}


class TestSimOverlap:
    @pytest.fixture(scope="class")
    def cost(self):
        return CostModel(get_config("mistral-large-123b"), H100_NODE)

    @pytest.mark.parametrize("qps", [0.5, 2.0])
    def test_overlapped_ttft_strictly_below_blocking(self, cost, qps):
        # The acceptance shape of fig_overlap: batched async admission
        # beats the one-shot blocking pull at every QPS on the
        # KV-inclusive TTFT, and the layerwise consumer (join at the
        # layer-0 tail) sits at or below the full-pull async engine.
        reqs = sample_requests(SHAREGPT, qps=qps, duration_s=60, seed=11)
        block = ClusterSim(cost, SimConfig(
            n_prefill=2, n_decode=2, transfer_overlap="blocking",
            admission_batch=1)).run(list(reqs)).summary()
        over = ClusterSim(cost, SimConfig(
            n_prefill=2, n_decode=2, transfer_overlap="overlapped",
            admission_batch=8)).run(list(reqs)).summary()
        layer = ClusterSim(cost, SimConfig(
            n_prefill=2, n_decode=2, transfer_overlap="layerwise",
            admission_batch=8)).run(list(reqs)).summary()
        assert over["p50_ttft_kv_s"] < block["p50_ttft_kv_s"]
        assert over["p90_ttft_kv_s"] < block["p90_ttft_kv_s"]
        assert layer["p50_ttft_kv_s"] <= over["p50_ttft_kv_s"]
        assert layer["p90_ttft_kv_s"] <= over["p90_ttft_kv_s"]
        assert layer["p90_ttft_kv_s"] < block["p90_ttft_kv_s"]

    def test_all_modes_conserve_requests(self, cost):
        reqs = sample_requests(SHAREGPT, qps=0.5, duration_s=60, seed=7)
        for overlap in ("pipelined", "blocking", "overlapped", "layerwise"):
            sim = ClusterSim(cost, SimConfig(transfer_overlap=overlap,
                                             admission_batch=2))
            res = sim.run(list(reqs))
            assert len(res.requests) == len(reqs)
            assert all(r.done_s is not None for r in res.requests)
            for d in sim.decodes:
                assert d.used_tokens == 0 and not d.active and not d.kv_queue
            for p in sim.prefills:
                assert p.held_tokens == 0

    def test_bad_overlap_value_rejected(self, cost):
        with pytest.raises(ValueError, match="transfer_overlap"):
            ClusterSim(cost, SimConfig(transfer_overlap="asap"))


@pytest.fixture(scope="module")
def service_setup():
    cfg = get_smoke_config("deepseek-67b")
    model = build_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    return cfg, model, params


def monolithic_generate(model, params, tokens, n):
    import jax.numpy as jnp
    logits, state = model.prefill(params, {"tokens": jnp.asarray(tokens[None])},
                                  remat=False)
    out = [int(jnp.argmax(logits[0, : model.cfg.vocab_size]))]
    tok = jnp.asarray([out[-1]], jnp.int32)
    for _ in range(n):
        logits, state = model.decode_step(params, state, tok)
        tok = jnp.argmax(logits[:, : model.cfg.vocab_size], axis=-1).astype(jnp.int32)
        out.append(int(tok[0]))
    return out


class TestOverlappedService:
    def test_generate_many_matches_monolithic(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=2, n_decode=2, num_blocks=64)
        rng = np.random.default_rng(0)
        toks = [rng.integers(0, cfg.vocab_size, 64).astype(np.int32) for _ in range(4)]
        reqs = [svc.submit(t) for t in toks]
        # router plans per-decode-worker batches; pulls overlap decode
        got = svc.generate_many(reqs, max_new=4)
        for req, t in zip(reqs, toks):
            assert got[req.request_id] == monolithic_generate(model, params, t, 4)
            assert req.state is RequestState.DONE
        assert not svc.pending

    def test_admit_queued_is_batched_per_worker(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=2, num_blocks=64)
        rng = np.random.default_rng(1)
        reqs = [svc.submit(rng.integers(0, cfg.vocab_size, 32).astype(np.int32))
                for _ in range(4)]
        assert all(r.state is RequestState.KV_QUEUED for r in reqs)
        plan = svc.admit_queued()
        assert sorted(rid for rids in plan.values() for rid in rids) == \
            sorted(r.request_id for r in reqs)
        # pulls submitted but nothing promoted yet until the engine runs
        assert all(r.state is RequestState.KV_TRANSFER for r in reqs)
        while svc.engine.pending:
            svc.pump(8)
        svc.pump(0)
        assert all(r.state is RequestState.DECODING for r in reqs)
        svc.generate_many(reqs, max_new=2)

    def test_decode_steps_overlap_inflight_pulls(self, service_setup):
        # The point of the refactor: decode compute must run while later
        # waves' transfer transactions are still queued in the engine.
        # (generate_many now drives the continuous serving loop, so the
        # unit of decode work is DecodeWorker.step, not decode_round.)
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        rng = np.random.default_rng(5)
        reqs = [svc.submit(rng.integers(0, cfg.vocab_size, 64).astype(np.int32))
                for _ in range(4)]
        dw = svc.decode
        pending_at_step = []
        orig = dw.step

        def spy(**kw):
            pending_at_step.append(svc.engine.pending)
            return orig(**kw)

        dw.step = spy
        got = svc.generate_many(reqs, max_new=2)
        assert len(got) == 4
        assert any(p > 0 for p in pending_at_step), \
            "no decode step started while transfer txns were in flight"

    def test_mid_pull_prefill_death_reroutes(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=2, n_decode=1, num_blocks=64)
        rng = np.random.default_rng(2)
        tokens = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
        ref = monolithic_generate(model, params, tokens, 3)
        req = svc.submit(tokens)
        victim = req.prefill_worker
        svc.admit_queued()  # pull submitted, NOT drained
        assert req.state is RequestState.KV_TRANSFER
        fut = svc.decode.inflight[req.request_id].future
        svc.fail_prefill_worker(victim)  # mid-pull crash
        assert fut.failed and isinstance(fut.exception(), ConnectionTornError)
        # the router re-routed the request to the surviving prefill worker
        assert req.prefill_worker != victim
        assert req.retries == 1
        got = svc.generate_many([req], max_new=3)[req.request_id]
        assert got == ref

    def test_mid_pull_decode_death_restarts_from_prefill(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=2, num_blocks=64)
        rng = np.random.default_rng(3)
        tokens = rng.integers(0, cfg.vocab_size, 64).astype(np.int32)
        ref = monolithic_generate(model, params, tokens, 3)
        req = svc.submit(tokens)
        svc.admit_queued()
        victim = req.decode_worker
        svc.fail_decode_worker(victim)
        assert req.decode_worker != victim
        got = svc.generate_many([req], max_new=3)[req.request_id]
        assert got == ref
        assert req.retries == 1

    def test_build_state_page_cache_matches_fresh_gather(self, service_setup):
        cfg, model, params = service_setup
        svc = DisaggService(model, params, n_prefill=1, n_decode=1, num_blocks=64)
        rng = np.random.default_rng(4)
        reqs = [svc.submit(rng.integers(0, cfg.vocab_size, 64).astype(np.int32))
                for _ in range(2)]
        svc.admit_queued()
        svc.engine.drain()
        dw = svc.decode
        dw.pump(0)
        batch = list(dw.resident.values())
        cached = dw._build_state(batch, margin_blocks=1)
        for r in batch:  # drop the caches: force a full slab re-gather
            assert r.k_cached is not None  # the cache was actually used
            r.k_cached = r.v_cached = None
        fresh = dw._build_state(batch, margin_blocks=1)
        np.testing.assert_array_equal(np.asarray(cached.k_pages),
                                      np.asarray(fresh.k_pages))
        np.testing.assert_array_equal(np.asarray(cached.v_pages),
                                      np.asarray(fresh.v_pages))
        svc.generate_many(reqs, max_new=2)
